//! PJRT serving demo (feature `xla-runtime`): the Rust coordinator loads
//! the AOT-compiled L2 graphs (artifacts/*.hlo.txt) and trains the Boolean
//! MLP *through XLA* — the forward/backward runs in the compiled artifact,
//! the Boolean optimizer and Adam run natively in Rust on the returned
//! votes. Python is nowhere on this path.
//!
//!     make artifacts && cargo run --release --features xla-runtime --example hlo_serve [steps]
//!
//! Built without the feature, this example prints what is missing and
//! exits instead of failing to compile. For the dependency-free native
//! serving path, see `bold serve-native` and examples in
//! rust/benches/bench_serve.rs.

#[cfg(feature = "xla-runtime")]
mod demo {
    use bold::data::{BatchSampler, ImageDataset};
    use bold::nn::{ParamRef, ParamStore};
    use bold::optim::{Adam, BooleanOptimizer};
    use bold::runtime::PjrtExecutor;
    use bold::tensor::{BitMatrix, Tensor};
    use bold::util::Rng;

    pub fn run() {
        let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(60);
        let exec = match PjrtExecutor::load_dir("artifacts") {
            Ok(e) => e,
            Err(e) => {
                eprintln!("cannot load artifacts ({e:#}); run `make artifacts` first");
                std::process::exit(1);
            }
        };
        println!("PJRT platform {}, entries {:?}", exec.platform(), exec.entries());

        // Artifact dims (python/compile/model.py): 784 → 512 → 256 → 10, batch 128.
        let (batch, d_in, h1, h2, classes) = (128usize, 784usize, 512usize, 256usize, 10usize);
        let (train, val) =
            ImageDataset::mnist_like(4096 + 1024, classes, d_in, 0.08, 3).split(4096);

        let mut rng = Rng::new(42);
        // Boolean weights live in Rust as packed bits; the artifact takes the
        // ±1 embedding (Prop. A.2 makes the two exactly equivalent).
        let mut w1 = BitMatrix::random(h1, d_in, &mut rng);
        let mut w2 = BitMatrix::random(h2, h1, &mut rng);
        let mut wfc = Tensor::randn(&[classes, h2], 0.05, &mut rng);
        let mut bfc = Tensor::zeros(&[classes]);

        // Accumulators m, ratios β and Adam moments live in the store.
        let mut store = ParamStore::new();
        let bool_opt = BooleanOptimizer::new(4.0);
        let mut adam = Adam::new(1e-3);
        let mut sampler = BatchSampler::new(train.n, batch, 1);
        let onehot = |labels: &[usize]| {
            let mut y = Tensor::zeros(&[labels.len(), classes]);
            for (i, &l) in labels.iter().enumerate() {
                *y.at2_mut(i, l) = 1.0;
            }
            y
        };

        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let idx = sampler.next_batch();
            let (x, labels) = train.batch_flat(&idx);
            let y = onehot(&labels);
            let out = exec
                .execute(
                    "bool_mlp_train_step",
                    &[x, y, w1.to_pm1(), w2.to_pm1(), wfc.clone(), bfc.clone()],
                )
                .expect("train step");
            // outputs: loss, n_correct, q_w1, q_w2, g_wfc, g_bfc
            let loss = out[0].data[0];
            let correct = out[1].data[0];
            // the artifact's q votes are the grads the Boolean optimizer consumes
            store.zero_grads();
            store.accumulate("w1", &out[2]);
            store.accumulate("w2", &out[3]);
            store.accumulate("wfc", &out[4]);
            store.accumulate("bfc", &out[5]);
            let mut params = vec![
                ParamRef::Bool { name: "w1".into(), bits: &mut w1 },
                ParamRef::Bool { name: "w2".into(), bits: &mut w2 },
            ];
            let stats = bool_opt.step(&mut params, &mut store);
            let mut fc_params = vec![
                ParamRef::Real { name: "wfc".into(), w: &mut wfc },
                ParamRef::Real { name: "bfc".into(), w: &mut bfc },
            ];
            adam.step(&mut fc_params, &mut store);
            if step % 10 == 0 {
                println!(
                    "step {step:>4}: loss {loss:>7.4}  acc {:>5.3}  flips {}",
                    correct / batch as f32,
                    stats.flips
                );
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        println!(
            "{steps} XLA train steps in {elapsed:.2}s ({:.1} ms/step)",
            elapsed * 1e3 / steps as f64
        );

        // Validation through the inference artifact.
        let mut correct = 0usize;
        let mut seen = 0usize;
        let mut i = 0;
        while i + batch <= val.n {
            let idx: Vec<usize> = (i..i + batch).collect();
            let (x, labels) = val.batch_flat(&idx);
            let out = exec
                .execute("bool_mlp_infer", &[x, w1.to_pm1(), w2.to_pm1(), wfc.clone(), bfc.clone()])
                .expect("infer");
            let preds = out[0].argmax_rows();
            correct += preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
            seen += labels.len();
            i += batch;
        }
        println!(
            "validation accuracy (XLA path): {:.2}%",
            correct as f32 / seen as f32 * 100.0
        );
        assert!(correct as f32 / seen as f32 > 0.85);
        println!("OK — the compiled L2 graph trains the Boolean model with no Python on the path.");
    }
}

#[cfg(feature = "xla-runtime")]
fn main() {
    demo::run();
}

#[cfg(not(feature = "xla-runtime"))]
fn main() {
    eprintln!(
        "hlo_serve needs the XLA/PJRT path, which this build omits.\n\
         rebuild with `cargo run --release --features xla-runtime --example hlo_serve`\n\
         (and link a real xla binding — see rust/vendor/xla-stub/README.md).\n\
         For dependency-free serving, use the native engine: `bold serve-native`."
    );
    std::process::exit(1);
}
