//! Boolean BERT fine-tuning on GLUE-like tasks (paper §4.3, Table 7):
//! a transformer encoder whose Q/K/V/FFN projections are native Boolean
//! layers trained with Boolean logic, attention/LayerNorm/head in FP.
//!
//!     cargo run --release --example bert_glue [steps]

use bold::data::{BatchSampler, GlueLikeTask, NlpDataset};
use bold::models::bert::{BertConfig, BertMini};
use bold::nn::{softmax_cross_entropy, ParamStore};
use bold::optim::{Adam, BooleanOptimizer, CosineSchedule};
use bold::util::Rng;

fn main() {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let len = 12;
    let vocab = 32;
    let cfg = BertConfig { vocab, max_len: len, d: 24, ff: 48, layers: 2, classes: 2 };
    println!("Boolean BERT-mini on GLUE-like tasks ({} steps each)\n", steps);
    println!("{:<14} {:>10} {:>12}", "task", "acc (%)", "flips/step");

    let mut accs = Vec::new();
    for task in GlueLikeTask::all() {
        let train = NlpDataset::generate(task, 1024, len, vocab, 42);
        let val = NlpDataset::generate(task, 256, len, vocab, 43);
        let mut rng = Rng::new(7);
        let mut model = BertMini::new(&cfg, &mut rng);
        let sched = CosineSchedule::new(1.0, 0.05, steps);
        let mut adam = Adam::new(2e-3);
        let mut store = ParamStore::new();
        let mut sampler = BatchSampler::new(train.n, 32, 1);
        let mut flips_total = 0usize;
        for step in 0..steps {
            let idx = sampler.next_batch();
            let (toks, labels) = train.batch(&idx);
            let logits = model.forward(&toks, idx.len(), len, true);
            let out = softmax_cross_entropy(&logits, &labels);
            store.zero_grads();
            model.backward(out.grad, &mut store);
            let mut params = model.params();
            flips_total +=
                BooleanOptimizer::new(sched.at(step)).step(&mut params, &mut store).flips;
            adam.step(&mut params, &mut store);
        }
        // evaluate
        let idx: Vec<usize> = (0..val.n).collect();
        let (toks, labels) = val.batch(&idx);
        let logits = model.forward(&toks, val.n, len, false);
        let preds = logits.argmax_rows();
        let acc = preds.iter().zip(&labels).filter(|(p, l)| p == l).count() as f32
            / val.n as f32
            * 100.0;
        accs.push(acc);
        println!(
            "{:<14} {:>10.1} {:>12.1}",
            task.name(),
            acc,
            flips_total as f64 / steps as f64
        );
    }
    let avg = accs.iter().sum::<f32>() / accs.len() as f32;
    println!("{:<14} {:>10.1}", "Avg.", avg);
    println!("\n(paper Table 7: B⊕LD avg 70.9 on GLUE, on par with BiT's 71.0)");
    assert!(avg > 58.0, "Boolean BERT should beat chance comfortably: {avg}");
}
