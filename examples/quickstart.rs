//! Quickstart: train a native Boolean MLP with Boolean logic — no gradient
//! descent, no FP latent weights — in under a minute on a laptop CPU.
//!
//!     cargo run --release --example quickstart
//!
//! What happens: a 2-hidden-layer MLP whose interior weights are single
//! bits is trained by the paper's Boolean optimizer (accumulate votes,
//! flip where xnor(m, w) = T), while only the 10-unit FP head uses Adam.

use bold::config::TrainConfig;
use bold::coordinator::ClassifierTrainer;
use bold::data::ImageDataset;
use bold::models::{boolean_mlp, MlpConfig};
use bold::nn::Layer;
use bold::util::Rng;

fn main() {
    let cfg = TrainConfig {
        model: "mlp".into(),
        steps: 150,
        batch: 64,
        lr_bool: 4.0,
        lr_fp: 1e-3,
        train_size: 2048,
        val_size: 512,
        classes: 10,
        ..Default::default()
    };
    println!("B⊕LD quickstart — Boolean MLP on a binary pattern task");

    // Binary ±1 features: 10 classes of 256-bit prototypes + 8% bit flips.
    let (train, val) =
        ImageDataset::mnist_like(cfg.train_size + cfg.val_size, 10, 256, 0.08, cfg.seed)
            .split(cfg.train_size);

    let mcfg = MlpConfig { d_in: 256, hidden: vec![128, 64], d_out: 10, tanh_scale: true };
    let mut rng = Rng::new(cfg.seed);
    let mut model = boolean_mlp(&mcfg, &mut rng);

    let n_bool: usize = model
        .params()
        .iter()
        .filter(|p| matches!(p, bold::nn::ParamRef::Bool { .. }))
        .map(|p| p.len())
        .sum();
    let n_real: usize = model
        .params()
        .iter()
        .filter(|p| matches!(p, bold::nn::ParamRef::Real { .. }))
        .map(|p| p.len())
        .sum();
    println!("parameters: {n_bool} Boolean bits + {n_real} FP scalars (head only)");

    let mut trainer = ClassifierTrainer::new(&cfg);
    let report = trainer.fit(&mut model, &train, &val, &cfg, true);

    println!("\nvalidation accuracy: {:.2}%", report.val_acc * 100.0);
    println!(
        "memory for the Boolean weights: {} bytes (32x smaller than FP32)",
        n_bool / 8
    );
    assert!(report.val_acc > 0.9, "expected >90% on this task");
    println!("OK — Boolean-logic training works.");
}
