//! End-to-end driver (DESIGN.md §End-to-end validation): train the Boolean
//! VGG-SMALL on a CIFAR-like workload for a few hundred steps, with the
//! full coordinator stack — config, data pipeline, augmentation,
//! dual-optimizer training, metric logging, checkpointing — then evaluate,
//! reload the checkpoint and verify bit-exact restoration.
//!
//!     cargo run --release --example train_cifar [steps]
//!
//! The loss curve is written to target/train_cifar_metrics.csv and the
//! run is recorded in EXPERIMENTS.md.

use bold::config::TrainConfig;
use bold::coordinator::{
    evaluate_classifier, load_model, save_model, ClassifierTrainer, MetricLog,
};
use bold::data::{random_crop_flip, BatchSampler, ImageDataset};
use bold::models::{vgg_small, VggConfig, VggKind};
use bold::nn::{Layer, Value};
use bold::util::Rng;

fn main() {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let cfg = TrainConfig {
        model: "vgg".into(),
        method: "bold".into(),
        steps,
        batch: 64,
        lr_bool: 8.0,
        lr_fp: 2e-3,
        train_size: 2048,
        val_size: 512,
        hw: 16,
        width_mult: 0.125,
        classes: 10,
        ..Default::default()
    };
    println!("E2E: Boolean VGG-SMALL on CIFAR-like 16x16x3, {} steps", cfg.steps);

    let (train, val) = ImageDataset::cifar_like(
        cfg.train_size + cfg.val_size,
        cfg.classes,
        3,
        cfg.hw,
        0.25,
        cfg.seed,
    )
    .split(cfg.train_size);

    let vcfg = VggConfig {
        kind: VggKind::Bold,
        hw: cfg.hw,
        width_mult: cfg.width_mult,
        classes: cfg.classes,
        ..Default::default()
    };
    let mut rng = Rng::new(cfg.seed);
    let mut model = vgg_small(&vcfg, &mut rng);
    println!("model: {} ({} trainable scalars)", model.name(), model.param_count());

    let mut trainer = ClassifierTrainer::new(&cfg);
    let mut sampler = BatchSampler::new(train.n, cfg.batch, cfg.seed);
    let mut aug_rng = Rng::new(cfg.seed ^ 0xA06);
    let mut log = MetricLog::new();
    let t0 = std::time::Instant::now();
    for step in 0..cfg.steps {
        let idx = sampler.next_batch();
        let (x, labels) = train.batch(&idx);
        let x = random_crop_flip(&x, 2, &mut aug_rng);
        let (loss, correct, stats) = trainer.train_step(&mut model, Value::F32(x), &labels, step);
        log.push("loss", step, loss as f64);
        log.push("train_acc", step, correct as f64 / labels.len() as f64);
        log.push("flip_rate", step, stats.flip_rate() as f64);
        if step % 25 == 0 || step + 1 == cfg.steps {
            println!(
                "step {step:>4}  loss {loss:>7.4}  batch-acc {:>5.2}  flips/weight {:>8.5}",
                correct as f32 / labels.len() as f32,
                stats.flip_rate()
            );
        }
    }
    let train_time = t0.elapsed().as_secs_f64();
    let val_acc = evaluate_classifier(&mut model, &val, cfg.batch);
    println!(
        "\ntrained {} steps in {:.1}s  ({:.1} ms/step)",
        cfg.steps,
        train_time,
        train_time * 1e3 / cfg.steps as f64
    );
    println!("validation accuracy: {:.2}%", val_acc * 100.0);

    // Checkpoint round-trip: save, load into a fresh model, compare.
    let ckpt = std::env::temp_dir().join("bold_train_cifar.ckpt");
    let ckpt = ckpt.to_str().unwrap();
    save_model(&mut model, ckpt).expect("save");
    let mut model2 = vgg_small(&vcfg, &mut Rng::new(999));
    load_model(&mut model2, ckpt).expect("load");
    let acc2 = evaluate_classifier(&mut model2, &val, cfg.batch);
    assert!((acc2 - val_acc).abs() < 1e-6, "checkpoint must restore bit-exactly");
    println!("checkpoint round-trip OK ({ckpt})");

    std::fs::create_dir_all("target").ok();
    let csv = "target/train_cifar_metrics.csv";
    log.write_csv(csv).expect("csv");
    println!("loss curve written to {csv}");
}
