//! Super-resolution demo (paper §4.2): train the Boolean small-EDSR on
//! procedural texture patches with the L1 loss, report PSNR against an FP
//! small-EDSR baseline and against bicubic-like box upsampling.
//!
//!     cargo run --release --example super_resolution [steps]

use bold::data::{BatchSampler, SrDataset};
use bold::models::edsr::psnr;
use bold::models::{edsr_small, EdsrConfig};
use bold::nn::{l1_loss, Layer, ParamStore, Value};
use bold::optim::{Adam, BooleanOptimizer};
use bold::tensor::Tensor;
use bold::util::Rng;

fn train(cfg: &EdsrConfig, steps: usize, seed: u64) -> (f32, f64) {
    let train = SrDataset::textures(96, 3, 8, cfg.scale, seed);
    let val = SrDataset::textures(16, 3, 8, cfg.scale, seed + 1);
    let mut rng = Rng::new(seed);
    let mut model = edsr_small(cfg, &mut rng);
    let bool_opt = BooleanOptimizer::new(6.0);
    let mut adam = Adam::new(1e-3);
    let mut store = ParamStore::new();
    let mut sampler = BatchSampler::new(train.n, 8, seed);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let idx = sampler.next_batch();
        let (lr, hr) = train.batch(&idx);
        let pred = model.forward(Value::F32(lr), true).expect_f32("sr");
        let out = l1_loss(&pred, &hr);
        store.zero_grads();
        let _ = model.backward(out.grad, &mut store);
        let mut params = model.params();
        bool_opt.step(&mut params, &mut store);
        adam.step(&mut params, &mut store);
        if step % 50 == 0 {
            println!("  step {step:>4}: L1 {:.4}", out.loss);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let idx: Vec<usize> = (0..val.n).collect();
    let (lr, hr) = val.batch(&idx);
    let pred = model.forward(Value::F32(lr), false).expect_f32("sr");
    (psnr(&pred, &hr), secs)
}

/// Nearest-neighbour upsample baseline PSNR (no learning at all).
fn naive_baseline(scale: usize, seed: u64) -> f32 {
    let val = SrDataset::textures(16, 3, 8, scale, seed + 1);
    let idx: Vec<usize> = (0..val.n).collect();
    let (lr, hr) = val.batch(&idx);
    let (n, c, h, w) = lr.dims4();
    let mut up = Tensor::zeros(&hr.shape);
    for ni in 0..n {
        for ci in 0..c {
            for y in 0..h * scale {
                for x in 0..w * scale {
                    up.data[((ni * c + ci) * h * scale + y) * w * scale + x] =
                        lr.data[((ni * c + ci) * h + y / scale) * w + x / scale];
                }
            }
        }
    }
    psnr(&up, &hr)
}

fn main() {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    println!("Boolean EDSR super-resolution (x2), {} steps\n", steps);
    let scale = 2;

    println!("training FP small-EDSR baseline…");
    let fp_cfg = EdsrConfig { features: 16, blocks: 3, scale, boolean: false, ..Default::default() };
    let (psnr_fp, t_fp) = train(&fp_cfg, steps, 31);

    println!("training B⊕LD EDSR (Boolean residual blocks)…");
    let bold_cfg = EdsrConfig { features: 16, blocks: 3, scale, boolean: true, ..Default::default() };
    let (psnr_bold, t_bold) = train(&bold_cfg, steps, 31);

    let psnr_naive = naive_baseline(scale, 31);
    println!("\n{:<28} {:>10} {:>12}", "method", "PSNR (dB)", "train time");
    println!("{:<28} {:>10.2} {:>11.1}s", "nearest-neighbour upsample", psnr_naive, 0.0);
    println!("{:<28} {:>10.2} {:>11.1}s", "SMALL EDSR (FP)", psnr_fp, t_fp);
    println!("{:<28} {:>10.2} {:>11.1}s", "B⊕LD EDSR", psnr_bold, t_bold);
    println!("\n(paper Table 3, x2 on Set5: FP 38.01 vs B⊕LD 37.42 — sub-dB gap)");
    assert!(psnr_bold > psnr_naive, "learned SR must beat naive upsampling");
}
