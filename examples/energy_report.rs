//! Energy report: the Appendix E analytic model on the paper's exact
//! architectures — regenerates the Cons.(%) columns of Tables 2/5 and the
//! energy axis of Fig. 1.
//!
//!     cargo run --release --example energy_report

use bold::energy::{
    conv_energy, network_energy, resnet18_shapes, vgg_small_shapes, ConvShape, Method, Phase,
    ASCEND, V100,
};

fn main() {
    // ------------- Table 2 energy columns (VGG-SMALL, CIFAR10) ----------
    for hw in [ASCEND(), V100()] {
        println!("=== {} — VGG-SMALL (batch 100), 1 training iteration", hw.name);
        let shapes = vgg_small_shapes(100);
        let fp = network_energy(&shapes, &hw, Method::Fp32, true).total_pj();
        println!(
            "{:<18} {:>12} {:>9} {:>9} {:>8} {:>9}",
            "method", "total (µJ)", "comp%", "mem%", "opt%", "vs FP%"
        );
        for m in Method::all() {
            let e = network_energy(&shapes, &hw, m, true);
            let t = e.total_pj();
            println!(
                "{:<18} {:>12.1} {:>9.1} {:>9.1} {:>8.1} {:>9.2}",
                m.name(),
                t / 1e6,
                e.compute_pj / t * 100.0,
                e.mem_pj / t * 100.0,
                e.optimizer_pj / t * 100.0,
                t / fp * 100.0
            );
        }
        println!();
    }

    // ------------- Table 5 energy columns (ResNet18, ImageNet) ----------
    let hw = V100();
    println!("=== {} — ResNet18 base sweep (batch 32), vs FP base-64", hw.name);
    let fp = network_energy(&resnet18_shapes(32, 64), &hw, Method::Fp32, true).total_pj();
    for base in [64usize, 128, 192, 256] {
        let e = network_energy(&resnet18_shapes(32, base), &hw, Method::Bold, true).total_pj();
        println!("B⊕LD base {base:<4} {:>8.2}% of FP training energy", e / fp * 100.0);
    }
    println!("(paper Table 5: base 256 at 24.45% of FP on V100)");
    println!();

    // ------------- per-layer breakdown of one conv ----------------------
    println!("=== per-layer anatomy: conv2a of VGG-SMALL (256x128x3x3 on 16x16)");
    let shape = ConvShape { n: 100, c: 128, m: 256, h: 16, w: 16, k: 3, stride: 1, pad: 1 };
    for m in [Method::Fp32, Method::BinaryNet, Method::Bold] {
        let bits = bold::energy::method_bitwidths(m);
        let f = conv_energy(&shape, &hw, &bits, Phase::Forward);
        let b = conv_energy(&shape, &hw, &bits, Phase::Backward);
        println!(
            "{:<18} fwd {:>10.1} µJ (comp {:>6.1} mem {:>6.1})   bwd {:>10.1} µJ",
            m.name(),
            f.total() / 1e6,
            f.compute_pj / 1e6,
            f.mem_pj / 1e6,
            b.total() / 1e6
        );
    }
}
