# AOT lowering: jax (L2) -> HLO TEXT -> artifacts/*.hlo.txt
#
# HLO *text* (not HloModuleProto.serialize()) is the interchange format: the
# published `xla` crate ships xla_extension 0.5.1, which rejects jax>=0.5
# protos (64-bit instruction ids, `proto.id() <= INT_MAX`); the text parser
# reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.
#
# Run `make artifacts` (idempotent: skips when outputs are newer than the
# compile/ sources).  Python runs ONCE here; the Rust binary is
# self-contained afterwards.
import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries():
    """(name, fn, example_args) for every artifact we ship."""
    x_s, y_s = model.batch_specs()
    w1_s, w2_s, wfc_s, bfc_s = model.param_specs()
    cx_s, = model.cnn_batch_specs()
    cw1_s, cw2_s, cwfc_s, cbfc_s = model.cnn_param_specs()
    return [
        (
            "bool_mlp_infer",
            model.bool_mlp_infer,
            (x_s, w1_s, w2_s, wfc_s, bfc_s),
        ),
        (
            "bool_mlp_train_step",
            model.bool_mlp_train_step,
            (x_s, y_s, w1_s, w2_s, wfc_s, bfc_s),
        ),
        (
            "bool_cnn_infer",
            model.bool_cnn_infer,
            (cx_s, cw1_s, cw2_s, cwfc_s, cbfc_s),
        ),
    ]


def spec_meta(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser(description="Lower B⊕LD L2 graphs to HLO text")
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the sentinel artifact (Makefile stamp); "
                    "all artifacts land in its directory")
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"batch": model.BATCH, "entries": {}}
    for name, fn, specs in entries():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [spec_meta(s) for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # Sentinel for the Makefile dependency (model.hlo.txt == mlp train step).
    sentinel = os.path.abspath(args.out)
    src = os.path.join(out_dir, "bool_mlp_train_step.hlo.txt")
    if sentinel != src:
        with open(src) as f_in, open(sentinel, "w") as f_out:
            f_out.write(f_in.read())
    print(f"manifest + sentinel written to {out_dir}")


if __name__ == "__main__":
    main()
