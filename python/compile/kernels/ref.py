# Pure-jnp correctness oracle for the L1 Pallas kernels.
#
# Everything is expressed in the ±1 *embedded* domain of Proposition A.2 of
# the paper:  e : (B, xnor) -> ({±1}, ×)  with e(T)=+1, e(F)=-1.  Under this
# isomorphism the Boolean neuron of Eq. (1),
#     s = w0 + sum_i xnor(w_i, x_i)          (counting of TRUEs - FALSEs)
# is exactly the integer-valued dot product  s = b + <e(x), e(w)>, and the
# Boolean backward of Algorithms 6/7 (Appendix B) is a plain matmul with the
# embedded weights/inputs.  All reference functions below therefore take and
# return ±1-valued (or integer/real-valued) arrays; the bit-level Boolean
# engine lives on the Rust side and is cross-checked against these semantics.
import jax.numpy as jnp
import numpy as np

__all__ = [
    "xnor_linear_fwd_ref",
    "xnor_linear_bwd_ref",
    "threshold_act_ref",
    "tanh_prime_scale_ref",
    "bool_opt_step_ref",
    "alpha_for_fanin",
]


def xnor_linear_fwd_ref(x, w, bias=None):
    """Boolean linear forward, Eq. (3), in the ±1 embedding.

    x:    (batch, m)  ±1
    w:    (n, m)      ±1   (row-major: one row per output neuron)
    bias: (n,) integer or None
    returns (batch, n) integer-valued pre-activations
            s_kj = b_j + sum_i xnor(w_ji, x_ki)  ==  b_j + <x_k, w_j>
    """
    s = x @ w.T
    if bias is not None:
        s = s + bias[None, :]
    return s


def xnor_linear_bwd_ref(z, x, w):
    """Boolean backward for the xnor-linear layer (Algorithms 6/7).

    With the xnor kernel, the atomic variations of Eq. (4) are
        δs_kj/δw_ji = x_ki      δs_kj/δx_ki = w_ji
    and the aggregations of Eq. (7)/(8) are, in the embedded domain,
        q_ji = sum_k  z_kj · x_ki        (vote over the batch)
        g_ki = sum_j  z_kj · w_ji        (vote over the outputs)
    which hold verbatim whether z is a real-valued downstream gradient
    (Algorithm 7) or an embedded Boolean signal in {±1} (Algorithm 6).

    z: (batch, n) downstream signal;  x: (batch, m) ±1;  w: (n, m) ±1.
    returns (g_x: (batch, m), q_w: (n, m), q_b: (n,))
    """
    g_x = z @ w
    q_w = z.T @ x
    q_b = z.sum(axis=0)
    return g_x, q_w, q_b


def threshold_act_ref(s, tau=0.0):
    """Forward Boolean activation (§3.1): T (=+1) iff s >= tau."""
    return jnp.where(s >= tau, 1.0, -1.0).astype(s.dtype)


def alpha_for_fanin(m):
    """Pre-activation scaling α = π / (2 sqrt(3 m)), Eq. (24) (Appendix C.3)."""
    return np.pi / (2.0 * np.sqrt(3.0 * float(m)))


def tanh_prime_scale_ref(z, s, fanin, tau=0.0):
    """Backprop re-weighting through the threshold activation (Appendix C).

    The downstream signal z is attenuated by tanh'(α·(s-τ)) = 1 - tanh²(α·Δ)
    so that an action on a weight far from the threshold contributes less.
    """
    alpha = alpha_for_fanin(fanin)
    t = jnp.tanh(alpha * (s - tau))
    return z * (1.0 - t * t)


def bool_opt_step_ref(w, accum, grad, lr, ratio):
    """One Boolean-optimizer step (Algorithm 8) in the ±1 embedding.

    w:     (...,) ±1 Boolean weights (embedded)
    accum: (...,) real accumulator m_t
    grad:  (...,) aggregated optimization signal q_t
    lr:    scalar η
    ratio: scalar β_t  (fraction of unchanged weights at t-1, per tensor)

    accum' = ratio·accum + lr·grad
    flip where accum'·w >= 1  (xnor(m, w) = T with |m| >= 1, Eq. (9))
    w' = -w there, accum' reset to 0 there (Algorithm 1 lines 11-13)
    ratio' = 1 - mean(flipped)                        (Eq. (11))
    returns (w', accum', ratio')
    """
    acc = ratio * accum + lr * grad
    flip = (acc * w) >= 1.0
    w_new = jnp.where(flip, -w, w)
    acc_new = jnp.where(flip, 0.0, acc)
    ratio_new = 1.0 - jnp.mean(flip.astype(jnp.float32))
    return w_new, acc_new, ratio_new
