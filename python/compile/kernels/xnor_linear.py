# L1 Pallas kernels for the Boolean linear layer (paper §3.1/§3.3, App. B).
#
# Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's Boolean
# neuron is a popcount of XNORs.  On the TPU MXU the profitable mapping is
# the ±1 embedding of Proposition A.2 — xnor becomes multiply, counting
# becomes the systolic accumulation — so each kernel below is a *tiled ±1
# matmul* whose BlockSpec expresses the HBM↔VMEM schedule (bm×bk / bk×bn
# tiles double-buffered by the pipeline, fp32 accumulator tile resident in
# VMEM).  interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
# custom-calls; on a real TPU the same kernels lower to MXU matmuls.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic array edge; the K tile of
# 512 keeps the working set (2·128·512·4B + 128·128·4B ≈ 580 KiB) well under
# a 16 MiB VMEM budget while amortizing the accumulator revisit.
BM, BN, BK = 128, 128, 512


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """Grid (M/bm, N/bn, K/bk): accumulate x_tile @ w_tile into o_tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a, mult0, mult1):
    """Zero-pad a 2-D array up to multiples of (mult0, mult1).

    Zero padding is exact for the ±1 embedding: padded inputs contribute
    e(0)=0 — the 𝕄 three-valued logic of Definition 3.1, where any logic op
    with a 0 operand yields 0 — so padded lanes add nothing to the count.
    """
    p0 = (-a.shape[0]) % mult0
    p1 = (-a.shape[1]) % mult1
    if p0 == 0 and p1 == 0:
        return a
    return jnp.pad(a, ((0, p0), (0, p1)))


def matmul_pallas(x, w, bm=BM, bn=BN, bk=BK, interpret=True):
    """Tiled matmul  (M,K) @ (K,N) -> (M,N)  via pallas_call.

    Shapes need not be multiples of the tile; inputs are zero-padded (exact,
    see _pad_to) and the result is sliced back.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    xp = _pad_to(x.astype(jnp.float32), bm_, bk_)
    wp = _pad_to(w.astype(jnp.float32), bk_, bn_)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def xnor_linear_fwd(x, w, bias=None, interpret=True):
    """Boolean linear forward, Eq. (3):  s = b + x @ wᵀ  in the ±1 embedding.

    x (batch, m) ±1;  w (n, m) ±1;  bias (n,) or None.
    The transpose is folded into the BlockSpec index map (w is read
    tile-transposed), not materialized.
    """
    s = matmul_pallas(x, w.T, interpret=interpret)
    if bias is not None:
        s = s + bias[None, :]
    return s


def xnor_linear_bwd(z, x, w, interpret=True):
    """Boolean backward (Algorithms 6/7): three ±1 matmuls.

    g_x = z @ w       — upstream signal, Eq. (8) aggregation over outputs
    q_w = zᵀ @ x      — weight vote,     Eq. (7) aggregation over the batch
    q_b = Σ_k z       — bias vote (bias pairs with constant TRUE input)
    """
    g_x = matmul_pallas(z, w, interpret=interpret)
    q_w = matmul_pallas(z.T, x, interpret=interpret)
    q_b = z.sum(axis=0)
    return g_x, q_w, q_b


# ---------------------------------------------------------------------------
# Elementwise kernels
# ---------------------------------------------------------------------------


def _threshold_kernel(s_ref, o_ref, *, tau: float):
    o_ref[...] = jnp.where(s_ref[...] >= tau, 1.0, -1.0)


def threshold_act(s, tau=0.0, interpret=True):
    """Forward Boolean activation (§3.1): +1 iff s >= τ (VPU elementwise)."""
    return pl.pallas_call(
        functools.partial(_threshold_kernel, tau=float(tau)),
        out_shape=jax.ShapeDtypeStruct(s.shape, jnp.float32),
        interpret=interpret,
    )(s.astype(jnp.float32))


def _tanh_prime_kernel(z_ref, s_ref, o_ref, *, alpha: float, tau: float):
    t = jnp.tanh(alpha * (s_ref[...] - tau))
    o_ref[...] = z_ref[...] * (1.0 - t * t)


def tanh_prime_scale(z, s, fanin, tau=0.0, interpret=True):
    """Appendix C backprop re-weighting: z · tanh'(α(s-τ)), α=π/(2√(3m))."""
    import numpy as np

    alpha = float(np.pi / (2.0 * np.sqrt(3.0 * float(fanin))))
    return pl.pallas_call(
        functools.partial(_tanh_prime_kernel, alpha=alpha, tau=float(tau)),
        out_shape=jax.ShapeDtypeStruct(z.shape, jnp.float32),
        interpret=interpret,
    )(z.astype(jnp.float32), s.astype(jnp.float32))


def _opt_step_kernel(w_ref, m_ref, q_ref, lr_ref, r_ref, wo_ref, mo_ref, f_ref):
    """Boolean optimizer flip step (Algorithm 8), elementwise on the VPU.

    Outputs the new weights, new accumulator and a flip mask (for β_{t+1}).
    """
    acc = r_ref[0] * m_ref[...] + lr_ref[0] * q_ref[...]
    flip = (acc * w_ref[...]) >= 1.0
    wo_ref[...] = jnp.where(flip, -w_ref[...], w_ref[...])
    mo_ref[...] = jnp.where(flip, 0.0, acc)
    f_ref[...] = flip.astype(jnp.float32)


def bool_opt_step(w, accum, grad, lr, ratio, interpret=True):
    """One Boolean optimizer step. Returns (w', accum', ratio')."""
    lr_a = jnp.asarray([lr], dtype=jnp.float32)
    r_a = jnp.asarray([ratio], dtype=jnp.float32)
    w_new, m_new, flips = pl.pallas_call(
        _opt_step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(w.shape, jnp.float32),
            jax.ShapeDtypeStruct(w.shape, jnp.float32),
            jax.ShapeDtypeStruct(w.shape, jnp.float32),
        ),
        interpret=interpret,
    )(
        w.astype(jnp.float32),
        accum.astype(jnp.float32),
        grad.astype(jnp.float32),
        lr_a,
        r_a,
    )
    ratio_new = 1.0 - flips.mean()
    return w_new, m_new, ratio_new
