# L2: the paper's Boolean model forward/backward as a jax compute graph,
# composed from the L1 Pallas kernels (compile.kernels.xnor_linear).
#
# Everything here is *build-time only*: aot.py lowers these functions once to
# HLO text and the Rust coordinator executes the compiled artifacts via PJRT.
# Python never sits on the request path.
#
# The graph works in the ±1 embedded domain (Proposition A.2), which is
# exactly isomorphic to the Boolean logic formulation — the Rust native
# bit-packed engine implements the same semantics at the bit level and the
# two are cross-checked in rust/tests/.
#
# Architecture (the paper's experimental recipe, §4 "Experimental Setup"):
# first and last layers stay FP and are trained with Adam; interior layers
# are native Boolean with threshold activations; the backward signal is
# re-weighted by tanh'(α·Δ) through each threshold (Appendix C).
import jax
import jax.numpy as jnp

from .kernels import xnor_linear as K

# Model dimensions for the AOT artifacts (a compact MNIST-scale MLP; the
# Rust engine builds the larger VGG/ResNet models natively).
BATCH = 128
D_IN = 784
D_H1 = 512
D_H2 = 256
D_OUT = 10


def param_specs():
    """ShapeDtypeStructs for (w1, w2, wfc, bfc)."""
    f = jnp.float32
    return (
        jax.ShapeDtypeStruct((D_H1, D_IN), f),   # Boolean, ±1 embedded
        jax.ShapeDtypeStruct((D_H2, D_H1), f),   # Boolean, ±1 embedded
        jax.ShapeDtypeStruct((D_OUT, D_H2), f),  # FP last layer
        jax.ShapeDtypeStruct((D_OUT,), f),
    )


def batch_specs():
    f = jnp.float32
    return (
        jax.ShapeDtypeStruct((BATCH, D_IN), f),   # ±1 binarized inputs
        jax.ShapeDtypeStruct((BATCH, D_OUT), f),  # one-hot labels
    )


def _forward(x, w1, w2, wfc, bfc):
    """Boolean MLP forward. Returns (logits, s1, h1, s2, h2)."""
    s1 = K.xnor_linear_fwd(x, w1)              # Eq. (3), integer-valued
    h1 = K.threshold_act(s1)                   # §3.1 forward activation
    s2 = K.xnor_linear_fwd(h1, w2)
    h2 = K.threshold_act(s2)
    logits = h2 @ wfc.T + bfc[None, :]         # FP head
    return logits, s1, h1, s2, h2


def bool_mlp_infer(x, w1, w2, wfc, bfc):
    """Inference entry point: logits only."""
    logits, *_ = _forward(x, w1, w2, wfc, bfc)
    return (logits,)


def bool_mlp_train_step(x, y, w1, w2, wfc, bfc):
    """One forward+backward pass. Stateless: optimizer lives in Rust.

    Returns
      loss        scalar mean cross-entropy
      n_correct   scalar number of correct top-1 predictions
      q_w1, q_w2  Boolean-weight optimization signals (Eq. 7 votes)
      g_wfc, g_bfc FP head gradients
    The Rust coordinator feeds q_* to the Boolean optimizer (Algorithm 8)
    and g_* to Adam, mirroring the paper's training setup.
    """
    logits, s1, h1, s2, h2 = _forward(x, w1, w2, wfc, bfc)

    # Softmax cross-entropy and its gradient wrt logits.
    zmax = jnp.max(logits, axis=1, keepdims=True)
    ez = jnp.exp(logits - zmax)
    p = ez / jnp.sum(ez, axis=1, keepdims=True)
    loss = -jnp.mean(jnp.sum(y * jnp.log(p + 1e-12), axis=1))
    n_correct = jnp.sum(
        (jnp.argmax(logits, axis=1) == jnp.argmax(y, axis=1)).astype(jnp.float32)
    )
    z = (p - y) / BATCH                         # dLoss/dlogits

    # FP head backward.
    g_wfc = z.T @ h2
    g_bfc = z.sum(axis=0)
    g_h2 = z @ wfc

    # Threshold activation 2: Appendix C tanh' re-weighting (fan-in = D_H1).
    z2 = K.tanh_prime_scale(g_h2, s2, fanin=D_H1)
    # Boolean layer 2 backward (Algorithm 7: real incoming signal).
    g_h1, q_w2, _ = K.xnor_linear_bwd(z2, h1, w2)

    # Threshold activation 1 (fan-in = D_IN).
    z1 = K.tanh_prime_scale(g_h1, s1, fanin=D_IN)
    # Boolean layer 1 backward: only the weight vote is needed upstream.
    _, q_w1, _ = K.xnor_linear_bwd(z1, x, w1)

    return loss, n_correct, q_w1, q_w2, g_wfc, g_bfc


# ---------------------------------------------------------------------------
# Compact Boolean CNN (VGG-SMALL-style block) — inference artifact.
# Boolean conv = im2col + the same xnor matmul kernel; this is exactly how
# the Rust engine and the energy model (Appendix E) treat convolutions.
# ---------------------------------------------------------------------------
CNN_BATCH = 32
CNN_HW = 16
CNN_CIN = 3
CNN_C1 = 32
CNN_C2 = 64
CNN_K = 3


def cnn_param_specs():
    f = jnp.float32
    return (
        jax.ShapeDtypeStruct((CNN_C1, CNN_CIN * CNN_K * CNN_K), f),
        jax.ShapeDtypeStruct((CNN_C2, CNN_C1 * CNN_K * CNN_K), f),
        jax.ShapeDtypeStruct((D_OUT, CNN_C2 * (CNN_HW // 4) * (CNN_HW // 4)), f),
        jax.ShapeDtypeStruct((D_OUT,), f),
    )


def cnn_batch_specs():
    return (jax.ShapeDtypeStruct((CNN_BATCH, CNN_CIN, CNN_HW, CNN_HW), jnp.float32),)


def _im2col(x, k):
    """NCHW -> (N·H·W, C·k·k) patches with SAME zero padding.

    Zero padding is exact Boolean 0 (the 𝕄 logic of Definition 3.1): padded
    taps contribute nothing to the xnor count.
    """
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (k // 2, k // 2), (k // 2, k // 2)))
    cols = []
    for di in range(k):
        for dj in range(k):
            cols.append(xp[:, :, di : di + h, dj : dj + w])
    # (k·k, N, C, H, W) -> (N, H, W, C·k·k)
    patches = jnp.stack(cols, axis=0)
    patches = patches.transpose(1, 3, 4, 2, 0).reshape(n, h, w, c * k * k)
    return patches.reshape(n * h * w, c * k * k)


def _bool_conv(x, w, k):
    """Boolean conv via im2col + xnor matmul. x NCHW ±1, w (cout, cin·k·k)."""
    n, c, h, wdt = x.shape
    cols = _im2col(x, k)
    s = K.xnor_linear_fwd(cols, w)             # (N·H·W, cout)
    return s.reshape(n, h, wdt, -1).transpose(0, 3, 1, 2)


def _maxpool2(x):
    n, c, h, w = x.shape
    x = x.reshape(n, c, h // 2, 2, w // 2, 2)
    return x.max(axis=(3, 5))


def bool_cnn_infer(x, w1, w2, wfc, bfc):
    """Boolean CNN inference: conv-pool-act ×2 then FP head."""
    xb = jnp.where(x >= 0, 1.0, -1.0)          # binarize input
    s1 = _bool_conv(xb, w1, CNN_K)
    h1 = K.threshold_act(_maxpool2(s1))
    s2 = _bool_conv(h1, w2, CNN_K)
    h2 = K.threshold_act(_maxpool2(s2))
    flat = h2.reshape(CNN_BATCH, -1)
    logits = flat @ wfc.T + bfc[None, :]
    return (logits,)
