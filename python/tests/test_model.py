# L2 correctness: the train-step graph trains (loss decreases) and its
# pieces agree with hand-computed backward on small cases.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref as R


def _init(rng):
    w1 = (rng.integers(0, 2, (model.D_H1, model.D_IN)) * 2 - 1).astype(np.float32)
    w2 = (rng.integers(0, 2, (model.D_H2, model.D_H1)) * 2 - 1).astype(np.float32)
    wfc = (rng.normal(size=(model.D_OUT, model.D_H2)) * 0.05).astype(np.float32)
    bfc = np.zeros(model.D_OUT, dtype=np.float32)
    return w1, w2, wfc, bfc


def _batch(rng, protos=None):
    """Linearly-separable-ish synthetic task in the ±1 input domain."""
    if protos is None:
        protos = np.random.default_rng(99).integers(0, 2, (model.D_OUT, model.D_IN)) * 2 - 1
    y_idx = rng.integers(0, model.D_OUT, model.BATCH)
    x = protos[y_idx].astype(np.float32)
    noise = rng.random((model.BATCH, model.D_IN)) < 0.1
    x = np.where(noise, -x, x)
    y = np.eye(model.D_OUT, dtype=np.float32)[y_idx]
    return x, y


def test_train_step_shapes():
    rng = np.random.default_rng(0)
    w1, w2, wfc, bfc = _init(rng)
    x, y = _batch(rng)
    out = model.bool_mlp_train_step(*map(jnp.asarray, (x, y, w1, w2, wfc, bfc)))
    loss, ncorr, q1, q2, gw, gb = out
    assert loss.shape == () and ncorr.shape == ()
    assert q1.shape == w1.shape and q2.shape == w2.shape
    assert gw.shape == wfc.shape and gb.shape == bfc.shape
    assert np.isfinite(float(loss))


def test_training_reduces_loss():
    """A few full Boolean-optimizer steps must cut the loss on an easy task."""
    rng = np.random.default_rng(1)
    w1, w2, wfc, bfc = (jnp.asarray(a) for a in _init(rng))
    m1 = jnp.zeros_like(w1)
    m2 = jnp.zeros_like(w2)
    r1 = r2 = 1.0
    step = jax.jit(model.bool_mlp_train_step)
    losses = []
    for it in range(30):
        x, y = _batch(rng)
        loss, ncorr, q1, q2, gw, gb = step(jnp.asarray(x), jnp.asarray(y), w1, w2, wfc, bfc)
        losses.append(float(loss))
        w1, m1, r1 = R.bool_opt_step_ref(w1, m1, q1, lr=4.0, ratio=r1)
        w2, m2, r2 = R.bool_opt_step_ref(w2, m2, q2, lr=4.0, ratio=r2)
        wfc = wfc - 0.05 * gw
        bfc = bfc - 0.05 * gb
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8, losses


def test_infer_matches_train_step_forward():
    rng = np.random.default_rng(2)
    w1, w2, wfc, bfc = _init(rng)
    x, y = _batch(rng)
    (logits,) = model.bool_mlp_infer(*map(jnp.asarray, (x, w1, w2, wfc, bfc)))
    assert logits.shape == (model.BATCH, model.D_OUT)
    # argmax agreement with the n_correct reported by the train step
    loss, ncorr, *_ = model.bool_mlp_train_step(*map(jnp.asarray, (x, y, w1, w2, wfc, bfc)))
    acc = float(ncorr) / model.BATCH
    manual = float(np.mean(np.argmax(np.asarray(logits), 1) == np.argmax(y, 1)))
    assert abs(acc - manual) < 1e-6


def test_cnn_infer_shapes_and_binary_interior():
    rng = np.random.default_rng(3)
    cw1 = (rng.integers(0, 2, (model.CNN_C1, model.CNN_CIN * 9)) * 2 - 1).astype(np.float32)
    cw2 = (rng.integers(0, 2, (model.CNN_C2, model.CNN_C1 * 9)) * 2 - 1).astype(np.float32)
    nflat = model.CNN_C2 * (model.CNN_HW // 4) ** 2
    cwfc = (rng.normal(size=(model.D_OUT, nflat)) * 0.05).astype(np.float32)
    cbfc = np.zeros(model.D_OUT, dtype=np.float32)
    x = rng.normal(size=(model.CNN_BATCH, model.CNN_CIN, model.CNN_HW, model.CNN_HW)).astype(np.float32)
    (logits,) = model.bool_cnn_infer(*map(jnp.asarray, (x, cw1, cw2, cwfc, cbfc)))
    assert logits.shape == (model.CNN_BATCH, model.D_OUT)
    assert np.isfinite(np.asarray(logits)).all()


def test_im2col_against_lax_conv():
    """Boolean conv via im2col must equal lax.conv with the same ±1 weights."""
    rng = np.random.default_rng(4)
    n, c, h, w, cout, k = 2, 3, 8, 8, 5, 3
    x = (rng.integers(0, 2, (n, c, h, w)) * 2 - 1).astype(np.float32)
    wk = (rng.integers(0, 2, (cout, c, k, k)) * 2 - 1).astype(np.float32)
    got = model._bool_conv(jnp.asarray(x), jnp.asarray(wk.reshape(cout, -1)), k)
    want = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(wk.transpose(2, 3, 1, 0)),
        window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "HWIO", "NCHW"),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
