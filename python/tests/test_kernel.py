# L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).
# hypothesis sweeps shapes/dtypes; equality is exact (integer-valued ±1
# arithmetic in f32 is lossless far below 2^24).
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref as R
from compile.kernels import xnor_linear as K

DIMS = st.integers(min_value=1, max_value=96)


def pm1(rng, shape, dtype=np.float32):
    return (rng.integers(0, 2, shape) * 2 - 1).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(b=DIMS, m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_xnor_linear_fwd_matches_ref(b, m, n, seed):
    rng = np.random.default_rng(seed)
    x, w = pm1(rng, (b, m)), pm1(rng, (n, m))
    bias = rng.integers(-5, 6, (n,)).astype(np.float32)
    got = K.xnor_linear_fwd(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias))
    want = R.xnor_linear_fwd_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(b=DIMS, m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_xnor_linear_bwd_matches_ref(b, m, n, seed):
    rng = np.random.default_rng(seed)
    x, w = pm1(rng, (b, m)), pm1(rng, (n, m))
    z = rng.normal(size=(b, n)).astype(np.float32)
    got = K.xnor_linear_bwd(jnp.asarray(z), jnp.asarray(x), jnp.asarray(w))
    want = R.xnor_linear_bwd_ref(jnp.asarray(z), jnp.asarray(x), jnp.asarray(w))
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=DIMS,
    m=DIMS,
    seed=st.integers(0, 2**31 - 1),
    tau=st.floats(-3, 3, allow_nan=False),
)
def test_threshold_act(b, m, seed, tau):
    rng = np.random.default_rng(seed)
    s = rng.integers(-20, 21, (b, m)).astype(np.float32)
    got = K.threshold_act(jnp.asarray(s), tau=tau)
    want = R.threshold_act_ref(jnp.asarray(s), tau=tau)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(b=DIMS, m=DIMS, fanin=st.integers(1, 4096), seed=st.integers(0, 2**31 - 1))
def test_tanh_prime_scale(b, m, fanin, seed):
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(b, m)).astype(np.float32)
    s = rng.integers(-fanin, fanin + 1, (b, m)).astype(np.float32)
    got = K.tanh_prime_scale(jnp.asarray(z), jnp.asarray(s), fanin=fanin)
    want = R.tanh_prime_scale_ref(jnp.asarray(z), jnp.asarray(s), fanin=fanin)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 512),
    seed=st.integers(0, 2**31 - 1),
    lr=st.floats(0.01, 50.0),
    ratio=st.floats(0.0, 1.0),
)
def test_bool_opt_step_matches_ref(n, seed, lr, ratio):
    rng = np.random.default_rng(seed)
    w = pm1(rng, (n,))
    accum = rng.normal(size=(n,)).astype(np.float32)
    grad = rng.normal(size=(n,)).astype(np.float32)
    got = K.bool_opt_step(jnp.asarray(w), jnp.asarray(accum), jnp.asarray(grad), lr, ratio)
    want = R.bool_opt_step_ref(jnp.asarray(w), jnp.asarray(accum), jnp.asarray(grad), lr, ratio)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-5, atol=1e-6)


def test_opt_step_invariants():
    """Flip ⇒ accumulator reset; no-flip ⇒ plain accumulation; β ∈ [0,1]."""
    rng = np.random.default_rng(7)
    w = pm1(rng, (256,))
    accum = np.zeros(256, dtype=np.float32)
    grad = rng.normal(size=(256,)).astype(np.float32) * 5
    w2, m2, r2 = (np.asarray(a) for a in
                  R.bool_opt_step_ref(jnp.asarray(w), jnp.asarray(accum), jnp.asarray(grad), 1.0, 1.0))
    flipped = w2 != w
    assert np.all(m2[flipped] == 0.0)
    assert np.allclose(m2[~flipped], grad[~flipped])
    assert 0.0 <= float(r2) <= 1.0
    # A weight flips only when the vote agrees with its own sign (Eq. 9).
    assert np.all((grad[flipped] * w[flipped]) >= 1.0)


def test_tile_boundary_shapes():
    """Shapes straddling the 128/512 tile edges must be exact."""
    rng = np.random.default_rng(3)
    for b, m, n in [(128, 512, 128), (129, 513, 129), (127, 511, 127), (1, 1, 1), (256, 1024, 64)]:
        x, w = pm1(rng, (b, m)), pm1(rng, (n, m))
        got = K.xnor_linear_fwd(jnp.asarray(x), jnp.asarray(w))
        want = R.xnor_linear_fwd_ref(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_preactivation_parity_range():
    """Eq. (1): s ≡ m (mod 2) shifted — with fan-in m, s ∈ {-m..m}, s ≡ m mod 2."""
    rng = np.random.default_rng(11)
    m = 33
    x, w = pm1(rng, (64, m)), pm1(rng, (16, m))
    s = np.asarray(K.xnor_linear_fwd(jnp.asarray(x), jnp.asarray(w)))
    assert s.min() >= -m and s.max() <= m
    assert np.all((s.astype(np.int64) - m) % 2 == 0)
