//! API-compatible stub for the `xla` (xla_extension) PJRT bindings.
//!
//! Pure-data `Literal` operations work; anything that needs a real PJRT
//! runtime returns an error telling the user how to link the real binding.
//! See this crate's README.md for the swap-in instructions.

use std::fmt;

/// Error type mirroring the real binding's opaque error.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error::new(format!(
        "{what} is unavailable: this build links the in-tree xla-stub. \
         Point the `xla` path dependency in rust/Cargo.toml at a real \
         xla_extension binding and rebuild with --features xla-runtime."
    ))
}

/// Scalar types a [`Literal`] can be read back as.
pub trait NativeType: Sized + Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Host-side tensor value (functional in the stub, f32 only).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape: {} elements vs dims {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { data: self.data, dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Decompose a tuple literal. Stub literals are never tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literal decomposition"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

/// Array shape (dims only — f32 element type implied in the stub).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module. Never constructible through the stub.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HLO text parsing"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. `cpu()` always errors in the stub.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("the PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executable dispatch"))
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_data_ops_work() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn runtime_paths_error_clearly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla-stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
