//! Fault-injection suite for distributed training (ISSUE 8, DESIGN.md
//! §Distributed-Training). The property under test is the strong one:
//! the coordinator's final weights are **bit-identical** to the
//! single-process `ParallelTrainer` reference no matter what faults the
//! worker fleet suffers — a SIGKILLed worker process, a coordinator
//! restart from a mid-run checkpoint, duplicate / torn / corrupt wire
//! frames, or fewer live workers than shards.
//!
//! Worker processes are the real `bold train-dist --role worker` binary
//! (`CARGO_BIN_EXE_bold`) where the fault is process death; scripted
//! in-test peers speak `bold::coordinator::wire` directly where the
//! fault is protocol-level.

use bold::config::TrainConfig;
use bold::coordinator::wire::{read_frame, write_frame, Msg};
use bold::coordinator::{
    apply_params_blob, compute_shard, run_coordinator, run_worker, DistConfig, JobSpec,
    ParallelTrainer, TrainReport,
};
use bold::nn::{Layer, ParamRef, ParamStore, Sequential};
use std::io::Write;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::time::{Duration, Instant};

fn small_cfg(workers: usize, steps: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        model: "mlp".into(),
        workers,
        steps,
        batch: 12,
        train_size: 48,
        val_size: 16,
        lr_bool: 2.0,
        cosine: true,
        seed,
        ..Default::default()
    }
}

/// Test-tuned knobs: fast heartbeats, a deadline long enough that no
/// shard is spuriously re-issued, and a give-up bound so a worker thread
/// can never outlive its test by more than a few seconds.
fn test_dcfg() -> DistConfig {
    DistConfig {
        heartbeat_ms: 50,
        deadline_ms: 10_000,
        backoff_base_ms: 10,
        backoff_cap_ms: 100,
        giveup_ms: 5_000,
        ckpt_every: 0,
        ckpt_path: None,
        resume: false,
    }
}

/// The single-process ground truth for `cfg`: report + leader model.
fn reference(cfg: &TrainConfig) -> (TrainReport, Sequential) {
    let spec = JobSpec::new(cfg.clone()).expect("valid job");
    let (train, val) = spec.data();
    let s2 = spec.clone();
    let mut pt = ParallelTrainer::new(cfg.workers, cfg, move |_| s2.model());
    let report = pt.fit(&train, &val, cfg, false);
    (report, pt.replicas.swap_remove(0))
}

fn assert_params_bit_equal(a: &mut Sequential, b: &mut Sequential) {
    let pa = a.params();
    let pb = b.params();
    assert_eq!(pa.len(), pb.len(), "param count diverged");
    for (x, y) in pa.iter().zip(pb.iter()) {
        match (x, y) {
            (ParamRef::Bool { name, bits: ba }, ParamRef::Bool { bits: bb, .. }) => {
                assert_eq!(ba.words, bb.words, "{name}: packed weights diverged");
            }
            (ParamRef::Real { name, w: wa }, ParamRef::Real { w: wb, .. }) => {
                let (da, db): (Vec<u32>, Vec<u32>) = (
                    wa.data.iter().map(|v| v.to_bits()).collect(),
                    wb.data.iter().map(|v| v.to_bits()).collect(),
                );
                assert_eq!(da, db, "{name}: FP weights diverged");
            }
            _ => panic!("param kind mismatch"),
        }
    }
}

fn assert_losses_bit_equal(got: &[f32], want: &[f32], what: &str) {
    let (dg, dw): (Vec<u32>, Vec<u32>) = (
        got.iter().map(|l| l.to_bits()).collect(),
        want.iter().map(|l| l.to_bits()).collect(),
    );
    assert_eq!(dg, dw, "{what}: loss curves must match bit-for-bit");
}

/// CLI argv for a real out-of-process worker: every field that feeds
/// `JobSpec::config_hash` is forwarded explicitly so the child builds
/// the exact same job.
fn worker_args(cfg: &TrainConfig, addr: &str, wid: u64) -> Vec<String> {
    let kv = [
        ("role", "worker".to_string()),
        ("connect", addr.to_string()),
        ("worker-id", wid.to_string()),
        ("seed", cfg.seed.to_string()),
        ("batch", cfg.batch.to_string()),
        ("steps", cfg.steps.to_string()),
        ("train_size", cfg.train_size.to_string()),
        ("val_size", cfg.val_size.to_string()),
        ("classes", cfg.classes.to_string()),
        ("workers", cfg.workers.to_string()),
        ("lr_bool", cfg.lr_bool.to_string()),
        ("lr_fp", cfg.lr_fp.to_string()),
        ("cosine", cfg.cosine.to_string()),
    ];
    let mut args = vec!["train-dist".to_string()];
    for (k, v) in kv {
        args.push(format!("--{k}"));
        args.push(v);
    }
    args
}

fn spawn_worker_process(cfg: &TrainConfig, addr: &str, wid: u64) -> std::process::Child {
    std::process::Command::new(env!("CARGO_BIN_EXE_bold"))
        .args(worker_args(cfg, addr, wid))
        .env("BOLD_NUM_THREADS", "2")
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn bold worker")
}

fn tmp_ckpt(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("bold_dist_{tag}_{}.ckpt", std::process::id()))
        .to_string_lossy()
        .to_string()
}

/// Acceptance (a): a 3-worker-process run where one worker is SIGKILLed
/// mid-run finishes with weights bit-identical to the in-process
/// 3-worker `ParallelTrainer`.
///
/// The kill is made deterministic by sequencing, not sleeps: the victim
/// is the ONLY worker until the step-1 checkpoint lands on disk, so at
/// kill time it has provably joined and computed every shard of step 0,
/// and ≥5 steps of the job remain for the replacements.
#[test]
fn sigkilled_worker_process_preserves_bit_exactness() {
    let cfg = small_cfg(3, 6, 21);
    let spec = JobSpec::new(cfg.clone()).expect("valid job");
    let ckpt = tmp_ckpt("kill");
    let _ = std::fs::remove_file(&ckpt);
    let dcfg = DistConfig {
        deadline_ms: 2_000,
        ckpt_every: 1,
        ckpt_path: Some(ckpt.clone()),
        ..test_dcfg()
    };
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();

    let coord = {
        let spec = spec.clone();
        let dcfg = dcfg.clone();
        std::thread::spawn(move || run_coordinator(&spec, &dcfg, listener, false))
    };

    let mut victim = spawn_worker_process(&cfg, &addr, 0);
    let deadline = Instant::now() + Duration::from_secs(120);
    while std::fs::metadata(&ckpt).is_err() {
        assert!(Instant::now() < deadline, "step-1 checkpoint never appeared");
        std::thread::sleep(Duration::from_millis(10));
    }
    victim.kill().expect("SIGKILL worker 0");
    let _ = victim.wait();

    // replacement fleet carries steps 1..6 to completion
    let mut rest: Vec<_> = (1..3).map(|wid| spawn_worker_process(&cfg, &addr, wid)).collect();
    let outcome = coord.join().expect("coordinator thread").expect("coordinator run");
    for c in &mut rest {
        let _ = c.wait();
    }
    let _ = std::fs::remove_file(&ckpt);

    assert!(outcome.stats.joins >= 3, "all three workers joined: {:?}", outcome.stats);
    assert!(outcome.stats.removed >= 1, "the SIGKILL must be noticed: {:?}", outcome.stats);

    let (want, mut ref_model) = reference(&cfg);
    let mut got_model = outcome.model;
    assert_params_bit_equal(&mut got_model, &mut ref_model);
    assert_losses_bit_equal(&outcome.report.losses, &want.losses, "kill run");
    assert_eq!(outcome.report.val_acc, want.val_acc);
}

/// Acceptance (b): a coordinator killed after step 3 of an 8-step job
/// restarts from its checkpoint (fresh port, fresh workers) and the
/// combined run is bit-identical to the uninterrupted 8-step reference.
///
/// `cosine: false` keeps the LR schedule prefix-stable (a cosine decay
/// is parameterized on the total step count, which differs between the
/// truncated first run and the reference); everything else — sampler
/// cursor, Adam moments and `adam_t`, Boolean accumulators — rides in
/// the checkpoint.
#[test]
fn coordinator_restart_from_checkpoint_is_bit_exact() {
    let mut cfg_a = small_cfg(2, 3, 22);
    cfg_a.cosine = false;
    let mut cfg_b = cfg_a.clone();
    cfg_b.steps = 8;
    let ckpt = tmp_ckpt("resume");
    let _ = std::fs::remove_file(&ckpt);

    // run A: steps 0..3, checkpoint cursor lands at 3
    let spec_a = JobSpec::new(cfg_a.clone()).expect("valid job");
    let dcfg_a = DistConfig { ckpt_every: 2, ckpt_path: Some(ckpt.clone()), ..test_dcfg() };
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind A");
    let addr = listener.local_addr().expect("addr A").to_string();
    let outcome_a = std::thread::scope(|s| {
        for wid in 0..2u64 {
            let (spec, dcfg, addr) = (spec_a.clone(), dcfg_a.clone(), addr.clone());
            s.spawn(move || run_worker(&spec, &addr, &dcfg, wid, false));
        }
        run_coordinator(&spec_a, &dcfg_a, listener, false).expect("run A")
    });
    assert_eq!(outcome_a.start_step, 0);
    assert!(std::fs::metadata(&ckpt).is_ok(), "run A must leave a checkpoint");

    // run B: resume at 3, continue to 8 — new port, new worker fleet
    let spec_b = JobSpec::new(cfg_b.clone()).expect("valid job");
    let dcfg_b =
        DistConfig { ckpt_path: Some(ckpt.clone()), resume: true, ..test_dcfg() };
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind B");
    let addr = listener.local_addr().expect("addr B").to_string();
    let outcome_b = std::thread::scope(|s| {
        for wid in 10..12u64 {
            let (spec, dcfg, addr) = (spec_b.clone(), dcfg_b.clone(), addr.clone());
            s.spawn(move || run_worker(&spec, &addr, &dcfg, wid, false));
        }
        run_coordinator(&spec_b, &dcfg_b, listener, false).expect("run B")
    });
    let _ = std::fs::remove_file(&ckpt);
    assert_eq!(outcome_b.start_step, 3, "run B must resume at the cursor");

    let (want, mut ref_model) = reference(&cfg_b);
    assert_losses_bit_equal(&outcome_a.report.losses, &want.losses[..3], "pre-restart prefix");
    assert_losses_bit_equal(&outcome_b.report.losses, &want.losses[3..], "post-restart suffix");
    let mut got_model = outcome_b.model;
    assert_params_bit_equal(&mut got_model, &mut ref_model);
    assert_eq!(outcome_b.report.val_acc, want.val_acc);
}

/// Acceptance (c): duplicate shard results and torn/corrupt wire frames
/// are rejected without corrupting vote state. A scripted peer speaks
/// the protocol by hand: it double-sends a result inside one step
/// (idempotence), tears a connection mid-frame, rejoins and feeds a
/// corrupt-magic frame (severed), and a real worker then finishes the
/// job — still bit-identical to the reference.
#[test]
fn duplicate_and_torn_frames_leave_vote_state_intact() {
    let cfg = small_cfg(2, 4, 23);
    let spec = JobSpec::new(cfg.clone()).expect("valid job");
    let dcfg = test_dcfg();
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();

    let outcome = std::thread::scope(|s| {
        let coord = {
            let (spec, dcfg) = (spec.clone(), dcfg.clone());
            s.spawn(move || run_coordinator(&spec, &dcfg, listener, false))
        };

        // --- connection 1: honest step 0, but the first shard's result
        // is sent TWICE, then the connection dies on a torn frame ---
        let (train, _val) = spec.data();
        let mut model = spec.model();
        let mut store = ParamStore::new();
        let mut s1 = TcpStream::connect(&addr).expect("conn 1");
        write_frame(&mut s1, &Msg::Hello { worker_id: 7, config_hash: spec.config_hash() })
            .expect("hello 1");
        match read_frame(&mut s1).expect("sync 0") {
            Msg::Sync { step, params } => {
                assert_eq!(step, 0);
                let mut p = model.params();
                apply_params_blob(&mut p, &params).expect("install step-0 weights");
            }
            m => panic!("expected Sync, got {m:?}"),
        }
        let mut computed = Vec::new();
        for _ in 0..2 {
            match read_frame(&mut s1).expect("assign") {
                Msg::Assign { step, shard_id, total, indices } => {
                    assert_eq!(step, 0);
                    let (loss, correct, grads) =
                        compute_shard(&mut model, &mut store, &train, &indices, total);
                    computed.push(Msg::ShardResult { step, shard_id, loss, correct, grads });
                }
                m => panic!("expected Assign, got {m:?}"),
            }
        }
        write_frame(&mut s1, &computed[0]).expect("result 0");
        write_frame(&mut s1, &computed[0].clone()).expect("duplicate of result 0");
        write_frame(&mut s1, &computed[1]).expect("result 1");
        // torn frame: a few header bytes, then gone
        let _ = s1.write_all(&[0xB0, 0x1D, 0xD1]);
        let _ = s1.shutdown(Shutdown::Both);
        drop(s1);

        // --- connection 2: valid rejoin, then a corrupt-magic frame —
        // the coordinator must sever it without touching vote state ---
        let mut s2 = TcpStream::connect(&addr).expect("conn 2");
        write_frame(&mut s2, &Msg::Hello { worker_id: 7, config_hash: spec.config_hash() })
            .expect("hello 2");
        match read_frame(&mut s2).expect("rejoin sync") {
            // usually step 1 (step 0 commits off conn 1's results), but the
            // join can race the commit — either way weights arrive first
            Msg::Sync { step, .. } => assert!(step <= 1, "unexpected sync step {step}"),
            m => panic!("expected Sync, got {m:?}"),
        }
        s2.write_all(&[0xAB; 12]).expect("corrupt frame");
        let _ = s2.shutdown(Shutdown::Both);
        drop(s2);

        // --- connection 3: a real worker finishes steps 1..4 ---
        let shards = run_worker(&spec, &addr, &dcfg, 7, false).expect("recovery worker");
        assert!(shards >= 6, "steps 1..4 × 2 shards re-run after the faults: {shards}");
        coord.join().expect("coordinator thread").expect("coordinator run")
    });

    let st = &outcome.stats;
    assert!(st.duplicates >= 1, "double-sent result must be dropped: {st:?}");
    assert!(st.corrupt_frames >= 1, "corrupt magic must be counted: {st:?}");
    assert!(st.removed >= 2, "torn and corrupt peers must be severed: {st:?}");
    assert!(st.reconnects >= 2, "worker 7 rejoined twice: {st:?}");

    let (want, mut ref_model) = reference(&cfg);
    let mut got_model = outcome.model;
    assert_params_bit_equal(&mut got_model, &mut ref_model);
    assert_losses_bit_equal(&outcome.report.losses, &want.losses, "fault run");
    assert_eq!(outcome.report.val_acc, want.val_acc);
}

/// Graceful degradation: 4 shards served by only 2 live workers must
/// produce exactly the 4-worker reference — the shard count, not the
/// fleet size, anchors determinism.
#[test]
fn fewer_live_workers_than_shards_is_bit_exact() {
    let cfg = small_cfg(4, 3, 24);
    let spec = JobSpec::new(cfg.clone()).expect("valid job");
    let dcfg = test_dcfg();
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();

    let outcome = std::thread::scope(|s| {
        for wid in 0..2u64 {
            let (spec, dcfg, addr) = (spec.clone(), dcfg.clone(), addr.clone());
            s.spawn(move || run_worker(&spec, &addr, &dcfg, wid, false));
        }
        run_coordinator(&spec, &dcfg, listener, false).expect("coordinator run")
    });

    let (want, mut ref_model) = reference(&cfg);
    let mut got_model = outcome.model;
    assert_params_bit_equal(&mut got_model, &mut ref_model);
    assert_losses_bit_equal(&outcome.report.losses, &want.losses, "degraded run");
    assert_eq!(outcome.report.val_acc, want.val_acc);
}
