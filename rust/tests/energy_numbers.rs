// Printable sanity check: `cargo test --test energy_numbers -- --nocapture`
use bold::energy::*;

#[test]
fn print_table2_style_numbers() {
    for hw in [hardware_ascend(), hardware_v100()] {
        let shapes = vgg_small_shapes(100);
        let fp = network_energy(&shapes, &hw, Method::Fp32, true).total_pj();
        println!("--- {} (VGG-SMALL, 1 training iter, % of FP)", hw.name);
        for m in Method::all() {
            let e = network_energy(&shapes, &hw, m, true);
            println!(
                "{:<18} {:6.2}%   (comp {:.1}% mem {:.1}% opt {:.1}%)",
                m.name(),
                e.total_pj() / fp * 100.0,
                e.compute_pj / fp * 100.0,
                e.mem_pj / fp * 100.0,
                e.optimizer_pj / fp * 100.0
            );
        }
    }
}

fn hardware_ascend() -> Hardware { ASCEND() }
fn hardware_v100() -> Hardware { V100() }
