//! Property-based invariant tests over the whole stack, using the
//! `bold::testing` harness (seed-swept deterministic cases).

use bold::logic::{embed, project, B3, F, T};
use bold::nn::{BackwardScale, BoolLinear, Layer, ParamRef, ParamStore, ThresholdAct, Value};
use bold::optim::BooleanOptimizer;
use bold::tensor::{BitMatrix, Tensor};
use bold::testing::{assert_close, forall, PropConfig};

#[test]
fn prop_embedding_isomorphism_on_streams() {
    // Prop. A.2: e(xnor(a,b)) = e(a)·e(b), on random Boolean streams.
    forall("embedding-isomorphism", PropConfig::default(), |c| {
        let n = c.dim() * 4;
        for _ in 0..n {
            let a = if c.rng.bernoulli(0.5) { T } else { F };
            let b = if c.rng.bernoulli(0.45) { T } else { F };
            if embed(a.xnor(b)) != embed(a) * embed(b) {
                return Err(format!("{a:?} xnor {b:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_projection_retracts_embedding() {
    forall("projection-retraction", PropConfig::default(), |c| {
        let k = (c.rng.next_u64() % 2000) as i32 - 1000;
        let want = match k.cmp(&0) {
            std::cmp::Ordering::Greater => T,
            std::cmp::Ordering::Equal => B3::Zero,
            std::cmp::Ordering::Less => F,
        };
        if project(k) != want {
            return Err(format!("project({k})"));
        }
        Ok(())
    });
}

#[test]
fn prop_xnor_gemm_equals_dense_matmul() {
    // Bit-level forward == embedded ±1 matmul, exactly, any shape.
    forall("xnor-gemm-vs-dense", PropConfig { cases: 40, ..Default::default() }, |c| {
        let (b, n, m) = (c.dim(), c.dim(), c.dim());
        let x = BitMatrix::random(b, m, c.rng);
        let w = BitMatrix::random(n, m, c.rng);
        let bits = x.xnor_gemm(&w);
        let dense = x.to_pm1().matmul_bt(&w.to_pm1());
        assert_close(&bits.data, &dense.data, 0.0)
    });
}

#[test]
fn prop_bool_linear_backward_is_adjoint() {
    // <z, L(x)> == <Lᵀ(z), x> in the embedded domain: the Boolean
    // backward g_X = z·e(W) is the exact adjoint of the forward.
    forall("bool-linear-adjoint", PropConfig { cases: 30, ..Default::default() }, |c| {
        let (b, n_in, n_out) = (1 + c.dim() / 2, c.dim(), c.dim());
        let mut rng2 = c.rng.fork(1);
        let mut layer = BoolLinear::new("l", n_in, n_out, &mut rng2);
        let x = Tensor::rand_pm1(&[b, n_in], c.rng);
        let y = layer.forward(Value::bit_from_pm1(&x), true).expect_f32("f");
        let z = Tensor::from_vec(&[b, n_out], c.normal_vec(b * n_out));
        let gx = layer.backward(z.clone(), &mut ParamStore::new());
        let lhs: f64 = y.data.iter().zip(&z.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = x.data.iter().zip(&gx.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        if (lhs - rhs).abs() > 1e-2 * lhs.abs().max(1.0) {
            return Err(format!("adjoint broken: {lhs} vs {rhs}"));
        }
        Ok(())
    });
}

#[test]
fn prop_threshold_backward_bounded_by_input_signal() {
    // The tanh' window is in (0, 1]: |out| ≤ |in| elementwise, equality at
    // the threshold.
    forall("threshold-window", PropConfig { cases: 40, ..Default::default() }, |c| {
        let n = c.dim();
        let mut act = ThresholdAct::new("a", 0.0, BackwardScale::TanhPrime { fanin: n.max(1) });
        let s = Tensor::from_vec(&[1, n], c.normal_vec(n)).scale(n as f32);
        let _ = act.forward(Value::F32(s), true);
        let z = Tensor::from_vec(&[1, n], c.normal_vec(n));
        let g = act.backward(z.clone(), &mut ParamStore::new());
        for i in 0..n {
            if g.data[i].abs() > z.data[i].abs() + 1e-6 {
                return Err(format!("window > 1 at {i}"));
            }
            if g.data[i] * z.data[i] < -1e-9 {
                return Err("window flipped sign".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_optimizer_flip_iff_aligned_and_saturated() {
    // Eq. 9 exhaustive per-element check on random states.
    forall("flip-rule", PropConfig { cases: 40, ..Default::default() }, |c| {
        let n = c.dim();
        let mut bits = BitMatrix::random(1, n, c.rng);
        let before = bits.clone();
        let grad = Tensor::from_vec(&[1, n], c.normal_vec(n)).scale(2.0);
        let accum0 = Tensor::from_vec(&[1, n], c.normal_vec(n));
        let beta = c.rng.uniform();
        let lr = 0.5 + c.rng.uniform();
        let opt = BooleanOptimizer::new(lr);
        let mut store = ParamStore::new();
        store.accumulate("w", &grad);
        {
            let slot = store.slot_mut("w");
            slot.accum_mut(n).data.copy_from_slice(&accum0.data);
            slot.ratio = beta;
        }
        let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
        opt.step(&mut params, &mut store);
        let accum = &store.slot("w").unwrap().accum;
        for i in 0..n {
            let m = beta * accum0.data[i] + lr * grad.data[i];
            let w = before.pm1(0, i);
            let should_flip = m * w >= 1.0;
            let flipped = bits.get(0, i) != before.get(0, i);
            if should_flip != flipped {
                return Err(format!("elem {i}: m={m} w={w} flip={flipped}"));
            }
            if flipped && accum.data[i] != 0.0 {
                return Err(format!("elem {i}: accumulator not reset"));
            }
            if !flipped && (accum.data[i] - m).abs() > 1e-5 {
                return Err(format!("elem {i}: accumulator wrong"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bit_pack_roundtrip_any_shape() {
    forall("pack-roundtrip", PropConfig { cases: 50, max_size: 200, ..Default::default() }, |c| {
        let (r, cdim) = (1 + c.dim() / 8, c.dim());
        let t = Tensor::rand_pm1(&[r.max(1), cdim], c.rng);
        let m = BitMatrix::from_pm1(&t);
        if m.to_pm1() != t {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_energy_monotone_in_bitwidth_and_batch() {
    use bold::energy::{conv_energy, method_bitwidths, ConvShape, Method, Phase, V100};
    forall("energy-monotone", PropConfig { cases: 15, max_size: 32, ..Default::default() }, |c| {
        let hw = V100();
        let n = 1 + c.dim();
        let ch = 8 + c.dim();
        let shape = ConvShape { n, c: ch, m: ch, h: 16, w: 16, k: 3, stride: 1, pad: 1 };
        let shape2 = ConvShape { n: n * 2, ..shape };
        let fp = method_bitwidths(Method::Fp32);
        let bold_bits = method_bitwidths(Method::Bold);
        let e_fp = conv_energy(&shape, &hw, &fp, Phase::Forward).total();
        let e_bold = conv_energy(&shape, &hw, &bold_bits, Phase::Forward).total();
        let e_fp2 = conv_energy(&shape2, &hw, &fp, Phase::Forward).total();
        if e_bold >= e_fp {
            return Err(format!("1-bit ≥ 32-bit: {e_bold} vs {e_fp}"));
        }
        if e_fp2 <= e_fp {
            return Err("bigger batch must cost more".into());
        }
        Ok(())
    });
}

#[test]
fn prop_chain_rule_on_random_function_tables() {
    use bold::logic::{chain_bb, variation, BoolFn};
    forall("chain-rule", PropConfig { cases: 64, ..Default::default() }, |c| {
        let pick = |rng: &mut bold::util::Rng| if rng.bernoulli(0.5) { T } else { F };
        let f = BoolFn::new(pick(c.rng), pick(c.rng));
        let g = BoolFn::new(pick(c.rng), pick(c.rng));
        for x in [T, F] {
            let lhs = variation(&f.compose(&g), x);
            let rhs = chain_bb(&f, &g, x);
            if lhs != rhs {
                return Err(format!("f={f:?} g={g:?} x={x:?}"));
            }
        }
        Ok(())
    });
}
