//! Parity and integration tests for the native packed serving stack:
//! the forward-only engine must reproduce the reference `nn::` forward
//! (BoolLinear → ThresholdAct → … → Linear) **exactly** — bit-identical
//! packed activations and bit-identical f32 logits — including odd
//! (non-multiple-of-64) widths and masked three-valued inputs.

use bold::coordinator::save_model;
use bold::models::{boolean_mlp, MlpConfig};
use bold::nn::{Layer, Value};
use bold::runtime::{NativeServer, PackedMlp, ServeConfig};
use bold::tensor::{BitMatrix, Tensor};
use bold::util::Rng;
use std::time::Duration;

fn mlp_and_engine(cfg: &MlpConfig, seed: u64) -> (bold::nn::Sequential, PackedMlp) {
    let mut rng = Rng::new(seed);
    let mut model = boolean_mlp(cfg, &mut rng);
    let engine = PackedMlp::from_layer(&mut model).expect("engine build");
    (model, engine)
}

#[test]
fn packed_engine_matches_reference_forward_exactly() {
    let configs = [
        (1u64, MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true }),
        // odd widths at every layer: tail-word masking on the hot path
        (2, MlpConfig { d_in: 70, hidden: vec![33, 17], d_out: 5, tanh_scale: true }),
        (3, MlpConfig { d_in: 100, hidden: vec![65, 64, 63], d_out: 10, tanh_scale: false }),
    ];
    for (seed, cfg) in configs {
        let (mut model, engine) = mlp_and_engine(&cfg, seed);
        let mut rng = Rng::new(seed + 100);
        let x = Tensor::rand_pm1(&[9, cfg.d_in], &mut rng);
        let reference = model.forward(Value::bit_from_pm1(&x), false).expect_f32("ref");
        let native = engine.forward_f32(&x);
        assert_eq!(native.shape, reference.shape);
        assert_eq!(
            native.max_abs_diff(&reference),
            0.0,
            "logits must match exactly (d_in={})",
            cfg.d_in
        );
        assert_eq!(native.argmax_rows(), reference.argmax_rows());
    }
}

#[test]
fn packed_hidden_layers_are_bit_identical_to_reference() {
    // Check the packed interior directly, not just the final logits.
    let cfg = MlpConfig { d_in: 70, hidden: vec![33], d_out: 4, tanh_scale: true };
    let (mut model, engine) = mlp_and_engine(&cfg, 8);
    let mut rng = Rng::new(9);
    let x = Tensor::rand_pm1(&[5, 70], &mut rng);
    // reference hidden bits: run BoolLinear + ThresholdAct (layers 0 and 1)
    let v = model.layers[0].forward(Value::bit_from_pm1(&x), false);
    let v = model.layers[1].forward(v, false);
    let (ref_bits, _) = v.expect_bit("hidden");
    let native_bits = engine.layers[0].apply(&BitMatrix::from_pm1(&x));
    assert_eq!(native_bits, ref_bits);
}

#[test]
fn engine_loads_save_model_checkpoints() {
    let dir = std::env::temp_dir().join("bold_native_engine_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("frozen.ckpt");
    let path = path.to_str().unwrap();

    let cfg = MlpConfig { d_in: 70, hidden: vec![33, 17], d_out: 5, tanh_scale: true };
    let (mut model, _) = mlp_and_engine(&cfg, 4);
    save_model(&mut model, path).unwrap();

    let engine = PackedMlp::load(path).expect("load frozen model");
    assert_eq!(engine.d_in(), 70);
    assert_eq!(engine.d_out(), 5);
    let mut rng = Rng::new(5);
    let x = Tensor::rand_pm1(&[7, 70], &mut rng);
    let reference = model.forward(Value::bit_from_pm1(&x), false).expect_f32("ref");
    let native = engine.forward_f32(&x);
    assert_eq!(native.max_abs_diff(&reference), 0.0);
}

#[test]
fn masked_layer_implements_three_valued_zero() {
    // A lane mask on the first layer must agree with the general
    // per-row masked GEMM (Definition 3.1's adjoined 0).
    let cfg = MlpConfig { d_in: 90, hidden: vec![40], d_out: 3, tanh_scale: true };
    let (_model, mut engine) = mlp_and_engine(&cfg, 11);
    // lanes 70..90 are padding ⇒ invalid
    let mut lane = BitMatrix::zeros(1, 90);
    for j in 0..70 {
        lane.set(0, j, true);
    }
    engine.layers[0].input_mask = Some(lane.row(0).to_vec());

    let mut rng = Rng::new(12);
    let x = BitMatrix::random(6, 90, &mut rng);
    let native = engine.layers[0].apply(&x);

    let mut mask = BitMatrix::zeros(6, 90);
    for i in 0..6 {
        for j in 0..70 {
            mask.set(i, j, true);
        }
    }
    let want = BitMatrix::from_pm1(
        &x.xnor_gemm_masked(&engine.layers[0].weights, &mask).sign_pm1(),
    );
    assert_eq!(native, want);
}

#[test]
fn server_batches_and_answers_like_the_engine() {
    let cfg = MlpConfig { d_in: 100, hidden: vec![48, 24], d_out: 6, tanh_scale: true };
    let (_m, reference) = mlp_and_engine(&cfg, 21);
    let (_m2, served) = mlp_and_engine(&cfg, 21); // same seed ⇒ same weights
    let server = NativeServer::start(
        served,
        ServeConfig {
            workers: 2,
            max_batch: 16,
            queue_cap: 32,
            batch_window: Duration::from_micros(100),
        },
    );
    let mut rng = Rng::new(31);
    let mut pendings = Vec::new();
    let mut expected = Vec::new();
    for _ in 0..64 {
        let x = Tensor::rand_pm1(&[1, 100], &mut rng);
        expected.push(reference.forward_f32(&x));
        pendings.push(server.submit(&x.data).expect("submit"));
    }
    for (p, want) in pendings.into_iter().zip(expected) {
        let resp = p.wait().expect("response");
        assert_eq!(resp.logits, want.data, "served logits must be bit-identical");
        assert_eq!(resp.class, want.argmax_rows()[0]);
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 64);
}
