//! TCP parity suite (ISSUE 6): a checkpoint served through the network
//! front-end must produce **bit-identical** logits to the same
//! checkpoint driven in-process through [`PackedGraph::forward_f32`] —
//! for the MLP and for a conv (VGG) checkpoint, across both body
//! encodings, and across micro-batch coalescing (concurrent clients
//! whose requests land in shared batches).
//!
//! Bitwise comparison over a *text* protocol works because Rust's `{}`
//! Display for `f32` is shortest-roundtrip: the serialized logit parses
//! back to exactly the same bits the server computed.

use bold::coordinator::save_model;
use bold::models::{boolean_mlp, vgg_small, MlpConfig, VggConfig};
use bold::nn::{Layer, Sequential, Value};
use bold::runtime::{loadgen, HttpConfig, HttpServer, ModelRegistry, PackedGraph, ServeConfig};
use bold::tensor::Tensor;
use bold::util::Rng;
use std::io::Read;
use std::net::TcpStream;
use std::time::Duration;

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("bold_net_parity_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_str().unwrap().to_string()
}

/// Move BN running stats off their init values (same convention as
/// tests/packed_graph.rs) so the parity covers folded non-trivial BN.
fn warm_up(model: &mut Sequential, shape: &[usize], seed: u64) {
    let mut rng = Rng::new(seed);
    for _ in 0..3 {
        let x = Tensor::randn(shape, 1.0, &mut rng);
        let _ = model.forward(Value::F32(x), true);
    }
}

/// Save `model`, then load the checkpoint twice: once as the in-process
/// reference, once for the server (separate instances, so parity is
/// checkpoint → wire, not shared memory).
fn checkpoint_pair(model: &mut Sequential, name: &str) -> (PackedGraph, PackedGraph) {
    let path = tmp(name);
    save_model(model, &path).unwrap();
    let reference = PackedGraph::load(&path).expect("reference load");
    let served = PackedGraph::load(&path).expect("served load");
    (reference, served)
}

fn serve(graph: PackedGraph, serve_cfg: ServeConfig) -> (HttpServer, String) {
    let registry = ModelRegistry::new();
    registry.add("m", graph, serve_cfg).expect("register");
    let cfg = HttpConfig { threads: 8, ..HttpConfig::default() };
    let server = HttpServer::start(registry, "127.0.0.1:0", cfg).expect("bind");
    let addr = server.local_addr().to_string();
    (server, addr)
}

fn small_batches() -> ServeConfig {
    ServeConfig {
        workers: 2,
        max_batch: 8,
        queue_cap: 256,
        batch_window: Duration::from_millis(2),
    }
}

/// Send one rendered request on `stream` and return the response body
/// (Content-Length framed, so the keep-alive connection stays usable).
fn roundtrip(stream: &mut TcpStream, request: &[u8]) -> String {
    use std::io::Write as _;
    stream.write_all(request).expect("send");
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p + 4;
        }
        let n = stream.read(&mut chunk).expect("read head");
        assert!(n > 0, "connection closed before response head");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    assert!(head.starts_with("HTTP/1.1 200"), "expected 200, got:\n{head}");
    let cl: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.trim().eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
        })
        .expect("Content-Length");
    while buf.len() < head_end + cl {
        let n = stream.read(&mut chunk).expect("read body");
        assert!(n > 0, "connection closed mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    String::from_utf8_lossy(&buf[head_end..head_end + cl]).to_string()
}

/// Extract `class` and `logits` from the predict response JSON. The
/// emitter writes flat single-line JSON; field-level extraction is
/// exact for it.
fn parse_prediction(body: &str) -> (usize, Vec<f32>) {
    let class = body
        .split("\"class\":")
        .nth(1)
        .and_then(|s| s.split([',', '}']).next())
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or_else(|| panic!("no class in {body:?}"));
    let logits = body
        .split("\"logits\":[")
        .nth(1)
        .and_then(|s| s.split(']').next())
        .unwrap_or_else(|| panic!("no logits in {body:?}"))
        .split(',')
        .map(|t| t.trim().parse().expect("logit parses"))
        .collect();
    (class, logits)
}

fn text_body(feats: &[f32]) -> Vec<u8> {
    feats.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(",").into_bytes()
}

fn binary_body(feats: &[f32]) -> Vec<u8> {
    let mut b = Vec::with_capacity(feats.len() * 4);
    for f in feats {
        b.extend_from_slice(&f.to_le_bytes());
    }
    b
}

/// Reference logits for one example, through the same packed path the
/// server uses.
fn reference_logits(graph: &PackedGraph, feats: &[f32]) -> (usize, Vec<f32>) {
    let x = Tensor::from_vec(&[1, feats.len()], feats.to_vec());
    let out = graph.forward_f32(&x);
    // same tie-breaking as the server's argmax_rows_into
    let class = out.argmax_rows()[0];
    (class, out.data)
}

fn assert_bitwise_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: logit count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: logit {i} differs: served {g} vs in-process {w}"
        );
    }
}

#[test]
fn mlp_checkpoint_tcp_parity_text_and_binary() {
    let cfg = MlpConfig { d_in: 96, hidden: vec![48, 24], d_out: 10, tanh_scale: true };
    let mut model = boolean_mlp(&cfg, &mut Rng::new(31));
    warm_up(&mut model, &[4, 96], 81);
    let (reference, served) = checkpoint_pair(&mut model, "mlp_parity.ckpt");
    let (server, addr) = serve(served, small_batches());

    let mut rng = Rng::new(314);
    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for i in 0..12 {
        let feats: Vec<f32> = (0..96).map(|_| rng.sign()).collect();
        let (want_class, want_logits) = reference_logits(&reference, &feats);
        // text encoding (keep-alive, same connection throughout)
        let req = loadgen::render_predict("m", &text_body(&feats), "text/plain");
        let (class, logits) = parse_prediction(&roundtrip(&mut stream, &req));
        assert_eq!(class, want_class, "text req {i}: class");
        assert_bitwise_eq(&logits, &want_logits, &format!("text req {i}"));
        // binary encoding of the same example must agree exactly too
        let req = loadgen::render_predict("m", &binary_body(&feats), "application/octet-stream");
        let (class, logits) = parse_prediction(&roundtrip(&mut stream, &req));
        assert_eq!(class, want_class, "binary req {i}: class");
        assert_bitwise_eq(&logits, &want_logits, &format!("binary req {i}"));
    }
    drop(server);
}

#[test]
fn vgg_checkpoint_tcp_parity() {
    // conv path: BN folded into per-channel thresholds by the packed
    // graph loader; d_in = 3*16*16 = 768 flat features over the wire
    let cfg = VggConfig { hw: 16, width_mult: 0.125, with_bn: true, ..Default::default() };
    let mut model = vgg_small(&cfg, &mut Rng::new(41));
    warm_up(&mut model, &[4, 3, 16, 16], 91);
    let (reference, served) = checkpoint_pair(&mut model, "vgg_parity.ckpt");
    let d_in = reference.d_in();
    assert_eq!(d_in, 3 * 16 * 16);
    let (server, addr) = serve(served, small_batches());

    let mut rng = Rng::new(514);
    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for i in 0..6 {
        let feats: Vec<f32> = (0..d_in).map(|_| rng.sign()).collect();
        let (want_class, want_logits) = reference_logits(&reference, &feats);
        let req = loadgen::render_predict("m", &binary_body(&feats), "application/octet-stream");
        let (class, logits) = parse_prediction(&roundtrip(&mut stream, &req));
        assert_eq!(class, want_class, "conv req {i}: class");
        assert_bitwise_eq(&logits, &want_logits, &format!("conv req {i}"));
    }
    drop(server);
}

#[test]
fn coalesced_batches_stay_bit_identical() {
    // concurrent keep-alive clients against max_batch 8 + a 2 ms window:
    // requests from different connections land in shared micro-batches,
    // and every response must still match the single-example reference
    let cfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 10, tanh_scale: true };
    let mut model = boolean_mlp(&cfg, &mut Rng::new(51));
    warm_up(&mut model, &[4, 64], 71);
    let (reference, served) = checkpoint_pair(&mut model, "mlp_coalesce.ckpt");
    let (server, addr) = serve(served, small_batches());

    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 12;
    // precompute inputs + references so the client threads only compare
    let mut inputs: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut wants: Vec<Vec<(usize, Vec<f32>)>> = Vec::new();
    for c in 0..CLIENTS {
        let mut rng = Rng::new(1000 + c as u64);
        let mut ins = Vec::new();
        let mut ws = Vec::new();
        for _ in 0..PER_CLIENT {
            let feats: Vec<f32> = (0..64).map(|_| rng.sign()).collect();
            ws.push(reference_logits(&reference, &feats));
            ins.push(feats);
        }
        inputs.push(ins);
        wants.push(ws);
    }

    std::thread::scope(|sc| {
        for c in 0..CLIENTS {
            let addr = &addr;
            let ins = &inputs[c];
            let ws = &wants[c];
            sc.spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect");
                stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                for (i, (feats, (want_class, want_logits))) in ins.iter().zip(ws).enumerate() {
                    let req = loadgen::render_predict("m", &text_body(feats), "text/plain");
                    let (class, logits) = parse_prediction(&roundtrip(&mut stream, &req));
                    assert_eq!(class, *want_class, "client {c} req {i}: class");
                    assert_bitwise_eq(&logits, want_logits, &format!("client {c} req {i}"));
                }
            });
        }
    });
    let stats = server.shutdown();
    assert_eq!(stats.ok, CLIENTS * PER_CLIENT, "every request answered 200: {stats:?}");
}

#[test]
fn fixed_rate_load_smoke_has_no_unexpected_errors() {
    // the CI load smoke: a modest fixed-rate open-loop run must produce
    // only 200s (and deliberate 503s under pressure) — any other 5xx,
    // 4xx, deadline expiry, or transport error fails
    let mut model = boolean_mlp(
        &MlpConfig { d_in: 64, hidden: vec![32], d_out: 10, tanh_scale: true },
        &mut Rng::new(61),
    );
    let graph = PackedGraph::from_layer(&mut model).expect("graph");
    let (server, addr) = serve(graph, small_batches());

    let mut rng = Rng::new(616);
    let feats: Vec<f32> = (0..64).map(|_| rng.sign()).collect();
    let request = loadgen::render_predict("m", &binary_body(&feats), "application/octet-stream");
    let rep = loadgen::open_loop(&addr, &request, 150.0, Duration::from_millis(1500), 8);

    assert_eq!(rep.other_5xx, 0, "unexpected 5xx under fixed-rate load: {rep:?}");
    assert_eq!(rep.other_4xx, 0, "unexpected 4xx under fixed-rate load: {rep:?}");
    assert_eq!(rep.io_errors, 0, "transport errors under fixed-rate load: {rep:?}");
    assert_eq!(rep.timeouts, 0, "socket timeouts under fixed-rate load: {rep:?}");
    assert_eq!(rep.connect_errors, 0, "refused connects under fixed-rate load: {rep:?}");
    assert_eq!(rep.expired, 0, "deadline expiries at 150 req/s: {rep:?}");
    assert!(
        rep.ok + rep.shed == rep.sent && rep.ok >= rep.sent * 9 / 10,
        "load smoke lost requests: {rep:?}"
    );
    let stats = server.shutdown();
    assert_eq!(stats.server_err, 0, "front-end recorded server errors: {stats:?}");
}
