//! Chaos soak for the live model lifecycle (ISSUE 10), end to end over
//! the real binary: spawn `bold serve-http` with two checkpoint-backed
//! models, drive fixed-rate open-loop load against one while the other
//! absorbs injected worker panics, hot-reload the loaded model
//! mid-flight through the canary-gated admin endpoint, then drain over
//! the wire. The acceptance contract: **zero hung requests** (every
//! arrival is answered — no timeouts, no transport errors) and a clean
//! process exit.
//!
//! The breaker thresholds are raised out of reach via env so the soak
//! measures request-path stability in isolation; breaker trips,
//! quarantine and rollback have their own suite in `tests/net_faults.rs`
//! and `runtime/lifecycle.rs`.

use bold::coordinator::save_model;
use bold::models::{boolean_mlp, MlpConfig};
use bold::runtime::loadgen;
use bold::util::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const D_IN: usize = 64;

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("bold_lifecycle_chaos");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_str().unwrap().to_string()
}

fn mlp_ckpt(path: &str, seed: u64) {
    let cfg = MlpConfig { d_in: D_IN, hidden: vec![32], d_out: 10, tanh_scale: true };
    let mut model = boolean_mlp(&cfg, &mut Rng::new(seed));
    save_model(&mut model, path).expect("save checkpoint");
}

/// One raw request on a fresh connection; read one framed response
/// (status line + headers + Content-Length body) with a 10 s timeout —
/// a hang here is exactly the failure the soak exists to catch.
fn roundtrip(addr: &str, raw: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(raw).expect("send");
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p + 4;
        }
        let n = s.read(&mut chunk).expect("read head");
        assert!(n > 0, "connection closed before a full response head");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let cl: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.trim().eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
        })
        .unwrap_or(0);
    while buf.len() < head_end + cl {
        let n = s.read(&mut chunk).expect("read body");
        assert!(n > 0, "connection closed mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    String::from_utf8_lossy(&buf[..head_end + cl]).to_string()
}

fn post(addr: &str, path: &str, body: &str) -> String {
    let raw = format!(
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    roundtrip(addr, raw.as_bytes())
}

fn status_of(resp: &str) -> u16 {
    resp.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0)
}

/// Wait for the child to exit within `limit`, killing it on overrun so
/// the suite fails with a message instead of wedging the CI job.
fn wait_with_deadline(child: &mut Child, limit: Duration) -> std::process::ExitStatus {
    let deadline = Instant::now() + limit;
    loop {
        if let Some(st) = child.try_wait().expect("try_wait") {
            return st;
        }
        if Instant::now() >= deadline {
            let _ = child.kill();
            let _ = child.wait();
            panic!("serve-http did not drain and exit within {limit:?}");
        }
        std::thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn chaos_soak_reload_and_panics_under_load_drain_clean() {
    let ckpt_a = tmp("soak_a.ckpt");
    let ckpt_b = tmp("soak_b.ckpt");
    mlp_ckpt(&ckpt_a, 11);
    mlp_ckpt(&ckpt_b, 22);

    let mut child = Command::new(env!("CARGO_BIN_EXE_bold"))
        .args([
            "serve-http",
            "--listen",
            "127.0.0.1:0",
            "--model",
            &format!("a={ckpt_a}"),
            "--model",
            &format!("b={ckpt_b}"),
            "--threads",
            "8",
            "--workers",
            "2",
            "--batch",
            "8",
            "--queue",
            "256",
        ])
        .env("BOLD_FAULT_INJECT", "1")
        // keep the breaker far out of reach: the soak injects panics to
        // prove request-path containment, not to exercise quarantine
        .env("BOLD_BREAKER_PANICS", "1000")
        .env("BOLD_BREAKER_ERRORS", "1000")
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn serve-http");

    // the child binds an ephemeral port and prints it; parse the
    // "listening on http://ADDR — ..." line, then keep draining stdout
    // on a thread (a full pipe would wedge the server's println)
    let stdout = child.stdout.take().expect("stdout piped");
    let mut reader = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("child stdout");
        assert!(n > 0, "serve-http exited before announcing its address");
        if let Some(rest) = line.split("http://").nth(1) {
            break rest.split_whitespace().next().expect("addr token").to_string();
        }
    };
    let tail: Arc<Mutex<String>> = Arc::new(Mutex::new(String::new()));
    let tail_writer = Arc::clone(&tail);
    let drain_thread = std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = reader.read_to_string(&mut rest);
        tail_writer.lock().unwrap().push_str(&rest);
    });

    let feats: Vec<f32> = (0..D_IN).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let body: Vec<u8> = feats.iter().flat_map(|f| f.to_le_bytes()).collect();
    let req_a = loadgen::render_predict("a", &body, "application/octet-stream");
    let req_b = loadgen::render_predict("b", &body, "application/octet-stream");

    // fixed-rate open-loop load on model a for the whole soak window;
    // faults land on model b and a hot reload lands on a mid-flight
    let rep = std::thread::scope(|s| {
        let addr_ref = &addr;
        let load = s.spawn(move || {
            loadgen::open_loop(addr_ref, &req_a, 300.0, Duration::from_millis(1500), 4)
        });

        std::thread::sleep(Duration::from_millis(300));
        // two injected worker panics on b: the two batches in flight
        // answer 500, the workers survive, later requests answer 200 —
        // and model a's load never notices
        let resp = post(&addr, "/v1/models/b/inject_panic", "");
        assert_eq!(status_of(&resp), 404, "inject_panic lives under /admin: {resp}");
        let resp = post(&addr, "/admin/models/b/inject_panic", "2");
        assert_eq!(status_of(&resp), 200, "panic injection (BOLD_FAULT_INJECT=1): {resp}");
        let statuses: Vec<u16> =
            (0..4).map(|_| status_of(&roundtrip(&addr, &req_b))).collect();
        assert_eq!(
            statuses.iter().filter(|&&s| s == 500).count(),
            2,
            "each injected panic fails exactly one batch: {statuses:?}"
        );
        assert!(
            statuses.iter().all(|&s| s == 500 || s == 200),
            "panicked batches answer, never hang or leak other statuses: {statuses:?}"
        );

        std::thread::sleep(Duration::from_millis(200));
        // hot reload of a under load: same checkpoint, so the canary
        // must pass bit-exact and promotion must be invisible to the
        // open-loop clients
        let resp = post(&addr, "/admin/models/a/load", &ckpt_a);
        assert_eq!(status_of(&resp), 200, "hot reload under load: {resp}");
        assert!(resp.contains("\"version\":2"), "reload promotes v2: {resp}");
        assert!(resp.contains("bit-exact"), "canary replayed golden vectors: {resp}");

        load.join().expect("load thread")
    });

    // zero hung or dropped requests across the soak: every arrival was
    // answered 200 (or deliberately shed) — no timeouts, no transport
    // errors, no unexpected statuses, through panics AND a promotion
    assert!(rep.sent > 100, "soak actually ran: {rep:?}");
    assert_eq!(rep.timeouts, 0, "hung requests during the soak: {rep:?}");
    assert_eq!(rep.io_errors, 0, "transport errors during the soak: {rep:?}");
    assert_eq!(rep.connect_errors, 0, "refused connects during the soak: {rep:?}");
    assert_eq!(rep.other_5xx, 0, "model a must never 500: {rep:?}");
    assert_eq!(rep.other_4xx, 0, "client errors during the soak: {rep:?}");
    assert_eq!(
        rep.ok + rep.shed + rep.expired,
        rep.sent,
        "every request accounted for: {rep:?}"
    );
    assert!(rep.ok >= rep.sent * 9 / 10, "goodput collapsed during the soak: {rep:?}");

    // post-soak bookkeeping over the wire: a is serving its reloaded
    // version, b's contained panics are counted
    let stats = roundtrip(&addr, b"GET /stats HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(stats.contains("\"a\":{\"health\":\"healthy\",\"version\":2"), "{stats}");
    let b_obj = {
        let start = stats.find("\"b\":{").expect("b in stats") + 5;
        let end = stats[start..].find('}').expect("b closes") + start;
        &stats[start..end]
    };
    assert!(b_obj.contains("\"worker_panics\":2"), "panics counted for b: {b_obj}");

    // drain over the wire; the process must exit cleanly and report it
    let resp = post(&addr, "/admin/shutdown", "");
    assert_eq!(status_of(&resp), 200, "shutdown: {resp}");
    assert!(resp.contains("\"draining\":true"), "{resp}");
    let st = wait_with_deadline(&mut child, Duration::from_secs(30));
    assert!(st.success(), "serve-http must exit 0 after a drain, got {st:?}");
    drain_thread.join().expect("stdout drain");
    let tail = tail.lock().unwrap();
    assert!(tail.contains("drained:"), "drain summary printed: {tail}");
}
