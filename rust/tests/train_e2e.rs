//! End-to-end training integration tests across tasks: the full
//! coordinator stack must learn on every workload family the paper
//! evaluates (classification, SR, segmentation, NLU) — fast smoke-scale
//! versions of the report experiments.

use bold::config::TrainConfig;
use bold::coordinator::{evaluate_classifier, ClassifierTrainer};
use bold::data::{ImageDataset, SegDataset, SrDataset};
use bold::models::edsr::psnr;
use bold::models::{
    edsr_small, segnet_boolean, vgg_small, EdsrConfig, SegNetConfig, VggConfig, VggKind,
};
use bold::nn::{l1_loss, softmax_cross_entropy_nchw, Layer, ParamStore, Value};
use bold::optim::{Adam, BooleanOptimizer};
use bold::util::Rng;

#[test]
#[cfg_attr(debug_assertions, ignore = "slow training test: run with cargo test --release")]
fn boolean_vgg_learns_cifar_like() {
    let cfg = TrainConfig {
        steps: 120,
        batch: 64,
        lr_bool: 8.0,
        lr_fp: 2e-3,
        train_size: 768,
        val_size: 192,
        hw: 16,
        width_mult: 0.125,
        ..Default::default()
    };
    let (train, val) =
        ImageDataset::cifar_like(cfg.train_size + cfg.val_size, 10, 3, cfg.hw, 0.25, 1)
            .split(cfg.train_size);
    let vcfg = VggConfig {
        kind: VggKind::Bold,
        hw: cfg.hw,
        width_mult: cfg.width_mult,
        ..Default::default()
    };
    let mut model = vgg_small(&vcfg, &mut Rng::new(cfg.seed));
    let mut trainer = ClassifierTrainer::new(&cfg);
    let report = trainer.fit(&mut model, &train, &val, &cfg, false);
    assert!(
        report.val_acc > 0.5,
        "Boolean VGG should be well above 10% chance: {:.3}",
        report.val_acc
    );
    assert!(report.tail_loss(10) < report.losses[0], "loss must decrease");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow training test: run with cargo test --release")]
fn boolean_edsr_beats_naive_upsampling() {
    let cfg = EdsrConfig { features: 12, blocks: 2, scale: 2, boolean: true, ..Default::default() };
    let train = SrDataset::textures(64, 3, 8, 2, 7);
    let val = SrDataset::textures(12, 3, 8, 2, 8);
    let mut model = edsr_small(&cfg, &mut Rng::new(1));
    let bool_opt = BooleanOptimizer::new(6.0);
    let mut adam = Adam::new(1e-3);
    let mut store = ParamStore::new();
    let mut sampler = bold::data::BatchSampler::new(train.n, 8, 1);
    for _ in 0..120 {
        let idx = sampler.next_batch();
        let (lr, hr) = train.batch(&idx);
        let pred = model.forward(Value::F32(lr), true).expect_f32("sr");
        let out = l1_loss(&pred, &hr);
        store.zero_grads();
        let _ = model.backward(out.grad, &mut store);
        let mut params = model.params();
        bool_opt.step(&mut params, &mut store);
        adam.step(&mut params, &mut store);
    }
    let idx: Vec<usize> = (0..val.n).collect();
    let (lr, hr) = val.batch(&idx);
    // naive baseline: nearest-neighbour upsample
    let (n, c, h, w) = lr.dims4();
    let mut naive = bold::tensor::Tensor::zeros(&hr.shape);
    for ni in 0..n {
        for ci in 0..c {
            for y in 0..h * 2 {
                for x in 0..w * 2 {
                    naive.data[((ni * c + ci) * h * 2 + y) * w * 2 + x] =
                        lr.data[((ni * c + ci) * h + y / 2) * w + x / 2];
                }
            }
        }
    }
    let pred = model.forward(Value::F32(lr), false).expect_f32("sr");
    let p_model = psnr(&pred, &hr);
    let p_naive = psnr(&naive, &hr);
    assert!(p_model > p_naive, "Boolean EDSR {p_model:.2} dB ≤ naive {p_naive:.2} dB");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow training test: run with cargo test --release")]
fn boolean_segnet_beats_majority_class() {
    let train = SegDataset::scenes(48, 5, 3, 16, 0.6, 2);
    let val = SegDataset::scenes(16, 5, 3, 16, 0.6, 3);
    let scfg = SegNetConfig { classes: 5, hw: 16, width: 10, ..Default::default() };
    let mut model = segnet_boolean(&scfg, &mut Rng::new(4));
    let bool_opt = BooleanOptimizer::new(6.0);
    let mut adam = Adam::new(1e-3);
    let mut store = ParamStore::new();
    let mut sampler = bold::data::BatchSampler::new(train.n, 8, 1);
    for _ in 0..100 {
        let idx = sampler.next_batch();
        let (x, labels) = train.batch(&idx);
        let logits = model.forward(Value::F32(x), true).expect_f32("seg");
        let out = softmax_cross_entropy_nchw(&logits, &labels, None);
        store.zero_grads();
        let _ = model.backward(out.grad, &mut store);
        let mut params = model.params();
        bool_opt.step(&mut params, &mut store);
        adam.step(&mut params, &mut store);
    }
    let idx: Vec<usize> = (0..val.n).collect();
    let (x, labels) = val.batch(&idx);
    let logits = model.forward(Value::F32(x), false).expect_f32("seg");
    let preds = logits.nchw_to_rows().argmax_rows();
    let acc = preds.iter().zip(&labels).filter(|(p, l)| p == l).count() as f32
        / labels.len() as f32;
    // majority-class (background) baseline
    // mIoU comparison vs an all-background predictor: predicting only the
    // majority class gets IoU≈bg on class 0 and 0 elsewhere.
    use bold::models::segnet::mean_iou;
    let miou = mean_iou(&preds, &labels, 5, None);
    let all_bg = vec![0usize; labels.len()];
    let miou_bg = mean_iou(&all_bg, &labels, 5, None);
    assert!(
        miou > miou_bg,
        "mIoU {miou:.3} must beat all-background {miou_bg:.3} (pixel acc {acc:.3})"
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow training test: run with cargo test --release")]
fn fp_vs_boolean_accuracy_ordering() {
    // The paper's qualitative ordering on the same task: FP ≥ B⊕LD ≫ chance.
    let cfg = TrainConfig {
        steps: 100,
        batch: 64,
        lr_bool: 8.0,
        lr_fp: 2e-3,
        train_size: 640,
        val_size: 160,
        hw: 16,
        width_mult: 0.125,
        ..Default::default()
    };
    let (train, val) =
        ImageDataset::cifar_like(cfg.train_size + cfg.val_size, 10, 3, cfg.hw, 0.25, 5)
            .split(cfg.train_size);
    let mut accs = Vec::new();
    for kind in [VggKind::Fp, VggKind::Bold] {
        let mut cfg_l = cfg.clone();
        if kind == VggKind::Fp {
            cfg_l.lr_bool = 0.0;
        }
        let vcfg = VggConfig { kind, hw: cfg.hw, width_mult: cfg.width_mult, ..Default::default() };
        let mut model = vgg_small(&vcfg, &mut Rng::new(7));
        let mut trainer = ClassifierTrainer::new(&cfg_l);
        let report = trainer.fit(&mut model, &train, &val, &cfg_l, false);
        accs.push(report.val_acc);
    }
    assert!(accs[1] > 0.4, "B⊕LD ≫ chance: {:.3}", accs[1]);
    assert!(
        accs[0] > accs[1] - 0.15,
        "FP should not lose badly to Boolean at this scale: {accs:?}"
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "slow training test: run with cargo test --release")]
fn finetuning_transfers() {
    // Table 6's headline: a Boolean model fine-tuned from a related task
    // reaches (at least) from-scratch accuracy.
    let cfg = TrainConfig {
        steps: 80,
        batch: 64,
        lr_bool: 8.0,
        train_size: 640,
        val_size: 160,
        hw: 16,
        width_mult: 0.125,
        ..Default::default()
    };
    let (tr_a, va_a) =
        ImageDataset::cifar_like(cfg.train_size + cfg.val_size, 10, 3, cfg.hw, 0.25, 21)
            .split(cfg.train_size);
    let (tr_b, va_b) =
        ImageDataset::cifar_like(cfg.train_size + cfg.val_size, 10, 3, cfg.hw, 0.25, 22)
            .split(cfg.train_size);
    let vcfg = VggConfig {
        kind: VggKind::Bold,
        hw: cfg.hw,
        width_mult: cfg.width_mult,
        ..Default::default()
    };
    // from scratch on B
    let mut scratch = vgg_small(&vcfg, &mut Rng::new(3));
    let mut t1 = ClassifierTrainer::new(&cfg);
    let r_scratch = t1.fit(&mut scratch, &tr_b, &va_b, &cfg, false);
    // pretrain on A then fine-tune on B
    let mut ft = vgg_small(&vcfg, &mut Rng::new(3));
    let mut t2 = ClassifierTrainer::new(&cfg);
    let _ = t2.fit(&mut ft, &tr_a, &va_a, &cfg, false);
    let mut t3 = ClassifierTrainer::new(&cfg);
    let r_ft = t3.fit(&mut ft, &tr_b, &va_b, &cfg, false);
    assert!(
        r_ft.val_acc > r_scratch.val_acc - 0.1,
        "fine-tuned {:.3} should be ≈ or better than scratch {:.3}",
        r_ft.val_acc,
        r_scratch.val_acc
    );
}
