//! Property-style suite for the incremental HTTP parser (ISSUE 6):
//! split-point invariance (any partition of the byte stream produces the
//! identical parse), byte-at-a-time equivalence, and random byte
//! mutations that must never panic — every outcome is Ready, NeedMore,
//! or a clean 4xx/5xx `HttpError`, and whole-buffer vs split feeding
//! agree on it. No fuzzing crate: `bold::util::Rng` drives deterministic
//! mutation streams, so failures replay exactly.

use bold::runtime::{HttpError, HttpLimits, HttpParser, Parse};
use bold::util::Rng;

/// Valid corpus covering the shapes the front-end actually sees.
fn corpus() -> Vec<&'static [u8]> {
    vec![
        b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n",
        b"GET /v1/models HTTP/1.0\r\n\r\n",
        b"POST /v1/models/mlp/predict HTTP/1.1\r\nContent-Type: text/plain\r\nContent-Length: 11\r\n\r\n1 -1 1 -1 1",
        b"POST /admin/shutdown HTTP/1.1\r\nContent-Length: 0\r\nConnection: close\r\n\r\n",
        b"POST /v1/models/vgg/predict HTTP/1.1\r\nContent-Length: 8\r\nExpect: 100-continue\r\n\r\nABCDEFGH",
        b"HEAD /healthz HTTP/1.1\r\nAccept: */*\r\nUser-Agent: loadgen\r\n\r\n",
        b"GET / HTTP/1.1\nHost: lf-only\n\n",
    ]
}

/// Final observable state of a parse, for equality across feed schedules.
#[derive(Debug, PartialEq)]
struct Outcome {
    result: Result<Parse, u16>,
    method: String,
    path: String,
    num_headers: usize,
    content_length: usize,
    body: Vec<u8>,
    keep_alive: bool,
    expects_continue: bool,
}

/// Feed `raw` in the given chunk sizes and snapshot the outcome. After
/// the first error, feeding stops (the server closes the connection
/// there; stickiness is asserted separately).
fn run(raw: &[u8], chunks: &[usize]) -> Outcome {
    let mut p = HttpParser::new(HttpLimits::default());
    let mut result = Ok(Parse::NeedMore);
    let mut off = 0;
    for &c in chunks {
        let end = (off + c).min(raw.len());
        if off >= end {
            break;
        }
        result = p.feed(&raw[off..end]).map_err(|e| e.status);
        if result.is_err() {
            break;
        }
        off = end;
    }
    Outcome {
        result,
        method: p.method().to_string(),
        path: p.path().to_string(),
        num_headers: p.num_headers(),
        content_length: p.content_length(),
        body: p.body().to_vec(),
        keep_alive: p.keep_alive(),
        expects_continue: p.expects_continue(),
    }
}

fn one_shot(raw: &[u8]) -> Outcome {
    run(raw, &[raw.len()])
}

#[test]
fn every_two_chunk_split_matches_one_shot() {
    for raw in corpus() {
        let whole = one_shot(raw);
        assert_eq!(whole.result, Ok(Parse::Ready), "corpus entry must be valid");
        for split in 1..raw.len() {
            let parts = run(raw, &[split, raw.len() - split]);
            assert_eq!(parts, whole, "split at {split} of {:?}", String::from_utf8_lossy(raw));
        }
    }
}

#[test]
fn byte_at_a_time_matches_one_shot() {
    for raw in corpus() {
        let whole = one_shot(raw);
        let ones = vec![1usize; raw.len()];
        assert_eq!(run(raw, &ones), whole, "{:?}", String::from_utf8_lossy(raw));
    }
}

#[test]
fn random_chunk_schedules_match_one_shot() {
    let mut rng = Rng::new(0x6006);
    for raw in corpus() {
        let whole = one_shot(raw);
        for _ in 0..50 {
            let mut chunks = Vec::new();
            let mut left = raw.len();
            while left > 0 {
                let c = 1 + rng.below(left.min(17));
                chunks.push(c);
                left -= c;
            }
            assert_eq!(run(raw, &chunks), whole, "chunks {chunks:?}");
        }
    }
}

#[test]
fn random_mutations_never_panic_and_split_consistently() {
    let mut rng = Rng::new(0xB01D);
    for raw in corpus() {
        for trial in 0..300 {
            let mut bytes = raw.to_vec();
            // 1-3 random byte substitutions anywhere in the request
            for _ in 0..(1 + rng.below(3)) {
                let pos = rng.below(bytes.len());
                bytes[pos] = (rng.next_u64() & 0xff) as u8;
            }
            let b2 = bytes.clone();
            let whole = std::panic::catch_unwind(move || one_shot(&b2))
                .unwrap_or_else(|_| panic!("parser panicked on {bytes:?} (trial {trial})"));
            // outcome is total: Ready, NeedMore, or a clean 4xx/5xx
            if let Err(status) = whole.result {
                assert!(
                    (400..600).contains(&status),
                    "non-HTTP error status {status} for {bytes:?}"
                );
            }
            // split-point invariance holds for mutated inputs too
            let split = 1 + rng.below(bytes.len() - 1);
            let parts = run(&bytes, &[split, bytes.len() - split]);
            assert_eq!(
                parts, whole,
                "mutated input diverged at split {split}: {:?}",
                String::from_utf8_lossy(&bytes)
            );
        }
    }
}

#[test]
fn truncations_never_claim_ready() {
    // any strict prefix of a valid request is NeedMore or a clean error
    for raw in corpus() {
        for cut in 0..raw.len() {
            let out = one_shot(&raw[..cut]);
            assert_ne!(
                out.result,
                Ok(Parse::Ready),
                "prefix of {cut} bytes claimed Ready: {:?}",
                String::from_utf8_lossy(raw)
            );
        }
    }
}

#[test]
fn buffered_bytes_stay_bounded_under_junk_floods() {
    // a head that never terminates must error at the cap, not buffer on
    let limits = HttpLimits { max_head_bytes: 256, max_body_bytes: 64, max_headers: 8 };
    let mut p = HttpParser::new(limits);
    let mut total_err: Option<HttpError> = None;
    for _ in 0..64 {
        match p.feed(&[b'G'; 32]) {
            Ok(_) => assert!(p.buffered() <= 256 + 32, "buffer grew past the cap"),
            Err(e) => {
                total_err = Some(e);
                break;
            }
        }
    }
    assert_eq!(total_err.map(|e| e.status), Some(431));
}

#[test]
fn pipelined_requests_parse_identically_to_sequential() {
    // two requests in one stream, with a split at every byte boundary
    let a: &[u8] = b"POST /v1/models/mlp/predict HTTP/1.1\r\nContent-Length: 4\r\n\r\nwxyz";
    let b: &[u8] = b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n";
    let mut joined = a.to_vec();
    joined.extend_from_slice(b);
    for split in 1..joined.len() {
        let mut p = HttpParser::new(HttpLimits::default());
        let mut r = p.feed(&joined[..split]).expect("valid stream");
        if r == Parse::NeedMore {
            r = p.feed(&joined[split..]).expect("valid stream");
        }
        assert_eq!(r, Parse::Ready, "first request ready (split {split})");
        assert_eq!(p.path(), "/v1/models/mlp/predict");
        assert_eq!(p.body(), b"wxyz");
        let mut r2 = p.consume().expect("second request");
        if r2 == Parse::NeedMore {
            // the tail of the stream had not been fed yet
            r2 = p.feed(&joined[split.max(a.len())..]).expect("valid tail");
        }
        assert_eq!(r2, Parse::Ready, "second request ready (split {split})");
        assert_eq!(p.path(), "/healthz");
        assert!(!p.keep_alive());
    }
}
