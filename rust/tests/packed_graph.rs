//! Bit-exact parity suite for the packed graph executor (ISSUE 4):
//! `PackedGraph::forward` vs the training-path eval forward for VGG-SMALL
//! and Boolean-ResNet configs — including odd channel counts, batches
//! smaller than the thread pool, and BN folded into per-channel integer
//! thresholds — plus checkpoint round-trips, the legacy no-arch
//! fallback, a conv-checkpoint server round-trip, and the precise loader
//! errors.

use bold::coordinator::{read_records, save_checkpoint, save_model, Record};
use bold::models::{
    boolean_mlp, resnet_boolean, vgg_small, MlpConfig, ResNetConfig, VggConfig, VggKind,
};
use bold::nn::{
    BackwardScale, BatchNorm2d, Binarize, BoolConv2d, Flatten, Layer, LayerDesc, Linear,
    ParamRef, Sequential, ThresholdAct, Value,
};
use bold::runtime::{
    GraphScratch, NativeServer, Node, PackedGraph, PackedLayer, PackedMlp, PackedOp, PassConfig,
    ServeConfig,
};
use bold::tensor::Tensor;
use bold::util::Rng;
use std::collections::HashMap;
use std::time::Duration;

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("bold_packed_graph_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_str().unwrap().to_string()
}

/// Move BN running stats and centered-act means off their init values so
/// the BN fold is exercised on non-trivial statistics.
fn warm_up(model: &mut Sequential, shape: &[usize], seed: u64) {
    let mut rng = Rng::new(seed);
    for _ in 0..3 {
        let x = Tensor::randn(shape, 1.0, &mut rng);
        let _ = model.forward(Value::F32(x), true);
    }
}

/// The acceptance check: graph forward on packed ±1 inputs vs the
/// training model's eval forward on the same values. Bit-exact class
/// predictions, logits within 1e-5 (in practice they are identical — the
/// executor replays the training arithmetic exactly).
fn assert_parity(model: &mut Sequential, graph: &PackedGraph, x: &Tensor, what: &str) {
    let reference = model.forward(Value::bit_from_pm1(x), false).expect_f32("ref");
    let native = graph.forward_f32(x);
    assert_eq!(native.shape, reference.shape, "{what}: logit shape");
    assert!(
        native.max_abs_diff(&reference) <= 1e-5,
        "{what}: logits diverged by {}",
        native.max_abs_diff(&reference)
    );
    assert_eq!(native.argmax_rows(), reference.argmax_rows(), "{what}: predictions");
}

#[test]
fn vgg_graph_matches_training_eval() {
    // width 0.125 ⇒ 16/32/64 channels; fc_layers 2 adds a Boolean FC +
    // centered activation to the classifier
    for (with_bn, fc_layers, seed) in [(false, 1usize, 1u64), (true, 1, 2), (true, 2, 3)] {
        let cfg = VggConfig {
            hw: 16,
            width_mult: 0.125,
            with_bn,
            fc_layers,
            ..Default::default()
        };
        let mut rng = Rng::new(seed);
        let mut model = vgg_small(&cfg, &mut rng);
        warm_up(&mut model, &[4, 3, 16, 16], seed + 50);

        let path = tmp(&format!("vgg_{with_bn}_{fc_layers}.ckpt"));
        save_model(&mut model, &path).unwrap();
        let graph = PackedGraph::load(&path).expect("graph load");
        assert_eq!(graph.input_shape, vec![3, 16, 16]);
        assert_eq!(graph.d_out(), 10);

        // batch 3 < any realistic thread-pool size
        let x = Tensor::rand_pm1(&[3, 3, 16, 16], &mut rng);
        assert_parity(&mut model, &graph, &x, &format!("vgg bn={with_bn} fc={fc_layers}"));
    }
}

#[test]
fn vgg_bn_folds_to_zero_op_thresholds() {
    // With BN enabled, the only explicit BatchNorm op left in the graph
    // is the stem's (real-valued input); every post-Boolean-conv BN must
    // have folded into a fused or per-channel integer threshold.
    let cfg = VggConfig { hw: 16, width_mult: 0.125, with_bn: true, ..Default::default() };
    let mut rng = Rng::new(9);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[4, 3, 16, 16], 99);
    // pinned to the full pipeline: this asserts what the fusion pass
    // produces, independent of the ambient BOLD_GRAPH_PASSES matrix
    let graph = PackedGraph::from_layer_with(&mut model, PassConfig::all()).expect("graph");
    let summary = graph.summary();
    assert_eq!(
        summary.matches("BatchNorm").count(),
        1,
        "only the FP-stem BN may stay an explicit op: {summary}"
    );
    assert!(summary.contains("Conv2d+thr"), "conv+threshold fusion missing: {summary}");
    // the pool-carrying convs absorb both their MaxPool and the folded BN
    assert!(summary.contains("Conv2d+pool+thr"), "conv+pool+threshold fusion missing: {summary}");
    let ps = graph.pass_stats();
    assert!(ps.fused_thresholds > 0, "no thresholds fused: {ps:?}");
    assert!(ps.fused_pools >= 1, "no pools fused: {ps:?}");
}

/// The interesting `BOLD_GRAPH_PASSES` selections, labeled. Tests always
/// pin the config through `from_layer_with`/`from_records_with` — never
/// the environment variable, which other test threads read concurrently.
fn pass_configs() -> [(&'static str, PassConfig); 6] {
    [
        ("none", PassConfig::none()),
        ("fuse", PassConfig { fuse: true, ..PassConfig::none() }),
        ("liveness", PassConfig { liveness: true, ..PassConfig::none() }),
        ("lut", PassConfig { lut: true, ..PassConfig::none() }),
        ("fuse,lut", PassConfig { fuse: true, lut: true, ..PassConfig::none() }),
        ("all", PassConfig::all()),
    ]
}

/// Compile `model` under every pass selection and require logits exactly
/// equal to the pass-disabled reference executor (and to the training
/// eval forward): the passes must be bit-exact by construction.
fn assert_pass_parity(model: &mut Sequential, shape: &[usize], rng: &mut Rng, what: &str) {
    let x = Tensor::rand_pm1(shape, rng);
    let reference = PackedGraph::from_layer_with(&mut *model, PassConfig::none())
        .expect("reference graph")
        .forward_f32(&x);
    for (label, cfg) in pass_configs() {
        let graph = PackedGraph::from_layer_with(&mut *model, cfg).expect("graph");
        let y = graph.forward_f32(&x);
        assert_eq!(y.shape, reference.shape, "{what}: passes={label} logit shape");
        assert_eq!(
            y.max_abs_diff(&reference),
            0.0,
            "{what}: passes={label} diverged from the unoptimized executor"
        );
    }
    let full = PackedGraph::from_layer_with(&mut *model, PassConfig::all()).expect("graph");
    assert_parity(model, &full, &x, what);
}

#[test]
fn passes_are_bit_exact_across_archetypes() {
    let mut rng = Rng::new(61);

    // MLP through the arch compiler (LinearCounts + Threshold refusion)
    let cfg = MlpConfig { d_in: 96, hidden: vec![40, 24], d_out: 6, tanh_scale: true };
    let mut mlp = boolean_mlp(&cfg, &mut rng);
    let probe = Tensor::rand_pm1(&[2, 96], &mut rng);
    let _ = mlp.forward(Value::bit_from_pm1(&probe), false);
    assert_pass_parity(&mut mlp, &[5, 96], &mut rng, "mlp");

    // VGG ± BN (threshold + pool fusion, Flatten elision)
    for with_bn in [false, true] {
        let cfg = VggConfig { hw: 16, width_mult: 0.125, with_bn, ..Default::default() };
        let mut model = vgg_small(&cfg, &mut rng);
        warm_up(&mut model, &[4, 3, 16, 16], 62);
        assert_pass_parity(&mut model, &[3, 3, 16, 16], &mut rng, &format!("vgg bn={with_bn}"));
    }

    // ResNet base 8/9: even and odd channel counts through the residual
    // merges, which the liveness pass must keep alias-free
    for (base, hw) in [(8usize, 16usize), (9, 8)] {
        let cfg = ResNetConfig { base, blocks: vec![1, 1], hw, ..Default::default() };
        let mut model = resnet_boolean(&cfg, &mut rng);
        warm_up(&mut model, &[4, 3, hw, hw], 63);
        assert_pass_parity(&mut model, &[3, 3, hw, hw], &mut rng, &format!("resnet base={base}"));
    }
}

/// Lockstep symbolic walk over two structurally identical op lists: each
/// op must read the same dataflow value in both graphs. If the liveness
/// recoloring ever reassigned a slot while its value was still live —
/// including a `Residual` branch output, which stays live until the
/// merge — some later read would resolve to a different value and the
/// walk fails. `va`/`vb` map slot index → value id per graph.
fn assert_dataflow_equivalent(
    a: &[Node],
    b: &[Node],
    va: &mut HashMap<usize, usize>,
    vb: &mut HashMap<usize, usize>,
    next: &mut usize,
    what: &str,
) {
    assert_eq!(a.len(), b.len(), "{what}: node count");
    for (na, nb) in a.iter().zip(b) {
        assert_eq!(na.op.kind(), nb.op.kind(), "{what}: op order");
        match (&na.op, &nb.op) {
            (
                PackedOp::Residual { main: ma, shortcut: sa, main_out: moa, short_out: soa },
                PackedOp::Residual { main: mb, shortcut: sb, main_out: mob, short_out: sob },
            ) => {
                assert_dataflow_equivalent(ma, mb, va, vb, next, what);
                assert_dataflow_equivalent(sa, sb, va, vb, next, what);
                for (x, y, which) in [(moa, mob, "main"), (soa, sob, "shortcut")] {
                    let (vx, vy) = (va.get(x), vb.get(y));
                    assert!(
                        vx.is_some() && vx == vy,
                        "{what}: {which} branch output was clobbered before the merge"
                    );
                }
            }
            _ => {
                let (vx, vy) = (va.get(&na.src), vb.get(&nb.src));
                assert!(
                    vx.is_some() && vx == vy,
                    "{what}: {} reads a clobbered slot",
                    na.op.kind()
                );
            }
        }
        // FpHead writes the logits buffer, not a slot; its dst is vestigial
        if !matches!(na.op, PackedOp::FpHead { .. }) {
            *next += 1;
            va.insert(na.dst, *next);
            vb.insert(nb.dst, *next);
        }
    }
}

#[test]
fn liveness_recoloring_is_alias_free_and_compacts_slots() {
    for (base, hw, seed) in [(8usize, 16usize, 71u64), (9, 8, 72)] {
        let cfg = ResNetConfig { base, blocks: vec![1, 1], hw, ..Default::default() };
        let mut rng = Rng::new(seed);
        let mut model = resnet_boolean(&cfg, &mut rng);
        warm_up(&mut model, &[4, 3, hw, hw], seed + 1);
        let what = format!("resnet base={base}");
        let naive =
            PackedGraph::from_layer_with(&mut model, PassConfig::none()).expect("naive graph");
        let live = PackedGraph::from_layer_with(
            &mut model,
            PassConfig { liveness: true, ..PassConfig::none() },
        )
        .expect("recolored graph");

        let (mut va, mut vb) = (HashMap::new(), HashMap::new());
        va.insert(0usize, 0usize); // slot 0 seeds the input in both
        vb.insert(0usize, 0usize);
        let mut next = 0usize;
        assert_dataflow_equivalent(&naive.nodes, &live.nodes, &mut va, &mut vb, &mut next, &what);

        // the acceptance bar: strictly fewer buffers than one-per-node,
        // and the reported stats agree with the graph itself
        assert!(
            live.n_slots() < naive.n_slots(),
            "{what}: liveness must compact slots ({} vs {})",
            live.n_slots(),
            naive.n_slots()
        );
        let ps = live.pass_stats();
        assert!(ps.liveness && !ps.fuse, "{what}: {ps:?}");
        assert_eq!(ps.raw_slots, naive.n_slots(), "{what}: raw slot count");
        assert_eq!(ps.live_slots, live.n_slots(), "{what}: live slot count");
    }
}

#[test]
fn flatten_is_elided_by_fusion_and_shapes_survive() {
    // fc_layers 2 puts a Boolean FC behind the Flatten, so the elision
    // rewires a real consumer chain rather than just the head
    let cfg = VggConfig {
        hw: 16,
        width_mult: 0.125,
        with_bn: true,
        fc_layers: 2,
        ..Default::default()
    };
    let mut rng = Rng::new(81);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[4, 3, 16, 16], 82);
    let naive = PackedGraph::from_layer_with(&mut model, PassConfig::none()).expect("naive");
    assert!(naive.summary().contains("Flatten"), "{}", naive.summary());
    let fused = PackedGraph::from_layer_with(
        &mut model,
        PassConfig { fuse: true, ..PassConfig::none() },
    )
    .expect("fused");
    assert!(!fused.summary().contains("Flatten"), "{}", fused.summary());
    assert!(fused.pass_stats().elided_flattens >= 1, "{:?}", fused.pass_stats());
    assert!(fused.num_ops() < naive.num_ops(), "fusion must shrink the op list");

    let x = Tensor::rand_pm1(&[2, 3, 16, 16], &mut rng);
    let (a, b) = (naive.forward_f32(&x), fused.forward_f32(&x));
    assert_eq!(a.shape, b.shape, "elision must not change the logit shape");
    assert_eq!(b.max_abs_diff(&a), 0.0, "elision must be bit-exact");
}

#[test]
fn conv_global_avg_pool_fuses_and_stays_exact() {
    // Hand-built arch: BoolConv2d 1→4 k3 p1 on [1,6,6] → GlobalAvgPool →
    // FP head. The GAP folds into the conv (the full-resolution count
    // map is never materialized) but must never carry a threshold — a
    // mean is not integer-valued.
    let words: Vec<u64> = (0..4u64).map(|r| (0x1B6 ^ (r * 0x55)) & 0x1FF).collect();
    let records = vec![
        Record::Arch {
            name: "gapnet".into(),
            input_shape: vec![1, 6, 6],
            layers: vec![
                LayerDesc::BoolConv2d {
                    name: "c".into(),
                    c_in: 1,
                    c_out: 4,
                    k: 3,
                    stride: 1,
                    pad: 1,
                },
                LayerDesc::GlobalAvgPool { name: "gap".into() },
                LayerDesc::Linear { name: "head".into(), n_in: 4, n_out: 3 },
            ],
        },
        Record::Bool { name: "c.weight".into(), rows: 4, cols: 9, words },
        Record::Real {
            name: "head.w".into(),
            data: (0..12).map(|i| (i as f32 * 0.37).sin()).collect(),
        },
        Record::Real { name: "head.b".into(), data: vec![0.1, -0.2, 0.05] },
    ];
    let naive = PackedGraph::from_records_with(&records, PassConfig::none()).expect("naive");
    assert!(naive.summary().contains("GlobalAvgPool"), "{}", naive.summary());
    let fused = PackedGraph::from_records_with(&records, PassConfig::all()).expect("fused");
    assert!(fused.summary().contains("Conv2d+pool"), "{}", fused.summary());
    assert_eq!(fused.pass_stats().fused_pools, 1, "{:?}", fused.pass_stats());
    assert_eq!(fused.pass_stats().fused_thresholds, 0, "{:?}", fused.pass_stats());

    let mut rng = Rng::new(91);
    let x = Tensor::rand_pm1(&[3, 1, 6, 6], &mut rng);
    let (a, b) = (naive.forward_f32(&x), fused.forward_f32(&x));
    assert_eq!(a.shape, vec![3, 3]);
    assert_eq!(b.max_abs_diff(&a), 0.0, "conv+GAP fusion must be bit-exact");
}

#[test]
fn scratch_bytes_reports_retained_footprint() {
    assert_eq!(GraphScratch::new().scratch_bytes(), 0, "fresh scratch holds nothing");
    let cfg = VggConfig { hw: 16, width_mult: 0.125, with_bn: true, ..Default::default() };
    let mut rng = Rng::new(93);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[2, 3, 16, 16], 94);
    let full = PackedGraph::from_layer_with(&mut model, PassConfig::all()).expect("graph");
    let naive = PackedGraph::from_layer_with(&mut model, PassConfig::none()).expect("graph");
    assert!(full.n_slots() < naive.n_slots());

    let x = Tensor::rand_pm1(&[2, 3, 16, 16], &mut rng);
    let packed = bold::tensor::BitMatrix::from_pm1(&x.view(&[2, 3 * 16 * 16]));
    let (mut s_full, mut s_naive) = (GraphScratch::new(), GraphScratch::new());
    full.forward_bits_into(&packed, &mut s_full);
    naive.forward_bits_into(&packed, &mut s_naive);
    assert!(s_full.scratch_bytes() > 0, "a forward must retain buffers");
    // the point of the pipeline: fewer live slots and fused pools ⇒ a
    // strictly smaller retained footprint than the naive executor
    assert!(
        s_full.scratch_bytes() < s_naive.scratch_bytes(),
        "{} vs {}",
        s_full.scratch_bytes(),
        s_naive.scratch_bytes()
    );
}

#[test]
fn resnet_graph_matches_training_eval() {
    // base 9 ⇒ odd channel counts (9, 18) through every conv and the
    // residual merges; base 8 covers the even/strided layout
    for (base, hw, seed) in [(8usize, 16usize, 4u64), (9, 8, 5)] {
        let cfg = ResNetConfig { base, blocks: vec![1, 1], hw, ..Default::default() };
        let mut rng = Rng::new(seed);
        let mut model = resnet_boolean(&cfg, &mut rng);
        warm_up(&mut model, &[4, 3, hw, hw], seed + 60);

        let path = tmp(&format!("resnet_{base}.ckpt"));
        save_model(&mut model, &path).unwrap();
        let graph = PackedGraph::load(&path).expect("graph load");
        assert_eq!(graph.input_shape, vec![3, hw, hw]);
        assert!(graph.summary().contains("Residual"), "{}", graph.summary());

        let x = Tensor::rand_pm1(&[3, 3, hw, hw], &mut rng);
        assert_parity(&mut model, &graph, &x, &format!("resnet base={base}"));
    }
}

#[test]
fn negative_and_zero_gamma_bn_channels_fold_correctly() {
    // A hand-built conv→BN→act net where one BN channel has γ < 0 (the
    // folded compare flips to s ≤ thr) and one has γ = 0 (constant).
    let mut rng = Rng::new(7);
    let mut model = Sequential::new("tiny");
    model.push(Box::new(Binarize::new("bin")));
    model.push(Box::new(BoolConv2d::new("c", 1, 3, 3, 1, 1, &mut rng)));
    model.push(Box::new(BatchNorm2d::new("bn", 3)));
    model.push(Box::new(
        ThresholdAct::new("a", 0.0, BackwardScale::TanhPrime { fanin: 9 }).centered(),
    ));
    model.push(Box::new(Flatten::new("fl")));
    model.push(Box::new(Linear::new("head", 3 * 6 * 6, 4, &mut rng)));
    warm_up(&mut model, &[4, 1, 6, 6], 70);
    for p in model.params() {
        if let ParamRef::Real { name, w } = p {
            if name == "bn.gamma" {
                w.data[0] = -0.7;
                w.data[1] = 0.0;
            }
        }
    }
    let graph = PackedGraph::from_layer(&mut model).expect("graph");
    let x = Tensor::rand_pm1(&[5, 1, 6, 6], &mut rng);
    assert_parity(&mut model, &graph, &x, "tiny conv, γ<0 / γ=0 channels");
}

/// Deterministic 64-bit mixer (splitmix64 finalizer) for hand-built
/// weight words — keeps LUT fixtures reproducible without an Rng dance.
fn mix(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A fan-in-`k` Boolean FC checkpoint: `BoolLinear(k → n_out)` +
/// scalar ThresholdAct + FP head — exactly the shape the `lut` pass
/// folds (via the naive LinearCounts/Threshold pair, or the fused
/// Linear when `fuse` runs first).
fn low_fanin_mlp_records(k: usize, n_out: usize, with_bias: bool, seed: u64) -> Vec<Record> {
    let kmask = (1u64 << k) - 1;
    let words: Vec<u64> = (0..n_out as u64).map(|j| mix(seed ^ j) & kmask).collect();
    let mut records = vec![
        Record::Arch {
            name: "lutnet".into(),
            input_shape: vec![k],
            layers: vec![
                LayerDesc::BoolLinear { name: "bl".into(), n_in: k, n_out, bias: with_bias },
                LayerDesc::ThresholdAct { name: "act".into(), tau: 0.5, centered: false },
                LayerDesc::Linear { name: "head".into(), n_in: n_out, n_out: 4 },
            ],
        },
        Record::Bool { name: "bl.weight".into(), rows: n_out, cols: k, words },
        Record::Real {
            name: "head.w".into(),
            data: (0..4 * n_out).map(|i| (i as f32 * 0.61).sin()).collect(),
        },
        Record::Real { name: "head.b".into(), data: vec![0.3, -0.1, 0.0, 0.2] },
    ];
    if with_bias {
        let wpr = n_out.div_ceil(64);
        let tail = match n_out % 64 {
            0 => u64::MAX,
            t => (1u64 << t) - 1,
        };
        let mut bias: Vec<u64> = (0..wpr as u64).map(|i| mix(seed ^ 0xB1A5 ^ i)).collect();
        *bias.last_mut().unwrap() &= tail;
        records.push(Record::Bool { name: "bl.bias".into(), rows: 1, cols: n_out, words: bias });
    }
    records
}

#[test]
fn lut_fold_matches_popcount_across_fanins() {
    // The tentpole acceptance sweep: every fan-in up to the default cap,
    // with 70 output neurons (two transpose tiles, one partial) and a
    // 130-row batch (two full lane groups plus a 2-lane tail). Odd
    // fan-ins also carry a Boolean bias.
    let mut rng = Rng::new(101);
    for k in 1..=10usize {
        let with_bias = k % 2 == 1;
        let records = low_fanin_mlp_records(k, 70, with_bias, 0xC0FFEE + k as u64);
        let x = Tensor::rand_pm1(&[130, k], &mut rng);
        let reference = PackedGraph::from_records_with(&records, PassConfig::none())
            .expect("reference graph")
            .forward_f32(&x);
        for (label, cfg) in pass_configs() {
            let graph = PackedGraph::from_records_with(&records, cfg).expect("graph");
            let y = graph.forward_f32(&x);
            assert_eq!(
                y.max_abs_diff(&reference),
                0.0,
                "fanin {k}: passes={label} diverged from popcount"
            );
            if cfg.lut {
                let ps = graph.pass_stats();
                assert!(graph.summary().contains("Lut"), "fanin {k}: {}", graph.summary());
                assert_eq!(ps.lut_ops, 1, "fanin {k}: {ps:?}");
                assert_eq!(ps.lut_neurons, 70, "fanin {k}: {ps:?}");
                let tw = (1usize << k).div_ceil(64);
                assert_eq!(ps.lut_table_bytes, 70 * tw * 8, "fanin {k}: {ps:?}");
            }
        }
    }
}

#[test]
fn lut_fold_conv_with_folded_bn_and_padding_is_bit_exact() {
    // Fan-in 9 (1 input channel, k=3) sits under the default cap, so the
    // conv folds to per-channel tables; pad=1 exercises the masked
    // border-lane replay, and the γ<0 / γ=0 BN channels exercise flipped
    // and constant tables.
    let mut rng = Rng::new(107);
    let mut model = Sequential::new("tiny");
    model.push(Box::new(Binarize::new("bin")));
    model.push(Box::new(BoolConv2d::new("c", 1, 3, 3, 1, 1, &mut rng)));
    model.push(Box::new(BatchNorm2d::new("bn", 3)));
    model.push(Box::new(
        ThresholdAct::new("a", 0.0, BackwardScale::TanhPrime { fanin: 9 }).centered(),
    ));
    model.push(Box::new(Flatten::new("fl")));
    model.push(Box::new(Linear::new("head", 3 * 6 * 6, 4, &mut rng)));
    warm_up(&mut model, &[4, 1, 6, 6], 108);
    for p in model.params() {
        if let ParamRef::Real { name, w } = p {
            if name == "bn.gamma" {
                w.data[0] = -0.7;
                w.data[1] = 0.0;
            }
        }
    }
    assert_pass_parity(&mut model, &[5, 1, 6, 6], &mut rng, "lut conv pad=1, γ<0/γ=0");
    let graph = PackedGraph::from_layer_with(&mut model, PassConfig::all()).expect("graph");
    assert!(graph.summary().contains("Conv2dLut"), "{}", graph.summary());
    assert_eq!(graph.pass_stats().lut_neurons, 3, "{:?}", graph.pass_stats());
}

#[test]
fn lut_fold_conv_pad0_scalar_threshold_is_bit_exact() {
    // pad=0: every im2col tap is valid, so the serve path never takes
    // the border fallback; the scalar ThresholdAct covers the
    // Conv2d+Threshold(Scalar) pair form under `lut` alone.
    let words: Vec<u64> = (0..4u64).map(|j| mix(0xBEEF ^ j) & 0x1FF).collect();
    let records = vec![
        Record::Arch {
            name: "lutconv".into(),
            input_shape: vec![1, 8, 8],
            layers: vec![
                LayerDesc::BoolConv2d {
                    name: "c".into(),
                    c_in: 1,
                    c_out: 4,
                    k: 3,
                    stride: 1,
                    pad: 0,
                },
                LayerDesc::ThresholdAct { name: "a".into(), tau: 0.5, centered: false },
                LayerDesc::Flatten { name: "fl".into() },
                LayerDesc::Linear { name: "head".into(), n_in: 4 * 6 * 6, n_out: 3 },
            ],
        },
        Record::Bool { name: "c.weight".into(), rows: 4, cols: 9, words },
        Record::Real {
            name: "head.w".into(),
            data: (0..3 * 144).map(|i| (i as f32 * 0.23).cos()).collect(),
        },
        Record::Real { name: "head.b".into(), data: vec![0.0, 0.1, -0.2] },
    ];
    let mut rng = Rng::new(109);
    let x = Tensor::rand_pm1(&[5, 1, 8, 8], &mut rng);
    let reference = PackedGraph::from_records_with(&records, PassConfig::none())
        .expect("reference graph")
        .forward_f32(&x);
    for (label, cfg) in pass_configs() {
        let graph = PackedGraph::from_records_with(&records, cfg).expect("graph");
        let y = graph.forward_f32(&x);
        assert_eq!(y.max_abs_diff(&reference), 0.0, "conv pad=0: passes={label}");
        if cfg.lut {
            assert!(graph.summary().contains("Conv2dLut"), "{}", graph.summary());
        }
    }
}

#[test]
fn lut_fold_masked_linear_through_from_mlp_is_bit_exact() {
    // A legacy PackedMlp layer with a ternary input mask (zero lanes are
    // the three-valued 𝕄 zero): the shared mask folds into the truth
    // tables, staying bit-identical to xnor_threshold_masked_into.
    let k = 9usize;
    let build = || {
        let words: Vec<u64> = (0..70u64).map(|j| mix(0xA5A5 ^ j) & 0x1FF).collect();
        let layer = PackedLayer {
            weights: bold::tensor::BitMatrix::from_words(70, k, words),
            bias: Some(bold::tensor::BitMatrix::from_words(
                1,
                70,
                vec![mix(0x1234), mix(0x4321) & 0x3F],
            )),
            threshold: 1.5,
            input_mask: Some(vec![0b1_0110_1101]), // 6 of 9 lanes valid
        };
        PackedMlp {
            layers: vec![layer],
            head_w: Tensor::from_vec(
                &[3, 70],
                (0..210).map(|i| (i as f32 * 0.37).sin()).collect(),
            ),
            head_b: Tensor::from_vec(&[3], vec![0.1, -0.3, 0.0]),
        }
    };
    let mut rng = Rng::new(113);
    let x = Tensor::rand_pm1(&[130, k], &mut rng);
    let packed = bold::tensor::BitMatrix::from_pm1(&x.view(&[130, k]));
    let reference = PackedGraph::from_mlp(build(), PassConfig::none()).forward_bits(&packed);
    for (label, cfg) in pass_configs() {
        let graph = PackedGraph::from_mlp(build(), cfg);
        let y = graph.forward_bits(&packed);
        assert_eq!(y.max_abs_diff(&reference), 0.0, "masked mlp: passes={label}");
        if cfg.lut {
            assert!(graph.summary().contains("Lut"), "{}", graph.summary());
        }
    }
}

#[test]
fn wide_layers_stay_on_popcount_and_caps_gate_conversion() {
    // Every fan-in of this MLP (70, 33, 17) exceeds the default cap of
    // 10, so the full pipeline must leave the whole graph on popcount —
    // the stats prove it ran and converted nothing.
    let mut rng = Rng::new(127);
    let cfg = MlpConfig { d_in: 70, hidden: vec![33, 17], d_out: 5, tanh_scale: true };
    let mut model = boolean_mlp(&cfg, &mut rng);
    let probe = Tensor::rand_pm1(&[2, 70], &mut rng);
    let _ = model.forward(Value::bit_from_pm1(&probe), false);
    let graph = PackedGraph::from_layer_with(&mut model, PassConfig::all()).expect("graph");
    let ps = graph.pass_stats();
    assert!(ps.lut, "{ps:?}");
    assert_eq!((ps.lut_ops, ps.lut_neurons, ps.lut_table_bytes), (0, 0, 0), "{ps:?}");
    assert!(!graph.summary().contains("Lut"), "{}", graph.summary());

    // cap gating on a convertible fan-in-6 layer
    let records = low_fanin_mlp_records(6, 12, false, 0xFACE);
    let x = Tensor::rand_pm1(&[9, 6], &mut rng);
    let reference = PackedGraph::from_records_with(&records, PassConfig::none())
        .expect("reference graph")
        .forward_f32(&x);
    // BOLD_LUT_MAX_FANIN=0 disables the stage entirely
    let off = PackedGraph::from_records_with(
        &records,
        PassConfig { lut_max_fanin: 0, ..PassConfig::all() },
    )
    .expect("graph");
    assert!(!off.pass_stats().lut, "{:?}", off.pass_stats());
    assert!(!off.summary().contains("Lut"), "{}", off.summary());
    // a cap below the layer fan-in leaves it on popcount
    let below = PackedGraph::from_records_with(
        &records,
        PassConfig { lut_max_fanin: 5, ..PassConfig::all() },
    )
    .expect("graph");
    assert_eq!(below.pass_stats().lut_ops, 0, "{:?}", below.pass_stats());
    // an over-wide env cap is clamped to the hard max and still converts
    let clamped = PackedGraph::from_records_with(
        &records,
        PassConfig { lut_max_fanin: 64, ..PassConfig::all() },
    )
    .expect("graph");
    assert_eq!(clamped.pass_stats().lut_ops, 1, "{:?}", clamped.pass_stats());
    for (what, g) in [("cap 0", &off), ("cap 5", &below), ("cap 64", &clamped)] {
        assert_eq!(g.forward_f32(&x).max_abs_diff(&reference), 0.0, "{what}");
    }
}

#[test]
fn mlp_save_model_checkpoint_compiles_through_arch() {
    let cfg = MlpConfig { d_in: 70, hidden: vec![33, 17], d_out: 5, tanh_scale: true };
    let mut rng = Rng::new(12);
    let mut model = boolean_mlp(&cfg, &mut rng);
    // forward once so the input shape is recorded
    let probe = Tensor::rand_pm1(&[2, 70], &mut rng);
    let _ = model.forward(Value::bit_from_pm1(&probe), false);

    let path = tmp("mlp_arch.ckpt");
    save_model(&mut model, &path).unwrap();
    // the checkpoint carries an Arch record with the recorded shape
    let records = read_records(&path).unwrap();
    let arch = records
        .iter()
        .find_map(|r| match r {
            Record::Arch { input_shape, layers, .. } => Some((input_shape.clone(), layers.len())),
            _ => None,
        })
        .expect("save_model must embed Record::Arch for describable models");
    assert_eq!(arch, (vec![70], 5)); // 2×(BoolLinear+act) + head

    let graph = PackedGraph::load(&path).expect("graph load");
    assert_eq!((graph.d_in(), graph.d_out()), (70, 5));
    let x = Tensor::rand_pm1(&[9, 70], &mut rng);
    let reference = model.forward(Value::bit_from_pm1(&x), false).expect_f32("ref");
    let native = graph.forward_f32(&x);
    assert_eq!(native.max_abs_diff(&reference), 0.0, "MLP through graph must stay exact");
}

#[test]
fn legacy_param_only_checkpoint_falls_back_to_linear_loader() {
    // save_checkpoint writes params only — no Arch record. The graph
    // loader must route through the PackedMlp compatibility wrapper.
    let cfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
    let mut rng = Rng::new(13);
    let mut model = boolean_mlp(&cfg, &mut rng);
    let path = tmp("legacy_mlp.ckpt");
    save_checkpoint(&mut model.params(), &path).unwrap();

    let graph = PackedGraph::load(&path).expect("fallback load");
    assert_eq!(graph.input_shape, vec![64]);
    let x = Tensor::rand_pm1(&[6, 64], &mut rng);
    let reference = model.forward(Value::bit_from_pm1(&x), false).expect_f32("ref");
    assert_eq!(graph.forward_f32(&x).max_abs_diff(&reference), 0.0);
}

#[test]
fn graph_scratch_reuse_across_batch_sizes_matches_fresh_forward() {
    // The serve-worker pattern: one GraphScratch reused for shrinking and
    // growing batches (conv geometry cache keyed on batch size included).
    let cfg = VggConfig { hw: 16, width_mult: 0.125, with_bn: true, ..Default::default() };
    let mut rng = Rng::new(21);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[4, 3, 16, 16], 22);
    let graph = PackedGraph::from_layer(&mut model).expect("graph");
    let mut scratch = GraphScratch::new();
    for b in [4usize, 1, 6, 2] {
        let x = Tensor::rand_pm1(&[b, 3, 16, 16], &mut rng);
        let flat = x.view(&[b, 3 * 16 * 16]);
        let packed = bold::tensor::BitMatrix::from_pm1(&flat);
        graph.forward_bits_into(&packed, &mut scratch);
        let fresh = graph.forward_bits(&packed);
        assert_eq!(scratch.logits.max_abs_diff(&fresh), 0.0, "batch {b}");
    }
}

#[test]
fn conv_checkpoint_server_round_trip() {
    let cfg = VggConfig { hw: 16, width_mult: 0.125, with_bn: true, ..Default::default() };
    let mut rng = Rng::new(31);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[4, 3, 16, 16], 32);
    let path = tmp("vgg_serve.ckpt");
    save_model(&mut model, &path).unwrap();

    let reference = PackedGraph::load(&path).expect("reference graph");
    let server = NativeServer::start(
        PackedGraph::load(&path).expect("served graph"),
        ServeConfig {
            workers: 2,
            max_batch: 8,
            queue_cap: 16,
            batch_window: Duration::from_micros(100),
        },
    );
    assert_eq!(server.d_in(), 3 * 16 * 16);
    let mut pendings = Vec::new();
    let mut expected = Vec::new();
    for _ in 0..24 {
        let x = Tensor::rand_pm1(&[1, 3, 16, 16], &mut rng);
        expected.push(reference.forward_f32(&x));
        let flat = x.view(&[1, 3 * 16 * 16]);
        pendings.push(server.submit(&flat.data).expect("submit"));
    }
    for (p, want) in pendings.into_iter().zip(expected) {
        let resp = p.wait().expect("response");
        assert_eq!(resp.logits, want.data, "served conv logits must be bit-identical");
        assert_eq!(resp.class, want.argmax_rows()[0]);
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 24);
}

#[test]
fn unsupported_layer_error_names_layer_and_kind() {
    // The FP VGG contains ReLU — refusing it must name the layer.
    let cfg = VggConfig {
        kind: VggKind::Fp,
        hw: 16,
        width_mult: 0.125,
        ..Default::default()
    };
    let mut rng = Rng::new(41);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[2, 3, 16, 16], 42);
    let err = PackedGraph::from_layer(&mut model).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("relu1a") && msg.contains("ReLU"), "{msg}");
}

#[test]
fn missing_weight_record_error_names_the_record() {
    let records = vec![
        Record::Arch {
            name: "m".into(),
            input_shape: vec![8],
            layers: vec![
                LayerDesc::BoolLinear { name: "bl0".into(), n_in: 8, n_out: 4, bias: false },
                LayerDesc::ThresholdAct { name: "act0".into(), tau: 0.0, centered: false },
                LayerDesc::Linear { name: "head".into(), n_in: 4, n_out: 2 },
            ],
        },
        Record::Real { name: "head.w".into(), data: vec![0.0; 8] },
        Record::Real { name: "head.b".into(), data: vec![0.0; 2] },
        // bl0.weight deliberately missing
    ];
    let err = PackedGraph::from_records(&records).unwrap_err();
    assert!(err.to_string().contains("bl0.weight"), "{err}");
}

#[test]
fn stray_record_error_names_the_record() {
    let mut rng = Rng::new(51);
    let cfg = MlpConfig { d_in: 16, hidden: vec![8], d_out: 2, tanh_scale: true };
    let mut model = boolean_mlp(&cfg, &mut rng);
    let probe = Tensor::rand_pm1(&[1, 16], &mut rng);
    let _ = model.forward(Value::bit_from_pm1(&probe), false);
    let path = tmp("stray.ckpt");
    save_model(&mut model, &path).unwrap();
    let mut records = read_records(&path).unwrap();
    records.push(Record::Buffer { name: "ghost.running_var".into(), data: vec![1.0] });
    let err = PackedGraph::from_records(&records).unwrap_err();
    assert!(err.to_string().contains("ghost.running_var"), "{err}");
}

#[test]
fn archless_conv_checkpoint_error_explains_the_fallback() {
    // Conv-shaped records without an Arch record: the fallback loader
    // must name the offending record AND point at the missing arch.
    let records = vec![
        Record::Bool { name: "bl0.weight".into(), rows: 4, cols: 8, words: vec![0; 4] },
        Record::Real { name: "head.w".into(), data: vec![0.0; 8] },
        Record::Real { name: "head.b".into(), data: vec![0.0; 2] },
        Record::Buffer { name: "bn2.running_var".into(), data: vec![1.0; 4] },
    ];
    let err = PackedGraph::from_records(&records).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("bn2.running_var"), "{msg}");
    assert!(msg.contains("architecture record"), "{msg}");
}
