//! Bit-exact parity suite for the packed graph executor (ISSUE 4):
//! `PackedGraph::forward` vs the training-path eval forward for VGG-SMALL
//! and Boolean-ResNet configs — including odd channel counts, batches
//! smaller than the thread pool, and BN folded into per-channel integer
//! thresholds — plus checkpoint round-trips, the legacy no-arch
//! fallback, a conv-checkpoint server round-trip, and the precise loader
//! errors.

use bold::coordinator::{read_records, save_checkpoint, save_model, Record};
use bold::models::{
    boolean_mlp, resnet_boolean, vgg_small, MlpConfig, ResNetConfig, VggConfig, VggKind,
};
use bold::nn::{
    BackwardScale, BatchNorm2d, Binarize, BoolConv2d, Flatten, Layer, LayerDesc, Linear,
    ParamRef, Sequential, ThresholdAct, Value,
};
use bold::runtime::{GraphScratch, NativeServer, PackedGraph, ServeConfig};
use bold::tensor::Tensor;
use bold::util::Rng;
use std::time::Duration;

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("bold_packed_graph_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_str().unwrap().to_string()
}

/// Move BN running stats and centered-act means off their init values so
/// the BN fold is exercised on non-trivial statistics.
fn warm_up(model: &mut Sequential, shape: &[usize], seed: u64) {
    let mut rng = Rng::new(seed);
    for _ in 0..3 {
        let x = Tensor::randn(shape, 1.0, &mut rng);
        let _ = model.forward(Value::F32(x), true);
    }
}

/// The acceptance check: graph forward on packed ±1 inputs vs the
/// training model's eval forward on the same values. Bit-exact class
/// predictions, logits within 1e-5 (in practice they are identical — the
/// executor replays the training arithmetic exactly).
fn assert_parity(model: &mut Sequential, graph: &PackedGraph, x: &Tensor, what: &str) {
    let reference = model.forward(Value::bit_from_pm1(x), false).expect_f32("ref");
    let native = graph.forward_f32(x);
    assert_eq!(native.shape, reference.shape, "{what}: logit shape");
    assert!(
        native.max_abs_diff(&reference) <= 1e-5,
        "{what}: logits diverged by {}",
        native.max_abs_diff(&reference)
    );
    assert_eq!(native.argmax_rows(), reference.argmax_rows(), "{what}: predictions");
}

#[test]
fn vgg_graph_matches_training_eval() {
    // width 0.125 ⇒ 16/32/64 channels; fc_layers 2 adds a Boolean FC +
    // centered activation to the classifier
    for (with_bn, fc_layers, seed) in [(false, 1usize, 1u64), (true, 1, 2), (true, 2, 3)] {
        let cfg = VggConfig {
            hw: 16,
            width_mult: 0.125,
            with_bn,
            fc_layers,
            ..Default::default()
        };
        let mut rng = Rng::new(seed);
        let mut model = vgg_small(&cfg, &mut rng);
        warm_up(&mut model, &[4, 3, 16, 16], seed + 50);

        let path = tmp(&format!("vgg_{with_bn}_{fc_layers}.ckpt"));
        save_model(&mut model, &path).unwrap();
        let graph = PackedGraph::load(&path).expect("graph load");
        assert_eq!(graph.input_shape, vec![3, 16, 16]);
        assert_eq!(graph.d_out(), 10);

        // batch 3 < any realistic thread-pool size
        let x = Tensor::rand_pm1(&[3, 3, 16, 16], &mut rng);
        assert_parity(&mut model, &graph, &x, &format!("vgg bn={with_bn} fc={fc_layers}"));
    }
}

#[test]
fn vgg_bn_folds_to_zero_op_thresholds() {
    // With BN enabled, the only explicit BatchNorm op left in the graph
    // is the stem's (real-valued input); every post-Boolean-conv BN must
    // have folded into a fused or per-channel integer threshold.
    let cfg = VggConfig { hw: 16, width_mult: 0.125, with_bn: true, ..Default::default() };
    let mut rng = Rng::new(9);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[4, 3, 16, 16], 99);
    let graph = PackedGraph::from_layer(&mut model).expect("graph");
    let summary = graph.summary();
    assert_eq!(
        summary.matches("BatchNorm").count(),
        1,
        "only the FP-stem BN may stay an explicit op: {summary}"
    );
    assert!(summary.contains("Conv2d+thr"), "conv+threshold fusion missing: {summary}");
}

#[test]
fn resnet_graph_matches_training_eval() {
    // base 9 ⇒ odd channel counts (9, 18) through every conv and the
    // residual merges; base 8 covers the even/strided layout
    for (base, hw, seed) in [(8usize, 16usize, 4u64), (9, 8, 5)] {
        let cfg = ResNetConfig { base, blocks: vec![1, 1], hw, ..Default::default() };
        let mut rng = Rng::new(seed);
        let mut model = resnet_boolean(&cfg, &mut rng);
        warm_up(&mut model, &[4, 3, hw, hw], seed + 60);

        let path = tmp(&format!("resnet_{base}.ckpt"));
        save_model(&mut model, &path).unwrap();
        let graph = PackedGraph::load(&path).expect("graph load");
        assert_eq!(graph.input_shape, vec![3, hw, hw]);
        assert!(graph.summary().contains("Residual"), "{}", graph.summary());

        let x = Tensor::rand_pm1(&[3, 3, hw, hw], &mut rng);
        assert_parity(&mut model, &graph, &x, &format!("resnet base={base}"));
    }
}

#[test]
fn negative_and_zero_gamma_bn_channels_fold_correctly() {
    // A hand-built conv→BN→act net where one BN channel has γ < 0 (the
    // folded compare flips to s ≤ thr) and one has γ = 0 (constant).
    let mut rng = Rng::new(7);
    let mut model = Sequential::new("tiny");
    model.push(Box::new(Binarize::new("bin")));
    model.push(Box::new(BoolConv2d::new("c", 1, 3, 3, 1, 1, &mut rng)));
    model.push(Box::new(BatchNorm2d::new("bn", 3)));
    model.push(Box::new(
        ThresholdAct::new("a", 0.0, BackwardScale::TanhPrime { fanin: 9 }).centered(),
    ));
    model.push(Box::new(Flatten::new("fl")));
    model.push(Box::new(Linear::new("head", 3 * 6 * 6, 4, &mut rng)));
    warm_up(&mut model, &[4, 1, 6, 6], 70);
    for p in model.params() {
        if let ParamRef::Real { name, w } = p {
            if name == "bn.gamma" {
                w.data[0] = -0.7;
                w.data[1] = 0.0;
            }
        }
    }
    let graph = PackedGraph::from_layer(&mut model).expect("graph");
    let x = Tensor::rand_pm1(&[5, 1, 6, 6], &mut rng);
    assert_parity(&mut model, &graph, &x, "tiny conv, γ<0 / γ=0 channels");
}

#[test]
fn mlp_save_model_checkpoint_compiles_through_arch() {
    let cfg = MlpConfig { d_in: 70, hidden: vec![33, 17], d_out: 5, tanh_scale: true };
    let mut rng = Rng::new(12);
    let mut model = boolean_mlp(&cfg, &mut rng);
    // forward once so the input shape is recorded
    let probe = Tensor::rand_pm1(&[2, 70], &mut rng);
    let _ = model.forward(Value::bit_from_pm1(&probe), false);

    let path = tmp("mlp_arch.ckpt");
    save_model(&mut model, &path).unwrap();
    // the checkpoint carries an Arch record with the recorded shape
    let records = read_records(&path).unwrap();
    let arch = records
        .iter()
        .find_map(|r| match r {
            Record::Arch { input_shape, layers, .. } => Some((input_shape.clone(), layers.len())),
            _ => None,
        })
        .expect("save_model must embed Record::Arch for describable models");
    assert_eq!(arch, (vec![70], 5)); // 2×(BoolLinear+act) + head

    let graph = PackedGraph::load(&path).expect("graph load");
    assert_eq!((graph.d_in(), graph.d_out()), (70, 5));
    let x = Tensor::rand_pm1(&[9, 70], &mut rng);
    let reference = model.forward(Value::bit_from_pm1(&x), false).expect_f32("ref");
    let native = graph.forward_f32(&x);
    assert_eq!(native.max_abs_diff(&reference), 0.0, "MLP through graph must stay exact");
}

#[test]
fn legacy_param_only_checkpoint_falls_back_to_linear_loader() {
    // save_checkpoint writes params only — no Arch record. The graph
    // loader must route through the PackedMlp compatibility wrapper.
    let cfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
    let mut rng = Rng::new(13);
    let mut model = boolean_mlp(&cfg, &mut rng);
    let path = tmp("legacy_mlp.ckpt");
    save_checkpoint(&mut model.params(), &path).unwrap();

    let graph = PackedGraph::load(&path).expect("fallback load");
    assert_eq!(graph.input_shape, vec![64]);
    let x = Tensor::rand_pm1(&[6, 64], &mut rng);
    let reference = model.forward(Value::bit_from_pm1(&x), false).expect_f32("ref");
    assert_eq!(graph.forward_f32(&x).max_abs_diff(&reference), 0.0);
}

#[test]
fn graph_scratch_reuse_across_batch_sizes_matches_fresh_forward() {
    // The serve-worker pattern: one GraphScratch reused for shrinking and
    // growing batches (conv geometry cache keyed on batch size included).
    let cfg = VggConfig { hw: 16, width_mult: 0.125, with_bn: true, ..Default::default() };
    let mut rng = Rng::new(21);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[4, 3, 16, 16], 22);
    let graph = PackedGraph::from_layer(&mut model).expect("graph");
    let mut scratch = GraphScratch::new();
    for b in [4usize, 1, 6, 2] {
        let x = Tensor::rand_pm1(&[b, 3, 16, 16], &mut rng);
        let flat = x.view(&[b, 3 * 16 * 16]);
        let packed = bold::tensor::BitMatrix::from_pm1(&flat);
        graph.forward_bits_into(&packed, &mut scratch);
        let fresh = graph.forward_bits(&packed);
        assert_eq!(scratch.logits.max_abs_diff(&fresh), 0.0, "batch {b}");
    }
}

#[test]
fn conv_checkpoint_server_round_trip() {
    let cfg = VggConfig { hw: 16, width_mult: 0.125, with_bn: true, ..Default::default() };
    let mut rng = Rng::new(31);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[4, 3, 16, 16], 32);
    let path = tmp("vgg_serve.ckpt");
    save_model(&mut model, &path).unwrap();

    let reference = PackedGraph::load(&path).expect("reference graph");
    let server = NativeServer::start(
        PackedGraph::load(&path).expect("served graph"),
        ServeConfig {
            workers: 2,
            max_batch: 8,
            queue_cap: 16,
            batch_window: Duration::from_micros(100),
        },
    );
    assert_eq!(server.d_in(), 3 * 16 * 16);
    let mut pendings = Vec::new();
    let mut expected = Vec::new();
    for _ in 0..24 {
        let x = Tensor::rand_pm1(&[1, 3, 16, 16], &mut rng);
        expected.push(reference.forward_f32(&x));
        let flat = x.view(&[1, 3 * 16 * 16]);
        pendings.push(server.submit(&flat.data).expect("submit"));
    }
    for (p, want) in pendings.into_iter().zip(expected) {
        let resp = p.wait().expect("response");
        assert_eq!(resp.logits, want.data, "served conv logits must be bit-identical");
        assert_eq!(resp.class, want.argmax_rows()[0]);
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 24);
}

#[test]
fn unsupported_layer_error_names_layer_and_kind() {
    // The FP VGG contains ReLU — refusing it must name the layer.
    let cfg = VggConfig {
        kind: VggKind::Fp,
        hw: 16,
        width_mult: 0.125,
        ..Default::default()
    };
    let mut rng = Rng::new(41);
    let mut model = vgg_small(&cfg, &mut rng);
    warm_up(&mut model, &[2, 3, 16, 16], 42);
    let err = PackedGraph::from_layer(&mut model).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("relu1a") && msg.contains("ReLU"), "{msg}");
}

#[test]
fn missing_weight_record_error_names_the_record() {
    let records = vec![
        Record::Arch {
            name: "m".into(),
            input_shape: vec![8],
            layers: vec![
                LayerDesc::BoolLinear { name: "bl0".into(), n_in: 8, n_out: 4, bias: false },
                LayerDesc::ThresholdAct { name: "act0".into(), tau: 0.0, centered: false },
                LayerDesc::Linear { name: "head".into(), n_in: 4, n_out: 2 },
            ],
        },
        Record::Real { name: "head.w".into(), data: vec![0.0; 8] },
        Record::Real { name: "head.b".into(), data: vec![0.0; 2] },
        // bl0.weight deliberately missing
    ];
    let err = PackedGraph::from_records(&records).unwrap_err();
    assert!(err.to_string().contains("bl0.weight"), "{err}");
}

#[test]
fn stray_record_error_names_the_record() {
    let mut rng = Rng::new(51);
    let cfg = MlpConfig { d_in: 16, hidden: vec![8], d_out: 2, tanh_scale: true };
    let mut model = boolean_mlp(&cfg, &mut rng);
    let probe = Tensor::rand_pm1(&[1, 16], &mut rng);
    let _ = model.forward(Value::bit_from_pm1(&probe), false);
    let path = tmp("stray.ckpt");
    save_model(&mut model, &path).unwrap();
    let mut records = read_records(&path).unwrap();
    records.push(Record::Buffer { name: "ghost.running_var".into(), data: vec![1.0] });
    let err = PackedGraph::from_records(&records).unwrap_err();
    assert!(err.to_string().contains("ghost.running_var"), "{err}");
}

#[test]
fn archless_conv_checkpoint_error_explains_the_fallback() {
    // Conv-shaped records without an Arch record: the fallback loader
    // must name the offending record AND point at the missing arch.
    let records = vec![
        Record::Bool { name: "bl0.weight".into(), rows: 4, cols: 8, words: vec![0; 4] },
        Record::Real { name: "head.w".into(), data: vec![0.0; 8] },
        Record::Real { name: "head.b".into(), data: vec![0.0; 2] },
        Record::Buffer { name: "bn2.running_var".into(), data: vec![1.0; 4] },
    ];
    let err = PackedGraph::from_records(&records).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("bn2.running_var"), "{msg}");
    assert!(msg.contains("architecture record"), "{msg}");
}
