//! Fault-injection suite for the TCP/HTTP front-end (ISSUE 6): malformed
//! request lines and headers, oversized heads/bodies, mid-request
//! disconnects, slow-loris byte-dribbling clients, queue overload and
//! connection floods past the accept backlog, and zero deadlines. Every
//! test asserts (a) the precise status code, (b) no worker death — a
//! known-good request succeeds on a fresh connection after each fault.
//!
//! Raw `TcpStream`s throughout: the faults are injected below the HTTP
//! layer, exactly as a hostile peer would.

use bold::coordinator::save_model;
use bold::models::{boolean_mlp, vgg_small, MlpConfig, VggConfig};
use bold::nn::{Layer, Value};
use bold::runtime::{
    HttpConfig, HttpLimits, HttpServer, LifecycleConfig, ModelRegistry, NativeServer, PackedGraph,
    ServeConfig,
};
use bold::tensor::Tensor;
use bold::util::Rng;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

const D_IN: usize = 128;

fn mlp_graph() -> PackedGraph {
    let cfg = MlpConfig { d_in: D_IN, hidden: vec![64, 32], d_out: 10, tanh_scale: true };
    let mut model = boolean_mlp(&cfg, &mut Rng::new(3));
    PackedGraph::from_layer(&mut model).expect("mlp graph")
}

/// A deliberately *slow* model (conv forward, milliseconds per batch):
/// overload tests need the batch worker pinned long enough for the
/// bounded queue to actually fill.
fn slow_graph() -> PackedGraph {
    let cfg = VggConfig { hw: 32, width_mult: 0.25, with_bn: true, ..Default::default() };
    let mut rng = Rng::new(5);
    let mut model = vgg_small(&cfg, &mut rng);
    let probe = Tensor::rand_pm1(&[1, 3, 32, 32], &mut rng);
    let _ = model.forward(Value::F32(probe), false);
    PackedGraph::from_layer(&mut model).expect("vgg graph")
}

fn start(graph: PackedGraph, serve: ServeConfig, cfg: HttpConfig) -> (HttpServer, String) {
    let registry = ModelRegistry::new();
    registry.add("m", graph, serve).expect("register");
    let server = HttpServer::start(registry, "127.0.0.1:0", cfg).expect("bind");
    let addr = server.local_addr().to_string();
    (server, addr)
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("bold_net_faults_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_str().unwrap().to_string()
}

/// Save a fresh seed-`seed` MLP checkpoint with the suite's standard
/// shape (`D_IN` → 64 → 32 → 10) at `path`.
fn mlp_ckpt(path: &str, seed: u64) {
    let cfg = MlpConfig { d_in: D_IN, hidden: vec![64, 32], d_out: 10, tanh_scale: true };
    let mut model = boolean_mlp(&cfg, &mut Rng::new(seed));
    save_model(&mut model, path).expect("save checkpoint");
}

fn default_serve() -> ServeConfig {
    ServeConfig {
        workers: 2,
        max_batch: 8,
        queue_cap: 64,
        batch_window: Duration::from_micros(100),
    }
}

/// Test-tuned front-end config: generous enough not to flake, small
/// enough that timeout tests finish fast.
fn default_http() -> HttpConfig {
    HttpConfig {
        threads: 4,
        limits: HttpLimits { max_head_bytes: 512, max_body_bytes: 4096, max_headers: 16 },
        read_timeout: Duration::from_millis(2_000),
        write_timeout: Duration::from_millis(2_000),
        head_timeout: Duration::from_millis(4_000),
        request_deadline: Duration::from_millis(2_000),
        conn_backlog: 64,
    }
}

/// Write `raw`, half-close, read to EOF. Valid for responses that close
/// the connection (every fault path does).
fn roundtrip_to_eof(addr: &str, raw: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(raw).expect("send");
    s.shutdown(Shutdown::Write).expect("half-close");
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read response");
    out
}

/// Read exactly one framed HTTP response (status line + headers +
/// Content-Length body) from a keep-alive stream.
fn read_framed(s: &mut TcpStream) -> String {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p + 4;
        }
        let n = s.read(&mut chunk).expect("read head");
        assert!(n > 0, "connection closed before a full response head");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let cl: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.trim().eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
        })
        .unwrap_or(0);
    while buf.len() < head_end + cl {
        let n = s.read(&mut chunk).expect("read body");
        assert!(n > 0, "connection closed mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    String::from_utf8_lossy(&buf[..head_end + cl]).to_string()
}

fn predict_named(model: &str, features: usize) -> Vec<u8> {
    let body: String = (0..features)
        .map(|i| if i % 2 == 0 { "1" } else { "-1" })
        .collect::<Vec<_>>()
        .join(",");
    format!(
        "POST /v1/models/{model}/predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn predict_raw(features: usize) -> Vec<u8> {
    predict_named("m", features)
}

/// Render a `POST /admin/models/<name>/<action>` request.
fn admin_raw(model: &str, action: &str, body: &str) -> Vec<u8> {
    format!(
        "POST /admin/models/{model}/{action} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// One request on a fresh keep-alive connection, one framed response.
fn framed_roundtrip(addr: &str, raw: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(raw).expect("send");
    read_framed(&mut s)
}

/// The no-worker-death probe: a fresh connection must complete a real
/// prediction (not just a health check) after whatever fault preceded.
fn assert_healthy(addr: &str, d_in: usize) {
    let mut s = TcpStream::connect(addr).expect("healthy connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&predict_raw(d_in)).expect("healthy send");
    let resp = read_framed(&mut s);
    assert!(
        resp.starts_with("HTTP/1.1 200"),
        "healthy request after fault must return 200, got:\n{resp}"
    );
    assert!(resp.contains("\"class\":"), "prediction body missing: {resp}");
}

fn assert_status(resp: &str, status: u16, what: &str) {
    assert!(
        resp.starts_with(&format!("HTTP/1.1 {status} ")),
        "{what}: expected {status}, got:\n{resp}"
    );
}

#[test]
fn malformed_request_lines_and_headers() {
    let (server, addr) = start(mlp_graph(), default_serve(), default_http());
    for (raw, status, what) in [
        (&b"BADLY FORMED\r\n\r\n"[..], 400u16, "two-token request line"),
        (&b"GET /x HTTP/2.0\r\n\r\n"[..], 505, "unsupported version"),
        (&b"get / HTTP/1.1\r\n\r\n"[..], 400, "lowercase method"),
        (&b"GET relative HTTP/1.1\r\n\r\n"[..], 400, "non-origin-form target"),
        (&b"GET / HTTP/1.1\r\nNoColonHere\r\n\r\n"[..], 400, "header without colon"),
        (&b"GET / HTTP/1.1\r\n bad: folding\r\n\r\n"[..], 400, "leading whitespace header"),
        (&b"POST /v1/models/m/predict HTTP/1.1\r\n\r\n"[..], 411, "POST without Content-Length"),
        (&b"POST / HTTP/1.1\r\nContent-Length: x\r\n\r\n"[..], 400, "unparsable Content-Length"),
        (&b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"[..], 501, "chunked TE"),
        (&b"GET / HTTP/1.1\r\nExpect: 42\r\n\r\n"[..], 417, "unsupported Expect"),
        (&b"\x01\x02\x03\r\n\r\n"[..], 400, "control bytes"),
    ] {
        let resp = roundtrip_to_eof(&addr, raw);
        assert_status(&resp, status, what);
        assert!(resp.contains("Connection: close"), "{what}: fault responses must close");
        assert_healthy(&addr, D_IN);
    }
    drop(server);
}

#[test]
fn oversized_head_and_body_are_rejected() {
    let (server, addr) = start(mlp_graph(), default_serve(), default_http());

    // head past max_head_bytes (512): one huge header line, no terminator
    let mut raw = b"GET / HTTP/1.1\r\nX-Pad: ".to_vec();
    raw.extend_from_slice(&[b'a'; 1024]);
    raw.extend_from_slice(b"\r\n\r\n");
    let resp = roundtrip_to_eof(&addr, &raw);
    assert_status(&resp, 431, "oversized head");
    assert_healthy(&addr, D_IN);

    // more headers than max_headers (16)
    let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
    for i in 0..20 {
        raw.extend_from_slice(format!("H{i}: v\r\n").as_bytes());
    }
    raw.extend_from_slice(b"\r\n");
    let resp = roundtrip_to_eof(&addr, &raw);
    assert_status(&resp, 431, "too many headers");
    assert_healthy(&addr, D_IN);

    // declared body past max_body_bytes (4096) — rejected at the head,
    // before any body byte is read
    let resp = roundtrip_to_eof(
        &addr,
        b"POST /v1/models/m/predict HTTP/1.1\r\nContent-Length: 100000\r\n\r\n",
    );
    assert_status(&resp, 413, "oversized body");
    assert_healthy(&addr, D_IN);
    drop(server);
}

#[test]
fn bad_predict_requests_get_400s_not_crashes() {
    let (server, addr) = start(mlp_graph(), default_serve(), default_http());
    // wrong feature count
    let body = "1,2,3";
    let resp = roundtrip_to_eof(
        &addr,
        format!(
            "POST /v1/models/m/predict HTTP/1.1\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    );
    assert_status(&resp, 400, "wrong feature count");
    // non-numeric garbage
    let body = "this is not a feature vector";
    let resp = roundtrip_to_eof(
        &addr,
        format!(
            "POST /v1/models/m/predict HTTP/1.1\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    );
    assert_status(&resp, 400, "garbage body");
    // binary body with the wrong byte count
    let resp = roundtrip_to_eof(
        &addr,
        b"POST /v1/models/m/predict HTTP/1.1\r\nConnection: close\r\nContent-Type: \
          application/octet-stream\r\nContent-Length: 7\r\n\r\nABCDEFG",
    );
    assert_status(&resp, 400, "binary wrong width");
    // unknown model
    let resp = roundtrip_to_eof(
        &addr,
        b"POST /v1/models/nope/predict HTTP/1.1\r\nConnection: close\r\nContent-Length: 1\r\n\r\n1",
    );
    assert_status(&resp, 404, "unknown model");
    // wrong method on predict
    let resp = roundtrip_to_eof(
        &addr,
        b"GET /v1/models/m/predict HTTP/1.1\r\nConnection: close\r\n\r\n",
    );
    assert_status(&resp, 405, "GET on predict");
    assert!(resp.contains("Allow: POST"), "405 must carry Allow: {resp}");
    // unknown endpoint
    let resp = roundtrip_to_eof(&addr, b"GET /nope HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_status(&resp, 404, "unknown endpoint");
    assert_healthy(&addr, D_IN);
    drop(server);
}

#[test]
fn mid_request_disconnects_do_not_kill_workers() {
    let (server, addr) = start(mlp_graph(), default_serve(), default_http());
    for cut in [4usize, 20, 45] {
        let raw = predict_raw(D_IN);
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.write_all(&raw[..cut]).expect("partial send");
        drop(s); // vanish mid-request
        assert_healthy(&addr, D_IN);
    }
    // the aborted counter increments when the handling worker sees EOF;
    // give the concurrent workers a moment to get there
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let stats = server.stats();
        if stats.aborted >= 1 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "mid-request disconnects must be counted: {stats:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    drop(server);
}

#[test]
fn slow_loris_clients_get_408_and_release_the_worker() {
    let mut cfg = default_http();
    cfg.read_timeout = Duration::from_millis(1_000);
    cfg.head_timeout = Duration::from_millis(300); // total-arrival cap
    let (server, addr) = start(mlp_graph(), default_serve(), cfg);

    // dribble one byte every 40 ms: each read succeeds, but the total
    // head budget expires -> 408. Poll for the response between writes
    // (writing past the server's close could RST away the buffered 408).
    let raw = predict_raw(D_IN);
    let mut s = TcpStream::connect(&addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_millis(5))).unwrap();
    let mut got = Vec::new();
    let mut chunk = [0u8; 1024];
    for byte in raw.iter().take(40) {
        if s.write_all(std::slice::from_ref(byte)).is_err() {
            break; // server already answered and closed
        }
        std::thread::sleep(Duration::from_millis(40));
        match s.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                got.extend_from_slice(&chunk[..n]);
                if got.windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
            }
            Err(_) => {} // no response yet; keep dribbling
        }
    }
    if !got.windows(4).any(|w| w == b"\r\n\r\n") {
        // dribbling ended first; collect the response with a long timeout
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        while let Ok(n) = s.read(&mut chunk) {
            if n == 0 {
                break;
            }
            got.extend_from_slice(&chunk[..n]);
        }
    }
    let resp = String::from_utf8_lossy(&got).to_string();
    assert_status(&resp, 408, "slow-loris dribble");
    assert_healthy(&addr, D_IN);

    // mid-request silence past the per-read timeout -> 408 as well
    let mut cfg = default_http();
    cfg.read_timeout = Duration::from_millis(200);
    let (server2, addr2) = start(mlp_graph(), default_serve(), cfg);
    let mut s = TcpStream::connect(&addr2).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(b"GET /healthz HT").expect("partial head");
    let resp = read_framed(&mut s); // server times the read out at 200 ms
    assert_status(&resp, 408, "silent mid-request");
    assert_healthy(&addr2, D_IN);
    drop(server2);
    drop(server);
}

#[test]
fn overload_sheds_503_with_retry_after_and_recovers() {
    // one worker on a milliseconds-per-forward conv model, queue of 1:
    // a burst must answer every request 200 or 503 -- no hangs, no drops
    let serve = ServeConfig {
        workers: 1,
        max_batch: 1,
        queue_cap: 1,
        batch_window: Duration::from_micros(10),
    };
    let mut cfg = default_http();
    cfg.threads = 12;
    cfg.limits.max_body_bytes = 64 * 1024;
    cfg.request_deadline = Duration::from_secs(30); // only 503s, never 504s
    let graph = slow_graph();
    let d_in = graph.d_in();
    let (server, addr) = start(graph, serve, cfg);

    let (oks, sheds) = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..12)
            .map(|_| {
                let addr = addr.clone();
                sc.spawn(move || {
                    let mut s = TcpStream::connect(&addr).expect("connect");
                    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                    let raw = predict_raw(d_in);
                    let (mut ok, mut shed) = (0usize, 0usize);
                    for _ in 0..4 {
                        s.write_all(&raw).expect("send");
                        let resp = read_framed(&mut s);
                        if resp.starts_with("HTTP/1.1 200") {
                            ok += 1;
                        } else if resp.starts_with("HTTP/1.1 503") {
                            assert!(
                                resp.contains("Retry-After:"),
                                "503 must carry Retry-After: {resp}"
                            );
                            shed += 1;
                        } else {
                            panic!("overload answered neither 200 nor 503:\n{resp}");
                        }
                    }
                    (ok, shed)
                })
            })
            .collect();
        let mut oks = 0;
        let mut sheds = 0;
        for h in handles {
            let (o, s) = h.join().expect("burst client");
            oks += o;
            sheds += s;
        }
        (oks, sheds)
    });
    assert!(oks >= 1, "at least one request must be served under overload");
    assert!(
        sheds >= 1,
        "48 near-simultaneous requests against queue_cap=1 on a slow model must shed \
         (got {oks} ok / {sheds} shed)"
    );
    let stats = server.stats();
    assert_eq!(stats.shed, sheds, "front-end shed counter matches observed 503s");
    // recovery: the same server serves cleanly once the burst is over
    assert_healthy(&addr, d_in);
    drop(server);
}

#[test]
fn connection_flood_past_backlog_is_rejected_not_queued() {
    let mut cfg = default_http();
    cfg.threads = 1; // one busy worker ...
    cfg.conn_backlog = 1; // ... and one connection of headroom
    cfg.read_timeout = Duration::from_millis(400);
    let (server, addr) = start(mlp_graph(), default_serve(), cfg);

    // A occupies the single worker (sends nothing; worker blocks reading)
    let a = TcpStream::connect(&addr).expect("A");
    std::thread::sleep(Duration::from_millis(100)); // let the worker pop A
    // B fills the accept backlog
    let mut b = TcpStream::connect(&addr).expect("B");
    std::thread::sleep(Duration::from_millis(50));
    // C and D must be rejected immediately with 503
    let mut rejected = 0;
    for _ in 0..2 {
        let mut s = TcpStream::connect(&addr).expect("flood conn");
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read rejection");
        if out.starts_with("HTTP/1.1 503") {
            assert!(out.contains("Retry-After:"), "accept-reject carries Retry-After");
            rejected += 1;
        }
    }
    assert!(rejected >= 1, "flood connections past the backlog must see 503");

    // B was queued, not dropped: once A times out (400 ms) the worker
    // picks B up and serves it
    b.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    b.write_all(&predict_raw(D_IN)).expect("B send");
    let resp = read_framed(&mut b);
    assert_status(&resp, 200, "queued connection eventually served");
    drop(a);
    let stats = server.stats();
    assert!(stats.conns_rejected >= 1, "rejections must be counted: {stats:?}");
    assert_healthy(&addr, D_IN);
    drop(server);
}

#[test]
fn zero_deadline_expires_with_504() {
    // batch_window 50 ms + max_batch > 1 means the lone request's answer
    // takes >= the window; a zero deadline must 504 deterministically --
    // and the enqueued work must not wedge the worker
    let serve = ServeConfig {
        workers: 1,
        max_batch: 8,
        queue_cap: 16,
        batch_window: Duration::from_millis(50),
    };
    let mut cfg = default_http();
    cfg.request_deadline = Duration::ZERO;
    let (server, addr) = start(mlp_graph(), serve, cfg);
    let mut s = TcpStream::connect(&addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&predict_raw(D_IN)).expect("send");
    let resp = read_framed(&mut s);
    assert_status(&resp, 504, "zero deadline");
    // health endpoint is not subject to the predict deadline
    s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").expect("send health");
    let resp = read_framed(&mut s);
    assert_status(&resp, 200, "healthz under zero deadline");
    let stats = server.stats();
    assert!(stats.expired >= 1, "504 must be counted: {stats:?}");
    drop(server);
}

#[test]
fn graceful_drain_answers_in_flight_requests() {
    let (server, addr) = start(mlp_graph(), default_serve(), default_http());
    // park a keep-alive connection with a request already submitted
    let mut s = TcpStream::connect(&addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&predict_raw(D_IN)).expect("send");
    let resp = read_framed(&mut s);
    assert_status(&resp, 200, "pre-drain request");

    // trigger the drain over the wire
    let resp = roundtrip_to_eof(&addr, b"POST /admin/shutdown HTTP/1.1\r\nContent-Length: 0\r\n\r\n");
    assert_status(&resp, 200, "shutdown endpoint");
    assert!(resp.contains("\"draining\":true"), "{resp}");
    assert!(server.is_draining());

    // requests on the parked connection still get answered, with close
    s.write_all(&predict_raw(D_IN)).expect("send during drain");
    let resp = read_framed(&mut s);
    assert_status(&resp, 200, "in-flight request during drain");
    assert!(resp.contains("Connection: close"), "drain responses must close: {resp}");

    let stats = server.shutdown();
    assert!(stats.ok >= 3, "all three requests answered: {stats:?}");
}

#[test]
fn worker_panic_is_contained_and_worker_survives() {
    // Direct NativeServer path: a panic inside the batched forward must
    // answer the batch's in-flight requests with an error (not drop
    // their senders), bump the worker_panics counter, and leave the
    // worker thread alive with rebuilt scratch state.
    let server = NativeServer::start(
        mlp_graph(),
        ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_cap: 16,
            batch_window: Duration::from_micros(100),
        },
    );
    let features: Vec<f32> =
        (0..D_IN).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();

    // sanity: clean request before the fault
    server.submit(&features).expect("submit").wait().expect("pre-fault request");

    server.inject_panics(1);
    let err = server
        .submit(&features)
        .expect("submit")
        .wait()
        .expect_err("request in the panicked batch must get an error, not hang");
    assert!(err.msg.contains("panicked"), "error must name the panic: {}", err.msg);

    // the single worker must have survived the panic
    for _ in 0..3 {
        server.submit(&features).expect("submit").wait().expect("post-panic request");
    }
    let stats = server.stats();
    assert!(stats.worker_panics >= 1, "contained panic must be counted: {stats:?}");
    drop(server);
}

#[test]
fn worker_panic_maps_to_500_and_stats_json() {
    // HTTP path: the panicked batch's requests answer 500 (keep-alive
    // preserved — the connection is healthy, the batch was not), later
    // requests on the same connection succeed, and /stats exposes the
    // worker_panics counter.
    let (server, addr) = start(mlp_graph(), default_serve(), default_http());
    server.registry().get("m").expect("registered").inject_panics(1);

    let mut s = TcpStream::connect(&addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&predict_raw(D_IN)).expect("send");
    let resp = read_framed(&mut s);
    assert_status(&resp, 500, "request in panicked batch");
    assert!(resp.contains("panicked"), "500 body names the cause: {resp}");

    // same keep-alive connection serves cleanly afterwards
    s.write_all(&predict_raw(D_IN)).expect("send after panic");
    let resp = read_framed(&mut s);
    assert_status(&resp, 200, "request after contained panic");

    let resp = roundtrip_to_eof(&addr, b"GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_status(&resp, 200, "stats");
    assert!(resp.contains("\"worker_panics\":1"), "panic counter in /stats: {resp}");
    assert_healthy(&addr, D_IN);
    drop(server);
}

#[test]
fn stats_and_listing_endpoints_serve_json() {
    let (server, addr) = start(mlp_graph(), default_serve(), default_http());
    let resp = roundtrip_to_eof(&addr, b"GET /v1/models HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_status(&resp, 200, "model listing");
    assert!(resp.contains("\"name\":\"m\""), "{resp}");
    assert!(resp.contains(&format!("\"d_in\":{D_IN}")), "{resp}");
    assert!(resp.contains("\"lut_neurons\":"), "LUT stats in listing: {resp}");
    assert!(resp.contains("\"lut_table_bytes\":"), "LUT stats in listing: {resp}");
    let resp = roundtrip_to_eof(&addr, b"GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_status(&resp, 200, "stats");
    assert!(resp.contains("\"connections\":"), "{resp}");
    // wrong method on an aux endpoint
    let resp = roundtrip_to_eof(&addr, b"POST /stats HTTP/1.1\r\nContent-Length: 0\r\nConnection: close\r\n\r\n");
    assert_status(&resp, 405, "POST /stats");
    drop(server);
}

/// Extract the flat per-model counter object for `name` from a `/stats`
/// response (`"models":{"<name>":{...}}` — no nested braces inside).
fn model_stats(stats_json: &str, name: &str) -> String {
    let key = format!("\"{name}\":{{");
    let start = stats_json.find(&key).unwrap_or_else(|| panic!("{name} in stats: {stats_json}"))
        + key.len();
    let end = stats_json[start..].find('}').expect("counter object closes") + start;
    stats_json[start..end].to_string()
}

#[test]
fn admin_canary_gates_hot_reload_and_allows_explicit_divergence() {
    // incumbent loaded from a checkpoint so the reload of the *same*
    // file must replay bit-exact through the identical compile path
    let base = tmp("reload_base.ckpt");
    mlp_ckpt(&base, 3);
    let graph = PackedGraph::load(&base).expect("base load");
    let (server, addr) = start(graph, default_serve(), default_http());

    let resp = framed_roundtrip(&addr, &admin_raw("m", "load", &base));
    assert_status(&resp, 200, "bit-exact hot reload");
    assert!(resp.contains("\"version\":2"), "promotion bumps the version: {resp}");
    assert!(resp.contains("bit-exact"), "canary verdict in the response: {resp}");
    assert_healthy(&addr, D_IN);

    // retrained weights (same shape, different seed): the canary must
    // reject the promotion and the incumbent must keep serving
    let diverged = tmp("reload_diverged.ckpt");
    mlp_ckpt(&diverged, 777);
    let resp = framed_roundtrip(&addr, &admin_raw("m", "load", &diverged));
    assert_status(&resp, 409, "canary divergence rejects");
    assert!(resp.contains("canary divergence"), "409 names the cause: {resp}");
    assert_healthy(&addr, D_IN);

    // same checkpoint with the explicit override promotes (shape-checked)
    let body = format!("{diverged} allow_divergence");
    let resp = framed_roundtrip(&addr, &admin_raw("m", "load", &body));
    assert_status(&resp, 200, "allow_divergence promotes retrained weights");
    assert!(resp.contains("\"version\":3"), "{resp}");
    assert_healthy(&addr, D_IN);

    // manual rollback returns to the previous warm version, still serving
    let resp = framed_roundtrip(&addr, &admin_raw("m", "rollback", ""));
    assert_status(&resp, 200, "manual rollback");
    assert!(resp.contains("\"version\":2"), "rollback restores v2: {resp}");
    assert_healthy(&addr, D_IN);

    // a nonexistent checkpoint path is a 400-class load failure for the
    // admin caller; the incumbent keeps serving untouched
    let resp = framed_roundtrip(&addr, &admin_raw("m", "load", "/nonexistent/path.ckpt"));
    assert_status(&resp, 400, "unreadable checkpoint");
    assert_healthy(&addr, D_IN);
    drop(server);
}

#[test]
fn breaker_quarantines_failing_model_isolates_healthy_one_and_freezes_counters() {
    // tight programmatic thresholds: two worker panics open the circuit
    let lc = LifecycleConfig {
        canary_vectors: 4,
        canary_seed: 7,
        breaker_window: 8,
        breaker_errors: 4,
        breaker_panics: 2,
    };
    let registry = ModelRegistry::with_defaults(default_serve(), lc);
    registry.add("good", mlp_graph(), default_serve()).expect("good");
    registry.add("bad", mlp_graph(), default_serve()).expect("bad");
    let server = HttpServer::start(registry, "127.0.0.1:0", default_http()).expect("bind");
    let addr = server.local_addr().to_string();

    for m in ["good", "bad"] {
        let resp = framed_roundtrip(&addr, &predict_named(m, D_IN));
        assert_status(&resp, 200, m);
    }

    // two injected worker panics answer 500 each; the second crosses
    // breaker_panics, and v1 retains no last-known-good, so the model
    // quarantines rather than rolling back
    server.registry().get("bad").expect("bad serving").inject_panics(2);
    for i in 0..2 {
        let resp = framed_roundtrip(&addr, &predict_named("bad", D_IN));
        assert_status(&resp, 500, &format!("panicked batch {i}"));
    }
    let resp = framed_roundtrip(&addr, &predict_named("bad", D_IN));
    assert_status(&resp, 503, "quarantined model refuses");
    assert!(resp.contains("Retry-After:"), "breaker 503 carries Retry-After: {resp}");

    // the blast radius is one model: its neighbour still serves
    let resp = framed_roundtrip(&addr, &predict_named("good", D_IN));
    assert_status(&resp, 200, "healthy model unaffected by the neighbour's breaker");

    // listing reflects the split-brain state and names the cause
    let resp = roundtrip_to_eof(&addr, b"GET /v1/models HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert!(resp.contains("\"health\":\"quarantined\""), "{resp}");
    assert!(resp.contains("\"health\":\"healthy\""), "{resp}");
    assert!(resp.contains("circuit breaker tripped"), "{resp}");

    // the quarantined model's counters are frozen: refused requests are
    // answered 503 without advancing requests/errors/worker_panics
    let stats = roundtrip_to_eof(&addr, b"GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n");
    let before = model_stats(&stats, "bad");
    assert!(before.contains("\"health\":\"quarantined\""), "{before}");
    for _ in 0..3 {
        let resp = framed_roundtrip(&addr, &predict_named("bad", D_IN));
        assert_status(&resp, 503, "still refused");
    }
    let stats = roundtrip_to_eof(&addr, b"GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n");
    let after = model_stats(&stats, "bad");
    assert_eq!(before, after, "a quarantined model's counters must not advance");

    // ... while the healthy model's counters do advance
    let good_before = model_stats(&stats, "good");
    let resp = framed_roundtrip(&addr, &predict_named("good", D_IN));
    assert_status(&resp, 200, "good keeps serving");
    let stats = roundtrip_to_eof(&addr, b"GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_ne!(good_before, model_stats(&stats, "good"), "healthy counters advance");

    // manual recovery: load a fresh checkpoint into the quarantined slot
    let rescue = tmp("breaker_rescue.ckpt");
    mlp_ckpt(&rescue, 3);
    let body = format!("{rescue} allow_divergence");
    let resp = framed_roundtrip(&addr, &admin_raw("bad", "load", &body));
    assert_status(&resp, 200, "load is the way out of quarantine");
    let resp = framed_roundtrip(&addr, &predict_named("bad", D_IN));
    assert_status(&resp, 200, "recovered model serves again");
    drop(server);
}
