//! Determinism suite (DESIGN.md §Parallelism): every kernel that shards
//! across the persistent pool must be **bit-exact** against its
//! single-thread form — same bits, same f32 words, no tolerance.
//!
//! The thread count is forced via `pool::with_thread_budget`, so the suite
//! is meaningful on any machine (on a 1-core runner the parallel path
//! degenerates to inline execution and equality is trivial, which is the
//! correct behaviour, not a skip). Shapes are chosen to actually cross the
//! kernels' work quanta so the multi-shard path engages, and to cover the
//! awkward cases: non-multiple-of-64 fan-in (tail words), batches smaller
//! than the thread count (row-capped sharding), empty (0-sized) operands
//! and all-masked (𝕄-zero) rows.
//!
//! CI runs this file in `--release` as well, where the parallel paths see
//! realistic shard sizes (.github/workflows/ci.yml).

//! The whole file is additionally run under `BOLD_SIMD=scalar` AND the
//! default (auto) backend by CI, so every assertion here holds on both
//! the scalar and the SIMD kernel backends (DESIGN.md §SIMD-Backend).

use bold::nn::{ParamRef, ParamStore};
use bold::optim::BooleanOptimizer;
use bold::tensor::simd::{self, Backend};
use bold::tensor::{BitMatrix, Tensor};
use bold::util::{pool, Rng};

/// Run `f` at thread budget 1 and 8 and return both results.
fn both<R>(mut f: impl FnMut() -> R) -> (R, R) {
    let seq = pool::with_thread_budget(1, &mut f);
    let par = pool::with_thread_budget(8, &mut f);
    (seq, par)
}

/// Shapes that cross the packed kernels' work quantum (so the pool path
/// actually engages at budget 8) plus edge shapes that must stay exact on
/// the sequential fallback: odd fan-in, tiny batch, empty operands.
const PACKED_SHAPES: &[(usize, usize, usize)] = &[
    (66, 70, 2050),  // odd everything, multi-shard
    (128, 129, 4096), // word-aligned fan-in, odd n
    (2, 1024, 4097), // batch smaller than thread count: row-capped shards
    (7, 5, 63),      // small: sequential fallback
    (1, 33, 130),    // single row
    (0, 8, 64),      // empty batch
    (4, 0, 64),      // no output units
    (4, 8, 0),       // zero fan-in
];

fn random_mask(rows: usize, cols: usize, rng: &mut Rng) -> BitMatrix {
    let mut mask = BitMatrix::zeros(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            mask.set(i, j, rng.bernoulli(0.8));
        }
    }
    // one fully-masked ("empty") row: every lane is the 𝕄 zero
    if rows > 0 {
        for j in 0..cols {
            mask.set(rows - 1, j, false);
        }
    }
    mask
}

#[test]
fn xnor_gemm_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(101);
    for &(b, n, m) in PACKED_SHAPES {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let (seq, par) = both(|| x.xnor_gemm(&w));
        assert_eq!(seq, par, "xnor_gemm {b}x{n}x{m}");
    }
}

#[test]
fn xnor_gemm_masked_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(102);
    for &(b, n, m) in PACKED_SHAPES {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let mask = random_mask(b, m, &mut rng);
        let (seq, par) = both(|| x.xnor_gemm_masked(&w, &mask));
        assert_eq!(seq, par, "xnor_gemm_masked {b}x{n}x{m}");
    }
}

#[test]
fn xnor_threshold_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(103);
    for &(b, n, m) in PACKED_SHAPES {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let bias = if n > 0 { Some(BitMatrix::random(1, n, &mut rng)) } else { None };
        for thr in [0.0f32, -2.0] {
            let (seq, par) = both(|| x.xnor_threshold(&w, bias.as_ref(), thr));
            assert_eq!(seq, par, "xnor_threshold {b}x{n}x{m} thr={thr}");
        }
    }
}

#[test]
fn xnor_threshold_masked_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(104);
    for &(b, n, m) in PACKED_SHAPES {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let lane = random_mask(1, m, &mut rng);
        let (seq, par) = both(|| x.xnor_threshold_masked(&w, lane.row(0), None, 0.0));
        assert_eq!(seq, par, "xnor_threshold_masked {b}x{n}x{m}");
    }
}

#[test]
fn backward_input_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(105);
    for &(b, n, m) in PACKED_SHAPES {
        let w = BitMatrix::random(n, m, &mut rng);
        let z = Tensor::randn(&[b, n], 1.0, &mut rng);
        let (seq, par) = both(|| w.backward_input(&z));
        assert_eq!(seq, par, "backward_input {b}x{n}x{m}");
    }
}

#[test]
fn backward_weight_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(106);
    for &(b, n, m) in PACKED_SHAPES {
        let x = BitMatrix::random(b, m, &mut rng);
        let z = Tensor::randn(&[b, n], 1.0, &mut rng);
        let (seq, par) = both(|| x.backward_weight(&z));
        assert_eq!(seq, par, "backward_weight {b}x{n}x{m}");
    }
}

#[test]
fn backward_weight_masked_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(107);
    for &(b, n, m) in PACKED_SHAPES {
        let x = BitMatrix::random(b, m, &mut rng);
        let mask = random_mask(b, m, &mut rng);
        let z = Tensor::randn(&[b, n], 1.0, &mut rng);
        let (seq, par) = both(|| x.backward_weight_masked(&z, &mask));
        assert_eq!(seq, par, "backward_weight_masked {b}x{n}x{m}");
    }
}

/// Dense GEMMs: sharded rows preserve each element's f32 accumulation
/// order, so even floating point must match to the last bit.
#[test]
fn dense_matmuls_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(108);
    for &(m, k, n) in
        &[(80usize, 100usize, 90usize), (130, 515, 64), (2, 2048, 70), (1, 5, 3), (0, 4, 4)]
    {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bt = b.transpose2();
        let at = a.transpose2();
        let (s1, p1) = both(|| a.matmul(&b));
        assert_eq!(s1, p1, "matmul {m}x{k}x{n}");
        let (s2, p2) = both(|| a.matmul_bt(&bt));
        assert_eq!(s2, p2, "matmul_bt {m}x{k}x{n}");
        let (s3, p3) = both(|| at.matmul_at(&b));
        assert_eq!(s3, p3, "matmul_at {m}x{k}x{n}");
    }
}

#[test]
fn im2col_col2im_bit_exact_across_thread_counts() {
    let mut rng = Rng::new(109);
    // 3 images < thread count: the col2im shard count is image-capped.
    for &(n, c, h, k, s, p) in
        &[(3usize, 8usize, 33usize, 3usize, 1usize, 1usize), (5, 4, 19, 3, 2, 1), (1, 2, 7, 3, 1, 0)]
    {
        let x = Tensor::randn(&[n, c, h, h], 1.0, &mut rng);
        let (seq, par) = both(|| x.im2col(k, s, p));
        assert_eq!(seq, par, "im2col n{n} c{c} h{h}");
        let grad = Tensor::randn(&seq.shape, 1.0, &mut rng);
        let (gs, gp) = both(|| grad.col2im(n, c, h, h, k, s, p));
        assert_eq!(gs, gp, "col2im n{n} c{c} h{h}");
    }
}

/// The optimizer's whole observable state transition — packed weights,
/// accumulator, flip count, β — must be identical at any thread budget.
#[test]
fn optimizer_step_bit_exact_across_thread_counts() {
    for (rows, cols) in [(1024usize, 520usize), (3, 70), (256, 4097)] {
        let run = |budget: usize| {
            pool::with_thread_budget(budget, || {
                let mut rng = Rng::new(110);
                let mut bits = BitMatrix::random(rows, cols, &mut rng);
                let grad = Tensor::randn(&[rows, cols], 1.2, &mut rng);
                let mut store = ParamStore::new();
                store.accumulate("w", &grad);
                let opt = BooleanOptimizer::new(1.0);
                let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
                let stats = opt.step(&mut params, &mut store);
                let slot = store.slot("w").unwrap();
                (bits.clone(), stats.flips, slot.accum.data.clone(), slot.ratio)
            })
        };
        let seq = run(1);
        let par = run(8);
        assert_eq!(seq.0, par.0, "{rows}x{cols}: packed weights");
        assert_eq!(seq.1, par.1, "{rows}x{cols}: flip count");
        assert_eq!(seq.2, par.2, "{rows}x{cols}: accumulator");
        assert_eq!(seq.3, par.3, "{rows}x{cols}: beta");
    }
}

/// End to end: a full layer forward/backward through BoolLinear-style
/// kernels gives identical results at any budget (the composition the
/// trainer relies on).
#[test]
fn packed_forward_backward_chain_bit_exact() {
    let mut rng = Rng::new(111);
    let (b, n, m) = (66, 70, 2050);
    let x = BitMatrix::random(b, m, &mut rng);
    let w = BitMatrix::random(n, m, &mut rng);
    let z = Tensor::randn(&[b, n], 0.7, &mut rng);
    let chain = || {
        let s = x.xnor_gemm(&w);
        let q = x.backward_weight(&z);
        let g = w.backward_input(&z);
        (s, q, g)
    };
    let (seq, par) = both(chain);
    assert_eq!(seq.0, par.0, "forward");
    assert_eq!(seq.1, par.1, "weight vote");
    assert_eq!(seq.2, par.2, "input signal");
}

/// Backends × threads: a single-threaded forced-scalar run against a
/// sharded run on the process-wide backend. At budget 8 the thread-local
/// scalar override does NOT reach the pool workers — deliberately: the
/// caller's shard runs scalar while workers run the global (possibly
/// SIMD) backend, so this asserts that even a *mixed-backend* sharded
/// execution is bit-exact against the pure scalar reference, the
/// strongest form of the §SIMD-Backend exactness claim.
#[test]
fn kernels_bit_exact_across_backends_and_thread_counts() {
    let mut rng = Rng::new(112);
    let (b, n, m) = (66, 70, 4099);
    let x = BitMatrix::random(b, m, &mut rng);
    let w = BitMatrix::random(n, m, &mut rng);
    let z = Tensor::randn(&[b, n], 1.0, &mut rng);
    let mut compute = || {
        (
            x.xnor_gemm(&w),
            x.xnor_threshold(&w, None, 0.0),
            x.backward_weight(&z),
            w.backward_input(&z),
        )
    };
    let seq_scalar = pool::with_thread_budget(1, || {
        simd::with_backend(Backend::Scalar, &mut compute)
    });
    let par_mixed = pool::with_thread_budget(8, || {
        simd::with_backend(Backend::Scalar, &mut compute)
    });
    let par_global = pool::with_thread_budget(8, &mut compute);
    assert_eq!(seq_scalar, par_mixed, "mixed scalar/global shards diverge from scalar");
    assert_eq!(seq_scalar, par_global, "global backend diverges from scalar reference");
}
