//! SIMD backend parity suite (DESIGN.md §SIMD-Backend): every kernel
//! routed through the `tensor::simd` dispatch table must be **bit-exact**
//! between the forced-scalar backend and the auto-detected SIMD backend
//! (AVX2 / NEON), across a randomized width sweep of 1..=193 — every
//! tail-word shape, byte-boundary and vector-boundary case — plus
//! wide fan-ins that engage the Harley–Seal block loop (≥ 64 words) and
//! the K-tiling (> 512 words), masked 𝕄-inputs including fully-masked
//! rows, and empty operands.
//!
//! On a machine without a SIMD backend both sides run scalar and the
//! suite degenerates to self-consistency — the correct behaviour, not a
//! skip (same convention as `parallel_determinism.rs`). Everything runs
//! at thread budget 1 so the thread-local backend override covers the
//! whole computation (pool workers keep the process-wide backend);
//! cross-thread mixing is exercised in `parallel_determinism.rs`.

use bold::nn::{ParamRef, ParamStore};
use bold::optim::BooleanOptimizer;
use bold::tensor::simd::{self, Backend};
use bold::tensor::{BitMatrix, Tensor};
use bold::util::{pool, Rng};

/// Run `f` under forced-scalar and under the auto-detected backend,
/// single-threaded, returning both results.
fn ab<R>(mut f: impl FnMut() -> R) -> (R, R) {
    pool::with_thread_budget(1, || {
        let s = simd::with_backend(Backend::Scalar, &mut f);
        let v = simd::with_backend(simd::auto_backend(), &mut f);
        (s, v)
    })
}

/// Random mask with ~80% valid lanes; the last row (when present) is
/// fully masked — every lane the adjoined 𝕄 zero.
fn random_mask(rows: usize, cols: usize, rng: &mut Rng) -> BitMatrix {
    let mut mask = BitMatrix::zeros(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            mask.set(i, j, rng.bernoulli(0.8));
        }
    }
    if rows > 0 {
        for j in 0..cols {
            mask.set(rows - 1, j, false);
        }
    }
    mask
}

#[test]
fn forward_kernels_parity_across_width_sweep() {
    let mut rng = Rng::new(501);
    for m in 1..=193usize {
        let (b, n) = (5, 9);
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let mask = random_mask(b, m, &mut rng);
        let lane = random_mask(1, m, &mut rng);
        let bias = BitMatrix::random(1, n, &mut rng);

        let (s, v) = ab(|| x.xnor_gemm(&w));
        assert_eq!(s, v, "xnor_gemm m={m}");
        let (s, v) = ab(|| x.xnor_gemm_masked(&w, &mask));
        assert_eq!(s, v, "xnor_gemm_masked m={m}");
        let (s, v) = ab(|| x.xnor_threshold(&w, Some(&bias), -1.0));
        assert_eq!(s, v, "xnor_threshold m={m}");
        let (s, v) = ab(|| x.xnor_threshold_masked(&w, lane.row(0), None, 0.0));
        assert_eq!(s, v, "xnor_threshold_masked m={m}");
    }
}

#[test]
fn backward_kernels_parity_across_width_sweep() {
    let mut rng = Rng::new(502);
    for m in 1..=193usize {
        let (b, n) = (4, 7);
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let mask = random_mask(b, m, &mut rng);
        let z = Tensor::randn(&[b, n], 1.0, &mut rng);

        let (s, v) = ab(|| w.backward_input(&z));
        assert_eq!(s, v, "backward_input m={m}");
        let (s, v) = ab(|| x.backward_weight(&z));
        assert_eq!(s, v, "backward_weight m={m}");
        let (s, v) = ab(|| x.backward_weight_masked(&z, &mask));
        assert_eq!(s, v, "backward_weight_masked m={m}");
    }
}

/// Wide fan-ins: ≥ 64 words/row engages the AVX2 Harley–Seal block
/// loop; > 512 words/row crosses a K-tile boundary; odd word counts
/// leave vector and scalar tails. Row counts cross the 4-row block.
#[test]
fn forward_kernels_parity_at_wide_fanin() {
    let mut rng = Rng::new(503);
    for &m in &[4096usize, 4200, 8192 + 67, 33_000] {
        for &(b, n) in &[(1usize, 3usize), (5, 9), (6, 2)] {
            let x = BitMatrix::random(b, m, &mut rng);
            let w = BitMatrix::random(n, m, &mut rng);
            let mask = random_mask(b, m, &mut rng);
            let (s, v) = ab(|| x.xnor_gemm(&w));
            assert_eq!(s, v, "xnor_gemm b={b} n={n} m={m}");
            let (s, v) = ab(|| x.xnor_gemm_masked(&w, &mask));
            assert_eq!(s, v, "xnor_gemm_masked b={b} n={n} m={m}");
            let (s, v) = ab(|| x.xnor_threshold(&w, None, 2.0));
            assert_eq!(s, v, "xnor_threshold b={b} n={n} m={m}");
        }
    }
}

#[test]
fn empty_operands_parity() {
    let mut rng = Rng::new(504);
    for &(b, n, m) in &[(0usize, 8usize, 64usize), (4, 0, 64), (4, 8, 0)] {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let mask = BitMatrix::zeros(b, m);
        let z = Tensor::randn(&[b, n], 1.0, &mut rng);
        let (s, v) = ab(|| x.xnor_gemm(&w));
        assert_eq!(s, v, "xnor_gemm {b}x{n}x{m}");
        let (s, v) = ab(|| x.xnor_gemm_masked(&w, &mask));
        assert_eq!(s, v, "xnor_gemm_masked {b}x{n}x{m}");
        let (s, v) = ab(|| x.xnor_threshold(&w, None, 0.0));
        assert_eq!(s, v, "xnor_threshold {b}x{n}x{m}");
        let (s, v) = ab(|| x.backward_weight(&z));
        assert_eq!(s, v, "backward_weight {b}x{n}x{m}");
    }
}

/// The optimizer's full observable state transition — packed weights,
/// flip count, accumulator, β — under both backends, with and without
/// the |m| ≤ κ clip, across tail-word shapes.
#[test]
fn optimizer_step_parity() {
    for clip in [None, Some(2.0f32)] {
        for &(rows, cols) in &[(3usize, 70usize), (16, 64), (9, 193), (64, 127)] {
            let run = |backend: Backend| {
                pool::with_thread_budget(1, || {
                    simd::with_backend(backend, || {
                        let mut rng = Rng::new(505);
                        let mut bits = BitMatrix::random(rows, cols, &mut rng);
                        let grad = Tensor::randn(&[rows, cols], 1.2, &mut rng);
                        let mut store = ParamStore::new();
                        store.accumulate("w", &grad);
                        let mut opt = BooleanOptimizer::new(1.0);
                        if let Some(k) = clip {
                            opt = opt.with_clip(k);
                        }
                        let mut params =
                            vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
                        let stats = opt.step(&mut params, &mut store);
                        let slot = store.slot("w").unwrap();
                        (bits.clone(), stats.flips, slot.accum.data.clone(), slot.ratio)
                    })
                })
            };
            let s = run(Backend::Scalar);
            let v = run(simd::auto_backend());
            assert_eq!(s.0, v.0, "{rows}x{cols} clip={clip:?}: packed weights");
            assert_eq!(s.1, v.1, "{rows}x{cols} clip={clip:?}: flip count");
            assert_eq!(s.2, v.2, "{rows}x{cols} clip={clip:?}: accumulator");
            assert_eq!(s.3, v.3, "{rows}x{cols} clip={clip:?}: beta");
        }
    }
}

/// End-to-end composition: a BoolLinear-style forward/backward chain and
/// the fused serving kernels agree across backends on one wide shape.
#[test]
fn packed_chain_parity() {
    let mut rng = Rng::new(506);
    let (b, n, m) = (6, 33, 4097);
    let x = BitMatrix::random(b, m, &mut rng);
    let w = BitMatrix::random(n, m, &mut rng);
    let z = Tensor::randn(&[b, n], 0.7, &mut rng);
    let (s, v) = ab(|| {
        let fwd = x.xnor_gemm(&w);
        let q = x.backward_weight(&z);
        let g = w.backward_input(&z);
        let bits = x.xnor_threshold(&w, None, 0.0);
        (fwd, q, g, bits)
    });
    assert_eq!(s.0, v.0, "forward");
    assert_eq!(s.1, v.1, "weight vote");
    assert_eq!(s.2, v.2, "input signal");
    assert_eq!(s.3, v.3, "fused threshold");
}
