//! Cross-check: the native bit-packed Rust engine and the PJRT-compiled L2
//! jax graph must agree EXACTLY (the ±1 embedding of Prop. A.2 makes
//! Boolean logic and integer arithmetic isomorphic — equality, not
//! approximation, modulo f32 rounding in the FP head).
//!
//! Requires `make artifacts` (skips gracefully if absent) and the
//! `xla-runtime` feature with a real xla binding linked — the whole file
//! is compiled out of default builds.

#![cfg(feature = "xla-runtime")]

use bold::models::{boolean_mlp, MlpConfig};
use bold::nn::{Layer, Value};
use bold::runtime::PjrtExecutor;
use bold::tensor::Tensor;
use bold::util::Rng;

fn load_exec() -> Option<PjrtExecutor> {
    if !std::path::Path::new("artifacts/bool_mlp_infer.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtExecutor::load_dir("artifacts").expect("load artifacts"))
}

/// Build the artifact-shaped native MLP and extract its weight tensors.
fn artifact_mlp(rng: &mut Rng) -> (bold::nn::Sequential, Tensor, Tensor, Tensor, Tensor) {
    let cfg = MlpConfig { d_in: 784, hidden: vec![512, 256], d_out: 10, tanh_scale: true };
    let mut model = boolean_mlp(&cfg, rng);
    let mut w1 = None;
    let mut w2 = None;
    let mut wfc = None;
    let mut bfc = None;
    for p in model.params() {
        match p {
            bold::nn::ParamRef::Bool { name, bits, .. } => {
                if name.starts_with("bl0") {
                    w1 = Some(bits.to_pm1());
                } else {
                    w2 = Some(bits.to_pm1());
                }
            }
            bold::nn::ParamRef::Real { name, w, .. } => {
                if name.ends_with(".w") {
                    wfc = Some(w.clone());
                } else {
                    bfc = Some(w.clone());
                }
            }
        }
    }
    (model, w1.unwrap(), w2.unwrap(), wfc.unwrap(), bfc.unwrap())
}

#[test]
fn native_and_xla_forward_agree() {
    let Some(exec) = load_exec() else { return };
    let mut rng = Rng::new(11);
    let (mut model, w1, w2, wfc, bfc) = artifact_mlp(&mut rng);
    let x = Tensor::rand_pm1(&[128, 784], &mut rng);
    let native = model.forward(Value::bit_from_pm1(&x), false).expect_f32("native");
    let xla = exec
        .execute("bool_mlp_infer", &[x, w1, w2, wfc, bfc])
        .expect("xla")
        .remove(0);
    assert_eq!(native.shape, xla.shape);
    let diff = native.max_abs_diff(&xla);
    assert!(diff < 1e-3, "native vs XLA logits differ by {diff}");
}

#[test]
fn native_and_xla_weight_votes_agree() {
    let Some(exec) = load_exec() else { return };
    let mut rng = Rng::new(13);
    let (mut model, w1, w2, wfc, bfc) = artifact_mlp(&mut rng);
    let x = Tensor::rand_pm1(&[128, 784], &mut rng);
    let labels: Vec<usize> = (0..128).map(|i| i % 10).collect();
    let mut y = Tensor::zeros(&[128, 10]);
    for (i, &l) in labels.iter().enumerate() {
        *y.at2_mut(i, l) = 1.0;
    }

    // native: forward + CE + backward (votes land in the ParamStore)
    let logits = model.forward(Value::bit_from_pm1(&x), true).expect_f32("native");
    let out = bold::nn::softmax_cross_entropy(&logits, &labels);
    let mut store = bold::nn::ParamStore::new();
    let _ = model.backward(out.grad, &mut store);
    let mut q1_native = None;
    let mut q2_native = None;
    for p in model.params() {
        if let bold::nn::ParamRef::Bool { name, .. } = p {
            let grad = store.grad(&name).expect("vote buffer").clone();
            if name.starts_with("bl0") {
                q1_native = Some(grad);
            } else {
                q2_native = Some(grad);
            }
        }
    }

    // XLA: the compiled train step
    let res = exec
        .execute("bool_mlp_train_step", &[x, y, w1, w2, wfc, bfc])
        .expect("xla step");
    let (loss_xla, q1_xla, q2_xla) = (res[0].data[0], &res[2], &res[3]);

    assert!((out.loss - loss_xla).abs() < 1e-4, "loss {} vs {}", out.loss, loss_xla);
    let d1 = q1_native.unwrap().max_abs_diff(q1_xla);
    let d2 = q2_native.unwrap().max_abs_diff(q2_xla);
    // Both sides compute the identical closed-form Boolean backward; the
    // only noise is f32 summation order.
    assert!(d1 < 5e-3, "q_w1 votes differ by {d1}");
    assert!(d2 < 5e-3, "q_w2 votes differ by {d2}");
}

#[test]
fn cnn_artifact_executes() {
    let Some(exec) = load_exec() else { return };
    let mut rng = Rng::new(17);
    let x = Tensor::randn(&[32, 3, 16, 16], 1.0, &mut rng);
    let w1 = Tensor::rand_pm1(&[32, 27], &mut rng);
    let w2 = Tensor::rand_pm1(&[64, 288], &mut rng);
    let wfc = Tensor::randn(&[10, 64 * 16], 0.05, &mut rng);
    let bfc = Tensor::zeros(&[10]);
    let out = exec.execute("bool_cnn_infer", &[x, w1, w2, wfc, bfc]).expect("cnn");
    assert_eq!(out[0].shape, vec![32, 10]);
    assert!(out[0].data.iter().all(|v| v.is_finite()));
}
