//! Hot-path benchmark: the xnor-popcount GEMM vs the dense f32 GEMM at
//! equal logical shape — the paper's core arithmetic claim in wall-clock
//! form. (Custom harness: no criterion in the offline registry.)

use bold::tensor::{BitMatrix, Tensor};
use bold::util::{Rng, Timer};

fn main() {
    println!("== bench_gemm: xnor-popcount vs f32 GEMM (logical MACs equal)");
    let mut rng = Rng::new(1);
    for (b, n, m) in [(64, 256, 1024), (128, 512, 4096), (256, 512, 8192)] {
        let macs = (b * n * m) as f64;
        let xb = BitMatrix::random(b, m, &mut rng);
        let wb = BitMatrix::random(n, m, &mut rng);
        let xf = xb.to_pm1();
        let wf = wb.to_pm1();

        let mut t_bit = Timer::new(&format!("xnor_gemm {b}x{n}x{m}"));
        t_bit.bench(2, 7, || {
            std::hint::black_box(xb.xnor_gemm(&wb));
        });
        t_bit.report(Some(macs));

        let mut t_f32 = Timer::new(&format!("f32 matmul {b}x{n}x{m}"));
        t_f32.bench(1, 5, || {
            std::hint::black_box(xf.matmul_bt(&wf));
        });
        t_f32.report(Some(macs));

        println!(
            "    speedup: {:.1}x  (paper premise: Boolean dataflow is ~cheap)\n",
            t_f32.median() / t_bit.median()
        );
    }

    println!("== backward kernels (dense z against packed operands)");
    let (b, n, m) = (128, 512, 4096);
    let xb = BitMatrix::random(b, m, &mut rng);
    let wb = BitMatrix::random(n, m, &mut rng);
    let z = Tensor::randn(&[b, n], 1.0, &mut rng);
    let mut t = Timer::new("backward_input  z@e(W)");
    t.bench(1, 5, || {
        std::hint::black_box(wb.backward_input(&z));
    });
    t.report(Some((b * n * m) as f64));
    let mut t = Timer::new("backward_weight zT@e(X)");
    t.bench(1, 5, || {
        std::hint::black_box(xb.backward_weight(&z));
    });
    t.report(Some((b * n * m) as f64));
}
