//! End-to-end train-step latency per method (the whole-stack hot path):
//! forward + backward + optimizer on the scaled VGG-SMALL, the
//! word-parallel vs per-bit Boolean optimizer-step comparison, plus the
//! native-vs-XLA MLP step comparison when artifacts are present.

use bold::baselines::{bnn_vgg_small, BnnKind};
use bold::config::TrainConfig;
use bold::coordinator::ClassifierTrainer;
use bold::data::ImageDataset;
use bold::models::{vgg_small, VggConfig, VggKind};
use bold::nn::{ParamRef, ParamStore, Value};
use bold::optim::BooleanOptimizer;
use bold::tensor::{BitMatrix, Tensor};
use bold::util::{Rng, Timer};

/// The pre-refactor optimizer inner loop (bit-at-a-time `get`/`flip`),
/// kept here as the "before" baseline for the word-parallel kernel.
#[allow(clippy::needless_range_loop)]
fn step_per_bit_reference(
    lr: f32,
    bits: &mut BitMatrix,
    grad: &Tensor,
    accum: &mut Tensor,
    ratio: &mut f32,
) -> usize {
    let (rows, cols) = (bits.rows, bits.cols);
    let beta = *ratio;
    let mut flips = 0usize;
    for r in 0..rows {
        for c in 0..cols {
            let idx = r * cols + c;
            let m = beta * accum.data[idx] + lr * grad.data[idx];
            let w = if bits.get(r, c) { 1.0 } else { -1.0 };
            if m * w >= 1.0 {
                bits.flip(r, c);
                accum.data[idx] = 0.0;
                flips += 1;
            } else {
                accum.data[idx] = m;
            }
        }
    }
    *ratio = 1.0 - flips as f32 / (rows * cols).max(1) as f32;
    flips
}

/// Optimizer-step microbench: per-bit baseline vs the word-parallel
/// flip-mask kernel, on VGG/MLP-representative tensor shapes.
fn optimizer_step_comparison() {
    println!("\n== Boolean optimizer step: per-bit (before) vs word-parallel (after)");
    let mut rng = Rng::new(9);
    for (r, c) in [(512usize, 1024usize), (1024, 4096), (4096, 4096)] {
        let weights = (r * c) as f64;
        let grad = Tensor::randn(&[r, c], 0.5, &mut rng);

        let mut bits_a = BitMatrix::random(r, c, &mut rng);
        let mut accum = Tensor::zeros(&[r, c]);
        let mut ratio = 1.0f32;
        let mut t = Timer::new(&format!("per-bit step {r}x{c}"));
        t.bench(2, 9, || {
            std::hint::black_box(step_per_bit_reference(
                1.0,
                &mut bits_a,
                &grad,
                &mut accum,
                &mut ratio,
            ));
        });
        t.report(Some(weights));

        let mut bits_b = BitMatrix::random(r, c, &mut rng);
        let mut store = ParamStore::new();
        store.accumulate("w", &grad);
        let opt = BooleanOptimizer::new(1.0);
        let mut t = Timer::new(&format!("word-parallel step {r}x{c}"));
        t.bench(2, 9, || {
            let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits_b }];
            std::hint::black_box(opt.step(&mut params, &mut store));
        });
        t.report(Some(weights));
    }
}

fn main() {
    println!("== bench_train_step: one fwd+bwd+step, VGG-SMALL 16x16 w=0.125, batch 64");
    let cfg = TrainConfig { hw: 16, width_mult: 0.125, batch: 64, cosine: false, ..Default::default() };
    let ds = ImageDataset::cifar_like(256, 10, 3, cfg.hw, 0.25, 1);
    let idx: Vec<usize> = (0..cfg.batch).collect();
    let (x, labels) = ds.batch(&idx);

    let vcfg = VggConfig { hw: cfg.hw, width_mult: cfg.width_mult, ..Default::default() };
    for name in ["B⊕LD", "FP32", "BinaryNet"] {
        let mut rng = Rng::new(1);
        let mut model = match name {
            "B⊕LD" => vgg_small(&vcfg, &mut rng),
            "FP32" => vgg_small(&VggConfig { kind: VggKind::Fp, ..vcfg.clone() }, &mut rng),
            _ => bnn_vgg_small(BnnKind::BinaryNet, &vcfg, &mut rng),
        };
        let mut trainer = ClassifierTrainer::new(&cfg);
        let mut t = Timer::new(&format!("train_step {name}"));
        let mut step = 0usize;
        t.bench(2, 7, || {
            let _ = trainer.train_step(&mut model, Value::F32(x.clone()), &labels, step);
            step += 1;
        });
        t.report(None);
    }

    optimizer_step_comparison();
    xla_comparison();
}

/// XLA-vs-native step comparison; only meaningful with the `xla-runtime`
/// feature and `make artifacts`.
#[cfg(feature = "xla-runtime")]
fn xla_comparison() {
    // XLA path (skipped when artifacts are absent)
    if std::path::Path::new("artifacts/bool_mlp_train_step.hlo.txt").exists() {
        println!("\n== XLA train step (compiled L2 graph, MLP 784-512-256-10, batch 128)");
        let exec = bold::runtime::PjrtExecutor::load_dir("artifacts").expect("artifacts");
        let mut rng = Rng::new(3);
        let x = bold::tensor::Tensor::rand_pm1(&[128, 784], &mut rng);
        let mut y = bold::tensor::Tensor::zeros(&[128, 10]);
        for i in 0..128 {
            *y.at2_mut(i, i % 10) = 1.0;
        }
        let w1 = bold::tensor::Tensor::rand_pm1(&[512, 784], &mut rng);
        let w2 = bold::tensor::Tensor::rand_pm1(&[256, 512], &mut rng);
        let wfc = bold::tensor::Tensor::randn(&[10, 256], 0.05, &mut rng);
        let bfc = bold::tensor::Tensor::zeros(&[10]);
        let mut t = Timer::new("xla bool_mlp_train_step");
        t.bench(2, 9, || {
            std::hint::black_box(
                exec.execute(
                    "bool_mlp_train_step",
                    &[x.clone(), y.clone(), w1.clone(), w2.clone(), wfc.clone(), bfc.clone()],
                )
                .unwrap(),
            );
        });
        t.report(None);

        // native equivalent for the same shapes
        use bold::models::{boolean_mlp, MlpConfig};
        let mcfg = MlpConfig { d_in: 784, hidden: vec![512, 256], d_out: 10, tanh_scale: true };
        let mut model = boolean_mlp(&mcfg, &mut Rng::new(4));
        let labels: Vec<usize> = (0..128).map(|i| i % 10).collect();
        let cfg2 = TrainConfig { batch: 128, cosine: false, ..Default::default() };
        let mut trainer = ClassifierTrainer::new(&cfg2);
        let mut t = Timer::new("native bool mlp train_step");
        let mut step = 0usize;
        t.bench(2, 9, || {
            let _ = trainer.train_step(&mut model, Value::bit_from_pm1(&x), &labels, step);
            step += 1;
        });
        t.report(None);
    } else {
        println!("(artifacts absent — run `make artifacts` for the XLA comparison)");
    }
}

#[cfg(not(feature = "xla-runtime"))]
fn xla_comparison() {
    println!("(built without --features xla-runtime — skipping the XLA step comparison)");
}
