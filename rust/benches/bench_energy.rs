//! Energy-model benchmark + the Fig. 1 / Table 2 / Table 5 energy columns
//! (the analytic part of every energy table in the paper, end to end).

use bold::energy::{network_energy, resnet18_shapes, vgg_small_shapes, Method, ASCEND, V100};
use bold::util::Timer;

fn main() {
    println!("== bench_energy: tiling search + network aggregation wall time");
    let shapes = resnet18_shapes(32, 64);
    let hw = V100();
    let mut t = Timer::new("resnet18 full-network energy eval");
    t.bench(1, 5, || {
        std::hint::black_box(network_energy(&shapes, &hw, Method::Bold, true));
    });
    t.report(None);

    println!("\n== Fig. 1 / Table 2 energy columns (VGG-SMALL, training iter)");
    for hw in [ASCEND(), V100()] {
        let shapes = vgg_small_shapes(100);
        let fp = network_energy(&shapes, &hw, Method::Fp32, true).total_pj();
        println!("--- {}", hw.name);
        for m in Method::all() {
            let e = network_energy(&shapes, &hw, m, true).total_pj();
            println!("{:<18} {:>8.2}% of FP", m.name(), e / fp * 100.0);
        }
    }

    println!("\n== Table 5 energy column (ResNet18 base sweep, V100, training iter)");
    let hw = V100();
    let fp = network_energy(&resnet18_shapes(32, 64), &hw, Method::Fp32, true).total_pj();
    for base in [64, 128, 192, 256] {
        let e = network_energy(&resnet18_shapes(32, base), &hw, Method::Bold, true).total_pj();
        println!("B⊕LD base {base:<4} {:>8.2}% of FP", e / fp * 100.0);
    }
}
