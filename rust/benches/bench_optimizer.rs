//! Boolean-optimizer step throughput (weights/second) — the training-side
//! hot loop after the GEMMs. Exercises the word-parallel flip-mask kernel
//! (per-word XOR + multi-threaded row sharding).

use bold::nn::{ParamRef, ParamStore};
use bold::optim::BooleanOptimizer;
use bold::tensor::{BitMatrix, Tensor};
use bold::util::{Rng, Timer};

fn main() {
    println!("== bench_optimizer: Boolean optimizer step (Algorithm 8, word-parallel)");
    let mut rng = Rng::new(2);
    for (r, c) in [(512, 1024), (1024, 4096), (4096, 4096)] {
        let mut bits = BitMatrix::random(r, c, &mut rng);
        let grad = Tensor::randn(&[r, c], 0.5, &mut rng);
        let mut store = ParamStore::new();
        store.accumulate("w", &grad);
        let opt = BooleanOptimizer::new(1.0);
        let weights = (r * c) as f64;
        let mut t = Timer::new(&format!("bool step {r}x{c}"));
        t.bench(2, 9, || {
            let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
            std::hint::black_box(opt.step(&mut params, &mut store));
        });
        t.report(Some(weights));
    }

    println!("\n== Adam step on equal-size FP tensors (for contrast)");
    let mut adam = bold::optim::Adam::new(1e-3);
    for (r, c) in [(1024usize, 4096usize)] {
        let mut w = Tensor::randn(&[r, c], 0.1, &mut rng);
        let g = Tensor::randn(&[r, c], 0.1, &mut rng);
        let mut store = ParamStore::new();
        store.accumulate("w", &g);
        let mut t = Timer::new(&format!("adam step {r}x{c}"));
        t.bench(2, 9, || {
            let mut params = vec![ParamRef::Real { name: "w".into(), w: &mut w }];
            adam.step(&mut params, &mut store);
        });
        t.report(Some((r * c) as f64));
    }
}
