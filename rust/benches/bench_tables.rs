//! `cargo bench --bench bench_tables` — regenerates every paper table and
//! figure in quick mode (the full-budget versions run via
//! `bold report <id>`). This is the single entry point that exercises the
//! complete reproduction matrix end to end.

fn main() {
    let t0 = std::time::Instant::now();
    bold::report::run("all", true).expect("report harness");
    println!(
        "\n== all paper tables/figures regenerated (quick mode) in {:.1}s ==",
        t0.elapsed().as_secs_f64()
    );
}
