//! Kernel sweep with a threads = {1, N} column and a SIMD backend A/B
//! section: every packed and dense hot-path kernel, sequential vs
//! sharded across the persistent pool (DESIGN.md §Parallelism), and the
//! popcount kernels under forced-scalar vs the auto-detected SIMD
//! backend (DESIGN.md §SIMD-Backend). Template rows for EXPERIMENTS.md
//! §Perf.
//!
//! Besides the stdout table, the run emits machine-readable
//! `BENCH_kernels.json` (one record per measured cell: kernel, dims,
//! threads, simd backend, ns/iter, Gop/s) into `BOLD_BENCH_JSON_DIR`
//! (default: current directory) so the perf trajectory is tracked
//! across PRs instead of living only in prose.
//!
//! The thread column is driven by `pool::with_thread_budget` and the
//! backend column by `simd::with_backend`, so a single run measures all
//! paths on identical inputs; `tests/parallel_determinism.rs` and
//! `tests/simd_parity.rs` separately assert the paths are bit-exact.
//! (Custom harness: no criterion in the offline registry.)

use bold::nn::{ParamRef, ParamStore};
use bold::optim::BooleanOptimizer;
use bold::runtime::{PackedLayer, PackedLut};
use bold::tensor::simd::{self, Backend};
use bold::tensor::{BitMatrix, Tensor};
use bold::util::{pool, Rng, Timer};

/// One measured cell, serialised into BENCH_kernels.json.
struct Rec {
    kernel: String,
    dims: String,
    threads: usize,
    simd: &'static str,
    ns_per_iter: f64,
    gops: f64,
}

fn write_json(file: &str, recs: &[Rec]) {
    let dir = std::env::var("BOLD_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{dir}/{file}");
    let mut s = String::from("[\n");
    for (i, r) in recs.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"kernel\":\"{}\",\"dims\":\"{}\",\"threads\":{},\"simd\":\"{}\",\
             \"ns_per_iter\":{:.1},\"gops\":{:.3}}}{}\n",
            r.kernel,
            r.dims,
            r.threads,
            r.simd,
            r.ns_per_iter,
            r.gops,
            if i + 1 < recs.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    match std::fs::write(&path, s) {
        Ok(()) => println!("\nwrote {path} ({} records)", recs.len()),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}

/// Median seconds for `f` under a fixed intra-op thread budget.
fn timed<F: FnMut()>(name: &str, budget: usize, mut f: F) -> f64 {
    pool::with_thread_budget(budget, || {
        let mut t = Timer::new(name);
        t.bench(2, 7, &mut f);
        t.median()
    })
}

/// One table row: kernel × shape, threads=1 vs threads=N, speedup.
/// Records both cells under the process-wide SIMD backend.
fn row(recs: &mut Vec<Rec>, kernel: &str, dims: String, work: f64, mut f: impl FnMut()) {
    let n = pool::num_threads();
    let t1 = timed(kernel, 1, &mut f);
    let tn = timed(kernel, n, &mut f);
    let label = format!("{kernel} {dims}");
    println!(
        "{label:<44} t1 {:>9.3} ms  t{n} {:>9.3} ms  speedup {:>5.2}x  {:>8.2} Gop/s",
        t1 * 1e3,
        tn * 1e3,
        t1 / tn,
        work / tn / 1e9
    );
    for (threads, t) in [(1usize, t1), (n, tn)] {
        recs.push(Rec {
            kernel: kernel.to_string(),
            dims: dims.clone(),
            threads,
            simd: simd::backend_name(),
            ns_per_iter: t * 1e9,
            gops: work / t / 1e9,
        });
    }
}

/// Single-thread scalar-vs-SIMD A/B for one kernel (the ISSUE-5
/// acceptance cell: speedup at K ≥ 4096).
fn ab_row(recs: &mut Vec<Rec>, kernel: &str, dims: String, work: f64, mut f: impl FnMut()) {
    let auto = simd::auto_backend();
    let t_scalar = simd::with_backend(Backend::Scalar, || timed(kernel, 1, &mut f));
    let t_simd = simd::with_backend(auto, || timed(kernel, 1, &mut f));
    let label = format!("{kernel} {dims}");
    println!(
        "{label:<44} scalar {:>9.3} ms  {} {:>9.3} ms  speedup {:>5.2}x  {:>8.2} Gop/s",
        t_scalar * 1e3,
        auto.name(),
        t_simd * 1e3,
        t_scalar / t_simd,
        work / t_simd / 1e9
    );
    for (simd_name, t) in [("scalar", t_scalar), (auto.name(), t_simd)] {
        recs.push(Rec {
            kernel: kernel.to_string(),
            dims: dims.clone(),
            threads: 1,
            simd: simd_name,
            ns_per_iter: t * 1e9,
            gops: work / t / 1e9,
        });
    }
}

fn main() {
    let mut recs: Vec<Rec> = Vec::new();
    println!(
        "== bench_kernels: packed + dense kernels, threads = 1 vs {} (BOLD_NUM_THREADS), \
         simd backend = {} (BOLD_SIMD)\n",
        pool::num_threads(),
        simd::backend_name()
    );
    let mut rng = Rng::new(7);

    println!("-- packed forward (xnor-popcount)");
    for (b, n, m) in [(64, 256, 1024), (128, 512, 4096), (256, 512, 8192)] {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let mut mask = BitMatrix::zeros(b, m);
        for i in 0..b {
            for j in 0..m {
                mask.set(i, j, rng.bernoulli(0.9));
            }
        }
        let macs = (b * n * m) as f64;
        let dims = format!("{b}x{n}x{m}");
        let mut out = Tensor::zeros(&[0]);
        row(&mut recs, "xnor_gemm", dims.clone(), macs, || {
            x.xnor_gemm_into(&w, &mut out);
            std::hint::black_box(&out);
        });
        row(&mut recs, "xnor_gemm_masked", dims.clone(), macs, || {
            x.xnor_gemm_masked_into(&w, &mask, &mut out);
            std::hint::black_box(&out);
        });
        let mut bits_out = BitMatrix::zeros(0, 0);
        row(&mut recs, "xnor_threshold", dims.clone(), macs, || {
            x.xnor_threshold_into(&w, None, 0.0, &mut bits_out);
            std::hint::black_box(&bits_out);
        });
        let lane: Vec<u64> = mask.row(0).to_vec();
        row(&mut recs, "xnor_threshold_masked", dims, macs, || {
            x.xnor_threshold_masked_into(&w, &lane, None, 0.0, &mut bits_out);
            std::hint::black_box(&bits_out);
        });
    }

    println!(
        "\n-- simd backend A/B: scalar vs {} (single thread; parity: tests/simd_parity.rs)",
        simd::auto_backend().name()
    );
    for (b, n, m) in [(64, 256, 1024), (128, 512, 4096), (64, 256, 16384), (32, 128, 65536)] {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let macs = (b * n * m) as f64;
        let dims = format!("{b}x{n}x{m}");
        let mut out = Tensor::zeros(&[0]);
        ab_row(&mut recs, "xnor_gemm", dims.clone(), macs, || {
            x.xnor_gemm_into(&w, &mut out);
            std::hint::black_box(&out);
        });
        let mut bits_out = BitMatrix::zeros(0, 0);
        ab_row(&mut recs, "xnor_threshold", dims, macs, || {
            x.xnor_threshold_into(&w, None, 0.0, &mut bits_out);
            std::hint::black_box(&bits_out);
        });
    }

    println!("\n-- lut-fold vs popcount (low fan-in layers, DESIGN.md §LUT-Folding)");
    for k in [2usize, 4, 6, 8, 10] {
        let (b, n) = (8192usize, 256usize);
        let x = BitMatrix::random(b, k, &mut rng);
        let layer = PackedLayer {
            weights: BitMatrix::random(n, k, &mut rng),
            bias: None,
            threshold: 0.5,
            input_mask: None,
        };
        let lut = PackedLut::from_linear(&layer);
        let macs = (b * n * k) as f64;
        let dims = format!("{b}x{n}xk{k}");
        let mut bits_out = BitMatrix::zeros(0, 0);
        row(&mut recs, "xnor_threshold_lowfanin", dims.clone(), macs, || {
            layer.apply_into(&x, &mut bits_out);
            std::hint::black_box(&bits_out);
        });
        let (mut cols, mut buf, mut tile) = (Vec::new(), Vec::new(), Vec::new());
        row(&mut recs, "lut_fold", dims, macs, || {
            lut.apply_linear_into(&x, &mut bits_out, &mut cols, &mut buf, &mut tile);
            std::hint::black_box(&bits_out);
        });
    }

    println!("\n-- packed backward (dense z against packed operands)");
    for (b, n, m) in [(128, 512, 4096), (256, 512, 8192)] {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let mut mask = BitMatrix::zeros(b, m);
        for i in 0..b {
            for j in 0..m {
                mask.set(i, j, rng.bernoulli(0.9));
            }
        }
        let z = Tensor::randn(&[b, n], 1.0, &mut rng);
        let macs = (b * n * m) as f64;
        let dims = format!("{b}x{n}x{m}");
        let mut out = Tensor::zeros(&[0]);
        row(&mut recs, "backward_input", dims.clone(), macs, || {
            w.backward_input_into(&z, &mut out);
            std::hint::black_box(&out);
        });
        row(&mut recs, "backward_weight", dims.clone(), macs, || {
            x.backward_weight_into(&z, &mut out);
            std::hint::black_box(&out);
        });
        row(&mut recs, "backward_weight_masked", dims, macs, || {
            x.backward_weight_masked_into(&z, &mask, &mut out);
            std::hint::black_box(&out);
        });
    }

    println!("\n-- dense f32 GEMM");
    for (m, k, n) in [(128, 1024, 256), (256, 4096, 512)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b_ = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bt = b_.transpose2();
        let at = a.transpose2();
        let macs = (m * k * n) as f64;
        let dims = format!("{m}x{k}x{n}");
        row(&mut recs, "matmul", dims.clone(), macs, || {
            std::hint::black_box(a.matmul(&b_));
        });
        row(&mut recs, "matmul_bt", dims.clone(), macs, || {
            std::hint::black_box(a.matmul_bt(&bt));
        });
        row(&mut recs, "matmul_at", dims, macs, || {
            std::hint::black_box(at.matmul_at(&b_));
        });
    }

    println!("\n-- conv data movement (im2col / col2im)");
    for (n, c, h, k) in [(32, 16, 32, 3), (16, 64, 16, 3)] {
        let x = Tensor::randn(&[n, c, h, h], 1.0, &mut rng);
        let cols = x.im2col(k, 1, 1);
        let moved = (cols.rows() * cols.cols()) as f64;
        let dims = format!("n{n}c{c}h{h}k{k}");
        row(&mut recs, "im2col", dims.clone(), moved, || {
            std::hint::black_box(x.im2col(k, 1, 1));
        });
        row(&mut recs, "col2im", dims, moved, || {
            std::hint::black_box(cols.col2im(n, c, h, h, k, 1, 1));
        });
    }

    println!("\n-- Boolean optimizer step (word-parallel flip kernel)");
    for (rows, cols) in [(512, 4096), (2048, 8192)] {
        let bits0 = BitMatrix::random(rows, cols, &mut rng);
        let grad = Tensor::randn(&[rows, cols], 1.1, &mut rng);
        let opt = BooleanOptimizer::new(1.0);
        let lanes = (rows * cols) as f64;
        let mut bits = bits0.clone();
        let mut store = ParamStore::new();
        let dims = format!("{rows}x{cols}");
        row(&mut recs, "optimizer_step", dims, lanes, || {
            // re-seed votes each rep so the scan has work to do
            store.zero_grads();
            store.accumulate("w", &grad);
            let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
            std::hint::black_box(opt.step(&mut params, &mut store));
        });
    }

    println!("\n(bit-exactness: tests/parallel_determinism.rs + tests/simd_parity.rs)");
    write_json("BENCH_kernels.json", &recs);
}
