//! Kernel sweep with a threads = {1, N} column: every packed and dense
//! hot-path kernel, sequential vs sharded across the persistent pool
//! (DESIGN.md §Parallelism). Template rows for EXPERIMENTS.md §Perf.
//!
//! The thread column is driven by `pool::with_thread_budget`, so a single
//! run measures both paths on identical inputs; the determinism suite
//! (`tests/parallel_determinism.rs`) separately asserts the two paths are
//! bit-exact. (Custom harness: no criterion in the offline registry.)

use bold::nn::{ParamRef, ParamStore};
use bold::optim::BooleanOptimizer;
use bold::tensor::{BitMatrix, Tensor};
use bold::util::{pool, Rng, Timer};

/// Median seconds for `f` under a fixed intra-op thread budget.
fn timed<F: FnMut()>(name: &str, budget: usize, mut f: F) -> f64 {
    pool::with_thread_budget(budget, || {
        let mut t = Timer::new(name);
        t.bench(2, 7, &mut f);
        t.median()
    })
}

/// One table row: kernel × shape, threads=1 vs threads=N, speedup.
fn row(label: &str, work: f64, mut f: impl FnMut()) {
    let n = pool::num_threads();
    let t1 = timed(label, 1, &mut f);
    let tn = timed(label, n, &mut f);
    println!(
        "{label:<44} t1 {:>9.3} ms  t{n} {:>9.3} ms  speedup {:>5.2}x  {:>8.2} Gop/s",
        t1 * 1e3,
        tn * 1e3,
        t1 / tn,
        work / tn / 1e9
    );
}

fn main() {
    println!(
        "== bench_kernels: packed + dense kernels, threads = 1 vs {} (BOLD_NUM_THREADS)\n",
        pool::num_threads()
    );
    let mut rng = Rng::new(7);

    println!("-- packed forward (xnor-popcount)");
    for (b, n, m) in [(64, 256, 1024), (128, 512, 4096), (256, 512, 8192)] {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let mut mask = BitMatrix::zeros(b, m);
        for i in 0..b {
            for j in 0..m {
                mask.set(i, j, rng.bernoulli(0.9));
            }
        }
        let macs = (b * n * m) as f64;
        let mut out = Tensor::zeros(&[0]);
        row(&format!("xnor_gemm {b}x{n}x{m}"), macs, || {
            x.xnor_gemm_into(&w, &mut out);
            std::hint::black_box(&out);
        });
        row(&format!("xnor_gemm_masked {b}x{n}x{m}"), macs, || {
            x.xnor_gemm_masked_into(&w, &mask, &mut out);
            std::hint::black_box(&out);
        });
        let mut bits_out = BitMatrix::zeros(0, 0);
        row(&format!("xnor_threshold {b}x{n}x{m}"), macs, || {
            x.xnor_threshold_into(&w, None, 0.0, &mut bits_out);
            std::hint::black_box(&bits_out);
        });
        let lane: Vec<u64> = mask.row(0).to_vec();
        row(&format!("xnor_threshold_masked {b}x{n}x{m}"), macs, || {
            x.xnor_threshold_masked_into(&w, &lane, None, 0.0, &mut bits_out);
            std::hint::black_box(&bits_out);
        });
    }

    println!("\n-- packed backward (dense z against packed operands)");
    for (b, n, m) in [(128, 512, 4096), (256, 512, 8192)] {
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        let mut mask = BitMatrix::zeros(b, m);
        for i in 0..b {
            for j in 0..m {
                mask.set(i, j, rng.bernoulli(0.9));
            }
        }
        let z = Tensor::randn(&[b, n], 1.0, &mut rng);
        let macs = (b * n * m) as f64;
        let mut out = Tensor::zeros(&[0]);
        row(&format!("backward_input {b}x{n}x{m}"), macs, || {
            w.backward_input_into(&z, &mut out);
            std::hint::black_box(&out);
        });
        row(&format!("backward_weight {b}x{n}x{m}"), macs, || {
            x.backward_weight_into(&z, &mut out);
            std::hint::black_box(&out);
        });
        row(&format!("backward_weight_masked {b}x{n}x{m}"), macs, || {
            x.backward_weight_masked_into(&z, &mask, &mut out);
            std::hint::black_box(&out);
        });
    }

    println!("\n-- dense f32 GEMM");
    for (m, k, n) in [(128, 1024, 256), (256, 4096, 512)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b_ = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bt = b_.transpose2();
        let at = a.transpose2();
        let macs = (m * k * n) as f64;
        row(&format!("matmul {m}x{k}x{n}"), macs, || {
            std::hint::black_box(a.matmul(&b_));
        });
        row(&format!("matmul_bt {m}x{k}x{n}"), macs, || {
            std::hint::black_box(a.matmul_bt(&bt));
        });
        row(&format!("matmul_at {m}x{k}x{n}"), macs, || {
            std::hint::black_box(at.matmul_at(&b_));
        });
    }

    println!("\n-- conv data movement (im2col / col2im)");
    for (n, c, h, k) in [(32, 16, 32, 3), (16, 64, 16, 3)] {
        let x = Tensor::randn(&[n, c, h, h], 1.0, &mut rng);
        let cols = x.im2col(k, 1, 1);
        let moved = (cols.rows() * cols.cols()) as f64;
        row(&format!("im2col n{n} c{c} {h}x{h} k{k}"), moved, || {
            std::hint::black_box(x.im2col(k, 1, 1));
        });
        row(&format!("col2im n{n} c{c} {h}x{h} k{k}"), moved, || {
            std::hint::black_box(cols.col2im(n, c, h, h, k, 1, 1));
        });
    }

    println!("\n-- Boolean optimizer step (word-parallel flip kernel)");
    for (rows, cols) in [(512, 4096), (2048, 8192)] {
        let bits0 = BitMatrix::random(rows, cols, &mut rng);
        let grad = Tensor::randn(&[rows, cols], 1.1, &mut rng);
        let opt = BooleanOptimizer::new(1.0);
        let lanes = (rows * cols) as f64;
        let mut bits = bits0.clone();
        let mut store = ParamStore::new();
        row(&format!("optimizer_step {rows}x{cols}"), lanes, || {
            // re-seed votes each rep so the scan has work to do
            store.zero_grads();
            store.accumulate("w", &grad);
            let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
            std::hint::black_box(opt.step(&mut params, &mut store));
        });
    }

    println!("\n(bit-exactness of every t1-vs-tN pair: tests/parallel_determinism.rs)");
}
