//! Serving benchmark (EXPERIMENTS.md §Perf): single-stream latency vs
//! micro-batched multi-worker throughput of the native packed engine on
//! the artifact-shaped MLP (784-512-256-10).
//!
//! Acceptance target: batch 64 with 4 workers delivers ≥4× the
//! single-example (batch 1, 1 worker) throughput on the same model.

use bold::models::{boolean_mlp, MlpConfig};
use bold::runtime::{NativeServer, PackedMlp, ServeConfig};
use bold::tensor::BitMatrix;
use bold::util::{Rng, Timer};
use std::time::{Duration, Instant};

fn engine() -> PackedMlp {
    let mut model = boolean_mlp(&MlpConfig::default(), &mut Rng::new(7));
    PackedMlp::from_layer(&mut model).expect("engine")
}

/// Drive `n` requests through the server from `clients` pipelined client
/// threads; returns requests/second.
fn drive(server: &NativeServer, n: usize, clients: usize, depth: usize) -> f64 {
    let d_in = server.d_in();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let n_c = n / clients + usize::from(c < n % clients);
            s.spawn(move || {
                let mut rng = Rng::new(77 + c as u64);
                let mut inflight = Vec::with_capacity(depth);
                for _ in 0..n_c {
                    let feats: Vec<f32> = (0..d_in).map(|_| rng.sign()).collect();
                    inflight.push(server.submit(&feats).expect("submit"));
                    if inflight.len() >= depth {
                        for p in inflight.drain(..) {
                            p.wait().expect("response");
                        }
                    }
                }
                for p in inflight {
                    p.wait().expect("response");
                }
            });
        }
    });
    n as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    println!("== bench_serve: native packed engine, MLP 784-512-256-10");

    // --- raw engine: per-example cost, batch 1 vs batch 64 --------------
    let eng = engine();
    let mut rng = Rng::new(9);
    let x1 = BitMatrix::random(1, 784, &mut rng);
    let x64 = BitMatrix::random(64, 784, &mut rng);
    let mut t = Timer::new("engine forward batch 1 (single-stream)");
    t.bench(3, 15, || {
        std::hint::black_box(eng.forward_bits(&x1));
    });
    t.report(None);
    let lat1 = t.median();
    let mut t = Timer::new("engine forward batch 64");
    t.bench(2, 9, || {
        std::hint::black_box(eng.forward_bits(&x64));
    });
    t.report(None);
    let lat64 = t.median();
    println!(
        "    single-stream latency {:.1} µs/req; per-example batching gain {:.2}x\n",
        lat1 * 1e6,
        lat1 / (lat64 / 64.0)
    );

    // --- full server: queue + micro-batching + worker pool --------------
    let n_requests = 8192;
    let configs = [
        (1usize, 1usize, 1usize, "1 worker, batch 1 (single-example)"),
        (1, 64, 128, "1 worker, batch 64"),
        (4, 64, 128, "4 workers, batch 64"),
    ];
    let mut rates = Vec::new();
    for &(workers, batch, clients, label) in &configs {
        let server = NativeServer::start(
            engine(),
            ServeConfig {
                workers,
                max_batch: batch,
                queue_cap: 4096,
                batch_window: Duration::from_micros(200),
            },
        );
        let rate = drive(&server, n_requests, clients, 32);
        let stats = server.shutdown();
        println!(
            "{label:<38} {rate:>10.0} req/s   (avg batch fill {:.1})",
            stats.avg_batch()
        );
        rates.push(rate);
    }
    println!(
        "\nbatch 64 + 4 workers vs single-example: {:.1}x  (target >= 4x)",
        rates[2] / rates[0]
    );
    println!(
        "batch 64, same worker count:            {:.1}x  (micro-batching alone)",
        rates[1] / rates[0]
    );
}
