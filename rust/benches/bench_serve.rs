//! Serving benchmark (EXPERIMENTS.md §Perf): single-stream latency vs
//! micro-batched multi-worker throughput of the native packed engine on
//! the artifact-shaped MLP (784-512-256-10), plus a conv-model section
//! (VGG-SMALL through the packed graph executor) for the ISSUE-4
//! serve-throughput row.
//!
//! Acceptance target: batch 64 with 4 workers delivers ≥4× the
//! single-example (batch 1, 1 worker) throughput on the same model.
//!
//! Besides the stdout report, the run emits machine-readable
//! `BENCH_serve.json` (model, config, workers, batch, req/s or µs/iter,
//! simd backend, threads) into `BOLD_BENCH_JSON_DIR` (default: current
//! directory) — the cross-PR perf trajectory record.

use bold::models::{boolean_mlp, vgg_small, MlpConfig, VggConfig};
use bold::nn::{Layer, Value};
use bold::runtime::{
    loadgen, GraphScratch, HttpConfig, HttpServer, ModelRegistry, NativeServer, PackedGraph,
    ServeConfig,
};
use bold::tensor::{simd, BitMatrix, Tensor};
use bold::util::{pool, Rng, Timer};
use std::time::{Duration, Instant};

/// One measured cell of BENCH_serve.json. `req_per_s` is 0 for raw
/// engine-latency rows (which carry `us_per_iter` instead, and vice
/// versa). `extra` is an optional pre-rendered JSON fragment
/// (`,"k":v,...`) for rows with bench-specific fields (the open-loop
/// rows carry offered rate, latency percentiles and shed counts).
struct Rec {
    bench: String,
    config: String,
    workers: usize,
    batch: usize,
    req_per_s: f64,
    us_per_iter: f64,
    extra: String,
}

fn write_json(recs: &[Rec]) {
    let dir = std::env::var("BOLD_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{dir}/BENCH_serve.json");
    let mut s = String::from("[\n");
    for (i, r) in recs.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"bench\":\"{}\",\"config\":\"{}\",\"workers\":{},\"batch\":{},\
             \"req_per_s\":{:.0},\"us_per_iter\":{:.2}{},\"simd\":\"{}\",\"threads\":{}}}{}\n",
            r.bench,
            r.config,
            r.workers,
            r.batch,
            r.req_per_s,
            r.us_per_iter,
            r.extra,
            simd::backend_name(),
            pool::num_threads(),
            if i + 1 < recs.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    match std::fs::write(&path, s) {
        Ok(()) => println!("wrote {path} ({} records)", recs.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Memory fields appended to every row (ISSUE-7): peak `GraphScratch`
/// bytes plus the graph's slot count before/after the compiler passes,
/// so `bench_check` gates scratch-footprint regressions like latency.
fn mem_extra(scratch_bytes: usize, g: &PackedGraph) -> String {
    let ps = g.pass_stats();
    format!(
        ",\"scratch_bytes\":{scratch_bytes},\"slots_raw\":{},\"slots_live\":{}",
        ps.raw_slots, ps.live_slots
    )
}

fn mlp_engine() -> PackedGraph {
    let mut model = boolean_mlp(&MlpConfig::default(), &mut Rng::new(7));
    PackedGraph::from_layer(&mut model).expect("mlp engine")
}

fn vgg_engine() -> PackedGraph {
    // CPU-scale VGG-SMALL (width 0.25 ⇒ 32/64/128 channels) with BN so the
    // bench exercises the folded per-channel thresholds.
    let cfg = VggConfig { hw: 32, width_mult: 0.25, with_bn: true, ..Default::default() };
    let mut rng = Rng::new(11);
    let mut model = vgg_small(&cfg, &mut rng);
    // one eval forward records the input shape for Record::Arch
    let probe = Tensor::rand_pm1(&[1, 3, 32, 32], &mut rng);
    let _ = model.forward(Value::F32(probe), false);
    PackedGraph::from_layer(&mut model).expect("vgg engine")
}

/// Drive `n` requests through the server from `clients` pipelined client
/// threads; returns requests/second.
fn drive(server: &NativeServer, n: usize, clients: usize, depth: usize) -> f64 {
    let d_in = server.d_in();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let n_c = n / clients + usize::from(c < n % clients);
            s.spawn(move || {
                let mut rng = Rng::new(77 + c as u64);
                let mut inflight = Vec::with_capacity(depth);
                for _ in 0..n_c {
                    let feats: Vec<f32> = (0..d_in).map(|_| rng.sign()).collect();
                    inflight.push(server.submit(&feats).expect("submit"));
                    if inflight.len() >= depth {
                        for p in inflight.drain(..) {
                            p.wait().expect("response");
                        }
                    }
                }
                for p in inflight {
                    p.wait().expect("response");
                }
            });
        }
    });
    n as f64 / t0.elapsed().as_secs_f64()
}

/// The three-config sweep (single-example / micro-batched / batched +
/// parallel) over one engine builder; returns the req/s per config.
fn sweep(
    recs: &mut Vec<Rec>,
    label: &str,
    n_requests: usize,
    mk: impl Fn() -> PackedGraph,
) -> Vec<f64> {
    println!("-- {label}");
    let configs = [
        (1usize, 1usize, 1usize, "1 worker, batch 1 (single-example)"),
        (1, 64, 128, "1 worker, batch 64"),
        (4, 64, 128, "4 workers, batch 64"),
    ];
    let mut rates = Vec::new();
    for &(workers, batch, clients, cfg_label) in &configs {
        let server = NativeServer::start(
            mk(),
            ServeConfig {
                workers,
                max_batch: batch,
                queue_cap: 4096,
                batch_window: Duration::from_micros(200),
            },
        );
        let rate = drive(&server, n_requests, clients, 32);
        let peak_scratch = server
            .worker_scratch_bytes()
            .into_iter()
            .max()
            .unwrap_or(0);
        let mem = mem_extra(peak_scratch, server.model());
        let stats = server.shutdown();
        println!(
            "{cfg_label:<38} {rate:>10.0} req/s   (avg batch fill {:.1}, peak scratch {} KiB)",
            stats.avg_batch(),
            peak_scratch / 1024
        );
        recs.push(Rec {
            bench: label.to_string(),
            config: cfg_label.to_string(),
            workers,
            batch,
            req_per_s: rate,
            us_per_iter: 0.0,
            extra: mem,
        });
        rates.push(rate);
    }
    println!(
        "batch 64 + 4 workers vs single-example: {:.1}x  (target >= 4x)\n",
        rates[2] / rates[0]
    );
    rates
}

fn main() {
    println!(
        "== bench_serve: native packed engine (simd backend = {})",
        simd::backend_name()
    );
    let mut recs: Vec<Rec> = Vec::new();

    // --- raw engine: per-example cost, batch 1 vs batch 64 --------------
    // caller-owned scratch (the serve-worker path), so each row can also
    // record the retained scratch footprint at that batch size
    let eng = mlp_engine();
    let mut rng = Rng::new(9);
    let x1 = BitMatrix::random(1, 784, &mut rng);
    let x64 = BitMatrix::random(64, 784, &mut rng);
    let mut scratch = GraphScratch::new();
    let mut t = Timer::new("MLP engine forward batch 1 (single-stream)");
    t.bench(3, 15, || {
        eng.forward_bits_into(&x1, &mut scratch);
        std::hint::black_box(&scratch.logits);
    });
    t.report(None);
    let lat1 = t.median();
    let mem1 = mem_extra(scratch.scratch_bytes(), &eng);
    let mut t = Timer::new("MLP engine forward batch 64");
    t.bench(2, 9, || {
        eng.forward_bits_into(&x64, &mut scratch);
        std::hint::black_box(&scratch.logits);
    });
    t.report(None);
    let lat64 = t.median();
    let mem64 = mem_extra(scratch.scratch_bytes(), &eng);
    println!(
        "    single-stream latency {:.1} µs/req; per-example batching gain {:.2}x\n",
        lat1 * 1e6,
        lat1 / (lat64 / 64.0)
    );
    recs.push(Rec {
        bench: "mlp_engine_forward".into(),
        config: "batch 1".into(),
        workers: 1,
        batch: 1,
        req_per_s: 0.0,
        us_per_iter: lat1 * 1e6,
        extra: mem1,
    });
    recs.push(Rec {
        bench: "mlp_engine_forward".into(),
        config: "batch 64".into(),
        workers: 1,
        batch: 64,
        req_per_s: 0.0,
        us_per_iter: lat64 * 1e6,
        extra: mem64,
    });

    let vgg = vgg_engine();
    let v1 = BitMatrix::random(1, vgg.d_in(), &mut rng);
    let v16 = BitMatrix::random(16, vgg.d_in(), &mut rng);
    let mut scratch = GraphScratch::new();
    let mut t = Timer::new("VGG graph forward batch 1 (conv, BN folded)");
    t.bench(2, 7, || {
        vgg.forward_bits_into(&v1, &mut scratch);
        std::hint::black_box(&scratch.logits);
    });
    t.report(None);
    recs.push(Rec {
        bench: "vgg_graph_forward".into(),
        config: "batch 1".into(),
        workers: 1,
        batch: 1,
        req_per_s: 0.0,
        us_per_iter: t.median() * 1e6,
        extra: mem_extra(scratch.scratch_bytes(), &vgg),
    });
    let mut t = Timer::new("VGG graph forward batch 16");
    t.bench(1, 5, || {
        vgg.forward_bits_into(&v16, &mut scratch);
        std::hint::black_box(&scratch.logits);
    });
    t.report(None);
    let ps = vgg.pass_stats();
    println!(
        "    VGG scratch at batch 16: {} KiB, slots {} -> {}",
        scratch.scratch_bytes() / 1024,
        ps.raw_slots,
        ps.live_slots
    );
    recs.push(Rec {
        bench: "vgg_graph_forward".into(),
        config: "batch 16".into(),
        workers: 1,
        batch: 16,
        req_per_s: 0.0,
        us_per_iter: t.median() * 1e6,
        extra: mem_extra(scratch.scratch_bytes(), &vgg),
    });
    println!();

    // --- full server: queue + micro-batching + worker pool --------------
    sweep(&mut recs, "MLP 784-512-256-10", 8192, mlp_engine);
    sweep(&mut recs, "VGG-SMALL w0.25 (packed conv graph)", 512, vgg_engine);

    // --- open-loop load over the TCP/HTTP front-end ----------------------
    open_loop_http(&mut recs);
    write_json(&recs);
}

/// Open-loop load section (ISSUE-6): real TCP + HTTP parsing in the
/// path, fixed arrival rates at 0.5×/1×/2× of a measured closed-loop
/// saturation estimate. The 2× row is the overload case: the interesting
/// numbers are goodput (should hold near saturation) and shed count
/// (503s, never hangs), with coordinated-omission-corrected latency
/// percentiles for the rows below saturation.
fn open_loop_http(recs: &mut Vec<Rec>) {
    let quick = std::env::var("BOLD_BENCH_QUICK").is_ok();
    let (probe_s, run_s) = if quick { (1.0, 2.0) } else { (3.0, 8.0) };
    let conns = 32usize;

    let registry = ModelRegistry::new();
    registry
        .add(
            "mlp",
            mlp_engine(),
            ServeConfig {
                workers: 4,
                max_batch: 64,
                queue_cap: 1024,
                batch_window: Duration::from_micros(200),
            },
        )
        .expect("register mlp");
    let cfg = HttpConfig { threads: conns.min(16), ..HttpConfig::default() };
    let http_threads = cfg.threads;
    let server = HttpServer::start(registry, "127.0.0.1:0", cfg).expect("bind http");
    let addr = server.local_addr().to_string();

    // body: 784 ±1 features, binary encoding (cheap to parse, realistic)
    let mut rng = Rng::new(21);
    let feats: Vec<f32> = (0..784).map(|_| rng.sign()).collect();
    let mut body = Vec::with_capacity(784 * 4);
    for f in &feats {
        body.extend_from_slice(&f.to_le_bytes());
    }
    let request = loadgen::render_predict("mlp", &body, "application/octet-stream");

    println!("-- open-loop HTTP load (MLP over TCP, {conns} connections)");
    let sat = loadgen::closed_loop_rate(&addr, &request, conns, Duration::from_secs_f64(probe_s));
    println!("closed-loop saturation estimate: {sat:.0} req/s");
    for (mult, label) in [(0.5, "0.5x"), (1.0, "1.0x"), (2.0, "2.0x")] {
        let rate = (sat * mult).max(conns as f64);
        let rep = loadgen::open_loop(&addr, &request, rate, Duration::from_secs_f64(run_s), conns);
        println!("{label:<6} {}", rep.summary());
        assert_eq!(
            rep.other_5xx, 0,
            "front-end must answer overload with 503/504, never other 5xx"
        );
        let mlp = server.registry().get("mlp").expect("mlp registered");
        let peak_scratch = mlp.worker_scratch_bytes().into_iter().max().unwrap_or(0);
        let mem = mem_extra(peak_scratch, mlp.model());
        recs.push(Rec {
            bench: "http_open_loop MLP".into(),
            config: format!("{label} saturation"),
            workers: http_threads,
            batch: 64,
            req_per_s: rep.goodput_per_s,
            us_per_iter: 0.0,
            extra: format!(
                ",\"offered_per_s\":{:.0},\"p50_us\":{:.1},\"p99_us\":{:.1},\"p999_us\":{:.1},\
                 \"sent\":{},\"shed\":{},\"expired\":{},\"io_errors\":{},\"timeouts\":{},\
                 \"connect_errors\":{},\"s500\":{}{mem}",
                rep.offered_per_s,
                rep.p50_us,
                rep.p99_us,
                rep.p999_us,
                rep.sent,
                rep.shed,
                rep.expired,
                rep.io_errors,
                rep.timeouts,
                rep.connect_errors,
                rep.by_5xx.iter().find(|(s, _)| *s == 500).map_or(0, |(_, n)| *n)
            ),
        });
    }
    let stats = server.shutdown();
    println!(
        "front-end: {} conns, {} requests ({} ok, {} shed, {} expired)\n",
        stats.connections, stats.requests, stats.ok, stats.shed, stats.expired
    );
}
