//! `bold` — launcher CLI for the B⊕LD reproduction.
//!
//! Subcommands:
//!   train        [--config FILE] [--model M] [--method M] [--steps N] …
//!   train-dist   --role coordinator|worker [--spawn N] …
//!                                      (fault-tolerant multi-process training)
//!   report       <fig1|table2|…|all> [--quick]
//!   energy       [--arch vgg|resnet] [--base N] [--batch N]
//!   serve-native [--model CKPT] [--workers N] [--batch N] …
//!                                      (native packed-bit batch server)
//!   serve-http   [--listen ADDR] [--model NAME=CKPT]… [--threads N] …
//!                                      (zero-dependency TCP/HTTP front-end)
//!   serve        [--artifacts DIR]     (PJRT demo, feature xla-runtime)
//!   info                               (build + feature + artifact status)

use bold::config::TrainConfig;
use bold::coordinator::{save_training, ClassifierTrainer, MetricLog, ParallelTrainer};
use bold::data::ImageDataset;
use bold::energy::{network_energy, resnet18_shapes, vgg_small_shapes, Method};
use bold::models::{boolean_mlp, resnet_boolean, vgg_small, MlpConfig, ResNetConfig, VggConfig, VggKind};
use bold::util::Rng;

fn usage() -> ! {
    eprintln!(
        r#"bold — Boolean Logic Deep Learning (NeurIPS 2024 reproduction)

USAGE:
  bold train  [--config FILE] [--model mlp|vgg|resnet] [--method bold|bold_bn|fp|binaryconnect|binarynet|xnornet]
              [--steps N] [--batch N] [--lr_bool X] [--lr_fp X] [--workers N] [--seed N]
              [--ckpt PATH] [--metrics CSV]
  bold train-dist [--role coordinator|worker] [--listen HOST:PORT] [--connect HOST:PORT]
              [--spawn N] [--worker-id N] [--ckpt PATH] [--ckpt-every N] [--resume]
              [train flags: --steps --batch --workers --seed ...]
              (multi-process data-parallel training; BOLD_DIST_* env knobs)
  bold report <{reports}|all> [--quick]
  bold energy [--arch vgg|resnet] [--base N] [--batch N] [--inference]
  bold serve-native [--model CKPT] [--workers N] [--batch N] [--requests N]
              [--clients N] [--window-us U] [--queue N]
  bold serve-http [--listen HOST:PORT] [--model NAME=CKPT]... [--model-dir DIR]
              [--threads N] [--workers N] [--batch N] [--queue N] [--window-us U]
              [--deadline-ms D] [--for-secs S]
              (POST /v1/models/NAME/predict; GET /healthz /v1/models /stats;
               POST /admin/models/NAME/load|unload|rollback; SIGHUP re-scans
               --model-dir; BOLD_CANARY_* / BOLD_BREAKER_* env knobs)
  bold serve  [--artifacts DIR]                 (needs --features xla-runtime)
  bold info
"#,
        reports = bold::report::ALL_REPORTS.join("|")
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "train" => cmd_train(rest),
        "train-dist" => cmd_train_dist(rest),
        "report" => cmd_report(rest),
        "energy" => cmd_energy(rest),
        "serve-native" => cmd_serve_native(rest),
        "serve-http" => cmd_serve_http(rest),
        "serve" => cmd_serve(rest),
        "info" => cmd_info(),
        "-h" | "--help" | "help" => usage(),
        other => {
            eprintln!("unknown command '{other}'");
            usage()
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs into a map; returns (flags, positional).
fn parse_kv(args: &[String]) -> Result<(Vec<(String, String)>, Vec<String>), String> {
    let mut kv = Vec::new();
    let mut pos = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if key == "quick" || key == "inference" || key == "resume" {
                kv.push((key.to_string(), "true".to_string()));
                i += 1;
            } else {
                let val = args.get(i + 1).ok_or_else(|| format!("--{key} needs a value"))?;
                kv.push((key.to_string(), val.clone()));
                i += 2;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    Ok((kv, pos))
}

fn cmd_train(args: &[String]) -> Result<(), String> {
    let (kv, _pos) = parse_kv(args)?;
    let mut cfg = TrainConfig::default();
    let mut ckpt: Option<String> = None;
    let mut metrics_path: Option<String> = None;
    for (k, v) in &kv {
        match k.as_str() {
            "config" => cfg = TrainConfig::from_file(v).map_err(|e| e.to_string())?,
            _ => {}
        }
    }
    for (k, v) in &kv {
        match k.as_str() {
            "config" => {}
            "ckpt" => ckpt = Some(v.clone()),
            "metrics" => metrics_path = Some(v.clone()),
            _ => cfg.apply_override(k, v).map_err(|e| e.to_string())?,
        }
    }
    println!("config: {cfg:?}");
    let mut log = MetricLog::new();

    let report = match cfg.model.as_str() {
        "mlp" => {
            let (train, val) =
                ImageDataset::mnist_like(cfg.train_size + cfg.val_size, cfg.classes, 256, 0.08, cfg.seed)
                    .split(cfg.train_size);
            let mcfg = MlpConfig { d_in: 256, hidden: vec![128, 64], d_out: cfg.classes, tanh_scale: true };
            if cfg.workers > 1 {
                let mcfg2 = mcfg.clone();
                let mut pt = ParallelTrainer::new(cfg.workers, &cfg, move |seed| {
                    boolean_mlp(&mcfg2, &mut Rng::new(seed))
                });
                let r = pt.fit(&train, &val, &cfg, true);
                if let Some(p) = &ckpt {
                    // training snapshot: weights + optimizer state (the
                    // serving engine skips the optimizer records)
                    save_training(&mut pt.replicas[0], &pt.opt.store, p)
                        .map_err(|e| e.to_string())?;
                }
                r
            } else {
                let mut model = boolean_mlp(&mcfg, &mut Rng::new(cfg.seed));
                let mut tr = ClassifierTrainer::new(&cfg);
                let r = tr.fit(&mut model, &train, &val, &cfg, true);
                if let Some(p) = &ckpt {
                    save_training(&mut model, &tr.opt.store, p).map_err(|e| e.to_string())?;
                }
                r
            }
        }
        "vgg" => {
            let (train, val) =
                ImageDataset::cifar_like(cfg.train_size + cfg.val_size, cfg.classes, 3, cfg.hw, 0.25, cfg.seed)
                    .split(cfg.train_size);
            let kind = if cfg.method == "fp" { VggKind::Fp } else { VggKind::Bold };
            let vcfg = VggConfig {
                kind,
                hw: cfg.hw,
                width_mult: cfg.width_mult,
                classes: cfg.classes,
                with_bn: cfg.method == "bold_bn",
                ..Default::default()
            };
            let mut model = match cfg.method.as_str() {
                "binaryconnect" => bold::baselines::bnn_vgg_small(
                    bold::baselines::BnnKind::BinaryConnect, &vcfg, &mut Rng::new(cfg.seed)),
                "binarynet" => bold::baselines::bnn_vgg_small(
                    bold::baselines::BnnKind::BinaryNet, &vcfg, &mut Rng::new(cfg.seed)),
                "xnornet" => bold::baselines::bnn_vgg_small(
                    bold::baselines::BnnKind::XnorNet, &vcfg, &mut Rng::new(cfg.seed)),
                _ => vgg_small(&vcfg, &mut Rng::new(cfg.seed)),
            };
            let mut tr = ClassifierTrainer::new(&cfg);
            let r = tr.fit(&mut model, &train, &val, &cfg, true);
            if let Some(p) = &ckpt {
                save_training(&mut model, &tr.opt.store, p).map_err(|e| e.to_string())?;
            }
            r
        }
        "resnet" => {
            let (train, val) =
                ImageDataset::cifar_like(cfg.train_size + cfg.val_size, cfg.classes, 3, cfg.hw, 0.25, cfg.seed)
                    .split(cfg.train_size);
            let rcfg = ResNetConfig {
                base: ((16.0 * cfg.width_mult * 8.0) as usize).max(4),
                blocks: vec![2, 2],
                hw: cfg.hw,
                classes: cfg.classes,
                ..Default::default()
            };
            let mut model = resnet_boolean(&rcfg, &mut Rng::new(cfg.seed));
            let mut tr = ClassifierTrainer::new(&cfg);
            let r = tr.fit(&mut model, &train, &val, &cfg, true);
            if let Some(p) = &ckpt {
                save_training(&mut model, &tr.opt.store, p).map_err(|e| e.to_string())?;
            }
            r
        }
        other => return Err(format!("unknown model '{other}' (mlp|vgg|resnet)")),
    };

    for (i, &l) in report.losses.iter().enumerate() {
        log.push("loss", i, l as f64);
    }
    for (i, &a) in report.train_acc.iter().enumerate() {
        log.push("train_acc", i, a as f64);
    }
    for (i, &f) in report.flip_rates.iter().enumerate() {
        log.push("flip_rate", i, f as f64);
    }
    println!(
        "done: final loss {:.4}, val acc {:.2}%",
        report.tail_loss(10),
        report.val_acc * 100.0
    );
    if let Some(p) = metrics_path {
        log.write_csv(&p).map_err(|e| e.to_string())?;
        println!("metrics written to {p}");
    }
    Ok(())
}

/// Multi-process data-parallel training over TCP (DESIGN.md
/// §Distributed-Training): one coordinator owns model + optimizer and
/// shards each batch across worker processes; final weights are
/// bit-identical to single-process training regardless of worker churn.
fn cmd_train_dist(args: &[String]) -> Result<(), String> {
    use bold::coordinator::{run_coordinator, run_worker, DistConfig, JobSpec};
    use std::net::TcpListener;

    let (kv, _pos) = parse_kv(args)?;
    let mut cfg = TrainConfig { model: "mlp".into(), ..TrainConfig::default() };
    for (k, v) in &kv {
        if k == "config" {
            cfg = TrainConfig::from_file(v).map_err(|e| e.to_string())?;
        }
    }
    let mut role = "coordinator".to_string();
    let mut listen = "127.0.0.1:7979".to_string();
    let mut connect: Option<String> = None;
    let mut spawn = 0usize;
    let mut worker_id = std::process::id() as u64;
    let mut dcfg = DistConfig::from_env();
    for (k, v) in &kv {
        match k.as_str() {
            "config" => {}
            "role" => role = v.clone(),
            "listen" => listen = v.clone(),
            "connect" => connect = Some(v.clone()),
            "spawn" => spawn = v.parse().map_err(|_| "bad --spawn")?,
            "worker-id" => worker_id = v.parse().map_err(|_| "bad --worker-id")?,
            "ckpt" => dcfg.ckpt_path = Some(v.clone()),
            "ckpt-every" => dcfg.ckpt_every = v.parse().map_err(|_| "bad --ckpt-every")?,
            "resume" => dcfg.resume = true,
            _ => cfg.apply_override(k, v).map_err(|e| e.to_string())?,
        }
    }
    let spec = JobSpec::new(cfg.clone())?;
    match role.as_str() {
        "worker" => {
            let addr = connect.ok_or("--role worker needs --connect HOST:PORT")?;
            let shards = run_worker(&spec, &addr, &dcfg, worker_id, true)?;
            println!("worker {worker_id} done: {shards} shard(s) computed");
            Ok(())
        }
        "coordinator" => {
            let listener =
                TcpListener::bind(&listen).map_err(|e| format!("bind {listen}: {e}"))?;
            let addr = listener.local_addr().map_err(|e| e.to_string())?;
            println!(
                "coordinator on {addr}: {} shard(s)/step, {} steps",
                spec.n_shards(),
                cfg.steps
            );
            let mut children = Vec::new();
            if spawn > 0 {
                let exe = std::env::current_exe().map_err(|e| e.to_string())?;
                let threads = bold::util::pool::child_budget(spawn);
                // forward the training flags verbatim so every worker
                // builds the exact same job (JobSpec::config_hash gates it)
                let mut fwd: Vec<String> = Vec::new();
                for (k, v) in &kv {
                    let dist_only = matches!(
                        k.as_str(),
                        "role" | "listen" | "connect" | "spawn" | "worker-id" | "ckpt"
                            | "ckpt-every" | "resume"
                    );
                    if !dist_only {
                        fwd.push(format!("--{k}"));
                        fwd.push(v.clone());
                    }
                }
                for i in 0..spawn {
                    let child = std::process::Command::new(&exe)
                        .arg("train-dist")
                        .args([
                            "--role",
                            "worker",
                            "--connect",
                            &addr.to_string(),
                            "--worker-id",
                            &i.to_string(),
                        ])
                        .args(&fwd)
                        .env("BOLD_NUM_THREADS", threads.to_string())
                        .stdout(std::process::Stdio::null())
                        .spawn()
                        .map_err(|e| format!("spawn worker {i}: {e}"))?;
                    children.push(child);
                }
                println!("spawned {spawn} local worker(s), {threads} thread(s) each");
            }
            let outcome = run_coordinator(&spec, &dcfg, listener, true)?;
            for mut c in children {
                let _ = c.wait();
            }
            let r = &outcome.report;
            let s = &outcome.stats;
            println!(
                "done: final loss {:.4}, val acc {:.2}% (started at step {})",
                r.tail_loss(10),
                r.val_acc * 100.0,
                outcome.start_step
            );
            println!(
                "fault log: {} join(s) ({} reconnect(s)), {} removed, {} re-issued shard(s), \
                 {} duplicate(s), {} stale, {} rejected, {} corrupt frame(s)",
                s.joins,
                s.reconnects,
                s.removed,
                s.reissues,
                s.duplicates,
                s.stale,
                s.rejected,
                s.corrupt_frames
            );
            Ok(())
        }
        other => Err(format!("unknown --role '{other}' (coordinator|worker)")),
    }
}

fn cmd_report(args: &[String]) -> Result<(), String> {
    let (kv, pos) = parse_kv(args)?;
    let quick = kv.iter().any(|(k, _)| k == "quick");
    let id = pos.first().map(String::as_str).unwrap_or("all");
    bold::report::run(id, quick)
}

fn cmd_energy(args: &[String]) -> Result<(), String> {
    let (kv, _) = parse_kv(args)?;
    let mut arch = "vgg".to_string();
    let mut base = 64usize;
    let mut batch = 100usize;
    let mut train = true;
    for (k, v) in &kv {
        match k.as_str() {
            "arch" => arch = v.clone(),
            "base" => base = v.parse().map_err(|_| "bad --base")?,
            "batch" => batch = v.parse().map_err(|_| "bad --batch")?,
            "inference" => train = false,
            _ => return Err(format!("unknown option --{k}")),
        }
    }
    let shapes = match arch.as_str() {
        "vgg" => vgg_small_shapes(batch),
        "resnet" => resnet18_shapes(batch, base),
        other => return Err(format!("unknown arch '{other}'")),
    };
    for hw in [bold::energy::ASCEND(), bold::energy::V100()] {
        println!(
            "--- {} / {} (batch {batch}{}) — {}",
            hw.name,
            arch,
            if arch == "resnet" { format!(", base {base}") } else { String::new() },
            if train { "1 training iteration" } else { "inference" }
        );
        let fp = network_energy(&shapes, &hw, Method::Fp32, train).total_pj();
        println!(
            "{:<18} {:>14} {:>10} {:>10} {:>10} {:>9}",
            "method", "total (µJ)", "compute%", "memory%", "optim%", "vs FP%"
        );
        for m in Method::all() {
            let e = network_energy(&shapes, &hw, m, train);
            let t = e.total_pj();
            println!(
                "{:<18} {:>14.1} {:>10.1} {:>10.1} {:>10.1} {:>9.2}",
                m.name(),
                t / 1e6,
                e.compute_pj / t * 100.0,
                e.mem_pj / t * 100.0,
                e.optimizer_pj / t * 100.0,
                t / fp * 100.0
            );
        }
    }
    // Serve-path LUT-folding delta (DESIGN.md §LUT-Folding): 64-bit word
    // accesses of a folded fan-in-K layer vs the XNOR+popcount kernel it
    // replaces, per forward batch. Positive save% is where the `lut`
    // graph pass converts profitably.
    println!("--- LUT-fold word accesses per forward (batch {batch}, 64 neurons)");
    println!(
        "{:<8} {:>14} {:>14} {:>10} {:>12}",
        "fanin", "popcount", "lut", "save%", "table (B)"
    );
    for k in [2usize, 4, 6, 8, 10] {
        let c = bold::energy::lut_layer_cost(k, 64, batch);
        println!(
            "{:<8} {:>14.0} {:>14.0} {:>10.1} {:>12}",
            k,
            c.popcount_accesses,
            c.lut_accesses,
            c.saving_pct(),
            c.table_bytes
        );
    }
    Ok(())
}

/// Native packed-bit batch serving: load (or build) a frozen Boolean
/// model — any describable architecture (MLP, VGG, ResNet) via the
/// packed graph executor — start the worker pool, drive synthetic client
/// traffic through it and report throughput + latency percentiles.
fn cmd_serve_native(args: &[String]) -> Result<(), String> {
    use bold::runtime::{NativeServer, PackedGraph, ServeConfig};
    use std::time::{Duration, Instant};

    let (kv, _) = parse_kv(args)?;
    let mut model_path: Option<String> = None;
    let mut workers = 4usize;
    let mut batch = 64usize;
    let mut requests = 8192usize;
    let mut clients = 64usize;
    let mut window_us = 200u64;
    let mut queue_cap = 1024usize;
    for (k, v) in &kv {
        match k.as_str() {
            "model" => model_path = Some(v.clone()),
            "workers" => workers = v.parse().map_err(|_| "bad --workers")?,
            "batch" => batch = v.parse().map_err(|_| "bad --batch")?,
            "requests" => requests = v.parse().map_err(|_| "bad --requests")?,
            "clients" => clients = v.parse().map_err(|_| "bad --clients")?,
            "window-us" => window_us = v.parse().map_err(|_| "bad --window-us")?,
            "queue" => queue_cap = v.parse().map_err(|_| "bad --queue")?,
            _ => return Err(format!("unknown option --{k}")),
        }
    }
    if workers == 0 || batch == 0 || clients == 0 || queue_cap == 0 || requests == 0 {
        return Err("--workers/--batch/--clients/--queue/--requests must be >= 1".into());
    }
    let engine = match &model_path {
        Some(p) => {
            let e = PackedGraph::load(p).map_err(|e| e.to_string())?;
            println!("loaded frozen model from {p}");
            e
        }
        None => {
            println!("no --model given — serving a randomly initialised 784-512-256-10 MLP");
            let mut model = boolean_mlp(&MlpConfig::default(), &mut Rng::new(7));
            PackedGraph::from_layer(&mut model).map_err(|e| e.to_string())?
        }
    };
    let (d_in, d_out) = (engine.d_in(), engine.d_out());
    println!(
        "native engine: {} ops [{}], input {:?} ({d_in} bits), d_out {d_out}, {} packed weight \
         bits ({} KiB)",
        engine.num_ops(),
        engine.summary(),
        engine.input_shape,
        engine.param_bits(),
        engine.param_bits() / 8 / 1024
    );
    println!(
        "server: {workers} workers, micro-batch {batch} (window {window_us} µs), queue cap \
         {queue_cap}; driving {requests} requests from {clients} clients\n"
    );
    let server = NativeServer::start(
        engine,
        ServeConfig {
            workers,
            max_batch: batch,
            queue_cap,
            batch_window: Duration::from_micros(window_us),
        },
    );

    // spot-check: one known input answered identically to a direct forward
    let mut rng = Rng::new(1);
    let probe: Vec<f32> = (0..d_in).map(|_| rng.sign()).collect();
    let want = server
        .model()
        .forward_f32(&bold::tensor::Tensor::from_vec(&[1, d_in], probe.clone()));
    let got = server
        .submit(&probe)
        .map_err(|e| e.to_string())?
        .wait()
        .map_err(|e| e.to_string())?;
    if got.logits != want.data {
        return Err("spot-check failed: server response differs from direct forward".into());
    }
    // counters so far belong to the spot-check, not the measured run
    let pre = server.stats();

    let t_start = Instant::now();
    let mut lat_ns: Vec<u64> = Vec::with_capacity(requests);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = &server;
            let n = requests / clients + usize::from(c < requests % clients);
            handles.push(s.spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                let mut lats = Vec::with_capacity(n);
                for _ in 0..n {
                    let feats: Vec<f32> = (0..d_in).map(|_| rng.sign()).collect();
                    let t0 = Instant::now();
                    let resp = server
                        .submit(&feats)
                        .expect("submit")
                        .wait()
                        .expect("response");
                    lats.push(t0.elapsed().as_nanos() as u64);
                    std::hint::black_box(resp.class);
                }
                lats
            }));
        }
        for h in handles {
            lat_ns.extend(h.join().expect("client thread"));
        }
    });
    let wall = t_start.elapsed().as_secs_f64();
    let stats = server.shutdown();
    let reqs = stats.requests - pre.requests;
    let batches = stats.batches - pre.batches;
    let fill = if batches == 0 { 0.0 } else { reqs as f64 / batches as f64 };
    lat_ns.sort_unstable();
    let pct = |p: f64| lat_ns[((lat_ns.len() - 1) as f64 * p) as usize] as f64 / 1e3;
    println!("answered {reqs} requests in {wall:.3}s over {batches} batched forwards");
    println!(
        "throughput: {:>10.0} req/s   (avg batch fill {fill:.1})",
        lat_ns.len() as f64 / wall
    );
    println!(
        "latency:    p50 {:>8.1} µs   p95 {:>8.1} µs   p99 {:>8.1} µs",
        pct(0.50),
        pct(0.95),
        pct(0.99)
    );
    Ok(())
}

/// TCP/HTTP-1.1 front-end over the native packed-bit server: register
/// one or more checkpoints under names, bind a listener and serve until
/// `POST /admin/shutdown`, Ctrl-C, or `--for-secs` elapses. Knobs not
/// given as flags fall back to the `BOLD_HTTP_*` environment variables
/// (see README §Serving knobs).
fn cmd_serve_http(args: &[String]) -> Result<(), String> {
    use bold::runtime::{HttpConfig, HttpServer, LifecycleConfig, ModelRegistry, PackedGraph, ServeConfig};
    use std::time::Duration;

    let (kv, _) = parse_kv(args)?;
    let mut listen = "127.0.0.1:7878".to_string();
    let mut models: Vec<(String, String)> = Vec::new(); // (name, ckpt path)
    let mut model_dir: Option<String> = None;
    let mut workers = 4usize;
    let mut batch = 64usize;
    let mut queue_cap = 1024usize;
    let mut window_us = 200u64;
    let mut for_secs: Option<u64> = None;
    let mut cfg = HttpConfig::default();
    for (k, v) in &kv {
        match k.as_str() {
            "listen" => listen = v.clone(),
            "model" => {
                let (name, path) = v
                    .split_once('=')
                    .ok_or_else(|| format!("--model wants NAME=CKPT, got '{v}'"))?;
                models.push((name.to_string(), path.to_string()));
            }
            "model-dir" => model_dir = Some(v.clone()),
            "threads" => cfg.threads = v.parse().map_err(|_| "bad --threads")?,
            "workers" => workers = v.parse().map_err(|_| "bad --workers")?,
            "batch" => batch = v.parse().map_err(|_| "bad --batch")?,
            "queue" => queue_cap = v.parse().map_err(|_| "bad --queue")?,
            "window-us" => window_us = v.parse().map_err(|_| "bad --window-us")?,
            "deadline-ms" => {
                cfg.request_deadline =
                    Duration::from_millis(v.parse().map_err(|_| "bad --deadline-ms")?)
            }
            "for-secs" => for_secs = Some(v.parse().map_err(|_| "bad --for-secs")?),
            _ => return Err(format!("unknown option --{k}")),
        }
    }
    if workers == 0 || batch == 0 || queue_cap == 0 || cfg.threads == 0 {
        return Err("--threads/--workers/--batch/--queue must be >= 1".into());
    }
    let serve_cfg = ServeConfig {
        workers,
        max_batch: batch,
        queue_cap,
        batch_window: Duration::from_micros(window_us),
    };
    // runtime-added models (admin load of a new name, --model-dir
    // scans) inherit the same serve config
    let registry = ModelRegistry::with_defaults(serve_cfg.clone(), LifecycleConfig::from_env());
    if models.is_empty() && model_dir.is_none() {
        println!("no --model given — serving a randomly initialised MLP as 'mlp'");
        let mut model = boolean_mlp(&MlpConfig::default(), &mut Rng::new(7));
        let graph = bold::runtime::PackedGraph::from_layer(&mut model).map_err(|e| e.to_string())?;
        registry.add("mlp", graph, serve_cfg.clone()).map_err(|e| e.to_string())?;
    }
    for (name, path) in &models {
        let graph = PackedGraph::load(path).map_err(|e| format!("{name}: {e}"))?;
        println!(
            "model '{name}' from {path}: {} ops [{}], d_in {}, d_out {}",
            graph.num_ops(),
            graph.summary(),
            graph.d_in(),
            graph.d_out()
        );
        registry.add(name, graph, serve_cfg.clone()).map_err(|e| e.to_string())?;
    }
    if let Some(dir) = &model_dir {
        // initial scan: a corrupt checkpoint registers its entry
        // quarantined (named in /v1/models) instead of aborting startup
        for line in registry.rescan_dir(dir) {
            println!("model-dir: {line}");
        }
    }
    let server = HttpServer::start(registry, &listen, cfg).map_err(|e| e.to_string())?;
    println!(
        "listening on http://{} — {} http thread(s), {workers} worker(s)/model, micro-batch \
         {batch} (window {window_us} µs), queue cap {queue_cap}",
        server.local_addr(),
        server.config().threads
    );
    println!(
        "endpoints: POST /v1/models/<name>/predict · GET /healthz /v1/models /stats · \
         POST /admin/models/<name>/load|unload|rollback · POST /admin/shutdown"
    );
    // park until something asks for a drain: `POST /admin/shutdown`,
    // SIGINT/SIGTERM (zero-dep handler — an atomic flag polled here), or
    // the --for-secs deadline. All three paths drain gracefully: stop
    // accepting, answer in-flight requests, then join. With --model-dir,
    // SIGHUP triggers a hot re-scan from this loop (never a drain).
    bold::util::signal::install_shutdown_handler();
    if model_dir.is_some() {
        bold::util::signal::install_reload_handler();
    }
    let deadline = for_secs.map(|s| std::time::Instant::now() + Duration::from_secs(s));
    while !server.is_draining() && !bold::util::signal::triggered() {
        if deadline.is_some_and(|d| std::time::Instant::now() >= d) {
            break;
        }
        if bold::util::signal::take_hup() {
            if let Some(dir) = &model_dir {
                println!("SIGHUP — re-scanning {dir}");
                for line in server.registry().rescan_dir(dir) {
                    println!("model-dir: {line}");
                }
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    if bold::util::signal::triggered() {
        println!("shutdown signal received — draining");
    }
    let stats = server.shutdown();
    println!(
        "drained: {} conns ({} rejected), {} requests — {} ok, {} shed, {} expired, {} client \
         errors, {} aborted",
        stats.connections,
        stats.conns_rejected,
        stats.requests,
        stats.ok,
        stats.shed,
        stats.expired,
        stats.client_err,
        stats.aborted
    );
    Ok(())
}

#[cfg(feature = "xla-runtime")]
fn cmd_serve(args: &[String]) -> Result<(), String> {
    let (kv, _) = parse_kv(args)?;
    let dir = kv
        .iter()
        .find(|(k, _)| k == "artifacts")
        .map(|(_, v)| v.clone())
        .unwrap_or_else(|| "artifacts".to_string());
    let exec = bold::runtime::PjrtExecutor::load_dir(&dir).map_err(|e| format!("{e:#}"))?;
    println!("PJRT platform: {}", exec.platform());
    println!("compiled entries: {:?}", exec.entries());
    // demo: run the MLP inference artifact on random ±1 inputs
    let mut rng = Rng::new(0);
    let x = bold::tensor::Tensor::rand_pm1(&[128, 784], &mut rng);
    let w1 = bold::tensor::Tensor::rand_pm1(&[512, 784], &mut rng);
    let w2 = bold::tensor::Tensor::rand_pm1(&[256, 512], &mut rng);
    let wfc = bold::tensor::Tensor::randn(&[10, 256], 0.05, &mut rng);
    let bfc = bold::tensor::Tensor::zeros(&[10]);
    let t0 = std::time::Instant::now();
    let out = exec
        .execute("bool_mlp_infer", &[x, w1, w2, wfc, bfc])
        .map_err(|e| format!("{e:#}"))?;
    println!(
        "bool_mlp_infer: logits {:?} in {:.2} ms",
        out[0].shape,
        t0.elapsed().as_secs_f64() * 1e3
    );
    Ok(())
}

/// Without the `xla-runtime` feature the PJRT path is compiled out; keep
/// the subcommand present and fail with guidance instead of "unknown
/// command".
#[cfg(not(feature = "xla-runtime"))]
fn cmd_serve(_args: &[String]) -> Result<(), String> {
    Err("`bold serve` needs the XLA/PJRT path, which this binary was built without.\n\
         rebuild with `cargo build --release --features xla-runtime` (and link a real xla \
         binding, see rust/vendor/xla-stub/README.md), or use the native engine instead: \
         `bold serve-native`"
        .to_string())
}

fn cmd_info() -> Result<(), String> {
    println!("bold {} — B⊕LD reproduction", env!("CARGO_PKG_VERSION"));
    if cfg!(feature = "xla-runtime") {
        println!("features: xla-runtime ON (PJRT `serve` path compiled in)");
    } else {
        println!("features: xla-runtime off — native packed-bit engine only (`serve-native`)");
    }
    println!(
        "kernels: simd backend = {} (BOLD_SIMD={{auto,scalar}}), pool threads = {} \
         (BOLD_NUM_THREADS)",
        bold::tensor::simd::backend_name(),
        bold::util::pool::num_threads()
    );
    let pc = bold::runtime::PassConfig::from_env();
    println!(
        "graph passes: fuse {}, lut {} (max fan-in {}, BOLD_LUT_MAX_FANIN), liveness {} \
         (BOLD_GRAPH_PASSES={{all,none}} or comma list of fuse,liveness,lut)",
        if pc.fuse { "on" } else { "off" },
        if pc.lut && pc.lut_max_fanin > 0 { "on" } else { "off" },
        pc.lut_max_fanin,
        if pc.liveness { "on" } else { "off" }
    );
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.exists() {
        let entries: Vec<String> = std::fs::read_dir(artifacts)
            .map_err(|e| e.to_string())?
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".hlo.txt"))
            .collect();
        println!("artifacts: {entries:?}");
    } else {
        println!("artifacts: none (run `make artifacts`)");
    }
    Ok(())
}
