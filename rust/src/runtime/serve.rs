//! Multi-threaded micro-batching server over the native packed engine
//! (DESIGN.md §Serving-Runtime): the "serve heavy traffic" runtime of the
//! ROADMAP, with the paper's XOR+POPCNT kernel as the only thing on the
//! hot path.
//!
//! Architecture:
//!
//! * clients call [`NativeServer::submit`] — the *client* thread packs the
//!   f32 features to bits (input bit-packing stays off the worker hot
//!   path) and enqueues into a **bounded** queue; submission blocks while
//!   the queue is at capacity, which back-pressures producers instead of
//!   growing memory;
//! * each worker pops a request, then gathers more until either
//!   `max_batch` requests are assembled or the `batch_window` expires —
//!   micro-batching amortises the packed-weight streaming across the
//!   batch (the same 2-D reuse argument as the training GEMM);
//! * the worker runs one [`PackedGraph::forward_bits_into`] over the
//!   assembled batch and answers every request through its own channel.
//!
//! Batch assembly is shape-aware: a request row is the flattened packed
//! input (`C·H·W` bits for conv models, `D` for flat ones), and the
//! graph reinterprets the gathered `rows × C·H·W` matrix against its
//! recorded input shape — the server itself stays architecture-agnostic.
//!
//! Shutdown drains: workers only exit once the queue is empty, so every
//! accepted request is answered.

use super::graph::{GraphScratch, PackedGraph};
use crate::tensor::BitMatrix;
use crate::util::pool;
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads running batched forwards.
    pub workers: usize,
    /// Maximum requests fused into one forward.
    pub max_batch: usize,
    /// Bounded queue capacity (back-pressure point).
    pub queue_cap: usize,
    /// How long a worker waits for a batch to fill before running it
    /// anyway — the latency/throughput trade-off.
    pub batch_window: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            max_batch: 64,
            queue_cap: 1024,
            batch_window: Duration::from_micros(200),
        }
    }
}

/// Serving error (bad request shape, server shut down, worker panic, …).
#[derive(Debug, Clone)]
pub struct ServeError {
    pub msg: String,
}

impl ServeError {
    fn new(msg: impl Into<String>) -> Self {
        ServeError { msg: msg.into() }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "serve error: {}", self.msg)
    }
}

impl std::error::Error for ServeError {}

/// Why a non-blocking [`NativeServer::try_submit`] did not enqueue.
#[derive(Debug)]
pub enum TrySubmitError {
    /// The bounded queue is at capacity — shed load now (the HTTP
    /// front-end maps this to `503` + `Retry-After`) instead of blocking
    /// the caller behind it.
    Full,
    /// Malformed request or server shutting down.
    Rejected(ServeError),
}

impl fmt::Display for TrySubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrySubmitError::Full => write!(f, "queue full"),
            TrySubmitError::Rejected(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for TrySubmitError {}

/// One answered inference request.
#[derive(Debug, Clone)]
pub struct Response {
    /// Raw logits (d_out).
    pub logits: Vec<f32>,
    /// Argmax class id.
    pub class: usize,
}

/// Handle to an in-flight request. The channel carries a `Result` so a
/// worker that panics mid-batch can still answer its in-flight requests
/// with an error instead of silently dropping the sender.
pub struct Pending {
    rx: mpsc::Receiver<Result<Response, ServeError>>,
}

impl Pending {
    /// Block until the answer arrives.
    pub fn wait(self) -> Result<Response, ServeError> {
        self.rx
            .recv()
            .map_err(|_| ServeError::new("server shut down before answering"))?
    }

    /// Non-blocking poll. An errored request (worker panic) reads as
    /// `None` here — use [`Self::wait`]/[`Self::wait_timeout`] where the
    /// distinction matters.
    pub fn try_wait(&self) -> Option<Response> {
        self.rx.try_recv().ok().and_then(|r| r.ok())
    }

    /// Deadline-bounded wait: `Ok(None)` when `timeout` expires first —
    /// the request stays queued and is still computed (its result is
    /// discarded), so an expired deadline never wedges a worker. The
    /// HTTP front-end maps `None` to `504`.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Option<Response>, ServeError> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Ok(Some(r?)),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(ServeError::new("server shut down before answering"))
            }
        }
    }
}

/// Monotonic serving counters.
#[derive(Debug, Clone, Copy)]
pub struct ServerStats {
    /// Requests answered.
    pub requests: usize,
    /// Batched forwards executed.
    pub batches: usize,
    /// Worker panics contained: the batch's requests were answered with
    /// an error and the worker respawned its scratch state in place.
    pub worker_panics: usize,
}

impl ServerStats {
    /// Average requests fused per forward.
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

struct Request {
    words: Vec<u64>,
    tx: mpsc::Sender<Result<Response, ServeError>>,
}

struct Shared {
    model: PackedGraph,
    cfg: ServeConfig,
    queue: Mutex<VecDeque<Request>>,
    not_empty: Condvar,
    not_full: Condvar,
    shutdown: AtomicBool,
    served: AtomicUsize,
    batches: AtomicUsize,
    /// Batches whose forward panicked (contained; see [`worker_loop`]).
    worker_panics: AtomicUsize,
    /// Fault-injection hook: each batch decrements this and panics while
    /// it is non-zero. Test-only by contract, compiled in always so the
    /// integration suite (no cfg(test) in the lib) can reach it.
    panic_inject: AtomicUsize,
    /// Per-worker [`GraphScratch::scratch_bytes`], refreshed after every
    /// batched forward (scratch only grows, so this is the worker's peak
    /// footprint) — surfaced in HTTP `/stats` and the serve benches.
    scratch_bytes: Vec<AtomicUsize>,
}

/// The batch server: a frozen [`PackedGraph`] behind a bounded queue and
/// a worker pool.
pub struct NativeServer {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl NativeServer {
    /// Start `cfg.workers` worker threads around a frozen model. Accepts
    /// anything convertible into a [`PackedGraph`] — in particular a
    /// legacy [`crate::runtime::PackedMlp`], which wraps into a
    /// linear-only graph.
    pub fn start(model: impl Into<PackedGraph>, cfg: ServeConfig) -> Self {
        let model: PackedGraph = model.into();
        assert!(cfg.workers >= 1, "need at least one worker");
        assert!(cfg.max_batch >= 1, "need max_batch >= 1");
        assert!(cfg.queue_cap >= 1, "need queue_cap >= 1");
        let scratch_bytes = (0..cfg.workers).map(|_| AtomicUsize::new(0)).collect();
        let shared = Arc::new(Shared {
            model,
            cfg,
            queue: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            shutdown: AtomicBool::new(false),
            served: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            worker_panics: AtomicUsize::new(0),
            panic_inject: AtomicUsize::new(0),
            scratch_bytes,
        });
        let workers = (0..shared.cfg.workers)
            .map(|idx| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh, idx))
            })
            .collect();
        NativeServer { shared, workers }
    }

    /// Input width the model expects.
    pub fn d_in(&self) -> usize {
        self.shared.model.d_in()
    }

    /// The served model (for spot-checking responses).
    pub fn model(&self) -> &PackedGraph {
        &self.shared.model
    }

    /// Requests currently queued.
    pub fn queue_len(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Bounded queue capacity (the admission-control point).
    pub fn queue_cap(&self) -> usize {
        self.shared.cfg.queue_cap
    }

    /// Pack real-valued features (`v ≥ 0 ⇒ T`) into a request row.
    fn pack_features(&self, features: &[f32]) -> Result<Vec<u64>, ServeError> {
        let d = self.shared.model.d_in();
        if features.len() != d {
            return Err(ServeError::new(format!(
                "request width {} vs model d_in {d}",
                features.len()
            )));
        }
        let mut words = vec![0u64; d.div_ceil(64)];
        for (c, &v) in features.iter().enumerate() {
            if v >= 0.0 {
                words[c / 64] |= 1u64 << (c % 64);
            }
        }
        Ok(words)
    }

    /// Pack real-valued features (`v ≥ 0 ⇒ T`) and enqueue. Blocks while
    /// the bounded queue is full.
    pub fn submit(&self, features: &[f32]) -> Result<Pending, ServeError> {
        let words = self.pack_features(features)?;
        self.submit_packed(words)
    }

    /// Non-blocking [`Self::submit`]: a full queue returns
    /// [`TrySubmitError::Full`] immediately instead of back-pressuring
    /// the caller — the admission-control primitive of the network
    /// front-end (DESIGN.md §Network-Front-End).
    pub fn try_submit(&self, features: &[f32]) -> Result<Pending, TrySubmitError> {
        let words = self.pack_features(features).map_err(TrySubmitError::Rejected)?;
        self.try_submit_packed(words)
    }

    /// Non-blocking [`Self::submit_packed`].
    pub fn try_submit_packed(&self, words: Vec<u64>) -> Result<Pending, TrySubmitError> {
        let wpr = self.shared.model.d_in().div_ceil(64);
        if words.len() != wpr {
            return Err(TrySubmitError::Rejected(ServeError::new(format!(
                "packed width {} words vs expected {wpr}",
                words.len()
            ))));
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.shutdown.load(Ordering::SeqCst) {
                return Err(TrySubmitError::Rejected(ServeError::new(
                    "server is shutting down",
                )));
            }
            if q.len() >= self.shared.cfg.queue_cap {
                return Err(TrySubmitError::Full);
            }
            q.push_back(Request { words, tx });
        }
        self.shared.not_empty.notify_one();
        Ok(Pending { rx })
    }

    /// Enqueue an already-packed input row (`ceil(d_in/64)` words).
    pub fn submit_packed(&self, words: Vec<u64>) -> Result<Pending, ServeError> {
        let wpr = self.shared.model.d_in().div_ceil(64);
        if words.len() != wpr {
            return Err(ServeError::new(format!(
                "packed width {} words vs expected {wpr}",
                words.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            loop {
                if self.shared.shutdown.load(Ordering::SeqCst) {
                    return Err(ServeError::new("server is shutting down"));
                }
                if q.len() < self.shared.cfg.queue_cap {
                    break;
                }
                q = self.shared.not_full.wait(q).unwrap();
            }
            q.push_back(Request { words, tx });
        }
        self.shared.not_empty.notify_one();
        Ok(Pending { rx })
    }

    /// Current scratch footprint of each batch worker, in bytes
    /// ([`GraphScratch::scratch_bytes`], refreshed after every batched
    /// forward; zero until a worker has run its first batch).
    pub fn worker_scratch_bytes(&self) -> Vec<usize> {
        self.shared
            .scratch_bytes
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .collect()
    }

    /// Serving counters so far.
    pub fn stats(&self) -> ServerStats {
        ServerStats {
            requests: self.shared.served.load(Ordering::SeqCst),
            batches: self.shared.batches.load(Ordering::SeqCst),
            worker_panics: self.shared.worker_panics.load(Ordering::SeqCst),
        }
    }

    /// Fault-injection hook for the test suites: the next `n` batched
    /// forwards (across all workers) panic mid-batch. Not for production
    /// use.
    #[doc(hidden)]
    pub fn inject_panics(&self, n: usize) {
        self.shared.panic_inject.fetch_add(n, Ordering::SeqCst);
    }

    /// Stop accepting work, drain the queue, join the workers and return
    /// the final counters.
    pub fn shutdown(mut self) -> ServerStats {
        self.stop_and_join();
        self.stats()
    }

    fn stop_and_join(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for NativeServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn worker_loop(sh: &Shared, idx: usize) {
    let max_batch = sh.cfg.max_batch;
    let window = sh.cfg.batch_window;
    let d = sh.model.d_in();
    // Thread-budget handoff (DESIGN.md §Parallelism): the workers are
    // already batch-parallel, so each one limits its kernels' intra-op
    // sharding to its fair share of the pool.
    let _budget = pool::BudgetGuard::new((pool::num_threads() / sh.cfg.workers).max(1));
    // Per-worker reusable buffers: the steady-state batch path does no
    // allocation beyond the per-request response rows (and the FP
    // stem/head temporaries on conv graphs) — the batch gather list and
    // the argmax output are reused across drained batches, not rebuilt.
    let mut scratch = GraphScratch::new();
    let mut x = BitMatrix::zeros(0, 0);
    let mut batch: Vec<Request> = Vec::with_capacity(max_batch);
    let mut classes: Vec<usize> = Vec::with_capacity(max_batch);
    loop {
        batch.clear();
        {
            let mut q = sh.queue.lock().unwrap();
            while q.is_empty() {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return; // drained: empty queue + shutdown
                }
                // timeout is a lost-wakeup safety net; shutdown notifies
                let (guard, _) = sh
                    .not_empty
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
            batch.push(q.pop_front().unwrap());
            // micro-batch window: gather until full, drained past the
            // window, or shutdown
            let deadline = Instant::now() + window;
            while batch.len() < max_batch {
                if let Some(r) = q.pop_front() {
                    batch.push(r);
                    continue;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                // the pops above freed queue slots; wake blocked producers
                // before parking for the window, or (with queue_cap <
                // max_batch) they would stay blocked on a drained queue
                // until the gather finishes
                sh.not_full.notify_all();
                let (guard, res) = sh.not_empty.wait_timeout(q, deadline - now).unwrap();
                q = guard;
                if res.timed_out() && q.is_empty() {
                    break;
                }
            }
        }
        sh.not_full.notify_all();

        // one packed forward over the assembled batch, behind a panic
        // boundary: a poisoned model input or a kernel bug must cost ONE
        // batch, not the worker thread (a dead worker would silently
        // shrink capacity until the server wedges).
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if sh
                .panic_inject
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                .is_ok()
            {
                panic!("injected test panic");
            }
            // gather request rows straight into the reused input matrix
            // (single copy, no staging)
            x.assign_packed_rows(d, batch.iter().map(|r| r.words.as_slice()));
            debug_assert_eq!(x.rows, batch.len());
            sh.model.forward_bits_into(&x, &mut scratch);
            sh.scratch_bytes[idx].store(scratch.scratch_bytes(), Ordering::Relaxed);
            let logits = &scratch.logits;
            logits.argmax_rows_into(&mut classes);
            let n_out = logits.cols();
            sh.served.fetch_add(batch.len(), Ordering::SeqCst);
            sh.batches.fetch_add(1, Ordering::SeqCst);
            for (i, req) in batch.drain(..).enumerate() {
                // the response row is the one allocation left on this path:
                // it is owned by the client and crosses the channel
                let row = logits.data[i * n_out..(i + 1) * n_out].to_vec();
                // a client that dropped its Pending is not an error
                let _ = req.tx.send(Ok(Response { logits: row, class: classes[i] }));
            }
        }));
        if outcome.is_err() {
            // contain: answer every in-flight request with an error, count
            // the fault, and respawn the worker state in place — the
            // half-written scratch/input buffers are unwind debris.
            sh.worker_panics.fetch_add(1, Ordering::SeqCst);
            sh.served.fetch_add(batch.len(), Ordering::SeqCst);
            for req in batch.drain(..) {
                let _ = req
                    .tx
                    .send(Err(ServeError::new("worker panicked during batched forward")));
            }
            scratch = GraphScratch::new();
            x = BitMatrix::zeros(0, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{boolean_mlp, MlpConfig};
    use crate::runtime::PackedMlp;
    use crate::util::Rng;

    fn engine(seed: u64) -> PackedMlp {
        let cfg = MlpConfig { d_in: 100, hidden: vec![48, 24], d_out: 6, tanh_scale: true };
        let mut model = boolean_mlp(&cfg, &mut Rng::new(seed));
        PackedMlp::from_layer(&mut model).expect("engine")
    }

    #[test]
    fn answers_match_direct_forward() {
        let reference = engine(21);
        let server = NativeServer::start(
            engine(21),
            ServeConfig {
                workers: 3,
                max_batch: 8,
                queue_cap: 16, // smaller than the request count: exercises back-pressure
                batch_window: Duration::from_micros(100),
            },
        );
        let mut rng = Rng::new(77);
        let mut pendings = Vec::new();
        let mut expected = Vec::new();
        for _ in 0..100 {
            let x = crate::tensor::Tensor::rand_pm1(&[1, 100], &mut rng);
            expected.push(reference.forward_f32(&x));
            pendings.push(server.submit(&x.data).expect("submit"));
        }
        for (p, want) in pendings.into_iter().zip(expected) {
            let resp = p.wait().expect("response");
            assert_eq!(resp.logits, want.data);
            assert_eq!(resp.class, want.argmax_rows()[0]);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 100);
        assert!(stats.batches >= 13, "batch cap 8 ⇒ at least ceil(100/8) forwards");
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let server = NativeServer::start(
            engine(4),
            ServeConfig {
                workers: 1,
                max_batch: 4,
                queue_cap: 64,
                batch_window: Duration::from_micros(10),
            },
        );
        let mut rng = Rng::new(5);
        let pendings: Vec<Pending> = (0..20)
            .map(|_| {
                let x = crate::tensor::Tensor::rand_pm1(&[1, 100], &mut rng);
                server.submit(&x.data).expect("submit")
            })
            .collect();
        let stats = server.shutdown(); // drains before joining
        assert_eq!(stats.requests, 20);
        for p in pendings {
            p.wait().expect("drained request must still be answered");
        }
    }

    #[test]
    fn rejects_wrong_width() {
        let server = NativeServer::start(engine(9), ServeConfig::default());
        assert!(server.submit(&[1.0; 5]).is_err());
        assert!(server.submit_packed(vec![0u64; 1]).is_err());
    }
}
