//! Live model lifecycle for the serving stack (DESIGN.md
//! §Model-Lifecycle): hot checkpoint reload with shadow-validation
//! canaries, per-model circuit breakers, and automatic rollback.
//!
//! The HTTP front-end (runtime/net.rs) used to freeze its
//! [`ModelRegistry`] at startup: shipping a retrained checkpoint,
//! recovering a model whose workers keep panicking, or backing out a
//! corrupt file all meant killing the process and dropping every
//! in-flight connection. BOLD's cheap Boolean training makes frequent
//! re-checkpointing the normal operating mode, so model swap is a
//! first-class, validated, reversible operation here:
//!
//! * **Staged promotion** ([`ModelRegistry::load_checkpoint`], wired to
//!   `POST /admin/models/<name>/load`): the candidate checkpoint is
//!   read, CRC-verified and compiled under the active
//!   `BOLD_GRAPH_PASSES` config entirely off the request path — the
//!   incumbent keeps serving throughout. Promotion itself is one write
//!   under the entry lock: an atomic pointer swap, so a request either
//!   sees the old version or the new one, never a half-installed model.
//! * **Shadow-validation canary**: before promotion the candidate
//!   replays a golden-vector set (deterministic seeded packed rows)
//!   against the incumbent and must produce **bit-exact logits** — the
//!   gate that catches a bad LUT enumeration or a miscompiled pass
//!   before traffic hits it. Genuinely retrained weights pass
//!   `allow_divergence` instead, which skips the logit comparison and
//!   sanity-checks the candidate's shapes against the registered route.
//! * **Health state machine** (Healthy → Degraded → Quarantined) per
//!   entry, driven by worker-panic and error-rate counters over a
//!   sliding request window. A tripped breaker auto-rolls back to the
//!   last-known-good version when one is retained (it is kept *warm* —
//!   rollback is an `Arc` swap, not a reload), else quarantines the
//!   model: quarantined entries answer `503` + `Retry-After` without
//!   touching their counters while every other model keeps serving.
//! * **Retirement**: the previous active server is retained as
//!   last-known-good; the version before that is dropped. In-flight
//!   requests hold their own `Arc` to the server that admitted them, so
//!   a retiring [`NativeServer`] drains naturally — every accepted
//!   request is answered, then the worker threads join on the final
//!   `Arc` drop.
//!
//! Corrupt checkpoints (CRC/record errors from
//! [`crate::coordinator::checkpoint`]) never panic the serving process:
//! a failed staged load leaves the incumbent serving and records the
//! failing record name; a failed *first* load registers the entry
//! quarantined so `/v1/models` and `/stats` can name what is wrong.

use super::graph::PackedGraph;
use super::serve::{NativeServer, ServeConfig, ServeError};
use crate::coordinator::read_records;
use crate::tensor::BitMatrix;
use crate::util::Rng;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .unwrap_or(default)
}

/// Per-model health (the lifecycle state machine). Transitions:
///
/// ```text
///            clean breaker window          breaker trip,
///           ┌────────────────────┐      no last-good retained
///           ▼                    │     ┌─────────────────────┐
///       Healthy ──────────► Degraded ──┤                     ▼
///           │   first error      │     │               Quarantined
///           │   in a window      │     └── breaker trip,     │
///           │                    │         last-good warm:   │
///           └── promotion ◄──────┴──── auto-rollback (stays  │
///               (load/rollback resets      Degraded)         │
///                the machine) ◄──────────────────────────────┘
/// ```
///
/// Quarantined entries answer `503` + `Retry-After` from
/// [`ModelEntry::admit`] without advancing any per-model counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Serving normally.
    Healthy,
    /// Serving, but the current breaker window saw failures (or an
    /// auto-rollback just happened). A clean window heals to Healthy.
    Degraded,
    /// Not serving: breaker tripped with no last-known-good retained,
    /// or the entry's only load attempt failed. Manual `load`/`rollback`
    /// is the only way out.
    Quarantined,
}

impl HealthState {
    pub fn as_str(&self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Quarantined => "quarantined",
        }
    }
}

impl fmt::Display for HealthState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Lifecycle tuning knobs. [`Default`] reads the `BOLD_CANARY_*` /
/// `BOLD_BREAKER_*` environment (README §Runtime knobs); the fault
/// suites pin tiny thresholds programmatically.
#[derive(Debug, Clone)]
pub struct LifecycleConfig {
    /// Golden vectors replayed by the bit-exact canary.
    /// Env: `BOLD_CANARY_VECTORS`.
    pub canary_vectors: usize,
    /// Seed for the deterministic golden-vector generator.
    /// Env: `BOLD_CANARY_SEED`.
    pub canary_seed: u64,
    /// Breaker sliding window: completed requests per evaluation
    /// window. Env: `BOLD_BREAKER_WINDOW`.
    pub breaker_window: usize,
    /// Request failures (5xx answered for this model) within one window
    /// that trip the breaker. Env: `BOLD_BREAKER_ERRORS`.
    pub breaker_errors: usize,
    /// Worker-panic failures within one window that trip the breaker
    /// (panics are the stronger signal, so the threshold is lower).
    /// Env: `BOLD_BREAKER_PANICS`.
    pub breaker_panics: usize,
}

impl LifecycleConfig {
    pub fn from_env() -> Self {
        LifecycleConfig {
            canary_vectors: env_usize("BOLD_CANARY_VECTORS", 32),
            canary_seed: env_u64("BOLD_CANARY_SEED", 0xB01D),
            breaker_window: env_usize("BOLD_BREAKER_WINDOW", 64),
            breaker_errors: env_usize("BOLD_BREAKER_ERRORS", 8),
            breaker_panics: env_usize("BOLD_BREAKER_PANICS", 3),
        }
    }
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        Self::from_env()
    }
}

/// Why a lifecycle operation failed. The HTTP admin layer maps kinds to
/// statuses (Corrupt/InvalidName → 400, shape/canary/rollback conflicts
/// → 409, NoSuchModel → 404).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LifecycleErrorKind {
    /// Bad model name (charset/duplicate rules from the registry).
    InvalidName,
    /// No registry entry under that name.
    NoSuchModel,
    /// The checkpoint failed to read, CRC-verify, or compile. The
    /// message names the failing record when the loader could.
    Corrupt,
    /// Candidate shapes do not match the registered route.
    ShapeMismatch,
    /// The bit-exact canary found diverging logits.
    CanaryDivergence,
    /// Rollback requested but no last-known-good version is retained.
    NothingToRollBack,
}

/// Error from a staged load / rollback / unload.
#[derive(Debug, Clone)]
pub struct LifecycleError {
    pub kind: LifecycleErrorKind,
    pub msg: String,
}

impl LifecycleError {
    fn new(kind: LifecycleErrorKind, msg: impl Into<String>) -> Self {
        LifecycleError { kind, msg: msg.into() }
    }
}

impl fmt::Display for LifecycleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lifecycle error: {}", self.msg)
    }
}

impl std::error::Error for LifecycleError {}

/// What the shadow-validation canary concluded before promotion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CanaryVerdict {
    /// Golden-vector replay: candidate logits bit-exact vs incumbent.
    BitExact { vectors: usize },
    /// `allow_divergence`: logits not compared, shapes checked against
    /// the registered route.
    ShapeChecked,
    /// No incumbent to compare against (first load under this name).
    FirstLoad,
}

impl CanaryVerdict {
    pub fn describe(&self) -> String {
        match self {
            CanaryVerdict::BitExact { vectors } => {
                format!("bit-exact on {vectors} golden vector(s)")
            }
            CanaryVerdict::ShapeChecked => "divergence allowed, shapes checked".to_string(),
            CanaryVerdict::FirstLoad => "first load, no incumbent".to_string(),
        }
    }
}

/// A successful staged promotion.
#[derive(Debug, Clone)]
pub struct PromotionReport {
    pub model: String,
    /// Version now serving (monotonic per entry, starts at 1).
    pub version: u64,
    pub canary: CanaryVerdict,
    /// Behavioral fingerprint of the promoted graph
    /// ([`PackedGraph::behavior_fingerprint`]).
    pub fingerprint: u64,
}

/// Outcome of [`ModelEntry::admit`] for one predict request.
pub enum Admission {
    /// Route to this server. The `Arc` pins the admitting version for
    /// the request's lifetime — a concurrent promotion retires the old
    /// server only after every admitted request is answered.
    Serve(Arc<NativeServer>),
    /// Circuit open (quarantined / no active version): answer `503` +
    /// `Retry-After` and do **not** advance the per-model counters.
    Refused { reason: String },
}

/// One serving version (a warm [`NativeServer`] plus its identity).
struct ActiveVersion {
    version: u64,
    server: Arc<NativeServer>,
    /// Checkpoint path this version came from (None for programmatic
    /// [`ModelRegistry::add`]).
    path: Option<String>,
    fingerprint: u64,
}

/// Change-detection stamp for `--model-dir` rescans.
#[derive(Clone, PartialEq, Eq)]
struct SourceStamp {
    path: String,
    len: u64,
    modified: Option<std::time::SystemTime>,
}

fn stamp(path: &str) -> Option<SourceStamp> {
    let md = std::fs::metadata(path).ok()?;
    SourceStamp { path: path.to_string(), len: md.len(), modified: md.modified().ok() }.into()
}

struct EntryState {
    active: Option<ActiveVersion>,
    /// Previous active version, kept warm for instant rollback. Dropped
    /// (retired) when the next promotion shifts it out.
    last_good: Option<ActiveVersion>,
    health: HealthState,
    next_version: u64,
    /// Registered route shape `(d_in, d_out)` — survives quarantine so
    /// `/v1/models` still describes what the route serves, and anchors
    /// the `allow_divergence` shape check.
    route: Option<(usize, usize)>,
    /// Current health annotation (quarantine reason naming the failing
    /// record, auto-rollback note, …) — surfaced in `/v1/models`.
    note: Option<String>,
    /// Why the most recent staged load was rejected (incumbent kept
    /// serving) — cleared by the next successful promotion.
    last_load_error: Option<String>,
    /// Where the active version's checkpoint came from on disk, for
    /// `--model-dir` rescan change detection.
    source: Option<SourceStamp>,
    /// Worker panics accumulated on servers that have since retired, so
    /// the per-model total survives (and freezes at) retirement.
    retired_panics: usize,
}

/// One registry slot: a named route with its health machine, breaker
/// counters and up to two warm versions (active + last-known-good).
pub struct ModelEntry {
    name: String,
    serve_cfg: ServeConfig,
    lc: LifecycleConfig,
    /// Serializes staged loads/rollbacks per entry, so two concurrent
    /// admin loads cannot interleave their canaries and promotions. The
    /// request path never takes this.
    staging: Mutex<()>,
    state: RwLock<EntryState>,
    // HTTP-observed per-model counters. Frozen while quarantined by
    // construction: `admit` refuses before any of them advance.
    requests: AtomicUsize,
    ok: AtomicUsize,
    errors: AtomicUsize,
    shed: AtomicUsize,
    expired: AtomicUsize,
    // breaker sliding-window counters (reset on trip, promotion, or a
    // clean window)
    win_requests: AtomicUsize,
    win_errors: AtomicUsize,
    win_panics: AtomicUsize,
}

/// Point-in-time copy of an entry for `/stats` and `/v1/models`
/// rendering (each counter individually atomic).
pub struct EntrySnapshot {
    pub name: String,
    pub health: HealthState,
    /// Active version (0 while quarantined with no active server).
    pub version: u64,
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
    pub shed: usize,
    pub expired: usize,
    /// Worker panics across this entry's servers, including retired
    /// versions (frozen once quarantined).
    pub worker_panics: usize,
    /// Route shape; zeros if never established.
    pub d_in: usize,
    pub d_out: usize,
    pub note: Option<String>,
    pub last_load_error: Option<String>,
    pub source: Option<String>,
    pub fingerprint: u64,
    pub has_last_good: bool,
    /// Active server, when one is installed (for queue/pass-stat rows).
    pub server: Option<Arc<NativeServer>>,
}

impl ModelEntry {
    fn new(name: &str, serve_cfg: ServeConfig, lc: LifecycleConfig) -> Self {
        ModelEntry {
            name: name.to_string(),
            serve_cfg,
            lc,
            staging: Mutex::new(()),
            state: RwLock::new(EntryState {
                active: None,
                last_good: None,
                health: HealthState::Quarantined,
                next_version: 1,
                route: None,
                note: None,
                last_load_error: None,
                source: None,
                retired_panics: 0,
            }),
            requests: AtomicUsize::new(0),
            ok: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            expired: AtomicUsize::new(0),
            win_requests: AtomicUsize::new(0),
            win_errors: AtomicUsize::new(0),
            win_panics: AtomicUsize::new(0),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn health(&self) -> HealthState {
        self.state.read().unwrap().health
    }

    /// Active version number (0 when nothing is serving).
    pub fn version(&self) -> u64 {
        self.state.read().unwrap().active.as_ref().map_or(0, |a| a.version)
    }

    /// The active server, if one is installed and not quarantined.
    pub fn server(&self) -> Option<Arc<NativeServer>> {
        let st = self.state.read().unwrap();
        if st.health == HealthState::Quarantined {
            return None;
        }
        st.active.as_ref().map(|a| Arc::clone(&a.server))
    }

    /// Admission decision for one predict request (the circuit
    /// breaker's gate). Refusal deliberately bypasses every per-model
    /// counter — the `net_faults` suite asserts a quarantined model's
    /// counters stop advancing.
    pub fn admit(&self) -> Admission {
        let st = self.state.read().unwrap();
        if st.health == HealthState::Quarantined || st.active.is_none() {
            let reason = st
                .note
                .clone()
                .unwrap_or_else(|| "model quarantined".to_string());
            return Admission::Refused { reason };
        }
        Admission::Serve(Arc::clone(&st.active.as_ref().expect("checked").server))
    }

    /// A request was admitted and enqueued.
    pub fn note_submitted(&self) {
        self.requests.fetch_add(1, Ordering::SeqCst);
    }

    /// A request completed `200`. Closes the breaker window when enough
    /// clean completions accumulate, healing Degraded → Healthy.
    pub fn note_ok(&self) {
        self.ok.fetch_add(1, Ordering::SeqCst);
        let n = self.win_requests.fetch_add(1, Ordering::SeqCst) + 1;
        if n >= self.lc.breaker_window {
            self.reset_window();
            let mut st = self.state.write().unwrap();
            if st.health == HealthState::Degraded {
                st.health = HealthState::Healthy;
                st.note = Some("recovered: clean breaker window".to_string());
            }
        }
    }

    /// A request was shed (`503` queue-full). Shedding is the admission
    /// control working as designed, so it feeds neither the error
    /// counter nor the breaker.
    pub fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::SeqCst);
    }

    /// A request expired (`504`). Deadline pressure is an overload
    /// signal, not a broken model — tracked, but not a breaker input
    /// (a saturated-but-correct model must not trip its breaker).
    pub fn note_expired(&self) {
        self.expired.fetch_add(1, Ordering::SeqCst);
    }

    /// A request failed with a server error (`500`-class). `panicked`
    /// marks worker-panic failures, which trip the breaker at a lower
    /// threshold. May trip the breaker: auto-rollback to last-known-good
    /// when retained, else quarantine.
    pub fn note_failure(&self, panicked: bool) {
        self.errors.fetch_add(1, Ordering::SeqCst);
        self.win_requests.fetch_add(1, Ordering::SeqCst);
        let errs = self.win_errors.fetch_add(1, Ordering::SeqCst) + 1;
        let pans = if panicked {
            self.win_panics.fetch_add(1, Ordering::SeqCst) + 1
        } else {
            self.win_panics.load(Ordering::SeqCst)
        };
        if pans >= self.lc.breaker_panics || errs >= self.lc.breaker_errors {
            self.trip(format!(
                "circuit breaker tripped: {errs} error(s), {pans} worker panic(s) within a \
                 {}-request window",
                self.lc.breaker_window
            ));
        } else {
            let mut st = self.state.write().unwrap();
            if st.health == HealthState::Healthy {
                st.health = HealthState::Degraded;
                st.note = Some(format!(
                    "degraded: {errs} error(s) in the current breaker window"
                ));
            }
        }
    }

    fn reset_window(&self) {
        self.win_requests.store(0, Ordering::SeqCst);
        self.win_errors.store(0, Ordering::SeqCst);
        self.win_panics.store(0, Ordering::SeqCst);
    }

    /// Open the circuit: auto-rollback to the warm last-known-good
    /// version if retained (the failing server is dropped, not kept),
    /// else quarantine the entry. Runs on the request path, so it only
    /// takes the state write lock — never the staging lock.
    fn trip(&self, reason: String) {
        let mut st = self.state.write().unwrap();
        if st.health == HealthState::Quarantined {
            return;
        }
        self.reset_window();
        if let Some(good) = st.last_good.take() {
            let good_version = good.version;
            if let Some(bad) = st.active.replace(good) {
                st.retired_panics += bad.server.stats().worker_panics;
            }
            st.health = HealthState::Degraded;
            st.note = Some(format!("auto-rollback to v{good_version}: {reason}"));
        } else {
            if let Some(bad) = st.active.take() {
                st.retired_panics += bad.server.stats().worker_panics;
            }
            st.health = HealthState::Quarantined;
            st.note = Some(reason);
        }
    }

    /// Snapshot for `/stats` / `/v1/models` rendering.
    pub fn snapshot(&self) -> EntrySnapshot {
        let st = self.state.read().unwrap();
        let o = Ordering::SeqCst;
        let live_panics: usize = st
            .active
            .iter()
            .chain(st.last_good.iter())
            .map(|a| a.server.stats().worker_panics)
            .sum();
        let (d_in, d_out) = st.route.unwrap_or((0, 0));
        EntrySnapshot {
            name: self.name.clone(),
            health: st.health,
            version: st.active.as_ref().map_or(0, |a| a.version),
            requests: self.requests.load(o),
            ok: self.ok.load(o),
            errors: self.errors.load(o),
            shed: self.shed.load(o),
            expired: self.expired.load(o),
            worker_panics: st.retired_panics + live_panics,
            d_in,
            d_out,
            note: st.note.clone(),
            last_load_error: st.last_load_error.clone(),
            source: st.active.as_ref().and_then(|a| a.path.clone()),
            fingerprint: st.active.as_ref().map_or(0, |a| a.fingerprint),
            has_last_good: st.last_good.is_some(),
            server: if st.health == HealthState::Quarantined {
                None
            } else {
                st.active.as_ref().map(|a| Arc::clone(&a.server))
            },
        }
    }

    /// Install `graph` as the next active version: the incumbent shifts
    /// to last-known-good (warm), the previous last-good retires. One
    /// write-lock critical section — the promotion atomicity point.
    fn promote(
        &self,
        graph: PackedGraph,
        path: Option<String>,
        fingerprint: u64,
        source: Option<SourceStamp>,
    ) -> u64 {
        let shape = (graph.d_in(), graph.d_out());
        let server = Arc::new(NativeServer::start(graph, self.serve_cfg.clone()));
        let mut st = self.state.write().unwrap();
        let version = st.next_version;
        st.next_version += 1;
        let incumbent = st.active.replace(ActiveVersion { version, server, path, fingerprint });
        if let Some(retired) = std::mem::replace(&mut st.last_good, incumbent) {
            // the version before last leaves the warm set; in-flight
            // requests still hold their own Arc, so it drains and joins
            // on the final clone drop
            st.retired_panics += retired.server.stats().worker_panics;
        }
        st.health = HealthState::Healthy;
        st.route = Some(shape);
        st.note = None;
        st.last_load_error = None;
        st.source = source;
        self.reset_window();
        version
    }

    /// Record a failed staged load. A new entry (nothing ever served)
    /// quarantines with the failure as its note; an entry with an
    /// incumbent keeps serving untouched and records `last_load_error`.
    fn record_load_failure(&self, msg: &str, source: Option<SourceStamp>) {
        let mut st = self.state.write().unwrap();
        st.source = source; // don't re-chew the same bad file on rescan
        if st.active.is_none() {
            st.health = HealthState::Quarantined;
            st.note = Some(msg.to_string());
        }
        st.last_load_error = Some(msg.to_string());
    }
}

/// Several checkpoints behind one process, each a [`ModelEntry`] with
/// its own warm versions, health machine and breaker — addressed by
/// `POST /v1/models/<name>/predict`, managed by
/// `POST /admin/models/<name>/load|unload|rollback` and `--model-dir`
/// SIGHUP rescans.
pub struct ModelRegistry {
    entries: RwLock<Vec<Arc<ModelEntry>>>,
    /// Serve config for models added at runtime (admin load of a new
    /// name, `--model-dir` scan); `add` takes an explicit one.
    serve_cfg: ServeConfig,
    lc: LifecycleConfig,
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::with_defaults(ServeConfig::default(), LifecycleConfig::default())
    }

    /// Registry with explicit defaults for runtime-added models and the
    /// lifecycle knobs (tests pin tiny breaker thresholds here).
    pub fn with_defaults(serve_cfg: ServeConfig, lc: LifecycleConfig) -> Self {
        ModelRegistry { entries: RwLock::new(Vec::new()), serve_cfg, lc }
    }

    /// Start a batch server for `model` under `name` (version 1,
    /// Healthy). Names are path segments: `[A-Za-z0-9._-]+`, unique
    /// within the registry.
    pub fn add(
        &self,
        name: &str,
        model: impl Into<PackedGraph>,
        cfg: ServeConfig,
    ) -> Result<(), ServeError> {
        if !valid_name(name) {
            return Err(ServeError { msg: format!("invalid model name '{name}'") });
        }
        let mut entries = self.entries.write().unwrap();
        if entries.iter().any(|e| e.name == name) {
            return Err(ServeError { msg: format!("duplicate model name '{name}'") });
        }
        let entry = Arc::new(ModelEntry::new(name, cfg, self.lc.clone()));
        let graph: PackedGraph = model.into();
        let fp = graph.behavior_fingerprint(self.lc.canary_seed, 8);
        entry.promote(graph, None, fp, None);
        entries.push(entry);
        Ok(())
    }

    /// The entry registered under `name`.
    pub fn entry(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.entries
            .read()
            .unwrap()
            .iter()
            .find(|e| e.name == name)
            .map(Arc::clone)
    }

    /// The active server for `name` (None when unknown or quarantined).
    pub fn get(&self, name: &str) -> Option<Arc<NativeServer>> {
        self.entry(name)?.server()
    }

    /// All entries, in registration order.
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        self.entries.read().unwrap().iter().map(Arc::clone).collect()
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.read().unwrap().iter().map(|e| e.name.clone()).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.read().unwrap().is_empty()
    }

    fn entry_or_create(&self, name: &str) -> Result<Arc<ModelEntry>, LifecycleError> {
        if !valid_name(name) {
            return Err(LifecycleError::new(
                LifecycleErrorKind::InvalidName,
                format!("invalid model name '{name}'"),
            ));
        }
        let mut entries = self.entries.write().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            return Ok(Arc::clone(e));
        }
        let entry = Arc::new(ModelEntry::new(name, self.serve_cfg.clone(), self.lc.clone()));
        entries.push(Arc::clone(&entry));
        Ok(entry)
    }

    /// Stage `path` for `name` and promote it if the canary passes —
    /// the whole read/CRC-check/compile/canary pipeline runs without
    /// any entry lock, so the incumbent serves throughout; only the
    /// final promotion takes the write lock (one pointer swap).
    ///
    /// Canary contract: without `allow_divergence` the candidate must
    /// produce logits **bit-exact** with the incumbent on
    /// [`LifecycleConfig::canary_vectors`] deterministic golden rows
    /// (compiled under the same active `BOLD_GRAPH_PASSES` config).
    /// With `allow_divergence` (retrained weights) the logit comparison
    /// is skipped and the candidate's `(d_in, d_out)` must match the
    /// registered route instead. A first load under a fresh name skips
    /// both (there is nothing to compare against).
    pub fn load_checkpoint(
        &self,
        name: &str,
        path: &str,
        allow_divergence: bool,
    ) -> Result<PromotionReport, LifecycleError> {
        let entry = self.entry_or_create(name)?;
        let _staged = entry.staging.lock().unwrap();
        let source = stamp(path);

        // -- stage: read + CRC-verify + compile, off the request path --
        let records = match read_records(path) {
            Ok(r) => r,
            Err(e) => {
                let msg = format!("checkpoint '{path}': {}", e.msg);
                entry.record_load_failure(&msg, source);
                return Err(LifecycleError::new(LifecycleErrorKind::Corrupt, msg));
            }
        };
        let candidate = match PackedGraph::from_records(&records) {
            Ok(g) => g,
            Err(e) => {
                let msg = format!("checkpoint '{path}': {}", e.msg);
                entry.record_load_failure(&msg, source);
                return Err(LifecycleError::new(LifecycleErrorKind::Corrupt, msg));
            }
        };

        // -- shadow-validation canary against the incumbent --
        let incumbent: Option<(Arc<NativeServer>, (usize, usize))> = {
            let st = entry.state.read().unwrap();
            st.active
                .as_ref()
                .map(|a| (Arc::clone(&a.server), st.route.unwrap_or((0, 0))))
        };
        let verdict = match &incumbent {
            None => CanaryVerdict::FirstLoad,
            Some((server, route)) => {
                let shape = (candidate.d_in(), candidate.d_out());
                if shape != *route {
                    let msg = format!(
                        "candidate shape d_in {} / d_out {} does not match the registered \
                         route d_in {} / d_out {}",
                        shape.0, shape.1, route.0, route.1
                    );
                    entry.record_load_failure(&msg, source);
                    return Err(LifecycleError::new(LifecycleErrorKind::ShapeMismatch, msg));
                }
                if allow_divergence {
                    CanaryVerdict::ShapeChecked
                } else {
                    let n = self.lc.canary_vectors.max(1);
                    let mut rng = Rng::new(self.lc.canary_seed);
                    let golden = BitMatrix::random(n, route.0, &mut rng);
                    let want = server.model().forward_bits(&golden);
                    let got = candidate.forward_bits(&golden);
                    if let Some(at) = want
                        .data
                        .iter()
                        .zip(got.data.iter())
                        .position(|(a, b)| a.to_bits() != b.to_bits())
                    {
                        let (vec_i, logit_i) = (at / route.1, at % route.1);
                        let msg = format!(
                            "canary divergence: logit {logit_i} of golden vector {vec_i} \
                             differs ({} vs {}); pass allow_divergence for retrained weights",
                            want.data[at], got.data[at]
                        );
                        entry.record_load_failure(&msg, source);
                        return Err(LifecycleError::new(
                            LifecycleErrorKind::CanaryDivergence,
                            msg,
                        ));
                    }
                    CanaryVerdict::BitExact { vectors: n }
                }
            }
        };

        // -- atomic promotion --
        let fp = candidate.behavior_fingerprint(self.lc.canary_seed, 8);
        let version = entry.promote(candidate, Some(path.to_string()), fp, source);
        Ok(PromotionReport { model: name.to_string(), version, canary: verdict, fingerprint: fp })
    }

    /// Swap the active and last-known-good versions (both stay warm, so
    /// a rollback can be rolled forward again). Also the manual way out
    /// of quarantine when a last-good version is still retained.
    pub fn rollback(&self, name: &str) -> Result<PromotionReport, LifecycleError> {
        let entry = self.entry(name).ok_or_else(|| {
            LifecycleError::new(LifecycleErrorKind::NoSuchModel, format!("unknown model '{name}'"))
        })?;
        let _staged = entry.staging.lock().unwrap();
        let mut st = entry.state.write().unwrap();
        let Some(good) = st.last_good.take() else {
            return Err(LifecycleError::new(
                LifecycleErrorKind::NothingToRollBack,
                format!("model '{name}' has no retained last-known-good version"),
            ));
        };
        let version = good.version;
        let fingerprint = good.fingerprint;
        st.route = Some((good.server.d_in(), good.server.model().d_out()));
        st.last_good = st.active.replace(good);
        st.health = HealthState::Healthy;
        st.note = Some(format!("manual rollback to v{version}"));
        st.last_load_error = None;
        entry.reset_window();
        Ok(PromotionReport {
            model: name.to_string(),
            version,
            canary: CanaryVerdict::ShapeChecked,
            fingerprint,
        })
    }

    /// Remove the entry: the route answers `404` afterwards; its
    /// servers drain on the final `Arc` drops.
    pub fn unload(&self, name: &str) -> bool {
        let mut entries = self.entries.write().unwrap();
        let before = entries.len();
        entries.retain(|e| e.name != name);
        entries.len() != before
    }

    /// Scan `dir` for `<name>.ckpt` files and stage every new or
    /// changed one (`allow_divergence` — a changed file is presumed
    /// retrained; the shape check still guards the route). Unchanged
    /// files (same path, length, mtime) are skipped, so repeated
    /// SIGHUPs are cheap. Returns one human-readable line per file
    /// examined, for the serve-http log.
    pub fn rescan_dir(&self, dir: &str) -> Vec<String> {
        let mut lines = Vec::new();
        let rd = match std::fs::read_dir(dir) {
            Ok(rd) => rd,
            Err(e) => {
                lines.push(format!("model-dir '{dir}': {e}"));
                return lines;
            }
        };
        let mut files: Vec<(String, String)> = rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let p = e.path();
                let name = p.file_stem()?.to_str()?.to_string();
                let path = p.to_str()?.to_string();
                (p.extension()?.to_str()? == "ckpt").then_some((name, path))
            })
            .collect();
        files.sort(); // deterministic scan order
        for (name, path) in files {
            let unchanged = self
                .entry(&name)
                .map(|e| {
                    let st = e.state.read().unwrap();
                    st.source.is_some() && st.source == stamp(&path)
                })
                .unwrap_or(false);
            if unchanged {
                lines.push(format!("model '{name}': unchanged ({path})"));
                continue;
            }
            match self.load_checkpoint(&name, &path, true) {
                Ok(rep) => lines.push(format!(
                    "model '{name}': promoted v{} from {path} ({})",
                    rep.version,
                    rep.canary.describe()
                )),
                Err(e) => lines.push(format!("model '{name}': REJECTED — {}", e.msg)),
            }
        }
        lines
    }
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::save_model;
    use crate::models::{boolean_mlp, MlpConfig};
    use crate::util::Rng;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("bold_lifecycle_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    fn mlp_ckpt(path: &str, seed: u64, d_in: usize) {
        let cfg = MlpConfig { d_in, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut model = boolean_mlp(&cfg, &mut Rng::new(seed));
        save_model(&mut model, path).unwrap();
    }

    fn tiny_serve() -> ServeConfig {
        ServeConfig { workers: 1, max_batch: 4, queue_cap: 16, ..Default::default() }
    }

    fn tight_lc() -> LifecycleConfig {
        LifecycleConfig {
            canary_vectors: 8,
            canary_seed: 7,
            breaker_window: 8,
            breaker_errors: 3,
            breaker_panics: 2,
        }
    }

    #[test]
    fn corrupt_checkpoint_quarantines_with_named_record_not_panic() {
        let path = tmp("corrupt.ckpt");
        mlp_ckpt(&path, 1, 64);
        let mut bytes = std::fs::read(&path).unwrap();
        let name = b"bl0.weight";
        let at = bytes.windows(name.len()).position(|w| w == name).unwrap();
        bytes[at + name.len() + 16] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();

        let reg = ModelRegistry::with_defaults(tiny_serve(), tight_lc());
        let err = reg.load_checkpoint("m", &path, false).expect_err("corrupt must fail");
        assert_eq!(err.kind, LifecycleErrorKind::Corrupt);
        assert!(err.msg.contains("bl0.weight"), "must name the record: {}", err.msg);

        // the entry exists, quarantined, naming the failing record
        let e = reg.entry("m").expect("entry registered even on failure");
        assert_eq!(e.health(), HealthState::Quarantined);
        let snap = e.snapshot();
        assert!(snap.note.unwrap().contains("bl0.weight"));
        assert!(matches!(e.admit(), Admission::Refused { .. }));
        assert!(reg.get("m").is_none(), "quarantined entry must not serve");

        // a truncated tail record quarantines the same way (named record,
        // no panic), and a staged failure leaves an INCUMBENT serving
        let tpath = tmp("trunc_tail_lc.ckpt");
        mlp_ckpt(&tpath, 2, 64);
        let clean = std::fs::read(&tpath).unwrap();
        reg.load_checkpoint("n", &tpath, false).expect("clean first load");
        std::fs::write(&tpath, &clean[..clean.len() - 2]).unwrap();
        let err = reg.load_checkpoint("n", &tpath, false).expect_err("truncated must fail");
        assert_eq!(err.kind, LifecycleErrorKind::Corrupt);
        assert!(err.msg.contains("truncated"), "{}", err.msg);
        let n = reg.entry("n").unwrap();
        assert_eq!(n.health(), HealthState::Healthy, "incumbent keeps serving");
        assert!(n.snapshot().last_load_error.unwrap().contains("truncated"));
        assert!(matches!(n.admit(), Admission::Serve(_)));
    }

    #[test]
    fn bit_exact_canary_gates_promotion_and_divergence_is_rejected() {
        let same = tmp("same.ckpt");
        let diverged = tmp("diverged.ckpt");
        let wrong_shape = tmp("wrong_shape.ckpt");
        mlp_ckpt(&same, 1, 64);
        mlp_ckpt(&diverged, 2, 64);
        mlp_ckpt(&wrong_shape, 3, 48);

        let reg = ModelRegistry::with_defaults(tiny_serve(), tight_lc());
        let first = reg.load_checkpoint("m", &same, false).expect("first load");
        assert_eq!(first.version, 1);
        assert_eq!(first.canary, CanaryVerdict::FirstLoad);

        // identical weights re-staged: bit-exact canary passes
        let rep = reg.load_checkpoint("m", &same, false).expect("identical re-load");
        assert_eq!(rep.version, 2);
        assert_eq!(rep.canary, CanaryVerdict::BitExact { vectors: 8 });
        assert_eq!(rep.fingerprint, first.fingerprint, "same weights, same behavior");

        // different weights without allow_divergence: rejected, incumbent keeps serving
        let err = reg.load_checkpoint("m", &diverged, false).expect_err("must diverge");
        assert_eq!(err.kind, LifecycleErrorKind::CanaryDivergence);
        let e = reg.entry("m").unwrap();
        assert_eq!(e.version(), 2, "incumbent version unchanged after a rejected canary");
        assert!(matches!(e.admit(), Admission::Serve(_)));
        assert!(e.snapshot().last_load_error.unwrap().contains("canary divergence"));

        // wrong shape: rejected even with allow_divergence
        let err = reg.load_checkpoint("m", &wrong_shape, true).expect_err("shape gate");
        assert_eq!(err.kind, LifecycleErrorKind::ShapeMismatch);

        // retrained weights with allow_divergence: promoted
        let rep = reg.load_checkpoint("m", &diverged, true).expect("allow_divergence");
        assert_eq!(rep.version, 3);
        assert_eq!(rep.canary, CanaryVerdict::ShapeChecked);
        assert!(e.snapshot().last_load_error.is_none(), "promotion clears the load error");
    }

    #[test]
    fn breaker_trips_to_rollback_then_quarantine_and_manual_rollback_recovers() {
        let path = tmp("breaker.ckpt");
        mlp_ckpt(&path, 5, 64);
        let reg = ModelRegistry::with_defaults(tiny_serve(), tight_lc());
        reg.load_checkpoint("m", &path, false).unwrap(); // v1
        reg.load_checkpoint("m", &path, false).unwrap(); // v2, v1 retained warm
        let e = reg.entry("m").unwrap();
        assert_eq!(e.version(), 2);

        // one panic failure: degraded, still serving
        e.note_failure(true);
        assert_eq!(e.health(), HealthState::Degraded);
        assert!(matches!(e.admit(), Admission::Serve(_)));

        // second panic hits breaker_panics = 2: auto-rollback to v1
        e.note_failure(true);
        assert_eq!(e.health(), HealthState::Degraded);
        assert_eq!(e.version(), 1, "auto-rollback to the warm last-known-good");
        assert!(e.snapshot().note.unwrap().contains("auto-rollback to v1"));
        assert!(!e.snapshot().has_last_good, "the failing version is dropped, not retained");

        // a clean breaker window heals Degraded back to Healthy
        for _ in 0..8 {
            e.note_ok();
        }
        assert_eq!(e.health(), HealthState::Healthy);

        // trip again with nothing retained: quarantine, route refuses
        e.note_failure(true);
        e.note_failure(true);
        assert_eq!(e.health(), HealthState::Quarantined);
        assert!(matches!(e.admit(), Admission::Refused { .. }));
        assert_eq!(e.version(), 0, "no active version while quarantined");

        // counters are frozen by construction: admit() refuses before
        // any note_* call, and worker_panics no longer has a live server
        let before = e.snapshot();
        assert!(matches!(e.admit(), Admission::Refused { .. }));
        let after = e.snapshot();
        assert_eq!(before.requests, after.requests);
        assert_eq!(before.errors, after.errors);
        assert_eq!(before.worker_panics, after.worker_panics);

        // manual rollback has nothing retained either — only a fresh
        // load leaves quarantine now
        let err = reg.rollback("m").expect_err("nothing retained");
        assert_eq!(err.kind, LifecycleErrorKind::NothingToRollBack);
        let rep = reg.load_checkpoint("m", &path, true).expect("reload out of quarantine");
        assert_eq!(e.health(), HealthState::Healthy);
        assert!(rep.version >= 3);
    }

    #[test]
    fn manual_rollback_swaps_warm_versions_both_ways() {
        let path = tmp("swap.ckpt");
        mlp_ckpt(&path, 9, 64);
        let reg = ModelRegistry::with_defaults(tiny_serve(), tight_lc());
        reg.load_checkpoint("m", &path, false).unwrap(); // v1
        reg.load_checkpoint("m", &path, false).unwrap(); // v2
        let e = reg.entry("m").unwrap();
        assert_eq!(reg.rollback("m").unwrap().version, 1);
        assert_eq!(e.version(), 1);
        assert!(e.snapshot().has_last_good, "v2 stays warm for roll-forward");
        assert_eq!(reg.rollback("m").unwrap().version, 2);
        assert_eq!(e.version(), 2);
    }

    #[test]
    fn rescan_dir_loads_new_and_changed_skips_unchanged() {
        let dir = std::env::temp_dir().join("bold_lifecycle_scan");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let dir = dir.to_str().unwrap().to_string();
        mlp_ckpt(&format!("{dir}/alpha.ckpt"), 1, 64);
        mlp_ckpt(&format!("{dir}/beta.ckpt"), 2, 64);
        std::fs::write(format!("{dir}/notes.txt"), b"ignored").unwrap();

        let reg = ModelRegistry::with_defaults(tiny_serve(), tight_lc());
        let lines = reg.rescan_dir(&dir);
        assert_eq!(lines.len(), 2, "only *.ckpt files are scanned: {lines:?}");
        assert_eq!(reg.names(), vec!["alpha".to_string(), "beta".to_string()]);
        assert_eq!(reg.entry("alpha").unwrap().version(), 1);

        // second scan: both unchanged, no version churn
        let lines = reg.rescan_dir(&dir);
        assert!(lines.iter().all(|l| l.contains("unchanged")), "{lines:?}");
        assert_eq!(reg.entry("alpha").unwrap().version(), 1);

        // rewrite alpha with retrained weights: rescan promotes it
        // (len changes even when mtime granularity is coarse — the
        // record payloads are seeded differently)
        mlp_ckpt(&format!("{dir}/alpha.ckpt"), 42, 64);
        reg.rescan_dir(&dir);
        assert_eq!(reg.entry("alpha").unwrap().version(), 2);
        assert_eq!(reg.entry("beta").unwrap().version(), 1);

        assert!(reg.unload("beta"));
        assert!(reg.entry("beta").is_none());
        assert!(!reg.unload("beta"), "double unload is a no-op");
    }
}
