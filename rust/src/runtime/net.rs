//! Zero-dependency TCP/HTTP-1.1 network front-end over the native batch
//! server (DESIGN.md §Network-Front-End) — the piece that turns the
//! in-process [`NativeServer`] into something a fleet of real clients
//! can hit over a socket, without giving up the bounded-queue overload
//! semantics the serving stack is built around.
//!
//! Architecture (one process, `bold serve-http`):
//!
//! * an **accept loop** (one thread, non-blocking listener) hands
//!   accepted connections to a bounded [`JobQueue`] — when that queue is
//!   full the connection is answered `503` and closed immediately, so a
//!   connection flood degrades into fast rejections, never into memory
//!   growth or accept backlog collapse;
//! * **HTTP worker threads** (default `BOLD_HTTP_THREADS`) each run one
//!   connection at a time through an incremental, bounded
//!   [`HttpParser`]: keep-alive loops reuse the parser buffer and the
//!   response writer, so the steady state allocates only the packed
//!   request row and the response logits (both cross thread boundaries
//!   by design). These threads are deliberately *not* the kernel pool
//!   workers of [`crate::util::pool`]: they block on sockets for long
//!   stretches, and sharing threads would starve the latency-critical
//!   kernel shards — instead they reuse the pool module's bounded
//!   [`JobQueue`] hand-off primitive and leave the compute pool to the
//!   [`NativeServer`] batch workers;
//! * a **multi-model registry** maps `POST /v1/models/<name>/predict`
//!   to per-model [`NativeServer`]s, so one process serves several
//!   checkpoints, each with its own bounded queue and micro-batcher.
//!   The registry is *live* (runtime/lifecycle.rs): `POST
//!   /admin/models/<name>/load|unload|rollback` stages checkpoints
//!   through a shadow-validation canary and promotes them atomically
//!   under load, and a per-model circuit breaker (Healthy → Degraded →
//!   Quarantined) answers `503` + `Retry-After` for a quarantined
//!   model — with frozen per-model counters — while every other model
//!   keeps serving.
//!
//! Overload + robustness semantics (exercised by `tests/net_faults.rs`):
//!
//! * **admission control**: a full model queue answers `503` +
//!   `Retry-After` via the non-blocking [`NativeServer::try_submit`] —
//!   an overloaded server sheds load in microseconds instead of
//!   back-pressuring the socket and silently stalling every client
//!   behind a TCP buffer;
//! * **per-request deadline**: once a request is fully read it has
//!   [`HttpConfig::request_deadline`] to produce logits; expiry answers
//!   `504` (the enqueued work is still computed and discarded — a
//!   deadline never wedges a batch worker);
//! * **slow-loris defence**: per-read socket timeouts plus a total
//!   [`HttpConfig::head_timeout`] per request; a client dribbling bytes
//!   gets `408` and the connection back, a silent idle keep-alive
//!   connection is closed without a response;
//! * **graceful drain**: shutdown stops the accept loop, lets every
//!   accepted connection finish its in-flight request (answered with
//!   `Connection: close`), then drains the model queues — every
//!   accepted request is answered.

use super::http::{HttpError, HttpLimits, HttpParser, Parse, ResponseWriter};
use super::lifecycle::{Admission, LifecycleError, LifecycleErrorKind};
use super::serve::TrySubmitError;
use crate::util::pool::JobQueue;

// the registry lived here before the lifecycle subsystem; re-exported so
// `runtime::net::ModelRegistry` call sites keep working
pub use super::lifecycle::ModelRegistry;
use std::fmt::Write as _;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

fn env_ms(name: &str, default_ms: u64) -> Duration {
    Duration::from_millis(
        std::env::var(name)
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(default_ms),
    )
}

/// Front-end tuning knobs. [`Default`] reads the `BOLD_HTTP_*`
/// environment (README §Runtime knobs); every field can also be set
/// programmatically (the fault-injection tests pin tiny limits).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// HTTP worker threads (connections served concurrently).
    /// Env: `BOLD_HTTP_THREADS`.
    pub threads: usize,
    /// Parser caps: head bytes / body bytes / header count.
    /// Env: `BOLD_HTTP_MAX_HEAD`, `BOLD_HTTP_MAX_BODY`.
    pub limits: HttpLimits,
    /// Per-`read(2)` timeout; also the idle keep-alive timeout.
    /// Env: `BOLD_HTTP_READ_TIMEOUT_MS`.
    pub read_timeout: Duration,
    /// Per-`write(2)` timeout (slow readers cannot hold a worker).
    pub write_timeout: Duration,
    /// Total time one request may take to arrive, first byte to last
    /// body byte (slow-loris cap ⇒ `408`).
    /// Env: `BOLD_HTTP_HEAD_TIMEOUT_MS`.
    pub head_timeout: Duration,
    /// Deadline from fully-read request to response (`504` on expiry).
    /// Env: `BOLD_HTTP_DEADLINE_MS`.
    pub request_deadline: Duration,
    /// Bounded accepted-connection queue (overflow ⇒ immediate `503`).
    pub conn_backlog: usize,
    /// Enable the test-only `POST /admin/models/<name>/inject_panic`
    /// endpoint (the chaos-soak suite drives a *separate process*'s
    /// panic containment through it); `404` when off.
    /// Env: `BOLD_FAULT_INJECT` (any non-`0` value ⇒ on).
    pub fault_inject: bool,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            threads: env_usize("BOLD_HTTP_THREADS", crate::util::pool::num_threads().clamp(2, 16)),
            limits: HttpLimits {
                max_head_bytes: env_usize("BOLD_HTTP_MAX_HEAD", 16 * 1024),
                max_body_bytes: env_usize("BOLD_HTTP_MAX_BODY", 1 << 20),
                max_headers: 64,
            },
            read_timeout: env_ms("BOLD_HTTP_READ_TIMEOUT_MS", 5_000),
            write_timeout: env_ms("BOLD_HTTP_WRITE_TIMEOUT_MS", 5_000),
            head_timeout: env_ms("BOLD_HTTP_HEAD_TIMEOUT_MS", 10_000),
            request_deadline: env_ms("BOLD_HTTP_DEADLINE_MS", 2_000),
            conn_backlog: env_usize("BOLD_HTTP_CONN_BACKLOG", 256),
            fault_inject: std::env::var("BOLD_FAULT_INJECT")
                .is_ok_and(|v| !v.is_empty() && v != "0"),
        }
    }
}

#[derive(Default)]
struct Counters {
    connections: AtomicUsize,
    conns_rejected: AtomicUsize,
    requests: AtomicUsize,
    ok: AtomicUsize,
    client_err: AtomicUsize,
    shed: AtomicUsize,
    expired: AtomicUsize,
    server_err: AtomicUsize,
    aborted: AtomicUsize,
}

/// Monotonic front-end counters (a consistent-enough snapshot; each
/// field is individually atomic).
#[derive(Debug, Clone, Copy)]
pub struct HttpStats {
    /// Connections accepted.
    pub connections: usize,
    /// Connections rejected with `503` at the accept queue.
    pub conns_rejected: usize,
    /// Requests fully parsed and dispatched.
    pub requests: usize,
    /// `2xx` responses.
    pub ok: usize,
    /// `4xx` responses (including `408` slow-loris timeouts).
    pub client_err: usize,
    /// `503` shed responses (queue-full admission control).
    pub shed: usize,
    /// `504` deadline expiries.
    pub expired: usize,
    /// Other `5xx` responses.
    pub server_err: usize,
    /// Connections dropped mid-request by the peer (no response possible).
    pub aborted: usize,
}

struct NetShared {
    registry: ModelRegistry,
    cfg: HttpConfig,
    conns: JobQueue<TcpStream>,
    shutdown: AtomicBool,
    counters: Counters,
}

impl NetShared {
    fn stats(&self) -> HttpStats {
        let c = &self.counters;
        let o = Ordering::SeqCst;
        HttpStats {
            connections: c.connections.load(o),
            conns_rejected: c.conns_rejected.load(o),
            requests: c.requests.load(o),
            ok: c.ok.load(o),
            client_err: c.client_err.load(o),
            shed: c.shed.load(o),
            expired: c.expired.load(o),
            server_err: c.server_err.load(o),
            aborted: c.aborted.load(o),
        }
    }

    fn count_status(&self, status: u16) {
        let c = &self.counters;
        match status {
            200..=299 => c.ok.fetch_add(1, Ordering::SeqCst),
            503 => c.shed.fetch_add(1, Ordering::SeqCst),
            504 => c.expired.fetch_add(1, Ordering::SeqCst),
            400..=499 => c.client_err.fetch_add(1, Ordering::SeqCst),
            _ => c.server_err.fetch_add(1, Ordering::SeqCst),
        };
    }
}

/// The running front-end: accept loop + HTTP workers around a
/// [`ModelRegistry`]. Dropping (or calling [`HttpServer::shutdown`])
/// drains gracefully.
pub struct HttpServer {
    shared: Arc<NetShared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    addr: SocketAddr,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral test port) and
    /// start serving.
    pub fn start(registry: ModelRegistry, addr: &str, cfg: HttpConfig) -> std::io::Result<Self> {
        assert!(cfg.threads >= 1, "need at least one HTTP thread");
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let conns = JobQueue::bounded(cfg.conn_backlog.max(1));
        let shared = Arc::new(NetShared {
            registry,
            cfg,
            conns,
            shutdown: AtomicBool::new(false),
            counters: Counters::default(),
        });
        let accept = {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("bold-http-accept".into())
                .spawn(move || accept_loop(&sh, listener))
                .expect("spawn accept thread")
        };
        let workers = (0..shared.cfg.threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("bold-http-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn http worker")
            })
            .collect();
        Ok(HttpServer { shared, accept: Some(accept), workers, addr: local })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The effective configuration (env defaults already applied).
    pub fn config(&self) -> &HttpConfig {
        &self.shared.cfg
    }

    /// The served model registry (e.g. for reading per-worker scratch
    /// footprints before shutdown — the serve benches do).
    pub fn registry(&self) -> &ModelRegistry {
        &self.shared.registry
    }

    /// Snapshot of the front-end counters.
    pub fn stats(&self) -> HttpStats {
        self.shared.stats()
    }

    /// Ask the server to drain (same effect as `POST /admin/shutdown`):
    /// stop accepting, finish in-flight work. Non-blocking; follow with
    /// [`HttpServer::shutdown`] to join.
    pub fn request_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// True once a drain has been requested (admin endpoint or
    /// [`HttpServer::request_shutdown`]).
    pub fn is_draining(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Block until a drain is requested (the `serve-http` CLI parks
    /// here so `POST /admin/shutdown` can stop the process cleanly).
    pub fn wait_for_shutdown(&self) {
        while !self.is_draining() {
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// Graceful drain: stop accepting, answer every in-flight request,
    /// join all threads, shut the model servers down, return the final
    /// counters.
    pub fn shutdown(mut self) -> HttpStats {
        self.stop_and_join();
        let stats = self.shared.stats();
        // dropping `self` releases the last Arc: the NativeServers drain
        // their queues and join their batch workers in their own Drop
        stats
    }

    fn stop_and_join(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join(); // closes the connection queue on exit
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(sh: &NetShared, listener: TcpListener) {
    let mut reject_writer = ResponseWriter::new();
    while !sh.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                sh.counters.connections.fetch_add(1, Ordering::SeqCst);
                // the listener is non-blocking; the connection must not be
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let _ = stream.set_read_timeout(Some(sh.cfg.read_timeout));
                let _ = stream.set_write_timeout(Some(sh.cfg.write_timeout));
                if let Err(mut stream) = sh.conns.try_push(stream) {
                    // connection-level admission control: reject fast,
                    // never queue unboundedly (best-effort write; the
                    // peer may already be gone)
                    sh.counters.conns_rejected.fetch_add(1, Ordering::SeqCst);
                    let body = b"{\"error\":\"server overloaded, connection rejected\"}\n";
                    let _ = stream
                        .write_all(reject_writer.render(503, &[("Retry-After", "1")], body, false));
                    let _ = stream.shutdown(Shutdown::Both);
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    // drain hand-off: workers finish what was accepted, then see None
    sh.conns.close();
}

fn worker_loop(sh: &NetShared) {
    let mut parser = HttpParser::new(sh.cfg.limits.clone());
    let mut writer = ResponseWriter::new();
    let mut body = String::with_capacity(512);
    let mut feats: Vec<f32> = Vec::new();
    let mut chunk = [0u8; 8 * 1024];
    while let Some(stream) = sh.conns.pop() {
        handle_connection(sh, stream, &mut parser, &mut writer, &mut body, &mut feats, &mut chunk);
    }
}

/// Serve one connection's keep-alive request loop. Never panics on
/// malformed input or socket errors — every exit path is a clean close
/// (with a status line whenever the protocol still allows one).
fn handle_connection(
    sh: &NetShared,
    mut stream: TcpStream,
    parser: &mut HttpParser,
    writer: &mut ResponseWriter,
    body: &mut String,
    feats: &mut Vec<f32>,
    chunk: &mut [u8],
) {
    parser.reset();
    let mut state: Result<Parse, HttpError> = Ok(Parse::NeedMore);
    loop {
        // ---- read one full request (bounded: bytes, headers, time) ----
        let mut started: Option<Instant> = None;
        let mut sent_continue = false;
        loop {
            match &state {
                Ok(Parse::Ready) => break,
                Ok(Parse::NeedMore) => {}
                Err(e) => {
                    // protocol violation: answer with its status, close
                    // (framing is unreliable past a malformed head)
                    sh.counters.requests.fetch_add(1, Ordering::SeqCst);
                    sh.count_status(e.status);
                    body.clear();
                    let _ = writeln!(body, "{{\"error\":{:?}}}", e.msg);
                    let _ = stream.write_all(writer.render(e.status, &[], body.as_bytes(), false));
                    let _ = stream.shutdown(Shutdown::Both);
                    return;
                }
            }
            if parser.buffered() > 0 && started.is_none() {
                // pipelined bytes from the previous read count as a start
                started = Some(Instant::now());
            }
            if parser.head_complete() && parser.expects_continue() && !sent_continue {
                sent_continue = true;
                if stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").is_err() {
                    sh.counters.aborted.fetch_add(1, Ordering::SeqCst);
                    return;
                }
            }
            if let Some(t0) = started {
                if t0.elapsed() > sh.cfg.head_timeout {
                    // slow-loris: the request did not arrive in time
                    sh.counters.requests.fetch_add(1, Ordering::SeqCst);
                    sh.count_status(408);
                    let _ = stream.write_all(writer.render(
                        408,
                        &[],
                        b"{\"error\":\"request did not arrive within the head timeout\"}\n",
                        false,
                    ));
                    let _ = stream.shutdown(Shutdown::Both);
                    return;
                }
            }
            match stream.read(chunk) {
                Ok(0) => {
                    // peer closed; mid-request close is a counted fault
                    if parser.buffered() > 0 {
                        sh.counters.aborted.fetch_add(1, Ordering::SeqCst);
                    }
                    return;
                }
                Ok(n) => {
                    if started.is_none() {
                        started = Some(Instant::now());
                    }
                    state = parser.feed(&chunk[..n]);
                }
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
                {
                    if parser.buffered() == 0 {
                        // idle keep-alive connection timed out: close quietly
                        return;
                    }
                    sh.counters.requests.fetch_add(1, Ordering::SeqCst);
                    sh.count_status(408);
                    let _ = stream.write_all(writer.render(
                        408,
                        &[],
                        b"{\"error\":\"timed out mid-request\"}\n",
                        false,
                    ));
                    let _ = stream.shutdown(Shutdown::Both);
                    return;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    sh.counters.aborted.fetch_add(1, Ordering::SeqCst);
                    return;
                }
            }
        }

        // ---- dispatch ----
        sh.counters.requests.fetch_add(1, Ordering::SeqCst);
        let draining = sh.shutdown.load(Ordering::SeqCst);
        let keep = parser.keep_alive() && !draining;
        match respond(sh, parser, writer, body, feats, &mut stream, keep) {
            Err(_) => {
                sh.counters.aborted.fetch_add(1, Ordering::SeqCst);
                return;
            }
            Ok(false) => {
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
            Ok(true) => {}
        }
        state = parser.consume();
    }
}

/// Route + answer one parsed request. `Ok(keep)` says whether the
/// keep-alive loop continues; `Err` means the socket write failed (peer
/// gone) — the caller closes either way.
fn respond(
    sh: &NetShared,
    parser: &HttpParser,
    writer: &mut ResponseWriter,
    body: &mut String,
    feats: &mut Vec<f32>,
    stream: &mut TcpStream,
    keep: bool,
) -> std::io::Result<bool> {
    let t_ready = Instant::now();
    let method = parser.method();
    let path = parser.path();
    body.clear();

    // predict is the hot path: match it first
    if let Some(name) = path
        .strip_prefix("/v1/models/")
        .and_then(|p| p.strip_suffix("/predict"))
    {
        if method != "POST" {
            sh.count_status(405);
            body.push_str("{\"error\":\"predict requires POST\"}\n");
            stream.write_all(writer.render(405, &[("Allow", "POST")], body.as_bytes(), keep))?;
            return Ok(keep);
        }
        let Some(entry) = sh.registry.entry(name) else {
            sh.count_status(404);
            let msg = format!("unknown model '{name}'");
            let _ = writeln!(body, "{{\"error\":{msg:?}}}");
            stream.write_all(writer.render(404, &[], body.as_bytes(), keep))?;
            return Ok(keep);
        };
        // circuit breaker gate: a quarantined model answers 503 +
        // Retry-After without advancing any of its counters (the
        // net_faults suite asserts the freeze), while the `Arc` returned
        // for an admitted request pins its model version for the
        // request's lifetime — a concurrent promotion retires the old
        // server only after every admitted request is answered
        let server = match entry.admit() {
            Admission::Serve(s) => s,
            Admission::Refused { reason } => {
                sh.count_status(503);
                let _ = writeln!(body, "{{\"error\":{reason:?}}}");
                stream.write_all(writer.render(
                    503,
                    &[("Retry-After", "1")],
                    body.as_bytes(),
                    keep,
                ))?;
                return Ok(keep);
            }
        };
        match parse_features(parser, server.d_in(), feats) {
            Ok(()) => {}
            Err(msg) => {
                sh.count_status(400);
                let _ = writeln!(body, "{{\"error\":{msg:?}}}");
                stream.write_all(writer.render(400, &[], body.as_bytes(), keep))?;
                return Ok(keep);
            }
        }
        match server.try_submit(feats) {
            Err(TrySubmitError::Full) => {
                // admission control: the bounded queue is the overload
                // contract — shed with Retry-After, never block or hang.
                // Shedding is overload, not model failure: it is tracked
                // per model but never feeds the circuit breaker
                sh.count_status(503);
                entry.note_shed();
                body.push_str("{\"error\":\"model queue full\"}\n");
                stream.write_all(writer.render(
                    503,
                    &[("Retry-After", "1")],
                    body.as_bytes(),
                    keep,
                ))?;
                Ok(keep)
            }
            Err(TrySubmitError::Rejected(e)) => {
                sh.count_status(503);
                let _ = writeln!(body, "{{\"error\":{:?}}}", e.msg);
                stream.write_all(writer.render(503, &[], body.as_bytes(), false))?;
                Ok(false)
            }
            Ok(pending) => {
                entry.note_submitted();
                let remaining = sh.cfg.request_deadline.saturating_sub(t_ready.elapsed());
                match pending.wait_timeout(remaining) {
                    Ok(Some(resp)) => {
                        sh.count_status(200);
                        entry.note_ok();
                        let _ = write!(body, "{{\"model\":{name:?},\"class\":{}", resp.class);
                        body.push_str(",\"logits\":[");
                        for (i, l) in resp.logits.iter().enumerate() {
                            if i > 0 {
                                body.push(',');
                            }
                            let _ = write!(body, "{l}");
                        }
                        body.push_str("]}\n");
                        stream.write_all(writer.render(200, &JSON_CT, body.as_bytes(), keep))?;
                        Ok(keep)
                    }
                    Ok(None) => {
                        // deadline pressure is an overload signal, not a
                        // broken model: tracked, but not a breaker input
                        sh.count_status(504);
                        entry.note_expired();
                        body.push_str("{\"error\":\"deadline exceeded\"}\n");
                        stream.write_all(writer.render(504, &[], body.as_bytes(), keep))?;
                        Ok(keep)
                    }
                    Err(e) if e.msg.contains("panicked") => {
                        // the batch worker panicked mid-forward: the fault
                        // is contained (worker respawned, counted in
                        // /stats) and THIS request failed — a server
                        // error, not a drain, so keep-alive survives.
                        // Panics are the breaker's strongest input:
                        // enough of them in one window auto-rolls back
                        // to last-known-good or quarantines the entry
                        sh.count_status(500);
                        entry.note_failure(true);
                        body.push_str("{\"error\":\"batch worker panicked; request not served\"}\n");
                        stream.write_all(writer.render(500, &[], body.as_bytes(), keep))?;
                        Ok(keep)
                    }
                    Err(_) => {
                        sh.count_status(503);
                        body.push_str("{\"error\":\"server shutting down\"}\n");
                        stream.write_all(writer.render(503, &[], body.as_bytes(), false))?;
                        Ok(false)
                    }
                }
            }
        }
    } else if let Some(rest) = path.strip_prefix("/admin/models/") {
        respond_admin(sh, rest, parser, writer, body, stream, keep)
    } else {
        respond_aux(sh, method, path, writer, body, stream, keep)
    }
}

/// `POST /admin/models/<name>/load|unload|rollback` (plus the
/// fault-injection-gated `inject_panic`) — the model-lifecycle admin
/// surface (runtime/lifecycle.rs). `load` takes a plain-text body: a
/// checkpoint path, optionally followed by the token `allow_divergence`
/// for genuinely retrained weights.
fn respond_admin(
    sh: &NetShared,
    rest: &str,
    parser: &HttpParser,
    writer: &mut ResponseWriter,
    body: &mut String,
    stream: &mut TcpStream,
    keep: bool,
) -> std::io::Result<bool> {
    let Some((name, action)) = rest.rsplit_once('/') else {
        sh.count_status(404);
        body.push_str("{\"error\":\"no such endpoint\"}\n");
        stream.write_all(writer.render(404, &[], body.as_bytes(), keep))?;
        return Ok(keep);
    };
    if parser.method() != "POST" {
        sh.count_status(405);
        body.push_str("{\"error\":\"model admin requires POST\"}\n");
        stream.write_all(writer.render(405, &[("Allow", "POST")], body.as_bytes(), keep))?;
        return Ok(keep);
    }
    match action {
        "load" => {
            let text = std::str::from_utf8(parser.body()).unwrap_or("");
            let mut toks = text.split_ascii_whitespace();
            let Some(ckpt) = toks.next() else {
                sh.count_status(400);
                body.push_str(
                    "{\"error\":\"load requires a body: <checkpoint-path> [allow_divergence]\"}\n",
                );
                stream.write_all(writer.render(400, &[], body.as_bytes(), keep))?;
                return Ok(keep);
            };
            let allow = toks.next() == Some("allow_divergence");
            // staging + canary run on this HTTP worker thread, entirely
            // off the predict path — the incumbent keeps serving via
            // the other workers until the one-pointer-swap promotion
            match sh.registry.load_checkpoint(name, ckpt, allow) {
                Ok(rep) => {
                    sh.count_status(200);
                    let canary = rep.canary.describe();
                    let _ = writeln!(
                        body,
                        "{{\"model\":{:?},\"version\":{},\"canary\":{canary:?},\
                         \"fingerprint\":\"{:016x}\"}}",
                        rep.model, rep.version, rep.fingerprint
                    );
                    stream.write_all(writer.render(200, &JSON_CT, body.as_bytes(), keep))?;
                    Ok(keep)
                }
                Err(e) => write_lifecycle_error(sh, &e, writer, body, stream, keep),
            }
        }
        "rollback" => match sh.registry.rollback(name) {
            Ok(rep) => {
                sh.count_status(200);
                let _ = writeln!(
                    body,
                    "{{\"model\":{:?},\"version\":{},\"fingerprint\":\"{:016x}\"}}",
                    rep.model, rep.version, rep.fingerprint
                );
                stream.write_all(writer.render(200, &JSON_CT, body.as_bytes(), keep))?;
                Ok(keep)
            }
            Err(e) => write_lifecycle_error(sh, &e, writer, body, stream, keep),
        },
        "unload" => {
            if sh.registry.unload(name) {
                sh.count_status(200);
                let _ = writeln!(body, "{{\"model\":{name:?},\"unloaded\":true}}");
                stream.write_all(writer.render(200, &JSON_CT, body.as_bytes(), keep))?;
            } else {
                sh.count_status(404);
                let msg = format!("unknown model '{name}'");
                let _ = writeln!(body, "{{\"error\":{msg:?}}}");
                stream.write_all(writer.render(404, &[], body.as_bytes(), keep))?;
            }
            Ok(keep)
        }
        "inject_panic" => {
            if !sh.cfg.fault_inject {
                sh.count_status(404);
                body.push_str("{\"error\":\"no such endpoint\"}\n");
                stream.write_all(writer.render(404, &[], body.as_bytes(), keep))?;
                return Ok(keep);
            }
            let n = std::str::from_utf8(parser.body())
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .unwrap_or(1);
            match sh.registry.entry(name).map(|e| e.server()) {
                Some(Some(server)) => {
                    server.inject_panics(n);
                    sh.count_status(200);
                    let _ = writeln!(body, "{{\"injected\":{n}}}");
                    stream.write_all(writer.render(200, &JSON_CT, body.as_bytes(), keep))?;
                }
                Some(None) => {
                    sh.count_status(409);
                    body.push_str("{\"error\":\"model is not serving\"}\n");
                    stream.write_all(writer.render(409, &[], body.as_bytes(), keep))?;
                }
                None => {
                    sh.count_status(404);
                    let msg = format!("unknown model '{name}'");
                    let _ = writeln!(body, "{{\"error\":{msg:?}}}");
                    stream.write_all(writer.render(404, &[], body.as_bytes(), keep))?;
                }
            }
            Ok(keep)
        }
        _ => {
            sh.count_status(404);
            body.push_str("{\"error\":\"no such endpoint\"}\n");
            stream.write_all(writer.render(404, &[], body.as_bytes(), keep))?;
            Ok(keep)
        }
    }
}

/// Map a lifecycle failure onto HTTP: corrupt/invalid input is the
/// caller's `400`, unknown names `404`, and state conflicts (canary
/// divergence, shape mismatch, nothing to roll back) `409` — the
/// incumbent keeps serving in every case.
fn write_lifecycle_error(
    sh: &NetShared,
    e: &LifecycleError,
    writer: &mut ResponseWriter,
    body: &mut String,
    stream: &mut TcpStream,
    keep: bool,
) -> std::io::Result<bool> {
    let status = match e.kind {
        LifecycleErrorKind::InvalidName | LifecycleErrorKind::Corrupt => 400,
        LifecycleErrorKind::NoSuchModel => 404,
        LifecycleErrorKind::ShapeMismatch
        | LifecycleErrorKind::CanaryDivergence
        | LifecycleErrorKind::NothingToRollBack => 409,
    };
    sh.count_status(status);
    let _ = writeln!(body, "{{\"error\":{:?}}}", e.msg);
    stream.write_all(writer.render(status, &[], body.as_bytes(), keep))?;
    Ok(keep)
}

/// The non-predict endpoints (health, registry listing, counters,
/// drain trigger).
fn respond_aux(
    sh: &NetShared,
    method: &str,
    path: &str,
    writer: &mut ResponseWriter,
    body: &mut String,
    stream: &mut TcpStream,
    keep: bool,
) -> std::io::Result<bool> {
    match (method, path) {
        ("GET" | "HEAD", "/healthz") => {
            sh.count_status(200);
            let payload: &[u8] = if method == "HEAD" { b"" } else { b"ok\n" };
            stream.write_all(writer.render(200, &[], payload, keep))?;
            Ok(keep)
        }
        ("GET", "/v1/models") => {
            sh.count_status(200);
            body.push_str("{\"models\":[");
            for (i, entry) in sh.registry.entries().iter().enumerate() {
                if i > 0 {
                    body.push(',');
                }
                // quarantined entries keep their route identity
                // (d_in/d_out from the registered route) and surface the
                // quarantine reason — e.g. the failing checkpoint record
                // — in `note`/`last_load_error`; compile-derived fields
                // zero out while no version is serving
                let snap = entry.snapshot();
                let (ops, queue_cap, slots_raw, slots_live, lut_neurons, lut_table_bytes) =
                    match &snap.server {
                        Some(s) => {
                            let ps = s.model().pass_stats();
                            (
                                s.model().num_ops(),
                                s.queue_cap(),
                                ps.raw_slots,
                                ps.live_slots,
                                ps.lut_neurons,
                                ps.lut_table_bytes,
                            )
                        }
                        None => (0, 0, 0, 0, 0, 0),
                    };
                let _ = write!(
                    body,
                    "{{\"name\":{:?},\"d_in\":{},\"d_out\":{},\"ops\":{ops},\
                     \"queue_cap\":{queue_cap},\"slots_raw\":{slots_raw},\
                     \"slots_live\":{slots_live},\"lut_neurons\":{lut_neurons},\
                     \"lut_table_bytes\":{lut_table_bytes},\"health\":{:?},\"version\":{},\
                     \"fingerprint\":\"{:016x}\",\"note\":{},\"last_load_error\":{}}}",
                    snap.name,
                    snap.d_in,
                    snap.d_out,
                    snap.health.as_str(),
                    snap.version,
                    snap.fingerprint,
                    json_opt(&snap.note),
                    json_opt(&snap.last_load_error)
                );
            }
            body.push_str("]}\n");
            stream.write_all(writer.render(200, &JSON_CT, body.as_bytes(), keep))?;
            Ok(keep)
        }
        ("GET", "/stats") => {
            sh.count_status(200);
            let st = sh.stats();
            let _ = write!(
                body,
                "{{\"connections\":{},\"conns_rejected\":{},\"requests\":{},\"ok\":{},\
                 \"client_err\":{},\"shed\":{},\"expired\":{},\"server_err\":{},\"aborted\":{}",
                st.connections,
                st.conns_rejected,
                st.requests,
                st.ok,
                st.client_err,
                st.shed,
                st.expired,
                st.server_err,
                st.aborted
            );
            // contained batch-worker panics, summed across models
            // (includes retired versions — per-model totals never reset
            // on promotion or quarantine)
            let entries = sh.registry.entries();
            let panics: usize = entries.iter().map(|e| e.snapshot().worker_panics).sum();
            let _ = write!(body, ",\"worker_panics\":{panics}");
            // per-worker GraphScratch footprints per model (bytes; zero
            // until a worker has run its first batch; empty while a
            // model has no serving version)
            let mut total = 0usize;
            body.push_str(",\"scratch_per_worker\":{");
            for (i, e) in entries.iter().enumerate() {
                if i > 0 {
                    body.push(',');
                }
                let _ = write!(body, "{:?}:[", e.name());
                if let Some(s) = e.server() {
                    for (j, b) in s.worker_scratch_bytes().iter().enumerate() {
                        if j > 0 {
                            body.push(',');
                        }
                        let _ = write!(body, "{b}");
                        total += b;
                    }
                }
                body.push(']');
            }
            let _ = write!(body, "}},\"scratch_bytes\":{total}");
            // per-model lifecycle counters — the circuit breaker's
            // view; a quarantined model's map entry stops moving
            body.push_str(",\"models\":{");
            for (i, e) in entries.iter().enumerate() {
                if i > 0 {
                    body.push(',');
                }
                let snap = e.snapshot();
                let _ = write!(
                    body,
                    "{:?}:{{\"health\":{:?},\"version\":{},\"requests\":{},\"ok\":{},\
                     \"errors\":{},\"shed\":{},\"expired\":{},\"worker_panics\":{}}}",
                    snap.name,
                    snap.health.as_str(),
                    snap.version,
                    snap.requests,
                    snap.ok,
                    snap.errors,
                    snap.shed,
                    snap.expired,
                    snap.worker_panics
                );
            }
            body.push_str("}}\n");
            stream.write_all(writer.render(200, &JSON_CT, body.as_bytes(), keep))?;
            Ok(keep)
        }
        ("POST", "/admin/shutdown") => {
            sh.count_status(200);
            sh.shutdown.store(true, Ordering::SeqCst);
            body.push_str("{\"draining\":true}\n");
            stream.write_all(writer.render(200, &JSON_CT, body.as_bytes(), false))?;
            Ok(false)
        }
        (_, "/healthz" | "/v1/models" | "/stats" | "/admin/shutdown") => {
            sh.count_status(405);
            body.push_str("{\"error\":\"method not allowed\"}\n");
            stream.write_all(writer.render(405, &[("Allow", "GET")], body.as_bytes(), keep))?;
            Ok(keep)
        }
        _ => {
            sh.count_status(404);
            body.push_str("{\"error\":\"no such endpoint\"}\n");
            stream.write_all(writer.render(404, &[], body.as_bytes(), keep))?;
            Ok(keep)
        }
    }
}

const JSON_CT: [(&str, &str); 1] = [("Content-Type", "application/json")];

/// `Some(s)` as an escaped JSON string, `None` as `null`.
fn json_opt(v: &Option<String>) -> String {
    match v {
        Some(s) => format!("{s:?}"),
        None => "null".to_string(),
    }
}

/// Decode the request body into `d_in` f32 features, reusing `feats`.
/// Two encodings: raw little-endian f32 (`Content-Type:
/// application/octet-stream`, exactly `4·d_in` bytes) and ASCII decimal
/// text split on commas/whitespace.
fn parse_features(parser: &HttpParser, d_in: usize, feats: &mut Vec<f32>) -> Result<(), String> {
    feats.clear();
    let raw = parser.body();
    let binary = parser
        .header("content-type")
        .is_some_and(|ct| ct.to_ascii_lowercase().contains("octet-stream"));
    if binary {
        if raw.len() != 4 * d_in {
            return Err(format!(
                "binary body must be exactly 4*d_in = {} bytes, got {}",
                4 * d_in,
                raw.len()
            ));
        }
        feats.extend(
            raw.chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])),
        );
        return Ok(());
    }
    let text = std::str::from_utf8(raw).map_err(|_| "body is not UTF-8 text".to_string())?;
    for tok in text.split(|c: char| c == ',' || c.is_ascii_whitespace()) {
        if tok.is_empty() {
            continue;
        }
        let v: f32 = tok
            .parse()
            .map_err(|_| format!("not a number: {tok:?}"))?;
        feats.push(v);
    }
    if feats.len() != d_in {
        return Err(format!("expected {d_in} features, got {}", feats.len()));
    }
    Ok(())
}
