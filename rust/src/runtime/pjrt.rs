//! PJRT runtime (feature `xla-runtime`): load the AOT-compiled L2 graphs
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and execute
//! them from the Rust hot path. Python never runs at request time — the
//! HLO text is compiled to a PJRT CPU executable here and called like a
//! function.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. See /opt/xla-example/README.md and DESIGN.md
//! §Runtime.
//!
//! The default build links the in-tree `vendor/xla-stub` crate so this
//! module always compiles; executing real HLO requires repointing the
//! `xla` path dependency (vendor/xla-stub/README.md).

use crate::tensor::Tensor;
use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// PJRT-path error (artifact IO, HLO parsing, compilation, execution).
#[derive(Debug)]
pub struct PjrtError {
    pub msg: String,
}

impl PjrtError {
    fn new(msg: impl Into<String>) -> Self {
        PjrtError { msg: msg.into() }
    }
}

impl fmt::Display for PjrtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "pjrt error: {}", self.msg)
    }
}

impl std::error::Error for PjrtError {}

impl From<std::io::Error> for PjrtError {
    fn from(e: std::io::Error) -> Self {
        PjrtError::new(e.to_string())
    }
}

impl From<xla::Error> for PjrtError {
    fn from(e: xla::Error) -> Self {
        PjrtError::new(e.to_string())
    }
}

/// A compiled artifact registry: one PJRT executable per L2 entry point.
pub struct PjrtExecutor {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl PjrtExecutor {
    /// Compile every `*.hlo.txt` in `dir` (skipping the Makefile sentinel
    /// `model.hlo.txt`, a duplicate of the train step).
    pub fn load_dir(dir: &str) -> Result<Self, PjrtError> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| PjrtError::new(format!("create PJRT CPU client: {e}")))?;
        let mut exes = HashMap::new();
        let dirp = Path::new(dir);
        for entry in
            std::fs::read_dir(dirp).map_err(|e| PjrtError::new(format!("read {dir}: {e}")))?
        {
            let path = entry?.path();
            let fname = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if !fname.ends_with(".hlo.txt") || fname == "model.hlo.txt" {
                continue;
            }
            let name = fname.trim_end_matches(".hlo.txt").to_string();
            let path_str = path.to_str().ok_or_else(|| PjrtError::new("bad path"))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| PjrtError::new(format!("parse {fname}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| PjrtError::new(format!("compile {fname}: {e}")))?;
            exes.insert(name, exe);
        }
        if exes.is_empty() {
            return Err(PjrtError::new(format!(
                "no artifacts in {dir} — run `make artifacts` first"
            )));
        }
        Ok(PjrtExecutor { client, exes, dir: dirp.to_path_buf() })
    }

    pub fn entries(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.exes.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Execute an entry point. Inputs/outputs are dense f32 [`Tensor`]s;
    /// jax lowers with `return_tuple=True`, so the single output literal
    /// is a tuple that we decompose.
    pub fn execute(&self, entry: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>, PjrtError> {
        let exe = self.exes.get(entry).ok_or_else(|| {
            PjrtError::new(format!("unknown entry '{entry}' (have: {:?})", self.entries()))
        })?;
        let literals: Result<Vec<xla::Literal>, PjrtError> =
            inputs.iter().map(tensor_to_literal).collect();
        let result = exe.execute::<xla::Literal>(&literals?)?;
        let out = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| PjrtError::new("empty execution result"))?
            .to_literal_sync()?;
        let parts = out.to_tuple()?;
        parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
    }
}

/// Tensor (f32, row-major) → xla Literal of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal, PjrtError> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

/// xla Literal (f32) → Tensor.
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor, PjrtError> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>()?;
    let dims = if dims.is_empty() { vec![1] } else { dims };
    Ok(Tensor::from_vec(&dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Round-trip tests that don't need artifacts on disk.
    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(t, back);
    }

    // Full artifact tests live in rust/tests/xla_crosscheck.rs (they need
    // `make artifacts` to have run and a real xla binding linked).
}
