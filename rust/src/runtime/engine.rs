//! Forward-only packed-bit inference engine (DESIGN.md §Serving-Runtime).
//!
//! The training stack (`nn::`) keeps f32 vote/gradient buffers next to
//! every Boolean parameter; for serving none of that is needed. This
//! engine freezes a trained Boolean MLP into exactly the data the paper's
//! Eq. (1) neuron consumes — packed weight bits, per-layer thresholds and
//! an FP head — and runs the whole interior as fused XNOR+POPCNT with the
//! activation re-packed straight to bits
//! ([`BitMatrix::xnor_threshold`]): no XLA, no f32 unpacking between
//! Boolean layers.
//!
//! Frozen-model format: the engine loads the ordinary checkpoint files
//! written by [`crate::coordinator::save_model`] (see
//! `coordinator/checkpoint.rs` for the binary layout), so any trained
//! `models::boolean_mlp` checkpoint is directly servable. Supported
//! architecture: a stack of `BoolLinear` (+ optional Boolean bias,
//! optional centered threshold) closed by one FP `Linear` head — the
//! MLP family of the paper's §4.1. Layers may additionally carry a
//! validity lane-mask implementing the three-valued 𝕄 zero of
//! Definition 3.1 for padded/invalid input features (DESIGN.md
//! §Three-valued logic 𝕄).
//!
//! Conv / residual / BN-carrying architectures serve through the
//! architecture-agnostic graph executor instead
//! ([`crate::runtime::PackedGraph`], DESIGN.md §Packed-Graph-Executor),
//! which keeps this loader as its back-compat fallback for checkpoints
//! that predate the `Record::Arch` architecture record.
//!
//! The FP head intentionally replays the reference `nn::Linear`
//! accumulation order on a single cache-resident ±1 scratch row, so
//! engine logits are **bit-identical** to the training-stack forward —
//! the parity tests in `rust/tests/native_engine.rs` assert exact
//! equality, not tolerance.

use crate::coordinator::{read_records, CheckpointError, Record};
use crate::nn::{Layer, ParamRef};
use crate::tensor::{BitMatrix, Tensor};
use std::fmt;

/// Error building or loading a frozen model.
#[derive(Debug)]
pub struct EngineError {
    pub msg: String,
}

impl EngineError {
    pub(crate) fn new(msg: impl Into<String>) -> Self {
        EngineError { msg: msg.into() }
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "engine error: {}", self.msg)
    }
}

impl std::error::Error for EngineError {}

impl From<CheckpointError> for EngineError {
    fn from(e: CheckpointError) -> Self {
        EngineError::new(e.to_string())
    }
}

/// One frozen Boolean layer: weights + optional ±1 bias, fused with its
/// threshold activation.
pub struct PackedLayer {
    /// Packed weights, `n_out` rows × `n_in` bits.
    pub weights: BitMatrix,
    /// Optional Boolean bias (1 × n_out) in the ±1 embedding.
    pub bias: Option<BitMatrix>,
    /// Activation threshold: τ plus the centered running-mean shift when
    /// the training-time activation was `ThresholdAct::centered`.
    pub threshold: f32,
    /// Optional validity lane-mask (`wpr` packed words shared by every
    /// batch row): zero lanes are the three-valued 𝕄 zero and contribute
    /// nothing to the pre-activation count.
    pub input_mask: Option<Vec<u64>>,
}

impl PackedLayer {
    /// Fused forward: packed bits in, packed bits out.
    pub fn apply(&self, x: &BitMatrix) -> BitMatrix {
        let mut out = BitMatrix::zeros(0, 0);
        self.apply_into(x, &mut out);
        out
    }

    /// [`Self::apply`] into a reusable output matrix (the engine's
    /// ping-pong activation buffers — no allocation on the serving path).
    pub fn apply_into(&self, x: &BitMatrix, out: &mut BitMatrix) {
        match &self.input_mask {
            Some(m) => x.xnor_threshold_masked_into(
                &self.weights,
                m,
                self.bias.as_ref(),
                self.threshold,
                out,
            ),
            None => x.xnor_threshold_into(&self.weights, self.bias.as_ref(), self.threshold, out),
        }
    }
}

/// Reusable per-caller buffers for [`PackedMlp::forward_bits_into`]: two
/// ping-pong packed activation matrices, the FP head's decoded ±1 scratch
/// row and the logits tensor. One instance per serving worker makes the
/// steady-state batch path allocation-free; the engine itself stays
/// stateless (`&self` forwards), so sharing one `PackedMlp` across
/// workers is still safe.
pub struct EngineScratch {
    ping: BitMatrix,
    pong: BitMatrix,
    row: Vec<f32>,
    /// Logits of the last forward (B × d_out).
    pub logits: Tensor,
}

impl EngineScratch {
    pub fn new() -> Self {
        EngineScratch {
            ping: BitMatrix::zeros(0, 0),
            pong: BitMatrix::zeros(0, 0),
            row: Vec::new(),
            logits: Tensor::zeros(&[0]),
        }
    }
}

impl Default for EngineScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// A frozen Boolean MLP ready for serving: Boolean interior + FP head.
///
/// Thread-safe by construction — `forward_*` take `&self` and keep no
/// cache, so one instance can be shared across a worker pool (see
/// `runtime::serve`).
pub struct PackedMlp {
    /// Boolean interior, in forward order.
    pub layers: Vec<PackedLayer>,
    /// FP head weights (d_out × d_last).
    pub head_w: Tensor,
    /// FP head bias (d_out).
    pub head_b: Tensor,
}

impl PackedMlp {
    /// Input width in bits.
    pub fn d_in(&self) -> usize {
        self.layers.first().map(|l| l.weights.cols).unwrap_or_else(|| self.head_w.cols())
    }

    /// Number of output logits.
    pub fn d_out(&self) -> usize {
        self.head_w.rows()
    }

    /// Total Boolean weight bits (the "model size" of the energy story:
    /// 1 bit per interior parameter).
    pub fn param_bits(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.weights.rows * l.weights.cols + l.bias.as_ref().map(|b| b.cols).unwrap_or(0)
            })
            .sum()
    }

    /// Load a frozen model from a [`crate::coordinator::save_model`]
    /// checkpoint.
    pub fn load(path: &str) -> Result<Self, EngineError> {
        let records = read_records(path)?;
        Self::from_records(&records)
    }

    /// Freeze a live model (e.g. fresh out of the trainer) without a disk
    /// round-trip. The layer must expose `boolean_mlp`-style parameters:
    /// `*.weight` / `*.bias` Boolean records, one FP `*.w`/`*.b` head.
    /// For conv/residual models use `runtime::PackedGraph::from_layer`.
    pub fn from_layer(model: &mut dyn Layer) -> Result<Self, EngineError> {
        Self::from_records(&layer_records(model))
    }

    /// Build from parsed checkpoint records (the frozen-model format).
    pub fn from_records(records: &[Record]) -> Result<Self, EngineError> {
        let mut layers: Vec<(String, PackedLayer)> = Vec::new();
        let mut head_w: Option<Vec<f32>> = None;
        let mut head_b: Option<Vec<f32>> = None;
        let mut shifts: Vec<(Option<usize>, f32)> = Vec::new();
        for rec in records {
            match rec {
                Record::Bool { name, rows, cols, words } => {
                    if let Some(prefix) = name.strip_suffix(".weight") {
                        if *rows == 0 || *cols == 0 {
                            return Err(EngineError::new(format!(
                                "layer '{name}' has degenerate shape {rows}x{cols}"
                            )));
                        }
                        layers.push((
                            prefix.to_string(),
                            PackedLayer {
                                weights: BitMatrix::from_words(*rows, *cols, words.clone()),
                                bias: None,
                                threshold: 0.0,
                                input_mask: None,
                            },
                        ));
                    } else if let Some(prefix) = name.strip_suffix(".bias") {
                        let (_, layer) = layers
                            .iter_mut()
                            .find(|(p, _)| p.as_str() == prefix)
                            .ok_or_else(|| {
                                EngineError::new(format!("bias '{name}' has no matching weight"))
                            })?;
                        if *rows != 1 || *cols != layer.weights.rows {
                            return Err(EngineError::new(format!(
                                "bias '{name}': shape {rows}x{cols} vs {} outputs",
                                layer.weights.rows
                            )));
                        }
                        layer.bias = Some(BitMatrix::from_words(1, *cols, words.clone()));
                    } else {
                        return Err(EngineError::new(format!(
                            "unsupported Boolean record '{name}': the linear-stack loader only \
                             understands BoolLinear parameters (*.weight / *.bias)"
                        )));
                    }
                }
                Record::Real { name, data } => {
                    if name.ends_with(".w") {
                        if head_w.is_some() {
                            return Err(EngineError::new(
                                "more than one FP weight tensor — the native engine serves \
                                 Boolean-linear stacks with a single FP head",
                            ));
                        }
                        head_w = Some(data.clone());
                    } else if name.ends_with(".b") {
                        if head_b.is_some() {
                            return Err(EngineError::new("more than one FP bias tensor"));
                        }
                        head_b = Some(data.clone());
                    } else {
                        return Err(EngineError::new(format!(
                            "unsupported FP record '{name}': the linear-stack loader expects \
                             exactly one *.w / *.b head (FP conv/interior layers need the \
                             graph executor)"
                        )));
                    }
                }
                Record::Buffer { name, data } => {
                    if let Some(prefix) = name.strip_suffix(".running_mean") {
                        if data.is_empty() {
                            return Err(EngineError::new(format!("empty buffer '{name}'")));
                        }
                        shifts.push((trailing_index(prefix), data[0]));
                    } else {
                        return Err(EngineError::new(format!(
                            "unsupported buffer '{name}' — BN/stat-carrying architectures are \
                             not servable by the linear-stack loader; load the checkpoint with \
                             `PackedGraph::load` instead (DESIGN.md §Packed-Graph-Executor)"
                        )));
                    }
                }
                // Optimizer-state records (training snapshots from
                // `save_training`): irrelevant to a frozen server. The
                // architecture record belongs to the graph executor.
                Record::OptimBool { .. }
                | Record::OptimAdam { .. }
                | Record::Meta { .. }
                | Record::Arch { .. } => {}
            }
        }
        if layers.is_empty() {
            return Err(EngineError::new("no Boolean layers in checkpoint"));
        }
        // threshold shifts: by parsed layer index when available, else in
        // order of appearance.
        for (slot, (idx, shift)) in shifts.iter().enumerate() {
            let i = idx.unwrap_or(slot);
            let n_layers = layers.len();
            let layer = layers.get_mut(i).ok_or_else(|| {
                EngineError::new(format!(
                    "running_mean buffer maps to layer {i} but the model has {n_layers} layers"
                ))
            })?;
            layer.1.threshold += *shift;
        }
        // validate the layer chain
        for w in layers.windows(2) {
            let (a, b) = (&w[0].1.weights, &w[1].1.weights);
            if b.cols != a.rows {
                return Err(EngineError::new(format!(
                    "layer chain mismatch: {} outputs feed a fan-in of {}",
                    a.rows, b.cols
                )));
            }
        }
        let d_last = layers.last().map(|(_, l)| l.weights.rows).unwrap();
        let head_w = head_w.ok_or_else(|| EngineError::new("missing FP head weights (*.w)"))?;
        let head_b = head_b.ok_or_else(|| EngineError::new("missing FP head bias (*.b)"))?;
        if head_w.is_empty() || head_w.len() % d_last != 0 {
            return Err(EngineError::new(format!(
                "head weight len {} not a multiple of last hidden width {d_last}",
                head_w.len()
            )));
        }
        let d_out = head_w.len() / d_last;
        if head_b.len() != d_out {
            return Err(EngineError::new(format!(
                "head bias len {} vs {d_out} outputs",
                head_b.len()
            )));
        }
        Ok(PackedMlp {
            layers: layers.into_iter().map(|(_, l)| l).collect(),
            head_w: Tensor::from_vec(&[d_out, d_last], head_w),
            head_b: Tensor::from_vec(&[d_out], head_b),
        })
    }

    /// Forward on packed inputs (B × d_in bits) → logits (B × d_out).
    /// Boolean layers stay packed end to end; only the FP head produces
    /// f32, via a single reused scratch row.
    pub fn forward_bits(&self, x: &BitMatrix) -> Tensor {
        let mut scratch = EngineScratch::new();
        self.forward_bits_into(x, &mut scratch);
        scratch.logits
    }

    /// [`Self::forward_bits`] against caller-owned [`EngineScratch`]
    /// buffers; the logits land in `scratch.logits`. Steady-state serving
    /// (one scratch per worker) performs zero allocations per batch.
    pub fn forward_bits_into(&self, x: &BitMatrix, scratch: &mut EngineScratch) {
        assert_eq!(x.cols, self.d_in(), "input width {} vs model d_in {}", x.cols, self.d_in());
        match self.layers.split_first() {
            None => self.head_forward_into(x, &mut scratch.row, &mut scratch.logits),
            Some((first, rest)) => {
                first.apply_into(x, &mut scratch.ping);
                let mut cur_is_ping = true;
                for l in rest {
                    if cur_is_ping {
                        l.apply_into(&scratch.ping, &mut scratch.pong);
                    } else {
                        l.apply_into(&scratch.pong, &mut scratch.ping);
                    }
                    cur_is_ping = !cur_is_ping;
                }
                let cur = if cur_is_ping { &scratch.ping } else { &scratch.pong };
                self.head_forward_into(cur, &mut scratch.row, &mut scratch.logits);
            }
        }
    }

    /// Convenience: pack real-valued features (`v ≥ 0 ⇒ T`, the
    /// `BitMatrix::from_pm1` convention) and run [`Self::forward_bits`].
    pub fn forward_f32(&self, x: &Tensor) -> Tensor {
        let b = x.shape[0];
        let cols: usize = x.shape[1..].iter().product();
        let flat = x.view(&[b, cols]);
        self.forward_bits(&BitMatrix::from_pm1(&flat))
    }

    /// Per-row argmax class ids for a packed batch.
    pub fn predict(&self, x: &BitMatrix) -> Vec<usize> {
        self.forward_bits(x).argmax_rows()
    }

    /// FP head on the last packed activation — see [`fp_head_bits`].
    fn head_forward_into(&self, bits: &BitMatrix, row: &mut Vec<f32>, out: &mut Tensor) {
        fp_head_bits(bits, &self.head_w, &self.head_b, row, out);
    }
}

/// FP head over packed activations, shared by [`PackedMlp`] and the graph
/// executor's `FpHead` op. Replays the exact `Tensor::matmul_bt`
/// accumulation order (4 independent partial sums + tail) over one
/// decoded ±1 scratch row, then adds the bias — so the result is
/// bit-identical to `nn::Linear::forward` on the unpacked activations.
pub(crate) fn fp_head_bits(
    bits: &BitMatrix,
    head_w: &Tensor,
    head_b: &Tensor,
    row: &mut Vec<f32>,
    out: &mut Tensor,
) {
    let b = bits.rows;
    let (n_out, n_in) = (head_w.rows(), head_w.cols());
    assert_eq!(bits.cols, n_in, "head fan-in {} vs {}", bits.cols, n_in);
    out.resize_to(&[b, n_out]);
    row.resize(n_in, 0.0);
    let k4 = n_in - n_in % 4;
    for i in 0..b {
        bits.decode_pm1_row(i, row);
        let orow = &mut out.data[i * n_out..(i + 1) * n_out];
        for (j, o) in orow.iter_mut().enumerate() {
            let wrow = &head_w.data[j * n_in..(j + 1) * n_in];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut p = 0;
            while p < k4 {
                s0 += row[p] * wrow[p];
                s1 += row[p + 1] * wrow[p + 1];
                s2 += row[p + 2] * wrow[p + 2];
                s3 += row[p + 3] * wrow[p + 3];
                p += 4;
            }
            let mut acc = (s0 + s1) + (s2 + s3);
            for q in k4..n_in {
                acc += row[q] * wrow[q];
            }
            *o = acc + head_b.data[j];
        }
    }
}

/// Snapshot a live model's parameters, buffers and (when describable)
/// architecture into in-memory checkpoint records — the same record set
/// `save_model` writes (the arch record comes from the shared
/// [`crate::coordinator::arch_record`] so the freeze and save paths can
/// never diverge), used by the `from_layer` constructors to freeze
/// without a disk round-trip.
pub(crate) fn layer_records(model: &mut dyn Layer) -> Vec<Record> {
    let mut records = Vec::new();
    records.extend(crate::coordinator::arch_record(model));
    for p in model.params() {
        match p {
            ParamRef::Bool { name, bits, .. } => records.push(Record::Bool {
                name,
                rows: bits.rows,
                cols: bits.cols,
                words: bits.words.to_vec(),
            }),
            ParamRef::Real { name, w, .. } => {
                records.push(Record::Real { name, data: w.data.clone() })
            }
        }
    }
    for (name, buf) in model.buffers() {
        records.push(Record::Buffer { name, data: buf.clone() });
    }
    records
}

/// Parse a trailing decimal index from a layer-name prefix ("act3" → 3).
fn trailing_index(prefix: &str) -> Option<usize> {
    let digits: String =
        prefix.chars().rev().take_while(|c| c.is_ascii_digit()).collect::<Vec<_>>()
            .into_iter()
            .rev()
            .collect();
    if digits.is_empty() {
        None
    } else {
        digits.parse().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{boolean_mlp, MlpConfig};
    use crate::nn::Value;
    use crate::util::Rng;

    #[test]
    fn from_layer_matches_reference_eval() {
        let cfg = MlpConfig { d_in: 70, hidden: vec![33, 17], d_out: 5, tanh_scale: true };
        let mut rng = Rng::new(3);
        let mut model = boolean_mlp(&cfg, &mut rng);
        let engine = PackedMlp::from_layer(&mut model).expect("engine");
        assert_eq!(engine.d_in(), 70);
        assert_eq!(engine.d_out(), 5);
        assert_eq!(engine.param_bits(), 70 * 33 + 33 * 17);
        let x = Tensor::rand_pm1(&[6, 70], &mut rng);
        let want = model.forward(Value::bit_from_pm1(&x), false).expect_f32("ref");
        let got = engine.forward_f32(&x);
        assert_eq!(got.max_abs_diff(&want), 0.0, "exact parity required");
    }

    #[test]
    fn scratch_reuse_across_batches_matches_fresh_forward() {
        // One EngineScratch reused for shrinking/growing batches must give
        // exactly the allocating path's logits (the serve-worker pattern).
        let cfg = MlpConfig { d_in: 70, hidden: vec![33, 17], d_out: 5, tanh_scale: true };
        let mut rng = Rng::new(9);
        let mut model = boolean_mlp(&cfg, &mut rng);
        let engine = PackedMlp::from_layer(&mut model).expect("engine");
        let mut scratch = EngineScratch::new();
        for b in [8usize, 3, 12, 1] {
            let x = Tensor::rand_pm1(&[b, 70], &mut rng);
            let packed = crate::tensor::BitMatrix::from_pm1(&x);
            engine.forward_bits_into(&packed, &mut scratch);
            let want = engine.forward_bits(&packed);
            assert_eq!(scratch.logits.max_abs_diff(&want), 0.0, "batch {b}");
        }
    }

    #[test]
    fn rejects_unsupported_architectures() {
        // A BN-style buffer must be refused with a clear message, not
        // silently dropped.
        let records = vec![
            Record::Bool { name: "bl0.weight".into(), rows: 4, cols: 8, words: vec![0; 4] },
            Record::Real { name: "head.w".into(), data: vec![0.0; 8] },
            Record::Real { name: "head.b".into(), data: vec![0.0; 2] },
            Record::Buffer { name: "bn0.running_var".into(), data: vec![1.0] },
        ];
        let err = PackedMlp::from_records(&records).unwrap_err();
        assert!(err.to_string().contains("not servable"), "{err}");
    }

    #[test]
    fn rejects_broken_layer_chain() {
        let records = vec![
            Record::Bool { name: "bl0.weight".into(), rows: 4, cols: 8, words: vec![0; 4] },
            Record::Bool { name: "bl1.weight".into(), rows: 3, cols: 5, words: vec![0; 3] },
            Record::Real { name: "head.w".into(), data: vec![0.0; 6] },
            Record::Real { name: "head.b".into(), data: vec![0.0; 2] },
        ];
        let err = PackedMlp::from_records(&records).unwrap_err();
        assert!(err.to_string().contains("chain mismatch"), "{err}");
    }

    #[test]
    fn centered_running_mean_shifts_threshold() {
        let records = vec![
            Record::Bool { name: "bl0.weight".into(), rows: 4, cols: 8, words: vec![0; 4] },
            Record::Real { name: "head.w".into(), data: vec![0.0; 8] },
            Record::Real { name: "head.b".into(), data: vec![0.0; 2] },
            Record::Buffer { name: "act0.running_mean".into(), data: vec![1.5] },
        ];
        let engine = PackedMlp::from_records(&records).unwrap();
        assert_eq!(engine.layers[0].threshold, 1.5);
    }
}
