//! Architecture-agnostic packed graph executor (DESIGN.md
//! §Packed-Graph-Executor): serves ANY describable model — conv stacks,
//! residual nets, MLPs — from a `save_model` checkpoint with no
//! model-specific loader code.
//!
//! The checkpoint's `Record::Arch` (the [`crate::nn::Layer::describe`] op
//! list plus the recorded input shape) is compiled into a small op IR,
//! [`PackedOp`]. The Boolean interior runs entirely on the packed
//! XNOR/popcount kernels: `Conv2d` is bit-level im2col +
//! [`BitMatrix::xnor_gemm_masked_into`] + a fused per-channel threshold
//! that packs the integer counts straight back to bits, and `Residual`
//! sums branch popcounts so the next threshold re-signs their majority.
//! Every threshold re-pack (fused conv, per-channel, scalar) compares and
//! packs through the runtime-dispatched SIMD backend
//! ([`simd::pack_cmp_into`], DESIGN.md §SIMD-Backend) — 8 f32 compares
//! per AVX2 vector, one movemask per 8 bits, bit-exact vs scalar.
//!
//! # BatchNorm folding (zero ops at serve time)
//!
//! After a Boolean conv (and through `MaxPool`, which preserves
//! integrality) the pre-activations are *integers* in `[-fanin, fanin]`.
//! Eval-mode BN followed by a threshold activation is then a monotone
//! predicate over the integers: `fire(s) = γ·(s−μ)/√(σ²+ε) + β ≥ τ`.
//! At load time the compiler binary-searches the integer crossover of
//! that predicate — **replaying the training stack's exact f32
//! arithmetic** (same [`BN_EPS`], same operation order) — and stores one
//! integer threshold per channel (plus a flip flag for γ < 0). The serve
//! path then does a single compare per output unit and is bit-identical
//! to `BatchNorm2d` → `ThresholdAct` eval, with BN costing zero
//! operations. When the input is NOT integer (the FP stem), BN stays an
//! explicit per-channel affine op instead, still replaying the exact
//! training arithmetic.
//!
//! # Back-compat
//!
//! Checkpoints without a `Record::Arch` (pre-arch files, or
//! `save_checkpoint` param-only files) fall back to the [`PackedMlp`]
//! name-convention loader and are wrapped into a linear-only graph via
//! `From<PackedMlp>`, so every previously servable checkpoint keeps
//! loading unchanged.

use super::engine::{fp_head_bits, layer_records, EngineError, PackedLayer, PackedMlp};
use super::passes::{self, PassConfig, PassStats};
use crate::coordinator::{read_records, Record};
use crate::nn::{packed_im2col, Layer, LayerDesc, BN_EPS};
use crate::tensor::{simd, BitMatrix, Tensor};
use std::collections::{HashMap, HashSet};

/// Per-output-channel threshold on integer pre-activation counts, with
/// BN already folded in (see the module docs). `flip[c]` marks channels
/// whose folded BN slope is negative: the bit fires when `s ≤ thr[c]`
/// instead of `s ≥ thr[c]`.
#[derive(Debug, Clone)]
pub struct FusedThreshold {
    pub thr: Vec<f32>,
    pub flip: Vec<bool>,
}

/// Pooling folded into a Boolean conv by the fusion pass
/// ([`passes::PassConfig::fuse`]): the op gathers pooled values straight
/// out of the GEMM accumulator instead of materializing the
/// full-resolution count map, replaying the standalone pool op's exact
/// compare/sum order so the result is bit-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolSpec {
    /// k×k max pooling, stride k (exact `MaxPool2d` replay on counts).
    Max(usize),
    /// Global average pooling NCHW → (N, C), f32.
    GlobalAvg,
}

/// Boolean conv op: bit-im2col + masked XNOR GEMM (+ optional fused
/// pooling and/or fused per-channel threshold that re-packs straight to
/// bits).
pub struct PackedConv {
    pub name: String,
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// Packed weights, `c_out` rows × `c_in·k·k` bits.
    pub weights: BitMatrix,
    /// When present the op emits packed bits; when absent it emits f32
    /// counts (NCHW, pooled if `pool` is set) for a downstream
    /// pool/residual/threshold.
    pub fused: Option<FusedThreshold>,
    /// Pooling applied to the counts before the (optional) fused
    /// threshold. Set only by the fusion pass.
    pub pool: Option<PoolSpec>,
    /// Index into the per-graph conv scratch pool (im2col patches + the
    /// geometry-cached validity mask).
    scratch_id: usize,
}

/// Conv geometry and the padded-border fallback carried by a LUT-folded
/// conv op ([`PackedLut`]). The truth tables are built mask-independent
/// (every support bit assumed valid); output positions whose im2col
/// validity mask is not all-ones — only possible when `pad > 0` — replay
/// the masked popcount per lane from `weights`/`thr`/`flip` instead, so
/// the op stays bit-identical to [`PackedConv`] at every border.
pub struct LutConv {
    pub name: String,
    pub c_in: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// Original packed weights (`c_out` rows × `c_in·k·k` bits), kept
    /// for the padded-border lane replay.
    pub weights: BitMatrix,
    pub thr: Vec<f32>,
    pub flip: Vec<bool>,
    scratch_id: usize,
}

/// A Boolean layer folded into per-neuron truth tables by the `lut`
/// pass (DESIGN.md §LUT-Folding, the NullaNet direction): each output
/// neuron of a fan-in-K layer is a Boolean function of K input bits,
/// enumerated at compile time into a `2^K`-bit table that replays the
/// layer's exact integer-count + f32-compare arithmetic (bias, shared
/// input mask and per-channel BN-folded threshold/flip included). At
/// serve time 64 lanes evaluate per word through the bitslice mux
/// cascade ([`simd::lut_eval_word`]) — no XNOR GEMM, no popcounts.
pub struct PackedLut {
    /// Fan-in K: input bits per output neuron (= the layer's full
    /// fan-in; Boolean layers are dense, so every neuron reads all K).
    pub fanin: usize,
    /// Output neurons (linear rows, or conv output channels).
    pub n_out: usize,
    /// Words per truth table: `max(1, 2^fanin / 64)`.
    pub tw: usize,
    /// `n_out × tw` table words, neuron-major, LSB-first bit order.
    pub tables: Vec<u64>,
    /// Conv geometry + border fallback when this folds a conv;
    /// `None` for a linear layer.
    pub conv: Option<LutConv>,
}

/// Truth-table word count for a fan-in-K neuron.
fn table_words(fanin: usize) -> usize {
    (1usize << fanin).div_ceil(64)
}

impl PackedLut {
    /// Fold a fused Boolean linear layer ([`PackedLayer`]) into truth
    /// tables, replaying `pack_threshold_row`'s exact arithmetic:
    /// `s = base − 2·popc((x ⊕ w) & mask) + bias`, fire when
    /// `(s as f32) >= threshold` — with `base` the tail-tolerant valid
    /// count of the shared input mask (all of `fanin` when unmasked).
    pub fn from_linear(l: &PackedLayer) -> Self {
        Self::from_linear_thr(l, l.threshold)
    }

    /// [`Self::from_linear`] with an explicit threshold — the `lut` pass
    /// uses this to fold a naive `LinearCounts` + scalar `Threshold`
    /// pair directly (the pair computes the identical function).
    pub(crate) fn from_linear_thr(l: &PackedLayer, thr: f32) -> Self {
        let fanin = l.weights.cols;
        let n_out = l.weights.rows;
        assert!(
            (1..=passes::LUT_HARD_MAX_FANIN).contains(&fanin),
            "lut fold: fan-in {fanin} outside 1..={}",
            passes::LUT_HARD_MAX_FANIN
        );
        let tw = table_words(fanin);
        let tail = (1u64 << fanin) - 1;
        // replay xnor_threshold_masked_into's tail-tolerant valid count
        let mask = l.input_mask.as_ref().map(|m| m[0] & tail).unwrap_or(tail);
        let base = if l.input_mask.is_some() { mask.count_ones() as i64 } else { fanin as i64 };
        let mut tables = vec![0u64; n_out * tw];
        for j in 0..n_out {
            let w = l.weights.row(j)[0];
            let b: i64 = match &l.bias {
                Some(bm) => {
                    if bm.get(0, j) {
                        1
                    } else {
                        -1
                    }
                }
                None => 0,
            };
            let trow = &mut tables[j * tw..(j + 1) * tw];
            for idx in 0..(1usize << fanin) {
                let diff = (idx as u64 ^ w) & mask;
                let s = base - 2 * diff.count_ones() as i64 + b;
                if (s as f32) >= thr {
                    trow[idx / 64] |= 1u64 << (idx % 64);
                }
            }
        }
        PackedLut { fanin, n_out, tw, tables, conv: None }
    }

    /// Fold a Boolean conv into per-channel truth tables under the given
    /// per-channel threshold/flip epilogue (the conv's own fused
    /// epilogue, or a downstream standalone `Threshold`'s — both compare
    /// the same masked-GEMM counts). Tables assume every im2col tap is
    /// valid; padded borders replay per lane at serve time.
    pub(crate) fn from_conv(c: &PackedConv, ft: &FusedThreshold) -> Self {
        let fanin = c.weights.cols; // c_in·k·k
        let n_out = c.c_out;
        assert!(
            (1..=passes::LUT_HARD_MAX_FANIN).contains(&fanin),
            "lut fold: fan-in {fanin} outside 1..={}",
            passes::LUT_HARD_MAX_FANIN
        );
        assert_eq!(ft.thr.len(), n_out, "lut fold '{}': threshold width", c.name);
        let tw = table_words(fanin);
        let mut tables = vec![0u64; n_out * tw];
        for j in 0..n_out {
            let w = c.weights.row(j)[0];
            let trow = &mut tables[j * tw..(j + 1) * tw];
            for idx in 0..(1usize << fanin) {
                // all-valid mask row: popc(mask) = fanin, exactly the
                // gemm_masked_rows count for an interior position
                let s = (fanin as i64 - 2 * (idx as u64 ^ w).count_ones() as i64) as f32;
                let fire = if ft.flip[j] { s <= ft.thr[j] } else { s >= ft.thr[j] };
                if fire {
                    trow[idx / 64] |= 1u64 << (idx % 64);
                }
            }
        }
        PackedLut {
            fanin,
            n_out,
            tw,
            tables,
            conv: Some(LutConv {
                name: c.name.clone(),
                c_in: c.c_in,
                k: c.k,
                stride: c.stride,
                pad: c.pad,
                weights: c.weights.clone(),
                thr: ft.thr.clone(),
                flip: ft.flip.clone(),
                scratch_id: c.scratch_id,
            }),
        }
    }

    /// Table storage in bytes (the op's whole parameter footprint for a
    /// linear fold).
    pub fn table_bytes(&self) -> usize {
        self.tables.len() * 8
    }

    /// Serve-time evaluation of a linear fold: packed input
    /// (B × fanin bits) → packed output (B × n_out bits), bit-identical
    /// to [`PackedLayer::apply_into`]. Per 64-row lane group the K input
    /// bit-columns are gathered once and shared by every neuron; each
    /// neuron's eval word (lane = batch row) lands in a 64×64 tile that
    /// one bit transpose turns into row-major output words. `cols`,
    /// `buf` and `tile` are caller scratch ([`GraphScratch`] in the
    /// executor), resized here.
    pub fn apply_linear_into(
        &self,
        x: &BitMatrix,
        out: &mut BitMatrix,
        cols: &mut Vec<u64>,
        buf: &mut Vec<u64>,
        tile: &mut Vec<u64>,
    ) {
        assert!(self.conv.is_none(), "conv folds evaluate through the graph executor");
        assert_eq!(x.cols, self.fanin, "lut fan-in mismatch {} vs {}", x.cols, self.fanin);
        let n = x.rows;
        out.zero_resize(n, self.n_out);
        cols.resize(self.fanin, 0);
        buf.resize(1usize << (self.fanin - 1), 0);
        tile.resize(64, 0);
        for row0 in (0..n).step_by(64) {
            let lanes = (n - row0).min(64);
            for (i, cw) in cols.iter_mut().enumerate() {
                *cw = simd::gather_bit_column(&x.words, x.wpr, row0, lanes, i);
            }
            for j0 in (0..self.n_out).step_by(64) {
                let jn = (self.n_out - j0).min(64);
                for jj in 0..jn {
                    let t = &self.tables[(j0 + jj) * self.tw..(j0 + jj + 1) * self.tw];
                    tile[jj] = simd::lut_eval_word(t, self.fanin, cols, buf);
                }
                tile[jn..64].fill(0);
                let tt: &mut [u64; 64] = tile.as_mut_slice().try_into().unwrap();
                simd::transpose64(tt);
                // j0 is 64-aligned and bits ≥ jn are zero after the
                // transpose, so each deposit is one aligned word OR
                for l in 0..lanes {
                    simd::deposit(out.row_mut(row0 + l), j0, tile[l], jn);
                }
            }
        }
    }
}

/// FP conv (the paper keeps the stem in FP): exact replay of
/// `nn::Conv2d` eval — im2col + `matmul_bt` + bias.
pub struct FpConv {
    pub name: String,
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// (c_out × c_in·k·k).
    pub w: Tensor,
    pub b: Tensor,
}

/// Eval-mode BatchNorm affine, kept explicit only when the input is not
/// integer-valued (otherwise it folds into a [`FusedThreshold`]).
pub struct BnEval {
    pub name: String,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

/// Standalone f32 → bits threshold.
pub enum ThresholdSpec {
    /// Uniform scalar over every element (τ plus the centered shift).
    Scalar(f32),
    /// Per-channel integer thresholds on NCHW counts (BN folded).
    PerChannel(FusedThreshold),
}

/// One executor op. The value flowing between ops is either packed bits
/// (`BitMatrix`, one row per batch element, `C·H·W` flattened columns)
/// or a dense f32 tensor (integer pre-activation counts, or real values
/// around the FP stem/head).
pub enum PackedOp {
    /// Boolean FC fused with its scalar threshold: bits → bits.
    Linear(PackedLayer),
    /// Boolean FC *without* a fused threshold: bits → f32 integer counts
    /// (XNOR GEMM + ±1 bias add). This is the naive decomposition the
    /// compiler emits; the fusion pass folds a following scalar
    /// `Threshold` back into a [`PackedOp::Linear`].
    LinearCounts(PackedLayer),
    /// Boolean conv: bits → bits (fused) or bits → f32 counts.
    Conv2d(PackedConv),
    /// Low-fan-in Boolean layer folded into truth tables by the `lut`
    /// pass: bits → bits, no GEMM (DESIGN.md §LUT-Folding).
    Lut(PackedLut),
    /// FP stem conv: bits (decoded ±1) or f32 → f32.
    FpConv2d(FpConv),
    /// Explicit eval-mode BN (non-integer input only): f32 → f32.
    BatchNorm(BnEval),
    /// Threshold activation: f32 → bits.
    Threshold(ThresholdSpec),
    /// k×k max pooling, stride k, on f32 counts (exact training replay).
    MaxPool { k: usize },
    /// Global average pooling NCHW → (N, C), f32.
    GlobalAvgPool,
    /// Flatten to (batch, features). The compiler elides it (both value
    /// representations are already flat row-major and consumers derive
    /// `(batch, ∏ rest)` themselves); the op evaluates as a plain copy
    /// when present in a hand-built graph.
    Flatten,
    /// Two-branch merge: both branches end on f32 pre-activations which
    /// are summed; the next `Threshold` re-signs the majority of the
    /// combined branch popcounts. Empty `shortcut` = identity.
    Residual { main: Vec<Node>, shortcut: Vec<Node>, main_out: usize, short_out: usize },
    /// FP classifier head: bits (single decoded scratch row, exact
    /// `matmul_bt` replay) or f32 (direct `matmul_bt`) → logits.
    FpHead { w: Tensor, b: Tensor },
}

impl PackedOp {
    /// Short op name for summaries and errors.
    pub fn kind(&self) -> &'static str {
        match self {
            PackedOp::Linear(_) => "Linear",
            PackedOp::LinearCounts(_) => "LinearCounts",
            PackedOp::Conv2d(c) => match (&c.pool, &c.fused) {
                (None, None) => "Conv2d",
                (None, Some(_)) => "Conv2d+thr",
                (Some(_), None) => "Conv2d+pool",
                (Some(_), Some(_)) => "Conv2d+pool+thr",
            },
            PackedOp::Lut(l) => {
                if l.conv.is_some() {
                    "Conv2dLut"
                } else {
                    "Lut"
                }
            }
            PackedOp::FpConv2d(_) => "FpConv2d",
            PackedOp::BatchNorm(_) => "BatchNorm",
            PackedOp::Threshold(_) => "Threshold",
            PackedOp::MaxPool { .. } => "MaxPool",
            PackedOp::GlobalAvgPool => "GlobalAvgPool",
            PackedOp::Flatten => "Flatten",
            PackedOp::Residual { .. } => "Residual",
            PackedOp::FpHead { .. } => "FpHead",
        }
    }
}

/// One dataflow node: `op` reads activation slot `src` and writes slot
/// `dst`. The compiler assigns slots in SSA order (`src < dst`, each
/// slot written once); after the liveness pass recolors them
/// (`BOLD_GRAPH_PASSES`), slots are reused and only `src ≠ dst` (plus
/// merge-inputs ≠ merge-output for `Residual`) is guaranteed — which is
/// exactly what the executor needs to take the destination slot out of
/// the pool while reading the sources.
pub struct Node {
    pub op: PackedOp,
    pub src: usize,
    pub dst: usize,
}

/// A frozen model compiled to packed serving ops. Thread-safe by
/// construction: `forward_*` take `&self` and all mutable state lives in
/// the caller's [`GraphScratch`], so one instance is shared across the
/// whole worker pool (`runtime::serve`).
pub struct PackedGraph {
    pub nodes: Vec<Node>,
    /// Non-batch input dims: `[C, H, W]` for conv models, `[D]` flat.
    pub input_shape: Vec<usize>,
    pub(crate) n_slots: usize,
    n_convs: usize,
    d_out: usize,
    /// What the pass pipeline did (see [`PassStats`]).
    pub(crate) pass_stats: PassStats,
}

// ---------------------------------------------------------------------------
// scratch
// ---------------------------------------------------------------------------

/// One activation slot: both representations are kept allocated so a
/// shrinking/growing batch reuses the buffers; `is_bits` says which one
/// the producing op filled.
struct Slot {
    bits: BitMatrix,
    f: Tensor,
    shape: Vec<usize>,
    is_bits: bool,
}

impl Slot {
    fn new() -> Self {
        Slot {
            bits: BitMatrix::zeros(0, 0),
            f: Tensor::zeros(&[0]),
            shape: Vec::new(),
            is_bits: false,
        }
    }

    fn set_shape(&mut self, dims: &[usize]) {
        self.shape.clear();
        self.shape.extend_from_slice(dims);
    }

    fn dims4(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "op needs NCHW input, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    fn cols(&self) -> usize {
        self.shape[1..].iter().product()
    }

    /// Bytes currently held by this slot's retained buffers.
    fn bytes(&self) -> usize {
        self.bits.words.len() * 8 + self.f.data.len() * 4
    }
}

/// The executor takes a node's destination slot out of the pool with
/// `mem::take` while it reads the sources, so `Slot` needs a cheap
/// (allocation-free) empty value.
impl Default for Slot {
    fn default() -> Self {
        Slot::new()
    }
}

/// Per-conv-op reusable buffers: bit-im2col patches and the validity
/// mask, which depends only on geometry and is rebuilt only when the
/// input geometry changes (same caching as the training `BoolConv2d`).
struct ConvScratch {
    patches: BitMatrix,
    mask: BitMatrix,
    geom: Option<(usize, usize, usize)>,
}

impl ConvScratch {
    fn new() -> Self {
        ConvScratch { patches: BitMatrix::zeros(0, 0), mask: BitMatrix::zeros(0, 0), geom: None }
    }
}

/// Reusable buffers for [`PackedOp::Lut`] evaluation: the K gathered
/// input bit-columns, the mux-cascade fold scratch (`2^(K−1)` words) and
/// the 64×64 transpose tile of the linear variant. Shared by every LUT
/// op in the graph — sized by the widest one.
#[derive(Default)]
struct LutScratch {
    cols: Vec<u64>,
    buf: Vec<u64>,
    tile: Vec<u64>,
}

/// Reusable per-caller buffers for [`PackedGraph::forward_bits_into`]:
/// one activation slot per graph node (sized from the graph on first
/// use), per-conv im2col scratch, the GEMM count buffer, the FP head's
/// decoded ±1 row and the logits. One instance per serving worker makes
/// the steady-state batch path allocation-free outside the FP stem/head.
pub struct GraphScratch {
    slots: Vec<Slot>,
    convs: Vec<ConvScratch>,
    /// (N·OH·OW × Cout) GEMM output shared by all conv ops.
    counts: Tensor,
    /// One gathered channel column of `counts` (length OH·OW), staged
    /// contiguously so the fused threshold re-pack runs through the
    /// SIMD compare kernel ([`simd::pack_cmp_into`]).
    col: Vec<f32>,
    /// Decoded ±1 input for the FP stem.
    fp_in: Tensor,
    /// FP head scratch row.
    row: Vec<f32>,
    /// Column-gather + table-fold scratch for LUT-folded ops.
    lut: LutScratch,
    /// Logits of the last forward (B × d_out).
    pub logits: Tensor,
}

impl GraphScratch {
    pub fn new() -> Self {
        GraphScratch {
            slots: Vec::new(),
            convs: Vec::new(),
            counts: Tensor::zeros(&[0]),
            col: Vec::new(),
            fp_in: Tensor::zeros(&[0]),
            row: Vec::new(),
            lut: LutScratch::default(),
            logits: Tensor::zeros(&[0]),
        }
    }

    fn ensure(&mut self, n_slots: usize, n_convs: usize) {
        while self.slots.len() < n_slots {
            self.slots.push(Slot::new());
        }
        while self.convs.len() < n_convs {
            self.convs.push(ConvScratch::new());
        }
    }

    /// Total bytes currently held by the retained buffers: activation
    /// slots, conv im2col patches/masks, the shared GEMM accumulator and
    /// the FP stem/head staging. Buffers only grow across forwards, so
    /// after a steady-state batch this is the worker's peak scratch
    /// footprint — surfaced per worker in the HTTP `/stats` endpoint and
    /// the serve benches.
    pub fn scratch_bytes(&self) -> usize {
        let slots: usize = self.slots.iter().map(Slot::bytes).sum();
        let convs: usize = self
            .convs
            .iter()
            .map(|c| (c.patches.words.len() + c.mask.words.len()) * 8)
            .sum();
        let f32s = self.counts.data.len()
            + self.col.len()
            + self.fp_in.data.len()
            + self.row.len()
            + self.logits.data.len();
        let lut = (self.lut.cols.len() + self.lut.buf.len() + self.lut.tile.len()) * 8;
        slots + convs + f32s * 4 + lut
    }
}

impl Default for GraphScratch {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// public API
// ---------------------------------------------------------------------------

impl PackedGraph {
    /// Input width in bits (∏ input dims).
    pub fn d_in(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Number of output logits.
    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Number of activation slots a [`GraphScratch`] allocates for this
    /// graph — the recolored (live) count when the liveness pass ran.
    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// What the pass pipeline did to this graph at load time.
    pub fn pass_stats(&self) -> &PassStats {
        &self.pass_stats
    }

    /// Total Boolean weight bits across the graph (the 1-bit-per-weight
    /// model size of the energy story).
    pub fn param_bits(&self) -> usize {
        fn bits(nodes: &[Node]) -> usize {
            nodes
                .iter()
                .map(|n| match &n.op {
                    PackedOp::Linear(l) | PackedOp::LinearCounts(l) => {
                        l.weights.rows * l.weights.cols
                            + l.bias.as_ref().map(|b| b.cols).unwrap_or(0)
                    }
                    PackedOp::Conv2d(c) => c.weights.rows * c.weights.cols,
                    // a LUT fold's serving parameters are its tables
                    // (plus the border-fallback weights for convs)
                    PackedOp::Lut(l) => {
                        l.tables.len() * 64
                            + l.conv.as_ref().map(|g| g.weights.rows * g.weights.cols).unwrap_or(0)
                    }
                    PackedOp::Residual { main, shortcut, .. } => bits(main) + bits(shortcut),
                    _ => 0,
                })
                .sum()
        }
        bits(&self.nodes)
    }

    /// Total op count, including nested residual branches.
    pub fn num_ops(&self) -> usize {
        fn count(nodes: &[Node]) -> usize {
            nodes
                .iter()
                .map(|n| match &n.op {
                    PackedOp::Residual { main, shortcut, .. } => 1 + count(main) + count(shortcut),
                    _ => 1,
                })
                .sum()
        }
        count(&self.nodes)
    }

    /// One-line op chain, e.g. `FpConv2d → Threshold → Conv2d+thr → …`,
    /// plus a trailing pass report (fused/elided op counts, slot
    /// compaction) so `serve-native`/`serve-http` startup logs show what
    /// the compiler did.
    pub fn summary(&self) -> String {
        fn fmt(nodes: &[Node]) -> String {
            nodes
                .iter()
                .map(|n| match &n.op {
                    PackedOp::Residual { main, shortcut, .. } => {
                        format!("Residual[{} | {}]", fmt(main), fmt(shortcut))
                    }
                    op => op.kind().to_string(),
                })
                .collect::<Vec<_>>()
                .join(" → ")
        }
        let chain = fmt(&self.nodes);
        let ps = &self.pass_stats;
        let mut tags = Vec::new();
        if ps.fuse {
            tags.push(format!(
                "fuse(thr {}, pool {}, flat {})",
                ps.fused_thresholds, ps.fused_pools, ps.elided_flattens
            ));
        }
        if ps.lut {
            tags.push(format!(
                "lut(ops {}, neurons {}, tables {} B)",
                ps.lut_ops, ps.lut_neurons, ps.lut_table_bytes
            ));
        }
        if ps.liveness {
            tags.push(format!("liveness(slots {} -> {})", ps.raw_slots, ps.live_slots));
        }
        if tags.is_empty() {
            format!("{chain} | passes: off ({} slots)", self.n_slots)
        } else {
            format!("{chain} | passes: {}", tags.join(", "))
        }
    }

    /// Behavioral fingerprint: FNV-1a over the bit patterns of the
    /// logits produced for `n` deterministic seeded probe rows. Two
    /// graphs agree on the fingerprint iff they are bit-exact on the
    /// probe set regardless of how they were compiled (popcount vs LUT,
    /// fused vs not), so the model lifecycle layer
    /// (runtime/lifecycle.rs) uses it to tag promoted versions in
    /// `/v1/models` and promotion reports.
    pub fn behavior_fingerprint(&self, seed: u64, n: usize) -> u64 {
        let mut rng = crate::util::Rng::new(seed);
        let probe = BitMatrix::random(n.max(1), self.d_in(), &mut rng);
        let logits = self.forward_bits(&probe);
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for v in &logits.data {
            for b in v.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }

    /// Load a frozen model from a [`crate::coordinator::save_model`]
    /// checkpoint: compiles the embedded `Record::Arch` when present,
    /// otherwise falls back to the [`PackedMlp`] linear-stack loader.
    pub fn load(path: &str) -> Result<Self, EngineError> {
        let records = read_records(path)?;
        Self::from_records(&records)
    }

    /// Freeze a live model without a disk round-trip. The model should
    /// have been forwarded at least once so its input shape is recorded
    /// (conv graphs need it; plain linear stacks infer `d_in`).
    pub fn from_layer(model: &mut dyn Layer) -> Result<Self, EngineError> {
        Self::from_layer_with(model, PassConfig::from_env())
    }

    /// [`Self::from_layer`] with an explicit pass selection instead of
    /// the `BOLD_GRAPH_PASSES` environment default (tests use this so
    /// pass coverage never depends on — or mutates — process-global
    /// environment state).
    pub fn from_layer_with(
        model: &mut dyn Layer,
        cfg: PassConfig,
    ) -> Result<Self, EngineError> {
        let records = layer_records(model);
        Self::from_records_with(&records, cfg)
    }

    /// Build from parsed checkpoint records, with the pass pipeline
    /// selected by `BOLD_GRAPH_PASSES`.
    pub fn from_records(records: &[Record]) -> Result<Self, EngineError> {
        Self::from_records_with(records, PassConfig::from_env())
    }

    /// [`Self::from_records`] with an explicit pass selection.
    pub fn from_records_with(records: &[Record], cfg: PassConfig) -> Result<Self, EngineError> {
        let arch = records.iter().find_map(|r| match r {
            Record::Arch { input_shape, layers, .. } => Some((input_shape, layers)),
            _ => None,
        });
        match arch {
            Some((input_shape, layers)) => {
                compile(input_shape, layers, records).map(|g| g.run_passes(cfg))
            }
            None => PackedMlp::from_records(records)
                .map(|m| Self::from_mlp(m, cfg))
                .map_err(|e| {
                    EngineError::new(format!(
                        "{} (checkpoint has no architecture record; without `Record::Arch` only \
                         plain BoolLinear-stack checkpoints are servable — re-save with \
                         `save_model` after a forward pass to embed the architecture)",
                        e.msg
                    ))
                }),
        }
    }

    fn run_passes(mut self, cfg: PassConfig) -> Self {
        passes::run(&mut self, cfg);
        self
    }

    /// Forward on packed inputs (B × d_in bits) → logits (B × d_out).
    pub fn forward_bits(&self, x: &BitMatrix) -> Tensor {
        let mut scratch = GraphScratch::new();
        self.forward_bits_into(x, &mut scratch);
        scratch.logits
    }

    /// [`Self::forward_bits`] against caller-owned [`GraphScratch`]
    /// buffers; the logits land in `scratch.logits`.
    pub fn forward_bits_into(&self, x: &BitMatrix, scratch: &mut GraphScratch) {
        assert_eq!(x.cols, self.d_in(), "input width {} vs graph d_in {}", x.cols, self.d_in());
        scratch.ensure(self.n_slots, self.n_convs);
        {
            let s0 = &mut scratch.slots[0];
            s0.bits.clone_from(x);
            s0.is_bits = true;
            s0.shape.clear();
            s0.shape.push(x.rows);
            s0.shape.extend_from_slice(&self.input_shape);
        }
        let GraphScratch { slots, convs, counts, col, fp_in, row, lut, logits } = scratch;
        run_nodes(&self.nodes, slots, convs, counts, col, fp_in, row, lut, logits);
    }

    /// Convenience: pack real-valued features (`v ≥ 0 ⇒ T`, the
    /// [`BitMatrix::from_pm1`] convention) and run [`Self::forward_bits`].
    /// The tensor may be NCHW or already flat — only ∏ non-batch dims
    /// must equal `d_in`.
    pub fn forward_f32(&self, x: &Tensor) -> Tensor {
        let b = x.shape[0];
        let cols: usize = x.shape[1..].iter().product();
        let flat = x.view(&[b, cols]);
        self.forward_bits(&BitMatrix::from_pm1(&flat))
    }

    /// Per-row argmax class ids for a packed batch.
    pub fn predict(&self, x: &BitMatrix) -> Vec<usize> {
        self.forward_bits(x).argmax_rows()
    }
}

impl PackedGraph {
    /// Wrap a [`PackedMlp`] as a linear-only graph and run the pass
    /// pipeline on it: one fused `Linear` op per Boolean layer plus the
    /// FP head (the back-compat bridge for arch-less checkpoints). The
    /// thresholds are already fused in the [`PackedLayer`]s, so only the
    /// liveness pass has work to do — it recolors the slot chain down to
    /// a ping-pong pair.
    pub fn from_mlp(m: PackedMlp, cfg: PassConfig) -> Self {
        let d_in = m.d_in();
        let d_out = m.d_out();
        let mut nodes = Vec::new();
        let mut slot = 0usize;
        for l in m.layers {
            nodes.push(Node { op: PackedOp::Linear(l), src: slot, dst: slot + 1 });
            slot += 1;
        }
        nodes.push(Node {
            op: PackedOp::FpHead { w: m.head_w, b: m.head_b },
            src: slot,
            dst: slot + 1,
        });
        PackedGraph {
            nodes,
            input_shape: vec![d_in],
            n_slots: slot + 2,
            n_convs: 0,
            d_out,
            pass_stats: PassStats::default(),
        }
        .run_passes(cfg)
    }
}

/// See [`PackedGraph::from_mlp`]; pass selection from `BOLD_GRAPH_PASSES`.
impl From<PackedMlp> for PackedGraph {
    fn from(m: PackedMlp) -> Self {
        Self::from_mlp(m, PassConfig::from_env())
    }
}

// ---------------------------------------------------------------------------
// executor
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn run_nodes(
    nodes: &[Node],
    slots: &mut [Slot],
    convs: &mut [ConvScratch],
    counts: &mut Tensor,
    col: &mut Vec<f32>,
    fp_in: &mut Tensor,
    row: &mut Vec<f32>,
    lut: &mut LutScratch,
    logits: &mut Tensor,
) {
    for node in nodes {
        match &node.op {
            PackedOp::Residual { main, shortcut, main_out, short_out } => {
                run_nodes(main, slots, convs, counts, col, fp_in, row, lut, logits);
                run_nodes(shortcut, slots, convs, counts, col, fp_in, row, lut, logits);
                // the liveness pass never gives the merge output the
                // color of either branch output (both are read here), so
                // taking the dst slot out of the pool is alias-free
                debug_assert!(
                    node.dst != *main_out && node.dst != *short_out,
                    "residual dst slot aliases a branch output"
                );
                let mut out = std::mem::take(&mut slots[node.dst]);
                {
                    let a = &slots[*main_out];
                    let b = &slots[*short_out];
                    assert!(!a.is_bits && !b.is_bits, "residual branches must end on f32 counts");
                    assert_eq!(
                        a.shape, b.shape,
                        "residual branch shapes {:?} vs {:?}",
                        a.shape, b.shape
                    );
                    out.f.resize_to(&a.shape);
                    for (o, (&x, &y)) in out.f.data.iter_mut().zip(a.f.data.iter().zip(&b.f.data))
                    {
                        *o = x + y;
                    }
                    out.is_bits = false;
                    let shape = &a.shape;
                    out.set_shape(shape);
                }
                slots[node.dst] = out;
            }
            PackedOp::FpHead { w, b } => {
                let src = &slots[node.src];
                if src.is_bits {
                    fp_head_bits(&src.bits, w, b, row, logits);
                } else {
                    // exact replay of nn::Linear eval: view → matmul_bt →
                    // per-element bias add in the same loop order
                    let n = src.shape[0];
                    let d = src.cols();
                    let flat = src.f.view(&[n, d]);
                    *logits = flat.matmul_bt(w);
                    let n_out = w.rows();
                    for i in 0..n {
                        for j in 0..n_out {
                            *logits.at2_mut(i, j) += b.data[j];
                        }
                    }
                }
            }
            op => {
                // src ≠ dst holds for the compiler's SSA slots and is
                // preserved by the recoloring (a slot's color frees only
                // strictly after its last read)
                debug_assert_ne!(node.src, node.dst, "op dst slot aliases its src");
                let mut out = std::mem::take(&mut slots[node.dst]);
                eval_op(op, &slots[node.src], &mut out, convs, counts, col, fp_in, lut);
                slots[node.dst] = out;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn eval_op(
    op: &PackedOp,
    src: &Slot,
    out: &mut Slot,
    convs: &mut [ConvScratch],
    counts: &mut Tensor,
    col: &mut Vec<f32>,
    fp_in: &mut Tensor,
    lut: &mut LutScratch,
) {
    match op {
        PackedOp::Linear(l) => {
            assert!(src.is_bits, "Linear op needs packed input");
            l.apply_into(&src.bits, &mut out.bits);
            out.is_bits = true;
            out.set_shape(&[src.shape[0], l.weights.rows]);
        }
        PackedOp::Lut(l) => {
            assert!(src.is_bits, "Lut op needs packed input");
            match &l.conv {
                None => {
                    l.apply_linear_into(
                        &src.bits,
                        &mut out.bits,
                        &mut lut.cols,
                        &mut lut.buf,
                        &mut lut.tile,
                    );
                    out.set_shape(&[src.shape[0], l.n_out]);
                }
                Some(g) => {
                    let (n, ch, h, w) = src.dims4();
                    assert_eq!(ch, g.c_in, "conv '{}': {ch} channels vs c_in {}", g.name, g.c_in);
                    let (oh, ow) = {
                        let cs = &mut convs[g.scratch_id];
                        bit_im2col(&src.bits, n, ch, h, w, g.k, g.stride, g.pad, cs)
                    };
                    let cs = &convs[g.scratch_id];
                    let hw = oh * ow;
                    out.bits.zero_resize(n, l.n_out * hw);
                    lut.cols.resize(l.fanin, 0);
                    lut.buf.resize(1usize << (l.fanin - 1), 0);
                    // lanes = spatial positions within one image, so each
                    // channel's eval word deposits contiguously at bit
                    // `j·hw + p0` — the fused conv's channel-major layout,
                    // no transpose needed
                    for ni in 0..n {
                        let row = out.bits.row_mut(ni);
                        for p0 in (0..hw).step_by(64) {
                            let lanes = (hw - p0).min(64);
                            let lanes_mask =
                                if lanes == 64 { u64::MAX } else { (1u64 << lanes) - 1 };
                            let r0 = ni * hw + p0;
                            for (i, cw) in lut.cols.iter_mut().enumerate() {
                                *cw = simd::gather_bit_column(
                                    &cs.patches.words,
                                    cs.patches.wpr,
                                    r0,
                                    lanes,
                                    i,
                                );
                            }
                            // the tables assume every tap is valid; lanes
                            // whose im2col validity mask has any zero read
                            // padding and replay the masked popcount per
                            // lane instead (pad == 0 ⇒ mask all-ones)
                            let invalid = if g.pad > 0 {
                                let mut inv = 0u64;
                                for i in 0..l.fanin {
                                    inv |= !simd::gather_bit_column(
                                        &cs.mask.words,
                                        cs.mask.wpr,
                                        r0,
                                        lanes,
                                        i,
                                    );
                                }
                                inv & lanes_mask
                            } else {
                                0
                            };
                            for j in 0..l.n_out {
                                let t = &l.tables[j * l.tw..(j + 1) * l.tw];
                                let mut word =
                                    simd::lut_eval_word(t, l.fanin, &lut.cols, &mut lut.buf)
                                        & lanes_mask;
                                let mut inv = invalid;
                                while inv != 0 {
                                    let lb = inv.trailing_zeros() as usize;
                                    inv &= inv - 1;
                                    let (pr, mr) =
                                        (cs.patches.row(r0 + lb), cs.mask.row(r0 + lb));
                                    let wr = g.weights.row(j);
                                    let (mut base, mut acc) = (0i64, 0i64);
                                    for ((&p, &m), &wv) in pr.iter().zip(mr).zip(wr) {
                                        base += m.count_ones() as i64;
                                        acc += ((p ^ wv) & m).count_ones() as i64;
                                    }
                                    // gemm_masked_rows' count + the fused
                                    // compare, per lane
                                    let s = (base - 2 * acc) as f32;
                                    let fire =
                                        if g.flip[j] { s <= g.thr[j] } else { s >= g.thr[j] };
                                    word = (word & !(1u64 << lb)) | ((fire as u64) << lb);
                                }
                                simd::deposit(row, j * hw + p0, word, lanes);
                            }
                        }
                    }
                    out.set_shape(&[n, l.n_out, oh, ow]);
                }
            }
            out.is_bits = true;
        }
        PackedOp::LinearCounts(l) => {
            // naive decomposition of the fused Linear: XNOR GEMM to f32
            // integer counts, then the ±1 Boolean bias add — exactly the
            // `pack_threshold_row` accumulation without the compare, so
            // a downstream scalar Threshold reproduces `Linear` bit-
            // for-bit (counts are integers, exact in f32)
            assert!(src.is_bits, "LinearCounts op needs packed input");
            assert!(l.input_mask.is_none(), "masked linears serve through the fused path");
            src.bits.xnor_gemm_into(&l.weights, &mut out.f);
            let n_out = l.weights.rows;
            let n = src.shape[0];
            if let Some(bias) = &l.bias {
                for i in 0..n {
                    let orow = &mut out.f.data[i * n_out..(i + 1) * n_out];
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o += if bias.get(0, j) { 1.0 } else { -1.0 };
                    }
                }
            }
            out.is_bits = false;
            out.set_shape(&[n, n_out]);
        }
        PackedOp::Conv2d(c) => {
            assert!(src.is_bits, "Boolean conv needs packed input");
            let (n, ch, h, w) = src.dims4();
            assert_eq!(ch, c.c_in, "conv '{}': {ch} channels vs c_in {}", c.name, c.c_in);
            let cs = &mut convs[c.scratch_id];
            let (oh, ow) = bit_im2col(&src.bits, n, ch, h, w, c.k, c.stride, c.pad, cs);
            cs.patches.xnor_gemm_masked_into(&c.weights, &cs.mask, counts);
            let hw = oh * ow;
            let cd = &counts.data;
            // gather the max of one pooling window straight from the
            // GEMM (row = spatial, col = channel) layout — identical
            // value-visit order to the standalone MaxPool op, so ties
            // and the running `>` compare resolve the same way
            let pool_max = |ni: usize, j: usize, oy: usize, ox: usize, k: usize| -> f32 {
                let mut best = f32::NEG_INFINITY;
                for dy in 0..k {
                    for dx in 0..k {
                        let p = (oy * k + dy) * ow + (ox * k + dx);
                        let v = cd[(ni * hw + p) * c.c_out + j];
                        if v > best {
                            best = v;
                        }
                    }
                }
                best
            };
            match (&c.pool, &c.fused) {
                (None, Some(ft)) => {
                    // per-channel threshold + re-pack (bit col = j·hw + p,
                    // channel-major): each channel's strided GEMM column
                    // is staged contiguously, then compared and packed by
                    // the SIMD backend's compare kernel
                    out.bits.zero_resize(n, c.c_out * hw);
                    col.resize(hw, 0.0);
                    for ni in 0..n {
                        let row = out.bits.row_mut(ni);
                        for j in 0..c.c_out {
                            for (p, cv) in col.iter_mut().enumerate() {
                                *cv = cd[(ni * hw + p) * c.c_out + j];
                            }
                            simd::pack_cmp_into(row, j * hw, col, ft.thr[j], ft.flip[j]);
                        }
                    }
                    out.is_bits = true;
                    out.set_shape(&[n, c.c_out, oh, ow]);
                }
                (None, None) => {
                    // emit f32 counts in NCHW (the rows_to_nchw mapping)
                    out.f.resize_to(&[n, c.c_out, oh, ow]);
                    for ni in 0..n {
                        for p in 0..hw {
                            let r = ni * hw + p;
                            for j in 0..c.c_out {
                                out.f.data[(ni * c.c_out + j) * hw + p] = cd[r * c.c_out + j];
                            }
                        }
                    }
                    out.is_bits = false;
                    out.set_shape(&[n, c.c_out, oh, ow]);
                }
                (Some(PoolSpec::Max(k)), fused) => {
                    let k = *k;
                    assert!(
                        oh % k == 0 && ow % k == 0,
                        "conv '{}': pooled {oh}x{ow} not divisible by {k}",
                        c.name
                    );
                    let (ph, pw) = (oh / k, ow / k);
                    let phw = ph * pw;
                    match fused {
                        Some(ft) => {
                            // pool + threshold in one sweep: the pooled
                            // channel plane is staged contiguously, then
                            // compared/packed by the same SIMD kernel as
                            // the standalone per-channel Threshold
                            out.bits.zero_resize(n, c.c_out * phw);
                            col.resize(phw, 0.0);
                            for ni in 0..n {
                                let row = out.bits.row_mut(ni);
                                for j in 0..c.c_out {
                                    for oy in 0..ph {
                                        for ox in 0..pw {
                                            col[oy * pw + ox] = pool_max(ni, j, oy, ox, k);
                                        }
                                    }
                                    simd::pack_cmp_into(row, j * phw, col, ft.thr[j], ft.flip[j]);
                                }
                            }
                            out.is_bits = true;
                        }
                        None => {
                            out.f.resize_to(&[n, c.c_out, ph, pw]);
                            for ni in 0..n {
                                for j in 0..c.c_out {
                                    for oy in 0..ph {
                                        for ox in 0..pw {
                                            out.f.data[((ni * c.c_out + j) * ph + oy) * pw + ox] =
                                                pool_max(ni, j, oy, ox, k);
                                        }
                                    }
                                }
                            }
                            out.is_bits = false;
                        }
                    }
                    out.set_shape(&[n, c.c_out, ph, pw]);
                }
                (Some(PoolSpec::GlobalAvg), fused) => {
                    // the fusion pass never puts a threshold after a
                    // mean (no longer integer-valued counts)
                    assert!(fused.is_none(), "GlobalAvg pool cannot carry a fused threshold");
                    out.f.resize_to(&[n, c.c_out]);
                    let inv = 1.0 / hw as f32;
                    for ni in 0..n {
                        for j in 0..c.c_out {
                            // same ascending-p left-fold as the
                            // standalone GlobalAvgPool's slice sum
                            let mut s = 0.0f32;
                            for p in 0..hw {
                                s += cd[(ni * hw + p) * c.c_out + j];
                            }
                            out.f.data[ni * c.c_out + j] = s * inv;
                        }
                    }
                    out.is_bits = false;
                    out.set_shape(&[n, c.c_out]);
                }
            }
        }
        PackedOp::FpConv2d(fc) => {
            let (n, ch, h, w) = src.dims4();
            assert_eq!(ch, fc.c_in, "conv '{}': {ch} channels vs c_in {}", fc.name, fc.c_in);
            let xf: &Tensor = if src.is_bits {
                // decode ±1 exactly as Value::to_f32 would
                fp_in.resize_to(&[n, ch, h, w]);
                let cols = ch * h * w;
                for i in 0..n {
                    src.bits.decode_pm1_row(i, &mut fp_in.data[i * cols..(i + 1) * cols]);
                }
                fp_in
            } else {
                &src.f
            };
            let oh = (h + 2 * fc.pad - fc.k) / fc.stride + 1;
            let ow = (w + 2 * fc.pad - fc.k) / fc.stride + 1;
            // exact replay of nn::Conv2d eval (this path allocates per
            // call like the training layer does — stem only)
            let cols = xf.im2col(fc.k, fc.stride, fc.pad);
            let mut y = cols.matmul_bt(&fc.w);
            for i in 0..y.rows() {
                for j in 0..fc.c_out {
                    *y.at2_mut(i, j) += fc.b.data[j];
                }
            }
            out.f = y.rows_to_nchw(n, fc.c_out, oh, ow);
            out.is_bits = false;
            out.set_shape(&[n, fc.c_out, oh, ow]);
        }
        PackedOp::BatchNorm(bn) => {
            let (n, c, h, w) = src.dims4();
            assert_eq!(c, bn.gamma.len(), "BN '{}': {c} channels vs {}", bn.name, bn.gamma.len());
            out.f.resize_to(&src.shape);
            let hw = h * w;
            for ni in 0..n {
                for ci in 0..c {
                    // identical arithmetic to BnCore eval: (x−μ)/√(σ²+ε),
                    // then γ·h + β
                    let denom = (bn.var[ci] + BN_EPS).sqrt();
                    let plane = (ni * c + ci) * hw;
                    for p in 0..hw {
                        let hh = (src.f.data[plane + p] - bn.mean[ci]) / denom;
                        out.f.data[plane + p] = bn.gamma[ci] * hh + bn.beta[ci];
                    }
                }
            }
            out.is_bits = false;
            out.set_shape(&src.shape);
        }
        PackedOp::Threshold(spec) => {
            assert!(!src.is_bits, "threshold needs f32 input");
            let n = src.shape[0];
            match spec {
                ThresholdSpec::Scalar(thr) => {
                    let cols = src.cols();
                    out.bits.zero_resize(n, cols);
                    for i in 0..n {
                        let r = &src.f.data[i * cols..(i + 1) * cols];
                        simd::pack_cmp_into(out.bits.row_mut(i), 0, r, *thr, false);
                    }
                }
                ThresholdSpec::PerChannel(ft) => {
                    let (n, c, h, w) = src.dims4();
                    let hw = h * w;
                    out.bits.zero_resize(n, c * hw);
                    let data = &src.f.data;
                    for ni in 0..n {
                        let row = out.bits.row_mut(ni);
                        for ci in 0..c {
                            let plane = (ni * c + ci) * hw;
                            simd::pack_cmp_into(
                                row,
                                ci * hw,
                                &data[plane..plane + hw],
                                ft.thr[ci],
                                ft.flip[ci],
                            );
                        }
                    }
                }
            }
            out.is_bits = true;
            out.set_shape(&src.shape);
        }
        PackedOp::MaxPool { k } => {
            // exact replay of nn::MaxPool2d forward
            let (n, c, h, w) = src.dims4();
            let k = *k;
            assert!(h % k == 0 && w % k == 0, "maxpool: {h}x{w} not divisible by {k}");
            let (oh, ow) = (h / k, w / k);
            out.f.resize_to(&[n, c, oh, ow]);
            for ni in 0..n {
                for ci in 0..c {
                    let plane = (ni * c + ci) * h * w;
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut best = f32::NEG_INFINITY;
                            for dy in 0..k {
                                for dx in 0..k {
                                    let v = src.f.data[plane + (oy * k + dy) * w + (ox * k + dx)];
                                    if v > best {
                                        best = v;
                                    }
                                }
                            }
                            out.f.data[((ni * c + ci) * oh + oy) * ow + ox] = best;
                        }
                    }
                }
            }
            out.is_bits = false;
            out.set_shape(&[n, c, oh, ow]);
        }
        PackedOp::GlobalAvgPool => {
            // exact replay of nn::AvgPool2dGlobal forward
            let (n, c, h, w) = src.dims4();
            out.f.resize_to(&[n, c]);
            let inv = 1.0 / (h * w) as f32;
            for ni in 0..n {
                for ci in 0..c {
                    let plane = (ni * c + ci) * h * w;
                    let s: f32 = src.f.data[plane..plane + h * w].iter().sum();
                    out.f.data[ni * c + ci] = s * inv;
                }
            }
            out.is_bits = false;
            out.set_shape(&[n, c]);
        }
        PackedOp::Flatten => {
            let n = src.shape[0];
            let cols = src.cols();
            if src.is_bits {
                out.bits.clone_from(&src.bits);
                out.is_bits = true;
            } else {
                out.f.resize_to(&[n, cols]);
                out.f.data.copy_from_slice(&src.f.data);
                out.is_bits = false;
            }
            out.set_shape(&[n, cols]);
        }
        PackedOp::Residual { .. } | PackedOp::FpHead { .. } => {
            unreachable!("handled in run_nodes")
        }
    }
}

/// Bit-level im2col with the geometry-cached validity mask: delegates to
/// the training stack's [`packed_im2col`] core (ONE implementation of
/// the parity-critical padding/run geometry), keyed on this op's scratch.
fn bit_im2col(
    bits: &BitMatrix,
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    cs: &mut ConvScratch,
) -> (usize, usize) {
    let build_mask = cs.geom != Some((n, h, w));
    let (oh, ow) =
        packed_im2col(bits, n, c, h, w, k, stride, pad, &mut cs.patches, &mut cs.mask, build_mask);
    if build_mask {
        cs.geom = Some((n, h, w));
    }
    (oh, ow)
}

// ---------------------------------------------------------------------------
// compiler: LayerDesc list + checkpoint records → op graph
// ---------------------------------------------------------------------------

/// Checkpoint record lookup with consumption tracking, so the compiler
/// can report both *missing* records (by name and expected kind) and
/// *leftover* records the architecture never referenced.
struct RecordIndex<'r> {
    map: HashMap<&'r str, &'r Record>,
    used: HashSet<String>,
}

impl<'r> RecordIndex<'r> {
    fn new(records: &'r [Record]) -> Self {
        let mut map = HashMap::new();
        for r in records {
            match r {
                Record::Bool { name, .. } | Record::Real { name, .. }
                | Record::Buffer { name, .. } => {
                    map.insert(name.as_str(), r);
                }
                _ => {}
            }
        }
        RecordIndex { map, used: HashSet::new() }
    }

    fn get(&mut self, name: &str) -> Option<&'r Record> {
        let r = self.map.get(name).copied();
        if r.is_some() {
            self.used.insert(name.to_string());
        }
        r
    }

    fn bool_mat(&mut self, name: &str, what: &str) -> Result<BitMatrix, EngineError> {
        match self.get(name) {
            Some(Record::Bool { rows, cols, words, .. }) => {
                Ok(BitMatrix::from_words(*rows, *cols, words.clone()))
            }
            Some(_) => Err(EngineError::new(format!(
                "record '{name}' ({what}) is not a Boolean tensor"
            ))),
            None => Err(EngineError::new(format!("missing Boolean record '{name}' ({what})"))),
        }
    }

    fn real_vec(&mut self, name: &str, what: &str) -> Result<Vec<f32>, EngineError> {
        match self.get(name) {
            Some(Record::Real { data, .. }) => Ok(data.clone()),
            Some(_) => {
                Err(EngineError::new(format!("record '{name}' ({what}) is not an FP tensor")))
            }
            None => Err(EngineError::new(format!("missing FP record '{name}' ({what})"))),
        }
    }

    fn buffer_vec(&mut self, name: &str, what: &str) -> Result<Vec<f32>, EngineError> {
        match self.get(name) {
            Some(Record::Buffer { data, .. }) => Ok(data.clone()),
            Some(_) => Err(EngineError::new(format!("record '{name}' ({what}) is not a buffer"))),
            None => Err(EngineError::new(format!("missing buffer record '{name}' ({what})"))),
        }
    }

    /// First weight/buffer record the compiled architecture never
    /// consumed (indicates arch ↔ tensor desync in the checkpoint).
    fn leftover(&self) -> Option<&str> {
        self.map.keys().find(|n| !self.used.contains(**n)).copied()
    }
}

/// Compile-time dataflow state.
#[derive(Clone)]
struct St {
    /// Current value is packed bits (else f32).
    bits: bool,
    /// f32 value is integer-valued pre-activation counts.
    integer: bool,
    /// Channel (or feature) count of the current value.
    chans: usize,
    /// Max |count| when `integer` (the BN-fold search range).
    range: i64,
}

struct SeqCtx {
    nodes: Vec<Node>,
    cur: usize,
    pending_conv: Option<PackedConv>,
    pending_lin: Option<(String, PackedLayer)>,
    pending_bn: Option<BnEval>,
    st: St,
}

struct Compiler<'r> {
    recs: RecordIndex<'r>,
    next_slot: usize,
    next_conv: usize,
}

impl Compiler<'_> {
    fn alloc_slot(&mut self) -> usize {
        let s = self.next_slot;
        self.next_slot += 1;
        s
    }

    fn emit(&mut self, ctx: &mut SeqCtx, op: PackedOp) {
        let dst = self.alloc_slot();
        ctx.nodes.push(Node { op, src: ctx.cur, dst });
        ctx.cur = dst;
    }

    /// Emit any pending (unfused) ops: a conv whose threshold did not
    /// directly follow, then a BN that could not fold.
    fn flush(&mut self, ctx: &mut SeqCtx) -> Result<(), EngineError> {
        if let Some((name, _)) = &ctx.pending_lin {
            return Err(EngineError::new(format!(
                "BoolLinear '{name}' must be followed by a threshold activation to be servable"
            )));
        }
        if let Some(c) = ctx.pending_conv.take() {
            ctx.st = St {
                bits: false,
                integer: true,
                chans: c.c_out,
                range: (c.c_in * c.k * c.k) as i64,
            };
            self.emit(ctx, PackedOp::Conv2d(c));
        }
        if let Some(bn) = ctx.pending_bn.take() {
            self.emit(ctx, PackedOp::BatchNorm(bn));
            ctx.st.integer = false;
            ctx.st.range = 0;
        }
        Ok(())
    }

    fn load_bn(&mut self, name: &str, features: usize) -> Result<BnEval, EngineError> {
        let gamma = self.recs.real_vec(&format!("{name}.gamma"), "BN scale")?;
        let beta = self.recs.real_vec(&format!("{name}.beta"), "BN shift")?;
        let mean = self.recs.buffer_vec(&format!("{name}.running_mean"), "BN running mean")?;
        let var = self.recs.buffer_vec(&format!("{name}.running_var"), "BN running var")?;
        for (v, what) in
            [(&gamma, "gamma"), (&beta, "beta"), (&mean, "running_mean"), (&var, "running_var")]
        {
            if v.len() != features {
                return Err(EngineError::new(format!(
                    "BN '{name}': {what} len {} vs {features} features",
                    v.len()
                )));
            }
        }
        Ok(BnEval { name: name.to_string(), gamma, beta, mean, var })
    }

    fn act_threshold(&mut self, name: &str, tau: f32, centered: bool) -> Result<f32, EngineError> {
        if !centered {
            return Ok(tau);
        }
        let m = self.recs.buffer_vec(
            &format!("{name}.running_mean"),
            "centered-threshold running mean",
        )?;
        if m.is_empty() {
            return Err(EngineError::new(format!("activation '{name}': empty running_mean")));
        }
        Ok(tau + m[0])
    }

    fn compile_seq(
        &mut self,
        descs: &[LayerDesc],
        st: St,
        src: usize,
        top: bool,
    ) -> Result<(Vec<Node>, usize, St), EngineError> {
        let mut ctx = SeqCtx {
            nodes: Vec::new(),
            cur: src,
            pending_conv: None,
            pending_lin: None,
            pending_bn: None,
            st,
        };
        let last = descs.len().saturating_sub(1);
        for (i, desc) in descs.iter().enumerate() {
            self.compile_one(desc, &mut ctx, top && i == last)?;
        }
        if !top {
            self.flush(&mut ctx)?;
        }
        Ok((ctx.nodes, ctx.cur, ctx.st))
    }

    fn compile_one(
        &mut self,
        desc: &LayerDesc,
        ctx: &mut SeqCtx,
        is_final: bool,
    ) -> Result<(), EngineError> {
        match desc {
            LayerDesc::ThresholdAct { name, tau, centered } => {
                let thr = self.act_threshold(name, *tau, *centered)?;
                // the compiler emits the NAIVE decomposition — GEMM op,
                // then a standalone Threshold; the fusion pass
                // (`passes::run`) folds the pair back into the fused
                // kernels, so the unfused graph stays a living reference
                if let Some((_, pl)) = ctx.pending_lin.take() {
                    if ctx.pending_bn.is_some() {
                        return Err(EngineError::new(format!(
                            "BatchNorm between BoolLinear and activation '{name}' is not servable"
                        )));
                    }
                    let n_out = pl.weights.rows;
                    self.emit(ctx, PackedOp::LinearCounts(pl));
                    self.emit(ctx, PackedOp::Threshold(ThresholdSpec::Scalar(thr)));
                    ctx.st = St { bits: true, integer: false, chans: n_out, range: 0 };
                } else if let Some(c) = ctx.pending_conv.take() {
                    // BN folding stays a load-time weight transform (not
                    // a pass): the folded per-channel integer threshold
                    // IS the naive Threshold op here
                    let fanin = (c.c_in * c.k * c.k) as i64;
                    let c_out = c.c_out;
                    let spec = match ctx.pending_bn.take() {
                        Some(bn) => ThresholdSpec::PerChannel(fold_bn_threshold(&bn, thr, fanin)),
                        None => ThresholdSpec::Scalar(thr),
                    };
                    self.emit(ctx, PackedOp::Conv2d(c));
                    self.emit(ctx, PackedOp::Threshold(spec));
                    ctx.st = St { bits: true, integer: false, chans: c_out, range: 0 };
                } else {
                    if ctx.st.bits {
                        return Err(EngineError::new(format!(
                            "activation '{name}' applied to already-packed bits"
                        )));
                    }
                    match ctx.pending_bn.take() {
                        Some(bn) if ctx.st.integer => {
                            // BN + act over integer counts: fold to a
                            // per-channel integer threshold — zero BN ops
                            let ft = fold_bn_threshold(&bn, thr, ctx.st.range);
                            self.emit(ctx, PackedOp::Threshold(ThresholdSpec::PerChannel(ft)));
                        }
                        Some(bn) => {
                            self.emit(ctx, PackedOp::BatchNorm(bn));
                            self.emit(ctx, PackedOp::Threshold(ThresholdSpec::Scalar(thr)));
                        }
                        None => {
                            self.emit(ctx, PackedOp::Threshold(ThresholdSpec::Scalar(thr)));
                        }
                    }
                    ctx.st.bits = true;
                    ctx.st.integer = false;
                    ctx.st.range = 0;
                }
            }
            LayerDesc::BoolConv2d { name, c_in, c_out, k, stride, pad } => {
                self.flush(ctx)?;
                if !ctx.st.bits {
                    return Err(EngineError::new(format!(
                        "Boolean conv '{name}' receives real-valued input — a threshold \
                         activation must precede it"
                    )));
                }
                let weights = self.recs.bool_mat(&format!("{name}.weight"), "conv weights")?;
                let fanin = c_in * k * k;
                if (weights.rows, weights.cols) != (*c_out, fanin) {
                    return Err(EngineError::new(format!(
                        "conv '{name}': weight shape {}x{} vs arch {c_out}x{fanin}",
                        weights.rows, weights.cols
                    )));
                }
                ctx.pending_conv = Some(PackedConv {
                    name: name.clone(),
                    c_in: *c_in,
                    c_out: *c_out,
                    k: *k,
                    stride: *stride,
                    pad: *pad,
                    weights,
                    fused: None,
                    pool: None,
                    scratch_id: {
                        let id = self.next_conv;
                        self.next_conv += 1;
                        id
                    },
                });
            }
            LayerDesc::Conv2d { name, c_in, c_out, k, stride, pad } => {
                self.flush(ctx)?;
                let w = self.recs.real_vec(&format!("{name}.w"), "conv weights")?;
                let b = self.recs.real_vec(&format!("{name}.b"), "conv bias")?;
                let fanin = c_in * k * k;
                if w.len() != c_out * fanin || b.len() != *c_out {
                    return Err(EngineError::new(format!(
                        "conv '{name}': weight/bias lens {}/{} vs arch {c_out}x{fanin}",
                        w.len(),
                        b.len()
                    )));
                }
                self.emit(
                    ctx,
                    PackedOp::FpConv2d(FpConv {
                        name: name.clone(),
                        c_in: *c_in,
                        c_out: *c_out,
                        k: *k,
                        stride: *stride,
                        pad: *pad,
                        w: Tensor::from_vec(&[*c_out, fanin], w),
                        b: Tensor::from_vec(&[*c_out], b),
                    }),
                );
                ctx.st = St { bits: false, integer: false, chans: *c_out, range: 0 };
            }
            LayerDesc::BatchNorm2d { name, features } => {
                if ctx.pending_lin.is_some() || ctx.pending_bn.is_some() {
                    return Err(EngineError::new(format!(
                        "BatchNorm '{name}' in an unsupported position"
                    )));
                }
                let chans = ctx.pending_conv.as_ref().map(|c| c.c_out).unwrap_or(ctx.st.chans);
                if *features != chans {
                    return Err(EngineError::new(format!(
                        "BN '{name}': {features} features vs {chans} channels"
                    )));
                }
                ctx.pending_bn = Some(self.load_bn(name, *features)?);
            }
            LayerDesc::MaxPool2d { name, k } => {
                self.flush(ctx)?;
                if ctx.st.bits {
                    return Err(EngineError::new(format!(
                        "MaxPool '{name}' after a threshold activation is not servable"
                    )));
                }
                self.emit(ctx, PackedOp::MaxPool { k: *k });
                // max of integers is an integer: integer/range unchanged
            }
            LayerDesc::GlobalAvgPool { name } => {
                self.flush(ctx)?;
                if ctx.st.bits {
                    return Err(EngineError::new(format!(
                        "GlobalAvgPool '{name}' on packed bits is not servable"
                    )));
                }
                self.emit(ctx, PackedOp::GlobalAvgPool);
                ctx.st.integer = false; // mean divides: no longer integer
                ctx.st.range = 0;
            }
            LayerDesc::Flatten { .. } => {
                // emitted explicitly (a plain copy op); the fusion pass
                // elides it by rewriting slot indices, since packed bits
                // are already row-flattened, f32 data is contiguous
                // row-major, and every downstream consumer derives
                // (batch, ∏ rest) itself
                self.flush(ctx)?;
                self.emit(ctx, PackedOp::Flatten);
            }
            LayerDesc::Binarize { .. } => {
                self.flush(ctx)?;
                if !ctx.st.bits {
                    // sign(v) ⇔ v ≥ 0 under the from_pm1 convention
                    self.emit(ctx, PackedOp::Threshold(ThresholdSpec::Scalar(0.0)));
                    ctx.st.bits = true;
                    ctx.st.integer = false;
                    ctx.st.range = 0;
                }
                // on already-packed bits binarize is the identity: no op
            }
            LayerDesc::BoolLinear { name, n_in, n_out, bias } => {
                self.flush(ctx)?;
                if !ctx.st.bits {
                    return Err(EngineError::new(format!(
                        "BoolLinear '{name}' on real-valued input is not servable — a \
                         Binarize/ThresholdAct must precede it"
                    )));
                }
                let weights = self.recs.bool_mat(&format!("{name}.weight"), "linear weights")?;
                if (weights.rows, weights.cols) != (*n_out, *n_in) {
                    return Err(EngineError::new(format!(
                        "linear '{name}': weight shape {}x{} vs arch {n_out}x{n_in}",
                        weights.rows, weights.cols
                    )));
                }
                let bias = if *bias {
                    Some(self.recs.bool_mat(&format!("{name}.bias"), "linear bias")?)
                } else {
                    None
                };
                ctx.pending_lin = Some((
                    name.clone(),
                    PackedLayer { weights, bias, threshold: 0.0, input_mask: None },
                ));
            }
            LayerDesc::Linear { name, n_in, n_out } => {
                if !is_final {
                    return Err(EngineError::new(format!(
                        "FP Linear '{name}' in the network interior is not servable by the \
                         packed graph executor (only a final FP head)"
                    )));
                }
                self.flush(ctx)?;
                let w = self.recs.real_vec(&format!("{name}.w"), "head weights")?;
                let b = self.recs.real_vec(&format!("{name}.b"), "head bias")?;
                if w.len() != n_in * n_out || b.len() != *n_out {
                    return Err(EngineError::new(format!(
                        "head '{name}': weight/bias lens {}/{} vs arch {n_out}x{n_in}",
                        w.len(),
                        b.len()
                    )));
                }
                self.emit(
                    ctx,
                    PackedOp::FpHead {
                        w: Tensor::from_vec(&[*n_out, *n_in], w),
                        b: Tensor::from_vec(&[*n_out], b),
                    },
                );
            }
            LayerDesc::Residual { name, main, shortcut } => {
                self.flush(ctx)?;
                if ctx.st.bits {
                    return Err(EngineError::new(format!(
                        "residual '{name}' merges pre-activations — packed-bit input is not \
                         servable"
                    )));
                }
                let (mnodes, mout, mst) =
                    self.compile_seq(main, ctx.st.clone(), ctx.cur, false)?;
                if mst.bits {
                    return Err(EngineError::new(format!(
                        "residual '{name}': main branch must end on pre-activations"
                    )));
                }
                let (snodes, sout, sst) = if shortcut.is_empty() {
                    (Vec::new(), ctx.cur, ctx.st.clone())
                } else {
                    let (n, o, s) = self.compile_seq(shortcut, ctx.st.clone(), ctx.cur, false)?;
                    if s.bits {
                        return Err(EngineError::new(format!(
                            "residual '{name}': shortcut branch must end on pre-activations"
                        )));
                    }
                    (n, o, s)
                };
                if mst.chans != sst.chans {
                    return Err(EngineError::new(format!(
                        "residual '{name}': branch channels {} vs {}",
                        mst.chans, sst.chans
                    )));
                }
                let merged = St {
                    bits: false,
                    integer: mst.integer && sst.integer,
                    chans: mst.chans,
                    range: mst.range + sst.range,
                };
                self.emit(
                    ctx,
                    PackedOp::Residual {
                        main: mnodes,
                        shortcut: snodes,
                        main_out: mout,
                        short_out: sout,
                    },
                );
                ctx.st = merged;
            }
            other => {
                return Err(EngineError::new(format!(
                    "layer '{}' ({}) is not supported by the packed graph executor",
                    other.name(),
                    other.kind()
                )));
            }
        }
        Ok(())
    }
}

/// Fold eval-mode BN + threshold over *integer* pre-activations into one
/// integer threshold per channel: binary-search the crossover of the
/// monotone predicate `γ·(s−μ)/√(σ²+ε) + β ≥ τ`, replaying the exact
/// f32 arithmetic of `BnCore` eval + `ThresholdAct` so the folded
/// compare is bit-identical for every integer in `[-range, range]`.
fn fold_bn_threshold(bn: &BnEval, thr_act: f32, range: i64) -> FusedThreshold {
    let c = bn.gamma.len();
    let mut thr = vec![0.0f32; c];
    let mut flip = vec![false; c];
    let (lo, hi) = (-range, range);
    for j in 0..c {
        let denom = (bn.var[j] + BN_EPS).sqrt();
        let fire =
            |s: f32| bn.gamma[j] * ((s - bn.mean[j]) / denom) + bn.beta[j] >= thr_act;
        if bn.gamma[j] > 0.0 {
            // predicate is monotone non-decreasing in s: find the
            // smallest integer that fires
            if !fire(hi as f32) {
                thr[j] = (hi + 1) as f32; // never fires in range
            } else {
                let (mut a, mut b) = (lo, hi); // invariant: fire(b)
                while a < b {
                    let m = a + (b - a) / 2;
                    if fire(m as f32) {
                        b = m;
                    } else {
                        a = m + 1;
                    }
                }
                thr[j] = b as f32;
            }
        } else if bn.gamma[j] < 0.0 {
            // monotone non-increasing: find the largest integer that
            // fires; the packed compare flips to s ≤ thr
            flip[j] = true;
            if !fire(lo as f32) {
                thr[j] = (lo - 1) as f32; // never fires in range
            } else {
                let (mut a, mut b) = (lo, hi); // invariant: fire(a)
                while a < b {
                    let m = a + (b - a + 1) / 2;
                    if fire(m as f32) {
                        a = m;
                    } else {
                        b = m - 1;
                    }
                }
                thr[j] = a as f32;
            }
        } else {
            // γ = ±0 (or NaN): the BN output is the constant β for every
            // finite s, so the predicate is constant too
            thr[j] = if fire(0.0) { (lo - 1) as f32 } else { (hi + 1) as f32 };
        }
    }
    FusedThreshold { thr, flip }
}

fn compile(
    input_shape: &[usize],
    descs: &[LayerDesc],
    records: &[Record],
) -> Result<PackedGraph, EngineError> {
    if descs.is_empty() {
        return Err(EngineError::new("architecture record is empty"));
    }
    // input shape: spatial (conv/pool/BN2d/residual-bearing) models need
    // the recorded [C, H, W] — checked recursively so a conv anywhere in
    // the arch fails at LOAD with a clear error instead of panicking in a
    // serve worker; flat models can fall back to the first layer's fan-in
    fn has_spatial(descs: &[LayerDesc]) -> bool {
        descs.iter().any(|d| match d {
            LayerDesc::Conv2d { .. }
            | LayerDesc::BoolConv2d { .. }
            | LayerDesc::BatchNorm2d { .. }
            | LayerDesc::MaxPool2d { .. }
            | LayerDesc::GlobalAvgPool { .. } => true,
            LayerDesc::Residual { main, shortcut, .. } => {
                has_spatial(main) || has_spatial(shortcut)
            }
            _ => false,
        })
    }
    let needs_spatial = has_spatial(descs);
    let input_shape: Vec<usize> = if !input_shape.is_empty() {
        input_shape.to_vec()
    } else if needs_spatial {
        return Err(EngineError::new(
            "checkpoint has no recorded input shape — forward the model once before \
             save_model so the `Record::Arch` carries it",
        ));
    } else {
        match descs.first() {
            Some(LayerDesc::BoolLinear { n_in, .. }) | Some(LayerDesc::Linear { n_in, .. }) => {
                vec![*n_in]
            }
            _ => {
                return Err(EngineError::new(
                    "checkpoint has no recorded input shape — forward the model once before \
                     save_model so the `Record::Arch` carries it",
                ))
            }
        }
    };
    if needs_spatial && input_shape.len() != 3 {
        return Err(EngineError::new(format!(
            "conv architecture needs a [C, H, W] input shape, checkpoint records {input_shape:?}"
        )));
    }
    let mut compiler =
        Compiler { recs: RecordIndex::new(records), next_slot: 1, next_conv: 0 };
    let st = St { bits: true, integer: false, chans: input_shape[0], range: 0 };
    let (nodes, _out, _st) = compiler.compile_seq(descs, st, 0, true)?;
    let d_out = match nodes.last().map(|n| &n.op) {
        Some(PackedOp::FpHead { w, .. }) => w.rows(),
        _ => {
            return Err(EngineError::new(
                "architecture does not end in an FP head (final Linear layer)",
            ))
        }
    };
    if let Some(name) = compiler.recs.leftover() {
        return Err(EngineError::new(format!(
            "record '{name}' is not referenced by the architecture description — checkpoint \
             and arch record are out of sync"
        )));
    }
    Ok(PackedGraph {
        nodes,
        input_shape,
        n_slots: compiler.next_slot,
        n_convs: compiler.next_conv,
        d_out,
        pass_stats: PassStats::default(),
    })
}
