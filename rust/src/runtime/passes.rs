//! Graph-compiler optimization passes (DESIGN.md
//! §Graph-Compiler-Passes): rewrites applied to the [`PackedGraph`] op
//! list between compilation and execution.
//!
//! The compiler emits a *naive* graph — one op per architecture layer,
//! one activation slot per op — and every optimization is a separate,
//! individually toggleable pass over that IR:
//!
//! 1. **Fusion** ([`PassConfig::fuse`]): elides pure-metadata `Flatten`
//!    ops by rewriting slot indices, folds `Threshold` nodes into their
//!    producer `Linear`/`Conv2d` GEMMs (the producer packs bits straight
//!    out of the accumulator), and folds `MaxPool`/`GlobalAvgPool` into
//!    the producing conv so the full-resolution count map is never
//!    materialized. Each rewrite replaces a producer/consumer pair with
//!    one op computing the identical function — the fused kernels replay
//!    the decomposed ops' exact f32 compare/sum order, so the output is
//!    bit-exact by construction (asserted archetype-by-archetype in
//!    `tests/packed_graph.rs`).
//! 2. **Slot liveness** ([`PassConfig::liveness`]): computes
//!    first-def/last-use per activation slot on a linearized schedule
//!    (recursing through both `Residual` branch op lists, whose
//!    `main_out`/`short_out` values stay live until the merge), then
//!    recolors `src`/`dst` with a linear scan so [`GraphScratch`]
//!    allocates only the live-range chromatic number of buffers —
//!    typically 2–3 slots regardless of depth — instead of one slot per
//!    node.
//! 3. **LUT folding** ([`PassConfig::lut`], DESIGN.md §LUT-Folding):
//!    collapses Boolean layers whose per-output fan-in K is at or below
//!    [`PassConfig::lut_max_fanin`] into [`PackedOp::Lut`] nodes — each
//!    output neuron's `2^K`-entry truth table is enumerated at compile
//!    time by replaying the exact popcount+compare the layer would run,
//!    and the executor evaluates 64 lanes per word with a bitsliced mux
//!    cascade instead of an XNOR+popcount GEMM. Runs between fusion and
//!    liveness so fused threshold/flip epilogues fold into the tables.
//!
//! Pass selection comes from `BOLD_GRAPH_PASSES` (`all`, `none`, or a
//! comma-separated subset of `fuse`/`liveness`/`lut`; default `all`)
//! via [`PassConfig::from_env`], with the LUT fan-in cap from
//! `BOLD_LUT_MAX_FANIN`; the unoptimized executor stays a living
//! reference that CI runs the full parity suites against.
//!
//! Safety model: the passes assume the compiler's SSA discipline (each
//! slot written exactly once, defs precede uses). The liveness pass
//! re-verifies that discipline while linearizing and bails to the
//! identity coloring on any violation, so a hand-built graph can never
//! be miscolored — it just isn't compacted.
//!
//! [`PackedGraph`]: super::graph::PackedGraph
//! [`GraphScratch`]: super::graph::GraphScratch

use super::graph::{FusedThreshold, Node, PackedGraph, PackedLut, PackedOp, PoolSpec, ThresholdSpec};
use std::collections::BTreeSet;

/// Default fan-in cap of the LUT-folding pass (`BOLD_LUT_MAX_FANIN`
/// override): a fan-in-K layer costs `2^K` table bits per neuron, and
/// around K = 10 the table traffic starts rivalling the weight traffic
/// it replaces (DESIGN.md §LUT-Folding).
pub const LUT_DEFAULT_MAX_FANIN: usize = 10;

/// Hard ceiling on the fan-in the pass will ever fold, whatever the env
/// cap says: beyond 2^16 table bits per neuron the fold always loses to
/// XNOR+popcount and the mux-cascade scratch (`2^(K−1)` words) stops
/// being cache-resident. The env parse accepts up to the 64-bit gather
/// word width; this bounds what conversion does with it.
pub const LUT_HARD_MAX_FANIN: usize = 16;

/// Which optimization passes to run on a freshly compiled graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PassConfig {
    /// Op fusion: threshold/pool folding + Flatten elision.
    pub fuse: bool,
    /// Slot-liveness recoloring for scratch-buffer reuse.
    pub liveness: bool,
    /// LUT folding: collapse low-fan-in Boolean layers into bitsliced
    /// truth tables (runs after fusion, on fused and naive ops alike).
    pub lut: bool,
    /// Fan-in cap for the `lut` pass: layers with more input bits per
    /// neuron stay on XNOR+popcount. `0` disables the pass entirely
    /// (`BOLD_LUT_MAX_FANIN=0`).
    pub lut_max_fanin: usize,
}

impl PassConfig {
    /// Every pass enabled (the default pipeline).
    pub fn all() -> Self {
        PassConfig { fuse: true, liveness: true, lut: true, lut_max_fanin: LUT_DEFAULT_MAX_FANIN }
    }

    /// No passes: the naive compiler output runs as-is (the living
    /// reference executor).
    pub fn none() -> Self {
        PassConfig { fuse: false, liveness: false, lut: false, lut_max_fanin: LUT_DEFAULT_MAX_FANIN }
    }

    /// Parse a `BOLD_GRAPH_PASSES` value: `all`, `none`, or a
    /// comma-separated subset of `fuse`/`liveness`/`lut` (each token
    /// enables its pass; the single-token forms keep their original
    /// meaning). `None` (unset) and anything unrecognized select the
    /// full pipeline rather than silently serving unoptimized.
    pub fn parse(v: Option<&str>) -> Self {
        let Some(raw) = v else { return Self::all() };
        let raw = raw.trim();
        match raw {
            "none" => return Self::none(),
            "all" => return Self::all(),
            _ => {}
        }
        let mut cfg = Self::none();
        for tok in raw.split(',') {
            match tok.trim() {
                "fuse" => cfg.fuse = true,
                "liveness" => cfg.liveness = true,
                "lut" => cfg.lut = true,
                _ => return Self::all(),
            }
        }
        cfg
    }

    /// Parse a `BOLD_LUT_MAX_FANIN` value: unset/empty keeps the
    /// default, `0` disables the LUT pass, `1..=64` is accepted (the
    /// bit-column gather indexes one 64-bit word), and anything else —
    /// negative, non-numeric, above the word width — is rejected back
    /// to the default.
    pub fn parse_lut_cap(v: Option<&str>) -> usize {
        match v.map(str::trim) {
            None | Some("") => LUT_DEFAULT_MAX_FANIN,
            Some(s) => match s.parse::<usize>() {
                Ok(n) if n <= 64 => n,
                _ => LUT_DEFAULT_MAX_FANIN,
            },
        }
    }

    /// Pass selection from the `BOLD_GRAPH_PASSES` environment variable,
    /// with the LUT fan-in cap from `BOLD_LUT_MAX_FANIN`.
    pub fn from_env() -> Self {
        let mut cfg = Self::parse(std::env::var("BOLD_GRAPH_PASSES").ok().as_deref());
        cfg.lut_max_fanin =
            Self::parse_lut_cap(std::env::var("BOLD_LUT_MAX_FANIN").ok().as_deref());
        cfg
    }
}

impl Default for PassConfig {
    fn default() -> Self {
        Self::all()
    }
}

/// What the pass pipeline did to a graph — reported by
/// [`PackedGraph::summary`](super::graph::PackedGraph::summary) and the
/// serve benches.
#[derive(Debug, Clone, Default)]
pub struct PassStats {
    /// The fusion pass ran.
    pub fuse: bool,
    /// The liveness pass ran.
    pub liveness: bool,
    /// `Threshold` nodes folded into their producer GEMM.
    pub fused_thresholds: usize,
    /// `MaxPool`/`GlobalAvgPool` nodes folded into their producer conv.
    pub fused_pools: usize,
    /// `Flatten` nodes elided by slot rewriting.
    pub elided_flattens: usize,
    /// The LUT-folding pass ran (enabled and fan-in cap > 0).
    pub lut: bool,
    /// Ops converted to [`PackedOp::Lut`].
    pub lut_ops: usize,
    /// Output neurons across all converted ops (one truth table each).
    pub lut_neurons: usize,
    /// Total truth-table storage in bytes across converted ops.
    pub lut_table_bytes: usize,
    /// Slot count of the naive compiler output.
    pub raw_slots: usize,
    /// Slot count after recoloring (== `raw_slots` when liveness is off
    /// or bailed).
    pub live_slots: usize,
}

/// Run the configured passes over `graph` in place and record
/// [`PassStats`] on it.
pub(crate) fn run(graph: &mut PackedGraph, cfg: PassConfig) {
    let raw = graph.n_slots;
    let mut stats = PassStats {
        fuse: cfg.fuse,
        liveness: cfg.liveness,
        raw_slots: raw,
        live_slots: raw,
        ..PassStats::default()
    };
    if cfg.fuse {
        elide_flattens(&mut graph.nodes, &mut stats);
        let uses = use_counts(&graph.nodes, raw);
        fuse_pairs(&mut graph.nodes, &uses, &mut stats);
    }
    if cfg.lut && cfg.lut_max_fanin > 0 {
        stats.lut = true;
        let cap = cfg.lut_max_fanin.min(LUT_HARD_MAX_FANIN);
        let uses = use_counts(&graph.nodes, raw);
        lut_fold(&mut graph.nodes, &uses, cap, &mut stats);
    }
    if cfg.liveness {
        if let Some(n) = recolor(&mut graph.nodes, raw) {
            graph.n_slots = n;
            stats.live_slots = n;
        }
    }
    graph.pass_stats = stats;
}

// ---------------------------------------------------------------------------
// fusion pass
// ---------------------------------------------------------------------------

/// Rewrite every read of slot `from` to slot `to` in `nodes` (recursing
/// into residual branches). Writes are never rewritten: `from` is only
/// produced by an op the caller just removed.
fn replace_reads(nodes: &mut [Node], from: usize, to: usize) {
    for nd in nodes {
        if nd.src == from {
            nd.src = to;
        }
        if let PackedOp::Residual { main, shortcut, main_out, short_out } = &mut nd.op {
            replace_reads(main, from, to);
            replace_reads(shortcut, from, to);
            if *main_out == from {
                *main_out = to;
            }
            if *short_out == from {
                *short_out = to;
            }
        }
    }
}

/// Remove `Flatten` nodes: packed bits and f32 data are already flat
/// row-major, and every consumer derives `(batch, ∏ rest)` itself, so
/// the op is pure metadata. Consumers of the flatten's output are
/// rewired to its input. Returns `(old_dst, new_src)` renames so a
/// parent `Residual` can fix up a branch-tail reference.
fn elide_flattens(nodes: &mut Vec<Node>, stats: &mut PassStats) -> Vec<(usize, usize)> {
    for nd in nodes.iter_mut() {
        if let PackedOp::Residual { main, shortcut, main_out, short_out } = &mut nd.op {
            for (from, to) in elide_flattens(main, stats) {
                if *main_out == from {
                    *main_out = to;
                }
            }
            for (from, to) in elide_flattens(shortcut, stats) {
                if *short_out == from {
                    *short_out = to;
                }
            }
        }
    }
    let mut renames = Vec::new();
    let mut i = 0;
    while i < nodes.len() {
        if matches!(nodes[i].op, PackedOp::Flatten) {
            let (src, dst) = (nodes[i].src, nodes[i].dst);
            nodes.remove(i);
            replace_reads(&mut nodes[i..], dst, src);
            renames.push((dst, src));
            stats.elided_flattens += 1;
        } else {
            i += 1;
        }
    }
    renames
}

/// Read count per slot across the whole graph (a `Residual` merge reads
/// both branch outputs).
fn use_counts(nodes: &[Node], n_slots: usize) -> Vec<usize> {
    fn walk(nodes: &[Node], uses: &mut [usize]) {
        for nd in nodes {
            match &nd.op {
                PackedOp::Residual { main, shortcut, main_out, short_out } => {
                    walk(main, uses);
                    walk(shortcut, uses);
                    uses[*main_out] += 1;
                    uses[*short_out] += 1;
                }
                _ => uses[nd.src] += 1,
            }
        }
    }
    let mut uses = vec![0usize; n_slots];
    walk(nodes, &mut uses);
    uses
}

/// Fold producer/consumer pairs in one op list (recursing into residual
/// branches). A pair fuses only when the consumer directly reads the
/// producer's output and that output has no other reader, so the
/// intermediate value can vanish entirely. The merged node keeps the
/// consumer's `dst`, which means no outer slot reference ever changes.
fn fuse_pairs(nodes: &mut Vec<Node>, uses: &[usize], stats: &mut PassStats) {
    for nd in nodes.iter_mut() {
        if let PackedOp::Residual { main, shortcut, .. } = &mut nd.op {
            fuse_pairs(main, uses, stats);
            fuse_pairs(shortcut, uses, stats);
        }
    }
    let mut i = 0;
    while i < nodes.len() {
        if i + 1 < nodes.len() && nodes[i + 1].src == nodes[i].dst && uses[nodes[i].dst] == 1 {
            let fusible = match (&nodes[i].op, &nodes[i + 1].op) {
                // conv counts → threshold: pack bits straight out of the
                // (possibly pooled) accumulator. A mean (GlobalAvg) is
                // not integer-valued, so its threshold stays standalone.
                (PackedOp::Conv2d(c), PackedOp::Threshold(spec)) => {
                    c.fused.is_none()
                        && c.pool != Some(PoolSpec::GlobalAvg)
                        && match spec {
                            ThresholdSpec::Scalar(_) => true,
                            ThresholdSpec::PerChannel(ft) => ft.thr.len() == c.c_out,
                        }
                }
                // conv counts → pool: write pooled counts directly
                (PackedOp::Conv2d(c), PackedOp::MaxPool { .. })
                | (PackedOp::Conv2d(c), PackedOp::GlobalAvgPool) => {
                    c.fused.is_none() && c.pool.is_none()
                }
                // linear counts → scalar threshold: the fused Linear op
                (PackedOp::LinearCounts(_), PackedOp::Threshold(ThresholdSpec::Scalar(_))) => true,
                _ => false,
            };
            if fusible {
                let consumer = nodes.remove(i + 1);
                let producer = &mut nodes[i];
                match (&mut producer.op, consumer.op) {
                    (PackedOp::Conv2d(c), PackedOp::Threshold(spec)) => {
                        c.fused = Some(match spec {
                            ThresholdSpec::Scalar(t) => FusedThreshold {
                                thr: vec![t; c.c_out],
                                flip: vec![false; c.c_out],
                            },
                            ThresholdSpec::PerChannel(ft) => ft,
                        });
                        stats.fused_thresholds += 1;
                    }
                    (PackedOp::Conv2d(c), PackedOp::MaxPool { k }) => {
                        c.pool = Some(PoolSpec::Max(k));
                        stats.fused_pools += 1;
                    }
                    (PackedOp::Conv2d(c), PackedOp::GlobalAvgPool) => {
                        c.pool = Some(PoolSpec::GlobalAvg);
                        stats.fused_pools += 1;
                    }
                    (op @ PackedOp::LinearCounts(_), PackedOp::Threshold(spec)) => {
                        let ThresholdSpec::Scalar(t) = spec else { unreachable!() };
                        let PackedOp::LinearCounts(mut pl) =
                            std::mem::replace(op, PackedOp::Flatten)
                        else {
                            unreachable!()
                        };
                        pl.threshold = t;
                        *op = PackedOp::Linear(pl);
                        stats.fused_thresholds += 1;
                    }
                    _ => unreachable!("guard and rewrite arms agree"),
                }
                producer.dst = consumer.dst;
                // stay at i: a conv that absorbed its pool may now also
                // absorb the following threshold
                continue;
            }
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// LUT-folding pass
// ---------------------------------------------------------------------------

/// Count one conversion into the stats.
fn note_lut(stats: &mut PassStats, lut: &PackedLut) {
    stats.lut_ops += 1;
    stats.lut_neurons += lut.n_out;
    stats.lut_table_bytes += lut.table_bytes();
}

/// Collapse Boolean layers with per-output fan-in `1..=cap` into
/// [`PackedOp::Lut`] truth-table ops (DESIGN.md §LUT-Folding, recursing
/// into residual branches). Two shapes convert:
///
/// * **Single ops** — a fused `Linear` (threshold/bias/input-mask
///   already folded in, including everything `from_mlp` produces) or a
///   `Conv2d` carrying a fused per-channel threshold epilogue. This is
///   what the pass sees after `fuse` ran, so fuse→lut composes.
/// * **Naive pairs** — `LinearCounts` + scalar `Threshold`, or an
///   unfused pool-less `Conv2d` + `Threshold`, under the same
///   single-reader pairing rule as the fusion pass. This makes
///   `BOLD_GRAPH_PASSES=lut` work alone against the naive compiler
///   output.
///
/// Convs that pool their counts (the threshold compares pooled values,
/// not raw fan-in counts) and layers above the cap stay untouched —
/// bit-exactness never depends on this pass running.
fn lut_fold(nodes: &mut Vec<Node>, uses: &[usize], cap: usize, stats: &mut PassStats) {
    for nd in nodes.iter_mut() {
        if let PackedOp::Residual { main, shortcut, .. } = &mut nd.op {
            lut_fold(main, uses, cap, stats);
            lut_fold(shortcut, uses, cap, stats);
        }
    }
    let mut i = 0;
    while i < nodes.len() {
        // pair forms first: the naive compiler output
        if i + 1 < nodes.len() && nodes[i + 1].src == nodes[i].dst && uses[nodes[i].dst] == 1 {
            let lut = match (&nodes[i].op, &nodes[i + 1].op) {
                (PackedOp::LinearCounts(l), PackedOp::Threshold(ThresholdSpec::Scalar(t)))
                    if (1..=cap).contains(&l.weights.cols) =>
                {
                    Some(PackedLut::from_linear_thr(l, *t))
                }
                (PackedOp::Conv2d(c), PackedOp::Threshold(spec))
                    if c.fused.is_none()
                        && c.pool.is_none()
                        && (1..=cap).contains(&c.weights.cols) =>
                {
                    match spec {
                        ThresholdSpec::Scalar(t) => Some(PackedLut::from_conv(
                            c,
                            &FusedThreshold {
                                thr: vec![*t; c.c_out],
                                flip: vec![false; c.c_out],
                            },
                        )),
                        ThresholdSpec::PerChannel(ft) if ft.thr.len() == c.c_out => {
                            Some(PackedLut::from_conv(c, ft))
                        }
                        _ => None,
                    }
                }
                _ => None,
            };
            if let Some(lut) = lut {
                note_lut(stats, &lut);
                let consumer = nodes.remove(i + 1);
                nodes[i].op = PackedOp::Lut(lut);
                nodes[i].dst = consumer.dst;
                i += 1;
                continue;
            }
        }
        // single-op forms: post-fusion output and from_mlp graphs
        let lut = match &nodes[i].op {
            PackedOp::Linear(l) if (1..=cap).contains(&l.weights.cols) => {
                Some(PackedLut::from_linear(l))
            }
            PackedOp::Conv2d(c)
                if c.pool.is_none() && (1..=cap).contains(&c.weights.cols) =>
            {
                c.fused.as_ref().map(|ft| PackedLut::from_conv(c, ft))
            }
            _ => None,
        };
        if let Some(lut) = lut {
            note_lut(stats, &lut);
            nodes[i].op = PackedOp::Lut(lut);
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// liveness pass
// ---------------------------------------------------------------------------

/// Per-slot def/use positions on the linearized schedule. Position 0 is
/// the input seed into slot 0; every executed op gets the next position
/// in execution order (residual branches first, then the merge).
struct Liveness {
    def: Vec<Option<usize>>,
    last_use: Vec<Option<usize>>,
    ok: bool,
}

impl Liveness {
    fn read(&mut self, slot: usize, pos: usize) {
        match self.def[slot] {
            Some(d) if d <= pos => self.last_use[slot] = Some(pos),
            _ => self.ok = false, // use before def: not the compiler's SSA
        }
    }

    fn write(&mut self, slot: usize, pos: usize) {
        if self.def[slot].is_some() {
            self.ok = false; // double def: not the compiler's SSA
        } else {
            self.def[slot] = Some(pos);
        }
    }

    fn walk(&mut self, nodes: &[Node], pos: &mut usize) {
        for nd in nodes {
            match &nd.op {
                PackedOp::Residual { main, shortcut, main_out, short_out } => {
                    self.walk(main, pos);
                    self.walk(shortcut, pos);
                    let t = *pos;
                    *pos += 1;
                    // the merge reads both branch outputs (an empty
                    // branch forwards the residual input slot)
                    self.read(*main_out, t);
                    self.read(*short_out, t);
                    self.write(nd.dst, t);
                }
                PackedOp::FpHead { .. } => {
                    // reads its src, writes the logits buffer — the dst
                    // slot is vestigial and never materialized
                    let t = *pos;
                    *pos += 1;
                    self.read(nd.src, t);
                }
                _ => {
                    let t = *pos;
                    *pos += 1;
                    self.read(nd.src, t);
                    self.write(nd.dst, t);
                }
            }
        }
    }
}

/// Every slot index the rewrite will touch must have a color.
fn refs_colored(nodes: &[Node], color: &[usize]) -> bool {
    nodes.iter().all(|nd| {
        let own = match &nd.op {
            PackedOp::Residual { main, shortcut, main_out, short_out } => {
                refs_colored(main, color)
                    && refs_colored(shortcut, color)
                    && color[*main_out] != usize::MAX
                    && color[*short_out] != usize::MAX
                    && color[nd.dst] != usize::MAX
            }
            PackedOp::FpHead { .. } => true,
            _ => color[nd.dst] != usize::MAX,
        };
        own && color[nd.src] != usize::MAX
    })
}

fn apply_colors(nodes: &mut [Node], color: &[usize]) {
    for nd in nodes {
        nd.src = color[nd.src];
        match &mut nd.op {
            PackedOp::Residual { main, shortcut, main_out, short_out } => {
                apply_colors(main, color);
                apply_colors(shortcut, color);
                *main_out = color[*main_out];
                *short_out = color[*short_out];
                nd.dst = color[nd.dst];
            }
            PackedOp::FpHead { .. } => {
                // keep the vestigial dst a valid in-range index
                nd.dst = nd.src;
            }
            _ => nd.dst = color[nd.dst],
        }
    }
}

/// Linear-scan slot recoloring. Returns the compacted slot count, or
/// `None` (leave the graph untouched) when the op list does not follow
/// the compiler's SSA discipline.
///
/// A color frees only when its value's last read is *strictly before*
/// the defining position of the next value, so an op's `dst` can never
/// receive the color of any slot it still reads — including both
/// residual branch outputs, which the merge reads at its own position.
fn recolor(nodes: &mut [Node], n_slots: usize) -> Option<usize> {
    let mut lv = Liveness {
        def: vec![None; n_slots],
        last_use: vec![None; n_slots],
        ok: !nodes.is_empty() && n_slots > 0,
    };
    if n_slots > 0 {
        lv.def[0] = Some(0); // the input seed
    }
    let mut pos = 1usize;
    lv.walk(nodes, &mut pos);
    if !lv.ok {
        return None;
    }

    let mut events: Vec<(usize, usize)> =
        (0..n_slots).filter_map(|s| lv.def[s].map(|p| (p, s))).collect();
    events.sort_unstable();
    let mut color = vec![usize::MAX; n_slots];
    let mut free: BTreeSet<usize> = BTreeSet::new();
    let mut active: Vec<(usize, usize)> = Vec::new(); // (expiry, color)
    let mut next_color = 0usize;
    for (p, s) in events {
        let mut i = 0;
        while i < active.len() {
            if active[i].0 < p {
                free.insert(active[i].1);
                active.swap_remove(i);
            } else {
                i += 1;
            }
        }
        let c = match free.iter().next().copied() {
            Some(c) => {
                free.remove(&c);
                c
            }
            None => {
                next_color += 1;
                next_color - 1
            }
        };
        color[s] = c;
        // a value never read still occupies its slot at its own def
        active.push((lv.last_use[s].unwrap_or(p), c));
    }
    if color.first() != Some(&0) || !refs_colored(nodes, &color) {
        return None; // structurally odd graph: keep identity coloring
    }
    apply_colors(nodes, &color);
    Some(next_color)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pass_config_parsing() {
        assert_eq!(PassConfig::parse(None), PassConfig::all());
        assert_eq!(PassConfig::parse(Some("all")), PassConfig::all());
        assert_eq!(PassConfig::parse(Some("none")), PassConfig::none());
        assert_eq!(
            PassConfig::parse(Some("fuse")),
            PassConfig { fuse: true, ..PassConfig::none() }
        );
        assert_eq!(
            PassConfig::parse(Some(" liveness ")),
            PassConfig { liveness: true, ..PassConfig::none() }
        );
        // unrecognized values select the full pipeline rather than
        // silently serving unoptimized
        assert_eq!(PassConfig::parse(Some("bogus")), PassConfig::all());
    }

    #[test]
    fn pass_config_parses_lut_token_alone_and_in_combination() {
        assert_eq!(
            PassConfig::parse(Some("lut")),
            PassConfig { lut: true, ..PassConfig::none() }
        );
        assert_eq!(
            PassConfig::parse(Some("fuse,lut")),
            PassConfig { fuse: true, lut: true, ..PassConfig::none() }
        );
        assert_eq!(
            PassConfig::parse(Some(" lut , liveness ")),
            PassConfig { lut: true, liveness: true, ..PassConfig::none() }
        );
        assert_eq!(
            PassConfig::parse(Some("fuse,liveness,lut")),
            PassConfig::all()
        );
        // an unknown token anywhere in the list falls back to the full
        // pipeline, same as the single-token case
        assert_eq!(PassConfig::parse(Some("fuse,bogus")), PassConfig::all());
        assert_eq!(PassConfig::parse(Some("lut,nope")), PassConfig::all());
    }

    #[test]
    fn lut_cap_parsing_bounds() {
        // unset/empty keep the default
        assert_eq!(PassConfig::parse_lut_cap(None), LUT_DEFAULT_MAX_FANIN);
        assert_eq!(PassConfig::parse_lut_cap(Some("")), LUT_DEFAULT_MAX_FANIN);
        assert_eq!(PassConfig::parse_lut_cap(Some("  ")), LUT_DEFAULT_MAX_FANIN);
        // 0 disables the pass; anything up to the gather word width parses
        assert_eq!(PassConfig::parse_lut_cap(Some("0")), 0);
        assert_eq!(PassConfig::parse_lut_cap(Some("7")), 7);
        assert_eq!(PassConfig::parse_lut_cap(Some(" 10 ")), 10);
        assert_eq!(PassConfig::parse_lut_cap(Some("64")), 64);
        // above the word width / non-numeric / negative → default
        assert_eq!(PassConfig::parse_lut_cap(Some("65")), LUT_DEFAULT_MAX_FANIN);
        assert_eq!(PassConfig::parse_lut_cap(Some("-1")), LUT_DEFAULT_MAX_FANIN);
        assert_eq!(PassConfig::parse_lut_cap(Some("abc")), LUT_DEFAULT_MAX_FANIN);
        assert_eq!(PassConfig::parse_lut_cap(Some("1e3")), LUT_DEFAULT_MAX_FANIN);
    }
}
