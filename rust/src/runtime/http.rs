//! Incremental, zero-dependency HTTP/1.1 request parser and response
//! writer (DESIGN.md §Network-Front-End).
//!
//! The parser is built for a serving hot loop, not a general web stack:
//!
//! * **incremental** — [`HttpParser::feed`] accepts bytes in arbitrary
//!   chunks (one syscall's worth, or one byte at a time from a
//!   slow-loris client) and is split-point invariant: any partition of
//!   the byte stream produces the identical parse
//!   (`tests/http_parser.rs` proves this for every boundary);
//! * **bounded** — head bytes, header count and declared body length are
//!   all capped by [`HttpLimits`]; violations surface as typed
//!   [`HttpError`]s carrying the status code to send back (431/413/…),
//!   so a hostile peer can never make the connection buffer grow without
//!   bound;
//! * **allocation-free in steady state** — one reusable byte buffer and
//!   one reusable header-range table per connection; parsed fields are
//!   index ranges into the buffer, and [`HttpParser::consume`] recycles
//!   both for the next keep-alive request without shrinking capacity;
//! * **panic-free on arbitrary input** — every malformed byte pattern
//!   maps to a clean `HttpError` (the property suite feeds random
//!   mutations and asserts no panic ever escapes).
//!
//! Deliberate non-goals, rejected with precise statuses rather than
//! misparsed: chunked transfer encoding (501), HTTP/2+ (505), multiline
//! header folding (400).

use std::fmt;

/// Hard caps on what one request may buffer.
#[derive(Debug, Clone)]
pub struct HttpLimits {
    /// Request line + headers + blank line, in bytes (431 when exceeded).
    pub max_head_bytes: usize,
    /// Declared `Content-Length` cap in bytes (413 when exceeded).
    pub max_body_bytes: usize,
    /// Header count cap (431 when exceeded).
    pub max_headers: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits {
            max_head_bytes: 16 * 1024,
            max_body_bytes: 1 << 20,
            max_headers: 64,
        }
    }
}

/// A parse failure, carrying the HTTP status the connection should
/// answer with before closing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl HttpError {
    pub fn new(status: u16, msg: impl Into<String>) -> Self {
        HttpError { status, msg: msg.into() }
    }
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}: {}", self.status, reason(self.status), self.msg)
    }
}

impl std::error::Error for HttpError {}

/// Result of feeding bytes: either a full request is buffered and
/// every accessor is valid, or more bytes are needed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parse {
    /// The request (head + declared body) is complete.
    Ready,
    /// Valid so far; keep reading.
    NeedMore,
}

type Range = (usize, usize);

/// Incremental parser for one connection. Reuse across keep-alive
/// requests via [`HttpParser::consume`]; a returned [`HttpError`] is
/// sticky — the connection is expected to answer it and close.
pub struct HttpParser {
    limits: HttpLimits,
    buf: Vec<u8>,
    /// Newline scan cursor (avoids rescanning on byte-at-a-time feeds).
    scan: usize,
    /// Byte offset where the current line started.
    line_start: usize,
    head_done: bool,
    /// Head length including the blank line, once `head_done`.
    head_len: usize,
    /// Declared body length (0 when absent).
    body_len: usize,
    method: Range,
    path: Range,
    http11: bool,
    keep_alive: bool,
    expect_continue: bool,
    headers: Vec<(Range, Range)>,
    err: Option<HttpError>,
}

impl HttpParser {
    pub fn new(limits: HttpLimits) -> Self {
        HttpParser {
            limits,
            buf: Vec::with_capacity(1024),
            scan: 0,
            line_start: 0,
            head_done: false,
            head_len: 0,
            body_len: 0,
            method: (0, 0),
            path: (0, 0),
            http11: true,
            keep_alive: true,
            expect_continue: false,
            headers: Vec::with_capacity(16),
            err: None,
        }
    }

    /// Bytes currently buffered (bounded-memory assertion hook).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Append `bytes` and advance the parse.
    pub fn feed(&mut self, bytes: &[u8]) -> Result<Parse, HttpError> {
        if let Some(e) = &self.err {
            return Err(e.clone());
        }
        self.buf.extend_from_slice(bytes);
        match self.advance() {
            Ok(p) => Ok(p),
            Err(e) => {
                self.err = Some(e.clone());
                Err(e)
            }
        }
    }

    fn advance(&mut self) -> Result<Parse, HttpError> {
        if !self.head_done {
            // scan for the blank line ending the head, one line at a time
            while !self.head_done {
                let Some(nl) = self.buf[self.scan..].iter().position(|&b| b == b'\n') else {
                    self.scan = self.buf.len();
                    if self.buf.len() > self.limits.max_head_bytes {
                        return Err(HttpError::new(
                            431,
                            format!("request head exceeds {} bytes", self.limits.max_head_bytes),
                        ));
                    }
                    return Ok(Parse::NeedMore);
                };
                let nl = self.scan + nl;
                let mut line_end = nl;
                if line_end > self.line_start && self.buf[line_end - 1] == b'\r' {
                    line_end -= 1;
                }
                let line = (self.line_start, line_end);
                let at_request_line = self.line_start == 0;
                self.scan = nl + 1;
                self.line_start = nl + 1;
                if nl + 1 > self.limits.max_head_bytes {
                    return Err(HttpError::new(
                        431,
                        format!("request head exceeds {} bytes", self.limits.max_head_bytes),
                    ));
                }
                if line.0 == line.1 {
                    if at_request_line {
                        // tolerate leading blank line(s)? No: strict 400,
                        // an empty request line is malformed.
                        return Err(HttpError::new(400, "empty request line"));
                    }
                    self.head_len = nl + 1;
                    self.head_done = true;
                    self.finish_head()?;
                    break;
                }
                if at_request_line {
                    self.parse_request_line(line)?;
                } else {
                    self.parse_header_line(line)?;
                }
            }
        }
        if self.buf.len() >= self.head_len + self.body_len {
            Ok(Parse::Ready)
        } else {
            Ok(Parse::NeedMore)
        }
    }

    fn parse_request_line(&mut self, (s, e): Range) -> Result<(), HttpError> {
        // METHOD SP PATH SP VERSION — exactly three tokens
        let line = &self.buf[s..e];
        if line.iter().any(|&b| b < 0x20 || b == 0x7f) {
            return Err(HttpError::new(400, "control byte in request line"));
        }
        let mut parts = [(0usize, 0usize); 3];
        let mut n = 0;
        let mut i = 0;
        while i < line.len() {
            if line[i] == b' ' {
                i += 1;
                continue;
            }
            let start = i;
            while i < line.len() && line[i] != b' ' {
                i += 1;
            }
            if n == 3 {
                return Err(HttpError::new(400, "malformed request line"));
            }
            parts[n] = (s + start, s + i);
            n += 1;
        }
        if n != 3 {
            return Err(HttpError::new(400, "malformed request line"));
        }
        let method = &self.buf[parts[0].0..parts[0].1];
        if method.is_empty() || method.len() > 16 || !method.iter().all(u8::is_ascii_uppercase) {
            return Err(HttpError::new(400, "malformed method"));
        }
        let path = &self.buf[parts[1].0..parts[1].1];
        if path.first() != Some(&b'/') {
            return Err(HttpError::new(400, "request target must be origin-form (/path)"));
        }
        let version = &self.buf[parts[2].0..parts[2].1];
        self.http11 = match version {
            b"HTTP/1.1" => true,
            b"HTTP/1.0" => false,
            _ => return Err(HttpError::new(505, "only HTTP/1.0 and HTTP/1.1 are supported")),
        };
        self.keep_alive = self.http11;
        self.method = parts[0];
        self.path = parts[1];
        Ok(())
    }

    fn parse_header_line(&mut self, (s, e): Range) -> Result<(), HttpError> {
        let line = &self.buf[s..e];
        if line.iter().any(|&b| b < 0x20 && b != b'\t' || b == 0x7f) {
            return Err(HttpError::new(400, "control byte in header"));
        }
        if line[0] == b' ' || line[0] == b'\t' {
            return Err(HttpError::new(400, "obsolete header folding is not supported"));
        }
        let Some(colon) = line.iter().position(|&b| b == b':') else {
            return Err(HttpError::new(400, "header line without ':'"));
        };
        let name = &line[..colon];
        if name.is_empty()
            || !name
                .iter()
                .all(|&b| b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.'))
        {
            return Err(HttpError::new(400, "malformed header name"));
        }
        // trim optional whitespace around the value
        let mut vs = colon + 1;
        let mut ve = line.len();
        while vs < ve && (line[vs] == b' ' || line[vs] == b'\t') {
            vs += 1;
        }
        while ve > vs && (line[ve - 1] == b' ' || line[ve - 1] == b'\t') {
            ve -= 1;
        }
        if self.headers.len() == self.limits.max_headers {
            return Err(HttpError::new(
                431,
                format!("more than {} headers", self.limits.max_headers),
            ));
        }
        self.headers.push(((s, s + colon), (s + vs, s + ve)));
        Ok(())
    }

    /// Head fully buffered: resolve framing + connection semantics.
    fn finish_head(&mut self) -> Result<(), HttpError> {
        if self.header("transfer-encoding").is_some() {
            return Err(HttpError::new(
                501,
                "transfer-encoding is not supported; send Content-Length",
            ));
        }
        let mut body_len = 0usize;
        match self.header("content-length") {
            Some(v) => {
                let v = v.trim();
                body_len = v
                    .parse::<u64>()
                    .ok()
                    .and_then(|n| usize::try_from(n).ok())
                    .ok_or_else(|| HttpError::new(400, "malformed Content-Length"))?;
            }
            None => {
                if self.method() == "POST" || self.method() == "PUT" {
                    return Err(HttpError::new(411, "POST requires Content-Length"));
                }
            }
        }
        if body_len > self.limits.max_body_bytes {
            return Err(HttpError::new(
                413,
                format!("body of {body_len} bytes exceeds cap {}", self.limits.max_body_bytes),
            ));
        }
        self.body_len = body_len;
        let conn = self.header("connection").map(|c| {
            if c.eq_ignore_ascii_case("close") {
                Some(false)
            } else if c.eq_ignore_ascii_case("keep-alive") {
                Some(true)
            } else {
                None
            }
        });
        if let Some(Some(ka)) = conn {
            self.keep_alive = ka;
        }
        let expect = self.header("expect").map(|ex| ex.eq_ignore_ascii_case("100-continue"));
        match expect {
            Some(true) => self.expect_continue = true,
            Some(false) => return Err(HttpError::new(417, "unsupported Expect")),
            None => {}
        }
        Ok(())
    }

    // -- accessors (valid once the head has parsed; empty/default before) --

    fn str_at(&self, (s, e): Range) -> &str {
        // head bytes were verified ASCII-printable during the line parses
        std::str::from_utf8(&self.buf[s..e]).unwrap_or("")
    }

    /// True once the request line + headers are fully parsed (the body
    /// may still be streaming in) — the point to answer `Expect:
    /// 100-continue`.
    pub fn head_complete(&self) -> bool {
        self.head_done
    }

    pub fn method(&self) -> &str {
        self.str_at(self.method)
    }

    pub fn path(&self) -> &str {
        self.str_at(self.path)
    }

    /// Case-insensitive single-header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| self.str_at(*n).eq_ignore_ascii_case(name))
            .map(|(_, v)| self.str_at(*v))
    }

    pub fn num_headers(&self) -> usize {
        self.headers.len()
    }

    /// Declared body length.
    pub fn content_length(&self) -> usize {
        self.body_len
    }

    /// The request body (complete only in the `Ready` state).
    pub fn body(&self) -> &[u8] {
        let s = self.head_len.min(self.buf.len());
        let e = (self.head_len + self.body_len).min(self.buf.len());
        &self.buf[s..e]
    }

    /// Connection persistence after this request (version default +
    /// `Connection:` override).
    pub fn keep_alive(&self) -> bool {
        self.keep_alive
    }

    pub fn is_http11(&self) -> bool {
        self.http11
    }

    pub fn expects_continue(&self) -> bool {
        self.expect_continue
    }

    /// Full reset for reuse on a *new connection*: drops all buffered
    /// bytes (keeping capacity) and clears any sticky error.
    pub fn reset(&mut self) {
        self.buf.clear();
        self.scan = 0;
        self.line_start = 0;
        self.head_done = false;
        self.head_len = 0;
        self.body_len = 0;
        self.method = (0, 0);
        self.path = (0, 0);
        self.http11 = true;
        self.keep_alive = true;
        self.expect_continue = false;
        self.headers.clear();
        self.err = None;
    }

    /// Drop the parsed request's bytes (keeping any pipelined tail) and
    /// reset for the next request on this connection. Capacity is kept —
    /// the steady-state keep-alive loop does not allocate.
    pub fn consume(&mut self) -> Result<Parse, HttpError> {
        debug_assert!(self.head_done, "consume before a complete head");
        let total = (self.head_len + self.body_len).min(self.buf.len());
        self.buf.drain(..total);
        self.scan = 0;
        self.line_start = 0;
        self.head_done = false;
        self.head_len = 0;
        self.body_len = 0;
        self.method = (0, 0);
        self.path = (0, 0);
        self.http11 = true;
        self.keep_alive = true;
        self.expect_continue = false;
        self.headers.clear();
        self.err = None;
        self.advance().inspect_err(|e| self.err = Some(e.clone()))
    }
}

/// Canonical reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        100 => "Continue",
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        411 => "Length Required",
        413 => "Payload Too Large",
        417 => "Expectation Failed",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Reusable response serializer: renders one flat buffer per response so
/// the socket write is a single `write_all` (no interleaving, no partial
/// heads on a killed connection).
pub struct ResponseWriter {
    buf: Vec<u8>,
}

impl ResponseWriter {
    pub fn new() -> Self {
        ResponseWriter { buf: Vec::with_capacity(512) }
    }

    /// Render `status` + headers + body. `extra` headers are emitted
    /// verbatim; `Content-Length` and `Connection` are always set here.
    pub fn render(
        &mut self,
        status: u16,
        extra: &[(&str, &str)],
        body: &[u8],
        keep_alive: bool,
    ) -> &[u8] {
        use std::io::Write;
        self.buf.clear();
        let _ = write!(self.buf, "HTTP/1.1 {status} {}\r\n", reason(status));
        let _ = write!(self.buf, "Content-Length: {}\r\n", body.len());
        let _ = write!(
            self.buf,
            "Connection: {}\r\n",
            if keep_alive { "keep-alive" } else { "close" }
        );
        for (k, v) in extra {
            let _ = write!(self.buf, "{k}: {v}\r\n");
        }
        self.buf.extend_from_slice(b"\r\n");
        self.buf.extend_from_slice(body);
        &self.buf
    }
}

impl Default for ResponseWriter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_all(bytes: &[u8]) -> (HttpParser, Result<Parse, HttpError>) {
        let mut p = HttpParser::new(HttpLimits::default());
        let r = p.feed(bytes);
        (p, r)
    }

    #[test]
    fn parses_simple_get() {
        let (p, r) = parse_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(r, Ok(Parse::Ready));
        assert_eq!(p.method(), "GET");
        assert_eq!(p.path(), "/healthz");
        assert_eq!(p.header("host"), Some("x"));
        assert_eq!(p.header("HOST"), Some("x"));
        assert!(p.keep_alive());
        assert_eq!(p.body(), b"");
    }

    #[test]
    fn parses_post_with_body_incrementally() {
        let raw = b"POST /v1/models/m/predict HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        let mut p = HttpParser::new(HttpLimits::default());
        for b in &raw[..raw.len() - 1] {
            assert_eq!(p.feed(std::slice::from_ref(b)), Ok(Parse::NeedMore));
        }
        assert_eq!(p.feed(&raw[raw.len() - 1..]), Ok(Parse::Ready));
        assert_eq!(p.method(), "POST");
        assert_eq!(p.body(), b"hello");
    }

    #[test]
    fn lf_only_line_endings_accepted() {
        let (p, r) = parse_all(b"GET / HTTP/1.1\nHost: y\n\n");
        assert_eq!(r, Ok(Parse::Ready));
        assert_eq!(p.header("host"), Some("y"));
    }

    #[test]
    fn http10_defaults_to_close() {
        let (p, r) = parse_all(b"GET / HTTP/1.0\r\n\r\n");
        assert_eq!(r, Ok(Parse::Ready));
        assert!(!p.keep_alive());
    }

    #[test]
    fn connection_close_honoured() {
        let (p, _) = parse_all(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(!p.keep_alive());
    }

    #[test]
    fn rejects_malformed_inputs_with_statuses() {
        for (raw, status) in [
            (&b"BADLY FORMED\r\n\r\n"[..], 400),
            (b"GET /x HTTP/2.0\r\n\r\n", 505),
            (b"get / HTTP/1.1\r\n\r\n", 400),
            (b"GET x HTTP/1.1\r\n\r\n", 400),
            (b"GET / HTTP/1.1\r\nNoColon\r\n\r\n", 400),
            (b"GET / HTTP/1.1\r\n: novalue\r\n\r\n", 400),
            (b"POST / HTTP/1.1\r\n\r\n", 411),
            (b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n", 400),
            (b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 501),
            (b"GET / HTTP/1.1\r\nExpect: voodoo\r\n\r\n", 417),
            (b"\r\n\r\n", 400),
        ] {
            let (_, r) = parse_all(raw);
            assert_eq!(
                r.err().map(|e| e.status),
                Some(status),
                "input {:?}",
                String::from_utf8_lossy(raw)
            );
        }
    }

    #[test]
    fn oversized_head_and_body_capped() {
        let limits = HttpLimits { max_head_bytes: 64, max_body_bytes: 16, max_headers: 4 };
        let mut p = HttpParser::new(limits.clone());
        // no newline at all: cap still fires
        let r = p.feed(&[b'A'; 65]);
        assert_eq!(r.err().map(|e| e.status), Some(431));

        let mut p = HttpParser::new(limits.clone());
        let r = p.feed(b"POST / HTTP/1.1\r\nContent-Length: 17\r\n\r\n");
        assert_eq!(r.err().map(|e| e.status), Some(413));

        let mut p = HttpParser::new(limits);
        let r = p.feed(b"GET / HTTP/1.1\r\na: 1\r\nb: 2\r\nc: 3\r\nd: 4\r\ne: 5\r\n\r\n");
        assert_eq!(r.err().map(|e| e.status), Some(431));
    }

    #[test]
    fn errors_are_sticky() {
        let mut p = HttpParser::new(HttpLimits::default());
        assert!(p.feed(b"BAD\r\n\r\n").is_err());
        assert_eq!(p.feed(b"GET / HTTP/1.1\r\n\r\n").err().map(|e| e.status), Some(400));
    }

    #[test]
    fn keep_alive_consume_recycles_and_pipelines() {
        let mut p = HttpParser::new(HttpLimits::default());
        let two = b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /b HTTP/1.1\r\n\r\n";
        assert_eq!(p.feed(two), Ok(Parse::Ready));
        assert_eq!(p.path(), "/a");
        assert_eq!(p.body(), b"hi");
        // second pipelined request becomes ready straight from consume
        assert_eq!(p.consume(), Ok(Parse::Ready));
        assert_eq!(p.method(), "GET");
        assert_eq!(p.path(), "/b");
        assert_eq!(p.body(), b"");
        assert_eq!(p.consume(), Ok(Parse::NeedMore));
        assert_eq!(p.buffered(), 0);
    }

    #[test]
    fn expect_continue_detected_at_head() {
        let mut p = HttpParser::new(HttpLimits::default());
        let r = p.feed(b"POST / HTTP/1.1\r\nContent-Length: 3\r\nExpect: 100-continue\r\n\r\n");
        assert_eq!(r, Ok(Parse::NeedMore));
        assert!(p.head_complete());
        assert!(p.expects_continue());
        assert_eq!(p.feed(b"abc"), Ok(Parse::Ready));
    }

    #[test]
    fn response_writer_renders_exact_bytes() {
        let mut w = ResponseWriter::new();
        let out = w.render(503, &[("Retry-After", "1")], b"busy", false);
        let s = std::str::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(s.contains("Content-Length: 4\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.contains("Retry-After: 1\r\n"));
        assert!(s.ends_with("\r\n\r\nbusy"));
    }
}
