//! Open-loop HTTP load harness for the network front-end
//! (DESIGN.md §Network-Front-End, EXPERIMENTS.md §Perf).
//!
//! The point of *open-loop* generation: requests are fired on a fixed
//! arrival schedule (`t_i = t_0 + i/λ`) regardless of whether earlier
//! requests have completed. A closed-loop driver (send → wait → send)
//! self-throttles when the server slows down, which silently hides
//! overload — exactly the regime the BOLD serving claim is about.
//! Latency here is measured **from the scheduled arrival time**, not
//! from the actual send, so queueing delay caused by a saturated server
//! (or a busy sender thread) is charged to the server — the
//! coordinated-omission-corrected number.
//!
//! Zero-dependency client: hand-rolled HTTP/1.1 over `TcpStream` with
//! keep-alive, one outstanding request per connection, reconnect on
//! error. Used by `benches/bench_serve.rs` (0.5×/1×/2× saturation
//! sweep) and by the CI fixed-rate smoke test in `tests/net_parity.rs`.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Outcome of one load run.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Target arrival rate (requests/second).
    pub offered_per_s: f64,
    /// Wall-clock duration of the measured window.
    pub duration_s: f64,
    /// Requests actually sent (≈ offered × duration; lateness never
    /// drops arrivals, they fire back-to-back when behind schedule).
    pub sent: usize,
    /// `200` responses.
    pub ok: usize,
    /// `503` shed responses (the deliberate overload answer).
    pub shed: usize,
    /// `504` deadline expiries.
    pub expired: usize,
    /// Other `4xx` responses.
    pub other_4xx: usize,
    /// `5xx` other than 503/504 — should be **zero** in any healthy run.
    pub other_5xx: usize,
    /// [`LoadReport::other_5xx`] broken down by status code (sorted
    /// ascending). `500` here means worker panics / model failures —
    /// distinguishable from shed load (`503`) and deadline pressure
    /// (`504`), which is what the canary and chaos runs diff on.
    pub by_5xx: Vec<(u16, usize)>,
    /// Residual transport failures (reset/EOF mid-stream) — what is left
    /// of the old catch-all after [`LoadReport::timeouts`] and
    /// [`LoadReport::connect_errors`] are split out.
    pub io_errors: usize,
    /// Socket read/write deadlines hit mid-roundtrip (a hung server).
    pub timeouts: usize,
    /// Failures to establish the TCP connection (refused, unreachable).
    pub connect_errors: usize,
    /// Latency percentiles over successful (`200`) requests, µs,
    /// measured from the scheduled arrival time.
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    /// Successful responses per second of the measured window.
    pub goodput_per_s: f64,
}

impl LoadReport {
    /// Merge percentile inputs happens in [`open_loop`]; this is the
    /// one-line human summary used by the bench and the CLI.
    pub fn summary(&self) -> String {
        format!(
            "offered {:>8.0}/s  goodput {:>8.0}/s  shed {:>5}  504 {:>3}  err {:>3}  \
             p50 {:>8.1}µs  p99 {:>9.1}µs  p999 {:>9.1}µs",
            self.offered_per_s,
            self.goodput_per_s,
            self.shed,
            self.expired,
            self.other_4xx + self.other_5xx + self.io_errors + self.timeouts + self.connect_errors,
            self.p50_us,
            self.p99_us,
            self.p999_us
        )
    }
}

/// Classified transport failure — which [`LoadReport`] bucket an
/// `Err` from [`Client::roundtrip_classified`] belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum IoClass {
    /// `TcpStream::connect` itself failed — the server is down/refusing.
    Connect,
    /// A read/write deadline fired mid-roundtrip — the server is hung.
    Timeout,
    /// Residual: reset/EOF mid-stream, protocol garbage, etc.
    Io,
}

/// One keep-alive client connection with reusable buffers.
struct Client {
    addr: String,
    stream: Option<TcpStream>,
    buf: Vec<u8>,
}

impl Client {
    fn new(addr: &str) -> Self {
        Client { addr: addr.to_string(), stream: None, buf: Vec::with_capacity(4096) }
    }

    fn ensure_connected(&mut self) -> std::io::Result<()> {
        if self.stream.is_none() {
            let s = TcpStream::connect(&self.addr)?;
            s.set_nodelay(true)?;
            s.set_read_timeout(Some(Duration::from_secs(10)))?;
            s.set_write_timeout(Some(Duration::from_secs(10)))?;
            self.stream = Some(s);
        }
        Ok(())
    }

    /// Send `request` (a fully rendered HTTP/1.1 request) and read one
    /// response. Returns the status code and whether the server asked to
    /// close. The response body is read to completion (keep-alive
    /// framing) but not returned — the load path only needs the status.
    fn roundtrip(&mut self, request: &[u8]) -> std::io::Result<u16> {
        self.roundtrip_classified(request).map_err(|(_, e)| e)
    }

    /// [`Client::roundtrip`] plus an [`IoClass`] tag on failure, so
    /// [`open_loop`] can split the old `io_errors` catch-all into
    /// connect / timeout / residual buckets.
    fn roundtrip_classified(&mut self, request: &[u8]) -> Result<u16, (IoClass, std::io::Error)> {
        match self.roundtrip_inner(request) {
            Ok(s) => Ok(s),
            Err(e) => {
                self.stream = None; // force reconnect after any transport error
                let class = match e.kind() {
                    // refused/unreachable surface from `connect`; a live
                    // kernel never yields them mid-stream
                    ErrorKind::ConnectionRefused | ErrorKind::AddrNotAvailable => IoClass::Connect,
                    ErrorKind::TimedOut | ErrorKind::WouldBlock => IoClass::Timeout,
                    _ => IoClass::Io,
                };
                Err((class, e))
            }
        }
    }

    fn roundtrip_inner(&mut self, request: &[u8]) -> std::io::Result<u16> {
        self.ensure_connected()?;
        // disjoint field borrows: `stream` and `buf` come straight off
        // `self` so both can be held mutably at once
        let stream = self.stream.as_mut().expect("connected above");
        let buf = &mut self.buf;
        stream.write_all(request)?;
        buf.clear();
        let mut chunk = [0u8; 4096];
        // read head
        let head_len = loop {
            if let Some(p) = find_head_end(buf) {
                break p;
            }
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed mid-response",
                ));
            }
            buf.extend_from_slice(&chunk[..n]);
        };
        let head = std::str::from_utf8(&buf[..head_len])
            .map_err(|_| std::io::Error::new(ErrorKind::InvalidData, "non-utf8 head"))?;
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| std::io::Error::new(ErrorKind::InvalidData, "bad status line"))?;
        let content_length: usize = head
            .lines()
            .find_map(|l| {
                let (k, v) = l.split_once(':')?;
                k.trim().eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
            })
            .unwrap_or(0);
        let close = head.lines().any(|l| {
            l.split_once(':').is_some_and(|(k, v)| {
                k.trim().eq_ignore_ascii_case("connection")
                    && v.trim().eq_ignore_ascii_case("close")
            })
        });
        // read body to completion so the connection stays framed
        while buf.len() < head_len + content_length {
            let n = stream.read(&mut chunk)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed mid-body",
                ));
            }
            buf.extend_from_slice(&chunk[..n]);
        }
        if close {
            self.stream = None;
        }
        Ok(status)
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Render a `POST /v1/models/<model>/predict` request for `body`.
pub fn render_predict(model: &str, body: &[u8], content_type: &str) -> Vec<u8> {
    let mut req = format!(
        "POST /v1/models/{model}/predict HTTP/1.1\r\nHost: bold\r\nContent-Type: \
         {content_type}\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )
    .into_bytes();
    req.extend_from_slice(body);
    req
}

/// Blocking single request against `addr` (test/CLI convenience):
/// returns `(status, response_ok_count == 1)` style status only.
pub fn one_shot(addr: &str, request: &[u8]) -> std::io::Result<u16> {
    let mut c = Client::new(addr);
    c.roundtrip(request)
}

/// Closed-loop saturation probe: `conns` connections each firing
/// back-to-back predict requests for `duration`. Returns achieved
/// requests/second — the denominator for the 0.5×/1×/2× open-loop
/// sweep. Non-200s count toward the rate (the server is answering), io
/// errors do not.
pub fn closed_loop_rate(addr: &str, request: &[u8], conns: usize, duration: Duration) -> f64 {
    let done: Vec<usize> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..conns)
            .map(|_| {
                s.spawn(|| {
                    let mut c = Client::new(addr);
                    let mut n = 0usize;
                    let t0 = Instant::now();
                    while t0.elapsed() < duration {
                        if c.roundtrip(request).is_ok() {
                            n += 1;
                        }
                    }
                    n
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("probe thread")).collect()
    });
    done.iter().sum::<usize>() as f64 / duration.as_secs_f64()
}

/// Fixed-rate open-loop run: `rate_per_s` arrivals over `duration`,
/// spread across `conns` sender connections (arrival `i` belongs to
/// connection `i % conns`; a sender that falls behind fires immediately,
/// and the lateness is charged to latency).
pub fn open_loop(
    addr: &str,
    request: &[u8],
    rate_per_s: f64,
    duration: Duration,
    conns: usize,
) -> LoadReport {
    assert!(rate_per_s > 0.0 && conns >= 1);
    let total = (rate_per_s * duration.as_secs_f64()).round() as usize;
    let interval = Duration::from_secs_f64(1.0 / rate_per_s);
    let start = Instant::now() + Duration::from_millis(20); // let senders line up
    struct Shard {
        lat_us: Vec<f64>,
        ok: usize,
        shed: usize,
        expired: usize,
        other_4xx: usize,
        other_5xx: usize,
        by_5xx: Vec<(u16, usize)>,
        io_errors: usize,
        timeouts: usize,
        connect_errors: usize,
        sent: usize,
    }
    fn bump(v: &mut Vec<(u16, usize)>, status: u16) {
        match v.iter_mut().find(|(s, _)| *s == status) {
            Some((_, n)) => *n += 1,
            None => v.push((status, 1)),
        }
    }
    let shards: Vec<Shard> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                s.spawn(move || {
                    let mut sh = Shard {
                        lat_us: Vec::with_capacity(total / conns + 1),
                        ok: 0,
                        shed: 0,
                        expired: 0,
                        other_4xx: 0,
                        other_5xx: 0,
                        by_5xx: Vec::new(),
                        io_errors: 0,
                        timeouts: 0,
                        connect_errors: 0,
                        sent: 0,
                    };
                    let mut client = Client::new(addr);
                    let mut i = c;
                    while i < total {
                        let due = start + interval.mul_f64(i as f64);
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        sh.sent += 1;
                        match client.roundtrip_classified(request) {
                            Ok(status) => {
                                // scheduled-time latency: queueing from a
                                // late sender or a saturated server both
                                // count (coordinated-omission corrected)
                                let lat = due.elapsed().as_secs_f64() * 1e6;
                                match status {
                                    200..=299 => {
                                        sh.ok += 1;
                                        sh.lat_us.push(lat);
                                    }
                                    503 => sh.shed += 1,
                                    504 => sh.expired += 1,
                                    400..=499 => sh.other_4xx += 1,
                                    _ => {
                                        sh.other_5xx += 1;
                                        bump(&mut sh.by_5xx, status);
                                    }
                                }
                            }
                            Err((IoClass::Connect, _)) => sh.connect_errors += 1,
                            Err((IoClass::Timeout, _)) => sh.timeouts += 1,
                            Err((IoClass::Io, _)) => sh.io_errors += 1,
                        }
                        i += conns;
                    }
                    sh
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("sender thread")).collect()
    });
    let wall = start.elapsed().as_secs_f64();
    let mut lat: Vec<f64> = Vec::with_capacity(total);
    let mut rep = LoadReport { offered_per_s: rate_per_s, duration_s: wall, ..Default::default() };
    for sh in shards {
        lat.extend(sh.lat_us);
        rep.ok += sh.ok;
        rep.shed += sh.shed;
        rep.expired += sh.expired;
        rep.other_4xx += sh.other_4xx;
        rep.other_5xx += sh.other_5xx;
        for (status, n) in sh.by_5xx {
            match rep.by_5xx.iter_mut().find(|(s, _)| *s == status) {
                Some((_, m)) => *m += n,
                None => rep.by_5xx.push((status, n)),
            }
        }
        rep.io_errors += sh.io_errors;
        rep.timeouts += sh.timeouts;
        rep.connect_errors += sh.connect_errors;
        rep.sent += sh.sent;
    }
    rep.by_5xx.sort_unstable();
    lat.sort_unstable_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let pct = |p: f64| {
        if lat.is_empty() {
            0.0
        } else {
            lat[((lat.len() - 1) as f64 * p).round() as usize]
        }
    };
    rep.p50_us = pct(0.50);
    rep.p99_us = pct(0.99);
    rep.p999_us = pct(0.999);
    rep.goodput_per_s = rep.ok as f64 / wall.max(1e-9);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicBool, Ordering};

    /// A loopback port that is guaranteed closed: bind to grab a free
    /// port number, then drop the listener before returning.
    fn closed_port_addr() -> String {
        let l = TcpListener::bind("127.0.0.1:0").expect("bind probe");
        let addr = l.local_addr().expect("probe addr").to_string();
        drop(l);
        addr
    }

    #[test]
    fn refused_connections_count_as_connect_errors_not_panics() {
        let addr = closed_port_addr();
        let req = render_predict("m", b"1,-1", "text/plain");

        assert!(one_shot(&addr, &req).is_err(), "one_shot to a closed port must error");

        let rep = open_loop(&addr, &req, 200.0, Duration::from_millis(120), 2);
        assert!(rep.sent >= 1, "arrivals fire regardless of server state: {rep:?}");
        assert_eq!(rep.ok, 0, "nothing can succeed against a closed port: {rep:?}");
        assert_eq!(
            rep.connect_errors, rep.sent,
            "every refused connect must be charged to connect_errors: {rep:?}"
        );
        assert_eq!(
            rep.io_errors, 0,
            "a refused connect is a classified failure, not residual io: {rep:?}"
        );
        assert_eq!(rep.timeouts, 0, "no deadline ever fires on a dead port: {rep:?}");
        assert_eq!(rep.goodput_per_s, 0.0);

        // closed-loop probe against the same dead port: zero rate, no hang
        let rate = closed_loop_rate(&addr, &req, 2, Duration::from_millis(60));
        assert_eq!(rate, 0.0, "closed-loop rate against a dead port must be zero");
    }

    #[test]
    fn accept_then_close_resets_count_as_io_errors_and_reconnect() {
        // A hostile/broken server: accepts each connection and drops it
        // without reading. Clients see EOF (or RST) mid-roundtrip; the
        // Client must discard the dead stream and reconnect for the next
        // arrival rather than wedging on a stale socket.
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        listener.set_nonblocking(true).expect("nonblocking");
        let addr = listener.local_addr().expect("addr").to_string();
        let req = render_predict("m", b"1,-1", "text/plain");
        let stop = AtomicBool::new(false);
        let rep = std::thread::scope(|s| {
            s.spawn(|| {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((conn, _)) => drop(conn), // immediate close
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
            });
            let rep = open_loop(&addr, &req, 200.0, Duration::from_millis(120), 2);
            stop.store(true, Ordering::Relaxed);
            rep
        });
        assert_eq!(rep.ok, 0, "a server that never answers yields no 200s: {rep:?}");
        // EOF/RST after a successful connect is the *residual* transport
        // class — it must not leak into connect_errors or timeouts.
        assert_eq!(
            rep.io_errors, rep.sent,
            "every accept-then-close roundtrip must stay an io_error: {rep:?}"
        );
        assert_eq!(rep.connect_errors, 0, "the listener accepted every connect: {rep:?}");
        assert!(
            rep.sent >= 2,
            "the client must keep reconnecting after resets, not stop at one: {rep:?}"
        );
    }
}
