//! Serving runtime (DESIGN.md §Serving-Runtime and §Runtime).
//!
//! Two serving paths share this module:
//!
//! * **Native path** (default, zero dependencies): [`engine`] freezes a
//!   trained Boolean model into packed weight bits and runs forward-only
//!   inference as pure XNOR+POPCNT — the paper's one-XOR-per-64-weights
//!   energy story executed literally — and [`serve`] wraps it in a
//!   multi-threaded micro-batching server (`bold serve-native`).
//! * **XLA path** (feature `xla-runtime`): `PjrtExecutor` compiles the
//!   AOT-lowered L2 jax graphs (`artifacts/*.hlo.txt`) with PJRT and
//!   executes them from Rust (`bold serve`). Off by default so the
//!   default build stays dependency-light; without the feature the CLI
//!   degrades with a clear message instead of failing to compile.

pub mod engine;
#[cfg(feature = "xla-runtime")]
pub mod pjrt;
pub mod serve;

pub use engine::{EngineError, EngineScratch, PackedLayer, PackedMlp};
#[cfg(feature = "xla-runtime")]
pub use pjrt::{literal_to_tensor, tensor_to_literal, PjrtError, PjrtExecutor};
pub use serve::{NativeServer, Pending, Response, ServeConfig, ServeError, ServerStats};
