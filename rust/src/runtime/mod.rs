//! Serving runtime (DESIGN.md §Serving-Runtime and §Runtime).
//!
//! Two serving paths share this module:
//!
//! * **Native path** (default, zero dependencies): [`graph`] compiles a
//!   `save_model` checkpoint's architecture record into a packed op
//!   graph ([`PackedGraph`]) — conv, residual and MLP models all run
//!   forward-only as pure XNOR+POPCNT with BN folded into per-channel
//!   integer thresholds — and [`serve`] wraps it in a multi-threaded
//!   micro-batching server (`bold serve-native`). [`passes`] is the
//!   compile-time pass pipeline between the two (op fusion, LUT folding
//!   of low-fan-in layers, slot-liveness buffer reuse —
//!   `BOLD_GRAPH_PASSES`, DESIGN.md §LUT-Folding). [`engine`] keeps
//!   the original linear-stack [`PackedMlp`] as the back-compat loader
//!   for arch-less checkpoints.
//! * **XLA path** (feature `xla-runtime`): `PjrtExecutor` compiles the
//!   AOT-lowered L2 jax graphs (`artifacts/*.hlo.txt`) with PJRT and
//!   executes them from Rust (`bold serve`). Off by default so the
//!   default build stays dependency-light; without the feature the CLI
//!   degrades with a clear message instead of failing to compile.
//!
//! On top of the native path, [`http`] + [`net`] expose the server over
//! real TCP with a zero-dependency HTTP/1.1 front-end (`bold
//! serve-http`), [`lifecycle`] keeps the model registry *live* (hot
//! checkpoint reload behind a shadow-validation canary, per-model
//! circuit breakers with automatic rollback — DESIGN.md
//! §Model-Lifecycle), and [`loadgen`] is the matching open-loop load
//! harness (DESIGN.md §Network-Front-End).

pub mod engine;
pub mod graph;
pub mod http;
pub mod lifecycle;
pub mod loadgen;
pub mod net;
pub mod passes;
#[cfg(feature = "xla-runtime")]
pub mod pjrt;
pub mod serve;

pub use engine::{EngineError, EngineScratch, PackedLayer, PackedMlp};
pub use graph::{
    FusedThreshold, GraphScratch, LutConv, Node, PackedConv, PackedGraph, PackedLut, PackedOp,
    PoolSpec, ThresholdSpec,
};
pub use passes::{PassConfig, PassStats, LUT_DEFAULT_MAX_FANIN, LUT_HARD_MAX_FANIN};
#[cfg(feature = "xla-runtime")]
pub use pjrt::{literal_to_tensor, tensor_to_literal, PjrtError, PjrtExecutor};
pub use http::{HttpError, HttpLimits, HttpParser, Parse, ResponseWriter};
pub use lifecycle::{
    Admission, CanaryVerdict, EntrySnapshot, HealthState, LifecycleConfig, LifecycleError,
    LifecycleErrorKind, ModelEntry, ModelRegistry, PromotionReport,
};
pub use loadgen::{closed_loop_rate, open_loop, render_predict, LoadReport};
pub use net::{HttpConfig, HttpServer, HttpStats};
pub use serve::{
    NativeServer, Pending, Response, ServeConfig, ServeError, ServerStats, TrySubmitError,
};
