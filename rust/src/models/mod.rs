//! Model zoo: the architectures of the paper's evaluation (§4, App. D),
//! built from `nn::` layers in both B⊕LD (native Boolean) and FP flavours,
//! plus the BNN-baseline variants assembled in `baselines::`.
//!
//! Paper ↔ module map:
//! * Table 2/6/9, Fig. 1 — [`vgg_small`] (VGG-SMALL on CIFAR-scale inputs)
//! * Table 5/10 — [`resnet`] (Boolean ResNet Block-I family, base 64…256)
//! * Table 3 — [`edsr`] (small EDSR super-resolution)
//! * Table 4/12/13 — [`segnet`] (Boolean segmentation with BOOL-ASPP-lite)
//! * Table 7 — [`bert`] (Boolean BERT-mini encoder for GLUE-like tasks)
//! * the L2/L1 AOT MLP — [`mlp`] (matches python/compile/model.py dims)

pub mod bert;
pub mod edsr;
pub mod layers_extra;
pub mod mlp;
pub mod resnet;
pub mod segnet;
pub mod vgg_small;

pub use edsr::{edsr_small, EdsrConfig};
pub use mlp::{boolean_mlp, fp_mlp, MlpConfig};
pub use resnet::{resnet_boolean, ResNetConfig};
pub use segnet::{segnet_boolean, SegNetConfig};
pub use vgg_small::{vgg_small, VggConfig, VggKind};
