//! Small EDSR for super-resolution (paper §4.2 / Appendix D.2, Table 3):
//! FP stem conv → 8 Boolean residual blocks (no BN, per EDSR and per the
//! paper) → FP upsampler conv + pixel-shuffle → FP output conv.
//! Trained with L1 loss, like the paper.

use super::layers_extra::{PixelShuffle, ScaleLayer, UpsampleNearest};
use crate::nn::{
    BackwardScale, BoolConv2d, Conv2d, Residual, Sequential, ThresholdAct,
};
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct EdsrConfig {
    /// Feature width κ (paper: 256; scaled down for CPU runs).
    pub features: usize,
    /// Residual blocks (paper small EDSR: 8).
    pub blocks: usize,
    /// Upscale factor ∈ {2, 3, 4}.
    pub scale: usize,
    pub colors: usize,
    /// Boolean residual blocks (B⊕LD) vs FP blocks (SMALL EDSR baseline).
    pub boolean: bool,
}

impl Default for EdsrConfig {
    fn default() -> Self {
        EdsrConfig { features: 16, blocks: 4, scale: 2, colors: 3, boolean: true }
    }
}

impl EdsrConfig {
    /// Paper-shaped small EDSR for the energy model.
    pub fn paper(scale: usize) -> Self {
        EdsrConfig { features: 256, blocks: 8, scale, ..Default::default() }
    }
}

fn bool_block(name: &str, f: usize, rng: &mut Rng) -> Residual {
    // Figure 8: Boolean residual block = act → boolconv → act → boolconv,
    // identity shortcut summing in the integer/real domain. The final
    // α-scale (Eq. 24) brings the integer count back to the O(1) range of
    // the FP feature stream so the residual sum stays balanced.
    let fanin = f * 9;
    let mut main = Sequential::new(&format!("{name}.main"));
    main.push(Box::new(ThresholdAct::new(
        &format!("{name}.act1"),
        0.0,
        BackwardScale::TanhPrime { fanin },
    )));
    main.push(Box::new(BoolConv2d::new(&format!("{name}.conv1"), f, f, 3, 1, 1, rng)));
    main.push(Box::new(ThresholdAct::new(
        &format!("{name}.act2"),
        0.0,
        BackwardScale::TanhPrime { fanin },
    )));
    main.push(Box::new(BoolConv2d::new(&format!("{name}.conv2"), f, f, 3, 1, 1, rng)));
    main.push(Box::new(ScaleLayer::new(
        &format!("{name}.scale"),
        BackwardScale::alpha(fanin),
    )));
    Residual::new(name, main, Sequential::new(&format!("{name}.short")))
}

fn fp_block(name: &str, f: usize, rng: &mut Rng) -> Residual {
    let mut main = Sequential::new(&format!("{name}.main"));
    main.push(Box::new(Conv2d::new(&format!("{name}.conv1"), f, f, 3, 1, 1, rng)));
    main.push(Box::new(crate::nn::ReLU::new(&format!("{name}.relu"))));
    main.push(Box::new(Conv2d::new(&format!("{name}.conv2"), f, f, 3, 1, 1, rng)));
    Residual::new(name, main, Sequential::new(&format!("{name}.short")))
}

/// Build small EDSR. Input: F32 NCHW image in [0, 1]; output: upscaled
/// image (N, colors, H·scale, W·scale).
///
/// A *global* residual skip (nearest-neighbour upsample of the input)
/// wraps the whole network — standard SR practice, so the body only
/// learns the high-frequency correction.
pub fn edsr_small(cfg: &EdsrConfig, rng: &mut Rng) -> Sequential {
    let f = cfg.features;
    let mut body = Sequential::new("body");
    body.push(Box::new(Conv2d::new("stem", cfg.colors, f, 3, 1, 1, rng)));
    for b in 0..cfg.blocks {
        if cfg.boolean {
            body.push(Box::new(bool_block(&format!("rb{b}"), f, rng)));
        } else {
            body.push(Box::new(fp_block(&format!("rb{b}"), f, rng)));
        }
    }
    // Upsampler: FP conv expands channels by scale², then pixel shuffle.
    body.push(Box::new(Conv2d::new("up_conv", f, f * cfg.scale * cfg.scale, 3, 1, 1, rng)));
    body.push(Box::new(PixelShuffle::new("shuffle", cfg.scale)));
    // Zero-init the output conv: the network starts as the exact identity
    // skip and learns only the high-frequency correction (standard SR
    // residual-learning init).
    let mut out_conv = Conv2d::new("out_conv", f, cfg.colors, 3, 1, 1, rng);
    out_conv.w.scale_inplace(0.0);
    out_conv.b.scale_inplace(0.0);
    body.push(Box::new(out_conv));

    let mut skip = Sequential::new("global_skip");
    skip.push(Box::new(UpsampleNearest::new("up_skip", cfg.scale)));

    let mut net = Sequential::new(if cfg.boolean { "edsr_bold" } else { "edsr_fp" });
    net.push(Box::new(Residual::new("global", body, skip)));
    net
}

/// PSNR in dB for predictions/targets in [0, 1].
pub fn psnr(pred: &crate::tensor::Tensor, target: &crate::tensor::Tensor) -> f32 {
    assert_eq!(pred.shape, target.shape);
    let n = pred.len() as f64;
    let mse: f64 = pred
        .data
        .iter()
        .zip(&target.data)
        .map(|(a, b)| {
            let d = (a.clamp(0.0, 1.0) - b) as f64;
            d * d
        })
        .sum::<f64>()
        / n;
    if mse <= 1e-12 {
        return 99.0;
    }
    (10.0 * (1.0 / mse).log10()) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Layer, Value};
    use crate::tensor::Tensor;

    #[test]
    fn upscales_by_factor() {
        let mut rng = Rng::new(1);
        for scale in [2, 3] {
            let cfg = EdsrConfig { features: 8, blocks: 1, scale, ..Default::default() };
            let mut net = edsr_small(&cfg, &mut rng);
            let x = Tensor::randn(&[1, 3, 8, 8], 0.3, &mut rng);
            let y = net.forward(Value::F32(x), true).expect_f32("t");
            assert_eq!(y.shape, vec![1, 3, 8 * scale, 8 * scale], "scale {scale}");
            let g = net.backward(Tensor::full(&y.shape.clone(), 0.01), &mut crate::nn::ParamStore::new());
            assert_eq!(g.shape, vec![1, 3, 8, 8]);
        }
    }

    #[test]
    fn psnr_sanity() {
        let a = Tensor::full(&[1, 1, 4, 4], 0.5);
        assert_eq!(psnr(&a, &a), 99.0);
        let mut b = a.clone();
        b.data[0] = 0.6;
        let p = psnr(&a, &b);
        assert!(p > 20.0 && p < 40.0, "{p}");
    }

    #[test]
    fn fp_variant_builds() {
        let mut rng = Rng::new(2);
        let cfg = EdsrConfig { features: 8, blocks: 1, boolean: false, ..Default::default() };
        let mut net = edsr_small(&cfg, &mut rng);
        let x = Tensor::randn(&[1, 3, 6, 6], 0.3, &mut rng);
        let y = net.forward(Value::F32(x), false).expect_f32("t");
        assert_eq!(y.shape, vec![1, 3, 12, 12]);
    }
}
