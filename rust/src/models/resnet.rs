//! Boolean ResNet — the paper's "Block I" family (Appendix D.1.3, Fig. 6a;
//! Table 5/10). Block I: two Boolean 3×3 convs on the main path, a Boolean
//! conv on the shortcut (stride handles downsampling), BN removed, ReLU
//! replaced by the threshold activation; the paths merge on integer
//! pre-activations, with the activation after the sum.
//!
//! `base` is the mapping dimension of the first layer — the paper's Table 5
//! knob (64 standard, 256 for the "large" model that beats the FP baseline).

use crate::nn::{
    AvgPool2dGlobal, BackwardScale, BoolConv2d, Conv2d, Flatten, Linear, Residual,
    Sequential, ThresholdAct,
};
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct ResNetConfig {
    /// Mapping dimension of the first layer ("Base" in Table 5).
    pub base: usize,
    /// Blocks per stage (ResNet18 layout = [2, 2, 2, 2]).
    pub blocks: Vec<usize>,
    pub in_channels: usize,
    pub classes: usize,
    pub hw: usize,
    /// Shortcut kernel size: 3 (paper's best, Table 10) or 1 (ablation).
    pub shortcut_k: usize,
    /// Stages that downsample (stride 2) at entry; stage 0 never does.
    pub downsample_from: usize,
}

impl Default for ResNetConfig {
    fn default() -> Self {
        ResNetConfig {
            base: 16,
            blocks: vec![2, 2, 2, 2],
            in_channels: 3,
            classes: 10,
            hw: 32,
            shortcut_k: 3,
            downsample_from: 1,
        }
    }
}

impl ResNetConfig {
    /// Paper-shaped ImageNet config for the energy model (base 64…256).
    pub fn paper(base: usize) -> Self {
        ResNetConfig { base, hw: 224, classes: 1000, ..Default::default() }
    }
}

fn block(
    name: &str,
    c_in: usize,
    c_out: usize,
    stride: usize,
    shortcut_k: usize,
    rng: &mut Rng,
) -> Residual {
    // Main path: act → conv → act → conv (input arrives as integer
    // pre-activations from the previous merge).
    let mut main = Sequential::new(&format!("{name}.main"));
    main.push(Box::new(
        ThresholdAct::new(&format!("{name}.act1"), 0.0, BackwardScale::TanhPrime { fanin: c_in * 9 })
            .centered(),
    ));
    main.push(Box::new(BoolConv2d::new(&format!("{name}.conv1"), c_in, c_out, 3, stride, 1, rng)));
    main.push(Box::new(
        ThresholdAct::new(&format!("{name}.act2"), 0.0, BackwardScale::TanhPrime { fanin: c_out * 9 })
            .centered(),
    ));
    main.push(Box::new(BoolConv2d::new(&format!("{name}.conv2"), c_out, c_out, 3, 1, 1, rng)));

    // Shortcut: Boolean conv with matching stride (Block I always has one;
    // the 3×3 keeps the dynamic range comparable to the main path —
    // Appendix D.3.1).
    let mut shortcut = Sequential::new(&format!("{name}.short"));
    shortcut.push(Box::new(
        ThresholdAct::new(
            &format!("{name}.sact"),
            0.0,
            BackwardScale::TanhPrime { fanin: c_in * shortcut_k * shortcut_k },
        )
        .centered(),
    ));
    shortcut.push(Box::new(BoolConv2d::new(
        &format!("{name}.sconv"),
        c_in,
        c_out,
        shortcut_k,
        stride,
        shortcut_k / 2,
        rng,
    )));

    Residual::new(name, main, shortcut)
}

/// Build the Boolean ResNet. Input: F32 NCHW; stem conv is FP (paper
/// setup), head is FP Linear.
pub fn resnet_boolean(cfg: &ResNetConfig, rng: &mut Rng) -> Sequential {
    let mut net = Sequential::new("resnet_bold");
    // FP stem.
    net.push(Box::new(Conv2d::new("stem", cfg.in_channels, cfg.base, 3, 1, 1, rng)));
    let mut c = cfg.base;
    for (s, &nblocks) in cfg.blocks.iter().enumerate() {
        let c_out = cfg.base << s.min(3); // 1×, 2×, 4×, 8×
        for b in 0..nblocks {
            let stride = if b == 0 && s >= cfg.downsample_from { 2 } else { 1 };
            net.push(Box::new(block(
                &format!("s{s}b{b}"),
                c,
                c_out,
                stride,
                cfg.shortcut_k,
                rng,
            )));
            c = c_out;
        }
    }
    // Head: final activation-free GAP on integer pre-activations + FP FC.
    net.push(Box::new(AvgPool2dGlobal::new("gap")));
    net.push(Box::new(Flatten::new("flat")));
    net.push(Box::new(Linear::new("head", c, cfg.classes, rng)));
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Layer, Value};
    use crate::tensor::Tensor;

    #[test]
    fn forward_backward_shapes() {
        let mut rng = Rng::new(1);
        let cfg = ResNetConfig {
            base: 8,
            blocks: vec![1, 1],
            hw: 16,
            ..Default::default()
        };
        let mut net = resnet_boolean(&cfg, &mut rng);
        let x = Tensor::randn(&[2, 3, 16, 16], 1.0, &mut rng);
        let y = net.forward(Value::F32(x), true).expect_f32("t");
        assert_eq!(y.shape, vec![2, 10]);
        let g = net.backward(Tensor::full(&[2, 10], 0.1), &mut crate::nn::ParamStore::new());
        assert_eq!(g.shape, vec![2, 3, 16, 16]);
    }

    #[test]
    fn base_width_scales_param_count() {
        let mut rng = Rng::new(2);
        let count = |base: usize, rng: &mut Rng| {
            let cfg = ResNetConfig { base, blocks: vec![1], hw: 8, ..Default::default() };
            resnet_boolean(&cfg, rng).param_count()
        };
        let p8 = count(8, &mut rng);
        let p16 = count(16, &mut rng);
        assert!(p16 > 3 * p8, "doubling base ≈ 4× boolean params: {p8} vs {p16}");
    }

    #[test]
    fn shortcut_kernel_ablation_builds() {
        let mut rng = Rng::new(3);
        for k in [1, 3] {
            let cfg = ResNetConfig {
                base: 8,
                blocks: vec![1, 1],
                hw: 16,
                shortcut_k: k,
                ..Default::default()
            };
            let mut net = resnet_boolean(&cfg, &mut rng);
            let x = Tensor::randn(&[1, 3, 16, 16], 1.0, &mut rng);
            let y = net.forward(Value::F32(x), false).expect_f32("t");
            assert_eq!(y.shape, vec![1, 10], "k={k}");
        }
    }
}
