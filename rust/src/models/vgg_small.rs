//! VGG-SMALL (Simonyan & Zisserman layout, the BinaryConnect variant used
//! throughout the paper: Table 2, Table 6, Table 9, Fig. 1).
//!
//! Paper dims on CIFAR10 (32×32): 2×128C3 – MP2 – 2×256C3 – MP2 – 2×512C3
//! – MP2 – 1024FC – 10. B⊕LD keeps the first conv and the last FC in FP
//! (§4 Experimental Setup); everything in between is native Boolean with
//! threshold activations, optionally BN ("B⊕LD with BN", Table 2).
//! `width_mult` scales channels down for CPU-scale runs.

use crate::nn::{
    BackwardScale, BatchNorm2d, BoolConv2d, Conv2d, Flatten, Linear, MaxPool2d, ReLU,
    Sequential, ThresholdAct,
};
use crate::util::Rng;

/// Which training paradigm the net implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VggKind {
    /// Full-precision baseline (ReLU + BN).
    Fp,
    /// B⊕LD: native Boolean interior.
    Bold,
}

#[derive(Debug, Clone)]
pub struct VggConfig {
    pub kind: VggKind,
    /// Input spatial size (paper: 32).
    pub hw: usize,
    pub in_channels: usize,
    pub classes: usize,
    /// Channel multiplier vs the paper's [128, 256, 512].
    pub width_mult: f32,
    /// Insert BN after each conv (the "with BN" rows of Table 2).
    pub with_bn: bool,
    /// Number of FC hidden layers (paper App. D.1.2: 3-FC classic vs 1-FC
    /// modern — Table 9 uses the 1-FC variant).
    pub fc_layers: usize,
    pub fc_width: usize,
}

impl Default for VggConfig {
    fn default() -> Self {
        VggConfig {
            kind: VggKind::Bold,
            hw: 32,
            in_channels: 3,
            classes: 10,
            width_mult: 0.25,
            with_bn: false,
            fc_layers: 1,
            fc_width: 256,
        }
    }
}

impl VggConfig {
    pub fn channels(&self) -> [usize; 3] {
        let m = |c: f32| ((c * self.width_mult).round() as usize).max(8);
        [m(128.0), m(256.0), m(512.0)]
    }

    /// Paper-exact shapes (width_mult = 1) for the energy model.
    pub fn paper() -> Self {
        VggConfig { width_mult: 1.0, fc_width: 1024, ..Default::default() }
    }
}

/// Build VGG-SMALL per the config. Input: F32 NCHW in [-1, 1].
pub fn vgg_small(cfg: &VggConfig, rng: &mut Rng) -> Sequential {
    match cfg.kind {
        VggKind::Fp => vgg_fp(cfg, rng),
        VggKind::Bold => vgg_bold(cfg, rng),
    }
}

fn vgg_bold(cfg: &VggConfig, rng: &mut Rng) -> Sequential {
    let [c1, c2, c3] = cfg.channels();
    let mut net = Sequential::new("vgg_small_bold");
    let act = |name: &str, fanin: usize| {
        // centered: see ThresholdAct::center — stabilizes post-MaxPool stats
        Box::new(ThresholdAct::new(name, 0.0, BackwardScale::TanhPrime { fanin }).centered())
    };
    let bn = |net: &mut Sequential, name: &str, c: usize| {
        if cfg.with_bn {
            net.push(Box::new(BatchNorm2d::new(name, c)));
        }
    };

    // Stage 1 — first conv stays FP on the real input (paper setup).
    net.push(Box::new(Conv2d::new("conv1a", cfg.in_channels, c1, 3, 1, 1, rng)));
    bn(&mut net, "bn1a", c1);
    net.push(act("act1a", cfg.in_channels * 9));
    net.push(Box::new(BoolConv2d::new("conv1b", c1, c1, 3, 1, 1, rng)));
    net.push(Box::new(MaxPool2d::new("mp1", 2)));
    bn(&mut net, "bn1b", c1);
    net.push(act("act1b", c1 * 9));

    // Stage 2
    net.push(Box::new(BoolConv2d::new("conv2a", c1, c2, 3, 1, 1, rng)));
    bn(&mut net, "bn2a", c2);
    net.push(act("act2a", c1 * 9));
    net.push(Box::new(BoolConv2d::new("conv2b", c2, c2, 3, 1, 1, rng)));
    net.push(Box::new(MaxPool2d::new("mp2", 2)));
    bn(&mut net, "bn2b", c2);
    net.push(act("act2b", c2 * 9));

    // Stage 3
    net.push(Box::new(BoolConv2d::new("conv3a", c2, c3, 3, 1, 1, rng)));
    bn(&mut net, "bn3a", c3);
    net.push(act("act3a", c2 * 9));
    net.push(Box::new(BoolConv2d::new("conv3b", c3, c3, 3, 1, 1, rng)));
    net.push(Box::new(MaxPool2d::new("mp3", 2)));
    bn(&mut net, "bn3b", c3);
    net.push(act("act3b", c3 * 9));

    // Classifier
    net.push(Box::new(Flatten::new("flat")));
    let spatial = cfg.hw / 8;
    let mut d = c3 * spatial * spatial;
    // Hidden FCs are Boolean; the final classifier stays FP (paper setup).
    for i in 0..cfg.fc_layers.saturating_sub(1) {
        net.push(Box::new(BoolLinear::new(&format!("fc{i}"), d, cfg.fc_width, rng)));
        net.push(Box::new(
            ThresholdAct::new(&format!("actfc{i}"), 0.0, BackwardScale::TanhPrime { fanin: d })
                .centered(),
        ));
        d = cfg.fc_width;
    }
    net.push(Box::new(Linear::new("head", d, cfg.classes, rng)));
    net
}

use crate::nn::BoolLinear;

fn vgg_fp(cfg: &VggConfig, rng: &mut Rng) -> Sequential {
    let [c1, c2, c3] = cfg.channels();
    let mut net = Sequential::new("vgg_small_fp");
    let mut stage = |net: &mut Sequential, idx: usize, cin: usize, cout: usize| {
        net.push(Box::new(Conv2d::new(&format!("conv{idx}a"), cin, cout, 3, 1, 1, rng)));
        if cfg.with_bn {
            net.push(Box::new(BatchNorm2d::new(&format!("bn{idx}a"), cout)));
        }
        net.push(Box::new(ReLU::new(&format!("relu{idx}a"))));
        net.push(Box::new(Conv2d::new(&format!("conv{idx}b"), cout, cout, 3, 1, 1, rng)));
        net.push(Box::new(MaxPool2d::new(&format!("mp{idx}"), 2)));
        if cfg.with_bn {
            net.push(Box::new(BatchNorm2d::new(&format!("bn{idx}b"), cout)));
        }
        net.push(Box::new(ReLU::new(&format!("relu{idx}b"))));
    };
    stage(&mut net, 1, cfg.in_channels, c1);
    stage(&mut net, 2, c1, c2);
    stage(&mut net, 3, c2, c3);
    net.push(Box::new(Flatten::new("flat")));
    let spatial = cfg.hw / 8;
    let mut d = c3 * spatial * spatial;
    for i in 0..cfg.fc_layers.saturating_sub(1) {
        net.push(Box::new(Linear::new(&format!("fc{i}"), d, cfg.fc_width, rng)));
        net.push(Box::new(ReLU::new(&format!("relufc{i}"))));
        d = cfg.fc_width;
    }
    net.push(Box::new(Linear::new("head", d, cfg.classes, rng)));
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Layer, Value};
    use crate::tensor::Tensor;

    fn smoke(cfg: &VggConfig) {
        let mut rng = Rng::new(1);
        let mut net = vgg_small(cfg, &mut rng);
        let x = Tensor::randn(&[2, cfg.in_channels, cfg.hw, cfg.hw], 1.0, &mut rng);
        let y = net.forward(Value::F32(x), true).expect_f32("t");
        assert_eq!(y.shape, vec![2, cfg.classes]);
        let g = net.backward(Tensor::full(&[2, cfg.classes], 0.1), &mut crate::nn::ParamStore::new());
        assert_eq!(g.shape, vec![2, cfg.in_channels, cfg.hw, cfg.hw]);
    }

    #[test]
    fn bold_forward_backward_shapes() {
        smoke(&VggConfig { hw: 16, width_mult: 0.125, ..Default::default() });
    }

    #[test]
    fn bold_with_bn_shapes() {
        smoke(&VggConfig { hw: 16, width_mult: 0.125, with_bn: true, ..Default::default() });
    }

    #[test]
    fn fp_shapes() {
        smoke(&VggConfig {
            kind: VggKind::Fp,
            hw: 16,
            width_mult: 0.125,
            with_bn: true,
            ..Default::default()
        });
    }

    #[test]
    fn paper_channels() {
        let cfg = VggConfig::paper();
        assert_eq!(cfg.channels(), [128, 256, 512]);
    }
}
