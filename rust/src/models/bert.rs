//! Boolean BERT-mini (paper §4.3 "BERT fine-tuning for NLU", Table 7).
//!
//! Transformer encoder in the paper's Boolean regime: the Q/K/V/output and
//! FFN projections are native Boolean layers (1-bit weights, 1-bit
//! activations via the threshold activation), while softmax attention,
//! LayerNorm, embeddings and the classifier head stay FP — mirroring how
//! the paper's Boolean BERT keeps the non-linear transformer core in FP
//! and swaps the arithmetic-heavy linear layers to Boolean logic.
//!
//! Single-head, explicit backward: the closed-form softmax-attention
//! adjoint composed with the Boolean variation backward of the
//! projections — the Theorem 3.11 chain rules applied across module
//! boundaries (Fig. 2's mixed ℝ/𝔹 backpropagation).

use crate::nn::{
    softmax_cross_entropy, BackwardScale, BoolLinear, Layer, LayerNorm, Linear, LossOut,
    ParamRef, ParamStore, ThresholdAct, Value,
};
use crate::tensor::Tensor;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct BertConfig {
    pub vocab: usize,
    pub max_len: usize,
    pub d: usize,
    pub ff: usize,
    pub layers: usize,
    pub classes: usize,
}

impl Default for BertConfig {
    fn default() -> Self {
        BertConfig { vocab: 64, max_len: 16, d: 32, ff: 64, layers: 2, classes: 2 }
    }
}

struct EncoderLayer {
    ln1: LayerNorm,
    act_attn: ThresholdAct,
    q: BoolLinear,
    k: BoolLinear,
    v: BoolLinear,
    o: BoolLinear,
    act_o: ThresholdAct,
    ln2: LayerNorm,
    act_ff: ThresholdAct,
    ff1: BoolLinear,
    act_mid: ThresholdAct,
    ff2: BoolLinear,
    d: usize,
    // attention caches: per batch sample (L×L) attention + Q/K/V (N·L × d)
    cache: Option<AttnCache>,
}

struct AttnCache {
    n: usize,
    l: usize,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    attn: Vec<Tensor>, // per-sample (L×L) post-softmax
}

impl EncoderLayer {
    fn new(name: &str, cfg: &BertConfig, rng: &mut Rng) -> Self {
        let d = cfg.d;
        let mk_act = |n: String, fanin: usize| {
            ThresholdAct::new(&n, 0.0, BackwardScale::TanhPrime { fanin })
        };
        EncoderLayer {
            ln1: LayerNorm::new(&format!("{name}.ln1"), d),
            act_attn: mk_act(format!("{name}.act_attn"), d),
            q: BoolLinear::new(&format!("{name}.q"), d, d, rng),
            k: BoolLinear::new(&format!("{name}.k"), d, d, rng),
            v: BoolLinear::new(&format!("{name}.v"), d, d, rng),
            o: BoolLinear::new(&format!("{name}.o"), d, d, rng),
            act_o: mk_act(format!("{name}.act_o"), d),
            ln2: LayerNorm::new(&format!("{name}.ln2"), d),
            act_ff: mk_act(format!("{name}.act_ff"), d),
            ff1: BoolLinear::new(&format!("{name}.ff1"), d, cfg.ff, rng),
            act_mid: mk_act(format!("{name}.act_mid"), cfg.ff),
            ff2: BoolLinear::new(&format!("{name}.ff2"), cfg.ff, d, rng),
            d,
            cache: None,
        }
    }

    /// h: (N·L × d). Returns the transformed hidden states.
    fn fwd(&mut self, h: &Tensor, n: usize, l: usize, train: bool) -> Tensor {
        let d = self.d;
        // --- attention sublayer ---
        let a = self.ln1.fwd(h, train);
        let a_bits = self.act_attn.forward(Value::F32(a), train);
        let q = self.q.forward(a_bits.clone(), train).expect_f32("q");
        let k = self.k.forward(a_bits.clone(), train).expect_f32("k");
        let v = self.v.forward(a_bits, train).expect_f32("v");
        let scale = 1.0 / (d as f32).sqrt();
        let mut ctx = Tensor::zeros(&[n * l, d]);
        let mut attns = Vec::with_capacity(n);
        for ni in 0..n {
            let qs = Tensor::from_vec(&[l, d], q.data[ni * l * d..(ni + 1) * l * d].to_vec());
            let ks = Tensor::from_vec(&[l, d], k.data[ni * l * d..(ni + 1) * l * d].to_vec());
            let vs = Tensor::from_vec(&[l, d], v.data[ni * l * d..(ni + 1) * l * d].to_vec());
            let mut scores = qs.matmul_bt(&ks);
            scores.scale_inplace(scale);
            // row softmax
            for i in 0..l {
                let row = &mut scores.data[i * l..(i + 1) * l];
                let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0.0;
                for r in row.iter_mut() {
                    *r = (*r - mx).exp();
                    z += *r;
                }
                for r in row.iter_mut() {
                    *r /= z;
                }
            }
            let c = scores.matmul(&vs);
            ctx.data[ni * l * d..(ni + 1) * l * d].copy_from_slice(&c.data);
            attns.push(scores);
        }
        let ctx_bits = self.act_o.forward(Value::F32(ctx), train);
        let attn_out = self.o.forward(ctx_bits, train).expect_f32("o");
        let h1 = h.add(&attn_out); // residual

        // --- FFN sublayer ---
        let a2 = self.ln2.fwd(&h1, train);
        let a2_bits = self.act_ff.forward(Value::F32(a2), train);
        let m = self.ff1.forward(a2_bits, train).expect_f32("ff1");
        let m_bits = self.act_mid.forward(Value::F32(m), train);
        let ff_out = self.ff2.forward(m_bits, train).expect_f32("ff2");
        let out = h1.add(&ff_out);

        if train {
            self.cache = Some(AttnCache { n, l, q, k, v, attn: attns });
        }
        out
    }

    /// z: (N·L × d) downstream signal; returns signal w.r.t. the input h.
    fn bwd(&mut self, z: &Tensor, store: &mut ParamStore) -> Tensor {
        let cache = self.cache.take().expect("backward before forward");
        let (n, l, d) = (cache.n, cache.l, self.d);
        let scale = 1.0 / (d as f32).sqrt();

        // --- FFN sublayer backward (residual splits the signal) ---
        let g_ff2 = self.ff2.backward(z.clone(), store);
        let g_mid = self.act_mid.backward(g_ff2, store);
        let g_ff1 = self.ff1.backward(g_mid, store);
        let g_a2 = self.act_ff.backward(g_ff1, store);
        let g_h1 = z.add(&self.ln2.bwd(&g_a2, store));

        // --- attention sublayer backward ---
        let g_o = self.o.backward(g_h1.clone(), store);
        let g_ctx = self.act_o.backward(g_o, store);
        let mut g_q = Tensor::zeros(&[n * l, d]);
        let mut g_k = Tensor::zeros(&[n * l, d]);
        let mut g_v = Tensor::zeros(&[n * l, d]);
        for ni in 0..n {
            let span = ni * l * d..(ni + 1) * l * d;
            let dctx = Tensor::from_vec(&[l, d], g_ctx.data[span.clone()].to_vec());
            let qs = Tensor::from_vec(&[l, d], cache.q.data[span.clone()].to_vec());
            let ks = Tensor::from_vec(&[l, d], cache.k.data[span.clone()].to_vec());
            let vs = Tensor::from_vec(&[l, d], cache.v.data[span.clone()].to_vec());
            let a = &cache.attn[ni];
            // dV = Aᵀ dctx;  dA = dctx Vᵀ
            let dv = a.matmul_at(&dctx);
            let da = dctx.matmul_bt(&vs);
            // softmax backward: dS = A ⊙ (dA − rowsum(dA ⊙ A))
            let mut ds = Tensor::zeros(&[l, l]);
            for i in 0..l {
                let arow = &a.data[i * l..(i + 1) * l];
                let darow = &da.data[i * l..(i + 1) * l];
                let dot: f32 = arow.iter().zip(darow).map(|(x, y)| x * y).sum();
                for j in 0..l {
                    ds.data[i * l + j] = arow[j] * (darow[j] - dot);
                }
            }
            ds.scale_inplace(scale);
            let dq = ds.matmul(&ks);
            let dk = ds.matmul_at(&qs); // dK = dSᵀ·Q
            g_q.data[span.clone()].copy_from_slice(&dq.data);
            g_k.data[span.clone()].copy_from_slice(&dk.data);
            g_v.data[span].copy_from_slice(&dv.data);
        }
        let gq_in = self.q.backward(g_q, store);
        let gk_in = self.k.backward(g_k, store);
        let gv_in = self.v.backward(g_v, store);
        let g_a = self.act_attn.backward(gq_in.add(&gk_in).add(&gv_in), store);
        g_h1.add(&self.ln1.bwd(&g_a, store))
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let mut p = self.ln1.params();
        p.extend(self.q.params());
        p.extend(self.k.params());
        p.extend(self.v.params());
        p.extend(self.o.params());
        p.extend(self.ln2.params());
        p.extend(self.ff1.params());
        p.extend(self.ff2.params());
        p
    }
}

/// Boolean BERT-mini for sequence classification.
pub struct BertMini {
    pub cfg: BertConfig,
    tok_emb: Tensor,
    pos_emb: Tensor,
    encoder: Vec<EncoderLayer>,
    ln_f: LayerNorm,
    head: Linear,
    cache_tokens: Option<Vec<usize>>,
    cache_nl: Option<(usize, usize)>,
}

impl BertMini {
    pub fn new(cfg: &BertConfig, rng: &mut Rng) -> Self {
        let d = cfg.d;
        BertMini {
            cfg: cfg.clone(),
            tok_emb: Tensor::randn(&[cfg.vocab, d], 0.5, rng),
            pos_emb: Tensor::randn(&[cfg.max_len, d], 0.1, rng),
            encoder: (0..cfg.layers)
                .map(|i| EncoderLayer::new(&format!("enc{i}"), cfg, rng))
                .collect(),
            ln_f: LayerNorm::new("ln_f", d),
            head: Linear::new("cls_head", d, cfg.classes, rng),
            cache_tokens: None,
            cache_nl: None,
        }
    }

    /// tokens: flat (N·L) ids; returns (N × classes) logits.
    pub fn forward(&mut self, tokens: &[usize], n: usize, l: usize, train: bool) -> Tensor {
        assert_eq!(tokens.len(), n * l);
        assert!(l <= self.cfg.max_len);
        let d = self.cfg.d;
        let mut h = Tensor::zeros(&[n * l, d]);
        for (i, &t) in tokens.iter().enumerate() {
            debug_assert!(t < self.cfg.vocab);
            let pos = i % l;
            for j in 0..d {
                h.data[i * d + j] = self.tok_emb.at2(t, j) + self.pos_emb.at2(pos, j);
            }
        }
        for layer in self.encoder.iter_mut() {
            h = layer.fwd(&h, n, l, train);
        }
        let hn = self.ln_f.fwd(&h, train);
        // CLS pooling: first token of every sequence.
        let mut pooled = Tensor::zeros(&[n, d]);
        for ni in 0..n {
            pooled.data[ni * d..(ni + 1) * d]
                .copy_from_slice(&hn.data[ni * l * d..ni * l * d + d]);
        }
        if train {
            self.cache_tokens = Some(tokens.to_vec());
            self.cache_nl = Some((n, l));
        }
        self.head.forward(Value::F32(pooled), train).expect_f32("head")
    }

    /// Backward from logits gradient; accumulates all parameter signals
    /// into `store`.
    pub fn backward(&mut self, g_logits: Tensor, store: &mut ParamStore) {
        let (n, l) = self.cache_nl.expect("backward before forward");
        let d = self.cfg.d;
        let g_pooled = self.head.backward(g_logits, store);
        // un-pool: signal lands on token 0 of every sequence
        let mut g_hn = Tensor::zeros(&[n * l, d]);
        for ni in 0..n {
            g_hn.data[ni * l * d..ni * l * d + d]
                .copy_from_slice(&g_pooled.data[ni * d..(ni + 1) * d]);
        }
        let mut g_h = self.ln_f.bwd(&g_hn, store);
        for layer in self.encoder.iter_mut().rev() {
            g_h = layer.bwd(&g_h, store);
        }
        // embedding scatter (in-place into the store's grad buffers)
        let tokens = self.cache_tokens.take().unwrap();
        {
            let g_tok = store.slot_mut("tok_emb").grad_mut(&[self.cfg.vocab, d]);
            for (i, &t) in tokens.iter().enumerate() {
                for j in 0..d {
                    *g_tok.at2_mut(t, j) += g_h.data[i * d + j];
                }
            }
        }
        {
            let g_pos = store.slot_mut("pos_emb").grad_mut(&[self.cfg.max_len, d]);
            for i in 0..tokens.len() {
                let pos = i % l;
                for j in 0..d {
                    *g_pos.at2_mut(pos, j) += g_h.data[i * d + j];
                }
            }
        }
    }

    /// Convenience: one loss evaluation (forward + CE) without updates.
    pub fn loss(&mut self, tokens: &[usize], labels: &[usize], n: usize, l: usize) -> LossOut {
        let logits = self.forward(tokens, n, l, false);
        softmax_cross_entropy(&logits, labels)
    }

    pub fn params(&mut self) -> Vec<ParamRef<'_>> {
        let mut p = vec![
            ParamRef::Real { name: "tok_emb".into(), w: &mut self.tok_emb },
            ParamRef::Real { name: "pos_emb".into(), w: &mut self.pos_emb },
        ];
        for layer in self.encoder.iter_mut() {
            p.extend(layer.params());
        }
        p.extend(self.ln_f.params());
        p.extend(self.head.params());
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Adam, BooleanOptimizer};

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let cfg = BertConfig { vocab: 16, max_len: 8, d: 16, ff: 32, layers: 1, classes: 3 };
        let mut bert = BertMini::new(&cfg, &mut rng);
        let tokens: Vec<usize> = (0..4 * 8).map(|i| i % 16).collect();
        let logits = bert.forward(&tokens, 4, 8, true);
        assert_eq!(logits.shape, vec![4, 3]);
        bert.backward(Tensor::full(&[4, 3], 0.1), &mut ParamStore::new());
    }

    #[test]
    fn learns_token_presence_task() {
        // label = does token 0 appear in the sequence (easy separable task)
        let mut rng = Rng::new(2);
        let cfg = BertConfig { vocab: 12, max_len: 8, d: 16, ff: 32, layers: 1, classes: 2 };
        let mut bert = BertMini::new(&cfg, &mut rng);
        let boolopt = BooleanOptimizer::new(20.0);
        let mut adam = Adam::new(2e-3);
        let mut store = ParamStore::new();
        let (n, l) = (16, 8);
        let mut make_batch = |rng: &mut Rng| {
            let mut toks = Vec::with_capacity(n * l);
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                let has = rng.bernoulli(0.5);
                let mut seq: Vec<usize> = (0..l).map(|_| 1 + rng.below(11)).collect();
                if has {
                    seq[rng.below(l)] = 0;
                }
                labels.push(has as usize);
                toks.extend(seq);
            }
            (toks, labels)
        };
        let mut first_loss = 0.0;
        let mut last_loss = 0.0;
        for step in 0..60 {
            let (toks, labels) = make_batch(&mut rng);
            let logits = bert.forward(&toks, n, l, true);
            let out = softmax_cross_entropy(&logits, &labels);
            store.zero_grads();
            bert.backward(out.grad.clone(), &mut store);
            let mut params = bert.params();
            boolopt.step(&mut params, &mut store);
            adam.step(&mut params, &mut store);
            if step == 0 {
                first_loss = out.loss;
            }
            last_loss = out.loss;
        }
        assert!(
            last_loss < first_loss * 0.9,
            "loss should drop: first {first_loss} last {last_loss}"
        );
    }
}
