//! Boolean semantic segmentation (paper §4.3 / Appendix D.3, Tables 4/12/13).
//!
//! Scaled DeepLab-style layout: Boolean encoder (÷4 spatial, like the
//! paper's ÷8 strategy scaled down), a BOOL-ASPP-lite context module, a FP
//! 1×1 classifier and nearest upsampling back to input resolution.
//!
//! The Table 12 ablation point is preserved: the *naive* ASPP binarizes the
//! features before global average pooling (losing image-level statistics),
//! while BOOL-ASPP keeps the GAP branch on the integer pre-activations
//! (Fig. 12c vs 12d). Dilated convs are replaced by an extra 3×3 branch —
//! a substitution documented in DESIGN.md (no dilation support in the
//! minimal conv engine; the multi-branch structure is what matters for the
//! ablation).

use super::layers_extra::UpsampleNearest;
use crate::nn::{
    BackwardScale, BatchNorm2d, BoolConv2d, Conv2d, Layer, ParamRef, ParamStore, Residual,
    Sequential, ThresholdAct, Value,
};
use crate::tensor::Tensor;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct SegNetConfig {
    pub classes: usize,
    pub in_channels: usize,
    pub hw: usize,
    pub width: usize,
    /// Naive BOOL-ASPP (binarized GAP branch) vs the paper's BOOL-ASPP
    /// (integer GAP branch) — the Table 12 ablation switch.
    pub naive_aspp: bool,
}

impl Default for SegNetConfig {
    fn default() -> Self {
        SegNetConfig { classes: 6, in_channels: 3, hw: 32, width: 16, naive_aspp: false }
    }
}

/// BOOL-ASPP-lite: two Boolean conv branches + a GAP branch, summed.
struct BoolAspp {
    branch1: Sequential,
    branch2: Sequential,
    /// GAP branch: BN + FP 1×1 conv on either integer (paper) or
    /// binarized (naive) features.
    gap_bn: BatchNorm2d,
    gap_conv: Conv2d,
    naive: bool,
    name: String,
    cache_dims: Option<(usize, usize, usize, usize)>,
    cache_gap_in: Option<Tensor>,
}

impl BoolAspp {
    fn new(name: &str, c: usize, naive: bool, rng: &mut Rng) -> Self {
        let mk_branch = |bn: &str, k: usize, rng: &mut Rng| {
            let mut s = Sequential::new(bn);
            s.push(Box::new(ThresholdAct::new(
                &format!("{bn}.act"),
                0.0,
                BackwardScale::TanhPrime { fanin: c * k * k },
            )));
            s.push(Box::new(BoolConv2d::new(&format!("{bn}.conv"), c, c, k, 1, k / 2, rng)));
            s
        };
        BoolAspp {
            branch1: mk_branch(&format!("{name}.b1"), 1, rng),
            branch2: mk_branch(&format!("{name}.b2"), 3, rng),
            gap_bn: BatchNorm2d::new(&format!("{name}.gap_bn"), c),
            gap_conv: Conv2d::new(&format!("{name}.gap_conv"), c, c, 1, 1, 0, rng),
            naive,
            name: name.to_string(),
            cache_dims: None,
            cache_gap_in: None,
        }
    }
}

impl Layer for BoolAspp {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        let (n, c, h, w) = t.dims4();
        self.cache_dims = Some((n, c, h, w));

        let y1 = self.branch1.forward(Value::F32(t.clone()), train).expect_f32("aspp b1");
        let y2 = self.branch2.forward(Value::F32(t.clone()), train).expect_f32("aspp b2");

        // GAP branch (Fig. 12c naive: binarize first / 12d: keep integer).
        let gap_in = if self.naive { t.sign_pm1() } else { t.clone() };
        if train {
            self.cache_gap_in = Some(gap_in.clone());
        }
        // global average per (n, c), broadcast back
        let mut pooled = Tensor::zeros(&[n, c, 1, 1]);
        let inv = 1.0 / (h * w) as f32;
        for ni in 0..n {
            for ci in 0..c {
                let plane = (ni * c + ci) * h * w;
                pooled.data[ni * c + ci] =
                    gap_in.data[plane..plane + h * w].iter().sum::<f32>() * inv;
            }
        }
        let bn_out = self.gap_bn.forward(Value::F32(pooled), train).expect_f32("gap bn");
        let gap_feat = self.gap_conv.forward(Value::F32(bn_out), train).expect_f32("gap conv");
        // broadcast-add the three branches
        let mut out = y1.add(&y2);
        for ni in 0..n {
            for ci in 0..c {
                let v = gap_feat.data[ni * c + ci];
                let plane = (ni * c + ci) * h * w;
                for p in 0..h * w {
                    out.data[plane + p] += v;
                }
            }
        }
        Value::F32(out)
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        let (n, c, h, w) = self.cache_dims.expect("backward before forward");
        let g1 = self.branch1.backward(z.clone(), store);
        let g2 = self.branch2.backward(z.clone(), store);
        // GAP branch backward: sum z over space → conv → bn → spread mean.
        let mut z_pooled = Tensor::zeros(&[n, c, 1, 1]);
        for ni in 0..n {
            for ci in 0..c {
                let plane = (ni * c + ci) * h * w;
                z_pooled.data[ni * c + ci] = z.data[plane..plane + h * w].iter().sum();
            }
        }
        let g_conv = self.gap_conv.backward(z_pooled, store);
        let g_bn = self.gap_bn.backward(g_conv, store);
        let inv = 1.0 / (h * w) as f32;
        let mut g = g1.add(&g2);
        if !self.naive {
            // integer GAP branch: signal flows back into the features
            for ni in 0..n {
                for ci in 0..c {
                    let v = g_bn.data[ni * c + ci] * inv;
                    let plane = (ni * c + ci) * h * w;
                    for p in 0..h * w {
                        g.data[plane + p] += v;
                    }
                }
            }
        }
        // naive: binarization blocks the (dense) signal — information loss,
        // which is exactly the Table 12 failure mode being reproduced.
        g
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let mut v = self.branch1.params();
        v.extend(self.branch2.params());
        v.extend(self.gap_bn.params());
        v.extend(self.gap_conv.params());
        v
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

/// Build the Boolean segmentation net: logits at input resolution.
pub fn segnet_boolean(cfg: &SegNetConfig, rng: &mut Rng) -> Sequential {
    let wdt = cfg.width;
    let mut net = Sequential::new("segnet_bold");
    // FP stem, stride 2.
    net.push(Box::new(Conv2d::new("stem", cfg.in_channels, wdt, 3, 2, 1, rng)));
    // Boolean encoder block, stride 2 (÷4 total).
    {
        let mut main = Sequential::new("enc.main");
        main.push(Box::new(ThresholdAct::new(
            "enc.act1",
            0.0,
            BackwardScale::TanhPrime { fanin: wdt * 9 },
        )));
        main.push(Box::new(BoolConv2d::new("enc.conv1", wdt, wdt, 3, 2, 1, rng)));
        main.push(Box::new(ThresholdAct::new(
            "enc.act2",
            0.0,
            BackwardScale::TanhPrime { fanin: wdt * 9 },
        )));
        main.push(Box::new(BoolConv2d::new("enc.conv2", wdt, wdt, 3, 1, 1, rng)));
        let mut short = Sequential::new("enc.short");
        short.push(Box::new(ThresholdAct::new(
            "enc.sact",
            0.0,
            BackwardScale::TanhPrime { fanin: wdt * 9 },
        )));
        short.push(Box::new(BoolConv2d::new("enc.sconv", wdt, wdt, 3, 2, 1, rng)));
        net.push(Box::new(Residual::new("enc", main, short)));
    }
    // Context module.
    net.push(Box::new(BoolAspp::new("aspp", wdt, cfg.naive_aspp, rng)));
    // FP classifier + upsample to input resolution.
    net.push(Box::new(Conv2d::new("cls", wdt, cfg.classes, 1, 1, 0, rng)));
    net.push(Box::new(UpsampleNearest::new("up", 4)));
    net
}

/// Mean intersection-over-union over `classes`, ignoring `ignore` labels.
pub fn mean_iou(pred: &[usize], target: &[usize], classes: usize, ignore: Option<usize>) -> f32 {
    assert_eq!(pred.len(), target.len());
    let mut inter = vec![0usize; classes];
    let mut union = vec![0usize; classes];
    for (&p, &t) in pred.iter().zip(target) {
        if Some(t) == ignore {
            continue;
        }
        if p == t {
            inter[t] += 1;
            union[t] += 1;
        } else {
            if p < classes {
                union[p] += 1;
            }
            union[t] += 1;
        }
    }
    let mut sum = 0.0;
    let mut cnt = 0;
    for c in 0..classes {
        if union[c] > 0 {
            sum += inter[c] as f32 / union[c] as f32;
            cnt += 1;
        }
    }
    if cnt == 0 { 0.0 } else { sum / cnt as f32 }
}

/// Per-class IoU (for the Table 12 class-wise report).
pub fn class_iou(pred: &[usize], target: &[usize], classes: usize) -> Vec<f32> {
    let mut inter = vec![0usize; classes];
    let mut union = vec![0usize; classes];
    for (&p, &t) in pred.iter().zip(target) {
        if p == t && t < classes {
            inter[t] += 1;
            union[t] += 1;
        } else {
            if p < classes {
                union[p] += 1;
            }
            if t < classes {
                union[t] += 1;
            }
        }
    }
    (0..classes)
        .map(|c| if union[c] == 0 { 0.0 } else { inter[c] as f32 / union[c] as f32 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_backward_shapes() {
        let mut rng = Rng::new(1);
        for naive in [false, true] {
            let cfg = SegNetConfig { hw: 16, width: 8, naive_aspp: naive, ..Default::default() };
            let mut net = segnet_boolean(&cfg, &mut rng);
            let x = Tensor::randn(&[2, 3, 16, 16], 1.0, &mut rng);
            let y = net.forward(Value::F32(x), true).expect_f32("t");
            assert_eq!(y.shape, vec![2, 6, 16, 16], "naive={naive}");
            let g = net.backward(Tensor::full(&y.shape.clone(), 0.01), &mut ParamStore::new());
            assert_eq!(g.shape, vec![2, 3, 16, 16]);
        }
    }

    #[test]
    fn miou_perfect_and_disjoint() {
        let t = vec![0, 0, 1, 1, 2, 2];
        assert!((mean_iou(&t, &t, 3, None) - 1.0).abs() < 1e-6);
        let p = vec![1, 1, 2, 2, 0, 0];
        assert_eq!(mean_iou(&p, &t, 3, None), 0.0);
    }

    #[test]
    fn miou_ignores_void() {
        let t = vec![0, 0, 255, 1];
        let p = vec![0, 0, 1, 1];
        let m = mean_iou(&p, &t, 2, Some(255));
        assert!((m - 1.0).abs() < 1e-6, "{m}");
    }

    #[test]
    fn class_iou_partial() {
        let t = vec![0, 0, 1, 1];
        let p = vec![0, 1, 1, 1];
        let ious = class_iou(&p, &t, 2);
        assert!((ious[0] - 0.5).abs() < 1e-6); // inter 1, union 2
        assert!((ious[1] - 2.0 / 3.0).abs() < 1e-6); // inter 2, union 3
    }
}
