//! Boolean MLP matching the L2 AOT artifact (python/compile/model.py):
//! BoolLinear(784→512) → act → BoolLinear(512→256) → act → FP Linear(→10).
//! The native engine and the PJRT-compiled artifact are cross-checked in
//! rust/tests/xla_crosscheck.rs.

use crate::nn::{BackwardScale, BoolLinear, Linear, Sequential, ThresholdAct};
use crate::util::Rng;

/// MLP configuration (defaults mirror the AOT artifact dims).
#[derive(Debug, Clone)]
pub struct MlpConfig {
    pub d_in: usize,
    pub hidden: Vec<usize>,
    pub d_out: usize,
    /// Appendix C tanh' backward scaling (on by default, as in the paper).
    pub tanh_scale: bool,
}

impl Default for MlpConfig {
    fn default() -> Self {
        MlpConfig { d_in: 784, hidden: vec![512, 256], d_out: 10, tanh_scale: true }
    }
}

fn scale(cfg: &MlpConfig, fanin: usize) -> BackwardScale {
    if cfg.tanh_scale { BackwardScale::TanhPrime { fanin } } else { BackwardScale::Identity }
}

/// Native Boolean MLP: Boolean interior, FP head (the paper's recipe).
/// Input is expected as a Bit value (±1-binarized features).
pub fn boolean_mlp(cfg: &MlpConfig, rng: &mut Rng) -> Sequential {
    let mut net = Sequential::new("bool_mlp");
    let mut d = cfg.d_in;
    for (i, &h) in cfg.hidden.iter().enumerate() {
        net.push(Box::new(BoolLinear::new(&format!("bl{i}"), d, h, rng)));
        net.push(Box::new(ThresholdAct::new(&format!("act{i}"), 0.0, scale(cfg, d))));
        d = h;
    }
    net.push(Box::new(Linear::new("head", d, cfg.d_out, rng)));
    net
}

/// FP baseline of the same shape (ReLU MLP).
pub fn fp_mlp(cfg: &MlpConfig, rng: &mut Rng) -> Sequential {
    let mut net = Sequential::new("fp_mlp");
    let mut d = cfg.d_in;
    for (i, &h) in cfg.hidden.iter().enumerate() {
        net.push(Box::new(Linear::new(&format!("fc{i}"), d, h, rng)));
        net.push(Box::new(crate::nn::ReLU::new(&format!("relu{i}"))));
        d = h;
    }
    net.push(Box::new(Linear::new("head", d, cfg.d_out, rng)));
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Layer, Value};
    use crate::tensor::Tensor;

    #[test]
    fn boolean_mlp_shapes() {
        let mut rng = Rng::new(1);
        let cfg = MlpConfig { d_in: 64, hidden: vec![32, 16], d_out: 4, tanh_scale: true };
        let mut net = boolean_mlp(&cfg, &mut rng);
        let x = Tensor::rand_pm1(&[8, 64], &mut rng);
        let y = net.forward(Value::bit_from_pm1(&x), true).expect_f32("t");
        assert_eq!(y.shape, vec![8, 4]);
        let g = net.backward(Tensor::full(&[8, 4], 0.1), &mut crate::nn::ParamStore::new());
        assert_eq!(g.shape, vec![8, 64]);
    }

    #[test]
    fn param_split_bool_vs_real() {
        let mut rng = Rng::new(2);
        let cfg = MlpConfig { d_in: 32, hidden: vec![16], d_out: 4, tanh_scale: false };
        let mut net = boolean_mlp(&cfg, &mut rng);
        let params = net.params();
        let bools = params.iter().filter(|p| matches!(p, crate::nn::ParamRef::Bool { .. })).count();
        let reals = params.iter().filter(|p| matches!(p, crate::nn::ParamRef::Real { .. })).count();
        assert_eq!(bools, 1, "one Boolean weight tensor");
        assert_eq!(reals, 2, "FP head w + b");
    }
}
