//! Model-specific layers: pixel shuffle (EDSR upsampler) and nearest
//! upsampling (segmentation decoder). Both are pure permutations /
//! replications with exact adjoint backwards.

use crate::nn::{Layer, ParamStore, Value};
use crate::tensor::Tensor;

/// Depth-to-space: (N, C·r², H, W) → (N, C, H·r, W·r) (EDSR upsampler).
pub struct PixelShuffle {
    pub r: usize,
    name: String,
    cache_dims: Option<(usize, usize, usize, usize)>, // input dims
}

impl PixelShuffle {
    pub fn new(name: &str, r: usize) -> Self {
        PixelShuffle { r, name: name.to_string(), cache_dims: None }
    }

    fn shuffle(&self, t: &Tensor) -> Tensor {
        let (n, c_in, h, w) = t.dims4();
        let r = self.r;
        assert_eq!(c_in % (r * r), 0, "{}: C not divisible by r²", self.name);
        let c = c_in / (r * r);
        let mut out = Tensor::zeros(&[n, c, h * r, w * r]);
        for ni in 0..n {
            for ci in 0..c {
                for dy in 0..r {
                    for dx in 0..r {
                        let src_c = ci * r * r + dy * r + dx;
                        for y in 0..h {
                            for x in 0..w {
                                let src = ((ni * c_in + src_c) * h + y) * w + x;
                                let dst =
                                    ((ni * c + ci) * (h * r) + y * r + dy) * (w * r) + x * r + dx;
                                out.data[dst] = t.data[src];
                            }
                        }
                    }
                }
            }
        }
        out
    }

    fn unshuffle(&self, z: &Tensor, dims: (usize, usize, usize, usize)) -> Tensor {
        let (n, c_in, h, w) = dims;
        let r = self.r;
        let c = c_in / (r * r);
        let mut g = Tensor::zeros(&[n, c_in, h, w]);
        for ni in 0..n {
            for ci in 0..c {
                for dy in 0..r {
                    for dx in 0..r {
                        let src_c = ci * r * r + dy * r + dx;
                        for y in 0..h {
                            for x in 0..w {
                                let dst = ((ni * c_in + src_c) * h + y) * w + x;
                                let src =
                                    ((ni * c + ci) * (h * r) + y * r + dy) * (w * r) + x * r + dx;
                                g.data[dst] = z.data[src];
                            }
                        }
                    }
                }
            }
        }
        g
    }
}

impl Layer for PixelShuffle {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        if train {
            self.cache_dims = Some(t.dims4());
        }
        Value::F32(self.shuffle(&t))
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        let dims = self.cache_dims.expect("backward before forward");
        self.unshuffle(&z, dims)
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

/// Nearest-neighbour upsampling ×k; backward sums the replicated lanes.
pub struct UpsampleNearest {
    pub k: usize,
    name: String,
    cache_dims: Option<(usize, usize, usize, usize)>,
}

impl UpsampleNearest {
    pub fn new(name: &str, k: usize) -> Self {
        UpsampleNearest { k, name: name.to_string(), cache_dims: None }
    }
}

impl Layer for UpsampleNearest {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        let (n, c, h, w) = t.dims4();
        if train {
            self.cache_dims = Some((n, c, h, w));
        }
        let k = self.k;
        let mut out = Tensor::zeros(&[n, c, h * k, w * k]);
        for ni in 0..n {
            for ci in 0..c {
                let plane = (ni * c + ci) * h * w;
                let oplane = (ni * c + ci) * h * k * w * k;
                for y in 0..h * k {
                    for x2 in 0..w * k {
                        out.data[oplane + y * w * k + x2] = t.data[plane + (y / k) * w + x2 / k];
                    }
                }
            }
        }
        Value::F32(out)
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        let (n, c, h, w) = self.cache_dims.expect("backward before forward");
        let k = self.k;
        let mut g = Tensor::zeros(&[n, c, h, w]);
        for ni in 0..n {
            for ci in 0..c {
                let plane = (ni * c + ci) * h * w;
                let oplane = (ni * c + ci) * h * k * w * k;
                for y in 0..h * k {
                    for x2 in 0..w * k {
                        g.data[plane + (y / k) * w + x2 / k] += z.data[oplane + y * w * k + x2];
                    }
                }
            }
        }
        g
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

/// Fixed scalar scale `y = s·x` (forward and backward) — used to bring
/// Boolean conv integer counts (O(fan-in)) back to the O(1) range of an FP
/// feature stream before a residual summation. The factor α = π/(2√(3m))
/// of Eq. (24) matches the count's standard deviation (Appendix C.3).
pub struct ScaleLayer {
    pub s: f32,
    name: String,
}

impl ScaleLayer {
    pub fn new(name: &str, s: f32) -> Self {
        ScaleLayer { s, name: name.to_string() }
    }
}

impl Layer for ScaleLayer {
    fn forward(&mut self, x: Value, _train: bool) -> Value {
        Value::F32(x.to_f32().scale(self.s))
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        z.scale(self.s)
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn scale_layer_scales_both_ways() {
        let mut s = ScaleLayer::new("s", 0.25);
        let x = Tensor::from_vec(&[1, 2], vec![4.0, -8.0]);
        let y = s.forward(Value::F32(x), true).expect_f32("t");
        assert_eq!(y.data, vec![1.0, -2.0]);
        let g = s.backward(Tensor::from_vec(&[1, 2], vec![1.0, 1.0]), &mut ParamStore::new());
        assert_eq!(g.data, vec![0.25, 0.25]);
    }

    #[test]
    fn pixel_shuffle_shapes_and_inverse() {
        let mut rng = Rng::new(1);
        let mut ps = PixelShuffle::new("ps", 2);
        let x = Tensor::randn(&[2, 8, 3, 3], 1.0, &mut rng);
        let y = ps.forward(Value::F32(x.clone()), true).expect_f32("t");
        assert_eq!(y.shape, vec![2, 2, 6, 6]);
        // backward is the exact inverse permutation
        let g = ps.backward(y, &mut ParamStore::new());
        assert!(g.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn pixel_shuffle_is_adjoint() {
        let mut rng = Rng::new(2);
        let mut ps = PixelShuffle::new("ps", 3);
        let x = Tensor::randn(&[1, 9, 2, 2], 1.0, &mut rng);
        let y = ps.forward(Value::F32(x.clone()), true).expect_f32("t");
        let z = Tensor::randn(&y.shape, 1.0, &mut rng);
        let g = ps.backward(z.clone(), &mut ParamStore::new());
        let lhs: f32 = y.data.iter().zip(&z.data).map(|(a, b)| a * b).sum();
        let rhs: f32 = x.data.iter().zip(&g.data).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-3);
    }

    #[test]
    fn upsample_nearest_replicates_and_sums() {
        let mut up = UpsampleNearest::new("up", 2);
        let x = Tensor::from_vec(&[1, 1, 1, 2], vec![3.0, 5.0]);
        let y = up.forward(Value::F32(x), true).expect_f32("t");
        assert_eq!(y.shape, vec![1, 1, 2, 4]);
        assert_eq!(y.data, vec![3.0, 3.0, 5.0, 5.0, 3.0, 3.0, 5.0, 5.0]);
        let g = up.backward(Tensor::full(&[1, 1, 2, 4], 1.0), &mut ParamStore::new());
        assert_eq!(g.data, vec![4.0, 4.0]);
    }
}
