//! Fault-tolerant multi-process data-parallel training (DESIGN.md
//! §Distributed-Training).
//!
//! A coordinator process owns the model, the [`DualOptimizer`] and the
//! batch schedule; worker processes hold stateless model replicas. Each
//! step the coordinator shards the batch's sample indices across live
//! workers over TCP ([`super::wire`]), workers run forward/backward on
//! their shard and ship the [`ParamStore`] vote/gradient delta back
//! ([`ParamStore::grad_blob`]), and the coordinator aggregates
//! store-to-store and applies ONE optimizer step — exactly the in-process
//! [`super::ParallelTrainer`] dance, with processes for threads.
//!
//! Determinism (the property the fault-injection suite pins down): the
//! shard count is FIXED at job start (`TrainConfig::workers`), not tied
//! to the live worker count, and shard deltas are aggregated in shard-id
//! order after all arrive. BOLD's Boolean votes are integer counts and
//! the FP grads are added in the same order as `ParallelTrainer`'s
//! leader loop, so the final weights are bit-identical to the
//! single-process reference no matter how many workers serve the job,
//! which workers die mid-epoch, or how often a shard is re-issued.
//!
//! Robustness mechanics:
//! - per-worker liveness deadline (`BOLD_DIST_DEADLINE_MS`) fed by
//!   heartbeats (`BOLD_DIST_HEARTBEAT_MS`) and any other traffic;
//! - straggler re-issue: a shard outstanding past the deadline is handed
//!   to another live worker — safe because results are idempotent per
//!   (step, shard) and duplicates are dropped;
//! - worker reconnect with capped exponential backoff + jitter
//!   (`BOLD_DIST_BACKOFF_{BASE,CAP}_MS`), full weight re-`Sync` on join;
//! - corrupt frames sever the connection without touching vote state;
//! - crash-resume from the kind-3/4/5 optimizer checkpoints
//!   ([`super::save_training_with_meta`] with a `dist.step` cursor),
//!   written atomically (tmp + rename) every `--ckpt-every` steps.

use super::checkpoint::{
    apply_params_blob, params_blob, read_records, save_training_with_meta, Record,
};
use super::wire::{read_frame, read_frame_idle, write_frame, Msg, WireError};
use super::{evaluate_classifier, DualOptimizer, TrainReport};
use crate::config::TrainConfig;
use crate::data::{BatchSampler, ImageDataset};
use crate::models::{boolean_mlp, MlpConfig};
use crate::nn::{softmax_cross_entropy, Layer, ParamStore, Sequential, Value};
use crate::util::Rng;
use std::collections::{HashMap, HashSet, VecDeque};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Meta-record name of the resume cursor in dist checkpoints.
pub const META_DIST_STEP: &str = "dist.step";

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Distributed-training knobs. [`DistConfig::from_env`] reads the
/// `BOLD_DIST_*` environment (README §Training knobs); CLI flags override.
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Worker idle-heartbeat period, ms (`BOLD_DIST_HEARTBEAT_MS`).
    pub heartbeat_ms: u64,
    /// Liveness + straggler deadline, ms (`BOLD_DIST_DEADLINE_MS`): a
    /// worker silent this long is dead; a shard outstanding this long is
    /// re-issued.
    pub deadline_ms: u64,
    /// Reconnect backoff base, ms (`BOLD_DIST_BACKOFF_BASE_MS`).
    pub backoff_base_ms: u64,
    /// Reconnect backoff cap, ms (`BOLD_DIST_BACKOFF_CAP_MS`).
    pub backoff_cap_ms: u64,
    /// Worker gives up after this long of consecutive failed connects,
    /// ms (`BOLD_DIST_GIVEUP_MS`) — bounds orphan workers when the
    /// coordinator is gone for good.
    pub giveup_ms: u64,
    /// Checkpoint every N committed steps (0 = only at job end).
    pub ckpt_every: usize,
    /// Checkpoint path (enables checkpointing and resume).
    pub ckpt_path: Option<String>,
    /// Resume from `ckpt_path` if it exists.
    pub resume: bool,
}

impl DistConfig {
    pub fn from_env() -> Self {
        DistConfig {
            heartbeat_ms: env_u64("BOLD_DIST_HEARTBEAT_MS", 500),
            deadline_ms: env_u64("BOLD_DIST_DEADLINE_MS", 5000),
            backoff_base_ms: env_u64("BOLD_DIST_BACKOFF_BASE_MS", 50),
            backoff_cap_ms: env_u64("BOLD_DIST_BACKOFF_CAP_MS", 2000),
            giveup_ms: env_u64("BOLD_DIST_GIVEUP_MS", 60_000),
            ckpt_every: 0,
            ckpt_path: None,
            resume: false,
        }
    }
}

impl Default for DistConfig {
    fn default() -> Self {
        Self::from_env()
    }
}

/// The canonical job description: every process of a job (coordinator,
/// workers, the test's reference trainer) builds dataset and model from
/// the same [`TrainConfig`] through this ONE site, so they cannot drift.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub cfg: TrainConfig,
}

impl JobSpec {
    /// Validate the config for distributed training (the dist path
    /// drives the MLP classifier job, like `bold train --model mlp`).
    pub fn new(cfg: TrainConfig) -> Result<Self, String> {
        if cfg.model != "mlp" {
            return Err(format!("train-dist supports --model mlp (got '{}')", cfg.model));
        }
        if cfg.workers == 0 {
            return Err("--workers (the fixed shard count) must be >= 1".into());
        }
        Ok(JobSpec { cfg })
    }

    /// Fixed shard count: determinism is anchored to it, never to the
    /// number of live workers.
    pub fn n_shards(&self) -> usize {
        self.cfg.workers
    }

    /// The job's (train, val) datasets — same synthesis as `bold train`.
    pub fn data(&self) -> (ImageDataset, ImageDataset) {
        ImageDataset::mnist_like(
            self.cfg.train_size + self.cfg.val_size,
            self.cfg.classes,
            256,
            0.08,
            self.cfg.seed,
        )
        .split(self.cfg.train_size)
    }

    /// A fresh model replica — same init as `bold train --model mlp`.
    pub fn model(&self) -> Sequential {
        let mcfg = MlpConfig {
            d_in: 256,
            hidden: vec![128, 64],
            d_out: self.cfg.classes,
            tanh_scale: true,
        };
        boolean_mlp(&mcfg, &mut Rng::new(self.cfg.seed))
    }

    /// Fingerprint of everything that must agree between coordinator and
    /// worker for votes to be meaningful: dataset identity, model init,
    /// batch schedule, shard count. FNV-1a over a field serialization.
    pub fn config_hash(&self) -> u64 {
        let c = &self.cfg;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        };
        eat(c.model.as_bytes());
        for v in [
            c.seed,
            c.batch as u64,
            c.steps as u64,
            c.train_size as u64,
            c.val_size as u64,
            c.classes as u64,
            c.workers as u64,
            c.lr_bool.to_bits() as u64,
            c.lr_fp.to_bits() as u64,
            c.cosine as u64,
        ] {
            eat(&v.to_le_bytes());
        }
        h
    }
}

/// Fault/recovery counters of one coordinator run.
#[derive(Debug, Clone, Default)]
pub struct DistStats {
    /// Worker connections accepted and admitted (Hello verified).
    pub joins: u64,
    /// Admitted joins from a worker id seen before (reconnects).
    pub reconnects: u64,
    /// Workers declared dead (io error, corrupt frame, or deadline).
    pub removed: u64,
    /// Shards re-issued past the straggler deadline.
    pub reissues: u64,
    /// Duplicate shard results dropped (idempotence at work).
    pub duplicates: u64,
    /// Results for a step other than the current one, dropped.
    pub stale: u64,
    /// Connections turned away (bad Hello / config-hash mismatch).
    pub rejected: u64,
    /// Connections severed on corrupt framing.
    pub corrupt_frames: u64,
}

/// What a finished coordinator run hands back: the trained model (for
/// bit-exactness checks and checkpointing), the usual training report,
/// and the fault counters.
pub struct DistOutcome {
    pub model: Sequential,
    pub report: TrainReport,
    pub stats: DistStats,
    /// First step this run executed (>0 after a resume).
    pub start_step: usize,
}

enum Event {
    Joined { conn: u64, worker_id: u64, stream: TcpStream },
    Frame { conn: u64, msg: Msg },
    Gone { conn: u64, corrupt: bool },
    Rejected,
}

struct WorkerConn {
    stream: TcpStream,
    worker_id: u64,
    last_seen: Instant,
}

struct ShardRes {
    loss: f32,
    correct: u32,
    delta: ParamStore,
}

/// Per-connection reader: verifies the Hello handshake, then pumps
/// frames into the coordinator's event queue until the peer goes away.
fn reader_thread(conn: u64, mut stream: TcpStream, tx: mpsc::Sender<Event>, want_hash: u64) {
    let _ = stream.set_nodelay(true);
    match read_frame(&mut stream) {
        Ok(Msg::Hello { worker_id, config_hash }) if config_hash == want_hash => {
            let Ok(wstream) = stream.try_clone() else {
                let _ = tx.send(Event::Rejected);
                return;
            };
            if tx.send(Event::Joined { conn, worker_id, stream: wstream }).is_err() {
                return;
            }
        }
        Ok(_) => {
            // wrong config or non-Hello opener: turn it away before it
            // can contribute votes computed against different state
            let _ = write_frame(&mut stream, &Msg::Bye);
            let _ = tx.send(Event::Rejected);
            return;
        }
        Err(_) => {
            let _ = tx.send(Event::Rejected);
            return;
        }
    }
    loop {
        match read_frame(&mut stream) {
            Ok(msg) => {
                if tx.send(Event::Frame { conn, msg }).is_err() {
                    return;
                }
            }
            Err(e) => {
                let corrupt = matches!(e, WireError::Corrupt(_));
                let _ = tx.send(Event::Gone { conn, corrupt });
                return;
            }
        }
    }
}

/// Run the coordinator side of a job on a pre-bound listener (bind to
/// port 0 and read `listener.local_addr()` to wire up workers/tests).
/// Blocks until all `cfg.steps` steps have committed, surviving worker
/// churn; returns the trained model, report and fault counters.
pub fn run_coordinator(
    spec: &JobSpec,
    dcfg: &DistConfig,
    listener: TcpListener,
    log: bool,
) -> Result<DistOutcome, String> {
    let cfg = &spec.cfg;
    let n_shards = spec.n_shards();
    let (train, val) = spec.data();
    let mut model = spec.model();
    let mut opt = DualOptimizer::new(cfg);

    // --- resume from checkpoint (bit-exact: weights + optimizer state
    // + schedule cursor) ---
    let mut start_step = 0usize;
    if dcfg.resume {
        let path = dcfg
            .ckpt_path
            .as_deref()
            .ok_or("--resume needs --ckpt PATH")?;
        super::load_training(&mut model, &mut opt.store, path).map_err(|e| e.to_string())?;
        start_step = read_records(path)
            .map_err(|e| e.to_string())?
            .iter()
            .find_map(|r| match r {
                Record::Meta { name, value } if name == META_DIST_STEP => Some(*value as usize),
                _ => None,
            })
            .ok_or_else(|| format!("{path}: no {META_DIST_STEP} cursor — not a dist snapshot"))?;
        if log {
            println!("resumed from {path} at step {start_step}");
        }
    }

    // Same schedule as ParallelTrainer::fit, replayed up to the cursor.
    let mut sampler = BatchSampler::new(train.n, cfg.batch, cfg.seed ^ 0x5A);
    for _ in 0..start_step {
        let _ = sampler.next_batch();
    }

    // --- accept/reader plumbing ---
    let (tx, rx) = mpsc::channel::<Event>();
    let stop = Arc::new(AtomicBool::new(false));
    let all_conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
    let want_hash = spec.config_hash();
    let accept_handle = {
        let tx = tx.clone();
        let stop = Arc::clone(&stop);
        let all_conns = Arc::clone(&all_conns);
        listener.set_nonblocking(true).map_err(|e| e.to_string())?;
        std::thread::spawn(move || {
            let mut next_conn = 1u64;
            loop {
                match listener.accept() {
                    Ok((stream, _addr)) => {
                        let conn = next_conn;
                        next_conn += 1;
                        if let Ok(c) = stream.try_clone() {
                            all_conns.lock().expect("conn registry").push(c);
                        }
                        let _ = stream.set_nonblocking(false);
                        let tx = tx.clone();
                        std::thread::spawn(move || reader_thread(conn, stream, tx, want_hash));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => {
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            }
        })
    };
    drop(tx); // readers hold clones; rx closes when all are gone

    let deadline = Duration::from_millis(dcfg.deadline_ms.max(1));
    let tick = Duration::from_millis((dcfg.heartbeat_ms / 2).clamp(10, 250));

    let mut workers: HashMap<u64, WorkerConn> = HashMap::new();
    let mut seen_ids: HashSet<u64> = HashSet::new();
    let mut stats = DistStats::default();
    let mut report = TrainReport { steps: cfg.steps, ..Default::default() };

    let ckpt = |model: &mut Sequential, opt: &DualOptimizer, next_step: usize| -> Result<(), String> {
        let Some(path) = dcfg.ckpt_path.as_deref() else { return Ok(()) };
        let tmp = format!("{path}.tmp");
        save_training_with_meta(
            model,
            &opt.store,
            &[(META_DIST_STEP.to_string(), next_step as u64)],
            &tmp,
        )
        .map_err(|e| e.to_string())?;
        std::fs::rename(&tmp, path).map_err(|e| e.to_string())
    };

    for step in start_step..cfg.steps {
        let idx = sampler.next_batch();
        let total = idx.len();
        let shard_size = idx.len().div_ceil(n_shards);
        let shards: Vec<Vec<u32>> =
            idx.chunks(shard_size).map(|c| c.iter().map(|&i| i as u32).collect()).collect();
        let n_live = shards.len();
        let blob = {
            let p = model.params();
            params_blob(&p)
        };

        let mut pending: VecDeque<usize> = (0..n_live).collect();
        let mut assignments: HashMap<usize, (u64, Instant)> = HashMap::new();
        let mut results: Vec<Option<ShardRes>> = (0..n_live).map(|_| None).collect();
        let mut done = 0usize;
        let mut warned_idle = false;

        while done < n_live {
            // --- dispatch pending shards to the least-loaded live workers ---
            while let Some(&sid) = pending.front() {
                let mut dispatched = false;
                while !workers.is_empty() {
                    // least outstanding assignments first
                    let (&conn, _) = workers
                        .iter()
                        .min_by_key(|(c, _)| {
                            assignments.values().filter(|(a, _)| a == *c).count()
                        })
                        .expect("non-empty");
                    let msg = Msg::Assign {
                        step: step as u64,
                        shard_id: sid as u32,
                        total: total as u32,
                        indices: shards[sid].clone(),
                    };
                    let ok = write_frame(&mut workers.get_mut(&conn).expect("live").stream, &msg)
                        .is_ok();
                    if ok {
                        assignments.insert(sid, (conn, Instant::now()));
                        dispatched = true;
                        break;
                    }
                    // write failure: the worker is gone
                    remove_worker(&mut workers, conn, &mut stats, &mut assignments, &mut pending, &results);
                }
                if dispatched {
                    pending.pop_front();
                } else {
                    break; // no live workers; wait for joins
                }
            }
            if workers.is_empty() && log && !warned_idle {
                println!("step {step}: no live workers — waiting for (re)connects");
                warned_idle = true;
            }

            // --- one event or a tick ---
            let ev = match rx.recv_timeout(tick) {
                Ok(ev) => Some(ev),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err("coordinator event channel closed".into())
                }
            };
            if let Some(ev) = ev {
                match ev {
                    Event::Joined { conn, worker_id, mut stream } => {
                        if !seen_ids.insert(worker_id) {
                            stats.reconnects += 1;
                        }
                        stats.joins += 1;
                        // weights first, always: a worker may never act on
                        // an Assign for a step it was not synced at
                        if write_frame(&mut stream, &Msg::Sync { step: step as u64, params: blob.clone() })
                            .is_ok()
                        {
                            workers.insert(
                                conn,
                                WorkerConn { stream, worker_id, last_seen: Instant::now() },
                            );
                            if log {
                                println!("step {step}: worker {worker_id} joined (conn {conn})");
                            }
                        }
                    }
                    Event::Frame { conn, msg } => {
                        if let Some(w) = workers.get_mut(&conn) {
                            w.last_seen = Instant::now();
                        }
                        match msg {
                            Msg::ShardResult { step: rstep, shard_id, loss, correct, grads } => {
                                let sid = shard_id as usize;
                                if rstep as usize != step || sid >= n_live {
                                    stats.stale += 1;
                                } else if results[sid].is_some() {
                                    stats.duplicates += 1;
                                } else {
                                    match ParamStore::from_grad_blob(&grads) {
                                        Ok(delta) => {
                                            results[sid] =
                                                Some(ShardRes { loss, correct, delta });
                                            done += 1;
                                            assignments.remove(&sid);
                                        }
                                        Err(_) => {
                                            // structurally invalid delta:
                                            // sever, re-issue the shard
                                            stats.corrupt_frames += 1;
                                            remove_worker(
                                                &mut workers,
                                                conn,
                                                &mut stats,
                                                &mut assignments,
                                                &mut pending,
                                                &results,
                                            );
                                        }
                                    }
                                }
                            }
                            Msg::Heartbeat => {}
                            Msg::Bye => {
                                remove_worker(
                                    &mut workers,
                                    conn,
                                    &mut stats,
                                    &mut assignments,
                                    &mut pending,
                                    &results,
                                );
                            }
                            _ => {}
                        }
                    }
                    Event::Gone { conn, corrupt } => {
                        if corrupt {
                            stats.corrupt_frames += 1;
                        }
                        remove_worker(
                            &mut workers,
                            conn,
                            &mut stats,
                            &mut assignments,
                            &mut pending,
                            &results,
                        );
                    }
                    Event::Rejected => stats.rejected += 1,
                }
            }

            // --- liveness + straggler sweep ---
            let now = Instant::now();
            let dead: Vec<u64> = workers
                .iter()
                .filter(|(_, w)| now.duration_since(w.last_seen) > deadline)
                .map(|(&c, _)| c)
                .collect();
            for conn in dead {
                if log {
                    let wid = workers[&conn].worker_id;
                    println!("step {step}: worker {wid} missed deadline — removing");
                }
                remove_worker(&mut workers, conn, &mut stats, &mut assignments, &mut pending, &results);
            }
            let overdue: Vec<usize> = assignments
                .iter()
                .filter(|(sid, (_, t))| {
                    results[**sid].is_none() && now.duration_since(*t) > deadline
                })
                .map(|(&sid, _)| sid)
                .collect();
            for sid in overdue {
                // hand the shard to another worker; the original result,
                // if it ever lands, is dropped as a duplicate
                stats.reissues += 1;
                let holder = assignments.get(&sid).map(|(c, _)| *c);
                let other = workers
                    .iter()
                    .filter(|(c, _)| Some(**c) != holder)
                    .map(|(&c, _)| c)
                    .next()
                    .or(holder);
                if let Some(conn) = other {
                    let msg = Msg::Assign {
                        step: step as u64,
                        shard_id: sid as u32,
                        total: total as u32,
                        indices: shards[sid].clone(),
                    };
                    if write_frame(&mut workers.get_mut(&conn).expect("live").stream, &msg).is_ok()
                    {
                        assignments.insert(sid, (conn, Instant::now()));
                    } else {
                        remove_worker(&mut workers, conn, &mut stats, &mut assignments, &mut pending, &results);
                    }
                }
            }
        }

        // --- aggregate in shard-id order (the determinism anchor), one
        // optimizer step, commit broadcast ---
        opt.store.zero_grads();
        let mut loss = 0.0f32;
        let mut correct = 0usize;
        for r in results.iter().flatten() {
            opt.store.add_grads_from(&r.delta);
            loss += r.loss;
            correct += r.correct as usize;
        }
        let flips = {
            let mut p = model.params();
            opt.apply(&mut p, step)
        };
        report.losses.push(loss);
        report.train_acc.push(correct as f32 / total.max(1) as f32);
        report.flip_rates.push(flips.flip_rate());
        if log && step % cfg.log_every.max(1) == 0 {
            println!(
                "step {step:>5}  loss {loss:>8.4}  [{} live worker(s), {} shards]",
                workers.len(),
                n_live
            );
        }

        let commit_blob = {
            let p = model.params();
            params_blob(&p)
        };
        let conns: Vec<u64> = workers.keys().copied().collect();
        for conn in conns {
            let ok = write_frame(
                &mut workers.get_mut(&conn).expect("live").stream,
                &Msg::Sync { step: step as u64 + 1, params: commit_blob.clone() },
            )
            .is_ok();
            if !ok {
                let mut unused_pending = VecDeque::new();
                remove_worker(&mut workers, conn, &mut stats, &mut assignments, &mut unused_pending, &results);
            }
        }

        if dcfg.ckpt_every > 0 && (step + 1) % dcfg.ckpt_every == 0 && step + 1 < cfg.steps {
            ckpt(&mut model, &opt, step + 1)?;
        }
    }

    // final checkpoint (resume cursor = steps ⇒ a resumed job is a no-op)
    if dcfg.ckpt_path.is_some() {
        ckpt(&mut model, &opt, cfg.steps)?;
    }

    // orderly goodbye, then tear down the accept loop and any parked
    // reader threads
    for (_, w) in workers.iter_mut() {
        let _ = write_frame(&mut w.stream, &Msg::Bye);
    }
    stop.store(true, Ordering::Release);
    for c in all_conns.lock().expect("conn registry").iter() {
        let _ = c.shutdown(Shutdown::Both);
    }
    let _ = accept_handle.join();

    report.val_acc = evaluate_classifier(&mut model, &val, cfg.batch);
    Ok(DistOutcome { model, report, stats, start_step })
}

/// Drop a worker connection: sever the socket and put the shards it was
/// computing (and has not delivered) back on the pending queue.
fn remove_worker(
    workers: &mut HashMap<u64, WorkerConn>,
    conn: u64,
    stats: &mut DistStats,
    assignments: &mut HashMap<usize, (u64, Instant)>,
    pending: &mut VecDeque<usize>,
    results: &[Option<ShardRes>],
) {
    let Some(w) = workers.remove(&conn) else { return };
    stats.removed += 1;
    let _ = w.stream.shutdown(Shutdown::Both);
    let lost: Vec<usize> = assignments
        .iter()
        .filter(|(sid, (c, _))| *c == conn && results[**sid].is_none())
        .map(|(&sid, _)| sid)
        .collect();
    for sid in lost {
        assignments.remove(&sid);
        if !pending.contains(&sid) {
            pending.push_back(sid);
        }
    }
}

/// One shard of work, exactly as a `ParallelTrainer` replica would run
/// it: zero the local vote store, forward/backward over `indices` with
/// the gradient scaled by `indices.len() / total`, and serialize the
/// delta. Exported so the fault-injection tests can drive scripted
/// workers over raw sockets.
pub fn compute_shard(
    model: &mut Sequential,
    store: &mut ParamStore,
    train: &ImageDataset,
    indices: &[u32],
    total: u32,
) -> (f32, u32, Vec<u8>) {
    let idx: Vec<usize> = indices.iter().map(|&i| i as usize).collect();
    let flat = train.h == 1;
    let (x, labels) = if flat { train.batch_flat(&idx) } else { train.batch(&idx) };
    let v = if flat { Value::bit_from_pm1(&x) } else { Value::F32(x) };
    store.zero_grads();
    let logits = model.forward(v, true).expect_f32("dist worker");
    let out = softmax_cross_entropy(&logits, &labels);
    let scale = labels.len() as f32 / total as f32;
    let _ = model.backward(out.grad.scale(scale), store);
    (out.loss * scale, out.correct as u32, store.grad_blob())
}

/// Run the worker side of a job: connect (with capped exponential
/// backoff + jitter), handshake, then serve Sync/Assign until the
/// coordinator says `Bye`. Returns the number of shards computed.
pub fn run_worker(
    spec: &JobSpec,
    connect: &str,
    dcfg: &DistConfig,
    worker_id: u64,
    log: bool,
) -> Result<u64, String> {
    let (train, _val) = spec.data();
    let mut model = spec.model();
    let mut store = ParamStore::new();
    let mut rng = Rng::new(worker_id ^ 0x9E37_79B9_7F4A_7C15);
    let hash = spec.config_hash();
    let heartbeat = Duration::from_millis(dcfg.heartbeat_ms.max(10));
    let mut computed = 0u64;

    let mut attempt = 0u32;
    let mut failing_since: Option<Instant> = None;
    loop {
        let stream = match TcpStream::connect(connect) {
            Ok(s) => s,
            Err(e) => {
                let since = *failing_since.get_or_insert_with(Instant::now);
                if since.elapsed() >= Duration::from_millis(dcfg.giveup_ms) {
                    return Err(format!(
                        "worker {worker_id}: coordinator unreachable for {}ms: {e}",
                        dcfg.giveup_ms
                    ));
                }
                // capped exponential backoff with jitter, so a worker
                // herd does not reconnect in lockstep
                let exp = dcfg
                    .backoff_base_ms
                    .saturating_mul(1u64 << attempt.min(10))
                    .min(dcfg.backoff_cap_ms);
                let jitter = if dcfg.backoff_base_ms > 0 {
                    rng.below(dcfg.backoff_base_ms as usize) as u64
                } else {
                    0
                };
                attempt = attempt.saturating_add(1);
                std::thread::sleep(Duration::from_millis(exp + jitter));
                continue;
            }
        };
        attempt = 0;
        failing_since = None;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(heartbeat));
        match serve_connection(
            stream, &train, &mut model, &mut store, hash, worker_id, &mut computed, log,
        ) {
            ConnEnd::Done => return Ok(computed),
            ConnEnd::Retry => {
                if log {
                    println!("worker {worker_id}: connection lost — reconnecting");
                }
            }
        }
    }
}

enum ConnEnd {
    /// Job complete (`Bye` received) — exit cleanly.
    Done,
    /// Connection died — reconnect with backoff.
    Retry,
}

#[allow(clippy::too_many_arguments)]
fn serve_connection(
    mut stream: TcpStream,
    train: &ImageDataset,
    model: &mut Sequential,
    store: &mut ParamStore,
    hash: u64,
    worker_id: u64,
    computed: &mut u64,
    log: bool,
) -> ConnEnd {
    if write_frame(&mut stream, &Msg::Hello { worker_id, config_hash: hash }).is_err() {
        return ConnEnd::Retry;
    }
    // step this replica's weights are synced at; Assigns for any other
    // step are ignored (the coordinator's straggler logic covers them)
    let mut synced: Option<u64> = None;
    loop {
        match read_frame_idle(&mut stream) {
            Ok(None) => {
                // idle past the heartbeat period
                if write_frame(&mut stream, &Msg::Heartbeat).is_err() {
                    return ConnEnd::Retry;
                }
            }
            Ok(Some(Msg::Sync { step, params })) => {
                let mut p = model.params();
                if apply_params_blob(&mut p, &params).is_err() {
                    // weights we cannot install are a protocol breach:
                    // resync from scratch over a fresh connection
                    return ConnEnd::Retry;
                }
                synced = Some(step);
            }
            Ok(Some(Msg::Assign { step, shard_id, total, indices })) => {
                if synced != Some(step) {
                    continue;
                }
                let (loss, correct, grads) =
                    compute_shard(model, store, train, &indices, total);
                *computed += 1;
                if log && *computed % 50 == 0 {
                    println!("worker {worker_id}: {computed} shards computed");
                }
                let msg = Msg::ShardResult { step, shard_id, loss, correct, grads };
                if write_frame(&mut stream, &msg).is_err() {
                    return ConnEnd::Retry;
                }
            }
            Ok(Some(Msg::Bye)) => return ConnEnd::Done,
            Ok(Some(_)) => {}
            Err(_) => return ConnEnd::Retry,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ParallelTrainer;
    use crate::nn::ParamRef;

    fn small_cfg(workers: usize, steps: usize) -> TrainConfig {
        TrainConfig {
            model: "mlp".into(),
            workers,
            steps,
            batch: 12,
            train_size: 48,
            val_size: 16,
            lr_bool: 2.0,
            cosine: true,
            ..Default::default()
        }
    }

    fn assert_params_bit_equal(a: &mut Sequential, b: &mut Sequential) {
        let pa = a.params();
        let pb = b.params();
        assert_eq!(pa.len(), pb.len());
        for (x, y) in pa.iter().zip(pb.iter()) {
            match (x, y) {
                (ParamRef::Bool { name, bits: ba }, ParamRef::Bool { bits: bb, .. }) => {
                    assert_eq!(ba.words, bb.words, "{name}: packed weights diverged");
                }
                (ParamRef::Real { name, w: wa }, ParamRef::Real { w: wb, .. }) => {
                    let (da, db): (Vec<u32>, Vec<u32>) = (
                        wa.data.iter().map(|v| v.to_bits()).collect(),
                        wb.data.iter().map(|v| v.to_bits()).collect(),
                    );
                    assert_eq!(da, db, "{name}: FP weights diverged");
                }
                _ => panic!("param kind mismatch"),
            }
        }
    }

    #[test]
    fn config_hash_separates_jobs() {
        let a = JobSpec::new(small_cfg(2, 4)).unwrap();
        let mut cfg_b = small_cfg(2, 4);
        cfg_b.seed ^= 1;
        let b = JobSpec::new(cfg_b).unwrap();
        assert_eq!(a.config_hash(), a.config_hash());
        assert_ne!(a.config_hash(), b.config_hash());
    }

    #[test]
    fn job_spec_rejects_non_mlp_and_zero_shards() {
        let mut cfg = small_cfg(2, 4);
        cfg.model = "vgg".into();
        assert!(JobSpec::new(cfg).is_err());
        let mut cfg = small_cfg(0, 4);
        cfg.workers = 0;
        assert!(JobSpec::new(cfg).is_err());
    }

    /// Loopback end-to-end: 2 in-process workers, 2 shards — final
    /// weights bit-identical to the in-process ParallelTrainer(2), and
    /// the loss curve matches float-for-float.
    #[test]
    fn loopback_two_workers_match_parallel_trainer_bit_exactly() {
        let cfg = small_cfg(2, 4);
        let spec = JobSpec::new(cfg.clone()).unwrap();
        let dcfg = DistConfig {
            heartbeat_ms: 50,
            deadline_ms: 10_000,
            giveup_ms: 5_000, // bound the test if a worker outlives the job
            ..DistConfig::from_env()
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();

        let outcome = std::thread::scope(|s| {
            for wid in 0..2u64 {
                let spec = spec.clone();
                let dcfg = dcfg.clone();
                let addr = addr.clone();
                s.spawn(move || run_worker(&spec, &addr, &dcfg, wid, false));
            }
            run_coordinator(&spec, &dcfg, listener, false).unwrap()
        });

        let (train, val) = spec.data();
        let spec2 = spec.clone();
        let mut pt = ParallelTrainer::new(2, &cfg, move |_| spec2.model());
        let reference = pt.fit(&train, &val, &cfg, false);

        let mut dist_model = outcome.model;
        assert_params_bit_equal(&mut dist_model, pt.leader());
        let (dl, rl): (Vec<u32>, Vec<u32>) = (
            outcome.report.losses.iter().map(|l| l.to_bits()).collect(),
            reference.losses.iter().map(|l| l.to_bits()).collect(),
        );
        assert_eq!(dl, rl, "loss curves must match bit-for-bit");
        assert_eq!(outcome.report.val_acc, reference.val_acc);
    }
}
