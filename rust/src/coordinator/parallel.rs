//! Batch-parallel training: the coordinator's multi-worker mode, mapping
//! the paper's 8×V100 data-parallel setup (Appendix D.1.1) onto threads.
//!
//! Each worker holds a full model replica plus its own [`ParamStore`] and
//! processes a shard of the batch; the leader *sums the Boolean votes*
//! (Eq. 7 aggregation is additive over samples, so store-to-store vote
//! summation across workers is exactly equivalent to a single large
//! batch — tested below), applies the optimizers once, and broadcasts the
//! updated weights. Note the communication payload for Boolean weights is
//! 1 bit/weight — the distributed-training face of the paper's energy
//! argument.

use super::DualOptimizer;
use crate::config::TrainConfig;
use crate::data::ImageDataset;
use crate::nn::{softmax_cross_entropy, Layer, ParamRef, ParamStore, Sequential, Value};
use crate::optim::FlipStats;
use crate::util::pool;

/// Multi-worker trainer with vote aggregation.
pub struct ParallelTrainer {
    pub replicas: Vec<Sequential>,
    /// One vote store per non-leader replica (the leader accumulates
    /// straight into `opt.store`).
    worker_stores: Vec<ParamStore>,
    pub opt: DualOptimizer,
}

impl ParallelTrainer {
    /// Build `workers` replicas from a factory. The factory is called with
    /// the SAME seed-derived RNG for every replica so all start identical.
    pub fn new<F>(workers: usize, cfg: &TrainConfig, factory: F) -> Self
    where
        F: Fn(u64) -> Sequential,
    {
        assert!(workers >= 1);
        let replicas: Vec<Sequential> = (0..workers).map(|_| factory(cfg.seed)).collect();
        ParallelTrainer {
            replicas,
            worker_stores: (1..workers).map(|_| ParamStore::new()).collect(),
            opt: DualOptimizer::new(cfg),
        }
    }

    pub fn leader(&mut self) -> &mut Sequential {
        &mut self.replicas[0]
    }

    /// One synchronous data-parallel step over shard inputs: `shards[i]`
    /// feeds replica i. A batch may split into FEWER shards than workers
    /// (uneven final chunking) — surplus replicas simply sit the step out;
    /// their zeroed stores contribute nothing to the vote sum.
    /// Returns (mean loss, correct, flips).
    pub fn train_step(
        &mut self,
        shards: Vec<(Value, Vec<usize>)>,
        step: usize,
    ) -> (f32, usize, FlipStats) {
        assert!(
            !shards.is_empty() && shards.len() <= self.replicas.len(),
            "got {} shards for {} workers",
            shards.len(),
            self.replicas.len()
        );
        let total: usize = shards.iter().map(|(_, l)| l.len()).sum();
        // Fresh vote buffers everywhere — including idle workers, so a
        // stale shard from a previous step can never be double-counted.
        self.opt.store.zero_grads();
        for s in self.worker_stores.iter_mut() {
            s.zero_grads();
        }
        // --- parallel forward/backward on each replica's shard ---
        // Thread-budget handoff (DESIGN.md §Parallelism): each worker's
        // intra-op kernels shard over at most its fair share of the pool,
        // so data-parallel × intra-op never oversubscribes the machine.
        let n_active = shards.len();
        let intra_budget = (pool::num_threads() / n_active.max(1)).max(1);
        let results: Vec<(f32, usize)> = std::thread::scope(|scope| {
            let stores = std::iter::once(&mut self.opt.store)
                .chain(self.worker_stores.iter_mut());
            let mut handles = Vec::new();
            for ((model, store), (x, labels)) in
                self.replicas.iter_mut().zip(stores).zip(shards)
            {
                handles.push(scope.spawn(move || {
                    let _budget = pool::BudgetGuard::new(intra_budget);
                    let logits = model.forward(x, true).expect_f32("worker");
                    let out = softmax_cross_entropy(&logits, &labels);
                    // scale shard gradient by shard/total so the summed
                    // votes equal the single-large-batch gradient
                    let scale = labels.len() as f32 / total as f32;
                    let _ = model.backward(out.grad.scale(scale), store);
                    (out.loss * scale, out.correct)
                }));
            }
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        let loss: f32 = results.iter().map(|(l, _)| l).sum();
        let correct: usize = results.iter().map(|(_, c)| c).sum();

        // --- vote aggregation: store-to-store sums into the leader ---
        for ws in &self.worker_stores {
            self.opt.store.add_grads_from(ws);
        }

        // --- single optimizer step on the leader ---
        let stats = {
            let mut p0 = self.replicas[0].params();
            self.opt.apply(&mut p0, step)
        };

        // --- broadcast: copy leader weights to all workers ---
        self.broadcast();
        (loss, correct, stats)
    }

    /// Copy the leader's weights (bits + FP) to every other replica.
    /// Boolean weights travel as packed words (1 bit/weight).
    pub fn broadcast(&mut self) {
        let (leader, rest) = self.replicas.split_at_mut(1);
        let mut p0 = leader[0].params();
        for worker in rest.iter_mut() {
            let pw = worker.params();
            for (a, b) in p0.iter_mut().zip(pw) {
                match (a, b) {
                    (ParamRef::Bool { bits: src, .. }, ParamRef::Bool { bits: dst, .. }) => {
                        dst.words.copy_from_slice(&src.words);
                    }
                    (ParamRef::Real { w: src, .. }, ParamRef::Real { w: dst, .. }) => {
                        dst.data.copy_from_slice(&src.data);
                    }
                    _ => panic!("replica param kind mismatch"),
                }
            }
        }
    }

    /// Fit a classifier dataset, sharding each batch across workers.
    pub fn fit(
        &mut self,
        train: &ImageDataset,
        val: &ImageDataset,
        cfg: &TrainConfig,
        log: bool,
    ) -> super::TrainReport {
        let workers = self.replicas.len();
        let mut sampler = crate::data::BatchSampler::new(train.n, cfg.batch, cfg.seed ^ 0x5A);
        let mut report = super::TrainReport { steps: cfg.steps, ..Default::default() };
        let flat = train.h == 1;
        for step in 0..cfg.steps {
            let idx = sampler.next_batch();
            let shard_size = idx.len().div_ceil(workers);
            // An uneven split can yield fewer shards than workers; that is
            // fine — train_step leaves the surplus replicas idle instead
            // of re-feeding samples (which would double-count their votes).
            let shards: Vec<(Value, Vec<usize>)> = idx
                .chunks(shard_size)
                .map(|chunk| {
                    let (x, labels) =
                        if flat { train.batch_flat(chunk) } else { train.batch(chunk) };
                    let v = if flat { Value::bit_from_pm1(&x) } else { Value::F32(x) };
                    (v, labels)
                })
                .collect();
            let (loss, correct, stats) = self.train_step(shards, step);
            report.losses.push(loss);
            report.train_acc.push(correct as f32 / idx.len().max(1) as f32);
            report.flip_rates.push(stats.flip_rate());
            if log && step % 25 == 0 {
                println!("step {step:>5}  loss {loss:>8.4}  [{} workers]", workers);
            }
        }
        report.val_acc = super::evaluate_classifier(&mut self.replicas[0], val, cfg.batch);
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{boolean_mlp, MlpConfig};
    use crate::optim::{Adam, BooleanOptimizer};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn mk_factory(mcfg: MlpConfig) -> impl Fn(u64) -> Sequential {
        move |seed| {
            let mut rng = Rng::new(seed);
            boolean_mlp(&mcfg, &mut rng)
        }
    }

    /// Reference: single model + single store trained on the full batch.
    fn single_model_step(
        mcfg: &MlpConfig,
        cfg: &TrainConfig,
        x: &Tensor,
        labels: &[usize],
    ) -> Sequential {
        let mut single = mk_factory(mcfg.clone())(cfg.seed);
        let mut store = ParamStore::new();
        let logits = single.forward(Value::bit_from_pm1(x), true).expect_f32("t");
        let out = softmax_cross_entropy(&logits, labels);
        let _ = single.backward(out.grad, &mut store);
        let bool_opt = BooleanOptimizer::new(cfg.lr_bool);
        let mut adam = Adam::new(cfg.lr_fp);
        let mut ps = single.params();
        bool_opt.step(&mut ps, &mut store);
        adam.step(&mut ps, &mut store);
        single
    }

    #[test]
    fn replicas_start_identical() {
        let cfg = TrainConfig { workers: 3, ..Default::default() };
        let mcfg = MlpConfig { d_in: 32, hidden: vec![16], d_out: 4, tanh_scale: true };
        let mut pt = ParallelTrainer::new(3, &cfg, mk_factory(mcfg));
        let mut rng = Rng::new(7);
        let x = Tensor::rand_pm1(&[4, 32], &mut rng);
        let outs: Vec<Tensor> = pt
            .replicas
            .iter_mut()
            .map(|m| m.forward(Value::bit_from_pm1(&x), false).expect_f32("t"))
            .collect();
        assert_eq!(outs[0].max_abs_diff(&outs[1]), 0.0);
        assert_eq!(outs[0].max_abs_diff(&outs[2]), 0.0);
    }

    #[test]
    fn two_workers_equal_one_big_batch() {
        // vote additivity: 2-worker aggregated step == single-model step
        // on the concatenated batch (exact, not approximate).
        let cfg = TrainConfig {
            workers: 2,
            steps: 1,
            lr_bool: 2.0,
            cosine: false,
            ..Default::default()
        };
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let ds = ImageDataset::mnist_like(32, 4, 64, 0.1, 5);
        let idx: Vec<usize> = (0..16).collect();
        let (x, labels) = ds.batch_flat(&idx);

        // parallel: two shards of 8
        let mut pt = ParallelTrainer::new(2, &cfg, mk_factory(mcfg.clone()));
        let (xa, la) = ds.batch_flat(&idx[..8]);
        let (xb, lb) = ds.batch_flat(&idx[8..]);
        let _ = pt.train_step(
            vec![
                (Value::bit_from_pm1(&xa), la),
                (Value::bit_from_pm1(&xb), lb),
            ],
            0,
        );

        // reference: single model, full batch
        let mut single = single_model_step(&mcfg, &cfg, &x, &labels);

        // weights must match exactly
        let mut rng = Rng::new(11);
        let probe = Tensor::rand_pm1(&[6, 64], &mut rng);
        let y_par = pt.leader().forward(Value::bit_from_pm1(&probe), false).expect_f32("t");
        let y_single = single.forward(Value::bit_from_pm1(&probe), false).expect_f32("t");
        assert!(
            y_par.max_abs_diff(&y_single) < 1e-4,
            "parallel vote aggregation must equal big-batch training"
        );
    }

    /// Regression (shard-padding bug): a batch that splits into FEWER
    /// shards than workers must still equal the single-model step — the
    /// old padding path re-fed sample 0 to the surplus worker, double
    /// counting its votes.
    #[test]
    fn uneven_shards_keep_vote_parity() {
        let cfg = TrainConfig {
            workers: 3,
            steps: 1,
            lr_bool: 2.0,
            cosine: false,
            ..Default::default()
        };
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let ds = ImageDataset::mnist_like(16, 4, 64, 0.1, 9);
        // batch of 4 over 3 workers: ceil(4/3) = 2 ⇒ only 2 shards
        let idx: Vec<usize> = (0..4).collect();
        let (x, labels) = ds.batch_flat(&idx);

        let mut pt = ParallelTrainer::new(3, &cfg, mk_factory(mcfg.clone()));
        let (xa, la) = ds.batch_flat(&idx[..2]);
        let (xb, lb) = ds.batch_flat(&idx[2..]);
        let (loss, correct, _) = pt.train_step(
            vec![
                (Value::bit_from_pm1(&xa), la),
                (Value::bit_from_pm1(&xb), lb),
            ],
            0,
        );
        assert!(loss.is_finite());
        assert!(correct <= 4);

        let mut single = single_model_step(&mcfg, &cfg, &x, &labels);

        let mut rng = Rng::new(13);
        let probe = Tensor::rand_pm1(&[6, 64], &mut rng);
        let y_par = pt.leader().forward(Value::bit_from_pm1(&probe), false).expect_f32("t");
        let y_single = single.forward(Value::bit_from_pm1(&probe), false).expect_f32("t");
        assert!(
            y_par.max_abs_diff(&y_single) < 1e-4,
            "idle workers must not re-feed samples (vote double-count)"
        );

        // ... and the idle worker still receives the broadcast weights.
        let y_idle = pt.replicas[2].forward(Value::bit_from_pm1(&probe), false).expect_f32("t");
        assert_eq!(y_par.max_abs_diff(&y_idle), 0.0, "broadcast reaches idle workers");
    }

    /// `fit` drives the uneven path end to end (batch not divisible by
    /// workers) without panicking or losing samples.
    #[test]
    fn fit_handles_batches_not_divisible_by_workers() {
        let cfg = TrainConfig {
            workers: 3,
            steps: 6,
            batch: 4, // ceil(4/3)=2 ⇒ 2 shards for 3 workers every step
            lr_bool: 4.0,
            ..Default::default()
        };
        let (train, val) = ImageDataset::mnist_like(64, 4, 64, 0.08, 2).split(48);
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut pt = ParallelTrainer::new(3, &cfg, mk_factory(mcfg));
        let report = pt.fit(&train, &val, &cfg, false);
        assert_eq!(report.losses.len(), 6);
        assert!(report.losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn parallel_fit_learns() {
        let cfg = TrainConfig {
            workers: 2,
            steps: 40,
            batch: 64,
            lr_bool: 4.0,
            ..Default::default()
        };
        let (train, val) = ImageDataset::mnist_like(640, 4, 64, 0.08, 1).split(512);
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut pt = ParallelTrainer::new(2, &cfg, mk_factory(mcfg));
        let report = pt.fit(&train, &val, &cfg, false);
        assert!(report.val_acc > 0.8, "val acc {}", report.val_acc);
    }
}
