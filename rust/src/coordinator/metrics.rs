//! Metric logging: named scalar series with CSV export — the training
//! telemetry the examples and the report harness consume.

use std::collections::BTreeMap;
use std::io::Write;

/// Append-only scalar series keyed by metric name.
#[derive(Debug, Default)]
pub struct MetricLog {
    series: BTreeMap<String, Vec<(usize, f64)>>,
}

impl MetricLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: &str, step: usize, value: f64) {
        self.series.entry(name.to_string()).or_default().push((step, value));
    }

    pub fn get(&self, name: &str) -> Option<&[(usize, f64)]> {
        self.series.get(name).map(|v| v.as_slice())
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        self.series.get(name).and_then(|v| v.last()).map(|&(_, v)| v)
    }

    pub fn names(&self) -> Vec<&str> {
        self.series.keys().map(String::as_str).collect()
    }

    /// Mean over the final `k` entries of a series.
    pub fn tail_mean(&self, name: &str, k: usize) -> Option<f64> {
        let v = self.series.get(name)?;
        if v.is_empty() {
            return None;
        }
        let k = k.min(v.len());
        Some(v[v.len() - k..].iter().map(|&(_, x)| x).sum::<f64>() / k as f64)
    }

    /// Dump all series as long-format CSV (metric,step,value).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("metric,step,value\n");
        for (name, vs) in &self.series {
            for &(step, v) in vs {
                s.push_str(&format!("{name},{step},{v}\n"));
            }
        }
        s
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_get_tail() {
        let mut m = MetricLog::new();
        for i in 0..10 {
            m.push("loss", i, 10.0 - i as f64);
        }
        assert_eq!(m.last("loss"), Some(1.0));
        assert_eq!(m.tail_mean("loss", 2), Some(1.5));
        assert_eq!(m.get("loss").unwrap().len(), 10);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn csv_format() {
        let mut m = MetricLog::new();
        m.push("a", 0, 1.5);
        m.push("b", 2, -3.0);
        let csv = m.to_csv();
        assert!(csv.starts_with("metric,step,value\n"));
        assert!(csv.contains("a,0,1.5"));
        assert!(csv.contains("b,2,-3"));
    }
}
