//! Checkpointing: binary save/load of every model parameter, keyed by
//! parameter name. Boolean weights are stored bit-packed (64 weights per
//! u64 word) — on disk exactly as in memory, which is itself a measure of
//! the format's 32× compression vs FP checkpoints.
//!
//! Format (little-endian):
//!   magic "BOLDCKP2" | u32 n_records | n× (record | u32 crc32)
//!   record: u8 kind | u32 name_len | name | payload
//!     kind 0 (bool param):   u32 rows | u32 cols | u64 words…
//!     kind 1 (real param):   u32 len  | f32 data…
//!     kind 2 (buffer):       u32 len  | f32 data…
//!     kind 3 (bool optim):   u32 len  | f32 accum… | f32 ratio
//!     kind 4 (adam moments): u32 len  | f32 m… | f32 v…
//!     kind 5 (meta u64):     u64 value
//!     kind 6 (architecture): u32 n_dims | u32 dim… | LayerDesc list
//!                            (see `nn::LayerDesc::write_list`)
//!
//! Buffers (kind 2) carry non-trainable running statistics (BatchNorm
//! mean/var, centered-threshold means). Kinds 3–5 carry the
//! [`ParamStore`] optimizer state (Boolean accumulators m + β ratios,
//! Adam moments, the shared Adam timestep) written by [`save_training`]
//! so [`load_training`] resumes a run bit-exactly; [`save_model`] /
//! [`load_model`] stay weights+buffers-only for serving consumers, and
//! `load_model` skips optimizer records it encounters. Kind 6 is the
//! architecture self-description ([`crate::nn::Layer::describe`]) plus
//! the recorded non-batch input shape: `runtime::PackedGraph::load`
//! compiles it into a servable op graph with no model-specific code.
//! Models that are not describable simply omit the record.
//!
//! Integrity (format v2, magic `BOLDCKP2`): every record is followed by
//! the CRC-32 (IEEE) of its serialized bytes (kind + name + payload), so
//! a truncated or bit-flipped file fails the load with an error naming
//! the damaged record instead of silently restoring garbage weights —
//! the property crash-resume of `train-dist` jobs depends on. v1 files
//! (magic `BOLDCKP1`, no trailers) still load unchecked.

use crate::nn::{Layer, LayerDesc, ParamRef, ParamStore};
use crate::util::crc32::{crc32, Crc32};
use std::fmt;
use std::io::{Read, Write};

/// Current on-disk version: per-record CRC-32 trailers.
const MAGIC: &[u8; 8] = b"BOLDCKP2";
/// Pre-integrity version, still accepted by [`read_records`] (no CRCs to
/// verify — the records parse exactly as before).
const MAGIC_V1: &[u8; 8] = b"BOLDCKP1";

/// Meta-record name under which the shared Adam timestep is stored.
const META_ADAM_T: &str = "optim.adam_t";

#[derive(Debug)]
pub struct CheckpointError {
    pub msg: String,
}

impl CheckpointError {
    fn new(msg: impl Into<String>) -> Self {
        CheckpointError { msg: msg.into() }
    }
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "checkpoint error: {}", self.msg)
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::new(e.to_string())
    }
}

fn w_u32(w: &mut impl Write, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn w_f32s(w: &mut impl Write, data: &[f32]) -> std::io::Result<()> {
    for &v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn r_f32s(r: &mut impl Read, len: usize) -> std::io::Result<Vec<f32>> {
    let mut data = vec![0.0f32; len];
    for v in data.iter_mut() {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        *v = f32::from_le_bytes(b);
    }
    Ok(data)
}

fn w_name(w: &mut impl Write, kind: u8, name: &str) -> std::io::Result<()> {
    w.write_all(&[kind])?;
    w_u32(w, name.len() as u32)?;
    w.write_all(name.as_bytes())
}

/// Write one fully-serialized record followed by its CRC-32 trailer (v2).
fn end_record(f: &mut impl Write, rec: Vec<u8>) -> std::io::Result<()> {
    f.write_all(&rec)?;
    w_u32(f, crc32(&rec))
}

/// `Read` adapter that folds everything it reads into a running CRC-32,
/// so [`read_records`] can verify a record's trailer without buffering
/// the record (Boolean conv checkpoints run to megabytes of words).
struct CrcReader<'a, R: Read> {
    inner: &'a mut R,
    crc: Crc32,
}

impl<'a, R: Read> CrcReader<'a, R> {
    fn new(inner: &'a mut R) -> Self {
        CrcReader { inner, crc: Crc32::new() }
    }
}

impl<R: Read> Read for CrcReader<'_, R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc = self.crc.update(&buf[..n]);
        Ok(n)
    }
}

/// One parsed checkpoint record. Public so forward-only consumers (the
/// native serving engine in `runtime::engine`) can rebuild a frozen model
/// from a [`save_model`] file without instantiating trainable layers.
pub enum Record {
    /// Bit-packed Boolean parameter (kind 0).
    Bool { name: String, rows: usize, cols: usize, words: Vec<u64> },
    /// Dense FP parameter, stored flat (kind 1).
    Real { name: String, data: Vec<f32> },
    /// Non-trainable buffer, e.g. running statistics (kind 2).
    Buffer { name: String, data: Vec<f32> },
    /// Boolean-optimizer state: accumulator m + unchanged-ratio β (kind 3).
    OptimBool { name: String, accum: Vec<f32>, ratio: f32 },
    /// Adam moments (kind 4).
    OptimAdam { name: String, m: Vec<f32>, v: Vec<f32> },
    /// Scalar metadata, e.g. the shared Adam timestep (kind 5).
    Meta { name: String, value: u64 },
    /// Architecture self-description (kind 6): the layer op list from
    /// [`crate::nn::Layer::describe`] plus the non-batch input shape
    /// (empty when the model was never forwarded before saving).
    Arch { name: String, input_shape: Vec<usize>, layers: Vec<LayerDesc> },
}

impl Record {
    /// The record's parameter/buffer/meta name (integrity errors cite it).
    pub fn name(&self) -> &str {
        match self {
            Record::Bool { name, .. }
            | Record::Real { name, .. }
            | Record::Buffer { name, .. }
            | Record::OptimBool { name, .. }
            | Record::OptimAdam { name, .. }
            | Record::Meta { name, .. }
            | Record::Arch { name, .. } => name,
        }
    }
}

/// The `Record::Arch` for a model, when it is describable — THE single
/// construction site of the architecture record, shared by
/// [`save_model`]/[`save_training`] and the serving engines' in-memory
/// freeze paths (`PackedMlp::from_layer` / `PackedGraph::from_layer`),
/// so a live-frozen model and its saved checkpoint can never disagree
/// about the record's shape.
pub fn arch_record(model: &dyn Layer) -> Option<Record> {
    model.describe().map(|layers| Record::Arch {
        name: model.name(),
        input_shape: model.input_shape().unwrap_or_default(),
        layers,
    })
}

/// Save a whole model: parameters + non-trainable buffers (BN running
/// stats, centered-threshold means). Preferred over [`save_checkpoint`]
/// whenever you have a `Layer`. For a resumable training snapshot that
/// also carries optimizer state, use [`save_training`].
pub fn save_model(model: &mut dyn Layer, path: &str) -> Result<(), CheckpointError> {
    save_impl(model, None, &[], path)
}

/// Save a resumable training snapshot: everything [`save_model`] writes
/// PLUS the [`ParamStore`] optimizer state (Boolean accumulators + β,
/// Adam moments + timestep). [`load_training`] restores it bit-exactly.
pub fn save_training(
    model: &mut dyn Layer,
    store: &ParamStore,
    path: &str,
) -> Result<(), CheckpointError> {
    save_impl(model, Some(store), &[], path)
}

/// [`save_training`] plus caller-supplied kind-5 meta records (e.g. the
/// distributed coordinator's `dist.step` resume cursor). `load_training`
/// ignores meta names it does not know, so extra metas never break a
/// plain resume; read them back via [`read_records`].
pub fn save_training_with_meta(
    model: &mut dyn Layer,
    store: &ParamStore,
    extra_meta: &[(String, u64)],
    path: &str,
) -> Result<(), CheckpointError> {
    save_impl(model, Some(store), extra_meta, path)
}

fn save_impl(
    model: &mut dyn Layer,
    store: Option<&ParamStore>,
    extra_meta: &[(String, u64)],
    path: &str,
) -> Result<(), CheckpointError> {
    // `buffers()` needs `&mut model`, so count them before taking the
    // (long-lived) params borrow below.
    let n_buffers = model.buffers().len();
    // Architecture record (kind 6), when the model supports
    // self-description.
    let arch = arch_record(model);
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    {
        // ONE params() walk: the optimizer-record list is derived from
        // the same snapshot the writes use, so the count header and the
        // record bodies can never disagree.
        let params = model.params();
        let optim: Vec<(&str, u8, Option<&crate::nn::ParamSlot>)> = match store {
            None => Vec::new(),
            Some(s) => {
                let mut v: Vec<(&str, u8, Option<&crate::nn::ParamSlot>)> = params
                    .iter()
                    .filter_map(|p| {
                        let slot = s.slot(p.name())?;
                        match p {
                            ParamRef::Bool { .. } if !slot.accum.is_empty() => {
                                Some((p.name(), 3, Some(slot)))
                            }
                            ParamRef::Real { .. } if !slot.adam_m.is_empty() => {
                                Some((p.name(), 4, Some(slot)))
                            }
                            _ => None,
                        }
                    })
                    .collect();
                v.push((META_ADAM_T, 5, None));
                v
            }
        };
        w_u32(
            &mut f,
            (params.len() + n_buffers + optim.len() + extra_meta.len()
                + usize::from(arch.is_some())) as u32,
        )?;
        // architecture first, so readers see it before the tensors it
        // references
        if let Some(Record::Arch { name, input_shape, layers }) = &arch {
            let mut rec = Vec::new();
            w_name(&mut rec, 6, name)?;
            w_u32(&mut rec, input_shape.len() as u32)?;
            for &d in input_shape {
                w_u32(&mut rec, d as u32)?;
            }
            LayerDesc::write_list(&mut rec, layers)?;
            end_record(&mut f, rec)?;
        }
        for p in params.iter() {
            let mut rec = Vec::new();
            write_param(&mut rec, p)?;
            end_record(&mut f, rec)?;
        }
        for &(name, kind, slot) in &optim {
            let mut rec = Vec::new();
            match (kind, slot) {
                (3, Some(slot)) => {
                    w_name(&mut rec, 3, name)?;
                    w_u32(&mut rec, slot.accum.len() as u32)?;
                    w_f32s(&mut rec, &slot.accum.data)?;
                    rec.extend_from_slice(&slot.ratio.to_le_bytes());
                }
                (4, Some(slot)) => {
                    w_name(&mut rec, 4, name)?;
                    w_u32(&mut rec, slot.adam_m.len() as u32)?;
                    w_f32s(&mut rec, &slot.adam_m)?;
                    w_f32s(&mut rec, &slot.adam_v)?;
                }
                _ => {
                    w_name(&mut rec, 5, name)?;
                    rec.extend_from_slice(
                        &store.expect("optim list implies store").adam_t.to_le_bytes(),
                    );
                }
            }
            end_record(&mut f, rec)?;
        }
        for (name, value) in extra_meta {
            let mut rec = Vec::new();
            w_name(&mut rec, 5, name)?;
            rec.extend_from_slice(&value.to_le_bytes());
            end_record(&mut f, rec)?;
        }
    }
    for (name, buf) in model.buffers() {
        let mut rec = Vec::new();
        w_name(&mut rec, 2, &name)?;
        w_u32(&mut rec, buf.len() as u32)?;
        w_f32s(&mut rec, buf)?;
        end_record(&mut f, rec)?;
    }
    Ok(())
}

/// Load a whole model saved with [`save_model`] / [`save_training`] (also
/// accepts param-only checkpoints from [`save_checkpoint`]). Optimizer
/// records are skipped — use [`load_training`] to restore those too.
pub fn load_model(model: &mut dyn Layer, path: &str) -> Result<usize, CheckpointError> {
    let records = read_records(path)?;
    apply_model_records(model, &records)
}

/// Restore a training snapshot written by [`save_training`]: model
/// weights + buffers into `model`, optimizer state into `store`.
/// Optimizer records are validated against the model (name must exist,
/// state length must match the parameter) BEFORE anything is written to
/// `store`, so a wrong-model file fails with a `CheckpointError` instead
/// of arming a size-assert that would abort the first training step.
/// Returns the number of records applied.
pub fn load_training(
    model: &mut dyn Layer,
    store: &mut ParamStore,
    path: &str,
) -> Result<usize, CheckpointError> {
    let records = read_records(path)?;
    // (name → (is_bool, element count)) of every model parameter
    let meta: Vec<(String, bool, usize)> = model
        .params()
        .iter()
        .map(|p| (p.name().to_string(), matches!(p, ParamRef::Bool { .. }), p.len()))
        .collect();
    let lookup = |name: &str| meta.iter().find(|(n, _, _)| n == name);
    for rec in &records {
        match rec {
            Record::OptimBool { name, accum, .. } => match lookup(name) {
                Some((_, true, len)) if *len == accum.len() => {}
                Some((_, true, len)) => {
                    return Err(CheckpointError::new(format!(
                        "{name}: accumulator len {} vs model {len}",
                        accum.len()
                    )))
                }
                Some(_) => {
                    return Err(CheckpointError::new(format!(
                        "{name}: Boolean optimizer state for a non-Boolean param"
                    )))
                }
                None => {
                    return Err(CheckpointError::new(format!(
                        "optimizer state for '{name}' not in model"
                    )))
                }
            },
            Record::OptimAdam { name, m, v } => match lookup(name) {
                Some((_, false, len)) if *len == m.len() && *len == v.len() => {}
                Some((_, false, len)) => {
                    return Err(CheckpointError::new(format!(
                        "{name}: Adam moment len {}/{} vs model {len}",
                        m.len(),
                        v.len()
                    )))
                }
                Some(_) => {
                    return Err(CheckpointError::new(format!(
                        "{name}: Adam state for a Boolean param"
                    )))
                }
                None => {
                    return Err(CheckpointError::new(format!(
                        "optimizer state for '{name}' not in model"
                    )))
                }
            },
            _ => {}
        }
    }
    let mut loaded = apply_model_records(model, &records)?;
    for rec in &records {
        match rec {
            Record::OptimBool { name, accum, ratio } => {
                let slot = store.slot_mut(name);
                slot.accum_mut(accum.len()).data.copy_from_slice(accum);
                slot.ratio = *ratio;
                loaded += 1;
            }
            Record::OptimAdam { name, m, v } => {
                let slot = store.slot_mut(name);
                let (sm, sv) = slot.adam_mut(m.len());
                sm.copy_from_slice(m);
                sv.copy_from_slice(v);
                loaded += 1;
            }
            Record::Meta { name, value } if name == META_ADAM_T => {
                store.adam_t = *value;
                loaded += 1;
            }
            _ => {}
        }
    }
    Ok(loaded)
}

fn apply_model_records(
    model: &mut dyn Layer,
    records: &[Record],
) -> Result<usize, CheckpointError> {
    let mut loaded = 0usize;
    {
        let mut params = model.params();
        for rec in records {
            if matches!(rec, Record::Bool { .. } | Record::Real { .. }) {
                apply_record(rec, &mut params)?;
                loaded += 1;
            }
        }
    }
    let mut buffers = model.buffers();
    for rec in records {
        if let Record::Buffer { name, data } = rec {
            let target = buffers
                .iter_mut()
                .find(|(n, _)| n == name)
                .ok_or_else(|| CheckpointError::new(format!("buffer '{name}' not in model")))?;
            if target.1.len() != data.len() {
                return Err(CheckpointError::new(format!(
                    "buffer '{name}': len {} vs model {}",
                    data.len(),
                    target.1.len()
                )));
            }
            target.1.copy_from_slice(data);
            loaded += 1;
        }
    }
    Ok(loaded)
}

fn write_param(f: &mut impl Write, p: &ParamRef<'_>) -> Result<(), CheckpointError> {
    match p {
        ParamRef::Bool { name, bits } => {
            w_name(f, 0, name)?;
            w_u32(f, bits.rows as u32)?;
            w_u32(f, bits.cols as u32)?;
            for &word in &bits.words {
                f.write_all(&word.to_le_bytes())?;
            }
        }
        ParamRef::Real { name, w } => {
            w_name(f, 1, name)?;
            w_u32(f, w.len() as u32)?;
            w_f32s(f, &w.data)?;
        }
    }
    Ok(())
}

/// Parse every record of a checkpoint written by [`save_model`] /
/// [`save_training`] / [`save_checkpoint`] without needing a live model
/// to load into.
pub fn read_records(path: &str) -> Result<Vec<Record>, CheckpointError> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    let checked = match &magic {
        m if m == MAGIC => true,
        m if m == MAGIC_V1 => false, // pre-integrity file: no trailers
        _ => return Err(CheckpointError::new("bad magic")),
    };
    let n = r_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // Every byte of the record flows through the CRC; the trailer
        // itself is read from the raw stream below.
        let (rec, crc) = {
            let mut cr = CrcReader::new(&mut f);
            let rec = parse_record(&mut cr)
                .map_err(|e| CheckpointError::new(format!("record {i}: {}", e.msg)))?;
            (rec, cr.crc.finish())
        };
        if checked {
            let want = r_u32(&mut f).map_err(|_| {
                CheckpointError::new(format!(
                    "record '{}': truncated before integrity trailer",
                    rec.name()
                ))
            })?;
            if want != crc {
                return Err(CheckpointError::new(format!(
                    "record '{}': CRC mismatch (stored {want:#010x}, computed {crc:#010x}) — \
                     checkpoint is corrupt",
                    rec.name()
                )));
            }
        }
        out.push(rec);
    }
    Ok(out)
}

/// Parse ONE record (kind + name + payload) from `r`. Shared by
/// [`read_records`] and the wire-protocol parameter blobs, which reuse
/// the checkpoint record encoding for full-weight Sync frames.
fn parse_record(r: &mut impl Read) -> Result<Record, CheckpointError> {
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind)?;
    let name_len = r_u32(r)? as usize;
    let mut name_buf = vec![0u8; name_len];
    r.read_exact(&mut name_buf)?;
    let name = String::from_utf8(name_buf).map_err(|_| CheckpointError::new("bad name"))?;
    let named = |e: std::io::Error| CheckpointError::new(format!("'{name}': {e}"));
    match kind[0] {
        0 => {
            let rows = r_u32(r).map_err(named)? as usize;
            let cols = r_u32(r).map_err(named)? as usize;
            let wpr = cols.div_ceil(64);
            let mut words = vec![0u64; rows * wpr];
            for w in words.iter_mut() {
                let mut b = [0u8; 8];
                r.read_exact(&mut b).map_err(named)?;
                *w = u64::from_le_bytes(b);
            }
            Ok(Record::Bool { name, rows, cols, words })
        }
        1 | 2 => {
            let len = r_u32(r).map_err(named)? as usize;
            let data = r_f32s(r, len).map_err(named)?;
            if kind[0] == 1 {
                Ok(Record::Real { name, data })
            } else {
                Ok(Record::Buffer { name, data })
            }
        }
        3 => {
            let len = r_u32(r).map_err(named)? as usize;
            let accum = r_f32s(r, len).map_err(named)?;
            let mut b = [0u8; 4];
            r.read_exact(&mut b).map_err(named)?;
            Ok(Record::OptimBool { name, accum, ratio: f32::from_le_bytes(b) })
        }
        4 => {
            let len = r_u32(r).map_err(named)? as usize;
            let m = r_f32s(r, len).map_err(named)?;
            let v = r_f32s(r, len).map_err(named)?;
            Ok(Record::OptimAdam { name, m, v })
        }
        5 => {
            let mut b = [0u8; 8];
            r.read_exact(&mut b).map_err(named)?;
            Ok(Record::Meta { name, value: u64::from_le_bytes(b) })
        }
        6 => {
            let n_dims = r_u32(r).map_err(named)? as usize;
            let mut input_shape = Vec::with_capacity(n_dims);
            for _ in 0..n_dims {
                input_shape.push(r_u32(r).map_err(named)? as usize);
            }
            let layers = LayerDesc::read_list(r)
                .map_err(|e| CheckpointError::new(format!("bad arch record: {e}")))?;
            Ok(Record::Arch { name, input_shape, layers })
        }
        k => Err(CheckpointError::new(format!("bad kind {k}"))),
    }
}

/// Serialize `params` to an in-memory blob in checkpoint record encoding
/// (count + kind-0/1 records, no CRC trailers — the wire frame carries
/// one CRC over the whole payload). The Sync/commit payload of
/// `train-dist`: Boolean weights travel packed, 1 bit/weight.
pub fn params_blob(params: &[ParamRef<'_>]) -> Vec<u8> {
    let mut out = Vec::new();
    let _ = w_u32(&mut out, params.len() as u32);
    for p in params {
        let _ = write_param(&mut out, p);
    }
    out
}

/// Apply a [`params_blob`] to a model's params, matching by name and
/// validating shapes. The distributed worker's weight-install path.
pub fn apply_params_blob(
    params: &mut [ParamRef<'_>],
    blob: &[u8],
) -> Result<usize, CheckpointError> {
    let mut r = blob;
    let n = r_u32(&mut r)? as usize;
    if n != params.len() {
        return Err(CheckpointError::new(format!(
            "params blob carries {n} records, model has {}",
            params.len()
        )));
    }
    for _ in 0..n {
        let rec = parse_record(&mut r)?;
        apply_record(&rec, params)?;
    }
    if !r.is_empty() {
        return Err(CheckpointError::new(format!("params blob: {} trailing bytes", r.len())));
    }
    Ok(n)
}

fn apply_record(rec: &Record, params: &mut [ParamRef<'_>]) -> Result<(), CheckpointError> {
    match rec {
        Record::Bool { name, rows, cols, words } => {
            let target = params.iter_mut().find_map(|p| match p {
                ParamRef::Bool { name: n2, bits } if n2 == name => Some(bits),
                _ => None,
            });
            match target {
                Some(bits) => {
                    if (bits.rows, bits.cols) != (*rows, *cols) {
                        return Err(CheckpointError::new(format!(
                            "{name}: shape {rows}x{cols} vs model {}x{}",
                            bits.rows, bits.cols
                        )));
                    }
                    bits.words.copy_from_slice(words);
                    Ok(())
                }
                None => Err(CheckpointError::new(format!("bool param '{name}' not in model"))),
            }
        }
        Record::Real { name, data } => {
            let target = params.iter_mut().find_map(|p| match p {
                ParamRef::Real { name: n2, w } if n2 == name => Some(w),
                _ => None,
            });
            match target {
                Some(w) => {
                    if w.len() != data.len() {
                        return Err(CheckpointError::new(format!(
                            "{name}: len {} vs model {}",
                            data.len(),
                            w.len()
                        )));
                    }
                    w.data.copy_from_slice(data);
                    Ok(())
                }
                None => Err(CheckpointError::new(format!("real param '{name}' not in model"))),
            }
        }
        _ => Ok(()),
    }
}

/// Save every parameter of `params` to `path`.
pub fn save_checkpoint(params: &mut [ParamRef<'_>], path: &str) -> Result<(), CheckpointError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    w_u32(&mut f, params.len() as u32)?;
    for p in params.iter() {
        let mut rec = Vec::new();
        write_param(&mut rec, p)?;
        end_record(&mut f, rec)?;
    }
    Ok(())
}

/// Load parameters from `path` into `params`, matching by name.
/// Every parameter record in the file must exist in `params` with
/// identical shape; params missing from the file are left untouched.
/// Buffer/optimizer records are rejected (use the model-level loaders).
pub fn load_checkpoint(params: &mut [ParamRef<'_>], path: &str) -> Result<usize, CheckpointError> {
    let records = read_records(path)?;
    let mut loaded = 0usize;
    for rec in &records {
        match rec {
            Record::Bool { .. } | Record::Real { .. } => {
                apply_record(rec, params)?;
                loaded += 1;
            }
            Record::Buffer { name, .. } => {
                return Err(CheckpointError::new(format!(
                    "buffer '{name}' needs a model-level loader (load_model)"
                )))
            }
            _ => {} // optimizer records: ignored at param level
        }
    }
    Ok(loaded)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::coordinator::ClassifierTrainer;
    use crate::data::ImageDataset;
    use crate::models::{boolean_mlp, MlpConfig};
    use crate::nn::{Layer, ParamStore, Value};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("bold_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    #[test]
    fn roundtrip_preserves_outputs() {
        let path = tmp("m.ckpt");

        let cfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut rng = Rng::new(1);
        let mut m1 = boolean_mlp(&cfg, &mut rng);
        let mut rng2 = Rng::new(99);
        let mut m2 = boolean_mlp(&cfg, &mut rng2); // different init

        let x = Tensor::rand_pm1(&[4, 64], &mut rng);
        let y1 = m1.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        let y2_before = m2.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert!(y1.max_abs_diff(&y2_before) > 0.0, "different inits differ");

        save_checkpoint(&mut m1.params(), &path).unwrap();
        let loaded = load_checkpoint(&mut m2.params(), &path).unwrap();
        assert_eq!(loaded, 3);
        let y2 = m2.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert_eq!(y1.max_abs_diff(&y2), 0.0, "loaded model must match exactly");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let path = tmp("mismatch.ckpt");
        let mut rng = Rng::new(1);
        let cfg_a = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let cfg_b = MlpConfig { d_in: 32, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut a = boolean_mlp(&cfg_a, &mut rng);
        let mut b = boolean_mlp(&cfg_b, &mut rng);
        save_checkpoint(&mut a.params(), &path).unwrap();
        assert!(load_checkpoint(&mut b.params(), &path).is_err());
    }

    #[test]
    fn training_snapshot_roundtrips_optimizer_state() {
        let path = tmp("optim.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let tcfg = TrainConfig { lr_bool: 1.5, cosine: false, ..Default::default() };
        let ds = ImageDataset::mnist_like(64, 4, 64, 0.1, 7);
        let mut rng = Rng::new(3);
        let mut model = boolean_mlp(&mcfg, &mut rng);
        let mut trainer = ClassifierTrainer::new(&tcfg);
        for step in 0..5 {
            let idx: Vec<usize> = (0..16).collect();
            let (x, labels) = ds.batch_flat(&idx);
            let _ = trainer.train_step(&mut model, Value::bit_from_pm1(&x), &labels, step);
        }
        save_training(&mut model, &trainer.opt.store, &path).unwrap();

        let mut store2 = ParamStore::new();
        let mut rng2 = Rng::new(55);
        let mut model2 = boolean_mlp(&mcfg, &mut rng2);
        load_training(&mut model2, &mut store2, &path).unwrap();

        assert_eq!(store2.adam_t, trainer.opt.store.adam_t);
        {
            let name = "bl0.weight";
            let a = trainer.opt.store.slot(name).expect("trained slot");
            let b = store2.slot(name).expect("restored slot");
            assert_eq!(a.accum.data, b.accum.data, "{name}: accumulator m");
            assert_eq!(a.ratio, b.ratio, "{name}: β");
        }
        {
            let name = "head.w";
            let a = trainer.opt.store.slot(name).expect("trained adam slot");
            let b = store2.slot(name).expect("restored adam slot");
            assert_eq!(a.adam_m, b.adam_m, "{name}: Adam m");
            assert_eq!(a.adam_v, b.adam_v, "{name}: Adam v");
        }
        // weights restored too
        let x = Tensor::rand_pm1(&[4, 64], &mut rng);
        let y1 = model.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        let y2 = model2.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert_eq!(y1.max_abs_diff(&y2), 0.0);
    }

    #[test]
    fn load_training_rejects_wrong_model() {
        // Optimizer records for a different architecture must fail the
        // load with a CheckpointError, not arm a panic for later.
        let path = tmp("wrongmodel.ckpt");
        let mcfg_a = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mcfg_b = MlpConfig { d_in: 48, hidden: vec![32], d_out: 4, tanh_scale: true };
        let tcfg = TrainConfig { cosine: false, ..Default::default() };
        let ds = ImageDataset::mnist_like(32, 4, 64, 0.1, 6);
        let mut rng = Rng::new(2);
        let mut model = boolean_mlp(&mcfg_a, &mut rng);
        let mut trainer = ClassifierTrainer::new(&tcfg);
        let idx: Vec<usize> = (0..16).collect();
        let (x, labels) = ds.batch_flat(&idx);
        let _ = trainer.train_step(&mut model, Value::bit_from_pm1(&x), &labels, 0);
        save_training(&mut model, &trainer.opt.store, &path).unwrap();

        let mut other = boolean_mlp(&mcfg_b, &mut Rng::new(3));
        let mut store = ParamStore::new();
        assert!(load_training(&mut other, &mut store, &path).is_err());
        assert!(store.is_empty(), "failed load must not leave partial state");
    }

    #[test]
    fn load_model_skips_optimizer_records() {
        // A training snapshot must still load as a plain (serving) model.
        let path = tmp("skip.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let tcfg = TrainConfig { cosine: false, ..Default::default() };
        let ds = ImageDataset::mnist_like(32, 4, 64, 0.1, 8);
        let mut rng = Rng::new(4);
        let mut model = boolean_mlp(&mcfg, &mut rng);
        let mut trainer = ClassifierTrainer::new(&tcfg);
        let idx: Vec<usize> = (0..16).collect();
        let (x, labels) = ds.batch_flat(&idx);
        let _ = trainer.train_step(&mut model, Value::bit_from_pm1(&x), &labels, 0);
        save_training(&mut model, &trainer.opt.store, &path).unwrap();

        let mut rng2 = Rng::new(77);
        let mut model2 = boolean_mlp(&mcfg, &mut rng2);
        load_model(&mut model2, &path).unwrap();
        let probe = Tensor::rand_pm1(&[4, 64], &mut rng);
        let y1 = model.forward(Value::bit_from_pm1(&probe), false).expect_f32("t");
        let y2 = model2.forward(Value::bit_from_pm1(&probe), false).expect_f32("t");
        assert_eq!(y1.max_abs_diff(&y2), 0.0);
    }

    /// v1 files (magic `BOLDCKP1`, no CRC trailers) written before the
    /// integrity trailer must still parse — handcrafted here since the
    /// writer only emits v2 now.
    #[test]
    fn v1_checkpoints_without_trailers_still_load() {
        let path = tmp("v1.ckpt");
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"BOLDCKP1");
        bytes.extend_from_slice(&2u32.to_le_bytes()); // n_records
        // kind 1 (real param) "w": len 2, data [1.5, -2.0]
        bytes.push(1);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'w');
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        bytes.extend_from_slice(&(-2.0f32).to_le_bytes());
        // kind 5 (meta) "optim.adam_t": 7
        bytes.push(5);
        bytes.extend_from_slice(&12u32.to_le_bytes());
        bytes.extend_from_slice(b"optim.adam_t");
        bytes.extend_from_slice(&7u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();

        let recs = read_records(&path).unwrap();
        assert_eq!(recs.len(), 2);
        match &recs[0] {
            Record::Real { name, data } => {
                assert_eq!(name, "w");
                assert_eq!(data, &vec![1.5, -2.0]);
            }
            _ => panic!("expected real record"),
        }
        match &recs[1] {
            Record::Meta { name, value } => {
                assert_eq!(name, "optim.adam_t");
                assert_eq!(*value, 7);
            }
            _ => panic!("expected meta record"),
        }
    }

    /// Any single flipped bit in a v2 record body must fail the load with
    /// an error naming the damaged record — never load garbage weights.
    #[test]
    fn bit_flipped_checkpoint_fails_with_named_record_error() {
        let path = tmp("flip.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut model = boolean_mlp(&mcfg, &mut Rng::new(1));
        save_model(&mut model, &path).unwrap();
        let clean = std::fs::read(&path).unwrap();

        // locate the "bl0.weight" record and flip a bit inside its packed
        // words (well past the name, well before the trailer)
        let name = b"bl0.weight";
        let at = clean.windows(name.len()).position(|w| w == name).expect("record present");
        let mut corrupt = clean.clone();
        corrupt[at + name.len() + 16] ^= 0x04;
        std::fs::write(&path, &corrupt).unwrap();

        let err = read_records(&path).expect_err("bit flip must be detected");
        assert!(err.msg.contains("CRC mismatch"), "unexpected error: {}", err.msg);
        assert!(err.msg.contains("bl0.weight"), "error must name the record: {}", err.msg);

        // ...and the model-level loader surfaces it too
        let mut m2 = boolean_mlp(&mcfg, &mut Rng::new(2));
        assert!(load_model(&mut m2, &path).is_err());
    }

    /// Truncation anywhere in the file must fail the load (io error or
    /// missing trailer), never return a partial record list as success.
    #[test]
    fn truncated_checkpoint_fails_to_load() {
        let path = tmp("trunc.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut model = boolean_mlp(&mcfg, &mut Rng::new(3));
        save_model(&mut model, &path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        for frac in [25, 50, 75, 99] {
            let cut = clean.len() * frac / 100;
            std::fs::write(&path, &clean[..cut]).unwrap();
            assert!(read_records(&path).is_err(), "truncation at {frac}% must fail");
        }
    }

    /// A tail record cut off inside its integrity trailer (the classic
    /// crash-mid-write shape) must fail with an error NAMING the last
    /// record — the model-lifecycle layer surfaces that name in
    /// `/v1/models` when it quarantines the checkpoint.
    #[test]
    fn truncated_tail_record_error_names_the_record() {
        let path = tmp("trunc_tail.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut model = boolean_mlp(&mcfg, &mut Rng::new(3));
        save_model(&mut model, &path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let last = read_records(&path).unwrap().last().expect("records").name().to_string();

        // cut inside the final record's 4-byte CRC trailer: the payload
        // parses, the trailer read fails, and the error cites the record
        std::fs::write(&path, &clean[..clean.len() - 2]).unwrap();
        let err = read_records(&path).expect_err("partial trailer must fail");
        assert!(
            err.msg.contains("truncated before integrity trailer"),
            "unexpected error: {}",
            err.msg
        );
        assert!(
            err.msg.contains(&format!("'{last}'")),
            "error must name the tail record '{last}': {}",
            err.msg
        );
    }

    /// A CRC flip in a MIDDLE record (not the first, not the last) is
    /// detected and named — damage detection cannot depend on the
    /// corruption being at either end of the file.
    #[test]
    fn crc_flipped_middle_record_error_names_the_record() {
        let path = tmp("flip_mid.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut model = boolean_mlp(&mcfg, &mut Rng::new(8));
        save_model(&mut model, &path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let records = read_records(&path).unwrap();
        assert!(records.len() >= 3, "need a middle record to corrupt");
        let mid = records[records.len() / 2].name().to_string();

        // flip one bit inside the middle record's payload; search for the
        // LAST occurrence of the name so the arch record's layer list
        // (which also spells parameter names) is not what gets hit
        let needle = mid.as_bytes();
        let at = (0..=clean.len() - needle.len())
            .rev()
            .find(|&i| &clean[i..i + needle.len()] == needle)
            .expect("record name present");
        let mut corrupt = clean.clone();
        corrupt[at + needle.len() + 16] ^= 0x40;
        std::fs::write(&path, &corrupt).unwrap();

        let err = read_records(&path).expect_err("middle-record flip must be detected");
        assert!(err.msg.contains("CRC mismatch"), "unexpected error: {}", err.msg);
        assert!(
            err.msg.contains(&format!("'{mid}'")),
            "error must name the middle record '{mid}': {}",
            err.msg
        );
    }

    /// Extra meta records (the dist coordinator's resume cursor) ride
    /// along without disturbing load_training, and read back exactly.
    #[test]
    fn extra_meta_records_roundtrip_and_are_ignored_by_load_training() {
        let path = tmp("meta.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let tcfg = TrainConfig { cosine: false, ..Default::default() };
        let ds = ImageDataset::mnist_like(32, 4, 64, 0.1, 6);
        let mut model = boolean_mlp(&mcfg, &mut Rng::new(4));
        let mut trainer = ClassifierTrainer::new(&tcfg);
        let idx: Vec<usize> = (0..16).collect();
        let (x, labels) = ds.batch_flat(&idx);
        let _ = trainer.train_step(&mut model, Value::bit_from_pm1(&x), &labels, 0);
        save_training_with_meta(
            &mut model,
            &trainer.opt.store,
            &[("dist.step".to_string(), 17)],
            &path,
        )
        .unwrap();

        let recs = read_records(&path).unwrap();
        let cursor = recs.iter().find_map(|r| match r {
            Record::Meta { name, value } if name == "dist.step" => Some(*value),
            _ => None,
        });
        assert_eq!(cursor, Some(17));

        let mut m2 = boolean_mlp(&mcfg, &mut Rng::new(5));
        let mut store2 = ParamStore::new();
        load_training(&mut m2, &mut store2, &path).unwrap();
        assert_eq!(store2.adam_t, trainer.opt.store.adam_t);
    }

    /// THE resume guarantee: save mid-run, reload into a FRESH model +
    /// trainer, continue, and end bit-identical to the uninterrupted run.
    #[test]
    fn resume_matches_uninterrupted_run_bit_exactly() {
        let path = tmp("resume.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let tcfg = TrainConfig { lr_bool: 2.0, batch: 16, cosine: true, steps: 20, ..Default::default() };
        let ds = ImageDataset::mnist_like(128, 4, 64, 0.1, 11);
        // fixed batch schedule shared by both runs
        let mut sampler = crate::data::BatchSampler::new(ds.n, tcfg.batch, 42);
        let batches: Vec<Vec<usize>> = (0..20).map(|_| sampler.next_batch()).collect();

        // --- uninterrupted: 20 steps ---
        let mut m_full = boolean_mlp(&mcfg, &mut Rng::new(5));
        let mut t_full = ClassifierTrainer::new(&tcfg);
        for (step, idx) in batches.iter().enumerate() {
            let (x, labels) = ds.batch_flat(idx);
            let _ = t_full.train_step(&mut m_full, Value::bit_from_pm1(&x), &labels, step);
        }

        // --- interrupted: 10 steps, save, reload fresh, 10 more ---
        let mut m_a = boolean_mlp(&mcfg, &mut Rng::new(5));
        let mut t_a = ClassifierTrainer::new(&tcfg);
        for (step, idx) in batches.iter().take(10).enumerate() {
            let (x, labels) = ds.batch_flat(idx);
            let _ = t_a.train_step(&mut m_a, Value::bit_from_pm1(&x), &labels, step);
        }
        save_training(&mut m_a, &t_a.opt.store, &path).unwrap();
        drop((m_a, t_a));

        let mut m_b = boolean_mlp(&mcfg, &mut Rng::new(999)); // different init…
        let mut t_b = ClassifierTrainer::new(&tcfg);
        load_training(&mut m_b, t_b.store_mut(), &path).unwrap(); // …fully overwritten
        for (step, idx) in batches.iter().enumerate().skip(10) {
            let (x, labels) = ds.batch_flat(idx);
            let _ = t_b.train_step(&mut m_b, Value::bit_from_pm1(&x), &labels, step);
        }

        // bit-exact: packed Boolean words AND FP weights identical
        let pf = m_full.params();
        let pb = m_b.params();
        assert_eq!(pf.len(), pb.len());
        for (a, b) in pf.iter().zip(pb.iter()) {
            match (a, b) {
                (ParamRef::Bool { name, bits: ba }, ParamRef::Bool { bits: bb, .. }) => {
                    assert_eq!(ba.words, bb.words, "{name}: packed weights diverged");
                }
                (ParamRef::Real { name, w: wa }, ParamRef::Real { w: wb, .. }) => {
                    assert_eq!(wa.data, wb.data, "{name}: FP weights diverged");
                }
                _ => panic!("param order mismatch"),
            }
        }
    }
}
