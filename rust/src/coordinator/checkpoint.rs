//! Checkpointing: binary save/load of every model parameter, keyed by
//! parameter name. Boolean weights are stored bit-packed (64 weights per
//! u64 word) — on disk exactly as in memory, which is itself a measure of
//! the format's 32× compression vs FP checkpoints.
//!
//! Format (little-endian):
//!   magic "BOLDCKP1" | u32 n_records | n× record
//!   record: u8 kind (0=bool param, 1=real param, 2=buffer) |
//!           u32 name_len | name |
//!           bool:        u32 rows | u32 cols | u64 words…
//!           real/buffer: u32 len  | f32 data…
//!
//! Buffers (kind 2) carry non-trainable running statistics (BatchNorm
//! mean/var, centered-threshold means) — written by [`save_model`] /
//! restored by [`load_model`].

use crate::nn::{Layer, ParamRef};
use std::fmt;
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"BOLDCKP1";

#[derive(Debug)]
pub struct CheckpointError {
    pub msg: String,
}

impl CheckpointError {
    fn new(msg: impl Into<String>) -> Self {
        CheckpointError { msg: msg.into() }
    }
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "checkpoint error: {}", self.msg)
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::new(e.to_string())
    }
}

fn w_u32(w: &mut impl Write, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Save a whole model: parameters + non-trainable buffers (BN running
/// stats, centered-threshold means). Preferred over [`save_checkpoint`]
/// whenever you have a `Layer`.
pub fn save_model(model: &mut dyn Layer, path: &str) -> Result<(), CheckpointError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    let n_params = model.params().len();
    let n_buffers = model.buffers().len();
    w_u32(&mut f, (n_params + n_buffers) as u32)?;
    for p in model.params().iter() {
        write_param(&mut f, p)?;
    }
    for (name, buf) in model.buffers() {
        f.write_all(&[2u8])?;
        w_u32(&mut f, name.len() as u32)?;
        f.write_all(name.as_bytes())?;
        w_u32(&mut f, buf.len() as u32)?;
        for &v in buf.iter() {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load a whole model saved with [`save_model`] (also accepts param-only
/// checkpoints from [`save_checkpoint`]).
pub fn load_model(model: &mut dyn Layer, path: &str) -> Result<usize, CheckpointError> {
    let records = read_records(path)?;
    let mut loaded = 0usize;
    {
        let mut params = model.params();
        for rec in &records {
            if let Record::Buffer { .. } = rec {
                continue;
            }
            apply_record(rec, &mut params)?;
            loaded += 1;
        }
    }
    let mut buffers = model.buffers();
    for rec in &records {
        if let Record::Buffer { name, data } = rec {
            let target = buffers
                .iter_mut()
                .find(|(n, _)| n == name)
                .ok_or_else(|| CheckpointError::new(format!("buffer '{name}' not in model")))?;
            if target.1.len() != data.len() {
                return Err(CheckpointError::new(format!(
                    "buffer '{name}': len {} vs model {}",
                    data.len(),
                    target.1.len()
                )));
            }
            target.1.copy_from_slice(data);
            loaded += 1;
        }
    }
    Ok(loaded)
}

/// One parsed checkpoint record. Public so forward-only consumers (the
/// native serving engine in `runtime::engine`) can rebuild a frozen model
/// from a [`save_model`] file without instantiating trainable layers.
pub enum Record {
    /// Bit-packed Boolean parameter (kind 0).
    Bool { name: String, rows: usize, cols: usize, words: Vec<u64> },
    /// Dense FP parameter, stored flat (kind 1).
    Real { name: String, data: Vec<f32> },
    /// Non-trainable buffer, e.g. running statistics (kind 2).
    Buffer { name: String, data: Vec<f32> },
}

fn write_param(f: &mut impl Write, p: &ParamRef<'_>) -> Result<(), CheckpointError> {
    match p {
        ParamRef::Bool { name, bits, .. } => {
            f.write_all(&[0u8])?;
            w_u32(f, name.len() as u32)?;
            f.write_all(name.as_bytes())?;
            w_u32(f, bits.rows as u32)?;
            w_u32(f, bits.cols as u32)?;
            for &word in &bits.words {
                f.write_all(&word.to_le_bytes())?;
            }
        }
        ParamRef::Real { name, w, .. } => {
            f.write_all(&[1u8])?;
            w_u32(f, name.len() as u32)?;
            f.write_all(name.as_bytes())?;
            w_u32(f, w.len() as u32)?;
            for &v in &w.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

/// Parse every record of a checkpoint written by [`save_model`] /
/// [`save_checkpoint`] without needing a live model to load into.
pub fn read_records(path: &str) -> Result<Vec<Record>, CheckpointError> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(CheckpointError::new("bad magic"));
    }
    let n = r_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut kind = [0u8; 1];
        f.read_exact(&mut kind)?;
        let name_len = r_u32(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf).map_err(|_| CheckpointError::new("bad name"))?;
        match kind[0] {
            0 => {
                let rows = r_u32(&mut f)? as usize;
                let cols = r_u32(&mut f)? as usize;
                let wpr = cols.div_ceil(64);
                let mut words = vec![0u64; rows * wpr];
                for w in words.iter_mut() {
                    let mut b = [0u8; 8];
                    f.read_exact(&mut b)?;
                    *w = u64::from_le_bytes(b);
                }
                out.push(Record::Bool { name, rows, cols, words });
            }
            1 | 2 => {
                let len = r_u32(&mut f)? as usize;
                let mut data = vec![0.0f32; len];
                for v in data.iter_mut() {
                    let mut b = [0u8; 4];
                    f.read_exact(&mut b)?;
                    *v = f32::from_le_bytes(b);
                }
                if kind[0] == 1 {
                    out.push(Record::Real { name, data });
                } else {
                    out.push(Record::Buffer { name, data });
                }
            }
            k => return Err(CheckpointError::new(format!("bad kind {k}"))),
        }
    }
    Ok(out)
}

fn apply_record(rec: &Record, params: &mut [ParamRef<'_>]) -> Result<(), CheckpointError> {
    match rec {
        Record::Bool { name, rows, cols, words } => {
            let target = params.iter_mut().find_map(|p| match p {
                ParamRef::Bool { name: n2, bits, .. } if n2 == name => Some(bits),
                _ => None,
            });
            match target {
                Some(bits) => {
                    if (bits.rows, bits.cols) != (*rows, *cols) {
                        return Err(CheckpointError::new(format!(
                            "{name}: shape {rows}x{cols} vs model {}x{}",
                            bits.rows, bits.cols
                        )));
                    }
                    bits.words.copy_from_slice(words);
                    Ok(())
                }
                None => Err(CheckpointError::new(format!("bool param '{name}' not in model"))),
            }
        }
        Record::Real { name, data } => {
            let target = params.iter_mut().find_map(|p| match p {
                ParamRef::Real { name: n2, w, .. } if n2 == name => Some(w),
                _ => None,
            });
            match target {
                Some(w) => {
                    if w.len() != data.len() {
                        return Err(CheckpointError::new(format!(
                            "{name}: len {} vs model {}",
                            data.len(),
                            w.len()
                        )));
                    }
                    w.data.copy_from_slice(data);
                    Ok(())
                }
                None => Err(CheckpointError::new(format!("real param '{name}' not in model"))),
            }
        }
        Record::Buffer { .. } => Ok(()),
    }
}

/// Save every parameter of `params` to `path`.
pub fn save_checkpoint(params: &mut [ParamRef<'_>], path: &str) -> Result<(), CheckpointError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    w_u32(&mut f, params.len() as u32)?;
    for p in params.iter() {
        match p {
            ParamRef::Bool { name, bits, .. } => {
                f.write_all(&[0u8])?;
                w_u32(&mut f, name.len() as u32)?;
                f.write_all(name.as_bytes())?;
                w_u32(&mut f, bits.rows as u32)?;
                w_u32(&mut f, bits.cols as u32)?;
                for &word in &bits.words {
                    f.write_all(&word.to_le_bytes())?;
                }
            }
            ParamRef::Real { name, w, .. } => {
                f.write_all(&[1u8])?;
                w_u32(&mut f, name.len() as u32)?;
                f.write_all(name.as_bytes())?;
                w_u32(&mut f, w.len() as u32)?;
                for &v in &w.data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Load parameters from `path` into `params`, matching by name.
/// Every parameter in the file must exist in `params` with identical
/// shape; params missing from the file are left untouched.
pub fn load_checkpoint(params: &mut [ParamRef<'_>], path: &str) -> Result<usize, CheckpointError> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(CheckpointError::new("bad magic"));
    }
    let n = r_u32(&mut f)? as usize;
    let mut loaded = 0usize;
    for _ in 0..n {
        let mut kind = [0u8; 1];
        f.read_exact(&mut kind)?;
        let name_len = r_u32(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf).map_err(|_| CheckpointError::new("bad name"))?;
        match kind[0] {
            0 => {
                let rows = r_u32(&mut f)? as usize;
                let cols = r_u32(&mut f)? as usize;
                let wpr = cols.div_ceil(64);
                let mut words = vec![0u64; rows * wpr];
                for w in words.iter_mut() {
                    let mut b = [0u8; 8];
                    f.read_exact(&mut b)?;
                    *w = u64::from_le_bytes(b);
                }
                let target = params.iter_mut().find_map(|p| match p {
                    ParamRef::Bool { name: n2, bits, .. } if *n2 == name => Some(bits),
                    _ => None,
                });
                match target {
                    Some(bits) => {
                        if (bits.rows, bits.cols) != (rows, cols) {
                            return Err(CheckpointError::new(format!(
                                "{name}: shape {rows}x{cols} vs model {}x{}",
                                bits.rows, bits.cols
                            )));
                        }
                        bits.words.copy_from_slice(&words);
                        loaded += 1;
                    }
                    None => {
                        return Err(CheckpointError::new(format!(
                            "bool param '{name}' not found in model"
                        )))
                    }
                }
            }
            1 => {
                let len = r_u32(&mut f)? as usize;
                let mut data = vec![0.0f32; len];
                for v in data.iter_mut() {
                    let mut b = [0u8; 4];
                    f.read_exact(&mut b)?;
                    *v = f32::from_le_bytes(b);
                }
                let target = params.iter_mut().find_map(|p| match p {
                    ParamRef::Real { name: n2, w, .. } if *n2 == name => Some(w),
                    _ => None,
                });
                match target {
                    Some(w) => {
                        if w.len() != len {
                            return Err(CheckpointError::new(format!(
                                "{name}: len {len} vs model {}",
                                w.len()
                            )));
                        }
                        w.data.copy_from_slice(&data);
                        loaded += 1;
                    }
                    None => {
                        return Err(CheckpointError::new(format!(
                            "real param '{name}' not found in model"
                        )))
                    }
                }
            }
            k => return Err(CheckpointError::new(format!("bad kind {k}"))),
        }
    }
    Ok(loaded)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{boolean_mlp, MlpConfig};
    use crate::nn::{Layer, Value};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn roundtrip_preserves_outputs() {
        let dir = std::env::temp_dir().join("bold_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        let path = path.to_str().unwrap();

        let cfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut rng = Rng::new(1);
        let mut m1 = boolean_mlp(&cfg, &mut rng);
        let mut rng2 = Rng::new(99);
        let mut m2 = boolean_mlp(&cfg, &mut rng2); // different init

        let x = Tensor::rand_pm1(&[4, 64], &mut rng);
        let y1 = m1.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        let y2_before = m2.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert!(y1.max_abs_diff(&y2_before) > 0.0, "different inits differ");

        save_checkpoint(&mut m1.params(), path).unwrap();
        let loaded = load_checkpoint(&mut m2.params(), path).unwrap();
        assert_eq!(loaded, 3);
        let y2 = m2.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert_eq!(y1.max_abs_diff(&y2), 0.0, "loaded model must match exactly");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = std::env::temp_dir().join("bold_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        let path = path.to_str().unwrap();
        let mut rng = Rng::new(1);
        let cfg_a = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let cfg_b = MlpConfig { d_in: 32, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut a = boolean_mlp(&cfg_a, &mut rng);
        let mut b = boolean_mlp(&cfg_b, &mut rng);
        save_checkpoint(&mut a.params(), path).unwrap();
        assert!(load_checkpoint(&mut b.params(), path).is_err());
    }
}
