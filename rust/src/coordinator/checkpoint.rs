//! Checkpointing: binary save/load of every model parameter, keyed by
//! parameter name. Boolean weights are stored bit-packed (64 weights per
//! u64 word) — on disk exactly as in memory, which is itself a measure of
//! the format's 32× compression vs FP checkpoints.
//!
//! Format (little-endian):
//!   magic "BOLDCKP1" | u32 n_records | n× record
//!   record: u8 kind | u32 name_len | name | payload
//!     kind 0 (bool param):   u32 rows | u32 cols | u64 words…
//!     kind 1 (real param):   u32 len  | f32 data…
//!     kind 2 (buffer):       u32 len  | f32 data…
//!     kind 3 (bool optim):   u32 len  | f32 accum… | f32 ratio
//!     kind 4 (adam moments): u32 len  | f32 m… | f32 v…
//!     kind 5 (meta u64):     u64 value
//!     kind 6 (architecture): u32 n_dims | u32 dim… | LayerDesc list
//!                            (see `nn::LayerDesc::write_list`)
//!
//! Buffers (kind 2) carry non-trainable running statistics (BatchNorm
//! mean/var, centered-threshold means). Kinds 3–5 carry the
//! [`ParamStore`] optimizer state (Boolean accumulators m + β ratios,
//! Adam moments, the shared Adam timestep) written by [`save_training`]
//! so [`load_training`] resumes a run bit-exactly; [`save_model`] /
//! [`load_model`] stay weights+buffers-only for serving consumers, and
//! `load_model` skips optimizer records it encounters. Kind 6 is the
//! architecture self-description ([`crate::nn::Layer::describe`]) plus
//! the recorded non-batch input shape: `runtime::PackedGraph::load`
//! compiles it into a servable op graph with no model-specific code.
//! Models that are not describable simply omit the record.

use crate::nn::{Layer, LayerDesc, ParamRef, ParamStore};
use std::fmt;
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"BOLDCKP1";

/// Meta-record name under which the shared Adam timestep is stored.
const META_ADAM_T: &str = "optim.adam_t";

#[derive(Debug)]
pub struct CheckpointError {
    pub msg: String,
}

impl CheckpointError {
    fn new(msg: impl Into<String>) -> Self {
        CheckpointError { msg: msg.into() }
    }
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "checkpoint error: {}", self.msg)
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::new(e.to_string())
    }
}

fn w_u32(w: &mut impl Write, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn w_f32s(w: &mut impl Write, data: &[f32]) -> std::io::Result<()> {
    for &v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn r_f32s(r: &mut impl Read, len: usize) -> std::io::Result<Vec<f32>> {
    let mut data = vec![0.0f32; len];
    for v in data.iter_mut() {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        *v = f32::from_le_bytes(b);
    }
    Ok(data)
}

fn w_name(w: &mut impl Write, kind: u8, name: &str) -> std::io::Result<()> {
    w.write_all(&[kind])?;
    w_u32(w, name.len() as u32)?;
    w.write_all(name.as_bytes())
}

/// One parsed checkpoint record. Public so forward-only consumers (the
/// native serving engine in `runtime::engine`) can rebuild a frozen model
/// from a [`save_model`] file without instantiating trainable layers.
pub enum Record {
    /// Bit-packed Boolean parameter (kind 0).
    Bool { name: String, rows: usize, cols: usize, words: Vec<u64> },
    /// Dense FP parameter, stored flat (kind 1).
    Real { name: String, data: Vec<f32> },
    /// Non-trainable buffer, e.g. running statistics (kind 2).
    Buffer { name: String, data: Vec<f32> },
    /// Boolean-optimizer state: accumulator m + unchanged-ratio β (kind 3).
    OptimBool { name: String, accum: Vec<f32>, ratio: f32 },
    /// Adam moments (kind 4).
    OptimAdam { name: String, m: Vec<f32>, v: Vec<f32> },
    /// Scalar metadata, e.g. the shared Adam timestep (kind 5).
    Meta { name: String, value: u64 },
    /// Architecture self-description (kind 6): the layer op list from
    /// [`crate::nn::Layer::describe`] plus the non-batch input shape
    /// (empty when the model was never forwarded before saving).
    Arch { name: String, input_shape: Vec<usize>, layers: Vec<LayerDesc> },
}

/// The `Record::Arch` for a model, when it is describable — THE single
/// construction site of the architecture record, shared by
/// [`save_model`]/[`save_training`] and the serving engines' in-memory
/// freeze paths (`PackedMlp::from_layer` / `PackedGraph::from_layer`),
/// so a live-frozen model and its saved checkpoint can never disagree
/// about the record's shape.
pub fn arch_record(model: &dyn Layer) -> Option<Record> {
    model.describe().map(|layers| Record::Arch {
        name: model.name(),
        input_shape: model.input_shape().unwrap_or_default(),
        layers,
    })
}

/// Save a whole model: parameters + non-trainable buffers (BN running
/// stats, centered-threshold means). Preferred over [`save_checkpoint`]
/// whenever you have a `Layer`. For a resumable training snapshot that
/// also carries optimizer state, use [`save_training`].
pub fn save_model(model: &mut dyn Layer, path: &str) -> Result<(), CheckpointError> {
    save_impl(model, None, path)
}

/// Save a resumable training snapshot: everything [`save_model`] writes
/// PLUS the [`ParamStore`] optimizer state (Boolean accumulators + β,
/// Adam moments + timestep). [`load_training`] restores it bit-exactly.
pub fn save_training(
    model: &mut dyn Layer,
    store: &ParamStore,
    path: &str,
) -> Result<(), CheckpointError> {
    save_impl(model, Some(store), path)
}

fn save_impl(
    model: &mut dyn Layer,
    store: Option<&ParamStore>,
    path: &str,
) -> Result<(), CheckpointError> {
    // `buffers()` needs `&mut model`, so count them before taking the
    // (long-lived) params borrow below.
    let n_buffers = model.buffers().len();
    // Architecture record (kind 6), when the model supports
    // self-description.
    let arch = arch_record(model);
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    {
        // ONE params() walk: the optimizer-record list is derived from
        // the same snapshot the writes use, so the count header and the
        // record bodies can never disagree.
        let params = model.params();
        let optim: Vec<(&str, u8, Option<&crate::nn::ParamSlot>)> = match store {
            None => Vec::new(),
            Some(s) => {
                let mut v: Vec<(&str, u8, Option<&crate::nn::ParamSlot>)> = params
                    .iter()
                    .filter_map(|p| {
                        let slot = s.slot(p.name())?;
                        match p {
                            ParamRef::Bool { .. } if !slot.accum.is_empty() => {
                                Some((p.name(), 3, Some(slot)))
                            }
                            ParamRef::Real { .. } if !slot.adam_m.is_empty() => {
                                Some((p.name(), 4, Some(slot)))
                            }
                            _ => None,
                        }
                    })
                    .collect();
                v.push((META_ADAM_T, 5, None));
                v
            }
        };
        w_u32(
            &mut f,
            (params.len() + n_buffers + optim.len() + usize::from(arch.is_some())) as u32,
        )?;
        // architecture first, so readers see it before the tensors it
        // references
        if let Some(Record::Arch { name, input_shape, layers }) = &arch {
            w_name(&mut f, 6, name)?;
            w_u32(&mut f, input_shape.len() as u32)?;
            for &d in input_shape {
                w_u32(&mut f, d as u32)?;
            }
            LayerDesc::write_list(&mut f, layers)?;
        }
        for p in params.iter() {
            write_param(&mut f, p)?;
        }
        for &(name, kind, slot) in &optim {
            match (kind, slot) {
                (3, Some(slot)) => {
                    w_name(&mut f, 3, name)?;
                    w_u32(&mut f, slot.accum.len() as u32)?;
                    w_f32s(&mut f, &slot.accum.data)?;
                    f.write_all(&slot.ratio.to_le_bytes())?;
                }
                (4, Some(slot)) => {
                    w_name(&mut f, 4, name)?;
                    w_u32(&mut f, slot.adam_m.len() as u32)?;
                    w_f32s(&mut f, &slot.adam_m)?;
                    w_f32s(&mut f, &slot.adam_v)?;
                }
                _ => {
                    w_name(&mut f, 5, name)?;
                    f.write_all(&store.expect("optim list implies store").adam_t.to_le_bytes())?;
                }
            }
        }
    }
    for (name, buf) in model.buffers() {
        w_name(&mut f, 2, &name)?;
        w_u32(&mut f, buf.len() as u32)?;
        w_f32s(&mut f, buf)?;
    }
    Ok(())
}

/// Load a whole model saved with [`save_model`] / [`save_training`] (also
/// accepts param-only checkpoints from [`save_checkpoint`]). Optimizer
/// records are skipped — use [`load_training`] to restore those too.
pub fn load_model(model: &mut dyn Layer, path: &str) -> Result<usize, CheckpointError> {
    let records = read_records(path)?;
    apply_model_records(model, &records)
}

/// Restore a training snapshot written by [`save_training`]: model
/// weights + buffers into `model`, optimizer state into `store`.
/// Optimizer records are validated against the model (name must exist,
/// state length must match the parameter) BEFORE anything is written to
/// `store`, so a wrong-model file fails with a `CheckpointError` instead
/// of arming a size-assert that would abort the first training step.
/// Returns the number of records applied.
pub fn load_training(
    model: &mut dyn Layer,
    store: &mut ParamStore,
    path: &str,
) -> Result<usize, CheckpointError> {
    let records = read_records(path)?;
    // (name → (is_bool, element count)) of every model parameter
    let meta: Vec<(String, bool, usize)> = model
        .params()
        .iter()
        .map(|p| (p.name().to_string(), matches!(p, ParamRef::Bool { .. }), p.len()))
        .collect();
    let lookup = |name: &str| meta.iter().find(|(n, _, _)| n == name);
    for rec in &records {
        match rec {
            Record::OptimBool { name, accum, .. } => match lookup(name) {
                Some((_, true, len)) if *len == accum.len() => {}
                Some((_, true, len)) => {
                    return Err(CheckpointError::new(format!(
                        "{name}: accumulator len {} vs model {len}",
                        accum.len()
                    )))
                }
                Some(_) => {
                    return Err(CheckpointError::new(format!(
                        "{name}: Boolean optimizer state for a non-Boolean param"
                    )))
                }
                None => {
                    return Err(CheckpointError::new(format!(
                        "optimizer state for '{name}' not in model"
                    )))
                }
            },
            Record::OptimAdam { name, m, v } => match lookup(name) {
                Some((_, false, len)) if *len == m.len() && *len == v.len() => {}
                Some((_, false, len)) => {
                    return Err(CheckpointError::new(format!(
                        "{name}: Adam moment len {}/{} vs model {len}",
                        m.len(),
                        v.len()
                    )))
                }
                Some(_) => {
                    return Err(CheckpointError::new(format!(
                        "{name}: Adam state for a Boolean param"
                    )))
                }
                None => {
                    return Err(CheckpointError::new(format!(
                        "optimizer state for '{name}' not in model"
                    )))
                }
            },
            _ => {}
        }
    }
    let mut loaded = apply_model_records(model, &records)?;
    for rec in &records {
        match rec {
            Record::OptimBool { name, accum, ratio } => {
                let slot = store.slot_mut(name);
                slot.accum_mut(accum.len()).data.copy_from_slice(accum);
                slot.ratio = *ratio;
                loaded += 1;
            }
            Record::OptimAdam { name, m, v } => {
                let slot = store.slot_mut(name);
                let (sm, sv) = slot.adam_mut(m.len());
                sm.copy_from_slice(m);
                sv.copy_from_slice(v);
                loaded += 1;
            }
            Record::Meta { name, value } if name == META_ADAM_T => {
                store.adam_t = *value;
                loaded += 1;
            }
            _ => {}
        }
    }
    Ok(loaded)
}

fn apply_model_records(
    model: &mut dyn Layer,
    records: &[Record],
) -> Result<usize, CheckpointError> {
    let mut loaded = 0usize;
    {
        let mut params = model.params();
        for rec in records {
            if matches!(rec, Record::Bool { .. } | Record::Real { .. }) {
                apply_record(rec, &mut params)?;
                loaded += 1;
            }
        }
    }
    let mut buffers = model.buffers();
    for rec in records {
        if let Record::Buffer { name, data } = rec {
            let target = buffers
                .iter_mut()
                .find(|(n, _)| n == name)
                .ok_or_else(|| CheckpointError::new(format!("buffer '{name}' not in model")))?;
            if target.1.len() != data.len() {
                return Err(CheckpointError::new(format!(
                    "buffer '{name}': len {} vs model {}",
                    data.len(),
                    target.1.len()
                )));
            }
            target.1.copy_from_slice(data);
            loaded += 1;
        }
    }
    Ok(loaded)
}

fn write_param(f: &mut impl Write, p: &ParamRef<'_>) -> Result<(), CheckpointError> {
    match p {
        ParamRef::Bool { name, bits } => {
            w_name(f, 0, name)?;
            w_u32(f, bits.rows as u32)?;
            w_u32(f, bits.cols as u32)?;
            for &word in &bits.words {
                f.write_all(&word.to_le_bytes())?;
            }
        }
        ParamRef::Real { name, w } => {
            w_name(f, 1, name)?;
            w_u32(f, w.len() as u32)?;
            w_f32s(f, &w.data)?;
        }
    }
    Ok(())
}

/// Parse every record of a checkpoint written by [`save_model`] /
/// [`save_training`] / [`save_checkpoint`] without needing a live model
/// to load into.
pub fn read_records(path: &str) -> Result<Vec<Record>, CheckpointError> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(CheckpointError::new("bad magic"));
    }
    let n = r_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut kind = [0u8; 1];
        f.read_exact(&mut kind)?;
        let name_len = r_u32(&mut f)? as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf).map_err(|_| CheckpointError::new("bad name"))?;
        match kind[0] {
            0 => {
                let rows = r_u32(&mut f)? as usize;
                let cols = r_u32(&mut f)? as usize;
                let wpr = cols.div_ceil(64);
                let mut words = vec![0u64; rows * wpr];
                for w in words.iter_mut() {
                    let mut b = [0u8; 8];
                    f.read_exact(&mut b)?;
                    *w = u64::from_le_bytes(b);
                }
                out.push(Record::Bool { name, rows, cols, words });
            }
            1 | 2 => {
                let len = r_u32(&mut f)? as usize;
                let data = r_f32s(&mut f, len)?;
                if kind[0] == 1 {
                    out.push(Record::Real { name, data });
                } else {
                    out.push(Record::Buffer { name, data });
                }
            }
            3 => {
                let len = r_u32(&mut f)? as usize;
                let accum = r_f32s(&mut f, len)?;
                let mut b = [0u8; 4];
                f.read_exact(&mut b)?;
                out.push(Record::OptimBool { name, accum, ratio: f32::from_le_bytes(b) });
            }
            4 => {
                let len = r_u32(&mut f)? as usize;
                let m = r_f32s(&mut f, len)?;
                let v = r_f32s(&mut f, len)?;
                out.push(Record::OptimAdam { name, m, v });
            }
            5 => {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                out.push(Record::Meta { name, value: u64::from_le_bytes(b) });
            }
            6 => {
                let n_dims = r_u32(&mut f)? as usize;
                let mut input_shape = Vec::with_capacity(n_dims);
                for _ in 0..n_dims {
                    input_shape.push(r_u32(&mut f)? as usize);
                }
                let layers = LayerDesc::read_list(&mut f)
                    .map_err(|e| CheckpointError::new(format!("bad arch record: {e}")))?;
                out.push(Record::Arch { name, input_shape, layers });
            }
            k => return Err(CheckpointError::new(format!("bad kind {k}"))),
        }
    }
    Ok(out)
}

fn apply_record(rec: &Record, params: &mut [ParamRef<'_>]) -> Result<(), CheckpointError> {
    match rec {
        Record::Bool { name, rows, cols, words } => {
            let target = params.iter_mut().find_map(|p| match p {
                ParamRef::Bool { name: n2, bits } if n2 == name => Some(bits),
                _ => None,
            });
            match target {
                Some(bits) => {
                    if (bits.rows, bits.cols) != (*rows, *cols) {
                        return Err(CheckpointError::new(format!(
                            "{name}: shape {rows}x{cols} vs model {}x{}",
                            bits.rows, bits.cols
                        )));
                    }
                    bits.words.copy_from_slice(words);
                    Ok(())
                }
                None => Err(CheckpointError::new(format!("bool param '{name}' not in model"))),
            }
        }
        Record::Real { name, data } => {
            let target = params.iter_mut().find_map(|p| match p {
                ParamRef::Real { name: n2, w } if n2 == name => Some(w),
                _ => None,
            });
            match target {
                Some(w) => {
                    if w.len() != data.len() {
                        return Err(CheckpointError::new(format!(
                            "{name}: len {} vs model {}",
                            data.len(),
                            w.len()
                        )));
                    }
                    w.data.copy_from_slice(data);
                    Ok(())
                }
                None => Err(CheckpointError::new(format!("real param '{name}' not in model"))),
            }
        }
        _ => Ok(()),
    }
}

/// Save every parameter of `params` to `path`.
pub fn save_checkpoint(params: &mut [ParamRef<'_>], path: &str) -> Result<(), CheckpointError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    w_u32(&mut f, params.len() as u32)?;
    for p in params.iter() {
        write_param(&mut f, p)?;
    }
    Ok(())
}

/// Load parameters from `path` into `params`, matching by name.
/// Every parameter record in the file must exist in `params` with
/// identical shape; params missing from the file are left untouched.
/// Buffer/optimizer records are rejected (use the model-level loaders).
pub fn load_checkpoint(params: &mut [ParamRef<'_>], path: &str) -> Result<usize, CheckpointError> {
    let records = read_records(path)?;
    let mut loaded = 0usize;
    for rec in &records {
        match rec {
            Record::Bool { .. } | Record::Real { .. } => {
                apply_record(rec, params)?;
                loaded += 1;
            }
            Record::Buffer { name, .. } => {
                return Err(CheckpointError::new(format!(
                    "buffer '{name}' needs a model-level loader (load_model)"
                )))
            }
            _ => {} // optimizer records: ignored at param level
        }
    }
    Ok(loaded)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::coordinator::ClassifierTrainer;
    use crate::data::ImageDataset;
    use crate::models::{boolean_mlp, MlpConfig};
    use crate::nn::{Layer, ParamStore, Value};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("bold_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    #[test]
    fn roundtrip_preserves_outputs() {
        let path = tmp("m.ckpt");

        let cfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut rng = Rng::new(1);
        let mut m1 = boolean_mlp(&cfg, &mut rng);
        let mut rng2 = Rng::new(99);
        let mut m2 = boolean_mlp(&cfg, &mut rng2); // different init

        let x = Tensor::rand_pm1(&[4, 64], &mut rng);
        let y1 = m1.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        let y2_before = m2.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert!(y1.max_abs_diff(&y2_before) > 0.0, "different inits differ");

        save_checkpoint(&mut m1.params(), &path).unwrap();
        let loaded = load_checkpoint(&mut m2.params(), &path).unwrap();
        assert_eq!(loaded, 3);
        let y2 = m2.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert_eq!(y1.max_abs_diff(&y2), 0.0, "loaded model must match exactly");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let path = tmp("mismatch.ckpt");
        let mut rng = Rng::new(1);
        let cfg_a = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let cfg_b = MlpConfig { d_in: 32, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut a = boolean_mlp(&cfg_a, &mut rng);
        let mut b = boolean_mlp(&cfg_b, &mut rng);
        save_checkpoint(&mut a.params(), &path).unwrap();
        assert!(load_checkpoint(&mut b.params(), &path).is_err());
    }

    #[test]
    fn training_snapshot_roundtrips_optimizer_state() {
        let path = tmp("optim.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let tcfg = TrainConfig { lr_bool: 1.5, cosine: false, ..Default::default() };
        let ds = ImageDataset::mnist_like(64, 4, 64, 0.1, 7);
        let mut rng = Rng::new(3);
        let mut model = boolean_mlp(&mcfg, &mut rng);
        let mut trainer = ClassifierTrainer::new(&tcfg);
        for step in 0..5 {
            let idx: Vec<usize> = (0..16).collect();
            let (x, labels) = ds.batch_flat(&idx);
            let _ = trainer.train_step(&mut model, Value::bit_from_pm1(&x), &labels, step);
        }
        save_training(&mut model, &trainer.opt.store, &path).unwrap();

        let mut store2 = ParamStore::new();
        let mut rng2 = Rng::new(55);
        let mut model2 = boolean_mlp(&mcfg, &mut rng2);
        load_training(&mut model2, &mut store2, &path).unwrap();

        assert_eq!(store2.adam_t, trainer.opt.store.adam_t);
        {
            let name = "bl0.weight";
            let a = trainer.opt.store.slot(name).expect("trained slot");
            let b = store2.slot(name).expect("restored slot");
            assert_eq!(a.accum.data, b.accum.data, "{name}: accumulator m");
            assert_eq!(a.ratio, b.ratio, "{name}: β");
        }
        {
            let name = "head.w";
            let a = trainer.opt.store.slot(name).expect("trained adam slot");
            let b = store2.slot(name).expect("restored adam slot");
            assert_eq!(a.adam_m, b.adam_m, "{name}: Adam m");
            assert_eq!(a.adam_v, b.adam_v, "{name}: Adam v");
        }
        // weights restored too
        let x = Tensor::rand_pm1(&[4, 64], &mut rng);
        let y1 = model.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        let y2 = model2.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert_eq!(y1.max_abs_diff(&y2), 0.0);
    }

    #[test]
    fn load_training_rejects_wrong_model() {
        // Optimizer records for a different architecture must fail the
        // load with a CheckpointError, not arm a panic for later.
        let path = tmp("wrongmodel.ckpt");
        let mcfg_a = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mcfg_b = MlpConfig { d_in: 48, hidden: vec![32], d_out: 4, tanh_scale: true };
        let tcfg = TrainConfig { cosine: false, ..Default::default() };
        let ds = ImageDataset::mnist_like(32, 4, 64, 0.1, 6);
        let mut rng = Rng::new(2);
        let mut model = boolean_mlp(&mcfg_a, &mut rng);
        let mut trainer = ClassifierTrainer::new(&tcfg);
        let idx: Vec<usize> = (0..16).collect();
        let (x, labels) = ds.batch_flat(&idx);
        let _ = trainer.train_step(&mut model, Value::bit_from_pm1(&x), &labels, 0);
        save_training(&mut model, &trainer.opt.store, &path).unwrap();

        let mut other = boolean_mlp(&mcfg_b, &mut Rng::new(3));
        let mut store = ParamStore::new();
        assert!(load_training(&mut other, &mut store, &path).is_err());
        assert!(store.is_empty(), "failed load must not leave partial state");
    }

    #[test]
    fn load_model_skips_optimizer_records() {
        // A training snapshot must still load as a plain (serving) model.
        let path = tmp("skip.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let tcfg = TrainConfig { cosine: false, ..Default::default() };
        let ds = ImageDataset::mnist_like(32, 4, 64, 0.1, 8);
        let mut rng = Rng::new(4);
        let mut model = boolean_mlp(&mcfg, &mut rng);
        let mut trainer = ClassifierTrainer::new(&tcfg);
        let idx: Vec<usize> = (0..16).collect();
        let (x, labels) = ds.batch_flat(&idx);
        let _ = trainer.train_step(&mut model, Value::bit_from_pm1(&x), &labels, 0);
        save_training(&mut model, &trainer.opt.store, &path).unwrap();

        let mut rng2 = Rng::new(77);
        let mut model2 = boolean_mlp(&mcfg, &mut rng2);
        load_model(&mut model2, &path).unwrap();
        let probe = Tensor::rand_pm1(&[4, 64], &mut rng);
        let y1 = model.forward(Value::bit_from_pm1(&probe), false).expect_f32("t");
        let y2 = model2.forward(Value::bit_from_pm1(&probe), false).expect_f32("t");
        assert_eq!(y1.max_abs_diff(&y2), 0.0);
    }

    /// THE resume guarantee: save mid-run, reload into a FRESH model +
    /// trainer, continue, and end bit-identical to the uninterrupted run.
    #[test]
    fn resume_matches_uninterrupted_run_bit_exactly() {
        let path = tmp("resume.ckpt");
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let tcfg = TrainConfig { lr_bool: 2.0, batch: 16, cosine: true, steps: 20, ..Default::default() };
        let ds = ImageDataset::mnist_like(128, 4, 64, 0.1, 11);
        // fixed batch schedule shared by both runs
        let mut sampler = crate::data::BatchSampler::new(ds.n, tcfg.batch, 42);
        let batches: Vec<Vec<usize>> = (0..20).map(|_| sampler.next_batch()).collect();

        // --- uninterrupted: 20 steps ---
        let mut m_full = boolean_mlp(&mcfg, &mut Rng::new(5));
        let mut t_full = ClassifierTrainer::new(&tcfg);
        for (step, idx) in batches.iter().enumerate() {
            let (x, labels) = ds.batch_flat(idx);
            let _ = t_full.train_step(&mut m_full, Value::bit_from_pm1(&x), &labels, step);
        }

        // --- interrupted: 10 steps, save, reload fresh, 10 more ---
        let mut m_a = boolean_mlp(&mcfg, &mut Rng::new(5));
        let mut t_a = ClassifierTrainer::new(&tcfg);
        for (step, idx) in batches.iter().take(10).enumerate() {
            let (x, labels) = ds.batch_flat(idx);
            let _ = t_a.train_step(&mut m_a, Value::bit_from_pm1(&x), &labels, step);
        }
        save_training(&mut m_a, &t_a.opt.store, &path).unwrap();
        drop((m_a, t_a));

        let mut m_b = boolean_mlp(&mcfg, &mut Rng::new(999)); // different init…
        let mut t_b = ClassifierTrainer::new(&tcfg);
        load_training(&mut m_b, t_b.store_mut(), &path).unwrap(); // …fully overwritten
        for (step, idx) in batches.iter().enumerate().skip(10) {
            let (x, labels) = ds.batch_flat(idx);
            let _ = t_b.train_step(&mut m_b, Value::bit_from_pm1(&x), &labels, step);
        }

        // bit-exact: packed Boolean words AND FP weights identical
        let pf = m_full.params();
        let pb = m_b.params();
        assert_eq!(pf.len(), pb.len());
        for (a, b) in pf.iter().zip(pb.iter()) {
            match (a, b) {
                (ParamRef::Bool { name, bits: ba }, ParamRef::Bool { bits: bb, .. }) => {
                    assert_eq!(ba.words, bb.words, "{name}: packed weights diverged");
                }
                (ParamRef::Real { name, w: wa }, ParamRef::Real { w: wb, .. }) => {
                    assert_eq!(wa.data, wb.data, "{name}: FP weights diverged");
                }
                _ => panic!("param order mismatch"),
            }
        }
    }
}
