//! Single-process trainer: the paper's dual-optimizer loop (Boolean
//! optimizer for native Boolean weights, Adam for FP parameters — §4
//! Experimental Setup) with cosine schedules on both.

use crate::config::TrainConfig;
use crate::data::ImageDataset;
use crate::nn::{softmax_cross_entropy, Layer, ParamRef, ParamStore, Sequential, Value};
use crate::optim::{Adam, BooleanOptimizer, CosineSchedule, FlipStats};
use crate::tensor::Tensor;

/// Per-run training record (loss curve, accuracy, flip-rate diagnostics).
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub train_acc: Vec<f32>,
    pub flip_rates: Vec<f32>,
    pub val_acc: f32,
    pub steps: usize,
}

impl TrainReport {
    /// Mean of the last `k` recorded losses.
    pub fn tail_loss(&self, k: usize) -> f32 {
        let n = self.losses.len();
        if n == 0 {
            return f32::NAN;
        }
        let k = k.min(n);
        self.losses[n - k..].iter().sum::<f32>() / k as f32
    }
}

/// The paper's dual-optimizer setup in one place: Boolean optimizer + Adam
/// with their cosine schedules, and the [`ParamStore`] both draw state
/// from. Shared by [`ClassifierTrainer`] and
/// [`super::ParallelTrainer`] (which used to duplicate this wiring).
pub struct DualOptimizer {
    pub lr_bool: f32,
    pub bool_sched: Option<CosineSchedule>,
    pub fp_sched: Option<CosineSchedule>,
    pub adam: Adam,
    /// Central optimizer state: votes/grads, Boolean accumulators + β,
    /// Adam moments. Serialized by `save_training` for bit-exact resume.
    pub store: ParamStore,
}

impl DualOptimizer {
    pub fn new(cfg: &TrainConfig) -> Self {
        let (bool_sched, fp_sched) = if cfg.cosine {
            (
                Some(CosineSchedule::new(cfg.lr_bool, cfg.lr_bool * 0.05, cfg.steps)),
                Some(CosineSchedule::new(cfg.lr_fp, cfg.lr_fp * 0.05, cfg.steps)),
            )
        } else {
            (None, None)
        };
        DualOptimizer {
            lr_bool: cfg.lr_bool,
            bool_sched,
            fp_sched,
            adam: Adam::new(cfg.lr_fp),
            store: ParamStore::new(),
        }
    }

    /// One optimizer step over already-accumulated votes/gradients.
    pub fn apply(&mut self, params: &mut [ParamRef<'_>], step: usize) -> FlipStats {
        // Store state is keyed by name: two layers sharing a name would
        // silently merge their votes/accumulators. Catch it in debug.
        #[cfg(debug_assertions)]
        {
            let mut seen = std::collections::HashSet::new();
            for p in params.iter() {
                assert!(
                    seen.insert(p.name().to_string()),
                    "duplicate parameter name '{}' — layer names must be unique \
                     or their ParamStore state merges",
                    p.name()
                );
            }
        }
        let lr_b = self.bool_sched.map_or(self.lr_bool, |s| s.at(step));
        if let Some(s) = self.fp_sched {
            self.adam.lr = s.at(step);
        }
        let stats = BooleanOptimizer::new(lr_b).step(params, &mut self.store);
        self.adam.step(params, &mut self.store);
        stats
    }
}

/// Classifier trainer: owns the dual-optimizer setup (and through it the
/// parameter store).
pub struct ClassifierTrainer {
    pub opt: DualOptimizer,
}

impl ClassifierTrainer {
    pub fn new(cfg: &TrainConfig) -> Self {
        ClassifierTrainer { opt: DualOptimizer::new(cfg) }
    }

    /// The central parameter store (for checkpointing / inspection).
    pub fn store_mut(&mut self) -> &mut ParamStore {
        &mut self.opt.store
    }

    /// One optimizer step on an already-accumulated model (votes filled by
    /// the caller's backward pass into this trainer's store).
    pub fn apply(&mut self, model: &mut Sequential, step: usize) -> FlipStats {
        let mut params = model.params();
        self.opt.apply(&mut params, step)
    }

    /// Full forward + loss + backward + step on one batch.
    /// Returns (loss, correct, flip stats).
    pub fn train_step(
        &mut self,
        model: &mut Sequential,
        x: Value,
        labels: &[usize],
        step: usize,
    ) -> (f32, usize, FlipStats) {
        let logits = model.forward(x, true).expect_f32("trainer");
        let out = softmax_cross_entropy(&logits, labels);
        self.opt.store.zero_grads();
        let _ = model.backward(out.grad, &mut self.opt.store);
        let stats = self.apply(model, step);
        (out.loss, out.correct, stats)
    }

    /// Train on a classification dataset per the config; returns the
    /// report with the loss curve and final validation accuracy.
    pub fn fit(
        &mut self,
        model: &mut Sequential,
        train: &ImageDataset,
        val: &ImageDataset,
        cfg: &TrainConfig,
        log: bool,
    ) -> TrainReport {
        let mut sampler = crate::data::BatchSampler::new(train.n, cfg.batch, cfg.seed ^ 0x5A);
        let mut report = TrainReport { steps: cfg.steps, ..Default::default() };
        let flat = train.h == 1; // MLP-style flat features
        for step in 0..cfg.steps {
            let idx = sampler.next_batch();
            let (x, labels) = if flat { train.batch_flat(&idx) } else { train.batch(&idx) };
            let value = if flat { Value::bit_from_pm1(&x) } else { Value::F32(x) };
            let (loss, correct, stats) = self.train_step(model, value, &labels, step);
            report.losses.push(loss);
            report.train_acc.push(correct as f32 / labels.len() as f32);
            report.flip_rates.push(stats.flip_rate());
            if log && (step % 25 == 0 || step + 1 == cfg.steps) {
                println!(
                    "step {step:>5}  loss {loss:>8.4}  acc {:>6.3}  flip-rate {:>8.5}",
                    report.train_acc.last().unwrap(),
                    stats.flip_rate()
                );
            }
        }
        report.val_acc = evaluate_classifier(model, val, cfg.batch);
        report
    }
}

/// Top-1 accuracy on a dataset (eval mode, running BN stats).
pub fn evaluate_classifier(model: &mut Sequential, ds: &ImageDataset, batch: usize) -> f32 {
    let flat = ds.h == 1;
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut i = 0;
    while i < ds.n {
        let hi = (i + batch).min(ds.n);
        let idx: Vec<usize> = (i..hi).collect();
        let (x, labels) = if flat { ds.batch_flat(&idx) } else { ds.batch(&idx) };
        let value = if flat { Value::bit_from_pm1(&x) } else { Value::F32(x) };
        let logits = model.forward(value, false).expect_f32("eval");
        let preds = logits.argmax_rows();
        correct += preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
        total += labels.len();
        i = hi;
    }
    correct as f32 / total.max(1) as f32
}

/// Helper: evaluate a model on explicit tensors (used by SR/seg drivers).
pub fn forward_eval(model: &mut Sequential, x: Tensor) -> Tensor {
    model.forward(Value::F32(x), false).expect_f32("forward_eval")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{boolean_mlp, MlpConfig};
    use crate::util::Rng;

    #[test]
    fn boolean_mlp_learns_mnist_like() {
        let cfg = TrainConfig {
            model: "mlp".into(),
            steps: 60,
            batch: 64,
            lr_bool: 4.0,
            train_size: 1024,
            val_size: 256,
            ..Default::default()
        };
        let (train, val) =
            ImageDataset::mnist_like(cfg.train_size + cfg.val_size, 10, 128, 0.08, 1)
                .split(cfg.train_size);
        let mut rng = Rng::new(cfg.seed);
        let mcfg = MlpConfig { d_in: 128, hidden: vec![64], d_out: 10, tanh_scale: true };
        let mut model = boolean_mlp(&mcfg, &mut rng);
        let mut trainer = ClassifierTrainer::new(&cfg);
        let report = trainer.fit(&mut model, &train, &val, &cfg, false);
        assert!(
            report.tail_loss(10) < report.losses[0] * 0.5,
            "loss must drop: {} -> {}",
            report.losses[0],
            report.tail_loss(10)
        );
        assert!(report.val_acc > 0.8, "val acc {}", report.val_acc);
    }

    #[test]
    fn flip_rate_decays_roughly() {
        // As training converges, weight flips should become rarer.
        let cfg = TrainConfig {
            steps: 80,
            batch: 64,
            lr_bool: 4.0,
            ..Default::default()
        };
        let (train, val) = ImageDataset::mnist_like(640, 4, 64, 0.05, 3).split(512);
        let mut rng = Rng::new(1);
        let mcfg = MlpConfig { d_in: 64, hidden: vec![32], d_out: 4, tanh_scale: true };
        let mut model = boolean_mlp(&mcfg, &mut rng);
        let mut trainer = ClassifierTrainer::new(&cfg);
        let report = trainer.fit(&mut model, &train, &val, &cfg, false);
        let early: f32 = report.flip_rates[5..15].iter().sum::<f32>() / 10.0;
        let late: f32 = report.flip_rates[report.flip_rates.len() - 10..].iter().sum::<f32>() / 10.0;
        assert!(late <= early, "flips should not grow: early {early} late {late}");
    }
}
