//! L3 coordinator: training orchestration on top of the native engine —
//! the launcher-facing layer (single- and multi-worker trainers, metric
//! logging, checkpointing).
//!
//! The paper's contribution lives at the algorithm level (L1/L2 and the
//! Boolean engine), so per the architecture rule this coordinator is a
//! *real but focused* training driver: config → data → train loop →
//! metrics → checkpoint, plus batch-parallel workers whose Boolean votes
//! are aggregated before a single optimizer step (the multi-GPU setup of
//! Appendix D.1.1, 8×V100, mapped to threads).

mod checkpoint;
mod dist;
mod metrics;
mod parallel;
mod trainer;
pub mod wire;

pub use checkpoint::{
    apply_params_blob, arch_record, load_checkpoint, load_model, load_training, params_blob,
    read_records, save_checkpoint, save_model, save_training, save_training_with_meta,
    CheckpointError, Record,
};
pub use dist::{
    compute_shard, run_coordinator, run_worker, DistConfig, DistOutcome, DistStats, JobSpec,
    META_DIST_STEP,
};
pub use metrics::MetricLog;
pub use parallel::ParallelTrainer;
pub use trainer::{
    evaluate_classifier, forward_eval, ClassifierTrainer, DualOptimizer, TrainReport,
};
