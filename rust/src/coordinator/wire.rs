//! Length-prefixed binary wire protocol for `train-dist` (DESIGN.md
//! §Distributed-Training).
//!
//! Frame layout (little-endian):
//!   u32 magic | u32 payload_len | u32 crc32(payload) | payload
//!
//! The CRC precedes the payload so a reader can verify integrity while
//! streaming; a mismatch, a bad magic, or an implausible length all
//! surface as [`WireError::Corrupt`] and the connection is dropped —
//! per-connection state is worthless once framing is lost, and the
//! worker's reconnect path (capped exponential backoff) restores it with
//! a fresh `Sync`. Torn frames (socket dies mid-payload) surface as the
//! underlying io error.
//!
//! Payload: `u8 msg tag | fields`. Variable-size fields are u32
//! length-prefixed. Weight and vote payloads reuse existing encodings
//! ([`crate::coordinator::params_blob`] record bytes,
//! [`crate::nn::ParamStore::grad_blob`]) rather than inventing a second
//! serialization of the same tensors.

use crate::util::crc32::crc32;
use std::fmt;
use std::io::{Read, Write};

/// Frame magic: rejects cross-protocol connects (e.g. an HTTP client
/// probing the coordinator port) on the first 4 bytes.
pub const FRAME_MAGIC: u32 = 0xB01D_D157;

/// Upper bound on a frame payload. Generous for full-model Sync frames
/// (Boolean weights are 1 bit/weight) while keeping a torn length prefix
/// from provoking a multi-GiB allocation.
pub const MAX_PAYLOAD: u32 = 256 * 1024 * 1024;

/// Protocol error: io (disconnect, timeout — retryable by reconnect) vs
/// corruption (framing lost — drop the connection).
#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    Corrupt(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io: {e}"),
            WireError::Corrupt(m) => write!(f, "wire corrupt: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

impl WireError {
    /// True when the error is a read timeout (the caller's heartbeat
    /// cadence) rather than a dead or corrupt connection.
    pub fn is_timeout(&self) -> bool {
        matches!(
            self,
            WireError::Io(e) if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            )
        )
    }
}

/// One protocol message. Worker→coordinator: `Hello`, `ShardResult`,
/// `Heartbeat`. Coordinator→worker: `Sync`, `Assign`, `Bye`.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker introduction. `config_hash` fingerprints the job config +
    /// dataset identity; a mismatched worker is turned away (`Bye`)
    /// before it can pollute the vote stream.
    Hello { worker_id: u64, config_hash: u64 },
    /// Full-weight install: the committed state entering `step`. Sent on
    /// join and after every optimizer step (the commit broadcast).
    Sync { step: u64, params: Vec<u8> },
    /// Compute shard `shard_id` of `step`: forward/backward over
    /// `indices`, gradient scaled by `indices.len() / total`.
    Assign { step: u64, shard_id: u32, total: u32, indices: Vec<u32> },
    /// A shard's vote delta ([`crate::nn::ParamStore::grad_blob`]) plus
    /// its loss/accuracy contribution. Idempotent per (step, shard_id):
    /// the coordinator drops duplicates, so re-issued shards are safe.
    ShardResult { step: u64, shard_id: u32, loss: f32, correct: u32, grads: Vec<u8> },
    /// Worker liveness signal (sent when idle past the heartbeat period).
    Heartbeat,
    /// Orderly goodbye (job complete or config rejected).
    Bye,
}

impl Msg {
    /// Short label for logs.
    pub fn label(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Sync { .. } => "sync",
            Msg::Assign { .. } => "assign",
            Msg::ShardResult { .. } => "result",
            Msg::Heartbeat => "heartbeat",
            Msg::Bye => "bye",
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Msg::Hello { worker_id, config_hash } => {
                p.push(1);
                p.extend_from_slice(&worker_id.to_le_bytes());
                p.extend_from_slice(&config_hash.to_le_bytes());
            }
            Msg::Sync { step, params } => {
                p.push(2);
                p.extend_from_slice(&step.to_le_bytes());
                p.extend_from_slice(&(params.len() as u32).to_le_bytes());
                p.extend_from_slice(params);
            }
            Msg::Assign { step, shard_id, total, indices } => {
                p.push(3);
                p.extend_from_slice(&step.to_le_bytes());
                p.extend_from_slice(&shard_id.to_le_bytes());
                p.extend_from_slice(&total.to_le_bytes());
                p.extend_from_slice(&(indices.len() as u32).to_le_bytes());
                for &i in indices {
                    p.extend_from_slice(&i.to_le_bytes());
                }
            }
            Msg::ShardResult { step, shard_id, loss, correct, grads } => {
                p.push(4);
                p.extend_from_slice(&step.to_le_bytes());
                p.extend_from_slice(&shard_id.to_le_bytes());
                p.extend_from_slice(&loss.to_le_bytes());
                p.extend_from_slice(&correct.to_le_bytes());
                p.extend_from_slice(&(grads.len() as u32).to_le_bytes());
                p.extend_from_slice(grads);
            }
            Msg::Heartbeat => p.push(5),
            Msg::Bye => p.push(6),
        }
        p
    }

    fn decode(p: &[u8]) -> Result<Msg, WireError> {
        fn take<'a>(p: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8], WireError> {
            let end = pos
                .checked_add(n)
                .ok_or_else(|| WireError::Corrupt("length overflow".to_string()))?;
            if end > p.len() {
                return Err(WireError::Corrupt(format!(
                    "message truncated at byte {pos} (want {n} more of {})",
                    p.len()
                )));
            }
            let s = &p[*pos..end];
            *pos = end;
            Ok(s)
        }
        fn r_u32(p: &[u8], pos: &mut usize) -> Result<u32, WireError> {
            let b = take(p, pos, 4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        }
        fn r_u64(p: &[u8], pos: &mut usize) -> Result<u64, WireError> {
            let b = take(p, pos, 8)?;
            Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
        }
        let mut pos = 1usize;
        let tag = *p.first().ok_or_else(|| WireError::Corrupt("empty message".to_string()))?;
        let msg = match tag {
            1 => {
                let worker_id = r_u64(p, &mut pos)?;
                let config_hash = r_u64(p, &mut pos)?;
                Msg::Hello { worker_id, config_hash }
            }
            2 => {
                let step = r_u64(p, &mut pos)?;
                let n = r_u32(p, &mut pos)? as usize;
                Msg::Sync { step, params: take(p, &mut pos, n)?.to_vec() }
            }
            3 => {
                let step = r_u64(p, &mut pos)?;
                let shard_id = r_u32(p, &mut pos)?;
                let total = r_u32(p, &mut pos)?;
                let n = r_u32(p, &mut pos)? as usize;
                let nbytes = n
                    .checked_mul(4)
                    .ok_or_else(|| WireError::Corrupt("index count overflow".to_string()))?;
                let indices = take(p, &mut pos, nbytes)?
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Msg::Assign { step, shard_id, total, indices }
            }
            4 => {
                let step = r_u64(p, &mut pos)?;
                let shard_id = r_u32(p, &mut pos)?;
                let loss =
                    f32::from_le_bytes(take(p, &mut pos, 4)?.try_into().expect("4 bytes"));
                let correct = r_u32(p, &mut pos)?;
                let n = r_u32(p, &mut pos)? as usize;
                let grads = take(p, &mut pos, n)?.to_vec();
                Msg::ShardResult { step, shard_id, loss, correct, grads }
            }
            5 => Msg::Heartbeat,
            6 => Msg::Bye,
            t => return Err(WireError::Corrupt(format!("unknown message tag {t}"))),
        };
        if pos != p.len() && !matches!(msg, Msg::Heartbeat | Msg::Bye) {
            return Err(WireError::Corrupt(format!("{} trailing bytes", p.len() - pos)));
        }
        if matches!(msg, Msg::Heartbeat | Msg::Bye) && p.len() != 1 {
            return Err(WireError::Corrupt(format!("{} trailing bytes", p.len() - 1)));
        }
        Ok(msg)
    }
}

/// Serialize `msg` into one frame on `w` (and flush — frames are the
/// protocol's unit of progress, a buffered half-frame helps no one).
pub fn write_frame(w: &mut impl Write, msg: &Msg) -> std::io::Result<()> {
    let payload = msg.encode();
    let mut head = [0u8; 12];
    head[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    head[4..8].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    head[8..12].copy_from_slice(&crc32(&payload).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(&payload)?;
    w.flush()
}

/// Read one frame from `r`, verifying magic, length sanity and CRC.
pub fn read_frame(r: &mut impl Read) -> Result<Msg, WireError> {
    let mut head = [0u8; 12];
    r.read_exact(&mut head)?;
    read_frame_with_head(r, head)
}

/// Read one frame from a stream with a read timeout, distinguishing
/// "idle" (nothing arrived within the timeout — `Ok(None)`, the caller's
/// heartbeat cue) from a mid-frame stall (bytes arrived, then the rest
/// timed out — an error, because partially consumed bytes mean framing
/// is lost and the connection must be dropped). The first read is a
/// plain `read`, which either consumes bytes or nothing at all, so the
/// idle path never tears a frame.
pub fn read_frame_idle(r: &mut impl Read) -> Result<Option<Msg>, WireError> {
    let mut head = [0u8; 12];
    let mut got = 0usize;
    while got == 0 {
        match r.read(&mut head) {
            Ok(0) => {
                return Err(WireError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed",
                )))
            }
            Ok(n) => got = n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(None)
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    r.read_exact(&mut head[got..])?; // timeout past this point is fatal
    Ok(Some(read_frame_with_head(r, head)?))
}

fn read_frame_with_head(r: &mut impl Read, head: [u8; 12]) -> Result<Msg, WireError> {
    let magic = u32::from_le_bytes(head[0..4].try_into().expect("4 bytes"));
    if magic != FRAME_MAGIC {
        return Err(WireError::Corrupt(format!("bad frame magic {magic:#010x}")));
    }
    let len = u32::from_le_bytes(head[4..8].try_into().expect("4 bytes"));
    if len > MAX_PAYLOAD {
        return Err(WireError::Corrupt(format!("frame length {len} exceeds cap {MAX_PAYLOAD}")));
    }
    let want_crc = u32::from_le_bytes(head[8..12].try_into().expect("4 bytes"));
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let got = crc32(&payload);
    if got != want_crc {
        return Err(WireError::Corrupt(format!(
            "frame CRC mismatch (header {want_crc:#010x}, payload {got:#010x})"
        )));
    }
    Msg::decode(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        write_frame(&mut buf, msg).unwrap();
        read_frame(&mut buf.as_slice()).unwrap()
    }

    #[test]
    fn every_message_roundtrips() {
        let msgs = vec![
            Msg::Hello { worker_id: 3, config_hash: 0xDEAD_BEEF_CAFE_F00D },
            Msg::Sync { step: 7, params: vec![1, 2, 3, 255, 0] },
            Msg::Assign { step: 9, shard_id: 2, total: 48, indices: vec![0, 5, 17, u32::MAX] },
            Msg::ShardResult {
                step: 9,
                shard_id: 2,
                loss: -0.0, // sign bit must survive
                correct: 11,
                grads: vec![9; 100],
            },
            Msg::Heartbeat,
            Msg::Bye,
        ];
        for m in &msgs {
            assert_eq!(&roundtrip(m), m, "{} must round-trip", m.label());
        }
        // -0.0 sign bit check, since PartialEq treats -0.0 == 0.0
        let mut buf = Vec::new();
        write_frame(&mut buf, &msgs[3]).unwrap();
        match read_frame(&mut buf.as_slice()).unwrap() {
            Msg::ShardResult { loss, .. } => assert_eq!(loss.to_bits(), (-0.0f32).to_bits()),
            _ => panic!("wrong message"),
        }
    }

    #[test]
    fn torn_frames_error_at_every_truncation_point() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Msg::Assign { step: 1, shard_id: 0, total: 8, indices: vec![1, 2, 3] })
            .unwrap();
        for cut in 0..buf.len() {
            let r = read_frame(&mut &buf[..cut]);
            assert!(r.is_err(), "torn frame at {cut}/{} must not parse", buf.len());
        }
    }

    #[test]
    fn bit_flips_anywhere_are_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Msg::ShardResult { step: 3, shard_id: 1, loss: 0.5, correct: 4, grads: vec![7; 32] })
            .unwrap();
        for i in 0..buf.len() {
            let mut t = buf.clone();
            t[i] ^= 0x10;
            assert!(
                read_frame(&mut t.as_slice()).is_err(),
                "flip at byte {i} must be caught by magic/len/CRC"
            );
        }
    }

    #[test]
    fn implausible_length_is_rejected_without_allocating() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // 4 GiB claim
        buf.extend_from_slice(&0u32.to_le_bytes());
        match read_frame(&mut buf.as_slice()) {
            Err(WireError::Corrupt(m)) => assert!(m.contains("exceeds cap"), "{m}"),
            other => panic!("want Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn cross_protocol_bytes_are_rejected_on_magic() {
        let http = b"POST /v1/models/mlp/predict HTTP/1.1\r\n\r\n";
        match read_frame(&mut &http[..]) {
            Err(WireError::Corrupt(m)) => assert!(m.contains("magic"), "{m}"),
            other => panic!("want Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn read_frame_idle_separates_idle_from_torn_and_eof() {
        // a reader that yields WouldBlock before any byte: idle, no error
        struct Idle;
        impl std::io::Read for Idle {
            fn read(&mut self, _b: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "idle"))
            }
        }
        assert!(matches!(read_frame_idle(&mut Idle), Ok(None)));

        // a complete frame parses as usual
        let mut buf = Vec::new();
        write_frame(&mut buf, &Msg::Heartbeat).unwrap();
        match read_frame_idle(&mut buf.as_slice()) {
            Ok(Some(Msg::Heartbeat)) => {}
            other => panic!("want heartbeat, got {other:?}"),
        }

        // WouldBlock AFTER the first bytes landed = torn frame = fatal
        struct Torn {
            sent: bool,
        }
        impl std::io::Read for Torn {
            fn read(&mut self, b: &mut [u8]) -> std::io::Result<usize> {
                if self.sent {
                    return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "stall"));
                }
                self.sent = true;
                b[0] = 0x57; // first byte of FRAME_MAGIC (LE)
                Ok(1)
            }
        }
        assert!(read_frame_idle(&mut Torn { sent: false }).is_err());

        // clean EOF before any byte is an error too (peer is gone)
        assert!(read_frame_idle(&mut std::io::empty()).is_err());
    }

    #[test]
    fn timeout_classification() {
        let e = WireError::Io(std::io::Error::new(std::io::ErrorKind::WouldBlock, "t"));
        assert!(e.is_timeout());
        let e = WireError::Io(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "t"));
        assert!(!e.is_timeout());
        assert!(!WireError::Corrupt("x".into()).is_timeout());
    }
}
