//! Bit-packed Boolean matrix: the simulated "native Boolean accelerator"
//! dataflow (DESIGN.md §Hardware-Adaptation).
//!
//! Layout: row-major, 64 Boolean values per `u64` word, bit = 1 ↔ T ↔ +1
//! under the Definition A.1 embedding. The Boolean neuron of Eq. (1) with
//! the xnor connective becomes, per output unit,
//!
//! ```text
//! s = Σ_i xnor(w_i, x_i) = (#agree) − (#disagree)
//!   = m_valid − 2·popcount((x ⊕ w) & valid)
//! ```
//!
//! i.e. one XOR + POPCNT per 64 weights — this is the energy story of the
//! paper made concrete. Optional validity masks implement the three-valued
//! 0 of Definition 3.1 (zero-padding in convolutions): masked-off lanes
//! contribute nothing to the count.
//!
//! # Parallelism and determinism
//!
//! Every kernel here shards **disjoint output-row ranges** across the
//! persistent [`crate::util::pool`] (DESIGN.md §Parallelism): each shard
//! runs the identical per-element arithmetic in the identical order as the
//! sequential form, so results are bit-exact for any thread count / any
//! `BOLD_NUM_THREADS` setting. The `_into` variants additionally reuse a
//! caller-owned output buffer so steady-state training and serving stop
//! allocating per batch.
//!
//! # SIMD backend and K-tiling
//!
//! Within a shard, the forward kernels run a cache-blocked loop —
//! `ROW_BLOCK` input rows share every streamed weight K-tile of
//! `K_TILE_WORDS` words — whose inner XOR+POPCNT reduction dispatches
//! through [`crate::tensor::simd`] (AVX2 Harley–Seal / NEON `vcntq_u8` /
//! scalar, `BOLD_SIMD` override); the backward kernels dispatch their
//! per-row `axpy_pm1[_masked]` updates the same way. Popcount sums are
//! integers and the f32 kernels replay the scalar reference's exact IEEE
//! ops, so every backend/tiling combination is bit-exact
//! (`tests/simd_parity.rs`). Word storage is 64-byte-aligned
//! [`AlignedWords`].

use super::simd::{self, scalar, AlignedWords, Backend, Kernels};
use super::Tensor;
use crate::util::pool::{self, MAC_QUANTUM};
use crate::util::Rng;

/// Minimum packed word-ops per pool shard for the XOR+POPCNT kernels
/// (~65 Ki word ops ≈ tens of µs): tensors that would give a shard less
/// work than the enqueue/wakeup overhead stay sequential. The LUT
/// backward kernels use the shared [`pool::MAC_QUANTUM`].
const WORD_QUANTUM: usize = 1 << 16;

/// K-tile width in packed words (4 KiB per row-tile): within one tile
/// the row block's input panels stay L1-resident while every weight row
/// streams through once, so wide fan-ins (im2col'd conv rows, BERT FFN)
/// never thrash L2 re-reading inputs. Integer popcount sums are
/// order-independent, so tiling cannot change any result bit. Multiple
/// of the AVX2 Harley–Seal block (64 words) so full tiles vectorise
/// without per-tile scalar tails.
const K_TILE_WORDS: usize = 512;

/// Input rows processed per weight-matrix pass: each streamed weight
/// K-tile is reused this many times from L1, quartering weight traffic
/// vs a row-at-a-time loop (the replacement for the old 2×2 blocking).
const ROW_BLOCK: usize = 4;

/// Below this many words per row, the `fn`-pointer dispatch costs more
/// than the reduction itself (tiny conv fan-ins): the cores inline the
/// [`scalar`] reference directly instead. Bit-exact either way.
const SIMD_MIN_WORDS: usize = 8;

thread_local! {
    /// Per-thread u32 count accumulator for the tiled forward cores
    /// (`ROW_BLOCK × n_out` entries). Thread-local so pool shards reuse
    /// it across calls — the kernels stay allocation-free at steady
    /// state. The cores are leaf code (they never re-enter the pool), so
    /// the RefCell can never observe a nested borrow.
    static ACC_TL: std::cell::RefCell<Vec<u32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Run `f` on the thread-local count accumulator, zeroed to `len`.
fn with_acc<R>(len: usize, f: impl FnOnce(&mut [u32]) -> R) -> R {
    ACC_TL.with(|c| {
        let mut v = c.borrow_mut();
        if v.len() < len {
            v.resize(len, 0);
        }
        let acc = &mut v[..len];
        acc.fill(0);
        f(acc)
    })
}

/// How the validity mask enters the tiled accumulation.
#[derive(Clone, Copy)]
enum MaskK<'a> {
    /// No mask: plain XOR+POPCNT.
    None,
    /// Per-input-row mask words, laid out like the input block.
    PerRow(&'a [u64]),
    /// One packed lane-mask row shared by every input row.
    Shared(&'a [u64]),
}

/// The tiled core shared by all four forward kernels:
/// `acc[i·n + j] += popc((x_i ⊕ w_j) [& m_i])` for `rows ≤ ROW_BLOCK`
/// input rows against all `n` weight rows, K-tiled ([`K_TILE_WORDS`])
/// with the inner reduction on the dispatched SIMD backend
/// ([`simd::kernels`], hoisted to `kk` by the caller). Small fan-ins
/// bypass the `fn`-pointer indirection (see [`SIMD_MIN_WORDS`]).
fn accum_counts(
    kk: &Kernels,
    x: &[u64],
    mk: MaskK<'_>,
    wpr: usize,
    rows: usize,
    w: &BitMatrix,
    n: usize,
    acc: &mut [u32],
) {
    debug_assert_eq!(acc.len(), rows * n);
    debug_assert_eq!(x.len(), rows * wpr);
    let inline_scalar = kk.backend == Backend::Scalar || wpr < SIMD_MIN_WORDS;
    let mut k0 = 0usize;
    while k0 < wpr {
        let kt = K_TILE_WORDS.min(wpr - k0);
        for j in 0..n {
            let wt = &w.row(j)[k0..k0 + kt];
            for i in 0..rows {
                let xt = &x[i * wpr + k0..i * wpr + k0 + kt];
                let d = match mk {
                    MaskK::None => {
                        if inline_scalar {
                            scalar::xor_popcnt(xt, wt)
                        } else {
                            (kk.xor_popcnt)(xt, wt)
                        }
                    }
                    MaskK::PerRow(m) => {
                        let mt = &m[i * wpr + k0..i * wpr + k0 + kt];
                        if inline_scalar {
                            scalar::xor_and_popcnt(xt, wt, mt)
                        } else {
                            (kk.xor_and_popcnt)(xt, wt, mt)
                        }
                    }
                    MaskK::Shared(m) => {
                        let mt = &m[k0..k0 + kt];
                        if inline_scalar {
                            scalar::xor_and_popcnt(xt, wt, mt)
                        } else {
                            (kk.xor_and_popcnt)(xt, wt, mt)
                        }
                    }
                };
                acc[i * n + j] += d as u32;
            }
        }
        k0 += kt;
    }
}

/// Bit-packed Boolean matrix (rows × cols), row-major, 64 cols per word.
///
/// ```
/// use bold::tensor::BitMatrix;
/// use bold::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let x = BitMatrix::random(2, 100, &mut rng); // 2 inputs, 100 bits each
/// let w = BitMatrix::random(4, 100, &mut rng); // 4 Boolean neurons
///
/// // Eq. (3) forward: one XOR + POPCNT per 64 weights.
/// let s = x.xnor_gemm(&w);
/// assert_eq!(s.shape, vec![2, 4]);
/// // Pre-activations count (#agree − #disagree) over the 100-bit fan-in.
/// assert!(s.data.iter().all(|&v| v.abs() <= 100.0));
///
/// // The same result through the ±1 embedding of Prop. A.2, exactly.
/// let dense = x.to_pm1().matmul_bt(&w.to_pm1());
/// assert_eq!(s.max_abs_diff(&dense), 0.0);
/// ```
#[derive(Debug, PartialEq, Eq)]
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    /// words per row = ceil(cols / 64)
    pub wpr: usize,
    /// 64-byte-aligned packed words ([`AlignedWords`] derefs to `[u64]`,
    /// so slice-style access works unchanged).
    pub words: AlignedWords,
}

impl Clone for BitMatrix {
    fn clone(&self) -> Self {
        BitMatrix { rows: self.rows, cols: self.cols, wpr: self.wpr, words: self.words.clone() }
    }

    /// Reuses the existing word allocation (the layer forward caches rely
    /// on this to stop allocating per batch).
    fn clone_from(&mut self, src: &Self) {
        self.rows = src.rows;
        self.cols = src.cols;
        self.wpr = src.wpr;
        self.words.clone_from(&src.words);
    }
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let wpr = cols.div_ceil(64);
        BitMatrix { rows, cols, wpr, words: AlignedWords::zeroed(rows * wpr) }
    }

    /// Rebuild from raw packed words (e.g. checkpoint records). Tail bits
    /// beyond `cols` are cleared so the whole-word popcount invariant holds
    /// even for words from an untrusted source.
    pub fn from_words(rows: usize, cols: usize, words: Vec<u64>) -> Self {
        let wpr = cols.div_ceil(64);
        assert_eq!(words.len(), rows * wpr, "word count {} vs {rows}x{cols}", words.len());
        let mut m = BitMatrix { rows, cols, wpr, words: AlignedWords::from(words) };
        m.mask_tail();
        m
    }

    /// Assemble a matrix from per-row packed slices (each `ceil(cols/64)`
    /// words), reshaping and reusing the existing allocation — the batch
    /// server gathers request rows with ONE copy and no staging buffer.
    /// Tail bits beyond `cols` are cleared, as in [`Self::from_words`].
    pub fn assign_packed_rows<'a, I>(&mut self, cols: usize, rows: I)
    where
        I: IntoIterator<Item = &'a [u64]>,
    {
        let wpr = cols.div_ceil(64);
        self.cols = cols;
        self.wpr = wpr;
        self.words.clear();
        let mut count = 0usize;
        for row in rows {
            assert_eq!(row.len(), wpr, "packed row width {} vs wpr {wpr}", row.len());
            self.words.extend_from_slice(row);
            count += 1;
        }
        self.rows = count;
        self.mask_tail();
    }

    /// Resize to (rows × cols) reusing the word allocation, leaving the
    /// contents **unspecified** — for `_into` kernels that fully overwrite
    /// every word. Not public: callers outside this module go through the
    /// overwriting kernels or [`Self::zero_resize`].
    fn reset_dims(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.wpr = cols.div_ceil(64);
        self.words.resize(rows * self.wpr, 0);
    }

    /// Resize to (rows × cols) reusing the word allocation, zeroing all
    /// content (for scratch buffers that are filled with `set_bits` runs).
    pub fn zero_resize(&mut self, rows: usize, cols: usize) {
        self.reset_dims(rows, cols);
        self.words.fill(0);
    }

    /// Random ±1 content (each bit Bernoulli(1/2)).
    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut m = BitMatrix::zeros(rows, cols);
        for w in m.words.iter_mut() {
            *w = rng.next_u64();
        }
        m.mask_tail();
        m
    }

    /// Zero out the bits beyond `cols` in each row's last word so that
    /// whole-word popcounts never see garbage. Invariant maintained by all
    /// constructors and mutators.
    fn mask_tail(&mut self) {
        let rem = self.cols % 64;
        if rem == 0 {
            return;
        }
        let mask = (1u64 << rem) - 1;
        for r in 0..self.rows {
            self.words[r * self.wpr + self.wpr - 1] &= mask;
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        (self.words[r * self.wpr + c / 64] >> (c % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let w = &mut self.words[r * self.wpr + c / 64];
        if v {
            *w |= 1u64 << (c % 64);
        } else {
            *w &= !(1u64 << (c % 64));
        }
    }

    #[inline]
    pub fn flip(&mut self, r: usize, c: usize) {
        self.words[r * self.wpr + c / 64] ^= 1u64 << (c % 64);
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u64] {
        &self.words[r * self.wpr..(r + 1) * self.wpr]
    }

    /// Mutable packed words of row `r` (for word-wise writers like the
    /// graph executor's [`simd::pack_cmp_into`] threshold re-pack).
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [u64] {
        let wpr = self.wpr;
        &mut self.words[r * wpr..(r + 1) * wpr]
    }

    /// Read `len ≤ 56` bits starting at (r, c) as the low bits of a u64
    /// (word-level, crosses at most one word boundary).
    #[inline]
    pub fn get_bits(&self, r: usize, c: usize, len: usize) -> u64 {
        debug_assert!(len <= 56 && c + len <= self.cols);
        let base = r * self.wpr;
        let wi = c / 64;
        let off = c % 64;
        let lo = self.words[base + wi] >> off;
        let val = if off + len > 64 {
            lo | (self.words[base + wi + 1] << (64 - off))
        } else {
            lo
        };
        val & ((1u64 << len) - 1)
    }

    /// Write `len ≤ 56` bits starting at (r, c) from the low bits of `v`.
    #[inline]
    pub fn set_bits(&mut self, r: usize, c: usize, len: usize, v: u64) {
        debug_assert!(len <= 56 && c + len <= self.cols);
        let mask = (1u64 << len) - 1;
        let v = v & mask;
        let base = r * self.wpr;
        let wi = c / 64;
        let off = c % 64;
        self.words[base + wi] = (self.words[base + wi] & !(mask << off)) | (v << off);
        if off + len > 64 {
            let hi_len = off + len - 64;
            let hi_mask = (1u64 << hi_len) - 1;
            self.words[base + wi + 1] =
                (self.words[base + wi + 1] & !hi_mask) | (v >> (64 - off));
        }
    }

    /// Value in the ±1 embedding: +1 for set bit (T), −1 otherwise.
    #[inline]
    pub fn pm1(&self, r: usize, c: usize) -> f32 {
        if self.get(r, c) { 1.0 } else { -1.0 }
    }

    /// Pack a ±1 f32 2-D tensor (x ≥ 0 ⇒ T, matching the threshold
    /// activation convention s ≥ τ ⇒ T).
    pub fn from_pm1(t: &Tensor) -> Self {
        let (r, c) = (t.rows(), t.cols());
        let mut m = BitMatrix::zeros(r, c);
        for i in 0..r {
            for j in 0..c {
                if t.at2(i, j) >= 0.0 {
                    m.set(i, j, true);
                }
            }
        }
        m
    }

    /// Unpack to a ±1 f32 tensor.
    pub fn to_pm1(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.rows, self.cols]);
        for i in 0..self.rows {
            for j in 0..self.cols {
                *t.at2_mut(i, j) = self.pm1(i, j);
            }
        }
        t
    }

    /// Count of set bits (TRUEs).
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Number of positions where `self` and `other` differ.
    pub fn hamming(&self, other: &BitMatrix) -> usize {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a ^ b).count_ones() as usize)
            .sum()
    }

    /// Boolean linear forward, Eq. (3): `self` is the input X (B × M bits),
    /// `w` the weights (N × M bits); result (B × N) integer pre-activations
    /// as f32. One XOR+POPCNT per word pair.
    pub fn xnor_gemm(&self, w: &BitMatrix) -> Tensor {
        let mut out = Tensor::zeros(&[0]);
        self.xnor_gemm_into(w, &mut out);
        out
    }

    /// [`Self::xnor_gemm`] into a reusable output tensor (reshaped and
    /// fully overwritten): batch rows shard across the pool.
    pub fn xnor_gemm_into(&self, w: &BitMatrix, out: &mut Tensor) {
        assert_eq!(self.cols, w.cols, "fan-in mismatch {} vs {}", self.cols, w.cols);
        let (b, n, m) = (self.rows, w.rows, self.cols);
        out.resize_to(&[b, n]);
        let shards = pool::shards_for(b * n * self.wpr, b, WORD_QUANTUM);
        if shards <= 1 {
            gemm_rows(&self.words, self.wpr, w, m, &mut out.data, n);
            return;
        }
        let rows_per = b.div_ceil(shards);
        let tasks: Vec<_> = self
            .words
            .chunks(rows_per * self.wpr)
            .zip(out.data.chunks_mut(rows_per * n))
            .map(|(xc, oc)| move || gemm_rows(xc, self.wpr, w, m, oc, n))
            .collect();
        pool::run_scoped(tasks);
    }

    /// Masked Boolean forward for three-valued inputs (Definition 3.1 /
    /// 3.5): lanes with `mask` bit 0 are the adjoined 0 and contribute
    /// nothing. `mask` has the same shape as `self` (per input row).
    ///
    /// ```text
    /// s_ij = popc(mask_i) − 2·popc((x_i ⊕ w_j) & mask_i)
    /// ```
    pub fn xnor_gemm_masked(&self, w: &BitMatrix, mask: &BitMatrix) -> Tensor {
        let mut out = Tensor::zeros(&[0]);
        self.xnor_gemm_masked_into(w, mask, &mut out);
        out
    }

    /// [`Self::xnor_gemm_masked`] into a reusable output tensor. Same
    /// tiled SIMD core as the unmasked GEMM (mask ANDed into the
    /// reduction) — this is the `BoolConv2d` forward hot path.
    pub fn xnor_gemm_masked_into(&self, w: &BitMatrix, mask: &BitMatrix, out: &mut Tensor) {
        assert_eq!(self.cols, w.cols);
        assert_eq!((self.rows, self.cols), (mask.rows, mask.cols));
        let (b, n) = (self.rows, w.rows);
        out.resize_to(&[b, n]);
        let shards = pool::shards_for(b * n * self.wpr, b, WORD_QUANTUM);
        if shards <= 1 {
            gemm_masked_rows(&self.words, &mask.words, self.wpr, w, &mut out.data, n);
            return;
        }
        let rows_per = b.div_ceil(shards);
        let tasks: Vec<_> = self
            .words
            .chunks(rows_per * self.wpr)
            .zip(mask.words.chunks(rows_per * self.wpr))
            .zip(out.data.chunks_mut(rows_per * n))
            .map(|((xc, mc), oc)| move || gemm_masked_rows(xc, mc, self.wpr, w, oc, n))
            .collect();
        pool::run_scoped(tasks);
    }

    /// Fused Boolean linear + threshold activation for the forward-only
    /// inference engine (DESIGN.md §Serving-Runtime): computes the Eq. (3)
    /// pre-activation `s = m − 2·popcount(x ⊕ w)` per output unit with
    /// integer arithmetic and packs `s ≥ thr` straight back into bits —
    /// the hot path never materialises an f32 activation tensor.
    ///
    /// `bias`, when present, is a 1 × n_out Boolean bias in the ±1
    /// embedding (added to `s` before thresholding), matching
    /// `nn::BoolLinear::with_bias`. The comparison is done in f32 so the
    /// result is bit-identical to the reference
    /// `nn::BoolLinear` → `nn::ThresholdAct` path for any threshold.
    ///
    /// Same tiled SIMD reduction as [`Self::xnor_gemm`], with the
    /// integer counts compared and packed straight back to bits
    /// (§Perf iteration log).
    pub fn xnor_threshold(&self, w: &BitMatrix, bias: Option<&BitMatrix>, thr: f32) -> BitMatrix {
        let mut out = BitMatrix::zeros(0, 0);
        self.xnor_threshold_into(w, bias, thr, &mut out);
        out
    }

    /// [`Self::xnor_threshold`] into a reusable output matrix (reshaped
    /// and fully overwritten): the serving engine's ping-pong activation
    /// buffers make the whole Boolean interior allocation-free.
    pub fn xnor_threshold_into(
        &self,
        w: &BitMatrix,
        bias: Option<&BitMatrix>,
        thr: f32,
        out: &mut BitMatrix,
    ) {
        assert_eq!(self.cols, w.cols, "fan-in mismatch {} vs {}", self.cols, w.cols);
        if let Some(b) = bias {
            assert_eq!((b.rows, b.cols), (1, w.rows), "bias shape {}x{}", b.rows, b.cols);
        }
        let (bsz, n, m) = (self.rows, w.rows, self.cols);
        out.reset_dims(bsz, n);
        if bsz == 0 || n == 0 {
            return;
        }
        let wpr_out = out.wpr;
        let shards = pool::shards_for(bsz * n * self.wpr, bsz, WORD_QUANTUM);
        if shards <= 1 || self.wpr == 0 {
            threshold_rows(&self.words, self.wpr, w, m, bias, thr, &mut out.words, wpr_out, n);
            return;
        }
        let rows_per = bsz.div_ceil(shards);
        let tasks: Vec<_> = self
            .words
            .chunks(rows_per * self.wpr)
            .zip(out.words.chunks_mut(rows_per * wpr_out))
            .map(|(xc, oc)| {
                move || threshold_rows(xc, self.wpr, w, m, bias, thr, oc, wpr_out, n)
            })
            .collect();
        pool::run_scoped(tasks);
    }

    /// Masked variant of [`Self::xnor_threshold`] for three-valued inputs:
    /// `lane_mask` (one packed row of `wpr` words, shared by every batch
    /// row) marks valid input lanes; masked-off lanes are the adjoined 𝕄
    /// zero and contribute nothing, so
    /// `s = popc(mask) − 2·popc((x ⊕ w) & mask)`.
    pub fn xnor_threshold_masked(
        &self,
        w: &BitMatrix,
        lane_mask: &[u64],
        bias: Option<&BitMatrix>,
        thr: f32,
    ) -> BitMatrix {
        let mut out = BitMatrix::zeros(0, 0);
        self.xnor_threshold_masked_into(w, lane_mask, bias, thr, &mut out);
        out
    }

    /// [`Self::xnor_threshold_masked`] into a reusable output matrix.
    pub fn xnor_threshold_masked_into(
        &self,
        w: &BitMatrix,
        lane_mask: &[u64],
        bias: Option<&BitMatrix>,
        thr: f32,
        out: &mut BitMatrix,
    ) {
        assert_eq!(self.cols, w.cols, "fan-in mismatch {} vs {}", self.cols, w.cols);
        assert_eq!(lane_mask.len(), self.wpr, "lane mask word count");
        if let Some(b) = bias {
            assert_eq!((b.rows, b.cols), (1, w.rows), "bias shape {}x{}", b.rows, b.cols);
        }
        let (bsz, n) = (self.rows, w.rows);
        // tolerate garbage mask bits beyond `cols` in the last word (the
        // data words already hold the tail invariant)
        let rem = self.cols % 64;
        let tail = if rem == 0 { u64::MAX } else { (1u64 << rem) - 1 };
        let valid: i64 = lane_mask
            .iter()
            .enumerate()
            .map(|(k, &mw)| {
                let mw = if k + 1 == lane_mask.len() { mw & tail } else { mw };
                mw.count_ones() as i64
            })
            .sum();
        out.reset_dims(bsz, n);
        if bsz == 0 || n == 0 {
            return;
        }
        let wpr_out = out.wpr;
        let shards = pool::shards_for(bsz * n * self.wpr, bsz, WORD_QUANTUM);
        if shards <= 1 || self.wpr == 0 {
            threshold_masked_rows(
                &self.words, self.wpr, w, lane_mask, valid, bias, thr, &mut out.words, wpr_out, n,
            );
            return;
        }
        let rows_per = bsz.div_ceil(shards);
        let tasks: Vec<_> = self
            .words
            .chunks(rows_per * self.wpr)
            .zip(out.words.chunks_mut(rows_per * wpr_out))
            .map(|(xc, oc)| {
                move || {
                    threshold_masked_rows(
                        xc, self.wpr, w, lane_mask, valid, bias, thr, oc, wpr_out, n,
                    )
                }
            })
            .collect();
        pool::run_scoped(tasks);
    }

    /// Decode one packed row into a caller-provided ±1 buffer (`out.len()`
    /// must equal `cols`) via the byte LUT — the engine's FP head uses this
    /// to stream one cache-resident scratch row instead of unpacking whole
    /// tensors.
    pub fn decode_pm1_row(&self, r: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.cols, "decode buffer len");
        scalar::decode_pm1(out, self.row(r));
    }

    /// z @ e(W): real backward signal times embedded Boolean weights
    /// (Algorithm 7, `G_X`). z is (B × N), self is W (N × M) → (B × M).
    ///
    /// Computed as gx = (Σ_{j: w_jk=T} z_ij) − (Σ_{j: w_jk=F} z_ij)
    ///            = 2·Σ_{j: w_jk=T} z_ij − Σ_j z_ij,
    /// walking each weight row once and adding ±z — no unpacking to f32.
    pub fn backward_input(&self, z: &Tensor) -> Tensor {
        let mut out = Tensor::zeros(&[0]);
        self.backward_input_into(z, &mut out);
        out
    }

    /// [`Self::backward_input`] into a reusable output tensor (reshaped,
    /// zeroed, then accumulated): batch rows shard across the pool.
    pub fn backward_input_into(&self, z: &Tensor, out: &mut Tensor) {
        let (n, m) = (self.rows, self.cols);
        assert_eq!(z.cols(), n, "z cols {} vs N {}", z.cols(), n);
        let b = z.rows();
        out.resize_to(&[b, m]);
        out.data.fill(0.0);
        let shards = pool::shards_for(b * n * m, b, MAC_QUANTUM);
        if shards <= 1 || n == 0 || m == 0 {
            bwd_input_rows(self, &z.data, n, &mut out.data, m);
            return;
        }
        let rows_per = b.div_ceil(shards);
        let tasks: Vec<_> = z
            .data
            .chunks(rows_per * n)
            .zip(out.data.chunks_mut(rows_per * m))
            .map(|(zc, oc)| move || bwd_input_rows(self, zc, n, oc, m))
            .collect();
        pool::run_scoped(tasks);
    }

    /// zᵀ @ e(X): the weight vote of Eq. (7) (Algorithm 7, `G_W`).
    /// z is (B × N), self is X (B × M bits) → (N × M).
    pub fn backward_weight(&self, z: &Tensor) -> Tensor {
        let mut out = Tensor::zeros(&[0]);
        self.backward_weight_into(z, &mut out);
        out
    }

    /// [`Self::backward_weight`] into a reusable output tensor (reshaped,
    /// zeroed, then accumulated): output-unit rows shard across the pool.
    pub fn backward_weight_into(&self, z: &Tensor, out: &mut Tensor) {
        let (b, m) = (self.rows, self.cols);
        assert_eq!(z.rows(), b, "z rows {} vs B {}", z.rows(), b);
        let n = z.cols();
        out.resize_to(&[n, m]);
        out.data.fill(0.0);
        let shards = pool::shards_for(b * n * m, n, MAC_QUANTUM);
        pool::for_each_row_chunk(&mut out.data, m, shards, |j0, oc| {
            bwd_weight_rows(self, &z.data, n, j0, oc, m, None)
        });
    }

    /// Masked variant of [`Self::backward_weight`]: lanes with mask bit 0
    /// are the three-valued 0 (e.g. conv zero-padding) and contribute no
    /// vote — e(0) = 0 in Definition A.1.
    pub fn backward_weight_masked(&self, z: &Tensor, mask: &BitMatrix) -> Tensor {
        let mut out = Tensor::zeros(&[0]);
        self.backward_weight_masked_into(z, mask, &mut out);
        out
    }

    /// [`Self::backward_weight_masked`] into a reusable output tensor.
    pub fn backward_weight_masked_into(&self, z: &Tensor, mask: &BitMatrix, out: &mut Tensor) {
        let (b, m) = (self.rows, self.cols);
        assert_eq!(z.rows(), b);
        assert_eq!((mask.rows, mask.cols), (b, m));
        let n = z.cols();
        out.resize_to(&[n, m]);
        out.data.fill(0.0);
        let shards = pool::shards_for(b * n * m, n, MAC_QUANTUM);
        pool::for_each_row_chunk(&mut out.data, m, shards, |j0, oc| {
            bwd_weight_rows(self, &z.data, n, j0, oc, m, Some(mask))
        });
    }
}

// ---------------------------------------------------------------------------
// row-range kernel cores (sequential bodies; the parallel wrappers above
// hand each core a disjoint output-row range, so any shard split computes
// bit-identical results to the single-shard call)
// ---------------------------------------------------------------------------

/// Eq. (3) forward over a contiguous row block. `x` holds `out.len()/n`
/// packed input rows of `wpr` words; `out` is the matching (rows × n)
/// output block. [`ROW_BLOCK`] input rows share each streamed weight
/// K-tile and the reduction runs on the dispatched SIMD backend (see
/// [`accum_counts`]).
fn gemm_rows(x: &[u64], wpr: usize, w: &BitMatrix, m: usize, out: &mut [f32], n: usize) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    if rows == 0 {
        return;
    }
    let kk = simd::kernels();
    with_acc(ROW_BLOCK.min(rows) * n, |acc| {
        let mut i0 = 0usize;
        while i0 < rows {
            let bl = ROW_BLOCK.min(rows - i0);
            let a = &mut acc[..bl * n];
            if i0 > 0 {
                a.fill(0);
            }
            accum_counts(kk, &x[i0 * wpr..(i0 + bl) * wpr], MaskK::None, wpr, bl, w, n, a);
            for i in 0..bl {
                let orow = &mut out[(i0 + i) * n..(i0 + i + 1) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = (m as i64 - 2 * a[i * n + j] as i64) as f32;
                }
            }
            i0 += bl;
        }
    });
}

/// Masked Eq. (3) forward over a contiguous row block: the same tiled
/// SIMD core as [`gemm_rows`] with the per-input-row mask ANDed into the
/// reduction and a per-row valid count (`mk` mirrors `x`). This is the
/// `BoolConv2d` forward hot path.
fn gemm_masked_rows(x: &[u64], mk: &[u64], wpr: usize, w: &BitMatrix, out: &mut [f32], n: usize) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    if rows == 0 {
        return;
    }
    let kk = simd::kernels();
    with_acc(ROW_BLOCK.min(rows) * n, |acc| {
        let mut i0 = 0usize;
        while i0 < rows {
            let bl = ROW_BLOCK.min(rows - i0);
            let a = &mut acc[..bl * n];
            if i0 > 0 {
                a.fill(0);
            }
            let xb = &x[i0 * wpr..(i0 + bl) * wpr];
            let mb = &mk[i0 * wpr..(i0 + bl) * wpr];
            accum_counts(kk, xb, MaskK::PerRow(mb), wpr, bl, w, n, a);
            for i in 0..bl {
                let v = (kk.popcnt)(&mb[i * wpr..(i + 1) * wpr]) as i64;
                let orow = &mut out[(i0 + i) * n..(i0 + i + 1) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = (v - 2 * a[i * n + j] as i64) as f32;
                }
            }
            i0 += bl;
        }
    });
}

/// Compare-and-pack one output row of the fused threshold kernels:
/// bit j = `(base − 2·acc[j] + bias_j) as f32 >= thr`, written word-wise
/// into `out` (every word of the row is overwritten — the `_into` reuse
/// contract tolerates dirty output buffers).
#[inline]
fn pack_threshold_row(
    acc: &[u32],
    base: i64,
    bias: Option<&BitMatrix>,
    thr: f32,
    out: &mut [u64],
    n: usize,
) {
    let bval = |j: usize| -> i64 {
        match bias {
            Some(b) => {
                if b.get(0, j) {
                    1
                } else {
                    -1
                }
            }
            None => 0,
        }
    };
    let mut word = 0u64;
    for j in 0..n {
        let s = (base - 2 * acc[j] as i64) + bval(j);
        if (s as f32) >= thr {
            word |= 1u64 << (j % 64);
        }
        if j % 64 == 63 {
            out[j / 64] = word;
            word = 0;
        }
    }
    if n % 64 != 0 {
        out[(n - 1) / 64] = word;
    }
}

/// Fused linear+threshold over a contiguous row block (`out` is the
/// matching packed (rows × n) block with `wpr_out` words per row): the
/// tiled SIMD reduction of [`accum_counts`], then a compare-and-pack
/// pass over the integer counts.
fn threshold_rows(
    x: &[u64],
    wpr: usize,
    w: &BitMatrix,
    m: usize,
    bias: Option<&BitMatrix>,
    thr: f32,
    out: &mut [u64],
    wpr_out: usize,
    n: usize,
) {
    let rows = out.len() / wpr_out;
    if rows == 0 {
        return;
    }
    let kk = simd::kernels();
    with_acc(ROW_BLOCK.min(rows) * n, |acc| {
        let mut i0 = 0usize;
        while i0 < rows {
            let bl = ROW_BLOCK.min(rows - i0);
            let a = &mut acc[..bl * n];
            if i0 > 0 {
                a.fill(0);
            }
            accum_counts(kk, &x[i0 * wpr..(i0 + bl) * wpr], MaskK::None, wpr, bl, w, n, a);
            for i in 0..bl {
                let orow = &mut out[(i0 + i) * wpr_out..(i0 + i + 1) * wpr_out];
                pack_threshold_row(&a[i * n..(i + 1) * n], m as i64, bias, thr, orow, n);
            }
            i0 += bl;
        }
    });
}

/// Masked fused linear+threshold over a contiguous row block (`valid` is
/// the precomputed popcount of the shared lane mask): same structure as
/// [`threshold_rows`] with the lane mask ANDed into the reduction.
fn threshold_masked_rows(
    x: &[u64],
    wpr: usize,
    w: &BitMatrix,
    lane_mask: &[u64],
    valid: i64,
    bias: Option<&BitMatrix>,
    thr: f32,
    out: &mut [u64],
    wpr_out: usize,
    n: usize,
) {
    let rows = out.len() / wpr_out;
    if rows == 0 {
        return;
    }
    let kk = simd::kernels();
    with_acc(ROW_BLOCK.min(rows) * n, |acc| {
        let mut i0 = 0usize;
        while i0 < rows {
            let bl = ROW_BLOCK.min(rows - i0);
            let a = &mut acc[..bl * n];
            if i0 > 0 {
                a.fill(0);
            }
            let xb = &x[i0 * wpr..(i0 + bl) * wpr];
            accum_counts(kk, xb, MaskK::Shared(lane_mask), wpr, bl, w, n, a);
            for i in 0..bl {
                let orow = &mut out[(i0 + i) * wpr_out..(i0 + i + 1) * wpr_out];
                pack_threshold_row(&a[i * n..(i + 1) * n], valid, bias, thr, orow, n);
            }
            i0 += bl;
        }
    });
}

/// G_X rows: `z` holds `out.len()/m` signal rows of width `n`; `w` is the
/// full weight matrix. Accumulates into a pre-zeroed output block via the
/// dispatched `axpy_pm1` (LUT scalar / 8-lane AVX2 — identical per-lane
/// IEEE ops, see [`simd`]); rows narrower than a vector's worth of words
/// inline the scalar path directly.
fn bwd_input_rows(w: &BitMatrix, z: &[f32], n: usize, out: &mut [f32], m: usize) {
    let rows = if n == 0 { 0 } else { z.len() / n };
    let kk = simd::kernels();
    let axpy = if kk.backend == Backend::Scalar || m < 64 {
        scalar::axpy_pm1
    } else {
        kk.axpy_pm1
    };
    for i in 0..rows {
        let zr = &z[i * n..(i + 1) * n];
        let orow = &mut out[i * m..(i + 1) * m];
        for (j, &zv) in zr.iter().enumerate() {
            if zv == 0.0 {
                continue;
            }
            axpy(orow, w.row(j), zv);
        }
    }
}

/// G_W rows: output units [j0, j0 + out.len()/m) of the (N × M) weight
/// vote. j-outer / k-inner: the accumulator row stays L1-resident while
/// the (much smaller) packed input rows stream through (§Perf). With
/// `mask`, lanes with mask bit 0 vote 0 (the 𝕄 zero). The per-row
/// update runs on the dispatched `axpy_pm1[_masked]`.
fn bwd_weight_rows(
    x: &BitMatrix,
    z: &[f32],
    n: usize,
    j0: usize,
    out: &mut [f32],
    m: usize,
    mask: Option<&BitMatrix>,
) {
    let rows = if m == 0 { 0 } else { out.len() / m };
    let b = x.rows;
    let kk = simd::kernels();
    let small = kk.backend == Backend::Scalar || m < 64;
    let axpy = if small { scalar::axpy_pm1 } else { kk.axpy_pm1 };
    let axpy_masked = if small { scalar::axpy_pm1_masked } else { kk.axpy_pm1_masked };
    for jj in 0..rows {
        let j = j0 + jj;
        let orow = &mut out[jj * m..(jj + 1) * m];
        for k in 0..b {
            let zv = z[k * n + j];
            if zv == 0.0 {
                continue;
            }
            match mask {
                None => axpy(orow, x.row(k), zv),
                Some(mk) => axpy_masked(orow, x.row(k), mk.row(k), zv),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_xnor_gemm(x: &BitMatrix, w: &BitMatrix) -> Tensor {
        let mut out = Tensor::zeros(&[x.rows, w.rows]);
        for i in 0..x.rows {
            for j in 0..w.rows {
                let mut s = 0i64;
                for k in 0..x.cols {
                    // xnor in the embedding: product of ±1
                    s += (x.pm1(i, k) * w.pm1(j, k)) as i64;
                }
                *out.at2_mut(i, j) = s as f32;
            }
        }
        out
    }

    /// Naive masked reference: the pre-blocking triple loop.
    fn naive_xnor_gemm_masked(x: &BitMatrix, w: &BitMatrix, mask: &BitMatrix) -> Tensor {
        let mut out = Tensor::zeros(&[x.rows, w.rows]);
        for i in 0..x.rows {
            for j in 0..w.rows {
                let mut s = 0i64;
                for k in 0..x.cols {
                    if mask.get(i, k) {
                        s += (x.pm1(i, k) * w.pm1(j, k)) as i64;
                    }
                }
                *out.at2_mut(i, j) = s as f32;
            }
        }
        out
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(1);
        for cols in [1, 63, 64, 65, 100, 128] {
            let m = BitMatrix::random(5, cols, &mut rng);
            let back = BitMatrix::from_pm1(&m.to_pm1());
            assert_eq!(m, back, "cols={cols}");
        }
    }

    #[test]
    fn xnor_gemm_matches_naive() {
        let mut rng = Rng::new(2);
        for (b, n, m) in [(3, 4, 5), (7, 9, 64), (5, 6, 65), (4, 3, 200)] {
            let x = BitMatrix::random(b, m, &mut rng);
            let w = BitMatrix::random(n, m, &mut rng);
            let fast = x.xnor_gemm(&w);
            let slow = naive_xnor_gemm(&x, &w);
            assert_eq!(fast, slow, "b={b} n={n} m={m}");
        }
    }

    #[test]
    fn xnor_gemm_matches_f32_matmul_via_embedding() {
        // Prop A.2: bit-level xnor-count == ±1 matmul, exactly.
        let mut rng = Rng::new(3);
        let x = BitMatrix::random(8, 77, &mut rng);
        let w = BitMatrix::random(6, 77, &mut rng);
        let bits = x.xnor_gemm(&w);
        let dense = x.to_pm1().matmul_bt(&w.to_pm1());
        assert!(bits.max_abs_diff(&dense) == 0.0);
    }

    #[test]
    fn masked_gemm_zero_mask_kills_everything() {
        let mut rng = Rng::new(4);
        let x = BitMatrix::random(3, 70, &mut rng);
        let w = BitMatrix::random(2, 70, &mut rng);
        let mask = BitMatrix::zeros(3, 70);
        let out = x.xnor_gemm_masked(&w, &mask);
        assert!(out.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn masked_gemm_full_mask_equals_unmasked() {
        let mut rng = Rng::new(5);
        let x = BitMatrix::random(4, 130, &mut rng);
        let w = BitMatrix::random(3, 130, &mut rng);
        let mut mask = BitMatrix::zeros(4, 130);
        for i in 0..4 {
            for j in 0..130 {
                mask.set(i, j, true);
            }
        }
        assert_eq!(x.xnor_gemm_masked(&w, &mask), x.xnor_gemm(&w));
    }

    /// The tiled masked GEMM against the naive per-bit reference:
    /// odd row counts (row-block tail), odd output counts, odd fan-in
    /// (tail word), and random masks.
    #[test]
    fn blocked_masked_gemm_matches_naive_reference() {
        let mut rng = Rng::new(31);
        for (b, n, m) in [(1, 1, 1), (3, 5, 70), (4, 4, 64), (7, 6, 130), (5, 9, 200)] {
            let x = BitMatrix::random(b, m, &mut rng);
            let w = BitMatrix::random(n, m, &mut rng);
            let mut mask = BitMatrix::zeros(b, m);
            for i in 0..b {
                for k in 0..m {
                    mask.set(i, k, rng.bernoulli(0.7));
                }
            }
            let fast = x.xnor_gemm_masked(&w, &mask);
            let slow = naive_xnor_gemm_masked(&x, &w, &mask);
            assert_eq!(fast, slow, "b={b} n={n} m={m}");
        }
    }

    #[test]
    fn masked_gemm_partial() {
        // Masked lanes behave like the 𝕄 zero: removing them changes the
        // count by exactly their ±1 contribution.
        let mut rng = Rng::new(6);
        let x = BitMatrix::random(1, 64, &mut rng);
        let w = BitMatrix::random(1, 64, &mut rng);
        let mut mask = BitMatrix::zeros(1, 64);
        for j in 0..64 {
            mask.set(0, j, true);
        }
        let full = x.xnor_gemm_masked(&w, &mask).data[0];
        mask.set(0, 17, false);
        let part = x.xnor_gemm_masked(&w, &mask).data[0];
        let contrib = x.pm1(0, 17) * w.pm1(0, 17);
        assert_eq!(part, full - contrib);
    }

    #[test]
    fn backward_input_matches_dense() {
        let mut rng = Rng::new(7);
        let w = BitMatrix::random(9, 83, &mut rng);
        let z = Tensor::randn(&[5, 9], 1.0, &mut rng);
        let fast = w.backward_input(&z);
        let dense = z.matmul(&w.to_pm1());
        assert!(fast.max_abs_diff(&dense) < 1e-4);
    }

    #[test]
    fn backward_weight_matches_dense() {
        let mut rng = Rng::new(8);
        let x = BitMatrix::random(5, 83, &mut rng);
        let z = Tensor::randn(&[5, 9], 1.0, &mut rng);
        let fast = x.backward_weight(&z);
        let dense = z.transpose2().matmul(&x.to_pm1());
        assert!(fast.max_abs_diff(&dense) < 1e-4);
    }

    #[test]
    fn backward_weight_masked_matches_dense_with_zeroed_lanes() {
        let mut rng = Rng::new(12);
        let x = BitMatrix::random(4, 70, &mut rng);
        let mut mask = BitMatrix::zeros(4, 70);
        for i in 0..4 {
            for j in 0..70 {
                mask.set(i, j, rng.bernoulli(0.8));
            }
        }
        let z = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let fast = x.backward_weight_masked(&z, &mask);
        // dense reference: embedded x with masked lanes set to 0
        let mut xd = x.to_pm1();
        for i in 0..4 {
            for j in 0..70 {
                if !mask.get(i, j) {
                    *xd.at2_mut(i, j) = 0.0;
                }
            }
        }
        let dense = z.transpose2().matmul(&xd);
        assert!(fast.max_abs_diff(&dense) < 1e-4);
    }

    #[test]
    fn xnor_threshold_matches_gemm_then_sign() {
        let mut rng = Rng::new(21);
        for (b, n, m) in [(3, 4, 5), (7, 65, 64), (5, 6, 130), (4, 64, 200)] {
            let x = BitMatrix::random(b, m, &mut rng);
            let w = BitMatrix::random(n, m, &mut rng);
            for thr in [0.0f32, 2.5, -3.0] {
                let fused = x.xnor_threshold(&w, None, thr);
                let s = x.xnor_gemm(&w);
                let want = BitMatrix::from_pm1(&s.map(|v| if v >= thr { 1.0 } else { -1.0 }));
                assert_eq!(fused, want, "b={b} n={n} m={m} thr={thr}");
            }
        }
    }

    #[test]
    fn xnor_threshold_bias_shifts_counts() {
        let mut rng = Rng::new(22);
        let x = BitMatrix::random(4, 70, &mut rng);
        let w = BitMatrix::random(9, 70, &mut rng);
        let bias = BitMatrix::random(1, 9, &mut rng);
        let fused = x.xnor_threshold(&w, Some(&bias), 0.0);
        let mut s = x.xnor_gemm(&w);
        for i in 0..4 {
            for j in 0..9 {
                *s.at2_mut(i, j) += bias.pm1(0, j);
            }
        }
        let want = BitMatrix::from_pm1(&s.sign_pm1());
        assert_eq!(fused, want);
    }

    #[test]
    fn xnor_threshold_masked_matches_per_row_masked_gemm() {
        let mut rng = Rng::new(23);
        let (b, n, m) = (5, 7, 100);
        let x = BitMatrix::random(b, m, &mut rng);
        let w = BitMatrix::random(n, m, &mut rng);
        // one lane mask shared by all rows
        let mut lane = BitMatrix::zeros(1, m);
        for j in 0..m {
            lane.set(0, j, rng.bernoulli(0.7));
        }
        let fused = x.xnor_threshold_masked(&w, lane.row(0), None, 0.0);
        // reference: replicate the lane mask per batch row
        let mut mask = BitMatrix::zeros(b, m);
        for i in 0..b {
            for j in 0..m {
                mask.set(i, j, lane.get(0, j));
            }
        }
        let want = BitMatrix::from_pm1(&x.xnor_gemm_masked(&w, &mask).sign_pm1());
        assert_eq!(fused, want);
    }

    /// The `_into` variants must reshape + fully overwrite a dirty reused
    /// buffer, leaving no stale content (the allocation-reuse contract the
    /// serving engine relies on).
    #[test]
    fn into_variants_overwrite_reused_buffers() {
        let mut rng = Rng::new(41);
        let x1 = BitMatrix::random(6, 100, &mut rng);
        let w1 = BitMatrix::random(9, 100, &mut rng);
        let x2 = BitMatrix::random(3, 70, &mut rng);
        let w2 = BitMatrix::random(5, 70, &mut rng);

        let mut t = Tensor::zeros(&[0]);
        x1.xnor_gemm_into(&w1, &mut t);
        assert_eq!(t, x1.xnor_gemm(&w1));
        x2.xnor_gemm_into(&w2, &mut t); // shrink, reuse
        assert_eq!(t, x2.xnor_gemm(&w2));

        let mut bm = BitMatrix::zeros(0, 0);
        x1.xnor_threshold_into(&w1, None, 0.0, &mut bm);
        assert_eq!(bm, x1.xnor_threshold(&w1, None, 0.0));
        x2.xnor_threshold_into(&w2, None, 0.0, &mut bm);
        assert_eq!(bm, x2.xnor_threshold(&w2, None, 0.0));

        let z1 = Tensor::randn(&[6, 9], 1.0, &mut rng);
        let z2 = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let mut g = Tensor::zeros(&[0]);
        x1.backward_weight_into(&z1, &mut g);
        assert_eq!(g, x1.backward_weight(&z1));
        x2.backward_weight_into(&z2, &mut g);
        assert_eq!(g, x2.backward_weight(&z2));

        w1.backward_input_into(&z1, &mut g);
        assert_eq!(g, w1.backward_input(&z1));
    }

    #[test]
    fn decode_pm1_row_matches_to_pm1() {
        let mut rng = Rng::new(24);
        for cols in [1, 8, 63, 64, 65, 96, 100] {
            let m = BitMatrix::random(3, cols, &mut rng);
            let dense = m.to_pm1();
            let mut buf = vec![0.0f32; cols];
            for r in 0..3 {
                m.decode_pm1_row(r, &mut buf);
                for c in 0..cols {
                    assert_eq!(buf[c], dense.at2(r, c), "cols={cols} r={r} c={c}");
                }
            }
        }
    }

    #[test]
    fn assign_packed_rows_gathers_and_masks_tail() {
        let mut rng = Rng::new(43);
        let src = BitMatrix::random(5, 70, &mut rng);
        let mut m = BitMatrix::zeros(0, 0);
        // gather rows 4, 0, 2 — with tail garbage injected into one row
        let dirty: Vec<u64> = vec![u64::MAX, u64::MAX];
        m.assign_packed_rows(70, [src.row(4), dirty.as_slice(), src.row(2)]);
        assert_eq!((m.rows, m.cols, m.wpr), (3, 70, 2));
        assert_eq!(m.row(0), src.row(4));
        assert_eq!(m.row(2), src.row(2));
        assert_eq!(m.row(1)[1] >> 6, 0, "tail beyond col 70 must be cleared");
    }

    #[test]
    fn clone_from_reuses_and_matches() {
        let mut rng = Rng::new(42);
        let src = BitMatrix::random(7, 130, &mut rng);
        let mut dst = BitMatrix::zeros(2, 5);
        dst.clone_from(&src);
        assert_eq!(dst, src);
    }

    #[test]
    fn from_words_clears_tail_garbage() {
        let words = vec![u64::MAX, u64::MAX];
        let m = BitMatrix::from_words(1, 70, words);
        assert_eq!(m.row(0)[1] >> 6, 0, "tail beyond col 70 must be clear");
        assert_eq!(m.count_ones(), 70);
    }

    #[test]
    fn flip_changes_exactly_one_bit() {
        let mut rng = Rng::new(9);
        let m0 = BitMatrix::random(4, 100, &mut rng);
        let mut m = m0.clone();
        m.flip(2, 99);
        assert_eq!(m.hamming(&m0), 1);
        assert_eq!(m.get(2, 99), !m0.get(2, 99));
    }

    #[test]
    fn tail_bits_stay_clear() {
        let mut rng = Rng::new(10);
        let m = BitMatrix::random(3, 65, &mut rng);
        for r in 0..3 {
            let last = m.row(r)[1];
            assert_eq!(last >> 1, 0, "tail garbage in row {r}");
        }
    }
}
