//! Runtime-dispatched SIMD kernel backend + aligned packed storage
//! (DESIGN.md §SIMD-Backend).
//!
//! Every hot bit-kernel in the crate — the six packed [`BitMatrix`]
//! kernels, the graph executor's threshold re-pack and the
//! [`crate::optim::BooleanOptimizer`] flip-mask scan — routes its inner
//! loop through the [`Kernels`] dispatch table returned by [`kernels`].
//! The table is selected **once** per process:
//!
//! * `x86_64` with AVX2 detected → vpshufb-LUT popcount with a
//!   Harley–Seal carry-save reduction over 256-bit lanes (4 words per
//!   vector, 64 words per CSA block);
//! * `aarch64` → NEON `vcntq_u8` byte-popcount for the popcount family
//!   (the f32 kernels stay scalar there);
//! * anywhere else, or `BOLD_SIMD=scalar` → the portable [`scalar`]
//!   reference backend.
//!
//! `BOLD_SIMD={auto,scalar}` is the supported contract (`avx2`/`neon`
//! force a specific backend when the CPU has it, else fall back to
//! scalar). Results are **bit-exact across backends**: the popcount
//! kernels sum integers (order-independent), and the f32 kernels
//! (`axpy_pm1*`, `cmp_mask64`, `flip_scan_word`) perform the identical
//! IEEE operations in the identical per-lane order as the scalar
//! reference — no FMA contraction, no reassociation — so
//! `tests/simd_parity.rs` can assert equality to the last bit for every
//! routed kernel. (The masked axpy matches scalar for all finite
//! signals; like the scalar LUT path it multiplies by a 0.0/1.0 mask.)
//!
//! [`AlignedWords`] is the storage side of the contract: `BitMatrix`
//! word buffers are 64-byte aligned (cache line / full vector width), so
//! streaming loads never straddle a line at the buffer base. Kernels
//! still use unaligned loads — a row starts at `r·wpr` words, which is
//! not a vector boundary for odd `wpr` — but the aligned, block-sized
//! allocation keeps split-line accesses rare and leaves the door open
//! for aligned-load fast paths.
//!
//! [`BitMatrix`]: crate::tensor::BitMatrix

use std::cell::Cell;
use std::sync::OnceLock;

// ---------------------------------------------------------------------------
// aligned storage
// ---------------------------------------------------------------------------

/// Words per 64-byte alignment block.
const BLOCK_WORDS: usize = 8;

/// One cache-line-sized, cache-line-aligned chunk of packed words. The
/// field is only ever read through the `Deref` pointer cast, which the
/// dead-code analysis cannot see.
#[derive(Clone, Copy)]
#[repr(C, align(64))]
struct Block(#[allow(dead_code)] [u64; BLOCK_WORDS]);

/// A `Vec<u64>`-like buffer whose base address is 64-byte aligned: the
/// backing store of [`crate::tensor::BitMatrix`]. Dereferences to
/// `[u64]`, so slice reads/writes, `iter()`, `copy_from_slice` and
/// indexing all work as before; the handful of growth methods mirror
/// their `Vec` counterparts. Equality and `Debug` see exactly the
/// `len()` live words (capacity padding is ignored).
pub struct AlignedWords {
    blocks: Vec<Block>,
    len: usize,
}

impl AlignedWords {
    pub fn new() -> Self {
        AlignedWords { blocks: Vec::new(), len: 0 }
    }

    /// `n` words, all zero.
    pub fn zeroed(n: usize) -> Self {
        AlignedWords { blocks: vec![Block([0; BLOCK_WORDS]); n.div_ceil(BLOCK_WORDS)], len: n }
    }

    /// Grow the block store so at least `n` words are addressable.
    fn reserve_words(&mut self, n: usize) {
        let blocks = n.div_ceil(BLOCK_WORDS);
        if blocks > self.blocks.len() {
            self.blocks.resize(blocks, Block([0; BLOCK_WORDS]));
        }
    }

    /// `Vec::resize` semantics: existing words keep their values, new
    /// words (including stale capacity words) are set to `v`.
    pub fn resize(&mut self, n: usize, v: u64) {
        let old = self.len;
        if n > old {
            self.reserve_words(n);
            self.len = n;
            self[old..n].fill(v);
        } else {
            self.len = n;
        }
    }

    /// Drop all words, keeping the allocation.
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Append a slice of words (`Vec::extend_from_slice`).
    pub fn extend_from_slice(&mut self, s: &[u64]) {
        let old = self.len;
        let n = old + s.len();
        self.reserve_words(n);
        self.len = n;
        self[old..n].copy_from_slice(s);
    }
}

impl Default for AlignedWords {
    fn default() -> Self {
        Self::new()
    }
}

impl std::ops::Deref for AlignedWords {
    type Target = [u64];
    fn deref(&self) -> &[u64] {
        // SAFETY: `blocks` owns `blocks.len()·BLOCK_WORDS ≥ len`
        // contiguous, initialised u64s ([u64; 8] in a repr(C) wrapper has
        // plain array layout); an empty Vec's dangling pointer is valid
        // for a zero-length slice.
        unsafe { std::slice::from_raw_parts(self.blocks.as_ptr() as *const u64, self.len) }
    }
}

impl std::ops::DerefMut for AlignedWords {
    fn deref_mut(&mut self) -> &mut [u64] {
        // SAFETY: as in `deref`, plus `&mut self` gives unique access.
        unsafe { std::slice::from_raw_parts_mut(self.blocks.as_mut_ptr() as *mut u64, self.len) }
    }
}

impl Clone for AlignedWords {
    fn clone(&self) -> Self {
        AlignedWords { blocks: self.blocks.clone(), len: self.len }
    }

    /// Reuses the existing block allocation (the layer caches rely on
    /// `BitMatrix::clone_from` staying allocation-free at steady state).
    fn clone_from(&mut self, src: &Self) {
        self.blocks.clone_from(&src.blocks);
        self.len = src.len;
    }
}

impl PartialEq for AlignedWords {
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

impl Eq for AlignedWords {}

impl std::fmt::Debug for AlignedWords {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(&**self, f)
    }
}

impl From<Vec<u64>> for AlignedWords {
    fn from(v: Vec<u64>) -> Self {
        let mut a = AlignedWords::new();
        a.extend_from_slice(&v);
        a
    }
}

impl<'a> IntoIterator for &'a AlignedWords {
    type Item = &'a u64;
    type IntoIter = std::slice::Iter<'a, u64>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

// ---------------------------------------------------------------------------
// dispatch
// ---------------------------------------------------------------------------

/// Kernel backend identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Portable reference implementation (always available).
    Scalar,
    /// x86_64 AVX2: vpshufb-LUT + Harley–Seal popcount, 8-lane f32 ops.
    Avx2,
    /// aarch64 NEON `vcntq_u8` popcount family (f32 kernels stay scalar).
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

/// The dispatch table: one entry per primitive the routed kernels need.
/// Entries are plain `fn` pointers selected once (see [`kernels`]); the
/// kernel cores hoist the table lookup out of their inner loops.
pub struct Kernels {
    pub backend: Backend,
    /// Σ popcount(a\[i\] ^ b\[i\]) over equal-length slices.
    pub xor_popcnt: fn(&[u64], &[u64]) -> u64,
    /// Σ popcount((a\[i\] ^ b\[i\]) & m\[i\]).
    pub xor_and_popcnt: fn(&[u64], &[u64], &[u64]) -> u64,
    /// Σ popcount(a\[i\]).
    pub popcnt: fn(&[u64]) -> u64,
    /// out\[k\] += zv · e(bit k) for one packed row (e = ±1 embedding).
    pub axpy_pm1: fn(&mut [f32], &[u64], f32),
    /// out\[k\] += zv · e(bit k) · mask_k (mask bit 0 ⇒ lane adds ±0).
    pub axpy_pm1_masked: fn(&mut [f32], &[u64], &[u64], f32),
    /// Bit i of the result = `data[i] >= thr` (or `<=` when flipped),
    /// for up to 64 contiguous f32 values; unused high bits are 0.
    pub cmp_mask64: fn(&[f32], f32, bool) -> u64,
    /// One 64-lane Boolean-optimizer word scan (Eq. 9–10): per lane
    /// `m = β·accum + η·grad` (then optional ±κ clamp), flip when
    /// xnor(m, w) holds with |m| ≥ 1; writes the updated accumulator
    /// (0.0 at flipped lanes) and returns the flip mask. `grad.len()`
    /// (= `accum.len()` ≤ 64) selects the live lanes of `word`.
    pub flip_scan_word: fn(u64, &[f32], &mut [f32], f32, f32, Option<f32>) -> u64,
}

static SCALAR: Kernels = Kernels {
    backend: Backend::Scalar,
    xor_popcnt: scalar::xor_popcnt,
    xor_and_popcnt: scalar::xor_and_popcnt,
    popcnt: scalar::popcnt,
    axpy_pm1: scalar::axpy_pm1,
    axpy_pm1_masked: scalar::axpy_pm1_masked,
    cmp_mask64: scalar::cmp_mask64,
    flip_scan_word: scalar::flip_scan_word,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    backend: Backend::Avx2,
    xor_popcnt: avx2::xor_popcnt,
    xor_and_popcnt: avx2::xor_and_popcnt,
    popcnt: avx2::popcnt,
    axpy_pm1: avx2::axpy_pm1,
    axpy_pm1_masked: avx2::axpy_pm1_masked,
    cmp_mask64: avx2::cmp_mask64,
    flip_scan_word: avx2::flip_scan_word,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    backend: Backend::Neon,
    xor_popcnt: neon::xor_popcnt,
    xor_and_popcnt: neon::xor_and_popcnt,
    popcnt: neon::popcnt,
    // The popcount family dominates the routed kernels; the f32
    // primitives use the portable path on aarch64 (still bit-exact).
    axpy_pm1: scalar::axpy_pm1,
    axpy_pm1_masked: scalar::axpy_pm1_masked,
    cmp_mask64: scalar::cmp_mask64,
    flip_scan_word: scalar::flip_scan_word,
};

/// Table for an explicitly requested backend, if this CPU supports it.
fn table_for(b: Backend) -> Option<&'static Kernels> {
    match b {
        Backend::Scalar => Some(&SCALAR),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => {
            if std::is_x86_feature_detected!("avx2") {
                Some(&AVX2)
            } else {
                None
            }
        }
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => Some(&NEON),
        #[allow(unreachable_patterns)] // foreign-arch variants remain
        _ => None,
    }
}

/// Best backend this CPU supports (ignores the env override).
fn best() -> &'static Kernels {
    #[cfg(target_arch = "x86_64")]
    if let Some(t) = table_for(Backend::Avx2) {
        return t;
    }
    #[cfg(target_arch = "aarch64")]
    if let Some(t) = table_for(Backend::Neon) {
        return t;
    }
    &SCALAR
}

/// Process-wide table: `BOLD_SIMD` read once (`scalar` forces the
/// portable path for A/B and determinism runs; `auto`/unset picks the
/// best detected backend; an explicit `avx2`/`neon` the CPU lacks falls
/// back to scalar).
fn global() -> &'static Kernels {
    static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("BOLD_SIMD").ok().as_deref().map(str::trim) {
        Some("scalar") => &SCALAR,
        Some("avx2") => table_for(Backend::Avx2).unwrap_or(&SCALAR),
        Some("neon") => table_for(Backend::Neon).unwrap_or(&SCALAR),
        _ => best(),
    })
}

thread_local! {
    static OVERRIDE: Cell<Option<&'static Kernels>> = const { Cell::new(None) };
}

/// The active dispatch table: the innermost [`with_backend`] override on
/// this thread, else the process-wide selection. Kernel cores call this
/// once per invocation and use the returned table in their loops.
pub fn kernels() -> &'static Kernels {
    OVERRIDE.with(|o| o.get()).unwrap_or_else(global)
}

/// The active backend (what [`kernels`] dispatches to).
pub fn active() -> Backend {
    kernels().backend
}

/// Name of the active backend (for bench JSON / logs).
pub fn backend_name() -> &'static str {
    active().name()
}

/// Best backend the CPU supports, independent of `BOLD_SIMD` — what
/// `auto` would pick (the A/B partner of [`Backend::Scalar`] in the
/// parity suite and benches).
pub fn auto_backend() -> Backend {
    best().backend
}

/// Whether `b` can run on this CPU.
pub fn supported(b: Backend) -> bool {
    table_for(b).is_some()
}

/// Run `f` with kernels dispatched to `b` **on this thread** (panics if
/// the CPU lacks `b`). Test/bench hook, mirroring
/// [`crate::util::pool::with_thread_budget`]: pool shards run on worker
/// threads that keep the process-wide backend, so force
/// `with_thread_budget(1, ..)` when a single backend must cover the
/// whole computation. (Mixing backends across shards is still bit-exact
/// — that is the point of the parity suite — but A/B timing wants one.)
pub fn with_backend<R>(b: Backend, f: impl FnOnce() -> R) -> R {
    let table = table_for(b)
        .unwrap_or_else(|| panic!("SIMD backend {:?} is not supported on this CPU", b));
    struct Restore(Option<&'static Kernels>);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.with(|o| o.set(self.0));
        }
    }
    let _restore = Restore(OVERRIDE.with(|o| o.replace(Some(table))));
    f()
}

// ---------------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------------

/// OR `len ≤ 64` result bits (`w`, low bits) into `out` at row-local bit
/// offset `pos`. `out` must be pre-zeroed over the target range and `w`
/// must be zero above bit `len` (a straddling write ORs the whole word).
/// Shared by the threshold re-pack below and the graph executor's LUT
/// output writes.
#[inline]
pub fn deposit(out: &mut [u64], pos: usize, w: u64, len: usize) {
    if len == 0 {
        return;
    }
    let wi = pos / 64;
    let off = pos % 64;
    out[wi] |= w << off;
    if off != 0 && off + len > 64 {
        out[wi + 1] |= w >> (64 - off);
    }
}

/// Pack `data[i] >= thr` (or `<=` when `flip`) into `out` starting at
/// bit offset `bit0`, via the active backend's [`Kernels::cmp_mask64`].
/// `out` must be pre-zeroed over `[bit0, bit0 + data.len())` — the
/// executor's `zero_resize`d activation rows satisfy this. This is the
/// graph executor's threshold re-pack primitive (f32 counts → bits).
pub fn pack_cmp_into(out: &mut [u64], bit0: usize, data: &[f32], thr: f32, flip: bool) {
    let cmp = kernels().cmp_mask64;
    let mut pos = bit0;
    for chunk in data.chunks(64) {
        deposit(out, pos, cmp(chunk, thr, flip), chunk.len());
        pos += chunk.len();
    }
}

// ---------------------------------------------------------------------------
// LUT-folding primitives (DESIGN.md §LUT-Folding)
// ---------------------------------------------------------------------------
//
// The truth-table evaluation of a low-fan-in Boolean neuron is pure
// word-wide logic (AND/XOR mux folding) with no arithmetic to vectorise
// differently per ISA, so — unlike the popcount family — one portable
// implementation IS the reference for every backend. It lives here,
// alongside the dispatch table, so the AVX2/NEON/scalar paths all route
// through the identical code and the bit-exactness contract holds by
// construction.

/// Gather one input bit-column across up to 64 consecutive packed rows:
/// bit `l` of the result is bit `col` of row `row0 + l`. `words` is a
/// row-major packed matrix with `wpr` words per row. Lanes `≥ nrows` are
/// zero.
#[inline]
pub fn gather_bit_column(words: &[u64], wpr: usize, row0: usize, nrows: usize, col: usize) -> u64 {
    debug_assert!(nrows <= 64);
    let (wi, off) = (col / 64, col % 64);
    let mut out = 0u64;
    let mut base = row0 * wpr + wi;
    for l in 0..nrows {
        out |= ((words[base] >> off) & 1) << l;
        base += wpr;
    }
    out
}

/// Bitsliced truth-table evaluation for 64 lanes at once: lane `l` of
/// the result is bit `idx(l)` of `table`, where `idx(l) = Σ_i
/// (cols[i] >> l & 1) << i` — i.e. each lane independently indexes the
/// `2^fanin`-bit table with its own gathered input bits.
///
/// The evaluation is the standard bitslice mux cascade: level 0 seeds
/// `2^(fanin-1)` words from adjacent table-bit pairs selected by
/// `cols[0]` (constants broadcast to all-0/all-1 words, so the four
/// pair cases collapse to `0`, `!0`, `cols[0]`, `!cols[0]`), then each
/// further level halves the word count with `mux(a, b, s) = a ^ ((a ^
/// b) & s)`. No per-lane branching anywhere.
///
/// `table` holds at least `max(1, 2^fanin / 64)` words (LSB-first bit
/// order); `buf` is caller scratch of at least `2^(fanin-1)` words
/// (1 for fanin ≤ 1).
#[inline]
pub fn lut_eval_word(table: &[u64], fanin: usize, cols: &[u64], buf: &mut [u64]) -> u64 {
    debug_assert!(cols.len() >= fanin);
    let bit = |i: usize| (table[i / 64] >> (i % 64)) & 1;
    if fanin == 0 {
        return 0u64.wrapping_sub(bit(0));
    }
    let half = 1usize << (fanin - 1);
    debug_assert!(buf.len() >= half);
    let c0 = cols[0];
    for (j, b) in buf.iter_mut().take(half).enumerate() {
        let a = 0u64.wrapping_sub(bit(2 * j));
        let bb = 0u64.wrapping_sub(bit(2 * j + 1));
        *b = a ^ ((a ^ bb) & c0);
    }
    for (i, &sel) in cols.iter().enumerate().take(fanin).skip(1) {
        let width = 1usize << (fanin - 1 - i);
        for j in 0..width {
            let (a, b) = (buf[2 * j], buf[2 * j + 1]);
            buf[j] = a ^ ((a ^ b) & sel);
        }
    }
    buf[0]
}

/// In-place 64×64 bit-matrix transpose (recursive block swap, Hacker's
/// Delight §7-3 adapted to LSB-first columns): bit `c` of word `r`
/// swaps with bit `r` of word `c`. The graph executor uses this to turn
/// 64 per-neuron LUT eval words (lane = batch row) into 64 row-major
/// output words.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j = 32usize;
    let mut m: u64 = 0xFFFF_FFFF_0000_0000;
    while j != 0 {
        let mut k = 0usize;
        while k < 64 {
            let t = (a[k] ^ (a[k + j] << j)) & m;
            a[k] ^= t;
            a[k + j] ^= t >> j;
            k = ((k | j) + 1) & !j;
        }
        j >>= 1;
        m ^= m >> j;
    }
}

// ---------------------------------------------------------------------------
// scalar backend (the portable reference all others must match)
// ---------------------------------------------------------------------------

/// Portable reference backend. `pub` so kernel cores can inline these
/// directly on their small-operand fast paths (a `fn`-pointer call per
/// handful of words would cost more than the work) — the dispatch table
/// is the route for everything large enough to vectorise.
pub mod scalar {
    /// Byte → 8-lane ±1 pattern (bit=1 ↦ +1). 8 KiB, cache-resident.
    static PM1_LUT: [[f32; 8]; 256] = {
        let mut lut = [[0.0f32; 8]; 256];
        let mut b = 0usize;
        while b < 256 {
            let mut k = 0usize;
            while k < 8 {
                lut[b][k] = if (b >> k) & 1 == 1 { 1.0 } else { -1.0 };
                k += 1;
            }
            b += 1;
        }
        lut
    };

    /// Byte → 8-lane 0/1 mask pattern (for the 𝕄-zero masked variants).
    static BIT_LUT: [[f32; 8]; 256] = {
        let mut lut = [[0.0f32; 8]; 256];
        let mut b = 0usize;
        while b < 256 {
            let mut k = 0usize;
            while k < 8 {
                lut[b][k] = ((b >> k) & 1) as f32;
                k += 1;
            }
            b += 1;
        }
        lut
    };

    /// 4-way unrolled XOR+popcount reduction: four independent counter
    /// chains keep the popcount ALU busy (the ILP the old hand-blocked
    /// GEMM got from interleaving four output cells).
    #[inline]
    pub fn xor_popcnt(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let (mut c0, mut c1, mut c2, mut c3) = (0u64, 0u64, 0u64, 0u64);
        let mut i = 0usize;
        while i + 4 <= n {
            c0 += (a[i] ^ b[i]).count_ones() as u64;
            c1 += (a[i + 1] ^ b[i + 1]).count_ones() as u64;
            c2 += (a[i + 2] ^ b[i + 2]).count_ones() as u64;
            c3 += (a[i + 3] ^ b[i + 3]).count_ones() as u64;
            i += 4;
        }
        while i < n {
            c0 += (a[i] ^ b[i]).count_ones() as u64;
            i += 1;
        }
        c0 + c1 + c2 + c3
    }

    /// Masked XOR+popcount: Σ popcount((a ^ b) & m).
    #[inline]
    pub fn xor_and_popcnt(a: &[u64], b: &[u64], m: &[u64]) -> u64 {
        debug_assert!(a.len() == b.len() && a.len() == m.len());
        let n = a.len();
        let (mut c0, mut c1) = (0u64, 0u64);
        let mut i = 0usize;
        while i + 2 <= n {
            c0 += ((a[i] ^ b[i]) & m[i]).count_ones() as u64;
            c1 += ((a[i + 1] ^ b[i + 1]) & m[i + 1]).count_ones() as u64;
            i += 2;
        }
        if i < n {
            c0 += ((a[i] ^ b[i]) & m[i]).count_ones() as u64;
        }
        c0 + c1
    }

    /// Plain popcount reduction.
    #[inline]
    pub fn popcnt(a: &[u64]) -> u64 {
        a.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// out\[k\] += zv · e(bits) for one packed row, via the byte LUT.
    pub fn axpy_pm1(out: &mut [f32], words: &[u64], zv: f32) {
        let len = out.len();
        let mut lane = 0usize;
        'words: for &word in words {
            let bytes = word.to_le_bytes();
            for &byte in &bytes {
                let pat = &PM1_LUT[byte as usize];
                if lane + 8 <= len {
                    let o = &mut out[lane..lane + 8];
                    for k in 0..8 {
                        o[k] += zv * pat[k];
                    }
                } else {
                    for k in 0..len - lane {
                        out[lane + k] += zv * pat[k];
                    }
                    break 'words;
                }
                lane += 8;
            }
        }
    }

    /// out\[k\] += zv · e(bits)·mask for one packed row (masked lanes
    /// add ±0, exactly like multiplying by the 0.0 LUT entry).
    pub fn axpy_pm1_masked(out: &mut [f32], words: &[u64], mask: &[u64], zv: f32) {
        let len = out.len();
        let mut lane = 0usize;
        'words: for (&word, &mword) in words.iter().zip(mask) {
            let wb = word.to_le_bytes();
            let mb = mword.to_le_bytes();
            for (&byte, &mbyte) in wb.iter().zip(&mb) {
                let pat = &PM1_LUT[byte as usize];
                let mpat = &BIT_LUT[mbyte as usize];
                if lane + 8 <= len {
                    let o = &mut out[lane..lane + 8];
                    for k in 0..8 {
                        o[k] += zv * pat[k] * mpat[k];
                    }
                } else {
                    for k in 0..len - lane {
                        out[lane + k] += zv * pat[k] * mpat[k];
                    }
                    break 'words;
                }
                lane += 8;
            }
        }
    }

    /// out\[k\] = e(bit k): decode one packed row into a ±1 buffer via
    /// the byte LUT (the FP head's streaming decode).
    pub fn decode_pm1(out: &mut [f32], words: &[u64]) {
        let len = out.len();
        let mut lane = 0usize;
        'words: for &word in words {
            for &byte in &word.to_le_bytes() {
                let pat = &PM1_LUT[byte as usize];
                if lane + 8 <= len {
                    out[lane..lane + 8].copy_from_slice(pat);
                } else {
                    for k in 0..len - lane {
                        out[lane + k] = pat[k];
                    }
                    break 'words;
                }
                lane += 8;
            }
        }
    }

    /// Bit i = `data[i] >= thr` (`<=` when `flip`); i < 64.
    #[inline]
    pub fn cmp_mask64(data: &[f32], thr: f32, flip: bool) -> u64 {
        debug_assert!(data.len() <= 64);
        let mut w = 0u64;
        if flip {
            for (i, &v) in data.iter().enumerate() {
                if v <= thr {
                    w |= 1u64 << i;
                }
            }
        } else {
            for (i, &v) in data.iter().enumerate() {
                if v >= thr {
                    w |= 1u64 << i;
                }
            }
        }
        w
    }

    /// The Eq. 9–10 word scan (see [`super::Kernels::flip_scan_word`]).
    pub fn flip_scan_word(
        word: u64,
        grad: &[f32],
        accum: &mut [f32],
        beta: f32,
        lr: f32,
        clip: Option<f32>,
    ) -> u64 {
        debug_assert!(grad.len() <= 64 && grad.len() == accum.len());
        let mut mask = 0u64;
        for lane in 0..grad.len() {
            // m ← β·m + η·q  (Eq. 10)
            let mut m = beta * accum[lane] + lr * grad[lane];
            if let Some(k) = clip {
                m = m.clamp(-k, k);
            }
            // Eq. (9): flip when xnor(m, w) = T with |m| ≥ 1 —
            // i.e. m ≥ 1 on set bits (w=+1), m ≤ −1 on clear bits.
            let set = (word >> lane) & 1 == 1;
            if (set && m >= 1.0) || (!set && m <= -1.0) {
                mask |= 1u64 << lane;
                accum[lane] = 0.0; // reset (Algorithm 1 l.12)
            } else {
                accum[lane] = m;
            }
        }
        mask
    }
}

// ---------------------------------------------------------------------------
// AVX2 backend (x86_64, runtime-detected)
// ---------------------------------------------------------------------------

/// AVX2 implementations. Every `pub fn` here is a safe wrapper whose
/// inner `#[target_feature(enable = "avx2")]` body is only reachable
/// when this table was installed, i.e. after `is_x86_feature_detected!`
/// succeeded — that detection is the safety argument for each wrapper.
///
/// The popcount family uses the vpshufb nibble-LUT byte popcount
/// (`popcnt256`) with a Harley–Seal carry-save adder cascade over blocks
/// of 16 × 256-bit vectors (64 words): the CSA defers the byte-popcount
/// to one in sixteen vectors, counting ~4 words per cycle. All integer,
/// so any split (block / vector / scalar tail) is bit-exact.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::scalar;
    use core::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn loadu(p: &[u64], i: usize) -> __m256i {
        debug_assert!(i + 4 <= p.len());
        _mm256_loadu_si256(p.as_ptr().add(i) as *const __m256i)
    }

    /// Per-64-bit-lane byte popcount of `v` (Mula's vpshufb algorithm):
    /// nibble LUT lookups summed with `vpsadbw` into 4 u64 lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt256(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low_mask = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi32::<4>(v), low_mask);
        let cnt =
            _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt, _mm256_setzero_si256())
    }

    /// Carry-save adder: bitwise full add of (a, b, c) → (carry, sum).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn csa(a: __m256i, b: __m256i, c: __m256i) -> (__m256i, __m256i) {
        let u = _mm256_xor_si256(a, b);
        (_mm256_or_si256(_mm256_and_si256(a, b), _mm256_and_si256(u, c)), _mm256_xor_si256(u, c))
    }

    /// Sum of the 4 u64 lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum64(v: __m256i) -> u64 {
        let s = _mm_add_epi64(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
        let s = _mm_add_epi64(s, _mm_unpackhi_epi64(s, s));
        _mm_cvtsi128_si64(s) as u64
    }

    /// Harley–Seal accumulator state across 16-vector blocks.
    struct Hs {
        ones: __m256i,
        twos: __m256i,
        fours: __m256i,
        eights: __m256i,
        /// Σ popcnt256(sixteens) so far (units of 16 bits each).
        total: __m256i,
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hs_new() -> Hs {
        let z = _mm256_setzero_si256();
        Hs { ones: z, twos: z, fours: z, eights: z, total: z }
    }

    /// Fold one block of 16 combined vectors into the CSA cascade.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hs_block(st: &mut Hs, v: &[__m256i; 16]) {
        let (ta, o) = csa(st.ones, v[0], v[1]);
        st.ones = o;
        let (tb, o) = csa(st.ones, v[2], v[3]);
        st.ones = o;
        let (fa, t) = csa(st.twos, ta, tb);
        st.twos = t;
        let (ta, o) = csa(st.ones, v[4], v[5]);
        st.ones = o;
        let (tb, o) = csa(st.ones, v[6], v[7]);
        st.ones = o;
        let (fb, t) = csa(st.twos, ta, tb);
        st.twos = t;
        let (ea, f) = csa(st.fours, fa, fb);
        st.fours = f;
        let (ta, o) = csa(st.ones, v[8], v[9]);
        st.ones = o;
        let (tb, o) = csa(st.ones, v[10], v[11]);
        st.ones = o;
        let (fa, t) = csa(st.twos, ta, tb);
        st.twos = t;
        let (ta, o) = csa(st.ones, v[12], v[13]);
        st.ones = o;
        let (tb, o) = csa(st.ones, v[14], v[15]);
        st.ones = o;
        let (fb, t) = csa(st.twos, ta, tb);
        st.twos = t;
        let (eb, f) = csa(st.fours, fa, fb);
        st.fours = f;
        let (sixteens, e) = csa(st.eights, ea, eb);
        st.eights = e;
        st.total = _mm256_add_epi64(st.total, popcnt256(sixteens));
    }

    /// Weighted drain of the CSA counters: 16·total + 8·eights + … .
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hs_finish(st: &Hs) -> u64 {
        let mut t = _mm256_slli_epi64::<4>(st.total);
        t = _mm256_add_epi64(t, _mm256_slli_epi64::<3>(popcnt256(st.eights)));
        t = _mm256_add_epi64(t, _mm256_slli_epi64::<2>(popcnt256(st.fours)));
        t = _mm256_add_epi64(t, _mm256_slli_epi64::<1>(popcnt256(st.twos)));
        t = _mm256_add_epi64(t, popcnt256(st.ones));
        hsum64(t)
    }

    /// The three popcount reductions share this skeleton; `combine`
    /// differs only in how a 4-word vector is formed from the operands.
    macro_rules! hs_reduce {
        ($name:ident, ($($arg:ident),+), $lead:ident, |$i:ident| $combine:expr, |$j:ident| $tail:expr) => {
            #[target_feature(enable = "avx2")]
            unsafe fn $name($($arg: &[u64]),+) -> u64 {
                let n = $lead.len();
                let mut st = hs_new();
                let mut buf = [_mm256_setzero_si256(); 16];
                let mut i = 0usize;
                while i + 64 <= n {
                    for k in 0..16 {
                        let $i = i + 4 * k;
                        buf[k] = $combine;
                    }
                    hs_block(&mut st, &buf);
                    i += 64;
                }
                let mut extra = _mm256_setzero_si256();
                while i + 4 <= n {
                    let $i = i;
                    extra = _mm256_add_epi64(extra, popcnt256($combine));
                    i += 4;
                }
                let mut total = hs_finish(&st) + hsum64(extra);
                while i < n {
                    let $j = i;
                    total += ($tail).count_ones() as u64;
                    i += 1;
                }
                total
            }
        };
    }

    hs_reduce!(xor_popcnt_imp, (a, b), a,
        |i| _mm256_xor_si256(loadu(a, i), loadu(b, i)),
        |j| (a[j] ^ b[j]));
    hs_reduce!(xor_and_popcnt_imp, (a, b, m), a,
        |i| _mm256_and_si256(_mm256_xor_si256(loadu(a, i), loadu(b, i)), loadu(m, i)),
        |j| ((a[j] ^ b[j]) & m[j]));
    hs_reduce!(popcnt_imp, (a), a, |i| loadu(a, i), |j| a[j]);

    pub fn xor_popcnt(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        // SAFETY: table installed only after AVX2 detection (module docs).
        unsafe { xor_popcnt_imp(a, b) }
    }

    pub fn xor_and_popcnt(a: &[u64], b: &[u64], m: &[u64]) -> u64 {
        debug_assert!(a.len() == b.len() && a.len() == m.len());
        // SAFETY: table installed only after AVX2 detection.
        unsafe { xor_and_popcnt_imp(a, b, m) }
    }

    pub fn popcnt(a: &[u64]) -> u64 {
        // SAFETY: table installed only after AVX2 detection.
        unsafe { popcnt_imp(a) }
    }

    /// 8 sign lanes from one bit byte: all-ones where the bit is SET.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn expand_byte(byte: u8) -> __m256i {
        let pos = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
        let b = _mm256_set1_epi32(byte as i32);
        _mm256_cmpeq_epi32(_mm256_and_si256(b, pos), pos)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn axpy_pm1_imp(out: &mut [f32], words: &[u64], zv: f32) {
        let len = out.len();
        let zv_v = _mm256_set1_ps(zv);
        let one = _mm256_set1_ps(1.0);
        let sign = _mm256_set1_epi32(i32::MIN);
        let mut lane = 0usize;
        while lane + 8 <= len {
            let byte = ((words[lane / 64] >> (lane % 64)) & 0xff) as u8;
            let setm = expand_byte(byte);
            // pat = ±1.0: flip the sign bit of 1.0 where the bit is clear
            let pat = _mm256_xor_ps(one, _mm256_castsi256_ps(_mm256_andnot_si256(setm, sign)));
            let o = out.as_mut_ptr().add(lane);
            // identical arithmetic to the scalar LUT path: o += zv·(±1)
            _mm256_storeu_ps(o, _mm256_add_ps(_mm256_loadu_ps(o), _mm256_mul_ps(zv_v, pat)));
            lane += 8;
        }
        if lane < len {
            axpy_tail(&mut out[lane..], words, lane, zv, None);
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn axpy_pm1_masked_imp(out: &mut [f32], words: &[u64], mask: &[u64], zv: f32) {
        let len = out.len();
        let zv_v = _mm256_set1_ps(zv);
        let one = _mm256_set1_ps(1.0);
        let sign = _mm256_set1_epi32(i32::MIN);
        let mut lane = 0usize;
        while lane + 8 <= len {
            let wbyte = ((words[lane / 64] >> (lane % 64)) & 0xff) as u8;
            let mbyte = ((mask[lane / 64] >> (lane % 64)) & 0xff) as u8;
            let pat = _mm256_xor_ps(
                one,
                _mm256_castsi256_ps(_mm256_andnot_si256(expand_byte(wbyte), sign)),
            );
            // mpat = 1.0 / +0.0, multiplied exactly like the scalar LUT:
            // (zv·pat)·mpat
            let mpat = _mm256_and_ps(one, _mm256_castsi256_ps(expand_byte(mbyte)));
            let o = out.as_mut_ptr().add(lane);
            let addend = _mm256_mul_ps(_mm256_mul_ps(zv_v, pat), mpat);
            _mm256_storeu_ps(o, _mm256_add_ps(_mm256_loadu_ps(o), addend));
            lane += 8;
        }
        if lane < len {
            axpy_tail(&mut out[lane..], words, lane, zv, Some(mask));
        }
    }

    /// Scalar tail (< 8 lanes), identical per-lane ops as the main loop.
    fn axpy_tail(out: &mut [f32], words: &[u64], lane0: usize, zv: f32, mask: Option<&[u64]>) {
        for (k, o) in out.iter_mut().enumerate() {
            let lane = lane0 + k;
            let pat = if (words[lane / 64] >> (lane % 64)) & 1 == 1 { 1.0f32 } else { -1.0 };
            match mask {
                None => *o += zv * pat,
                Some(m) => {
                    let mpat = ((m[lane / 64] >> (lane % 64)) & 1) as f32;
                    *o += zv * pat * mpat;
                }
            }
        }
    }

    pub fn axpy_pm1(out: &mut [f32], words: &[u64], zv: f32) {
        debug_assert!(words.len() * 64 >= out.len());
        // SAFETY: table installed only after AVX2 detection.
        unsafe { axpy_pm1_imp(out, words, zv) }
    }

    pub fn axpy_pm1_masked(out: &mut [f32], words: &[u64], mask: &[u64], zv: f32) {
        debug_assert!(words.len() * 64 >= out.len() && mask.len() >= words.len());
        // SAFETY: table installed only after AVX2 detection.
        unsafe { axpy_pm1_masked_imp(out, words, mask, zv) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn cmp_mask64_imp(data: &[f32], thr: f32, flip: bool) -> u64 {
        let t = _mm256_set1_ps(thr);
        let mut w = 0u64;
        let mut i = 0usize;
        while i + 8 <= data.len() {
            let v = _mm256_loadu_ps(data.as_ptr().add(i));
            // ordered-quiet compares: NaN ⇒ false, matching `>=` / `<=`
            let c = if flip {
                _mm256_cmp_ps::<_CMP_LE_OQ>(v, t)
            } else {
                _mm256_cmp_ps::<_CMP_GE_OQ>(v, t)
            };
            w |= ((_mm256_movemask_ps(c) as u32) as u64) << i;
            i += 8;
        }
        if i < data.len() {
            w |= scalar::cmp_mask64(&data[i..], thr, flip) << i;
        }
        w
    }

    pub fn cmp_mask64(data: &[f32], thr: f32, flip: bool) -> u64 {
        debug_assert!(data.len() <= 64);
        // SAFETY: table installed only after AVX2 detection.
        unsafe { cmp_mask64_imp(data, thr, flip) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn flip_scan_word_imp(
        word: u64,
        grad: &[f32],
        accum: &mut [f32],
        beta: f32,
        lr: f32,
        clip: Option<f32>,
    ) -> u64 {
        let lanes = grad.len();
        let beta_v = _mm256_set1_ps(beta);
        let lr_v = _mm256_set1_ps(lr);
        let one = _mm256_set1_ps(1.0);
        let neg_one = _mm256_set1_ps(-1.0);
        let mut mask = 0u64;
        let mut lane = 0usize;
        while lane + 8 <= lanes {
            let g = _mm256_loadu_ps(grad.as_ptr().add(lane));
            let a = _mm256_loadu_ps(accum.as_ptr().add(lane));
            // β·m + η·q with scalar rounding: add(mul, mul), no FMA
            let mut m = _mm256_add_ps(_mm256_mul_ps(beta_v, a), _mm256_mul_ps(lr_v, g));
            if let Some(k) = clip {
                // f32::clamp(-k, k): branch-equivalent blends (NaN keeps m)
                let lo = _mm256_set1_ps(-k);
                let hi = _mm256_set1_ps(k);
                m = _mm256_blendv_ps(m, lo, _mm256_cmp_ps::<_CMP_LT_OQ>(m, lo));
                m = _mm256_blendv_ps(m, hi, _mm256_cmp_ps::<_CMP_GT_OQ>(m, hi));
            }
            let set = _mm256_castsi256_ps(expand_byte(((word >> lane) & 0xff) as u8));
            let ge = _mm256_cmp_ps::<_CMP_GE_OQ>(m, one);
            let le = _mm256_cmp_ps::<_CMP_LE_OQ>(m, neg_one);
            let flip = _mm256_or_ps(_mm256_and_ps(set, ge), _mm256_andnot_ps(set, le));
            // flipped lanes reset to +0.0 (andnot with the all-ones lanes)
            let new_a = _mm256_andnot_ps(flip, m);
            _mm256_storeu_ps(accum.as_mut_ptr().add(lane), new_a);
            mask |= ((_mm256_movemask_ps(flip) as u32) as u64) << lane;
            lane += 8;
        }
        if lane < lanes {
            mask |= scalar::flip_scan_word(
                word >> lane,
                &grad[lane..],
                &mut accum[lane..],
                beta,
                lr,
                clip,
            ) << lane;
        }
        mask
    }

    pub fn flip_scan_word(
        word: u64,
        grad: &[f32],
        accum: &mut [f32],
        beta: f32,
        lr: f32,
        clip: Option<f32>,
    ) -> u64 {
        debug_assert!(grad.len() <= 64 && grad.len() == accum.len());
        // SAFETY: table installed only after AVX2 detection.
        unsafe { flip_scan_word_imp(word, grad, accum, beta, lr, clip) }
    }
}

// ---------------------------------------------------------------------------
// NEON backend (aarch64; NEON is baseline there, no detection needed)
// ---------------------------------------------------------------------------

/// NEON popcount family via `vcntq_u8` (per-byte popcount) and the
/// pairwise-add widening chain; the f32 primitives stay scalar on
/// aarch64 (see the dispatch table).
#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn ld(p: &[u64], i: usize) -> uint64x2_t {
        debug_assert!(i + 2 <= p.len());
        vld1q_u64(p.as_ptr().add(i))
    }

    /// Popcount one 128-bit vector into a u64x2 accumulator.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn acc_popcnt(acc: uint64x2_t, x: uint64x2_t) -> uint64x2_t {
        let c = vcntq_u8(vreinterpretq_u8_u64(x));
        vaddq_u64(acc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(c))))
    }

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn drain(acc: uint64x2_t) -> u64 {
        vgetq_lane_u64::<0>(acc) + vgetq_lane_u64::<1>(acc)
    }

    #[target_feature(enable = "neon")]
    unsafe fn xor_popcnt_imp(a: &[u64], b: &[u64]) -> u64 {
        let n = a.len();
        let mut acc = vdupq_n_u64(0);
        let mut i = 0usize;
        while i + 2 <= n {
            acc = acc_popcnt(acc, veorq_u64(ld(a, i), ld(b, i)));
            i += 2;
        }
        let mut total = drain(acc);
        while i < n {
            total += (a[i] ^ b[i]).count_ones() as u64;
            i += 1;
        }
        total
    }

    #[target_feature(enable = "neon")]
    unsafe fn xor_and_popcnt_imp(a: &[u64], b: &[u64], m: &[u64]) -> u64 {
        let n = a.len();
        let mut acc = vdupq_n_u64(0);
        let mut i = 0usize;
        while i + 2 <= n {
            acc = acc_popcnt(acc, vandq_u64(veorq_u64(ld(a, i), ld(b, i)), ld(m, i)));
            i += 2;
        }
        let mut total = drain(acc);
        while i < n {
            total += ((a[i] ^ b[i]) & m[i]).count_ones() as u64;
            i += 1;
        }
        total
    }

    #[target_feature(enable = "neon")]
    unsafe fn popcnt_imp(a: &[u64]) -> u64 {
        let n = a.len();
        let mut acc = vdupq_n_u64(0);
        let mut i = 0usize;
        while i + 2 <= n {
            acc = acc_popcnt(acc, ld(a, i));
            i += 2;
        }
        let mut total = drain(acc);
        while i < n {
            total += a[i].count_ones() as u64;
            i += 1;
        }
        total
    }

    pub fn xor_popcnt(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        // SAFETY: NEON is a baseline aarch64 target feature.
        unsafe { xor_popcnt_imp(a, b) }
    }

    pub fn xor_and_popcnt(a: &[u64], b: &[u64], m: &[u64]) -> u64 {
        debug_assert!(a.len() == b.len() && a.len() == m.len());
        // SAFETY: NEON is a baseline aarch64 target feature.
        unsafe { xor_and_popcnt_imp(a, b, m) }
    }

    pub fn popcnt(a: &[u64]) -> u64 {
        // SAFETY: NEON is a baseline aarch64 target feature.
        unsafe { popcnt_imp(a) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Primitive-level A/B: the auto-detected backend against the scalar
    /// reference, across lengths that cover the Harley–Seal block path
    /// (≥ 64 words), the plain-vector path, and the scalar tails. On a
    /// machine without SIMD support both sides are scalar and the test
    /// degenerates to self-consistency — the correct behaviour, not a
    /// skip (same convention as tests/parallel_determinism.rs).
    #[test]
    fn popcount_family_matches_scalar_reference() {
        let mut rng = Rng::new(90);
        let kk = best();
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 63, 64, 65, 127, 128, 200, 300] {
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let m: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            assert_eq!((kk.xor_popcnt)(&a, &b), scalar::xor_popcnt(&a, &b), "xor n={n}");
            assert_eq!(
                (kk.xor_and_popcnt)(&a, &b, &m),
                scalar::xor_and_popcnt(&a, &b, &m),
                "xor_and n={n}"
            );
            assert_eq!((kk.popcnt)(&a), scalar::popcnt(&a), "popcnt n={n}");
        }
    }

    #[test]
    fn axpy_family_matches_scalar_reference() {
        let mut rng = Rng::new(91);
        let kk = best();
        for len in [1usize, 7, 8, 9, 15, 16, 63, 64, 65, 100, 193] {
            let words: Vec<u64> = (0..len.div_ceil(64)).map(|_| rng.next_u64()).collect();
            let mask: Vec<u64> = (0..len.div_ceil(64)).map(|_| rng.next_u64()).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let zv = rng.normal();

            let mut want = init.clone();
            scalar::axpy_pm1(&mut want, &words, zv);
            let mut got = init.clone();
            (kk.axpy_pm1)(&mut got, &words, zv);
            assert_eq!(want, got, "axpy len={len}");

            let mut want = init.clone();
            scalar::axpy_pm1_masked(&mut want, &words, &mask, zv);
            let mut got = init.clone();
            (kk.axpy_pm1_masked)(&mut got, &words, &mask, zv);
            assert_eq!(want, got, "axpy_masked len={len}");
        }
    }

    #[test]
    fn cmp_mask_matches_scalar_reference() {
        let mut rng = Rng::new(92);
        let kk = best();
        for len in [0usize, 1, 7, 8, 9, 31, 32, 63, 64] {
            let data: Vec<f32> = (0..len).map(|_| rng.normal() * 3.0).collect();
            for thr in [0.0f32, 1.5, -2.0] {
                for flip in [false, true] {
                    assert_eq!(
                        (kk.cmp_mask64)(&data, thr, flip),
                        scalar::cmp_mask64(&data, thr, flip),
                        "len={len} thr={thr} flip={flip}"
                    );
                }
            }
        }
    }

    #[test]
    fn flip_scan_matches_scalar_reference() {
        let mut rng = Rng::new(93);
        let kk = best();
        for lanes in [1usize, 8, 9, 17, 56, 63, 64] {
            for clip in [None, Some(2.5f32)] {
                let word = rng.next_u64();
                let grad: Vec<f32> = (0..lanes).map(|_| rng.normal() * 1.3).collect();
                let accum0: Vec<f32> = (0..lanes).map(|_| rng.normal()).collect();
                let mut a_ref = accum0.clone();
                let m_ref = scalar::flip_scan_word(word, &grad, &mut a_ref, 0.8, 1.0, clip);
                let mut a_got = accum0.clone();
                let m_got = (kk.flip_scan_word)(word, &grad, &mut a_got, 0.8, 1.0, clip);
                assert_eq!(m_ref, m_got, "mask lanes={lanes} clip={clip:?}");
                assert_eq!(a_ref, a_got, "accum lanes={lanes} clip={clip:?}");
            }
        }
    }

    #[test]
    fn pack_cmp_into_matches_per_bit_packing() {
        let mut rng = Rng::new(94);
        for (bit0, len) in [(0usize, 1usize), (0, 64), (0, 65), (5, 60), (60, 10), (63, 129)] {
            let data: Vec<f32> = (0..len).map(|_| rng.normal() * 2.0).collect();
            let words = (bit0 + len).div_ceil(64);
            for flip in [false, true] {
                let mut out = vec![0u64; words];
                pack_cmp_into(&mut out, bit0, &data, 0.5, flip);
                let mut want = vec![0u64; words];
                for (i, &v) in data.iter().enumerate() {
                    let fire = if flip { v <= 0.5 } else { v >= 0.5 };
                    if fire {
                        want[(bit0 + i) / 64] |= 1u64 << ((bit0 + i) % 64);
                    }
                }
                assert_eq!(out, want, "bit0={bit0} len={len} flip={flip}");
            }
        }
    }

    #[test]
    fn gather_bit_column_matches_per_bit_reads() {
        let mut rng = Rng::new(95);
        for (wpr, nrows) in [(1usize, 64usize), (1, 17), (3, 64), (3, 1), (2, 33)] {
            let rows = nrows + 5;
            let words: Vec<u64> = (0..rows * wpr).map(|_| rng.next_u64()).collect();
            for row0 in [0usize, 3] {
                for col in [0usize, 1, 63, 64 * (wpr - 1) + wpr.min(2) - 1, wpr * 64 - 1] {
                    let got = gather_bit_column(&words, wpr, row0, nrows, col);
                    let mut want = 0u64;
                    for l in 0..nrows {
                        want |= ((words[(row0 + l) * wpr + col / 64] >> (col % 64)) & 1) << l;
                    }
                    assert_eq!(got, want, "wpr={wpr} nrows={nrows} row0={row0} col={col}");
                }
            }
        }
    }

    #[test]
    fn lut_eval_word_matches_per_lane_table_indexing() {
        let mut rng = Rng::new(96);
        for fanin in 0usize..=10 {
            let tw = (1usize << fanin).div_ceil(64).max(1);
            let table: Vec<u64> = (0..tw).map(|_| rng.next_u64()).collect();
            let cols: Vec<u64> = (0..fanin.max(1)).map(|_| rng.next_u64()).collect();
            let mut buf = vec![0u64; (1usize << fanin.saturating_sub(1)).max(1)];
            let got = lut_eval_word(&table, fanin, &cols, &mut buf);
            let mut want = 0u64;
            for l in 0..64 {
                let mut idx = 0usize;
                for (i, c) in cols.iter().enumerate().take(fanin) {
                    idx |= (((c >> l) & 1) as usize) << i;
                }
                want |= ((table[idx / 64] >> (idx % 64)) & 1) << l;
            }
            assert_eq!(got, want, "fanin={fanin}");
        }
    }

    #[test]
    fn transpose64_matches_naive_and_is_an_involution() {
        let mut rng = Rng::new(97);
        let orig: Vec<u64> = (0..64).map(|_| rng.next_u64()).collect();
        let mut a: [u64; 64] = orig.clone().try_into().unwrap();
        transpose64(&mut a);
        for r in 0..64 {
            for c in 0..64 {
                assert_eq!(
                    (a[r] >> c) & 1,
                    (orig[c] >> r) & 1,
                    "transposed bit ({r},{c})"
                );
            }
        }
        transpose64(&mut a);
        assert_eq!(a.to_vec(), orig, "transpose is an involution");
    }

    #[test]
    fn with_backend_overrides_and_restores() {
        let before = active();
        with_backend(Backend::Scalar, || {
            assert_eq!(active(), Backend::Scalar);
        });
        assert_eq!(active(), before);
        assert!(supported(Backend::Scalar));
        assert!(supported(auto_backend()));
    }

    #[test]
    fn aligned_words_is_64_byte_aligned_and_vec_like() {
        let mut w = AlignedWords::zeroed(11);
        assert_eq!(w.len(), 11);
        assert_eq!(w.as_ptr() as usize % 64, 0, "base must be cache-line aligned");
        w[10] = 7;
        w.resize(30, 3);
        assert_eq!(w[10], 7, "resize preserves content");
        assert!(w[11..30].iter().all(|&v| v == 3), "resize fills new words");
        w.resize(4, 0);
        assert_eq!(w.len(), 4);
        // stale capacity words must not resurface on regrow
        w.resize(30, 1);
        assert!(w[4..30].iter().all(|&v| v == 1), "regrow refills stale words");
        w.clear();
        w.extend_from_slice(&[1, 2, 3]);
        assert_eq!(&w[..], &[1, 2, 3]);
        assert_eq!(w.as_ptr() as usize % 64, 0);

        let v: AlignedWords = vec![5u64; 100].into();
        let mut c = AlignedWords::new();
        c.clone_from(&v);
        assert_eq!(c, v);
        assert_eq!(c.to_vec(), vec![5u64; 100]);
    }
}
