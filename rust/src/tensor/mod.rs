//! Tensor substrate.
//!
//! Two representations flow through the engine:
//!
//! * [`Tensor`] — dense f32, for FP layers, integer-valued pre-activations
//!   and backward signals (the ℤ/ℝ-typed data of Fig. 2 in the paper);
//! * [`BitMatrix`] — bit-packed Boolean data, 64 values per word, bit=1 ↔ T
//!   (+1 under the Definition A.1 embedding). This is the "native Boolean
//!   accelerator" dataflow the paper argues for: forward is word-level
//!   XNOR + popcount, 64 lanes per instruction.
//!
//! The two are exactly interconvertible through the ±1 embedding
//! (Proposition A.2), which the property tests exercise.
//!
//! The packed kernels' inner loops run on the runtime-dispatched
//! [`simd`] backend (AVX2 Harley–Seal popcount / NEON `vcntq_u8` /
//! portable scalar, selected once at startup, `BOLD_SIMD` override),
//! over 64-byte-aligned [`AlignedWords`] storage — bit-exact across
//! backends (DESIGN.md §SIMD-Backend).

mod bitmatrix;
pub mod simd;
#[allow(clippy::module_inception)]
mod tensor;

pub use bitmatrix::BitMatrix;
pub use simd::AlignedWords;
pub use tensor::Tensor;
