//! Dense f32 tensor with the small, explicit op set the engine needs.
//!
//! Deliberately not a general autodiff tensor: every layer implements its
//! own closed-form backward (the paper's Boolean layers do not have true
//! gradients anyway — they have *variations*), so all we need here is
//! shaped storage plus GEMM, elementwise ops and im2col/col2im.
//!
//! The GEMM variants and the conv im2col/col2im helpers shard disjoint
//! output-row ranges across the persistent [`crate::util::pool`]; each
//! shard preserves the per-element f32 accumulation order of the
//! sequential loop, so results are bit-exact for any thread count
//! (DESIGN.md §Parallelism, asserted in `tests/parallel_determinism.rs`).

use crate::util::pool::{self, MAC_QUANTUM};
use crate::util::Rng;

/// Minimum elements moved per pool shard for the copy/scatter conv
/// helpers (im2col / col2im).
const COPY_QUANTUM: usize = 1 << 16;

/// Row-major dense f32 tensor.
#[derive(Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Clone for Tensor {
    fn clone(&self) -> Self {
        Tensor { shape: self.shape.clone(), data: self.data.clone() }
    }

    /// Reuses the existing data allocation (scratch/cache buffers rely on
    /// this to stop allocating per batch).
    fn clone_from(&mut self, src: &Self) {
        self.shape.clone_from(&src.shape);
        self.data.clone_from(&src.data);
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
            "shape {shape:?} vs data len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    /// He-style normal init scaled by 1/sqrt(fan_in) (for FP layers).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal() * std).collect(),
        }
    }

    /// Uniform random ±1 tensor (embedded Boolean init).
    pub fn rand_pm1(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|_| rng.sign()).collect() }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on non-2D {:?}", self.shape);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on non-2D {:?}", self.shape);
        self.shape[1]
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at2_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.shape[1] + j]
    }

    /// Reshape in place to `shape`, reusing the data allocation. Existing
    /// content is preserved up to the new length (newly grown elements are
    /// zero) — for `_into` kernels that fully overwrite or zero-then-
    /// accumulate their output.
    pub fn resize_to(&mut self, shape: &[usize]) {
        let n: usize = shape.iter().product();
        if self.shape != shape {
            self.shape.clear();
            self.shape.extend_from_slice(shape);
        }
        self.data.resize(n, 0.0);
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len(),
            "reshape {:?} -> {:?}", self.shape, shape);
        self.shape = shape.to_vec();
        self
    }

    pub fn view(&self, shape: &[usize]) -> Tensor {
        self.clone().reshape(shape)
    }

    // ----- elementwise ---------------------------------------------------

    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace<F: Fn(f32) -> f32>(&mut self, f: F) {
        for x in self.data.iter_mut() {
            *x = f(*x);
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn add_inplace(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn hadamard(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn scale_inplace(&mut self, s: f32) {
        self.map_inplace(|x| x * s);
    }

    /// axpy: self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Sign in the ±1 embedding (0 maps to +1, matching `s >= τ`).
    pub fn sign_pm1(&self) -> Tensor {
        self.map(|x| if x >= 0.0 { 1.0 } else { -1.0 })
    }

    // ----- reductions ----------------------------------------------------

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn mean(&self) -> f32 {
        self.sum() / self.len() as f32
    }

    /// Column sums of a 2-D tensor → vector of length `cols`.
    pub fn sum_rows(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
        Tensor::from_vec(&[c], out)
    }

    /// Per-row argmax of a 2-D tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.argmax_rows_into(&mut out);
        out
    }

    /// [`Self::argmax_rows`] into a reusable buffer (cleared and
    /// refilled) — the serve workers call this per drained batch without
    /// allocating.
    pub fn argmax_rows_into(&self, out: &mut Vec<usize>) {
        let (r, c) = (self.rows(), self.cols());
        out.clear();
        out.extend((0..r).map(|i| {
            let row = &self.data[i * c..(i + 1) * c];
            let mut best = 0;
            for j in 1..c {
                if row[j] > row[best] {
                    best = j;
                }
            }
            best
        }));
    }

    // ----- GEMM ----------------------------------------------------------

    /// C = A·B with A (m×k), B (k×n). ikj loop order, slice inner loop;
    /// output rows shard across the pool (bit-exact vs sequential: each
    /// element keeps its ascending-p accumulation order).
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul {:?}x{:?}", self.shape, b.shape);
        let mut out = vec![0.0f32; m * n];
        let shards = pool::shards_for(m * k * n, m, MAC_QUANTUM);
        if shards <= 1 {
            matmul_rows(&self.data, m, k, &b.data, n, &mut out);
        } else {
            let rows_per = m.div_ceil(shards);
            let tasks: Vec<_> = self
                .data
                .chunks(rows_per * k)
                .zip(out.chunks_mut(rows_per * n))
                .map(|(ac, oc)| move || matmul_rows(ac, ac.len() / k, k, &b.data, n, oc))
                .collect();
            pool::run_scoped(tasks);
        }
        Tensor::from_vec(&[m, n], out)
    }

    /// C = A·Bᵀ with A (m×k), B (n×k) — the natural layout for row-major
    /// weights (one row per output unit). Four independent accumulators
    /// break the serial FP dependency chain so the k-loop vectorizes
    /// (§Perf iteration log); output rows shard across the pool.
    pub fn matmul_bt(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul_bt {:?}x{:?}", self.shape, b.shape);
        let mut out = vec![0.0f32; m * n];
        let shards = pool::shards_for(m * k * n, m, MAC_QUANTUM);
        if shards <= 1 || k == 0 {
            matmul_bt_rows(&self.data, m, k, &b.data, n, &mut out);
        } else {
            let rows_per = m.div_ceil(shards);
            let tasks: Vec<_> = self
                .data
                .chunks(rows_per * k)
                .zip(out.chunks_mut(rows_per * n))
                .map(|(ac, oc)| move || matmul_bt_rows(ac, ac.len() / k, k, &b.data, n, oc))
                .collect();
            pool::run_scoped(tasks);
        }
        Tensor::from_vec(&[m, n], out)
    }

    /// C = Aᵀ·B with A (k×m), B (k×n) — gradient accumulation layout.
    /// Output rows (columns of A) shard across the pool; every shard keeps
    /// the original p-outer walk over its column range, so per-element
    /// accumulation order — and the result — is identical to sequential.
    pub fn matmul_at(&self, b: &Tensor) -> Tensor {
        let (k, m) = (self.rows(), self.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul_at {:?}x{:?}", self.shape, b.shape);
        let mut out = vec![0.0f32; m * n];
        let shards = pool::shards_for(m * k * n, m, MAC_QUANTUM);
        pool::for_each_row_chunk(&mut out, n, shards, |i0, oc| {
            matmul_at_cols(&self.data, k, m, i0, &b.data, n, oc)
        });
        Tensor::from_vec(&[m, n], out)
    }

    pub fn transpose2(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::from_vec(&[c, r], out)
    }

    // ----- conv helpers ----------------------------------------------------

    /// im2col for NCHW input: output is (N·OH·OW) × (C·k·k), zero padding.
    ///
    /// In the Boolean reading, the zero pads are the adjoined 0 of the
    /// three-valued logic 𝕄 (Definition 3.1): they contribute nothing to
    /// the xnor count, exactly like a multiplicative 0 here.
    pub fn im2col(&self, k: usize, stride: usize, pad: usize) -> Tensor {
        let (n, c, h, w) = self.dims4();
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        let cols = c * k * k;
        let rows = n * oh * ow;
        let mut out = vec![0.0f32; rows * cols];
        let shards = pool::shards_for(rows * cols, rows, COPY_QUANTUM);
        pool::for_each_row_chunk(&mut out, cols, shards, |r0, oc| {
            im2col_rows(&self.data, c, h, w, k, stride, pad, oh, ow, r0, oc, cols)
        });
        Tensor::from_vec(&[rows, cols], out)
    }

    /// col2im: scatter-add the patch gradient back to NCHW (adjoint of
    /// `im2col` with identical geometry). Images are the shard unit: each
    /// image's scatter-adds stay on one thread in the sequential order, so
    /// the result is bit-exact vs single-threaded for any thread count.
    pub fn col2im(
        &self,
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Tensor {
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        let cols = c * k * k;
        assert_eq!(self.shape, vec![n * oh * ow, cols]);
        let img = c * h * w;
        let mut out = vec![0.0f32; n * img];
        let shards = pool::shards_for(n * oh * ow * cols, n, COPY_QUANTUM);
        pool::for_each_row_chunk(&mut out, img, shards, |n0, oc| {
            let imgs = if img == 0 { 0 } else { oc.len() / img };
            col2im_imgs(&self.data, n0, imgs, c, h, w, k, stride, pad, oh, ow, cols, oc)
        });
        Tensor::from_vec(&[n, c, h, w], out)
    }

    /// NCHW → (N·H·W, C): the row layout produced by `im2col`, used to
    /// express conv as GEMM (channel-last per output position).
    pub fn nchw_to_rows(&self) -> Tensor {
        let (n, c, h, w) = self.dims4();
        let mut out = vec![0.0f32; n * h * w * c];
        for ni in 0..n {
            for ci in 0..c {
                let src = ((ni * c) + ci) * h * w;
                for p in 0..h * w {
                    out[(ni * h * w + p) * c + ci] = self.data[src + p];
                }
            }
        }
        Tensor::from_vec(&[n * h * w, c], out)
    }

    /// (N·H·W, C) → NCHW, inverse of `nchw_to_rows`.
    pub fn rows_to_nchw(&self, n: usize, c: usize, h: usize, w: usize) -> Tensor {
        assert_eq!(self.shape, vec![n * h * w, c]);
        let mut out = vec![0.0f32; n * c * h * w];
        for ni in 0..n {
            for ci in 0..c {
                let dst = ((ni * c) + ci) * h * w;
                for p in 0..h * w {
                    out[dst + p] = self.data[(ni * h * w + p) * c + ci];
                }
            }
        }
        Tensor::from_vec(&[n, c, h, w], out)
    }

    /// Interpret shape as (N, C, H, W).
    pub fn dims4(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "dims4 on {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    /// Max absolute difference to another tensor (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

// ---------------------------------------------------------------------------
// row-range kernel cores (sequential bodies; the parallel wrappers hand
// each core a disjoint output-row range with unchanged per-element
// accumulation order, so any shard split is bit-exact vs one shard)
// ---------------------------------------------------------------------------

/// ikj GEMM over a contiguous block of `rows` A-rows / output rows.
fn matmul_rows(a: &[f32], rows: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// A·Bᵀ over a contiguous block of `rows` A-rows / output rows, with the
/// 4-accumulator k-loop.
fn matmul_bt_rows(a: &[f32], rows: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    let k4 = k - k % 4;
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut p = 0;
            while p < k4 {
                s0 += arow[p] * brow[p];
                s1 += arow[p + 1] * brow[p + 1];
                s2 += arow[p + 2] * brow[p + 2];
                s3 += arow[p + 3] * brow[p + 3];
                p += 4;
            }
            let mut acc = (s0 + s1) + (s2 + s3);
            for q in k4..k {
                acc += arow[q] * brow[q];
            }
            out[i * n + j] = acc;
        }
    }
}

/// Aᵀ·B restricted to A-columns [i0, i0 + out.len()/n): p-outer walk
/// identical to the sequential kernel, touching only this column range.
fn matmul_at_cols(a: &[f32], k: usize, m: usize, i0: usize, b: &[f32], n: usize, out: &mut [f32]) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..rows {
            let av = arow[i0 + i];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// im2col over a contiguous block of flat output rows starting at `r0`
/// (flat row = (ni·OH + oy)·OW + ox). Pure copies into a pre-zeroed
/// block; padded taps stay zero.
fn im2col_rows(
    src: &[f32],
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    r0: usize,
    out: &mut [f32],
    cols: usize,
) {
    let rows = if cols == 0 { 0 } else { out.len() / cols };
    for rr in 0..rows {
        let flat = r0 + rr;
        let ni = flat / (oh * ow);
        let rem = flat % (oh * ow);
        let oy = rem / ow;
        let ox = rem % ow;
        let row = rr * cols;
        for ci in 0..c {
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                let s = ((ni * c + ci) * h + iy as usize) * w;
                let dst = row + (ci * k + ky) * k;
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    out[dst + kx] = src[s + ix as usize];
                }
            }
        }
    }
}

/// col2im scatter-add for `imgs` images starting at image `n0`: reads the
/// full patch matrix, writes only this image block.
fn col2im_imgs(
    cols_dat: &[f32],
    n0: usize,
    imgs: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    cols: usize,
    out: &mut [f32],
) {
    for nl in 0..imgs {
        let ni = n0 + nl;
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((ni * oh + oy) * ow + ox) * cols;
                for ci in 0..c {
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        let dst = ((nl * c + ci) * h + iy as usize) * w;
                        let src = row + (ci * k + ky) * k;
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            out[dst + ix as usize] += cols_dat[src + kx];
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 9], 1.0, &mut rng);
        let c1 = a.matmul(&b);
        let c2 = a.matmul_bt(&b.transpose2());
        let c3 = a.transpose2().matmul_at(&b);
        assert!(c1.max_abs_diff(&c2) < 1e-5);
        assert!(c1.max_abs_diff(&c3) < 1e-5);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        assert_eq!(a, a.transpose2().transpose2());
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: im2col is a pure reshape/permute.
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[2, 3, 4, 4], 1.0, &mut rng);
        let cols = x.im2col(1, 1, 0);
        assert_eq!(cols.shape, vec![2 * 4 * 4, 3]);
        // spot check: element (n=1, c=2, y=3, x=0)
        let v = x.data[((1 * 3 + 2) * 4 + 3) * 4];
        assert_eq!(cols.at2((1 * 4 + 3) * 4, 2), v);
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> — the defining adjoint property.
        let mut rng = Rng::new(4);
        let (n, c, h, w, k, s, p) = (2, 3, 5, 5, 3, 1, 1);
        let x = Tensor::randn(&[n, c, h, w], 1.0, &mut rng);
        let cx = x.im2col(k, s, p);
        let y = Tensor::randn(&cx.shape, 1.0, &mut rng);
        let lhs: f32 = cx.data.iter().zip(&y.data).map(|(a, b)| a * b).sum();
        let back = y.col2im(n, c, h, w, k, s, p);
        let rhs: f32 = x.data.iter().zip(&back.data).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0));
    }

    #[test]
    fn strided_im2col_shapes() {
        let x = Tensor::zeros(&[1, 2, 8, 8]);
        let cols = x.im2col(3, 2, 1);
        // OH = OW = (8 + 2 - 3)/2 + 1 = 4
        assert_eq!(cols.shape, vec![16, 18]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.sum(), 21.0);
        assert_eq!(t.sum_rows().data, vec![5., 7., 9.]);
        assert_eq!(t.argmax_rows(), vec![2, 2]);
    }
}
