//! `bench_check` — benchmark-regression gate for the committed
//! `benchmarks/BENCH_*.json` baselines (DESIGN.md §Bench-Harness).
//!
//! The bench binaries (`bench_serve`, `bench_kernels`) emit
//! line-oriented JSON: one record per line, flat string/number fields.
//! This tool compares a fresh emission against a committed baseline and
//! fails (exit 1) when a shared metric regresses beyond the tolerance:
//!
//! ```text
//! bench_check --baseline benchmarks/BENCH_serve.json \
//!             --current  BENCH_serve.json [--tolerance 0.20]
//! bench_check --validate benchmarks/BENCH_serve.json ...   # shape check
//! ```
//!
//! Conventions:
//! * rows are matched by their identity fields — (`bench`,`config`) for
//!   serve records, (`kernel`,`dims`,`threads`,`simd`) for kernel
//!   records (auto-detected per row);
//! * `req_per_s`/`gops` are higher-is-better, `us_per_iter`/
//!   `ns_per_iter`/`p99_us` lower-is-better;
//! * a **zero-valued baseline metric is an unfilled sentinel** and is
//!   skipped: freshly added rows are committed with zeros and become
//!   binding once a measured run lands (EXPERIMENTS.md `_fill_`
//!   convention);
//! * rows present on only one side never fail the check (benches
//!   gain/drop rows across PRs) — but a current row missing from the
//!   baseline is surfaced as a counted **warning**, so a bench section
//!   landing without its zero-sentinel baseline rows is visible in CI
//!   logs instead of silently unchecked.
//!
//! Zero dependencies: the "parser" is a field extractor good for exactly
//! the flat records our emitters write, with unit tests pinning that
//! contract.

use std::collections::BTreeMap;
use std::process::ExitCode;

/// Extract `"key":value` from a flat one-line JSON record. Returns the
/// raw value text (quotes stripped for strings). Good enough for the
/// bench emitters' output; not a general JSON parser.
fn field(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    if let Some(stripped) = rest.strip_prefix('"') {
        // string value: scan to the closing quote (emitters never escape)
        Some(stripped[..stripped.find('"')?].to_string())
    } else {
        let end = rest
            .find(|c: char| c == ',' || c == '}')
            .unwrap_or(rest.len());
        Some(rest[..end].trim().to_string())
    }
}

fn num_field(line: &str, key: &str) -> Option<f64> {
    field(line, key)?.parse().ok()
}

/// Identity key for a record line: serve rows use (bench, config),
/// kernel rows (kernel, dims, threads, simd).
fn identity(line: &str) -> Option<String> {
    if let Some(kernel) = field(line, "kernel") {
        Some(format!(
            "{kernel} | {} | t{} | {}",
            field(line, "dims")?,
            field(line, "threads")?,
            field(line, "simd")?
        ))
    } else {
        let bench = field(line, "bench")?;
        Some(format!("{bench} | {}", field(line, "config")?))
    }
}

/// (metric name, higher_is_better) pairs checked when present.
/// `scratch_bytes` and `slots_live` are the ISSUE-7 memory metrics:
/// peak per-worker `GraphScratch` footprint and the post-liveness slot
/// count — gated exactly like latency (growth beyond tolerance fails).
const METRICS: &[(&str, bool)] = &[
    ("req_per_s", true),
    ("gops", true),
    ("us_per_iter", false),
    ("ns_per_iter", false),
    ("p99_us", false),
    ("scratch_bytes", false),
    ("slots_live", false),
];

fn parse_records(text: &str) -> BTreeMap<String, String> {
    let mut map = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        if !line.starts_with('{') {
            continue;
        }
        if let Some(id) = identity(line) {
            map.insert(id, line.to_string());
        }
    }
    map
}

struct Regression {
    id: String,
    metric: &'static str,
    base: f64,
    cur: f64,
    ratio: f64,
}

/// Compare and collect regressions beyond `tol` (0.20 = 20%). The last
/// element counts current rows absent from the baseline — unchecked
/// work the baseline should grow sentinel rows for.
fn compare(baseline: &str, current: &str, tol: f64) -> (Vec<Regression>, usize, usize, usize) {
    let base = parse_records(baseline);
    let cur = parse_records(current);
    let mut regressions = Vec::new();
    let mut checked = 0usize;
    let mut skipped = 0usize;
    let mut unbaselined = 0usize;
    for (id, bline) in &base {
        let Some(cline) = cur.get(id) else {
            println!("note: row only in baseline (skipped): {id}");
            continue;
        };
        for &(metric, higher_better) in METRICS {
            let (Some(b), Some(c)) = (num_field(bline, metric), num_field(cline, metric)) else {
                continue;
            };
            if b == 0.0 {
                // unfilled sentinel: baseline committed before any
                // measured run — becomes binding once filled
                skipped += 1;
                continue;
            }
            checked += 1;
            let ratio = if higher_better { c / b } else { b / c.max(1e-12) };
            if ratio < 1.0 - tol {
                regressions.push(Regression { id: id.clone(), metric, base: b, cur: c, ratio });
            }
        }
    }
    for id in cur.keys() {
        if !base.contains_key(id) {
            println!("warning: current row not in baseline (unchecked): {id}");
            unbaselined += 1;
        }
    }
    (regressions, checked, skipped, unbaselined)
}

/// Structural validation of a committed baseline: parseable rows, each
/// with an identity and at least one known metric, and no record-shaped
/// line (`{...`) that the extractor fails to identify — a malformed row
/// would otherwise be silently skipped by every future comparison.
fn validate(path: &str, text: &str) -> Result<usize, String> {
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim().trim_end_matches(',');
        if line.starts_with('{') && identity(line).is_none() {
            return Err(format!(
                "{path}: line {} looks like a record but has no identity fields: {line}",
                ln + 1
            ));
        }
    }
    let recs = parse_records(text);
    if recs.is_empty() {
        return Err(format!("{path}: no parseable records"));
    }
    for (id, line) in &recs {
        if !METRICS.iter().any(|(m, _)| num_field(line, m).is_some()) {
            return Err(format!("{path}: row '{id}' has no known metric field"));
        }
    }
    Ok(recs.len())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut baseline: Option<String> = None;
    let mut current: Option<String> = None;
    let mut tolerance = 0.20f64;
    let mut validate_paths: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--baseline" => {
                baseline = args.get(i + 1).cloned();
                i += 2;
            }
            "--current" => {
                current = args.get(i + 1).cloned();
                i += 2;
            }
            "--tolerance" => {
                tolerance = args
                    .get(i + 1)
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(tolerance);
                i += 2;
            }
            "--validate" => {
                // every following argument is a baseline file to validate
                validate_paths.extend(args[i + 1..].iter().cloned());
                i = args.len();
            }
            other => {
                eprintln!("unknown argument '{other}'");
                eprintln!(
                    "usage: bench_check --baseline FILE --current FILE [--tolerance 0.20]\n\
                     \x20      bench_check --validate FILE..."
                );
                return ExitCode::from(2);
            }
        }
    }

    if !validate_paths.is_empty() {
        let mut ok = true;
        for p in &validate_paths {
            match std::fs::read_to_string(p) {
                Ok(text) => match validate(p, &text) {
                    Ok(n) => println!("{p}: ok ({n} rows)"),
                    Err(e) => {
                        eprintln!("FAIL {e}");
                        ok = false;
                    }
                },
                Err(e) => {
                    eprintln!("FAIL {p}: {e}");
                    ok = false;
                }
            }
        }
        return if ok { ExitCode::SUCCESS } else { ExitCode::FAILURE };
    }

    let (Some(bpath), Some(cpath)) = (baseline, current) else {
        eprintln!("need --baseline and --current (or --validate); see --help text above");
        return ExitCode::from(2);
    };
    let btext = match std::fs::read_to_string(&bpath) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read baseline {bpath}: {e}");
            return ExitCode::from(2);
        }
    };
    let ctext = match std::fs::read_to_string(&cpath) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read current {cpath}: {e}");
            return ExitCode::from(2);
        }
    };
    let (regressions, checked, skipped, unbaselined) = compare(&btext, &ctext, tolerance);
    println!(
        "bench_check: {checked} metric(s) compared, {skipped} unfilled baseline metric(s) \
         skipped, {unbaselined} current row(s) without a baseline, tolerance {:.0}%",
        tolerance * 100.0
    );
    if regressions.is_empty() {
        println!("OK: no regression beyond tolerance");
        return ExitCode::SUCCESS;
    }
    for r in &regressions {
        eprintln!(
            "REGRESSION {}: {} {} -> {} ({:.1}% of baseline, floor {:.1}%)",
            r.id,
            r.metric,
            r.base,
            r.cur,
            r.ratio * 100.0,
            (1.0 - tolerance) * 100.0
        );
    }
    ExitCode::FAILURE
}

#[cfg(test)]
mod tests {
    use super::*;

    const SERVE: &str = r#"[
  {"bench":"MLP 784-512-256-10","config":"4 workers, batch 64","workers":4,"batch":64,"req_per_s":100000,"us_per_iter":0.00,"scratch_bytes":262144,"slots_raw":8,"slots_live":2,"simd":"avx2","threads":8},
  {"bench":"http_open_loop MLP","config":"1.0x saturation","workers":8,"batch":64,"req_per_s":90000,"us_per_iter":0.00,"offered_per_s":95000,"p99_us":850.0,"scratch_bytes":0,"slots_raw":0,"slots_live":0,"simd":"avx2","threads":8}
]"#;

    const KERNELS: &str = r#"[
  {"kernel":"xnor_threshold","dims":"512x784x64","threads":1,"simd":"avx2","ns_per_iter":1200.0,"gops":3.100}
]"#;

    #[test]
    fn field_extraction() {
        let line = r#"{"bench":"a b","config":"c, d","req_per_s":123,"p99_us":4.5}"#;
        assert_eq!(field(line, "bench").as_deref(), Some("a b"));
        // string values may contain commas; the scan stops at the quote
        assert_eq!(field(line, "config").as_deref(), Some("c, d"));
        assert_eq!(num_field(line, "req_per_s"), Some(123.0));
        assert_eq!(num_field(line, "p99_us"), Some(4.5));
        assert_eq!(field(line, "missing"), None);
    }

    #[test]
    fn identity_keys() {
        let serve = parse_records(SERVE);
        assert_eq!(serve.len(), 2);
        assert!(serve.contains_key("MLP 784-512-256-10 | 4 workers, batch 64"));
        let kern = parse_records(KERNELS);
        assert!(kern.contains_key("xnor_threshold | 512x784x64 | t1 | avx2"));
    }

    #[test]
    fn passes_within_tolerance() {
        let cur = SERVE.replace("\"req_per_s\":100000", "\"req_per_s\":85000");
        let (regs, checked, _, _) = compare(SERVE, &cur, 0.20);
        assert!(regs.is_empty(), "15% drop is within 20% tolerance");
        assert!(checked >= 3);
    }

    #[test]
    fn fails_beyond_tolerance_throughput() {
        let cur = SERVE.replace("\"req_per_s\":100000", "\"req_per_s\":70000");
        let (regs, _, _, _) = compare(SERVE, &cur, 0.20);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "req_per_s");
    }

    #[test]
    fn fails_on_latency_increase() {
        let cur = KERNELS.replace("\"ns_per_iter\":1200.0", "\"ns_per_iter\":2000.0");
        let (regs, _, _, _) = compare(KERNELS, &cur, 0.20);
        // ns_per_iter 1200 -> 2000 is a 40% slowdown; gops unchanged
        assert!(regs.iter().any(|r| r.metric == "ns_per_iter"));
    }

    #[test]
    fn zero_baseline_is_unfilled_sentinel() {
        let base = SERVE.replace("\"req_per_s\":100000", "\"req_per_s\":0");
        let cur = SERVE.replace("\"req_per_s\":100000", "\"req_per_s\":1");
        let (regs, _, skipped, _) = compare(&base, &cur, 0.20);
        assert!(regs.is_empty(), "zero baseline must be skipped, not compared");
        assert!(skipped >= 1);
    }

    #[test]
    fn fails_on_scratch_bytes_growth() {
        // 262144 -> 393216 is +50% peak scratch: a memory regression,
        // gated exactly like a latency increase
        let cur = SERVE.replace("\"scratch_bytes\":262144", "\"scratch_bytes\":393216");
        let (regs, _, _, _) = compare(SERVE, &cur, 0.20);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "scratch_bytes");
    }

    #[test]
    fn scratch_shrink_is_not_a_regression() {
        let cur = SERVE.replace("\"scratch_bytes\":262144", "\"scratch_bytes\":131072");
        let (regs, _, _, _) = compare(SERVE, &cur, 0.20);
        assert!(regs.is_empty(), "halving scratch must pass");
    }

    #[test]
    fn fails_on_live_slot_growth() {
        // liveness pass losing coloring quality (2 -> 4 buffers) fails
        let cur = SERVE.replace("\"slots_live\":2,", "\"slots_live\":4,");
        let (regs, _, _, _) = compare(SERVE, &cur, 0.20);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "slots_live");
    }

    #[test]
    fn zero_memory_baseline_is_unfilled_sentinel() {
        // the http_open_loop row carries scratch_bytes:0 / slots_live:0
        // (freshly added, unfilled): any current value must be skipped
        let cur = SERVE
            .replace("\"scratch_bytes\":0,", "\"scratch_bytes\":999999999,")
            .replace("\"slots_live\":0,", "\"slots_live\":64,");
        let (regs, _, skipped, _) = compare(SERVE, &cur, 0.20);
        assert!(regs.is_empty(), "unfilled memory baselines must be skipped");
        assert!(skipped >= 2);
    }

    #[test]
    fn missing_rows_never_fail_but_are_counted() {
        // the KERNELS row has no counterpart in the SERVE baseline: no
        // regression, but it must surface as an unbaselined warning
        let (regs, _, _, unbaselined) = compare(SERVE, KERNELS, 0.20);
        assert!(regs.is_empty());
        assert_eq!(unbaselined, 1);
    }

    #[test]
    fn fully_baselined_run_has_no_warnings() {
        let (_, _, _, unbaselined) = compare(SERVE, SERVE, 0.20);
        assert_eq!(unbaselined, 0);
    }

    #[test]
    fn validate_accepts_emitter_output_and_rejects_junk() {
        assert!(validate("s", SERVE).is_ok());
        assert!(validate("k", KERNELS).is_ok());
        assert!(validate("e", "[]\n").is_err());
        assert!(validate("j", "{\"bench\":\"x\",\"config\":\"y\"}").is_err());
    }

    #[test]
    fn validate_flags_record_shaped_line_without_identity() {
        // a truncated/hand-mangled row would be silently dropped by
        // parse_records; --validate must reject the file instead
        let text = format!("{KERNELS}\n{{\"kernel\":\"xnor_gemm\",\"threa\n");
        let err = validate("m", &text).unwrap_err();
        assert!(err.contains("no identity fields"), "got: {err}");
    }
}
