//! Minimal property-testing harness (the offline registry has no
//! `proptest`). Deterministic seed sweep with failing-seed reporting; case
//! sizes grow across the sweep so the first failure is naturally small.
//! Used by the invariant tests in `rust/tests/prop_invariants.rs`.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint passed to the generator (e.g. tensor dims).
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xB01D, max_size: 96 }
    }
}

/// Context handed to each property case: an RNG plus a size hint.
pub struct Case<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
    pub index: usize,
}

impl Case<'_> {
    /// Dimension in [1, size].
    pub fn dim(&mut self) -> usize {
        1 + self.rng.below(self.size.max(1))
    }

    /// ±1 vector of length n.
    pub fn pm1_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.sign()).collect()
    }

    /// Standard-normal vector of length n.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal()).collect()
    }
}

/// Run `prop` over `cfg.cases` random cases. Panics with the failing seed
/// and case index on the first failure (re-run with that seed to debug).
pub fn forall<P>(name: &str, cfg: PropConfig, mut prop: P)
where
    P: FnMut(&mut Case<'_>) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        // sweep sizes: small cases first so failures shrink naturally
        let size = 1 + (cfg.max_size * (i + 1)) / cfg.cases;
        let mut case = Case { rng: &mut rng, size, index: i };
        if let Err(msg) = prop(&mut case) {
            panic!(
                "property '{name}' failed at case {i} (seed {case_seed:#x}, size {size}): {msg}"
            );
        }
    }
}

/// Elementwise closeness check returning a property-friendly Result.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", PropConfig::default(), |c| {
            let n = c.dim();
            if n >= 1 { Ok(()) } else { Err("dim 0".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn forall_reports_failures() {
        forall("fails", PropConfig { cases: 4, ..Default::default() }, |c| {
            if c.index < 2 { Ok(()) } else { Err("boom".into()) }
        });
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
    }
}
