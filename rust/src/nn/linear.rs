//! Dense FP linear layer (the paper keeps the first/last layers in FP and
//! optimizes them with Adam — §4 Experimental Setup).

use super::{Layer, LayerDesc, ParamRef, ParamStore, Value};
use crate::tensor::Tensor;
use crate::util::Rng;

/// y = x·Wᵀ + b with W (n_out × n_in) FP. Gradients accumulate in the
/// [`ParamStore`] under `<name>.w` / `<name>.b`.
pub struct Linear {
    pub n_in: usize,
    pub n_out: usize,
    pub w: Tensor,
    pub b: Tensor,
    name: String,
    cache_x: Option<Tensor>,
}

impl Linear {
    pub fn new(name: &str, n_in: usize, n_out: usize, rng: &mut Rng) -> Self {
        let std = (2.0 / n_in as f32).sqrt();
        Linear {
            n_in,
            n_out,
            w: Tensor::randn(&[n_out, n_in], std, rng),
            b: Tensor::zeros(&[n_out]),
            name: name.to_string(),
            cache_x: None,
        }
    }

    /// Store key of the weight parameter.
    pub fn w_key(&self) -> String {
        format!("{}.w", self.name)
    }

    /// Store key of the bias parameter.
    pub fn b_key(&self) -> String {
        format!("{}.b", self.name)
    }
}

impl Layer for Linear {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        // Accepts Bit input too (converted to ±1): the "real weights,
        // Boolean inputs" mixed case of Definition 3.5.
        let t = x.to_f32();
        let flat = t.view(&[t.shape[0], self.n_in]);
        let mut y = flat.matmul_bt(&self.w);
        for i in 0..y.rows() {
            for j in 0..self.n_out {
                *y.at2_mut(i, j) += self.b.data[j];
            }
        }
        if train {
            self.cache_x = Some(flat);
        }
        Value::F32(y)
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        let x = self.cache_x.as_ref().expect("backward before forward");
        store.accumulate(&self.w_key(), &z.matmul_at(x));
        store.accumulate(&self.b_key(), &z.sum_rows());
        z.matmul(&self.w)
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let (wk, bk) = (self.w_key(), self.b_key());
        vec![
            ParamRef::Real { name: wk, w: &mut self.w },
            ParamRef::Real { name: bk, w: &mut self.b },
        ]
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::Linear {
            name: self.name.clone(),
            n_in: self.n_in,
            n_out: self.n_out,
        }])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference check of the analytic gradient.
    #[test]
    fn gradients_match_finite_difference() {
        let mut rng = Rng::new(1);
        let mut l = Linear::new("fc", 6, 3, &mut rng);
        let mut store = ParamStore::new();
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        // scalar objective: sum of outputs squared / 2
        let y = l.forward(Value::F32(x.clone()), true).expect_f32("t");
        let z = y.clone(); // dL/dy = y for L = ||y||²/2
        let gx = l.backward(z, &mut store);
        let gw = store.grad("fc.w").unwrap().clone();
        let eps = 1e-3;
        let loss = |l: &mut Linear, x: &Tensor| -> f32 {
            let y = l.forward(Value::F32(x.clone()), false).expect_f32("t");
            0.5 * y.data.iter().map(|v| v * v).sum::<f32>()
        };
        // dL/dw numeric spot checks
        for &(i, j) in &[(0usize, 0usize), (2, 5), (1, 3)] {
            let orig = l.w.at2(i, j);
            *l.w.at2_mut(i, j) = orig + eps;
            let lp = loss(&mut l, &x);
            *l.w.at2_mut(i, j) = orig - eps;
            let lm = loss(&mut l, &x);
            *l.w.at2_mut(i, j) = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - gw.at2(i, j)).abs() < 2e-2, "w[{i}{j}]: {num} vs {}", gw.at2(i, j));
        }
        // dL/dx numeric spot check
        let mut x2 = x.clone();
        let orig = x2.at2(1, 2);
        *x2.at2_mut(1, 2) = orig + eps;
        let lp = loss(&mut l, &x2);
        *x2.at2_mut(1, 2) = orig - eps;
        let lm = loss(&mut l, &x2);
        let num = (lp - lm) / (2.0 * eps);
        assert!((num - gx.at2(1, 2)).abs() < 2e-2);
    }

    #[test]
    fn accepts_bit_input() {
        let mut rng = Rng::new(2);
        let mut l = Linear::new("fc", 8, 2, &mut rng);
        let x = Tensor::rand_pm1(&[3, 8], &mut rng);
        let y1 = l.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        let y2 = l.forward(Value::F32(x), false).expect_f32("t");
        assert!(y1.max_abs_diff(&y2) < 1e-6);
    }
}
