//! Neural-network layers: the paper's native Boolean layers (§3.1, §3.3)
//! plus the FP substrate (first/last layers, BN, pooling, losses) needed to
//! reproduce the experimental setup of §4.
//!
//! # Dataflow
//!
//! Values flowing forward are either dense f32 ([`Value::F32`]) or
//! bit-packed Boolean ([`Value::Bit`]) — the latter is what makes the
//! Boolean dataflow cheap (64 lanes per word). Backward signals are always
//! dense f32 tensors holding either the usual gradient (downstream FP
//! layer) or an (integer-valued) aggregated Boolean variation, matching
//! Fig. 2 of the paper; a Boolean layer with `bool_bprop` quantizes its
//! outgoing signal to ±1, which is exactly the Algorithm 6 case under the
//! Proposition A.2 embedding.
//!
//! # Backward rules
//!
//! Each layer implements its closed-form backward derived from the
//! variation calculus (`logic::variation`): there is no general autodiff
//! because Boolean layers have *variations*, not gradients — the chain
//! rule of Theorem 3.11 is what justifies composing them layer by layer.

mod activation;
mod bool_conv;
mod bool_linear;
mod conv;
mod describe;
mod linear;
mod loss;
mod norm;
mod params;
mod pool;
mod sequential;
mod value;

pub use activation::{BackwardScale, Binarize, ReLU, ThresholdAct};
pub(crate) use bool_conv::packed_im2col;
pub use bool_conv::BoolConv2d;
pub use bool_linear::BoolLinear;
pub use conv::Conv2d;
pub use describe::LayerDesc;
pub use linear::Linear;
pub use loss::{l1_loss, mse_loss, softmax_cross_entropy, softmax_cross_entropy_nchw, LossOut};
pub use norm::{BatchNorm1d, BatchNorm2d, LayerNorm, BN_EPS};
pub use params::{ParamId, ParamRef, ParamSlot, ParamStore};
pub use pool::{AvgPool2dGlobal, MaxPool2d};
pub use sequential::{Flatten, Residual, Sequential};
pub use value::Value;

use crate::tensor::Tensor;

/// A trainable layer. `forward` caches whatever `backward` needs; the
/// trainer guarantees the backward call matches the latest forward.
pub trait Layer: Send {
    /// Forward pass. `train` enables training-only behaviour (BN batch
    /// stats, caching for backward).
    fn forward(&mut self, x: Value, train: bool) -> Value;

    /// Backward pass: takes the downstream signal w.r.t. this layer's
    /// output, accumulates parameter votes/gradients into `store` (under
    /// the same names that [`Layer::params`] reports), returns the signal
    /// w.r.t. this layer's input. The trainer zeroes the store's grads
    /// once per step ([`ParamStore::zero_grads`]) before calling this.
    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor;

    /// Parameter references for the optimizers (stable order).
    fn params(&mut self) -> Vec<ParamRef<'_>> {
        Vec::new()
    }

    /// Human-readable name for logs and checkpoints.
    fn name(&self) -> String;

    /// Total number of trainable scalars (Boolean bits count as 1 each).
    fn param_count(&mut self) -> usize {
        self.params().iter().map(|p| p.len()).sum()
    }

    /// Non-trainable state that must survive checkpointing (running
    /// statistics: BN running mean/var, centered-threshold running mean).
    fn buffers(&mut self) -> Vec<(String, &mut Vec<f32>)> {
        Vec::new()
    }

    /// Architecture self-description for the forward-only serving stack:
    /// one [`LayerDesc`] per atomic layer (`Sequential` concatenates its
    /// children). `save_model` embeds the description in the checkpoint
    /// (`Record::Arch`) so `runtime::PackedGraph::load` can rebuild and
    /// serve the model without model-specific code. The default `None`
    /// means "not describable" — the checkpoint is still written, it is
    /// just not graph-servable.
    fn describe(&self) -> Option<Vec<LayerDesc>> {
        None
    }

    /// Non-batch input shape of the most recent forward, if the layer
    /// records one (the top-level [`Sequential`] does). `save_model`
    /// embeds it in `Record::Arch` so the serving graph knows how to
    /// interpret flat packed request rows (e.g. `[C, H, W]` for conv
    /// models).
    fn input_shape(&self) -> Option<Vec<usize>> {
        None
    }
}
