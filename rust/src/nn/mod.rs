//! Neural-network layers: the paper's native Boolean layers (§3.1, §3.3)
//! plus the FP substrate (first/last layers, BN, pooling, losses) needed to
//! reproduce the experimental setup of §4.
//!
//! # Dataflow
//!
//! Values flowing forward are either dense f32 ([`Value::F32`]) or
//! bit-packed Boolean ([`Value::Bit`]) — the latter is what makes the
//! Boolean dataflow cheap (64 lanes per word). Backward signals are always
//! dense f32 tensors holding either the usual gradient (downstream FP
//! layer) or an (integer-valued) aggregated Boolean variation, matching
//! Fig. 2 of the paper; a Boolean layer with `bool_bprop` quantizes its
//! outgoing signal to ±1, which is exactly the Algorithm 6 case under the
//! Proposition A.2 embedding.
//!
//! # Backward rules
//!
//! Each layer implements its closed-form backward derived from the
//! variation calculus (`logic::variation`): there is no general autodiff
//! because Boolean layers have *variations*, not gradients — the chain
//! rule of Theorem 3.11 is what justifies composing them layer by layer.

mod activation;
mod bool_conv;
mod bool_linear;
mod conv;
mod linear;
mod loss;
mod norm;
mod pool;
mod sequential;
mod value;

pub use activation::{BackwardScale, Binarize, ReLU, ThresholdAct};
pub use bool_conv::BoolConv2d;
pub use bool_linear::BoolLinear;
pub use conv::Conv2d;
pub use linear::Linear;
pub use loss::{l1_loss, mse_loss, softmax_cross_entropy, softmax_cross_entropy_nchw, LossOut};
pub use norm::{BatchNorm1d, BatchNorm2d, LayerNorm};
pub use pool::{AvgPool2dGlobal, MaxPool2d};
pub use sequential::{Flatten, Residual, Sequential};
pub use value::Value;

use crate::tensor::{BitMatrix, Tensor};

/// Mutable references to a layer's parameters, grouped by kind so the
/// coordinator can route them to the right optimizer (Boolean optimizer
/// for `Bool`, Adam for `Real` — the paper's §4 setup).
pub enum ParamRef<'a> {
    /// Native Boolean parameter: packed bits + vote buffer + accumulator
    /// m_t (Eq. 10) + per-tensor unchanged-ratio β_t (Eq. 11).
    Bool {
        name: String,
        bits: &'a mut BitMatrix,
        grad: &'a mut Tensor,
        accum: &'a mut Tensor,
        ratio: &'a mut f32,
    },
    /// FP parameter with its gradient buffer.
    Real {
        name: String,
        w: &'a mut Tensor,
        grad: &'a mut Tensor,
    },
}

impl ParamRef<'_> {
    pub fn name(&self) -> &str {
        match self {
            ParamRef::Bool { name, .. } => name,
            ParamRef::Real { name, .. } => name,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ParamRef::Bool { bits, .. } => bits.rows * bits.cols,
            ParamRef::Real { w, .. } => w.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A trainable layer. `forward` caches whatever `backward` needs; the
/// trainer guarantees the backward call matches the latest forward.
pub trait Layer: Send {
    /// Forward pass. `train` enables training-only behaviour (BN batch
    /// stats, caching for backward).
    fn forward(&mut self, x: Value, train: bool) -> Value;

    /// Backward pass: takes the downstream signal w.r.t. this layer's
    /// output, accumulates parameter votes/gradients, returns the signal
    /// w.r.t. this layer's input.
    fn backward(&mut self, z: Tensor) -> Tensor;

    /// Parameter references for the optimizers (stable order).
    fn params(&mut self) -> Vec<ParamRef<'_>> {
        Vec::new()
    }

    /// Reset accumulated votes/gradients (before each step).
    fn zero_grads(&mut self) {}

    /// Human-readable name for logs and checkpoints.
    fn name(&self) -> String;

    /// Total number of trainable scalars (Boolean bits count as 1 each).
    fn param_count(&mut self) -> usize {
        self.params().iter().map(|p| p.len()).sum()
    }

    /// Non-trainable state that must survive checkpointing (running
    /// statistics: BN running mean/var, centered-threshold running mean).
    fn buffers(&mut self) -> Vec<(String, &mut Vec<f32>)> {
        Vec::new()
    }
}
