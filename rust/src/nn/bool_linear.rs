//! The paper's Boolean linear layer (Eq. 1/3) with xnor logic, native
//! Boolean weights and the Boolean backward of §3.3 / Appendix B.

use super::{Layer, LayerDesc, ParamRef, ParamStore, Value};
use crate::tensor::{BitMatrix, Tensor};
use crate::util::Rng;

/// Fully-connected Boolean layer: `n_out` neurons of fan-in `n_in`.
///
/// Forward (Eq. 3): `s_kj = b_j + Σ_i xnor(x_ki, w_ji)` — computed as
/// XOR+POPCNT on packed words for Boolean inputs, or as the mixed-type
/// neuron of Definition 3.5 (`s = x · e(W)ᵀ`) for real inputs.
///
/// Backward (Eqs. 4–8, Algorithms 6/7): with downstream signal `z`,
/// `q_W = zᵀ e(X)` (vote over the batch) and `g_X = z e(W)` (vote over the
/// outputs). With `bool_bprop`, `g_X` is sign-quantized before being passed
/// upstream (the Boolean-signal case of Fig. 2). Votes go to the
/// [`ParamStore`] under `<name>.weight` / `<name>.bias`; the layer itself
/// owns nothing but its packed weights.
pub struct BoolLinear {
    pub n_in: usize,
    pub n_out: usize,
    /// Packed weights: `n_out` rows of `n_in` bits (bit=1 ↔ T ↔ +1).
    pub weights: BitMatrix,
    /// Optional Boolean bias (pairs with a constant-T input).
    pub bias: Option<BitMatrix>,
    /// Quantize the upstream signal to ±1 (Algorithm 6) instead of passing
    /// the real-valued vote (Algorithm 7).
    pub bool_bprop: bool,
    name: String,
    // --- cached forward inputs (allocations reused across steps) ---
    cache_bits: Option<BitMatrix>,
    cache_f32: Option<Tensor>,
    // --- reusable scratch (steady-state training allocates nothing here) ---
    /// Weight-vote buffer for Eq. (7), handed to `store.accumulate`.
    scratch_qw: Tensor,
    /// Decoded ±1 bias row (`n_out` lanes), refreshed per forward.
    bias_row: Vec<f32>,
}

impl BoolLinear {
    pub fn new(name: &str, n_in: usize, n_out: usize, rng: &mut Rng) -> Self {
        BoolLinear {
            n_in,
            n_out,
            weights: BitMatrix::random(n_out, n_in, rng),
            bias: None,
            bool_bprop: false,
            name: name.to_string(),
            cache_bits: None,
            cache_f32: None,
            scratch_qw: Tensor::zeros(&[0]),
            bias_row: Vec::new(),
        }
    }

    pub fn with_bias(mut self, rng: &mut Rng) -> Self {
        self.bias = Some(BitMatrix::random(1, self.n_out, rng));
        self
    }

    pub fn with_bool_bprop(mut self) -> Self {
        self.bool_bprop = true;
        self
    }

    /// Store key of the weight parameter.
    pub fn weight_key(&self) -> String {
        format!("{}.weight", self.name)
    }

    /// Store key of the bias parameter.
    pub fn bias_key(&self) -> String {
        format!("{}.bias", self.name)
    }

    /// Add the Boolean bias: the ±1 row is decoded ONCE per call via the
    /// byte LUT ([`BitMatrix::decode_pm1_row`]) into a reused scratch row,
    /// then streamed over the batch — not one `BitMatrix::pm1` bit probe
    /// per output element per batch row.
    fn add_bias(&mut self, s: &mut Tensor) {
        if let Some(b) = &self.bias {
            let n = self.n_out;
            self.bias_row.resize(n, 0.0);
            b.decode_pm1_row(0, &mut self.bias_row);
            let rows = s.rows();
            for i in 0..rows {
                let srow = &mut s.data[i * n..(i + 1) * n];
                for (sv, &bv) in srow.iter_mut().zip(&self.bias_row) {
                    *sv += bv;
                }
            }
        }
    }
}

impl Layer for BoolLinear {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let mut s = match &x {
            Value::Bit { bits, shape } => {
                assert_eq!(shape.iter().skip(1).product::<usize>(), self.n_in,
                    "{}: fan-in mismatch {:?}", self.name, shape);
                let s = bits.xnor_gemm(&self.weights);
                if train {
                    // clone_from reuses the cached allocation across steps
                    match &mut self.cache_bits {
                        Some(c) => c.clone_from(bits),
                        slot => *slot = Some(bits.clone()),
                    }
                    self.cache_f32 = None;
                }
                s
            }
            Value::F32(t) => {
                // Mixed-type neuron (Definition 3.5): real inputs, Boolean
                // weights — s = x · e(W)ᵀ via a dense matmul against the
                // unpacked ±1 weight view.
                let flat = t.view(&[t.shape[0], self.n_in]);
                let wd = self.weights.to_pm1();
                let s = flat.matmul_bt(&wd);
                if train {
                    self.cache_f32 = Some(flat);
                    self.cache_bits = None;
                }
                s
            }
        };
        self.add_bias(&mut s);
        Value::F32(s)
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        assert_eq!(z.cols(), self.n_out, "{}: bad z", self.name);
        let weight_key = self.weight_key();
        // Weight vote, Eq. (7): q_W += zᵀ · e(X) — computed into the
        // layer's reusable scratch, then added to the store.
        if let Some(bits) = &self.cache_bits {
            bits.backward_weight_into(&z, &mut self.scratch_qw);
        } else if let Some(xf) = &self.cache_f32 {
            self.scratch_qw = z.matmul_at(xf); // zᵀ (n_out×B) · x (B×n_in)
        } else {
            panic!("{}: backward before forward", self.name)
        }
        store.accumulate(&weight_key, &self.scratch_qw);
        // Bias vote: pairs with constant TRUE input ⇒ q_b = Σ_k z.
        if self.bias.is_some() {
            let qb = z.sum_rows().reshape(&[1, self.n_out]);
            store.accumulate(&self.bias_key(), &qb);
        }
        // Upstream signal, Eq. (8): g_X = z · e(W).
        let mut g_x = self.weights.backward_input(&z);
        if self.bool_bprop {
            // Algorithm 6: the upstream layer receives a Boolean signal —
            // sign-quantize in the embedded domain.
            g_x = g_x.sign_pm1();
        }
        g_x
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let (weight_name, bias_name) = (self.weight_key(), self.bias_key());
        let mut v = vec![ParamRef::Bool { name: weight_name, bits: &mut self.weights }];
        if let Some(b) = &mut self.bias {
            v.push(ParamRef::Bool { name: bias_name, bits: b });
        }
        v
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::BoolLinear {
            name: self.name.clone(),
            n_in: self.n_in,
            n_out: self.n_out,
            bias: self.bias.is_some(),
        }])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_matches_embedded_matmul() {
        let mut rng = Rng::new(1);
        let mut l = BoolLinear::new("bl", 70, 12, &mut rng);
        let x = Tensor::rand_pm1(&[5, 70], &mut rng);
        let out = l.forward(Value::bit_from_pm1(&x), true).expect_f32("t");
        let want = x.matmul_bt(&l.weights.to_pm1());
        assert_eq!(out.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn forward_mixed_type_real_inputs() {
        // Definition 3.5: Boolean weights, real inputs.
        let mut rng = Rng::new(2);
        let mut l = BoolLinear::new("bl", 33, 7, &mut rng);
        let x = Tensor::randn(&[4, 33], 1.0, &mut rng);
        let out = l.forward(Value::F32(x.clone()), true).expect_f32("t");
        let want = x.matmul_bt(&l.weights.to_pm1());
        assert!(out.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn backward_votes_match_reference() {
        let mut rng = Rng::new(3);
        let mut l = BoolLinear::new("bl", 48, 9, &mut rng);
        let mut store = ParamStore::new();
        let x = Tensor::rand_pm1(&[6, 48], &mut rng);
        let _ = l.forward(Value::bit_from_pm1(&x), true);
        let z = Tensor::randn(&[6, 9], 1.0, &mut rng);
        let g_x = l.backward(z.clone(), &mut store);
        // reference: g_X = z·e(W), q_W = zᵀ·e(X)
        let wd = l.weights.to_pm1();
        assert!(g_x.max_abs_diff(&z.matmul(&wd)) < 1e-4);
        let q_ref = z.matmul_at(&x);
        assert!(store.grad("bl.weight").unwrap().max_abs_diff(&q_ref) < 1e-4);
    }

    #[test]
    fn bool_bprop_signs_the_signal() {
        let mut rng = Rng::new(4);
        let mut l = BoolLinear::new("bl", 32, 8, &mut rng).with_bool_bprop();
        let mut store = ParamStore::new();
        let x = Tensor::rand_pm1(&[3, 32], &mut rng);
        let _ = l.forward(Value::bit_from_pm1(&x), true);
        let g = l.backward(Tensor::randn(&[3, 8], 1.0, &mut rng), &mut store);
        assert!(g.data.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn bias_shifts_by_pm1() {
        let mut rng = Rng::new(5);
        let mut l = BoolLinear::new("bl", 16, 4, &mut rng).with_bias(&mut rng);
        let x = Tensor::rand_pm1(&[2, 16], &mut rng);
        let with_bias = l.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        let b = l.bias.take().unwrap();
        let without = l.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        for i in 0..2 {
            for j in 0..4 {
                assert_eq!(with_bias.at2(i, j) - without.at2(i, j), b.pm1(0, j));
            }
        }
    }

    #[test]
    fn grads_accumulate_in_store_and_zero() {
        let mut rng = Rng::new(6);
        let mut l = BoolLinear::new("bl", 16, 4, &mut rng);
        let mut store = ParamStore::new();
        let x = Tensor::rand_pm1(&[2, 16], &mut rng);
        let _ = l.forward(Value::bit_from_pm1(&x), true);
        let z = Tensor::full(&[2, 4], 1.0);
        l.backward(z.clone(), &mut store);
        let g1 = store.grad("bl.weight").unwrap().clone();
        let _ = l.forward(Value::bit_from_pm1(&x), true);
        l.backward(z, &mut store);
        assert!(store.grad("bl.weight").unwrap().max_abs_diff(&g1.scale(2.0)) < 1e-5);
        store.zero_grads();
        assert_eq!(store.grad("bl.weight").unwrap().sum(), 0.0);
    }
}
